"""Transformer LM: the paper's NLP workload (next-word prediction).

Paper: lightweight ALBERT fine-tuned on Reddit, evaluated by perplexity,
with ELBERT-style per-layer early exits defining the window blocks.
Here: a small causal transformer over a synthetic Markov token stream
(DESIGN.md §4): block 0 = embeddings (+learned positions), blocks 1..L =
transformer layers, with an early-exit LM head (Dense d->V) at every block
boundary.  Dense projections route through the Pallas matmul kernel.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .base import Layout, ModelDef, dense_apply, dense_flops


def build(vocab: int = 512, seq: int = 32, d: int = 64, layers: int = 4,
          heads: int = 4, mlp_mult: int = 4, batch: int = 8,
          seed: int = 5) -> ModelDef:
    lay = Layout()
    dh = d // heads
    dm = d * mlp_mult

    # Block 0: token + position embeddings.
    lay.add("block0/embed/tok", (vocab, d), 0, flops_fwd=float(seq * d),
            init="embed")
    lay.add("block0/embed/pos", (seq, d), 0, flops_fwd=float(seq * d),
            init="embed")
    lay.add("head0/w", (d, vocab), 0,
            flops_fwd=dense_flops(d, vocab, seq), is_head=True, init_scale=0.1)
    lay.add("head0/b", (vocab,), 0, flops_fwd=float(vocab), is_head=True,
            init="zeros")

    for i in range(layers):
        b = i + 1
        pref = f"block{b}"
        res_scale = 1.0 / (2.0 * layers) ** 0.5  # GPT-2 style residual init
        for nm, (di, do) in {"q": (d, d), "k": (d, d), "v": (d, d),
                             "o": (d, d)}.items():
            lay.add(f"{pref}/attn/{nm}/w", (di, do), b,
                    flops_fwd=dense_flops(di, do, seq),
                    init_scale=res_scale if nm == "o" else 1.0)
            lay.add(f"{pref}/attn/{nm}/b", (do,), b, flops_fwd=float(do),
                    init="zeros")
        lay.add(f"{pref}/ln1/g", (d,), b, flops_fwd=float(seq * d),
                init="zeros")  # stored as (gain - 1): init 0 => gain 1
        lay.add(f"{pref}/mlp/fc1/w", (d, dm), b,
                flops_fwd=dense_flops(d, dm, seq))
        lay.add(f"{pref}/mlp/fc1/b", (dm,), b, flops_fwd=float(dm),
                init="zeros")
        lay.add(f"{pref}/mlp/fc2/w", (dm, d), b,
                flops_fwd=dense_flops(dm, d, seq), init_scale=res_scale)
        lay.add(f"{pref}/mlp/fc2/b", (d,), b, flops_fwd=float(d),
                init="zeros")
        lay.add(f"{pref}/ln2/g", (d,), b, flops_fwd=float(seq * d),
                init="zeros")
        lay.add(f"head{b}/w", (d, vocab), b,
                flops_fwd=dense_flops(d, vocab, seq), is_head=True, init_scale=0.1)
        lay.add(f"head{b}/b", (vocab,), b, flops_fwd=float(vocab),
                is_head=True, init="zeros")

    def layernorm(x, gain_minus_one):
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.var(x, axis=-1, keepdims=True)
        return (x - mu) * jax.lax.rsqrt(var + 1e-5) * (1.0 + gain_minus_one)

    causal = jnp.tril(jnp.ones((seq, seq), jnp.float32))

    def attention(views, pref, x, bsz):
        def proj(nm, t):
            flat = t.reshape(bsz * seq, d)
            out = dense_apply(views, f"{pref}/attn/{nm}", flat)
            return out.reshape(bsz, seq, d)

        q, k, v = proj("q", x), proj("k", x), proj("v", x)
        q = q.reshape(bsz, seq, heads, dh).transpose(0, 2, 1, 3)
        k = k.reshape(bsz, seq, heads, dh).transpose(0, 2, 1, 3)
        v = v.reshape(bsz, seq, heads, dh).transpose(0, 2, 1, 3)
        att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(dh))
        att = jnp.where(causal[None, None] > 0, att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        y = jnp.einsum("bhqk,bhkd->bhqd", att, v)
        y = y.transpose(0, 2, 1, 3).reshape(bsz, seq, d)
        return proj("o", y)

    def forward(views: Dict[str, jax.Array], x: jax.Array, exit_e: int):
        # x: [bsz, seq] int32 token ids (passed as f32 and cast).
        bsz = x.shape[0]
        ids = x.astype(jnp.int32)
        h = views["block0/embed/tok"][ids] + views["block0/embed/pos"][None]
        for i in range(exit_e - 1):
            b = i + 1
            pref = f"block{b}"
            h = h + attention(views, pref,
                              layernorm(h, views[f"{pref}/ln1/g"]), bsz)
            hm = layernorm(h, views[f"{pref}/ln2/g"])
            hm = hm.reshape(bsz * seq, d)
            hm = jax.nn.relu(dense_apply(views, f"{pref}/mlp/fc1", hm))
            hm = dense_apply(views, f"{pref}/mlp/fc2", hm)
            h = h + hm.reshape(bsz, seq, d)
        flat = h.reshape(bsz * seq, d)
        return dense_apply(views, f"head{exit_e - 1}", flat)

    return ModelDef(
        name="tinylm_reddit", layout=lay, num_blocks=layers + 1, batch=batch,
        input_shape=(seq,), num_classes=vocab, label_len=batch * seq,
        task="lm", forward=forward, seed=seed)
