"""L2 model framework: flat-parameter layouts, blocks, and early exits.

Every model in the zoo is expressed over ONE flat f32 parameter vector so
the rust coordinator can treat parameters, gradients, masks, and
aggregation as dense `Vec<f32>` operations.  A `Layout` records, for each
tensor: its flat offset, shape, owning *block* (the unit FedEL's sliding
window moves over), whether it is an early-exit head, and the forward FLOPs
of the op it parameterizes (per example) — the raw material for the
ElasticTrainer tensor timing model on the rust side.

The train step lowered per exit `e` is exactly the FedEL window semantics:
forward runs through blocks `0..e-1` plus head `e-1` ONLY (blocks >= e are
absent from the graph, so they cost nothing, unlike plain ElasticTrainer);
backward computes gradients for everything in the forward graph (the
chain-rule dependency of Limitation #1), and *freezing* is the elementwise
`mask` applied by the L1 masked-SGD kernel.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp


@dataclasses.dataclass
class TensorSpec:
    name: str
    shape: Tuple[int, ...]
    offset: int
    size: int
    block: int
    is_head: bool
    flops_fwd: float  # forward FLOPs (per example) of the op this tensor feeds
    init: str         # "he" | "zeros" | "embed"
    init_scale: float = 1.0  # extra multiplier on the init std (residual scaling)

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "shape": list(self.shape),
            "offset": self.offset,
            "size": self.size,
            "block": self.block,
            "is_head": self.is_head,
            "flops_fwd": self.flops_fwd,
        }


class Layout:
    """Accumulates TensorSpecs and assigns flat offsets."""

    def __init__(self) -> None:
        self.tensors: List[TensorSpec] = []
        self._offset = 0

    def add(self, name: str, shape: Sequence[int], block: int, *,
            flops_fwd: float, is_head: bool = False,
            init: str = "he", init_scale: float = 1.0) -> int:
        size = int(np.prod(shape))
        spec = TensorSpec(name, tuple(shape), self._offset, size, block,
                          is_head, float(flops_fwd), init, init_scale)
        self.tensors.append(spec)
        self._offset += size
        return len(self.tensors) - 1

    @property
    def param_count(self) -> int:
        return self._offset

    def views(self, flat: jax.Array) -> Dict[str, jax.Array]:
        """Slice the flat vector into named, shaped tensor views."""
        return {
            t.name: jax.lax.dynamic_slice_in_dim(flat, t.offset, t.size)
            .reshape(t.shape)
            for t in self.tensors
        }

    def init_flat(self, seed: int) -> np.ndarray:
        """Deterministic initialization of the full flat vector."""
        rng = np.random.RandomState(seed)
        flat = np.zeros(self.param_count, dtype=np.float32)
        for t in self.tensors:
            if t.init == "zeros":
                continue
            if t.init == "embed":
                w = rng.randn(*t.shape).astype(np.float32) * 0.02
            else:  # he
                fan_in = int(np.prod(t.shape[:-1])) if len(t.shape) > 1 else t.shape[0]
                std = math.sqrt(2.0 / max(fan_in, 1))
                w = rng.randn(*t.shape).astype(np.float32) * std
            flat[t.offset:t.offset + t.size] = w.reshape(-1) * t.init_scale
        return flat

    def segment_sums(self, elem: jax.Array) -> jax.Array:
        """Per-tensor sums of an elementwise [P] vector -> [K]."""
        return jnp.stack([
            jnp.sum(jax.lax.dynamic_slice_in_dim(elem, t.offset, t.size))
            for t in self.tensors
        ])


@dataclasses.dataclass
class ModelDef:
    """A zoo entry: layout + forward + task metadata.

    forward(views, x, exit_e) must only touch tensors of blocks < exit_e
    and the head attached to block exit_e - 1, and must return logits of
    shape [label_len, num_classes].
    """

    name: str
    layout: Layout
    num_blocks: int
    batch: int
    input_shape: Tuple[int, ...]   # per-example
    num_classes: int
    label_len: int                 # rows of y per batch (B, or B*T for LM)
    task: str                      # "classification" | "lm"
    forward: Callable[[Dict[str, jax.Array], jax.Array, int], jax.Array]
    seed: int = 0

    @property
    def param_count(self) -> int:
        return self.layout.param_count

    def batched_input_shape(self) -> Tuple[int, ...]:
        return (self.batch, *self.input_shape)

    def block_tensor_ids(self) -> List[List[int]]:
        out: List[List[int]] = [[] for _ in range(self.num_blocks)]
        for i, t in enumerate(self.layout.tensors):
            out[t.block].append(i)
        return out

    def to_manifest(self) -> dict:
        blocks = []
        ids = self.block_tensor_ids()
        for b in range(self.num_blocks):
            flops = sum(self.layout.tensors[i].flops_fwd for i in ids[b]
                        if not self.layout.tensors[i].is_head)
            blocks.append({"id": b, "tensor_ids": ids[b], "flops_fwd": flops})
        return {
            "model": self.name,
            "batch": self.batch,
            "input_shape": list(self.input_shape),
            "num_classes": self.num_classes,
            "label_len": self.label_len,
            "task": self.task,
            "param_count": self.param_count,
            "num_tensors": len(self.layout.tensors),
            "num_blocks": self.num_blocks,
            "tensors": [t.to_json() for t in self.layout.tensors],
            "blocks": blocks,
            "exits": list(range(1, self.num_blocks + 1)),
        }


# ---------------------------------------------------------------------------
# Train / eval step builders (shared by every model).
# ---------------------------------------------------------------------------

def make_train_step(model: ModelDef, exit_e: int):
    """Build the masked-SGD train step for early exit `exit_e` (1..B).

    Signature (all f32 unless noted):
      (params [P], x [batch, ...], y [label_len] i32, mask [P], lr [])
        -> (new_params [P], loss [], tensor_sq_grads [K])
    """
    from ..kernels import masked_sgd as ms
    from ..kernels import softmax_xent as sx

    def loss_fn(params, x, y):
        views = model.layout.views(params)
        logits = model.forward(views, x, exit_e)
        return sx.mean_xent(logits, y)

    def step(params, x, y, mask, lr):
        loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
        new_params, sq = ms.masked_sgd(params, grads, mask, lr)
        return new_params, loss, model.layout.segment_sums(sq)

    return step


def make_eval_step(model: ModelDef):
    """Full-model eval: (params, x, y) -> (metric_sum, loss_sum).

    metric_sum = #correct rows (classification) == also #correct next-token
    predictions for the LM; loss_sum = summed xent, so the rust side can
    compute accuracy = metric/rows and perplexity = exp(loss/rows).
    """
    from ..kernels import softmax_xent as sx

    def step(params, x, y):
        views = model.layout.views(params)
        logits = model.forward(views, x, model.num_blocks)
        loss, _ = sx.softmax_xent(logits, y)
        pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        correct = jnp.sum((pred == y.astype(jnp.int32)).astype(jnp.float32))
        return correct, jnp.sum(loss)

    return step


# ---------------------------------------------------------------------------
# Shared layer helpers.
# ---------------------------------------------------------------------------

def dense_apply(views: Dict[str, jax.Array], name: str, x: jax.Array,
                *, use_pallas: bool = True) -> jax.Array:
    """x @ W + b through the Pallas dense kernel."""
    w = views[f"{name}/w"]
    b = views[f"{name}/b"]
    if use_pallas:
        from ..kernels.matmul import dense as pallas_dense
        return pallas_dense(x, w) + b
    return jnp.matmul(x, w) + b


def conv2d(views: Dict[str, jax.Array], name: str, x: jax.Array,
           stride: int = 1) -> jax.Array:
    """NHWC 3x3 same conv + bias (XLA-native; see DESIGN.md §2)."""
    w = views[f"{name}/w"]   # HWIO
    b = views[f"{name}/b"]
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def conv2d_1x1(views: Dict[str, jax.Array], name: str, x: jax.Array,
               stride: int = 1) -> jax.Array:
    w = views[f"{name}/w"]
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y


def maxpool2(x: jax.Array) -> jax.Array:
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                 (1, 2, 2, 1), "VALID")


def gap(x: jax.Array) -> jax.Array:
    """Global average pool NHWC -> [N, C]."""
    return jnp.mean(x, axis=(1, 2))


def conv_flops(h: int, w: int, k: int, cin: int, cout: int) -> float:
    return 2.0 * h * w * k * k * cin * cout


def dense_flops(din: int, dout: int, rows: int = 1) -> float:
    return 2.0 * din * dout * rows
