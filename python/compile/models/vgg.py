"""VGG-style chain CNN: the paper's image-classification workload.

Paper: VGG16 on CIFAR10 / Tiny ImageNet.  Here: the same chain-of-conv
architecture scaled for CPU-PJRT training (DESIGN.md §4 substitutions) —
eight conv blocks over 32x32x3 inputs, a maxpool every second block, an
early-exit head (GAP -> Dense) at every block boundary.  In a chain network
every layer is its own window block, exactly the paper's Sec. 4.1 choice
for VGG16.

`vgg_cifar`  : 10 classes (CIFAR10-like)
`vgg_tinyin` : 64 classes (Tiny-ImageNet-like)
"""

from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp

from .base import (Layout, ModelDef, conv2d, conv_flops, dense_apply,
                   dense_flops, gap, maxpool2)

# (channels, pool-after-block?) per block; spatial starts at 32x32.
PLAN = [(8, False), (8, True), (16, False), (16, True),
        (32, False), (32, True), (64, False), (64, True)]


def build(name: str = "vgg_cifar", num_classes: int = 10, batch: int = 16,
          seed: int = 2, plan: List = None) -> ModelDef:
    plan = plan or PLAN
    lay = Layout()
    h = w = 32
    cin = 3
    spatial = []
    for b, (cout, pool) in enumerate(plan):
        lay.add(f"block{b}/conv/w", (3, 3, cin, cout), b,
                flops_fwd=conv_flops(h, w, 3, cin, cout))
        lay.add(f"block{b}/conv/b", (cout,), b,
                flops_fwd=float(h * w * cout), init="zeros")
        if pool:
            h, w = h // 2, w // 2
        spatial.append((h, w))
        # Early-exit head: GAP -> dense(cout -> classes).
        lay.add(f"head{b}/w", (cout, num_classes), b,
                flops_fwd=dense_flops(cout, num_classes), is_head=True, init_scale=0.1)
        lay.add(f"head{b}/b", (num_classes,), b,
                flops_fwd=float(num_classes), is_head=True, init="zeros")
        cin = cout

    def forward(views: Dict[str, jax.Array], x: jax.Array, exit_e: int):
        hmap = x
        for b in range(exit_e):
            hmap = jax.nn.relu(conv2d(views, f"block{b}/conv", hmap))
            if plan[b][1]:
                hmap = maxpool2(hmap)
        pooled = gap(hmap)
        return dense_apply(views, f"head{exit_e - 1}", pooled)

    return ModelDef(
        name=name, layout=lay, num_blocks=len(plan), batch=batch,
        input_shape=(32, 32, 3), num_classes=num_classes, label_len=batch,
        task="classification", forward=forward, seed=seed)


def build_cifar(batch: int = 16) -> ModelDef:
    return build("vgg_cifar", num_classes=10, batch=batch, seed=2)


def build_tinyin(batch: int = 16) -> ModelDef:
    return build("vgg_tinyin", num_classes=64, batch=batch, seed=3)
