"""MLP zoo entry: the fast-path model for tests, quickstart, and CI.

Six Dense+ReLU blocks over a 64-d synthetic feature vector, an early-exit
head (Dense -> classes) after every block.  Small enough that a full FL
experiment runs in seconds, yet exercises every FedEL code path (blocks,
exits, masks, importance).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .base import Layout, ModelDef, dense_apply, dense_flops


def build(num_blocks: int = 6, width: int = 64, num_classes: int = 10,
          batch: int = 32, in_dim: int = 64, seed: int = 1) -> ModelDef:
    lay = Layout()
    dims = [in_dim] + [width] * num_blocks
    for b in range(num_blocks):
        lay.add(f"block{b}/dense/w", (dims[b], dims[b + 1]), b,
                flops_fwd=dense_flops(dims[b], dims[b + 1]))
        lay.add(f"block{b}/dense/b", (dims[b + 1],), b,
                flops_fwd=float(dims[b + 1]), init="zeros")
        # Early-exit head attached to block b (head b == exit b+1).
        lay.add(f"head{b}/w", (dims[b + 1], num_classes), b,
                flops_fwd=dense_flops(dims[b + 1], num_classes), is_head=True, init_scale=0.1)
        lay.add(f"head{b}/b", (num_classes,), b,
                flops_fwd=float(num_classes), is_head=True, init="zeros")

    def forward(views: Dict[str, jax.Array], x: jax.Array, exit_e: int):
        h = x
        for b in range(exit_e):
            h = jax.nn.relu(dense_apply(views, f"block{b}/dense", h))
        return dense_apply(views, f"head{exit_e - 1}", h)

    return ModelDef(
        name="mlp", layout=lay, num_blocks=num_blocks, batch=batch,
        input_shape=(in_dim,), num_classes=num_classes, label_len=batch,
        task="classification", forward=forward, seed=seed)
