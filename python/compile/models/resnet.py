"""Residual CNN: the paper's speech-recognition workload.

Paper: ResNet50 on Google Speech Commands (35-way keyword spotting).
Here: a residual network over 32x32x1 synthetic mel-spectrogram-like
inputs (DESIGN.md §4).  Following the paper's Sec. 4.1 blocking rule for
residual architectures, *each residual unit is one window block* (the stem
conv is its own block), so the sliding window never splits a skip
connection.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .base import (Layout, ModelDef, conv2d, conv2d_1x1, conv_flops,
                   dense_apply, dense_flops, gap)

# (cout, stride) per residual block after the stem.
RES_PLAN = [(16, 1), (32, 2), (32, 1), (64, 2), (64, 1)]


def build(num_classes: int = 35, batch: int = 16, seed: int = 4) -> ModelDef:
    lay = Layout()
    h = w = 32

    # Block 0: stem conv 1 -> 16.
    lay.add("block0/conv/w", (3, 3, 1, 16), 0,
            flops_fwd=conv_flops(h, w, 3, 1, 16))
    lay.add("block0/conv/b", (16,), 0, flops_fwd=float(h * w * 16),
            init="zeros")
    lay.add("head0/w", (16, num_classes), 0,
            flops_fwd=dense_flops(16, num_classes), is_head=True, init_scale=0.1)
    lay.add("head0/b", (num_classes,), 0, flops_fwd=float(num_classes),
            is_head=True, init="zeros")

    cin = 16
    dims = []
    for i, (cout, stride) in enumerate(RES_PLAN):
        b = i + 1
        if stride == 2:
            h, w = h // 2, w // 2
        lay.add(f"block{b}/conv1/w", (3, 3, cin, cout), b,
                flops_fwd=conv_flops(h, w, 3, cin, cout))
        lay.add(f"block{b}/conv1/b", (cout,), b,
                flops_fwd=float(h * w * cout), init="zeros")
        # conv2 starts near zero so each residual unit begins ~identity
        # (fixup-style; no batchnorm in the zoo).
        lay.add(f"block{b}/conv2/w", (3, 3, cout, cout), b,
                flops_fwd=conv_flops(h, w, 3, cout, cout), init_scale=0.1)
        lay.add(f"block{b}/conv2/b", (cout,), b,
                flops_fwd=float(h * w * cout), init="zeros")
        if cin != cout or stride != 1:
            lay.add(f"block{b}/skip/w", (1, 1, cin, cout), b,
                    flops_fwd=conv_flops(h, w, 1, cin, cout))
        lay.add(f"head{b}/w", (cout, num_classes), b,
                flops_fwd=dense_flops(cout, num_classes), is_head=True, init_scale=0.1)
        lay.add(f"head{b}/b", (num_classes,), b, flops_fwd=float(num_classes),
                is_head=True, init="zeros")
        dims.append((cin, cout, stride))
        cin = cout

    def forward(views: Dict[str, jax.Array], x: jax.Array, exit_e: int):
        hmap = jax.nn.relu(conv2d(views, "block0/conv", x))
        for i, (ci, co, stride) in enumerate(dims):
            b = i + 1
            if b >= exit_e:
                break
            y = jax.nn.relu(conv2d(views, f"block{b}/conv1", hmap,
                                   stride=stride))
            y = conv2d(views, f"block{b}/conv2", y)
            if ci != co or stride != 1:
                skip = conv2d_1x1(views, f"block{b}/skip", hmap,
                                  stride=stride)
            else:
                skip = hmap
            hmap = jax.nn.relu(y + skip)
        pooled = gap(hmap)
        return dense_apply(views, f"head{exit_e - 1}", pooled)

    return ModelDef(
        name="resnet_speech", layout=lay, num_blocks=len(RES_PLAN) + 1,
        batch=batch, input_shape=(32, 32, 1), num_classes=num_classes,
        label_len=batch, task="classification", forward=forward, seed=seed)
