"""L2 model zoo: the four FedEL workloads + the fast MLP test model.

Names match the paper's tasks (DESIGN.md §4 lists the substitutions):
  mlp           — fast-path model for tests/quickstart
  vgg_cifar     — VGG-style chain CNN, CIFAR10-like (10 classes)
  vgg_tinyin    — same, Tiny-ImageNet-like (64 classes)
  resnet_speech — residual CNN, Google-Speech-like (35 classes)
  tinylm_reddit — causal transformer LM, Reddit-like (perplexity)
"""

from __future__ import annotations

from typing import Callable, Dict

from .base import Layout, ModelDef, TensorSpec, make_eval_step, make_train_step
from . import mlp, resnet, tinylm, vgg

ZOO: Dict[str, Callable[[], ModelDef]] = {
    "mlp": mlp.build,
    "vgg_cifar": vgg.build_cifar,
    "vgg_tinyin": vgg.build_tinyin,
    "resnet_speech": resnet.build,
    "tinylm_reddit": tinylm.build,
}


def get(name: str) -> ModelDef:
    if name not in ZOO:
        raise KeyError(f"unknown model {name!r}; have {sorted(ZOO)}")
    return ZOO[name]()
