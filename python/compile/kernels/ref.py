"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function here is the *reference semantics*; the Pallas kernels in this
package must match them to float32 tolerance. pytest (python/tests) asserts
`assert_allclose(kernel(...), ref(...))` across shape/dtype sweeps driven by
hypothesis.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def masked_sgd_ref(params: jax.Array, grads: jax.Array, mask: jax.Array,
                   lr: jax.Array) -> jax.Array:
    """Elementwise masked SGD over the flat parameter vector.

    new_p = p - lr * mask * g.  `mask` is the FedEL tensor-selection mask
    broadcast to element granularity (sub-tensor masks are allowed: HeteroFL
    and FIARSE use fractional per-tensor coverage).
    """
    return params - lr * mask * grads


def sq_accum_ref(grads: jax.Array) -> jax.Array:
    """Elementwise squared gradients (input to per-tensor importance sums)."""
    return grads * grads


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Plain f32 matmul accumulator semantics: (M,K) @ (K,N) -> (M,N)."""
    return jnp.matmul(x, w, preferred_element_type=jnp.float32)


def softmax_xent_ref(logits: jax.Array, labels: jax.Array):
    """Row-wise softmax cross entropy.

    Returns (per_example_loss [B], softmax_probs [B, C]); probs are the
    residual saved for the backward pass: dlogits = (p - onehot(y)) * g / B.
    """
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    z = jnp.sum(e, axis=-1, keepdims=True)
    p = e / z
    logp = logits - m - jnp.log(z)
    loss = -jnp.take_along_axis(logp, labels[:, None].astype(jnp.int32),
                                axis=-1)[:, 0]
    return loss, p


def global_importance_ref(w_new: jax.Array, w_old: jax.Array,
                          inv_lr: jax.Array) -> jax.Array:
    """FedEL global tensor importance, elementwise part (Sec. 4.2):

    I^g = ((w_{r+1} - w_r) / eta) * (w_{r+1} - w_r) = (dw)^2 / eta.
    Per-tensor reduction happens outside (segment sums over the manifest
    layout).
    """
    dw = w_new - w_old
    return dw * dw * inv_lr
