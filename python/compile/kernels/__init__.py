"""L1: Pallas kernels for FedEL's compute hot spots.

masked_sgd   — fused masked-SGD update + g^2 importance accumulation
matmul.dense — MXU-tiled blocked matmul with Pallas custom_vjp
softmax_xent — fused row-blocked softmax cross-entropy with custom_vjp
ref          — pure-jnp oracles every kernel is tested against
"""
from . import masked_sgd, matmul, ref, softmax_xent  # noqa: F401
