"""L1 Pallas kernel: fused masked-SGD update + squared-gradient importance.

This is FedEL's per-step parameter hot path: given the flat parameter
vector, the flat gradient, and the FedEL tensor-selection mask (already
broadcast to element granularity by the rust coordinator), produce

    new_p = p - lr * mask * g        (frozen tensors: mask == 0)
    sq    = g * g                    (feeds per-tensor importance sums)

in a single pass over HBM.  Fusing the two avoids reading `g` twice — on a
real TPU this kernel is memory-bound, so one fused pass is the roofline.

TPU mapping (DESIGN.md §Hardware-Adaptation): a 1-D grid over the flat
vector in `TILE`-element blocks.  Each grid step stages three f32 input
tiles + writes two output tiles through VMEM: 5 * TILE * 4 bytes = 160 KiB
at TILE=8192, far below the ~16 MiB VMEM budget, leaving room for the
pipelined double-buffering the Mosaic compiler inserts automatically.
Lowered with interpret=True so the CPU PJRT plugin executes plain HLO.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 131072 f32 = 512 KiB per ref: the largest power-of-two tile whose five
# refs, double-buffered by the Mosaic pipeliner, stay inside a 16 MiB VMEM
# (5 x 512 KiB x 2 = 5.2 MiB). Perf note (EXPERIMENTS.md §Perf): the
# original 8192 tile cost 49 grid steps on the 400k-param LM and interpret
# mode charges ~1-5 ms of full-array staging per step — 76 ms/step, 40% of
# the whole train step; at 131072 the same update is 11.9 ms (and a single
# grid step for every other model in the zoo).
TILE = 131072


def _kernel(p_ref, g_ref, m_ref, lr_ref, out_p_ref, out_sq_ref):
    g = g_ref[...]
    out_p_ref[...] = p_ref[...] - lr_ref[0] * m_ref[...] * g
    out_sq_ref[...] = g * g


def masked_sgd(params: jax.Array, grads: jax.Array, mask: jax.Array,
               lr: jax.Array, *, tile: int = TILE):
    """Fused masked SGD + g^2; returns (new_params, sq_grads).

    Shapes: params/grads/mask are flat f32 [P] (any P — padded internally to
    a multiple of `tile`); lr is a scalar.
    """
    (n,) = params.shape
    n_pad = (-n) % tile
    if n_pad:
        pad = lambda a: jnp.pad(a, (0, n_pad))
        params, grads, mask = pad(params), pad(grads), pad(mask)
    total = params.shape[0]
    grid = (total // tile,)
    spec = pl.BlockSpec((tile,), lambda i: (i,))
    new_p, sq = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[spec, spec, spec,
                  pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((total,), jnp.float32)] * 2,
        interpret=True,
    )(params, grads, mask, jnp.reshape(lr, (1,)))
    if n_pad:
        new_p, sq = new_p[:n], sq[:n]
    return new_p, sq


def global_importance(w_new: jax.Array, w_old: jax.Array, inv_lr: jax.Array,
                      *, tile: int = TILE) -> jax.Array:
    """Elementwise FedEL global-importance kernel: (w_new - w_old)^2 / eta.

    Same 1-D tiling as masked_sgd; the per-tensor segment reduction happens
    in the caller (jnp) over the manifest layout.
    """

    def kernel(a_ref, b_ref, s_ref, o_ref):
        dw = a_ref[...] - b_ref[...]
        o_ref[...] = dw * dw * s_ref[0]

    (n,) = w_new.shape
    n_pad = (-n) % tile
    if n_pad:
        w_new = jnp.pad(w_new, (0, n_pad))
        w_old = jnp.pad(w_old, (0, n_pad))
    total = w_new.shape[0]
    spec = pl.BlockSpec((tile,), lambda i: (i,))
    out = pl.pallas_call(
        kernel,
        grid=(total // tile,),
        in_specs=[spec, spec, pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((total,), jnp.float32),
        interpret=True,
    )(w_new, w_old, jnp.reshape(inv_lr, (1,)))
    return out[:n] if n_pad else out
