"""L1 Pallas kernel: fused row-blocked softmax cross-entropy.

Every model's loss head lands here (image classifiers: [B, C] logits;
the LM: [B*T, V] logits).  The kernel fuses max-subtraction, exp,
normalization, and the label gather into one pass that keeps each logits
row block resident in VMEM; it emits both the per-row loss and the softmax
probabilities, which the custom_vjp consumes for the closed-form backward
dlogits = (p - onehot(y)) * dy_row — no re-materialization of exp() in the
backward HLO.

TPU mapping (DESIGN.md §Hardware-Adaptation): grid over row blocks of
`BR` rows; the class axis stays whole (C <= 2048 for every model in the
zoo -> one row block is at most BR * 2048 * 4 B = 1 MiB of VMEM).  The
label "gather" is a one-hot dot expressed with broadcasted_iota, which maps
to the VPU rather than a scalar loop.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BR = 128  # rows per grid step


def _xent_kernel(logits_ref, labels_ref, loss_ref, p_ref):
    x = logits_ref[...]                       # [br, C]
    y = labels_ref[...]                       # [br]
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    z = jnp.sum(e, axis=-1, keepdims=True)
    p = e / z
    logp = x - m - jnp.log(z)
    c = x.shape[-1]
    onehot = (jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
              == y[:, None].astype(jnp.int32))
    loss_ref[...] = -jnp.sum(jnp.where(onehot, logp, 0.0), axis=-1)
    p_ref[...] = p


def softmax_xent(logits: jax.Array, labels: jax.Array, *, br: int = BR):
    """Fused softmax cross entropy; returns (per_row_loss [B], probs [B,C])."""
    b, c = logits.shape
    pad = (-b) % br
    if pad:
        logits = jnp.pad(logits, ((0, pad), (0, 0)))
        # pad labels with class 0: padded rows are sliced off below.
        labels = jnp.pad(labels, (0, pad))
    bp = logits.shape[0]
    loss, p = pl.pallas_call(
        _xent_kernel,
        grid=(bp // br,),
        in_specs=[
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((br,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((br,), lambda i: (i,)),
            pl.BlockSpec((br, c), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp,), jnp.float32),
            jax.ShapeDtypeStruct((bp, c), jnp.float32),
        ],
        interpret=True,
    )(logits, labels)
    if pad:
        loss, p = loss[:b], p[:b]
    return loss, p


@jax.custom_vjp
def mean_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean softmax cross-entropy over rows, Pallas fwd + closed-form bwd."""
    loss, _ = softmax_xent(logits, labels)
    return jnp.mean(loss)


def _mx_fwd(logits, labels):
    loss, p = softmax_xent(logits, labels)
    return jnp.mean(loss), (p, labels)


def _mx_bwd(res, g):
    p, labels = res
    b, c = p.shape
    onehot = (jax.lax.broadcasted_iota(jnp.int32, p.shape, 1)
              == labels[:, None].astype(jnp.int32)).astype(jnp.float32)
    dlogits = (p - onehot) * (g / b)
    return dlogits, None


mean_xent.defvjp(_mx_fwd, _mx_bwd)
