"""L1 Pallas kernel: MXU-tiled blocked matmul with f32 accumulator.

The dense layers of every model in the zoo (MLP blocks, early-exit heads,
transformer QKV/MLP projections) route through this kernel, so it sits on
the lowered HLO's hot path next to the conv ops XLA fuses itself.

TPU mapping (DESIGN.md §Hardware-Adaptation): grid (M/bm, N/bn, K/bk) with
the K axis innermost so each (i, j) output tile is revisited across K steps
and accumulates in place — the classic MXU systolic schedule expressed via
BlockSpec index maps (the output index map ignores the K grid axis, which
is how Pallas keeps the tile resident in VMEM between K steps).  Block
shape (128, 128, 128) is the MXU-native tile; f32 inputs feed the MXU
directly (bf16 would double throughput on real hardware — numerics stay
f32 because the oracle comparison and the CPU interpret path are f32).

A custom_vjp (`dense` below) expresses the backward pass as two more
Pallas matmuls (dx = dy @ w^T, dw = x^T @ dy) so jax.grad of the whole
model keeps this kernel on the path in the *backward* HLO too.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM, BN, BK = 128, 128, 128

# Largest dimension the adaptive scheduler will cover with a single block.
# Perf note (EXPERIMENTS.md §Perf): under interpret=True every extra grid
# step pays full-array staging, making the MXU-canonical 128^3 tiling
# 20-100x slower than one whole-matrix block for the zoo's <=512-wide
# matmuls; on a real TPU the 128^3 path is the right schedule, so callers
# can still request it explicitly.
MAX_SINGLE_BLOCK = 1024


def _mm_kernel(x_ref, w_ref, o_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                          preferred_element_type=jnp.float32)


def _pad2(a: jax.Array, bm: int, bn: int) -> jax.Array:
    m, n = a.shape
    pm, pn = (-m) % bm, (-n) % bn
    if pm or pn:
        a = jnp.pad(a, ((0, pm), (0, pn)))
    return a


def _round_up(x: int, to: int) -> int:
    return ((x + to - 1) // to) * to


def matmul(x: jax.Array, w: jax.Array, *, bm: int = 0, bn: int = 0,
           bk: int = 0) -> jax.Array:
    """Blocked (M,K)@(K,N)->(M,N) f32 matmul; pads ragged edges.

    Block sizes of 0 pick the adaptive schedule: one whole-matrix block
    when every dim fits MAX_SINGLE_BLOCK (the fast interpret path), else
    the MXU-canonical 128^3 tiling.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    if bm == 0:
        if max(m, n, k) <= MAX_SINGLE_BLOCK:
            bm, bn, bk = _round_up(m, 8), _round_up(n, 128), _round_up(k, 8)
        else:
            bm, bn, bk = BM, BN, BK
    xp, wp = _pad2(x, bm, bk), _pad2(w, bk, bn)
    mp, kp = xp.shape
    _, np_ = wp.shape
    k_steps = kp // bk
    out = pl.pallas_call(
        functools.partial(_mm_kernel, k_steps=k_steps),
        grid=(mp // bm, np_ // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp)
    return out[:m, :n]


@jax.custom_vjp
def dense(x: jax.Array, w: jax.Array) -> jax.Array:
    """Pallas-backed matmul with a Pallas backward (custom_vjp)."""
    return matmul(x, w)


def _dense_fwd(x, w):
    return matmul(x, w), (x, w)


def _dense_bwd(res, dy):
    x, w = res
    dx = matmul(dy, w.T)
    dw = matmul(x.T, dy)
    return dx, dw


dense.defvjp(_dense_fwd, _dense_bwd)
