"""AOT pipeline: lower every (model, exit) train step + eval step to HLO
text, write the manifest and deterministic initial parameters.

Run once by `make artifacts`; python never appears on the training path
afterwards.  HLO *text* (not serialized HloModuleProto) is the interchange
format: jax >= 0.5 emits protos with 64-bit instruction ids which
xla_extension 0.5.1 (the version behind the published `xla` 0.1.6 crate)
rejects; the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Usage:
  python -m compile.aot --out-dir ../artifacts [--models mlp,vgg_cifar,...]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import models as zoo
from .models.base import ModelDef, make_eval_step, make_train_step


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_model(model: ModelDef, out_dir: str, verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    p = model.param_count
    f32, i32 = jnp.float32, jnp.int32
    params_s = jax.ShapeDtypeStruct((p,), f32)
    x_s = jax.ShapeDtypeStruct(model.batched_input_shape(), f32)
    y_s = jax.ShapeDtypeStruct((model.label_len,), i32)
    mask_s = jax.ShapeDtypeStruct((p,), f32)
    lr_s = jax.ShapeDtypeStruct((), f32)

    artifacts = {}
    for e in range(1, model.num_blocks + 1):
        t0 = time.time()
        step = make_train_step(model, e)
        lowered = jax.jit(step).lower(params_s, x_s, y_s, mask_s, lr_s)
        text = to_hlo_text(lowered)
        name = f"train_exit_{e}.hlo.txt"
        with open(os.path.join(out_dir, name), "w") as f:
            f.write(text)
        artifacts[f"train_exit_{e}"] = name
        if verbose:
            print(f"  [{model.name}] exit {e}/{model.num_blocks}: "
                  f"{len(text) / 1e6:.2f} MB HLO in {time.time() - t0:.1f}s")

    ev = make_eval_step(model)
    lowered = jax.jit(ev).lower(params_s, x_s, y_s)
    text = to_hlo_text(lowered)
    with open(os.path.join(out_dir, "eval.hlo.txt"), "w") as f:
        f.write(text)
    artifacts["eval"] = "eval.hlo.txt"

    init = model.layout.init_flat(model.seed)
    init.tofile(os.path.join(out_dir, "init.bin"))

    manifest = model.to_manifest()
    manifest["artifacts"] = artifacts
    manifest["init"] = "init.bin"
    manifest["init_sha1"] = hashlib.sha1(init.tobytes()).hexdigest()
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if verbose:
        print(f"  [{model.name}] P={p} K={manifest['num_tensors']} "
              f"B={manifest['num_blocks']} -> {out_dir}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default=",".join(sorted(zoo.ZOO)),
                    help="comma-separated zoo names")
    args = ap.parse_args()
    names = [n for n in args.models.split(",") if n]
    t0 = time.time()
    for n in names:
        model = zoo.get(n)
        lower_model(model, os.path.join(args.out_dir, n))
    print(f"AOT done: {len(names)} models in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
