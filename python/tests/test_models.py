"""L2 model-zoo correctness: layouts, early exits, masked train semantics."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import models as zoo
from compile.models.base import make_eval_step, make_train_step

F32 = np.float32
ALL_MODELS = sorted(zoo.ZOO)


def make_batch(m, seed=0):
    rs = np.random.RandomState(seed)
    if m.task == "lm":
        x = rs.randint(0, m.num_classes, m.batched_input_shape()).astype(F32)
    else:
        x = rs.randn(*m.batched_input_shape()).astype(F32)
    y = rs.randint(0, m.num_classes, m.label_len).astype(np.int32)
    return jnp.asarray(x), jnp.asarray(y)


# ---------------------------------------------------------------------------
# Layout invariants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ALL_MODELS)
def test_layout_offsets_are_contiguous(name):
    m = zoo.get(name)
    off = 0
    for t in m.layout.tensors:
        assert t.offset == off
        assert t.size == int(np.prod(t.shape))
        off += t.size
    assert off == m.param_count


@pytest.mark.parametrize("name", ALL_MODELS)
def test_layout_blocks_cover_all_tensors(name):
    m = zoo.get(name)
    ids = m.block_tensor_ids()
    flat = sorted(i for blk in ids for i in blk)
    assert flat == list(range(len(m.layout.tensors)))
    # every block has at least one non-head tensor and one head tensor
    for b, blk in enumerate(ids):
        kinds = {m.layout.tensors[i].is_head for i in blk}
        assert kinds == {True, False}, f"block {b} missing head or body"


@pytest.mark.parametrize("name", ALL_MODELS)
def test_init_deterministic(name):
    m1, m2 = zoo.get(name), zoo.get(name)
    a = m1.layout.init_flat(m1.seed)
    b = m2.layout.init_flat(m2.seed)
    np.testing.assert_array_equal(a, b)
    assert a.dtype == np.float32
    assert np.isfinite(a).all()


@pytest.mark.parametrize("name", ALL_MODELS)
def test_manifest_schema(name):
    m = zoo.get(name)
    man = m.to_manifest()
    for key in ("model", "batch", "input_shape", "num_classes", "label_len",
                "task", "param_count", "num_tensors", "num_blocks",
                "tensors", "blocks", "exits"):
        assert key in man, key
    assert man["num_tensors"] == len(man["tensors"])
    assert man["exits"] == list(range(1, man["num_blocks"] + 1))
    assert all(b["flops_fwd"] > 0 for b in man["blocks"])


# ---------------------------------------------------------------------------
# Early-exit semantics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ALL_MODELS)
def test_all_exits_produce_logits(name):
    m = zoo.get(name)
    params = jnp.asarray(m.layout.init_flat(m.seed))
    x, _ = make_batch(m)
    views = m.layout.views(params)
    for e in range(1, m.num_blocks + 1):
        logits = m.forward(views, x, e)
        assert logits.shape == (m.label_len, m.num_classes), (name, e)
        assert np.isfinite(np.asarray(logits)).all(), (name, e)


@pytest.mark.parametrize("name", ["mlp", "vgg_cifar"])
def test_exit_e_ignores_deeper_blocks(name):
    """Perturbing blocks >= e must not change exit-e logits."""
    m = zoo.get(name)
    params = m.layout.init_flat(m.seed)
    x, _ = make_batch(m)
    e = 2
    base = m.forward(m.layout.views(jnp.asarray(params)), x, e)
    tampered = params.copy()
    for t in m.layout.tensors:
        if t.block >= e:
            tampered[t.offset:t.offset + t.size] += 7.0
    got = m.forward(m.layout.views(jnp.asarray(tampered)), x, e)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(got))


@pytest.mark.parametrize("name", ["mlp", "vgg_cifar"])
def test_exit_e_uses_own_head_only(name):
    """Perturbing other heads must not change exit-e logits."""
    m = zoo.get(name)
    params = m.layout.init_flat(m.seed)
    x, _ = make_batch(m)
    e = 3
    base = m.forward(m.layout.views(jnp.asarray(params)), x, e)
    tampered = params.copy()
    for t in m.layout.tensors:
        if t.is_head and not t.name.startswith(f"head{e - 1}/"):
            tampered[t.offset:t.offset + t.size] -= 3.0
    got = m.forward(m.layout.views(jnp.asarray(tampered)), x, e)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(got))


# ---------------------------------------------------------------------------
# Train-step semantics (the exact artifact the rust runtime executes)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ALL_MODELS)
def test_train_step_shapes_and_finiteness(name):
    m = zoo.get(name)
    params = jnp.asarray(m.layout.init_flat(m.seed))
    x, y = make_batch(m)
    mask = jnp.ones(m.param_count, F32)
    step = jax.jit(make_train_step(m, m.num_blocks))
    new_p, loss, sq = step(params, x, y, mask, jnp.float32(0.01))
    assert new_p.shape == (m.param_count,)
    assert sq.shape == (len(m.layout.tensors),)
    assert np.isfinite(float(loss))
    assert np.isfinite(np.asarray(new_p)).all()
    assert (np.asarray(sq) >= 0).all()


@pytest.mark.parametrize("name", ["mlp", "vgg_cifar", "tinylm_reddit"])
def test_train_step_mask_freezes_tensors(name):
    m = zoo.get(name)
    params = m.layout.init_flat(m.seed)
    x, y = make_batch(m)
    mask = np.ones(m.param_count, F32)
    frozen = [t for t in m.layout.tensors if t.block == 0 and not t.is_head]
    for t in frozen:
        mask[t.offset:t.offset + t.size] = 0.0
    step = jax.jit(make_train_step(m, m.num_blocks))
    new_p, _, _ = step(jnp.asarray(params), x, y, jnp.asarray(mask),
                       jnp.float32(0.05))
    got = np.asarray(new_p)
    for t in frozen:
        np.testing.assert_array_equal(got[t.offset:t.offset + t.size],
                                      params[t.offset:t.offset + t.size])


@pytest.mark.parametrize("name", ["mlp"])
def test_train_step_importance_zero_for_unreached_blocks(name):
    """Blocks deeper than the exit contribute no gradient -> sq == 0."""
    m = zoo.get(name)
    params = jnp.asarray(m.layout.init_flat(m.seed))
    x, y = make_batch(m)
    e = 2
    step = jax.jit(make_train_step(m, e))
    _, _, sq = step(params, x, y, jnp.ones(m.param_count, F32),
                    jnp.float32(0.01))
    sq = np.asarray(sq)
    for i, t in enumerate(m.layout.tensors):
        if t.block >= e and not (t.is_head and t.block == e - 1):
            assert sq[i] == 0.0, t.name
        if t.block < e and not t.is_head:
            assert sq[i] > 0.0, t.name


@pytest.mark.parametrize("name", ALL_MODELS)
def test_loss_decreases_over_steps(name):
    m = zoo.get(name)
    params = jnp.asarray(m.layout.init_flat(m.seed))
    x, y = make_batch(m)
    mask = jnp.ones(m.param_count, F32)
    step = jax.jit(make_train_step(m, m.num_blocks))
    first = None
    for _ in range(8):
        params, loss, _ = step(params, x, y, mask, jnp.float32(0.02))
        first = first if first is not None else float(loss)
    assert float(loss) < first, f"{name}: {first} -> {float(loss)}"


@pytest.mark.parametrize("name", ["mlp", "resnet_speech"])
def test_eval_step_counts(name):
    m = zoo.get(name)
    params = jnp.asarray(m.layout.init_flat(m.seed))
    x, y = make_batch(m)
    ev = jax.jit(make_eval_step(m))
    correct, loss_sum = ev(params, x, y)
    assert 0.0 <= float(correct) <= m.label_len
    assert float(loss_sum) > 0.0


def test_train_step_equals_manual_sgd_mlp():
    """Full-mask artifact step == hand-rolled jax.grad SGD step."""
    m = zoo.get("mlp")
    params = jnp.asarray(m.layout.init_flat(m.seed))
    x, y = make_batch(m)
    from compile.kernels import softmax_xent as sx

    def loss_fn(p):
        return sx.mean_xent(m.forward(m.layout.views(p), x, m.num_blocks), y)

    g = jax.grad(loss_fn)(params)
    manual = params - 0.03 * g
    step = jax.jit(make_train_step(m, m.num_blocks))
    new_p, _, _ = step(params, x, y, jnp.ones(m.param_count, F32),
                       jnp.float32(0.03))
    np.testing.assert_allclose(np.asarray(new_p), np.asarray(manual),
                               rtol=1e-5, atol=1e-6)
