"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle.

hypothesis sweeps shapes (including ragged, non-tile-multiple sizes) and
value ranges; assert_allclose against ref.py is THE correctness signal for
the kernels that end up inside every AOT artifact.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import masked_sgd as ms
from compile.kernels import matmul as mm
from compile.kernels import ref
from compile.kernels import softmax_xent as sx

F32 = np.float32


def rnd(rs, *shape):
    return jnp.asarray(rs.randn(*shape).astype(F32))


# ---------------------------------------------------------------------------
# masked_sgd
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 5000), seed=st.integers(0, 2**31 - 1),
       lr=st.floats(1e-4, 1.0))
def test_masked_sgd_matches_ref(n, seed, lr):
    rs = np.random.RandomState(seed)
    p, g = rnd(rs, n), rnd(rs, n)
    mask = jnp.asarray((rs.rand(n) > 0.5).astype(F32))
    new_p, sq = ms.masked_sgd(p, g, mask, jnp.float32(lr), tile=256)
    np.testing.assert_allclose(new_p, ref.masked_sgd_ref(p, g, mask, lr),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(sq, ref.sq_accum_ref(g), rtol=1e-6, atol=1e-7)


def test_masked_sgd_zero_mask_freezes_everything():
    rs = np.random.RandomState(0)
    p, g = rnd(rs, 1000), rnd(rs, 1000)
    new_p, _ = ms.masked_sgd(p, g, jnp.zeros(1000, F32), jnp.float32(0.5),
                             tile=128)
    np.testing.assert_array_equal(np.asarray(new_p), np.asarray(p))


def test_masked_sgd_full_mask_is_plain_sgd():
    rs = np.random.RandomState(1)
    p, g = rnd(rs, 777), rnd(rs, 777)
    new_p, _ = ms.masked_sgd(p, g, jnp.ones(777, F32), jnp.float32(0.1),
                             tile=128)
    np.testing.assert_allclose(new_p, p - 0.1 * g, rtol=1e-5, atol=1e-6)


def test_masked_sgd_fractional_mask():
    """HeteroFL/FIARSE-style sub-tensor (fractional-coverage) masks."""
    rs = np.random.RandomState(2)
    p, g = rnd(rs, 300), rnd(rs, 300)
    mask = jnp.asarray(np.repeat([1.0, 0.0, 1.0], 100).astype(F32))
    new_p, _ = ms.masked_sgd(p, g, mask, jnp.float32(0.2), tile=64)
    got = np.asarray(new_p)
    np.testing.assert_allclose(got[100:200], np.asarray(p)[100:200])
    np.testing.assert_allclose(got[:100], np.asarray(p - 0.2 * g)[:100],
                               rtol=1e-5, atol=1e-6)


def test_masked_sgd_exact_tile_multiple_no_padding():
    rs = np.random.RandomState(3)
    n = 1024
    p, g = rnd(rs, n), rnd(rs, n)
    mask = jnp.ones(n, F32)
    new_p, sq = ms.masked_sgd(p, g, mask, jnp.float32(0.01), tile=256)
    assert new_p.shape == (n,) and sq.shape == (n,)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 3000), seed=st.integers(0, 2**31 - 1),
       lr=st.floats(1e-3, 1.0))
def test_global_importance_matches_ref(n, seed, lr):
    rs = np.random.RandomState(seed)
    w_new, w_old = rnd(rs, n), rnd(rs, n)
    inv_lr = jnp.float32(1.0 / lr)
    got = ms.global_importance(w_new, w_old, inv_lr, tile=256)
    np.testing.assert_allclose(
        got, ref.global_importance_ref(w_new, w_old, inv_lr),
        rtol=1e-5, atol=1e-6)


def test_global_importance_nonnegative():
    rs = np.random.RandomState(4)
    a, b = rnd(rs, 500), rnd(rs, 500)
    out = np.asarray(ms.global_importance(a, b, jnp.float32(2.0), tile=128))
    assert (out >= 0).all()


# ---------------------------------------------------------------------------
# matmul / dense
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 150), k=st.integers(1, 150), n=st.integers(1, 150),
       seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref(m, k, n, seed):
    rs = np.random.RandomState(seed)
    x, w = rnd(rs, m, k), rnd(rs, k, n)
    got = mm.matmul(x, w, bm=32, bn=32, bk=32)
    np.testing.assert_allclose(got, ref.matmul_ref(x, w), rtol=1e-4,
                               atol=1e-4)


def test_matmul_exact_block_sizes():
    rs = np.random.RandomState(5)
    x, w = rnd(rs, 128, 128), rnd(rs, 128, 128)
    np.testing.assert_allclose(mm.matmul(x, w), ref.matmul_ref(x, w),
                               rtol=1e-4, atol=1e-3)


def test_matmul_identity():
    eye = jnp.eye(64, dtype=F32)
    rs = np.random.RandomState(6)
    x = rnd(rs, 64, 64)
    np.testing.assert_allclose(mm.matmul(x, eye, bm=32, bn=32, bk=32), x,
                               rtol=1e-5, atol=1e-5)


def test_dense_vjp_matches_autodiff():
    rs = np.random.RandomState(7)
    x, w = rnd(rs, 40, 30), rnd(rs, 30, 20)

    def f_pallas(x, w):
        return jnp.sum(jnp.tanh(mm.dense(x, w)))

    def f_ref(x, w):
        return jnp.sum(jnp.tanh(ref.matmul_ref(x, w)))

    gx, gw = jax.grad(f_pallas, argnums=(0, 1))(x, w)
    rx, rw = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx, rx, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gw, rw, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# softmax_xent
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(b=st.integers(1, 200), c=st.integers(2, 64),
       seed=st.integers(0, 2**31 - 1))
def test_softmax_xent_matches_ref(b, c, seed):
    rs = np.random.RandomState(seed)
    logits = rnd(rs, b, c)
    labels = jnp.asarray(rs.randint(0, c, b).astype(np.int32))
    loss, p = sx.softmax_xent(logits, labels, br=32)
    lref, pref = ref.softmax_xent_ref(logits, labels)
    np.testing.assert_allclose(loss, lref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(p, pref, rtol=1e-5, atol=1e-6)


def test_softmax_xent_probs_sum_to_one():
    rs = np.random.RandomState(8)
    logits = rnd(rs, 50, 10)
    labels = jnp.asarray(rs.randint(0, 10, 50).astype(np.int32))
    _, p = sx.softmax_xent(logits, labels, br=16)
    np.testing.assert_allclose(np.asarray(p).sum(-1), np.ones(50), rtol=1e-5)


def test_softmax_xent_extreme_logits_stable():
    logits = jnp.asarray([[1000.0, -1000.0], [-1000.0, 1000.0]], F32)
    labels = jnp.asarray([0, 1], np.int32)
    loss, _ = sx.softmax_xent(logits, labels, br=2)
    assert np.isfinite(np.asarray(loss)).all()
    np.testing.assert_allclose(np.asarray(loss), [0.0, 0.0], atol=1e-5)


def test_mean_xent_grad_matches_autodiff():
    rs = np.random.RandomState(9)
    logits = rnd(rs, 33, 12)
    labels = jnp.asarray(rs.randint(0, 12, 33).astype(np.int32))
    g = jax.grad(lambda l: sx.mean_xent(l, labels))(logits)
    gr = jax.grad(lambda l: jnp.mean(ref.softmax_xent_ref(l, labels)[0]))(
        logits)
    np.testing.assert_allclose(g, gr, rtol=1e-5, atol=1e-6)


def test_mean_xent_grad_sums_to_zero_rows():
    """dlogits rows of softmax-xent always sum to ~0."""
    rs = np.random.RandomState(10)
    logits = rnd(rs, 17, 9)
    labels = jnp.asarray(rs.randint(0, 9, 17).astype(np.int32))
    g = np.asarray(jax.grad(lambda l: sx.mean_xent(l, labels))(logits))
    np.testing.assert_allclose(g.sum(-1), np.zeros(17), atol=1e-7)


# ---------------------------------------------------------------------------
# adaptive matmul scheduling (perf-pass regression tests)
# ---------------------------------------------------------------------------

def test_matmul_adaptive_single_block_matches_ref():
    rs = np.random.RandomState(11)
    x, w = rnd(rs, 200, 300), rnd(rs, 300, 150)
    got = mm.matmul(x, w)  # bm=0 -> adaptive whole-matrix schedule
    np.testing.assert_allclose(got, ref.matmul_ref(x, w), rtol=1e-4, atol=1e-3)


def test_matmul_adaptive_falls_back_to_mxu_tiles_when_large():
    rs = np.random.RandomState(12)
    # one dim above MAX_SINGLE_BLOCK -> the 128^3 path
    x, w = rnd(rs, 8, mm.MAX_SINGLE_BLOCK + 64), rnd(rs, mm.MAX_SINGLE_BLOCK + 64, 8)
    got = mm.matmul(x, w)
    np.testing.assert_allclose(got, ref.matmul_ref(x, w), rtol=1e-3, atol=1e-2)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 96), k=st.integers(1, 96), n=st.integers(1, 96),
       seed=st.integers(0, 2**31 - 1))
def test_matmul_adaptive_matches_explicit_blocks(m, k, n, seed):
    rs = np.random.RandomState(seed)
    x, w = rnd(rs, m, k), rnd(rs, k, n)
    a = mm.matmul(x, w)
    b = mm.matmul(x, w, bm=32, bn=32, bk=32)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_masked_sgd_large_vector_single_tile():
    """The perf-pass TILE covers <=131072 params in one grid step."""
    rs = np.random.RandomState(13)
    n = ms.TILE  # exactly one tile
    p, g = rnd(rs, n), rnd(rs, n)
    mask = jnp.ones(n, F32)
    new_p, sq = ms.masked_sgd(p, g, mask, jnp.float32(0.01))
    np.testing.assert_allclose(new_p, ref.masked_sgd_ref(p, g, mask, 0.01),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(sq, ref.sq_accum_ref(g), rtol=1e-6, atol=1e-7)
