"""AOT pipeline: HLO text round-trips, manifest consistency with artifacts."""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import models as zoo
from compile.models.base import make_train_step

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_hlo_text_is_parseable_hlo_module():
    m = zoo.get("mlp")
    p = m.param_count
    step = make_train_step(m, 1)
    lowered = jax.jit(step).lower(
        jax.ShapeDtypeStruct((p,), jnp.float32),
        jax.ShapeDtypeStruct(m.batched_input_shape(), jnp.float32),
        jax.ShapeDtypeStruct((m.label_len,), jnp.int32),
        jax.ShapeDtypeStruct((p,), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # the tuple return convention the rust loader expects
    assert "f32[%d]" % p in text


@pytest.mark.parametrize("name", sorted(zoo.ZOO))
def test_manifest_matches_model(name):
    """Manifest on disk (if `make artifacts` ran) must match the zoo."""
    mdir = os.path.join(ART, name)
    if not os.path.exists(os.path.join(mdir, "manifest.json")):
        pytest.skip("artifacts not built")
    man = json.load(open(os.path.join(mdir, "manifest.json")))
    m = zoo.get(name)
    assert man["param_count"] == m.param_count
    assert man["num_blocks"] == m.num_blocks
    assert man["num_tensors"] == len(m.layout.tensors)
    for ts, t in zip(man["tensors"], m.layout.tensors):
        assert ts["name"] == t.name
        assert ts["offset"] == t.offset
        assert ts["size"] == t.size
        assert ts["block"] == t.block


@pytest.mark.parametrize("name", sorted(zoo.ZOO))
def test_artifact_files_exist(name):
    mdir = os.path.join(ART, name)
    if not os.path.exists(os.path.join(mdir, "manifest.json")):
        pytest.skip("artifacts not built")
    man = json.load(open(os.path.join(mdir, "manifest.json")))
    for _, fname in man["artifacts"].items():
        path = os.path.join(mdir, fname)
        assert os.path.exists(path), fname
        assert os.path.getsize(path) > 100
    init = np.fromfile(os.path.join(mdir, man["init"]), dtype=np.float32)
    assert init.shape == (man["param_count"],)
    import hashlib
    assert hashlib.sha1(init.tobytes()).hexdigest() == man["init_sha1"]


def test_init_bin_reproducible_from_zoo():
    name = "mlp"
    mdir = os.path.join(ART, name)
    if not os.path.exists(os.path.join(mdir, "init.bin")):
        pytest.skip("artifacts not built")
    on_disk = np.fromfile(os.path.join(mdir, "init.bin"), dtype=np.float32)
    m = zoo.get(name)
    np.testing.assert_array_equal(on_disk, m.layout.init_flat(m.seed))
