//! β ablation example (Fig 11): sweep the importance-blend parameter on
//! the quickstart workload and print the accuracy-vs-β curve.
//!
//!   cargo run --release --example ablation_beta

use fedel::config::{ExperimentCfg, FleetSpec};
use fedel::report::Table;
use fedel::sim::experiment::Experiment;

fn main() -> anyhow::Result<()> {
    let base = ExperimentCfg {
        model: "mlp".into(),
        fleet: FleetSpec::Small10,
        rounds: 30,
        local_steps: 4,
        lr: 0.05,
        eval_every: 5,
        eval_batches: 8,
        ..Default::default()
    };
    let mut t = Table::new("beta ablation (mlp, small10)", &["beta", "final_acc", "sim_h"]);
    let mut fedavg_exp = Experiment::build(base.clone())?;
    let fedavg = fedavg_exp.run(Some("fedavg"))?;
    t.row(vec![
        "fedavg".into(),
        format!("{:.3}", fedavg.final_acc),
        format!("{:.1}", fedavg.sim_total_secs / 3600.0),
    ]);
    for beta in [0.0, 0.2, 0.4, 0.6, 0.8, 1.0] {
        let mut cfg = base.clone();
        cfg.beta = beta;
        let mut exp = Experiment::build(cfg)?;
        let res = exp.run(Some("fedel"))?;
        t.row(vec![
            format!("{beta}"),
            format!("{:.3}", res.final_acc),
            format!("{:.1}", res.sim_total_secs / 3600.0),
        ]);
    }
    t.print();
    println!("paper shape (Fig 11): moderate beta best; extremes fall below FedAvg");
    Ok(())
}
