//! β ablation example (Fig 11): sweep the importance-blend parameter on
//! the quickstart workload and print the accuracy-vs-β curve.
//!
//! An optional first argument pins the executor thread count (default: one
//! worker per core, where the engine supports concurrent sessions). The
//! sweep is bitwise-reproducible at any setting — client execution joins
//! in plan order by design.
//!
//!   cargo run --release --features pjrt --example ablation_beta [-- threads]

use fedel::config::{ExperimentCfg, FleetSpec};
use fedel::report::Table;
use fedel::sim::experiment::Experiment;

fn main() -> anyhow::Result<()> {
    let threads: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let base = ExperimentCfg {
        model: "mlp".into(),
        fleet: FleetSpec::Small10,
        rounds: 30,
        local_steps: 4,
        lr: 0.05,
        eval_every: 5,
        eval_batches: 8,
        exec_threads: threads,
        ..Default::default()
    };
    let mut t = Table::new("beta ablation (mlp, small10)", &["beta", "final_acc", "sim_h"]);
    let mut fedavg_exp = Experiment::build(base.clone())?;
    let fedavg = fedavg_exp.run(Some("fedavg"))?;
    t.row(vec![
        "fedavg".into(),
        format!("{:.3}", fedavg.final_acc),
        format!("{:.1}", fedavg.sim_total_secs / 3600.0),
    ]);
    for beta in [0.0, 0.2, 0.4, 0.6, 0.8, 1.0] {
        let mut cfg = base.clone();
        cfg.strategy_params
            .push(("strategy.fedel.harmonize_weight".to_string(), beta));
        let mut exp = Experiment::build(cfg)?;
        let res = exp.run(Some("fedel"))?;
        t.row(vec![
            format!("{beta}"),
            format!("{:.3}", res.final_acc),
            format!("{:.1}", res.sim_total_secs / 3600.0),
        ]);
    }
    t.print();
    println!("paper shape (Fig 11): moderate beta best; extremes fall below FedAvg");
    Ok(())
}
