//! End-to-end driver (EXPERIMENTS.md §E2E): the full system on a real
//! small workload, proving all three layers compose.
//!
//! Trains the VGG-style CNN (76k params, 8 conv blocks + early-exit heads)
//! on synthetic CIFAR10-like non-iid data across the paper's 10-device
//! Xavier/Orin testbed for a few hundred FL rounds, with REAL compute:
//! every local step executes an AOT-compiled HLO artifact (Pallas masked
//! SGD + Pallas softmax-xent inside) through the PJRT CPU client, while
//! the wall clock is simulated from the calibrated Jetson timing model.
//! Each round's clients execute through engine sessions (PJRT rounds run
//! sequentially until concurrent xla-wrapper use is validated — see
//! Engine::parallel_sessions). Logs the loss/accuracy curve to
//! target/e2e_cifar_curve.csv and a machine-readable per-round log to
//! target/e2e_cifar_<strategy>.jsonl via the JSONL observer.
//!
//!   make artifacts && cargo run --release --features pjrt --example e2e_cifar [-- rounds]

use std::path::Path;

use fedel::config::{ExperimentCfg, FleetSpec};
use fedel::fl::observer::JsonlObserver;
use fedel::metrics::energy::energy_report;
use fedel::report::{render_table1, table1_rows};
use fedel::sim::experiment::Experiment;
use fedel::util::io::write_csv;

fn main() -> anyhow::Result<()> {
    let rounds: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);
    let cfg = ExperimentCfg {
        model: "vgg_cifar".into(),
        fleet: FleetSpec::Small10,
        rounds,
        local_steps: 4,
        lr: 0.04,
        alpha: 0.1,
        eval_every: 10,
        eval_batches: 12,
        slowest_round_secs: 71.8 * 60.0, // paper Table 2 FedAvg CIFAR round
        verbose: true,
        ..Default::default()
    };
    println!(
        "e2e driver: vgg_cifar x {} rounds x 10 devices (5 Xavier + 5 Orin), non-iid alpha=0.1",
        cfg.rounds
    );
    let wall0 = std::time::Instant::now();
    let mut exp = Experiment::build(cfg)?;

    let mut results = Vec::new();
    for name in ["fedavg", "fedel"] {
        let t0 = std::time::Instant::now();
        let jsonl_path = format!("target/e2e_cifar_{name}.jsonl");
        let mut jsonl = JsonlObserver::create(Path::new(&jsonl_path))?;
        let res = exp.run_observed(Some(name), &mut jsonl)?;
        // Log loss is worth a warning, not worth discarding the run.
        match jsonl.take_error() {
            Some(e) => eprintln!("   WARNING: round log {jsonl_path} lost: {e}"),
            None => println!("   round log streamed to {jsonl_path}"),
        }
        println!(
            "== {name}: final acc {:.2}%, simulated {}, wall {:.0}s",
            100.0 * res.final_acc,
            fedel::util::fmt_hours(res.sim_total_secs),
            t0.elapsed().as_secs_f64()
        );
        let er = energy_report(&res, &exp.fleet)?;
        println!(
            "   fleet energy {:.0} kJ at mean power {:.1} W",
            er.total_kj, er.mean_power_w
        );
        results.push(res);
    }

    // Loss/accuracy curves -> CSV.
    let mut rows = Vec::new();
    for res in &results {
        for r in &res.records {
            if let Some(acc) = r.eval_acc {
                rows.push(vec![
                    if res.strategy == "fedavg" { 0.0 } else { 1.0 },
                    r.round as f64,
                    r.sim_time / 3600.0,
                    r.mean_train_loss,
                    acc,
                ]);
            }
        }
    }
    let out = Path::new("target/e2e_cifar_curve.csv");
    write_csv(out, &["strategy(0=fedavg,1=fedel)", "round", "sim_h", "train_loss", "acc"], &rows)?;
    println!("curve written to {out:?}");

    let t = table1_rows(&results, 0.95, false);
    render_table1("e2e summary", &t, false).print();
    println!("total wall time {:.0}s", wall0.elapsed().as_secs_f64());
    Ok(())
}
