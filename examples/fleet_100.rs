//! Large-scale simulation with fault tolerance: 100 heterogeneous clients
//! over the paper's four device types {1, 1/2, 1/3, 1/4}x, mirroring the
//! paper's Sec. 5.1 large-scale scenario. Local training fans out across
//! host cores (results identical to a sequential run), and the whole
//! campaign is persisted through the run store:
//!
//! 1. a fedavg baseline runs to completion, checkpointed,
//! 2. a fedel run is **killed mid-flight** (simulated crash between
//!    checkpoints),
//! 3. `resume_run` picks it back up from the store and finishes it,
//! 4. the resumed result is asserted **bitwise-identical** to an
//!    uninterrupted run, and
//! 5. the two stored runs are compared on time-to-accuracy.
//!
//!   cargo run --release --example fleet_100 [-- rounds] [-- clients] [-- model]
//!
//! The default model is the pure-rust mock engine; pass e.g. vgg_tinyin
//! with `--features pjrt` + artifacts for the paper's TinyImageNet VGG.

use fedel::config::{ExperimentCfg, FleetSpec};
use fedel::fl::observer::RoundObserver;
use fedel::fl::server::{ClientOutcome, RoundRecord};
use fedel::report::{render_table1, runs_compare, table1_rows, Target};
use fedel::sim::experiment::{resume_run, Experiment};
use fedel::store::checkpoint::CheckpointObserver;
use fedel::store::RunStore;
use fedel::strategies::ClientPlan;

/// Per-round progress line: participants, straggler cost, eval when run.
struct Progress {
    clients_done: usize,
}

impl RoundObserver for Progress {
    fn on_round_start(&mut self, _round: usize, _plans: &[ClientPlan]) {
        self.clients_done = 0;
    }

    fn on_client_done(&mut self, _round: usize, _plan: &ClientPlan, _out: &ClientOutcome) {
        self.clients_done += 1;
    }

    fn on_round_end(&mut self, r: &RoundRecord) {
        let eval = r
            .eval_acc
            .map(|a| format!(" acc={:.3}", a))
            .unwrap_or_default();
        eprintln!(
            "round {:3}: {:3} clients trained, round {:6.0}s (incl comm), t={:9.0}s{eval}",
            r.round, self.clients_done, r.round_secs, r.sim_time
        );
    }
}

fn main() -> anyhow::Result<()> {
    let mut args = std::env::args().skip(1);
    let rounds: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(20);
    let clients: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(100);
    let model = args.next().unwrap_or_else(|| "mock:8x100".into());
    anyhow::ensure!(rounds >= 4, "fleet_100 needs >= 4 rounds for the kill+resume demo");
    let cfg = ExperimentCfg {
        model,
        fleet: FleetSpec::Large(clients),
        rounds,
        local_steps: 4,
        lr: 0.04,
        alpha: 0.1,
        eval_every: 5,
        eval_batches: 8,
        slowest_round_secs: 161.9 * 60.0, // paper Table 2 TinyImageNet round
        exec_threads: 0,                  // one worker per host core
        ..Default::default()
    };
    let store_dir = std::env::temp_dir().join(format!("fedel-fleet100-{}", std::process::id()));
    let store = RunStore::open(&store_dir)?;
    println!(
        "fleet_100: {clients} clients x {rounds} rounds, {} — store at {}",
        cfg.model,
        store_dir.display()
    );
    let mut exp = Experiment::build(cfg.clone())?;

    // device-type census
    let mut census: std::collections::BTreeMap<String, usize> = Default::default();
    for d in &exp.fleet {
        *census.entry(d.name.clone()).or_insert(0) += 1;
    }
    println!("fleet census: {census:?}");

    // -- 1. fedavg baseline, stored + checkpointed every 5 rounds ----------
    let fedavg_id;
    let mut results = Vec::new();
    {
        let t0 = std::time::Instant::now();
        let mut ckpt = CheckpointObserver::create(&store, &exp.cfg, "fedavg", 5)?;
        fedavg_id = ckpt.run_id().to_string();
        let res = exp.run_from(Some("fedavg"), &mut ckpt, None)?;
        anyhow::ensure!(ckpt.take_error().is_none(), "fedavg checkpointing failed");
        println!(
            "== fedavg ({fedavg_id}): final acc {:.2}%, simulated {}, wall {:.0}s",
            100.0 * res.final_acc,
            fedel::util::fmt_hours(res.sim_total_secs),
            t0.elapsed().as_secs_f64()
        );
        results.push(res);
    }

    // -- 2. fedel, killed mid-flight (between checkpoints) ------------------
    // Checkpoints land every 2 rounds; the kill hits an odd round, so the
    // resume has to recompute the round after the last checkpoint —
    // exactly what a real crash leaves behind.
    let kill_at = (rounds / 2) | 1;
    let fedel_id;
    {
        let mut killed_cfg = cfg.clone();
        killed_cfg.halt_after = Some(kill_at);
        let mut killed_exp = Experiment::build(killed_cfg)?;
        let mut ckpt = CheckpointObserver::create(&store, &killed_exp.cfg, "fedel", 2)?;
        fedel_id = ckpt.run_id().to_string();
        let err = killed_exp
            .run_from(Some("fedel"), &mut ckpt, None)
            .expect_err("halt_after must abort the run");
        println!("== fedel ({fedel_id}) killed mid-flight: {err}");
    }

    // -- 3. resume from the store ------------------------------------------
    {
        let t0 = std::time::Instant::now();
        let mut progress = Progress { clients_done: 0 };
        let resumed = resume_run(&store, &fedel_id, 2, &mut progress)?;
        println!(
            "== fedel ({fedel_id}) resumed: final acc {:.2}%, simulated {}, wall {:.0}s",
            100.0 * resumed.final_acc,
            fedel::util::fmt_hours(resumed.sim_total_secs),
            t0.elapsed().as_secs_f64()
        );

        // -- 4. bitwise identity vs an uninterrupted run --------------------
        let uninterrupted = Experiment::build(cfg.clone())?.run(Some("fedel"))?;
        anyhow::ensure!(
            resumed.final_params == uninterrupted.final_params,
            "kill+resume diverged from the uninterrupted run"
        );
        anyhow::ensure!(resumed.records.len() == uninterrupted.records.len());
        for (a, b) in resumed.records.iter().zip(&uninterrupted.records) {
            anyhow::ensure!(
                a.sim_time.to_bits() == b.sim_time.to_bits()
                    && a.mean_train_loss.to_bits() == b.mean_train_loss.to_bits()
                    && a.eval_acc.map(f64::to_bits) == b.eval_acc.map(f64::to_bits),
                "round {} diverged after resume",
                a.round
            );
        }
        println!("== kill+resume verified bitwise-identical to an uninterrupted run");
        results.push(resumed);
    }

    // -- 5. compare the two stored runs on time-to-accuracy ----------------
    let (table, speedup) = runs_compare(
        &store.load_manifest(&fedel_id)?,
        &store.load_manifest(&fedavg_id)?,
        Target::Default,
    );
    table.print();
    if let Some(s) = speedup {
        println!("time-to-accuracy: {fedel_id} is {s:.2}x vs {fedavg_id}");
    }
    render_table1("fleet_100 summary", &table1_rows(&results, 0.95, false), false).print();
    Ok(())
}
