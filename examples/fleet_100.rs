//! Large-scale simulation: 100 heterogeneous clients over the paper's four
//! device types {1, 1/2, 1/3, 1/4}x, TinyImageNet-like VGG. Mirrors the
//! paper's Sec. 5.1 large-scale scenario. Local training of the 100
//! clients fans out across host cores on engines with validated
//! concurrent sessions (results are identical to a sequential run; PJRT
//! is gated sequential until validated), and progress is reported
//! through a custom `RoundObserver` instead of the old `verbose` flag.
//!
//!   cargo run --release --features pjrt --example fleet_100 [-- rounds] [-- clients]

use fedel::config::{ExperimentCfg, FleetSpec};
use fedel::fl::observer::RoundObserver;
use fedel::fl::server::{ClientOutcome, RoundRecord};
use fedel::report::{render_table1, table1_rows};
use fedel::sim::experiment::Experiment;
use fedel::strategies::ClientPlan;

/// Per-round progress line: participants, straggler cost, eval when run.
struct Progress {
    clients_done: usize,
}

impl RoundObserver for Progress {
    fn on_round_start(&mut self, _round: usize, _plans: &[ClientPlan]) {
        self.clients_done = 0;
    }

    fn on_client_done(&mut self, _round: usize, _plan: &ClientPlan, _out: &ClientOutcome) {
        self.clients_done += 1;
    }

    fn on_round_end(&mut self, r: &RoundRecord) {
        let eval = r
            .eval_acc
            .map(|a| format!(" acc={:.3}", a))
            .unwrap_or_default();
        eprintln!(
            "round {:3}: {:3} clients trained, round {:6.0}s (incl comm), t={:9.0}s{eval}",
            r.round, self.clients_done, r.round_secs, r.sim_time
        );
    }
}

fn main() -> anyhow::Result<()> {
    let mut args = std::env::args().skip(1);
    let rounds: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(40);
    let clients: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(100);
    let cfg = ExperimentCfg {
        model: "vgg_tinyin".into(),
        fleet: FleetSpec::Large(clients),
        rounds,
        local_steps: 4,
        lr: 0.04,
        alpha: 0.1,
        eval_every: 5,
        eval_batches: 8,
        slowest_round_secs: 161.9 * 60.0, // paper Table 2 TinyImageNet round
        exec_threads: 0,                  // one worker per host core
        ..Default::default()
    };
    println!("fleet_100: {clients} clients x {rounds} rounds, vgg_tinyin");
    let mut exp = Experiment::build(cfg)?;

    // device-type census
    let mut census: std::collections::BTreeMap<String, usize> = Default::default();
    for d in &exp.fleet {
        *census.entry(d.name.clone()).or_insert(0) += 1;
    }
    println!("fleet census: {census:?}");

    let mut results = Vec::new();
    for name in ["fedavg", "timelyfl", "fedel"] {
        let t0 = std::time::Instant::now();
        let mut progress = Progress { clients_done: 0 };
        let res = exp.run_observed(Some(name), &mut progress)?;
        println!(
            "== {name}: final acc {:.2}%, simulated {}, wall {:.0}s",
            100.0 * res.final_acc,
            fedel::util::fmt_hours(res.sim_total_secs),
            t0.elapsed().as_secs_f64()
        );
        results.push(res);
    }
    render_table1("fleet_100 summary", &table1_rows(&results, 0.95, false), false).print();
    Ok(())
}
