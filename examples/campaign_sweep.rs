//! Campaign demo: a typed-parameter-space sweep as one crash-safe unit.
//!
//! Reproducing FedEL's tables means sweeping grids of experiments; this
//! example sweeps strategy × seed × FedEL's importance-harmonization
//! weight (`strategy.fedel.harmonize_weight`, a registry-declared
//! tunable — no per-knob code anywhere) on the mock engine and
//! demonstrates the full fault-tolerance story:
//!
//! 1. the campaign is **killed mid-flight** — each in-flight cell aborts
//!    between checkpoints (`halt_after`), exactly like a crashed process,
//! 2. a second `run_campaign` call with the same spec resumes it:
//!    finished cells are skipped, killed cells continue from their
//!    checkpoints through the `ResumeState` machinery,
//! 3. the whole grid is reported N-way on time-to-accuracy, and then
//!    collapsed over the seed axis into the paper's Table-3 shape
//!    (mean ± std per remaining cell) — as tables and as the `--json`
//!    schema dashboards consume.
//!
//!   cargo run --release --example campaign_sweep [-- rounds]

use fedel::config::ExperimentCfg;
use fedel::report::Target;
use fedel::sim::campaign::{grouped_report, report, run_campaign, status_table, CampaignCfg};
use fedel::store::RunStore;

fn main() -> anyhow::Result<()> {
    let rounds: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);
    anyhow::ensure!(rounds >= 4, "campaign_sweep needs >= 4 rounds for the kill+resume demo");

    let base = ExperimentCfg {
        model: "mock:8x100".into(),
        fleet: fedel::config::FleetSpec::Large(20),
        rounds,
        local_steps: 4,
        lr: 0.1,
        eval_every: 2,
        eval_batches: 4,
        slowest_round_secs: 71.8 * 60.0,
        exec_threads: 1, // campaign workers already fan out across cores
        ..Default::default()
    };
    let mut cfg = CampaignCfg::new("sweep", base);
    cfg.axis("strategy=fedavg,fedel")?;
    cfg.axis("seed=1,2")?;
    // A strategy-declared tunable, swept like any other key.
    cfg.axis("strategy.fedel.harmonize_weight=0.3,0.6")?;
    cfg.checkpoint_every = 2;
    cfg.verbose = true;

    let store_dir = std::env::temp_dir().join(format!("fedel-campaign-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&store_dir);
    let store = RunStore::open(&store_dir)?;
    println!(
        "campaign sweep: {} cells x {rounds} rounds — store at {}",
        cfg.cells()?.len(),
        store_dir.display()
    );

    // -- 1. kill the whole campaign mid-flight ------------------------------
    // Every cell aborts after an odd round, between its even-numbered
    // checkpoints — what a pulled plug leaves behind.
    let mut killed = cfg.clone();
    killed.halt_after = Some((rounds / 2) | 1);
    let out = run_campaign(&store, &killed)?;
    let (_, _, failed, _) = out.counts();
    println!("\n== campaign killed mid-flight: {failed} cell(s) halted between checkpoints");
    status_table(&store, &store.load_campaign("sweep")?).print();

    // -- 2. resume: same spec, no kill switch -------------------------------
    let out = run_campaign(&store, &cfg)?;
    anyhow::ensure!(out.complete(), "resumed campaign must finish: {out:?}");
    let (skipped, completed, _, _) = out.counts();
    println!("== campaign resumed: {completed} cell(s) continued, {skipped} skipped");
    status_table(&store, &store.load_campaign("sweep")?).print();

    // -- 3. whole-grid time-to-accuracy report ------------------------------
    let manifest = store.load_campaign("sweep")?;
    let rep = report(&store, &manifest, Target::Default, None)?;
    rep.table().print();

    // -- 4. Table-3 shape: collapse the seed axis ---------------------------
    let agg = grouped_report(&store, &manifest, "seed", Target::Default, None)?;
    agg.table().print();
    println!("--json form:\n{}", agg.to_json().to_string_pretty());
    Ok(())
}
