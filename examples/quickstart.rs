//! Quickstart: FedEL vs FedAvg on the fast MLP workload, 10-device
//! heterogeneous fleet. Runs in a few seconds on the prebuilt artifacts:
//!
//!   make artifacts && cargo run --release --features pjrt --example quickstart
//!
//! Each round's clients train through per-worker engine sessions — in
//! parallel on engines with validated concurrent sessions (the mock
//! engine today; PJRT is gated sequential until validated), and with
//! bitwise-identical results at any `exec_threads` setting.

use fedel::config::{ExperimentCfg, FleetSpec};
use fedel::report::{render_table1, table1_rows};
use fedel::sim::experiment::Experiment;

fn main() -> anyhow::Result<()> {
    let cfg = ExperimentCfg {
        model: "mlp".into(),
        fleet: FleetSpec::Small10,
        rounds: 40,
        local_steps: 4,
        lr: 0.05,
        eval_every: 4,
        eval_batches: 8,
        exec_threads: 0, // parallel client execution, one worker per core
        ..Default::default()
    };
    println!("quickstart: {} rounds of FL on `mlp`, 5 Xavier + 5 Orin", cfg.rounds);
    let mut exp = Experiment::build(cfg)?;

    let mut results = Vec::new();
    for name in ["fedavg", "elastictrainer", "fedel"] {
        let t0 = std::time::Instant::now();
        let res = exp.run(Some(name))?;
        println!(
            "  {name:<16} final acc {:>5.1}%  simulated {:>6}  (wall {:.1}s)",
            100.0 * res.final_acc,
            fedel::util::fmt_hours(res.sim_total_secs),
            t0.elapsed().as_secs_f64()
        );
        results.push(res);
    }
    let rows = table1_rows(&results, 0.95, false);
    render_table1("quickstart summary (speedup at matched accuracy)", &rows, false).print();
    println!("next: examples/e2e_cifar.rs for the full end-to-end driver");
    Ok(())
}
