//! RoundObserver: the server's reporting seam.
//!
//! The round loop used to carry ad-hoc `verbose`/`record_selections`
//! flags; every new reporting need meant another flag threaded through
//! `ServerCfg`. Observers invert that: the server emits a small set of
//! callbacks (round planned, client executed, eval measured, round
//! closed) and reporters subscribe. Ordering contract — part of the
//! parallel-determinism invariant: all callbacks fire on the coordinator
//! thread, and `on_client_done` fires in *plan order* even when clients
//! executed concurrently, so an observer's view is identical at any
//! thread count.
//!
//! Shipped implementations:
//! * [`NullObserver`] — the default no-op.
//! * [`ConsoleObserver`] — the CLI's `--verbose` round log.
//! * [`SelectionTrace`] — per-client tensor-selection traces
//!   (Fig 10/14/18-20), previously the `record_selections` flag.
//! * [`JsonlObserver`] — one JSON object per round to any writer, for
//!   machine-readable experiment logs.
//! * [`ObserverSet`] — fan-out to several observers.

use std::io::Write;

use crate::fl::server::{ClientOutcome, ExperimentResult, RoundRecord};
use crate::strategies::{ClientPlan, Strategy};

/// The server's post-round state, exposed once per round after
/// `on_round_end` — the seam checkpointing rides on ([`crate::store`]).
/// Everything here plus the round records is exactly what
/// [`crate::fl::server::ResumeState`] needs to continue the run.
pub struct ServerState<'a> {
    /// Rounds completed so far (the round that just closed is
    /// `completed - 1`).
    pub completed: usize,
    /// Simulated seconds elapsed, inclusive of the round just closed.
    pub sim_time: f64,
    /// Global model after the round's aggregation.
    pub global: &'a [f32],
    /// The strategy, for [`Strategy::policy_state`] snapshots.
    pub strategy: &'a dyn Strategy,
    /// Asynchronous-runner snapshot serializer ([`crate::fl::exec::event`]):
    /// present only on async aggregation boundaries; checkpoints persist
    /// its output so in-flight client clocks and the staleness buffer
    /// resume exactly. Lazy on purpose — serializing the runner state is
    /// O(live versions × params), and most aggregations fall between
    /// checkpoint cadence points where nobody wants it.
    pub async_state: Option<&'a dyn Fn() -> crate::util::json::Json>,
}

/// Callbacks the server emits while running an experiment. All methods
/// default to no-ops so implementations override only what they need.
pub trait RoundObserver {
    /// A round was planned; `plans` is the execution order.
    fn on_round_start(&mut self, _round: usize, _plans: &[ClientPlan]) {}

    /// One client's local training finished. Fired on the coordinator
    /// thread in plan order, after the parallel fan-out joined.
    fn on_client_done(&mut self, _round: usize, _plan: &ClientPlan, _outcome: &ClientOutcome) {}

    /// The global model was evaluated on the held-out test set.
    fn on_eval(&mut self, _round: usize, _acc: f64, _loss: f64) {}

    /// The round closed; `record` holds everything measured.
    fn on_round_end(&mut self, _record: &RoundRecord) {}

    /// The post-round server state (global model, clock, policy), fired
    /// after `on_round_end`. Checkpointing observers persist from here.
    fn on_server_state(&mut self, _state: &ServerState<'_>) {}

    /// The experiment finished (after the final eval).
    fn on_experiment_end(&mut self, _result: &ExperimentResult) {}
}

/// Default observer: ignores everything.
pub struct NullObserver;

impl RoundObserver for NullObserver {}

/// Fan-out to several observers, in push order.
#[derive(Default)]
pub struct ObserverSet<'a> {
    obs: Vec<&'a mut dyn RoundObserver>,
}

impl<'a> ObserverSet<'a> {
    pub fn new() -> Self {
        ObserverSet { obs: Vec::new() }
    }

    pub fn push(&mut self, o: &'a mut dyn RoundObserver) {
        self.obs.push(o);
    }
}

impl RoundObserver for ObserverSet<'_> {
    fn on_round_start(&mut self, round: usize, plans: &[ClientPlan]) {
        for o in &mut self.obs {
            o.on_round_start(round, plans);
        }
    }

    fn on_client_done(&mut self, round: usize, plan: &ClientPlan, outcome: &ClientOutcome) {
        for o in &mut self.obs {
            o.on_client_done(round, plan, outcome);
        }
    }

    fn on_eval(&mut self, round: usize, acc: f64, loss: f64) {
        for o in &mut self.obs {
            o.on_eval(round, acc, loss);
        }
    }

    fn on_round_end(&mut self, record: &RoundRecord) {
        for o in &mut self.obs {
            o.on_round_end(record);
        }
    }

    fn on_server_state(&mut self, state: &ServerState<'_>) {
        for o in &mut self.obs {
            o.on_server_state(state);
        }
    }

    fn on_experiment_end(&mut self, result: &ExperimentResult) {
        for o in &mut self.obs {
            o.on_experiment_end(result);
        }
    }
}

/// The CLI round log (previously `ServerCfg::verbose`): one line per eval
/// round on stderr.
pub struct ConsoleObserver {
    strategy: String,
}

impl ConsoleObserver {
    pub fn new(strategy: &str) -> Self {
        ConsoleObserver { strategy: strategy.to_string() }
    }
}

impl RoundObserver for ConsoleObserver {
    fn on_round_end(&mut self, r: &RoundRecord) {
        if let Some(a) = r.eval_acc {
            eprintln!(
                "[{}] round {:4} t={:8.0}s loss={:.4} acc={:.4}",
                self.strategy, r.round, r.sim_time, r.mean_train_loss, a
            );
        }
    }
}

/// Records (round, client, selected tensor ids) traces — previously the
/// `ServerCfg::record_selections` flag.
#[derive(Default)]
pub struct SelectionTrace {
    selections: Vec<(usize, usize, Vec<usize>)>,
}

impl SelectionTrace {
    pub fn into_inner(self) -> Vec<(usize, usize, Vec<usize>)> {
        self.selections
    }
}

impl RoundObserver for SelectionTrace {
    fn on_client_done(&mut self, round: usize, plan: &ClientPlan, _outcome: &ClientOutcome) {
        let sel: Vec<usize> = plan
            .mask
            .tensor_coverage()
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0.0)
            .map(|(i, _)| i)
            .collect();
        self.selections.push((round, plan.client, sel));
    }
}

/// Streams one JSON object per round (plus a final summary object) to any
/// writer — the machine-readable counterpart of [`ConsoleObserver`].
///
/// Writes are best-effort during the run (a logging failure never aborts
/// training); the first io error is retained and must be checked with
/// [`JsonlObserver::take_error`] after the experiment if the log matters.
pub struct JsonlObserver<W: Write> {
    out: W,
    error: Option<std::io::Error>,
}

impl JsonlObserver<std::io::BufWriter<std::fs::File>> {
    /// Convenience: create/truncate a `.jsonl` file at `path`.
    pub fn create(path: &std::path::Path) -> anyhow::Result<Self> {
        let f = std::fs::File::create(path)
            .map_err(|e| anyhow::anyhow!("create {path:?}: {e}"))?;
        Ok(JsonlObserver::new(std::io::BufWriter::new(f)))
    }
}

impl<W: Write> JsonlObserver<W> {
    pub fn new(out: W) -> Self {
        JsonlObserver { out, error: None }
    }

    /// The first write/flush error encountered, if any.
    pub fn take_error(&mut self) -> Option<std::io::Error> {
        self.error.take()
    }

    fn record(&mut self, r: std::io::Result<()>) {
        if let Err(e) = r {
            self.error.get_or_insert(e);
        }
    }
}

impl<W: Write> RoundObserver for JsonlObserver<W> {
    fn on_round_end(&mut self, r: &RoundRecord) {
        let res = writeln!(self.out, "{}", r.to_json());
        self.record(res);
    }

    fn on_experiment_end(&mut self, res: &ExperimentResult) {
        use crate::util::json::Json;
        // The run store's canonical summary schema, tagged so log readers
        // can tell the summary line from round lines.
        let mut kv = vec![("summary".to_string(), Json::Bool(true))];
        if let Json::Obj(rest) = crate::store::schema::result_summary_to_json(res) {
            kv.extend(rest);
        }
        let w = writeln!(self.out, "{}", Json::Obj(kv));
        self.record(w);
        let f = self.out.flush();
        self.record(f);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strategies::MaskSpec;

    fn plan(client: usize) -> ClientPlan {
        ClientPlan {
            client,
            exit: 1,
            mask: MaskSpec::Tensor(vec![1.0, 0.0, 1.0]),
            local_steps: 1,
            est_time: 1.0,
        }
    }

    fn outcome(client: usize) -> ClientOutcome {
        ClientOutcome {
            client,
            delta: crate::fl::sparse::SparseDelta::dense(vec![0.0]),
            sq_grads: vec![0.0],
            mean_loss: 0.5,
        }
    }

    #[test]
    fn selection_trace_records_nonzero_tensors() {
        let mut t = SelectionTrace::default();
        t.on_client_done(3, &plan(7), &outcome(7));
        let sel = t.into_inner();
        assert_eq!(sel, vec![(3, 7, vec![0, 2])]);
    }

    #[test]
    fn observer_set_fans_out_in_order() {
        #[derive(Default)]
        struct Counter(Vec<usize>, usize);
        impl RoundObserver for Counter {
            fn on_client_done(&mut self, _r: usize, p: &ClientPlan, _o: &ClientOutcome) {
                self.0.push(p.client);
            }
            fn on_round_end(&mut self, _r: &RoundRecord) {
                self.1 += 1;
            }
        }
        let mut a = Counter::default();
        let mut b = Counter::default();
        {
            let mut set = ObserverSet::new();
            set.push(&mut a);
            set.push(&mut b);
            set.on_client_done(0, &plan(2), &outcome(2));
            set.on_client_done(0, &plan(5), &outcome(5));
        }
        assert_eq!(a.0, vec![2, 5]);
        assert_eq!(b.0, vec![2, 5]);
        assert_eq!(a.1, 0);
    }

    #[test]
    fn jsonl_observer_emits_parseable_lines() {
        let mut buf: Vec<u8> = Vec::new();
        {
            let mut o = JsonlObserver::new(&mut buf);
            let r = RoundRecord {
                round: 0,
                round_secs: 10.0,
                sim_time: 10.0,
                mean_train_loss: 1.5,
                participants: 2,
                mean_coverage: 0.75,
                o1: 0.0,
                eval_acc: Some(0.5),
                eval_loss: Some(1.0),
                client_secs: vec![(0, 4.0), (1, 10.0)],
                mean_staleness: None,
                max_staleness: None,
                dropped: vec![],
                spec_hits: 0,
                spec_misses: 0,
            };
            o.on_round_end(&r);
        }
        let text = String::from_utf8(buf).unwrap();
        let j = crate::util::json::Json::parse(text.trim()).unwrap();
        assert_eq!(j.f("round").unwrap(), 0.0);
        assert_eq!(j.f("eval_acc").unwrap(), 0.5);
    }
}
