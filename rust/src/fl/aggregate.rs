//! Server-side aggregation.
//!
//! The paper's stable masked aggregation (Appendix D, Eq. 4):
//!     w_g(t+1)[k] = Σ_n c_n[k] ⊙ w_n[k],
//!     c_n[k] = A_n[k] / Σ_m A_m[k]
//! i.e. each element is averaged over exactly the clients that trained it;
//! elements nobody trained keep the previous global value.
//!
//! Variants: plain FedAvg (data-size weighted average of full models),
//! FedProx (same aggregation; the prox term acts client-side), and
//! FedNova normalized averaging (Appendix B.4 / Table 3).
//!
//! Updates stream in one at a time — the aggregator keeps only O(P)
//! accumulators, never the whole fleet's parameters.

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggregateRule {
    /// Eq. 4 mask-normalized averaging (FedEL & partial-training methods).
    Masked,
    /// Data-size-weighted FedAvg over full models (also used by FedProx).
    FedAvg,
    /// FedNova: normalize each update by its local step count, rescale by
    /// the effective step count τ_eff = Σ p_n τ_n.
    FedNova,
}

pub struct MaskedAggregator {
    rule: AggregateRule,
    num: Vec<f64>,
    den: Vec<f64>,
    /// FedNova bookkeeping.
    tau_eff: f64,
    weight_sum: f64,
    pub clients_added: usize,
}

impl MaskedAggregator {
    pub fn new(param_count: usize, rule: AggregateRule) -> Self {
        MaskedAggregator {
            rule,
            num: vec![0.0; param_count],
            den: vec![0.0; param_count],
            tau_eff: 0.0,
            weight_sum: 0.0,
            clients_added: 0,
        }
    }

    /// Add one client's trained parameters.
    ///
    /// `mask` — element-level training mask (what the client updated);
    /// `weight` — client weight (data size; 1.0 for uniform);
    /// `tau` — local SGD steps taken (FedNova); `global` — the round's
    /// starting global model (FedNova computes deltas against it).
    pub fn add(
        &mut self,
        params: &[f32],
        mask: &[f32],
        weight: f64,
        tau: usize,
        global: &[f32],
    ) {
        assert_eq!(params.len(), self.num.len());
        assert_eq!(mask.len(), self.num.len());
        self.clients_added += 1;
        self.weight_sum += weight;
        match self.rule {
            AggregateRule::Masked => {
                for k in 0..params.len() {
                    let m = mask[k] as f64 * weight;
                    self.num[k] += m * params[k] as f64;
                    self.den[k] += m;
                }
            }
            AggregateRule::FedAvg => {
                for k in 0..params.len() {
                    self.num[k] += weight * params[k] as f64;
                    self.den[k] += weight;
                }
            }
            AggregateRule::FedNova => {
                let tau = tau.max(1) as f64;
                self.tau_eff += weight * tau;
                for k in 0..params.len() {
                    let m = mask[k] as f64 * weight;
                    self.num[k] += m * (params[k] as f64 - global[k] as f64) / tau;
                    self.den[k] += m;
                }
            }
        }
    }

    /// Produce the next global model; untouched elements keep `global`.
    pub fn finish(self, global: &[f32]) -> Vec<f32> {
        let mut out = Vec::with_capacity(global.len());
        match self.rule {
            AggregateRule::Masked | AggregateRule::FedAvg => {
                for k in 0..global.len() {
                    out.push(if self.den[k] > 0.0 {
                        (self.num[k] / self.den[k]) as f32
                    } else {
                        global[k]
                    });
                }
            }
            AggregateRule::FedNova => {
                let tau_eff = if self.weight_sum > 0.0 {
                    self.tau_eff / self.weight_sum
                } else {
                    0.0
                };
                for k in 0..global.len() {
                    out.push(if self.den[k] > 0.0 {
                        global[k] + (tau_eff * self.num[k] / self.den[k]) as f32
                    } else {
                        global[k]
                    });
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masked_average_over_coverers_only() {
        let global = vec![10.0f32; 4];
        let mut agg = MaskedAggregator::new(4, AggregateRule::Masked);
        agg.add(&[1.0, 1.0, 0.0, 0.0], &[1.0, 1.0, 0.0, 0.0], 1.0, 1, &global);
        agg.add(&[3.0, 0.0, 5.0, 0.0], &[1.0, 0.0, 1.0, 0.0], 1.0, 1, &global);
        let out = agg.finish(&global);
        assert_eq!(out, vec![2.0, 1.0, 5.0, 10.0]); // last elem untouched
    }

    #[test]
    fn fedavg_weighted_by_data_size() {
        let global = vec![0.0f32; 2];
        let mut agg = MaskedAggregator::new(2, AggregateRule::FedAvg);
        agg.add(&[1.0, 1.0], &[1.0, 1.0], 3.0, 1, &global);
        agg.add(&[5.0, 5.0], &[1.0, 1.0], 1.0, 1, &global);
        let out = agg.finish(&global);
        assert_eq!(out, vec![2.0, 2.0]);
    }

    #[test]
    fn aggregation_of_identical_models_is_identity() {
        let global = vec![0.5f32; 8];
        let w = vec![0.7f32; 8];
        for rule in [AggregateRule::Masked, AggregateRule::FedAvg] {
            let mut agg = MaskedAggregator::new(8, rule);
            for _ in 0..5 {
                agg.add(&w, &vec![1.0; 8], 2.0, 3, &global);
            }
            let out = agg.finish(&global);
            for (a, b) in out.iter().zip(&w) {
                assert!((a - b).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn fednova_normalizes_by_tau() {
        // client A: 10 steps moved +10; client B: 1 step moved +1.
        // Plain averaging would favor A; Nova equalizes per-step movement.
        let global = vec![0.0f32; 1];
        let mut agg = MaskedAggregator::new(1, AggregateRule::FedNova);
        agg.add(&[10.0], &[1.0], 1.0, 10, &global);
        agg.add(&[1.0], &[1.0], 1.0, 1, &global);
        let out = agg.finish(&global);
        // d_A = 1.0/step, d_B = 1.0/step -> mean d = 1.0; tau_eff = 5.5
        assert!((out[0] - 5.5).abs() < 1e-6, "{out:?}");
    }

    #[test]
    fn fednova_with_full_masks_equals_fedavg_when_taus_equal() {
        let global = vec![1.0f32; 3];
        let a = vec![2.0f32, 3.0, 4.0];
        let b = vec![4.0f32, 5.0, 6.0];
        let mask = vec![1.0f32; 3];
        let mut nova = MaskedAggregator::new(3, AggregateRule::FedNova);
        nova.add(&a, &mask, 1.0, 5, &global);
        nova.add(&b, &mask, 1.0, 5, &global);
        let nova_out = nova.finish(&global);
        let mut avg = MaskedAggregator::new(3, AggregateRule::FedAvg);
        avg.add(&a, &mask, 1.0, 5, &global);
        avg.add(&b, &mask, 1.0, 5, &global);
        let avg_out = avg.finish(&global);
        for (x, y) in nova_out.iter().zip(&avg_out) {
            assert!((x - y).abs() < 1e-5, "{nova_out:?} vs {avg_out:?}");
        }
    }

    #[test]
    fn no_updates_returns_global() {
        let global = vec![3.0f32; 5];
        let agg = MaskedAggregator::new(5, AggregateRule::Masked);
        assert_eq!(agg.finish(&global), global);
    }

    #[test]
    fn fractional_masks_weight_contributions() {
        let global = vec![0.0f32; 1];
        let mut agg = MaskedAggregator::new(1, AggregateRule::Masked);
        agg.add(&[1.0], &[1.0], 1.0, 1, &global);
        agg.add(&[4.0], &[0.5], 1.0, 1, &global);
        let out = agg.finish(&global);
        // (1*1 + 0.5*4) / 1.5 = 2.0
        assert!((out[0] - 2.0).abs() < 1e-6);
    }
}
