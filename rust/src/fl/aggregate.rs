//! Server-side aggregation.
//!
//! The paper's stable masked aggregation (Appendix D, Eq. 4):
//!     w_g(t+1)[k] = Σ_n c_n[k] ⊙ w_n[k],
//!     c_n[k] = A_n[k] / Σ_m A_m[k]
//! i.e. each element is averaged over exactly the clients that trained it;
//! elements nobody trained keep the previous global value.
//!
//! Variants: plain FedAvg (data-size weighted average of full models),
//! FedProx (same aggregation; the prox term acts client-side), and
//! FedNova normalized averaging (Appendix B.4 / Table 3).
//!
//! Updates stream in one at a time — the aggregator keeps only O(P)
//! accumulators, never the whole fleet's parameters.

use crate::fl::sparse::SparseDelta;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggregateRule {
    /// Eq. 4 mask-normalized averaging (FedEL & partial-training methods).
    Masked,
    /// Data-size-weighted FedAvg over full models (also used by FedProx).
    FedAvg,
    /// FedNova: normalize each update by its local step count, rescale by
    /// the effective step count τ_eff = Σ p_n τ_n.
    FedNova,
}

pub struct MaskedAggregator {
    rule: AggregateRule,
    num: Vec<f64>,
    den: Vec<f64>,
    /// FedNova bookkeeping.
    tau_eff: f64,
    weight_sum: f64,
    pub clients_added: usize,
}

impl MaskedAggregator {
    pub fn new(param_count: usize, rule: AggregateRule) -> Self {
        MaskedAggregator {
            rule,
            num: vec![0.0; param_count],
            den: vec![0.0; param_count],
            tau_eff: 0.0,
            weight_sum: 0.0,
            clients_added: 0,
        }
    }

    /// Add one client's trained parameters, densely.
    ///
    /// `mask` — element-level training mask (what the client updated);
    /// `weight` — client weight (data size; 1.0 for uniform);
    /// `tau` — local SGD steps taken (FedNova); `global` — the round's
    /// starting global model (FedNova computes deltas against it).
    ///
    /// This is the reference path: it visits every element. The round
    /// loop feeds [`MaskedAggregator::add_sparse`] instead, which is
    /// bitwise-identical (proved in rust/tests/prop_invariants.rs) but
    /// only visits contributed runs.
    pub fn add(
        &mut self,
        params: &[f32],
        mask: &[f32],
        weight: f64,
        tau: usize,
        global: &[f32],
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            params.len() == self.num.len(),
            "aggregator over {} params got a {}-param update",
            self.num.len(),
            params.len()
        );
        anyhow::ensure!(
            mask.len() == self.num.len(),
            "aggregator over {} params got a {}-element mask",
            self.num.len(),
            mask.len()
        );
        self.clients_added += 1;
        self.weight_sum += weight;
        match self.rule {
            AggregateRule::Masked => {
                for k in 0..params.len() {
                    let m = mask[k] as f64 * weight;
                    self.num[k] += m * params[k] as f64;
                    self.den[k] += m;
                }
            }
            AggregateRule::FedAvg => {
                for k in 0..params.len() {
                    self.num[k] += weight * params[k] as f64;
                    self.den[k] += weight;
                }
            }
            AggregateRule::FedNova => {
                let tau = tau.max(1) as f64;
                self.tau_eff += weight * tau;
                for k in 0..params.len() {
                    let m = mask[k] as f64 * weight;
                    self.num[k] += m * (params[k] as f64 - global[k] as f64) / tau;
                    self.den[k] += m;
                }
            }
        }
        Ok(())
    }

    /// Add one client's [`SparseDelta`], visiting only contributed runs —
    /// O(masked size) per client for the masked rules instead of
    /// O(model size).
    ///
    /// Bitwise-identical to expanding the delta and calling
    /// [`MaskedAggregator::add`]: for Masked/FedNova, a zero-mask element
    /// contributes `num[k] += ±0.0; den[k] += ±0.0`, and since the
    /// accumulators start at +0.0 and IEEE-754 round-to-nearest addition
    /// can never turn +0.0 into -0.0, skipping those elements leaves the
    /// exact same bits. FedAvg averages full models, so runs the delta
    /// doesn't carry fall back to the dispatched `global` — which is what
    /// the client's untouched elements are, bit-for-bit (the engine only
    /// writes masked elements).
    pub fn add_sparse(
        &mut self,
        delta: &SparseDelta,
        weight: f64,
        tau: usize,
        global: &[f32],
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            delta.param_count == self.num.len(),
            "aggregator over {} params got a {}-param sparse update",
            self.num.len(),
            delta.param_count
        );
        anyhow::ensure!(
            global.len() == self.num.len(),
            "aggregator over {} params got a {}-param global",
            self.num.len(),
            global.len()
        );
        let mut prev_end = 0usize;
        for r in &delta.runs {
            let end = r.offset + r.values.len();
            anyhow::ensure!(
                r.offset >= prev_end && end <= delta.param_count,
                "sparse update runs out of order or out of bounds"
            );
            prev_end = end;
        }
        self.clients_added += 1;
        self.weight_sum += weight;
        match self.rule {
            AggregateRule::Masked => {
                for r in &delta.runs {
                    let m = r.mask as f64 * weight;
                    for (i, &v) in r.values.iter().enumerate() {
                        let k = r.offset + i;
                        self.num[k] += m * v as f64;
                        self.den[k] += m;
                    }
                }
            }
            AggregateRule::FedAvg => {
                // Walk the full vector with a run cursor; gaps take the
                // dispatched global. Full-coverage deltas (the only shape
                // FedAvg-family strategies produce in practice) reduce to
                // the plain dense loop.
                let mut k = 0usize;
                for r in &delta.runs {
                    while k < r.offset {
                        self.num[k] += weight * global[k] as f64;
                        self.den[k] += weight;
                        k += 1;
                    }
                    for &v in &r.values {
                        self.num[k] += weight * v as f64;
                        self.den[k] += weight;
                        k += 1;
                    }
                }
                while k < self.num.len() {
                    self.num[k] += weight * global[k] as f64;
                    self.den[k] += weight;
                    k += 1;
                }
            }
            AggregateRule::FedNova => {
                let tau = tau.max(1) as f64;
                self.tau_eff += weight * tau;
                for r in &delta.runs {
                    let m = r.mask as f64 * weight;
                    for (i, &v) in r.values.iter().enumerate() {
                        let k = r.offset + i;
                        self.num[k] += m * (v as f64 - global[k] as f64) / tau;
                        self.den[k] += m;
                    }
                }
            }
        }
        Ok(())
    }

    /// Produce the next global model; untouched elements keep `global`.
    pub fn finish(self, global: &[f32]) -> Vec<f32> {
        let mut out = Vec::with_capacity(global.len());
        match self.rule {
            AggregateRule::Masked | AggregateRule::FedAvg => {
                for k in 0..global.len() {
                    out.push(if self.den[k] > 0.0 {
                        (self.num[k] / self.den[k]) as f32
                    } else {
                        global[k]
                    });
                }
            }
            AggregateRule::FedNova => {
                let tau_eff = if self.weight_sum > 0.0 {
                    self.tau_eff / self.weight_sum
                } else {
                    0.0
                };
                for k in 0..global.len() {
                    out.push(if self.den[k] > 0.0 {
                        global[k] + (tau_eff * self.num[k] / self.den[k]) as f32
                    } else {
                        global[k]
                    });
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masked_average_over_coverers_only() {
        let global = vec![10.0f32; 4];
        let mut agg = MaskedAggregator::new(4, AggregateRule::Masked);
        agg.add(&[1.0, 1.0, 0.0, 0.0], &[1.0, 1.0, 0.0, 0.0], 1.0, 1, &global).unwrap();
        agg.add(&[3.0, 0.0, 5.0, 0.0], &[1.0, 0.0, 1.0, 0.0], 1.0, 1, &global).unwrap();
        let out = agg.finish(&global);
        assert_eq!(out, vec![2.0, 1.0, 5.0, 10.0]); // last elem untouched
    }

    #[test]
    fn fedavg_weighted_by_data_size() {
        let global = vec![0.0f32; 2];
        let mut agg = MaskedAggregator::new(2, AggregateRule::FedAvg);
        agg.add(&[1.0, 1.0], &[1.0, 1.0], 3.0, 1, &global).unwrap();
        agg.add(&[5.0, 5.0], &[1.0, 1.0], 1.0, 1, &global).unwrap();
        let out = agg.finish(&global);
        assert_eq!(out, vec![2.0, 2.0]);
    }

    #[test]
    fn aggregation_of_identical_models_is_identity() {
        let global = vec![0.5f32; 8];
        let w = vec![0.7f32; 8];
        for rule in [AggregateRule::Masked, AggregateRule::FedAvg] {
            let mut agg = MaskedAggregator::new(8, rule);
            for _ in 0..5 {
                agg.add(&w, &vec![1.0; 8], 2.0, 3, &global);
            }
            let out = agg.finish(&global);
            for (a, b) in out.iter().zip(&w) {
                assert!((a - b).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn fednova_normalizes_by_tau() {
        // client A: 10 steps moved +10; client B: 1 step moved +1.
        // Plain averaging would favor A; Nova equalizes per-step movement.
        let global = vec![0.0f32; 1];
        let mut agg = MaskedAggregator::new(1, AggregateRule::FedNova);
        agg.add(&[10.0], &[1.0], 1.0, 10, &global).unwrap();
        agg.add(&[1.0], &[1.0], 1.0, 1, &global).unwrap();
        let out = agg.finish(&global);
        // d_A = 1.0/step, d_B = 1.0/step -> mean d = 1.0; tau_eff = 5.5
        assert!((out[0] - 5.5).abs() < 1e-6, "{out:?}");
    }

    #[test]
    fn fednova_with_full_masks_equals_fedavg_when_taus_equal() {
        let global = vec![1.0f32; 3];
        let a = vec![2.0f32, 3.0, 4.0];
        let b = vec![4.0f32, 5.0, 6.0];
        let mask = vec![1.0f32; 3];
        let mut nova = MaskedAggregator::new(3, AggregateRule::FedNova);
        nova.add(&a, &mask, 1.0, 5, &global).unwrap();
        nova.add(&b, &mask, 1.0, 5, &global).unwrap();
        let nova_out = nova.finish(&global);
        let mut avg = MaskedAggregator::new(3, AggregateRule::FedAvg);
        avg.add(&a, &mask, 1.0, 5, &global).unwrap();
        avg.add(&b, &mask, 1.0, 5, &global).unwrap();
        let avg_out = avg.finish(&global);
        for (x, y) in nova_out.iter().zip(&avg_out) {
            assert!((x - y).abs() < 1e-5, "{nova_out:?} vs {avg_out:?}");
        }
    }

    #[test]
    fn no_updates_returns_global() {
        let global = vec![3.0f32; 5];
        let agg = MaskedAggregator::new(5, AggregateRule::Masked);
        assert_eq!(agg.finish(&global), global);
    }

    #[test]
    fn fractional_masks_weight_contributions() {
        let global = vec![0.0f32; 1];
        let mut agg = MaskedAggregator::new(1, AggregateRule::Masked);
        agg.add(&[1.0], &[1.0], 1.0, 1, &global).unwrap();
        agg.add(&[4.0], &[0.5], 1.0, 1, &global).unwrap();
        let out = agg.finish(&global);
        // (1*1 + 0.5*4) / 1.5 = 2.0
        assert!((out[0] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn length_mismatch_is_an_error_not_a_panic() {
        let global = vec![0.0f32; 4];
        let mut agg = MaskedAggregator::new(4, AggregateRule::Masked);
        assert!(agg.add(&[1.0; 3], &[1.0; 4], 1.0, 1, &global).is_err());
        assert!(agg.add(&[1.0; 4], &[1.0; 5], 1.0, 1, &global).is_err());
        let short = SparseDelta::dense(vec![1.0; 3]);
        assert!(agg.add_sparse(&short, 1.0, 1, &global).is_err());
        // failed adds must not poison the accumulator
        assert_eq!(agg.clients_added, 0);
        agg.add(&[2.0; 4], &[1.0; 4], 1.0, 1, &global).unwrap();
        assert_eq!(agg.finish(&global), vec![2.0; 4]);
    }

    #[test]
    fn sparse_add_matches_dense_add_bitwise() {
        let global: Vec<f32> = (0..10).map(|i| (i as f32 * 0.7).cos()).collect();
        // client params: masked elements trained, the rest left at global
        // (the engine contract)
        let mask = [1.0f32, 1.0, 0.0, 0.0, 0.5, 0.5, 0.5, 0.0, 1.0, 0.0];
        let mut params = global.clone();
        for (k, &m) in mask.iter().enumerate() {
            if m != 0.0 {
                params[k] += 0.1 * (k as f32 + 1.0);
            }
        }
        for rule in [AggregateRule::Masked, AggregateRule::FedAvg, AggregateRule::FedNova] {
            let mut dense = MaskedAggregator::new(10, rule);
            dense.add(&params, &mask, 3.0, 4, &global).unwrap();
            let mut sparse = MaskedAggregator::new(10, rule);
            let delta = SparseDelta::from_dense_mask(&mask, &params);
            sparse.add_sparse(&delta, 3.0, 4, &global).unwrap();
            let (d, s) = (dense.finish(&global), sparse.finish(&global));
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&d), bits(&s), "{rule:?}");
        }
    }
}
