//! Federated learning core: masked aggregation (Appendix D Eq. 4), the
//! O₁ convergence-bias diagnostic (Theorem D.5 / Table 4), and the server
//! round loop driving engines + strategies.

pub mod aggregate;
pub mod bias;
pub mod server;

pub use aggregate::{AggregateRule, MaskedAggregator};
pub use server::{run_experiment, ExperimentResult, RoundRecord, ServerCfg};
