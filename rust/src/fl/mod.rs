//! Federated learning core: masked aggregation (Appendix D Eq. 4), the
//! O₁ convergence-bias diagnostic (Theorem D.5 / Table 4), the staged
//! execution core ([`exec`]: plan → dispatch → execute → validate →
//! commit, with the synchronous round loop, the event-driven
//! asynchronous schedule, and its speculative execution backend) driving
//! engine sessions + strategies, and the observer seam reporters hang
//! off.

pub mod aggregate;
pub mod bias;
pub mod exec;
pub mod observer;
pub mod server;
pub mod sparse;

pub use aggregate::{AggregateRule, MaskedAggregator};
pub use sparse::SparseDelta;
pub use observer::{
    ConsoleObserver, JsonlObserver, NullObserver, ObserverSet, RoundObserver, SelectionTrace,
    ServerState,
};
pub use server::{
    execute_plans, execute_plans_streaming, run_experiment, run_experiment_from, ClientOutcome,
    ExecPool, ExperimentResult, ResumeState, RoundInputs, RoundRecord, ServerCfg,
};
