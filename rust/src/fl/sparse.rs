//! The sparse masked-delta representation: what a partially-trained
//! client actually produces, as a first-class type.
//!
//! Partial training (FedEL windows, HeteroFL widths, DepthFL depths)
//! touches a *structured* subset of the flat parameter vector: whole
//! tensors (or leading prefixes of tensors) at a shared mask value, and
//! tensors are laid out contiguously ([`Manifest::validate`] enforces
//! ascending gap-free offsets). A [`SparseDelta`] exploits exactly that
//! shape — an index-run (RLE) encoding of `(offset, mask, values)` runs —
//! so client payloads, aggregation work, and checkpoint deltas all scale
//! with the *trained* fraction instead of the model size. A full-coverage
//! update degenerates to a single run over the whole vector (the dense
//! fallback, see [`SparseDelta::dense_view`]) with zero per-element
//! overhead.
//!
//! Runs store the client's **raw trained values**, not arithmetic
//! differences against the base: f32 subtraction would round, and both
//! repo invariants (bitwise thread-count determinism, bitwise
//! kill/resume) demand lossless reconstruction. "Delta" refers to which
//! elements changed, never to `new - old`.

use crate::manifest::Manifest;
use crate::strategies::MaskSpec;

/// One contiguous trained span: `values` replace the base vector at
/// `offset..offset + values.len()`, all under the same mask value.
#[derive(Clone, Debug, PartialEq)]
pub struct Run {
    pub offset: usize,
    /// The (possibly fractional) mask value shared by every element of
    /// the run — the aggregation weight multiplier, exactly what
    /// [`Manifest::expand_mask`] would have written element-wise.
    pub mask: f32,
    pub values: Vec<f32>,
}

impl Run {
    fn end(&self) -> usize {
        self.offset + self.values.len()
    }
}

/// A sparse masked update against a `param_count`-element base vector.
///
/// Invariant (enforced by every constructor and re-checked by
/// [`SparseDelta::decode`]/[`SparseDelta::to_dense`]): runs are sorted
/// ascending, non-overlapping, non-empty, and in bounds.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseDelta {
    pub param_count: usize,
    pub runs: Vec<Run>,
}

/// Wire/blob size of a run table entry: u64 offset + u64 len + f32 mask.
const RUN_HEADER_BYTES: usize = 20;
/// Wire/blob size of the header: u64 param_count + u64 run_count.
const HEADER_BYTES: usize = 16;

impl SparseDelta {
    /// A full-coverage update: one mask-1.0 run owning the whole vector
    /// (moved, not copied). The dense fallback every full-model plan —
    /// FedAvg-family and all async dispatches — takes.
    pub fn dense(values: Vec<f32>) -> SparseDelta {
        let param_count = values.len();
        let runs = if values.is_empty() {
            Vec::new()
        } else {
            vec![Run { offset: 0, mask: 1.0, values }]
        };
        SparseDelta { param_count, runs }
    }

    /// Build the delta a plan's [`MaskSpec`] defines over trained params:
    /// one run per maximal span of equal-mask contiguous tensors (Prefix
    /// masks cover leading fractions at mask 1.0, matching
    /// [`Manifest::expand_prefix_mask`]). A single full-vector 1.0 span
    /// short-circuits to [`SparseDelta::dense`], moving `params`.
    pub fn from_mask_spec(m: &Manifest, mask: &MaskSpec, params: Vec<f32>) -> SparseDelta {
        assert_eq!(
            params.len(),
            m.param_count,
            "from_mask_spec: {} params for a {}-param manifest",
            params.len(),
            m.param_count
        );
        let spans = mask_runs(m, mask);
        if let [(0, len, mval)] = spans[..] {
            if len == m.param_count && mval == 1.0 {
                return SparseDelta::dense(params);
            }
        }
        let runs = spans
            .into_iter()
            .map(|(offset, len, mask)| Run {
                offset,
                mask,
                values: params[offset..offset + len].to_vec(),
            })
            .collect();
        SparseDelta { param_count: m.param_count, runs }
    }

    /// RLE a raw element-level mask (the [`MaskSpec::expand`] form): one
    /// run per maximal span of equal nonzero mask values. The structure-
    /// agnostic fallback, used by tests to cross-check the spec-driven
    /// constructor against arbitrary masks.
    pub fn from_dense_mask(elem_mask: &[f32], params: &[f32]) -> SparseDelta {
        assert_eq!(
            elem_mask.len(),
            params.len(),
            "from_dense_mask: mask length {} != params length {}",
            elem_mask.len(),
            params.len()
        );
        let n = params.len();
        let mut runs = Vec::new();
        let mut k = 0usize;
        while k < n {
            let mval = elem_mask[k];
            if mval == 0.0 {
                k += 1;
                continue;
            }
            let start = k;
            while k < n && elem_mask[k] == mval {
                k += 1;
            }
            runs.push(Run { offset: start, mask: mval, values: params[start..k].to_vec() });
        }
        SparseDelta { param_count: n, runs }
    }

    /// The changed-element delta between two equal-length vectors: mask-1.0
    /// runs over every maximal span where the f32 *bits* differ (bitwise,
    /// so ±0.0 flips and NaNs are preserved — checkpoints reconstruct
    /// exactly). `next`'s raw values are stored, so applying the delta to
    /// `base` via [`SparseDelta::to_dense`] returns `next` bit-for-bit.
    pub fn diff(base: &[f32], next: &[f32]) -> SparseDelta {
        assert_eq!(
            base.len(),
            next.len(),
            "diff: base length {} != next length {}",
            base.len(),
            next.len()
        );
        let n = next.len();
        let mut runs = Vec::new();
        let mut k = 0usize;
        while k < n {
            if base[k].to_bits() == next[k].to_bits() {
                k += 1;
                continue;
            }
            let start = k;
            while k < n && base[k].to_bits() != next[k].to_bits() {
                k += 1;
            }
            runs.push(Run { offset: start, mask: 1.0, values: next[start..k].to_vec() });
        }
        SparseDelta { param_count: n, runs }
    }

    /// `Some(values)` when this delta is secretly dense — a single
    /// mask-1.0 run covering the whole vector (or an empty vector) — the
    /// shape the async executor's full-model dispatches always produce.
    pub fn dense_view(&self) -> Option<&[f32]> {
        if self.param_count == 0 {
            return Some(&[]);
        }
        match &self.runs[..] {
            [r] if r.offset == 0 && r.mask == 1.0 && r.values.len() == self.param_count => {
                Some(&r.values)
            }
            _ => None,
        }
    }

    /// Total elements the delta carries — what aggregation and upload
    /// cost scale with.
    pub fn masked_elements(&self) -> usize {
        self.runs.iter().map(|r| r.values.len()).sum()
    }

    /// Exact [`SparseDelta::encode`] output size in bytes; also the
    /// communication model's upload payload (indices + values, so the
    /// encoding overhead is honestly charged).
    pub fn encoded_bytes(&self) -> usize {
        HEADER_BYTES + RUN_HEADER_BYTES * self.runs.len() + 4 * self.masked_elements()
    }

    /// Binary form (all little-endian): `[u64 param_count][u64 run_count]`,
    /// then per run `[u64 offset][u64 len][f32 mask]`, then every run's
    /// values concatenated as f32s.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.encoded_bytes());
        out.extend_from_slice(&(self.param_count as u64).to_le_bytes());
        out.extend_from_slice(&(self.runs.len() as u64).to_le_bytes());
        for r in &self.runs {
            out.extend_from_slice(&(r.offset as u64).to_le_bytes());
            out.extend_from_slice(&(r.values.len() as u64).to_le_bytes());
            out.extend_from_slice(&r.mask.to_le_bytes());
        }
        for r in &self.runs {
            for v in &r.values {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out
    }

    /// Parse and fully validate an [`SparseDelta::encode`] blob: the run
    /// table must be sorted, non-overlapping, non-empty, in bounds, and
    /// account for exactly the trailing value bytes.
    pub fn decode(bytes: &[u8]) -> anyhow::Result<SparseDelta> {
        let mut pos = 0usize;
        let param_count = read_u64(bytes, &mut pos)? as usize;
        let run_count = read_u64(bytes, &mut pos)? as usize;
        anyhow::ensure!(
            run_count <= (bytes.len() - pos) / RUN_HEADER_BYTES,
            "sparse delta truncated: {run_count} runs declared in {} bytes",
            bytes.len()
        );
        let mut header = Vec::with_capacity(run_count);
        let mut prev_end = 0usize;
        let mut total = 0usize;
        for i in 0..run_count {
            let offset = read_u64(bytes, &mut pos)? as usize;
            let len = read_u64(bytes, &mut pos)? as usize;
            let mask = read_f32(bytes, &mut pos)?;
            anyhow::ensure!(len > 0, "sparse delta run {i} is empty");
            anyhow::ensure!(
                (i == 0 || offset >= prev_end)
                    && offset
                        .checked_add(len)
                        .is_some_and(|end| end <= param_count),
                "sparse delta run {i} ({offset}+{len}) out of order or out of bounds \
                 (param_count {param_count})"
            );
            prev_end = offset + len;
            total += len;
            header.push((offset, len, mask));
        }
        anyhow::ensure!(
            bytes.len() == pos + 4 * total,
            "sparse delta length mismatch: {} bytes for {total} values",
            bytes.len() - pos
        );
        let runs = header
            .into_iter()
            .map(|(offset, len, mask)| {
                let values = (0..len)
                    .map(|_| read_f32(bytes, &mut pos).expect("bounds checked above"))
                    .collect();
                Run { offset, mask, values }
            })
            .collect();
        Ok(SparseDelta { param_count, runs })
    }

    /// Overlay the delta onto a base vector: untouched elements keep the
    /// base bit-for-bit, runs replace their spans with the stored values.
    pub fn to_dense(&self, base: &[f32]) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(
            base.len() == self.param_count,
            "sparse delta over {} params applied to a {}-param base",
            self.param_count,
            base.len()
        );
        let mut out = base.to_vec();
        let mut prev_end = 0usize;
        for r in &self.runs {
            anyhow::ensure!(
                r.offset >= prev_end && r.end() <= self.param_count,
                "sparse delta runs out of order or out of bounds"
            );
            out[r.offset..r.end()].copy_from_slice(&r.values);
            prev_end = r.end();
        }
        Ok(out)
    }
}

fn read_u64(b: &[u8], pos: &mut usize) -> anyhow::Result<u64> {
    anyhow::ensure!(*pos + 8 <= b.len(), "sparse delta truncated at byte {}", *pos);
    let v = u64::from_le_bytes(b[*pos..*pos + 8].try_into().unwrap());
    *pos += 8;
    Ok(v)
}

fn read_f32(b: &[u8], pos: &mut usize) -> anyhow::Result<f32> {
    anyhow::ensure!(*pos + 4 <= b.len(), "sparse delta truncated at byte {}", *pos);
    let v = f32::from_le_bytes(b[*pos..*pos + 4].try_into().unwrap());
    *pos += 4;
    Ok(v)
}

/// The `(offset, len, mask)` spans a [`MaskSpec`] covers, merged across
/// contiguous equal-mask tensors — the run structure
/// [`SparseDelta::from_mask_spec`] materializes, exposed separately so
/// communication pricing can size a payload without copying any values.
pub fn mask_runs(m: &Manifest, mask: &MaskSpec) -> Vec<(usize, usize, f32)> {
    fn push(spans: &mut Vec<(usize, usize, f32)>, offset: usize, len: usize, mval: f32) {
        if len == 0 {
            return;
        }
        if let Some(last) = spans.last_mut() {
            if last.0 + last.1 == offset && last.2 == mval {
                last.1 += len;
                return;
            }
        }
        spans.push((offset, len, mval));
    }
    let mut spans = Vec::new();
    match mask {
        MaskSpec::Tensor(tm) => {
            assert_eq!(
                tm.len(),
                m.tensors.len(),
                "mask_runs: tensor mask length {} != tensor count {}",
                tm.len(),
                m.tensors.len()
            );
            for (t, &v) in m.tensors.iter().zip(tm) {
                if v != 0.0 {
                    push(&mut spans, t.offset, t.size, v);
                }
            }
        }
        MaskSpec::Prefix(f) => {
            assert_eq!(
                f.len(),
                m.tensors.len(),
                "mask_runs: prefix mask length {} != tensor count {}",
                f.len(),
                m.tensors.len()
            );
            for (t, &fr) in m.tensors.iter().zip(f) {
                let n = ((t.size as f64) * fr.clamp(0.0, 1.0) as f64).round() as usize;
                push(&mut spans, t.offset, n.min(t.size), 1.0);
            }
        }
    }
    spans
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::tests_support::toy_manifest;

    fn params26() -> Vec<f32> {
        (0..26).map(|i| i as f32 * 0.25 - 2.0).collect()
    }

    #[test]
    fn tensor_mask_produces_merged_runs() {
        // toy manifest: tensors of size 8/4/10/4 at offsets 0/8/12/22
        let m = toy_manifest();
        let d = SparseDelta::from_mask_spec(
            &m,
            &MaskSpec::Tensor(vec![1.0, 0.0, 0.5, 1.0]),
            params26(),
        );
        assert_eq!(d.param_count, 26);
        let spans: Vec<(usize, usize, f32)> =
            d.runs.iter().map(|r| (r.offset, r.values.len(), r.mask)).collect();
        // tensor 2 and 3 touch (12+10 == 22) but differ in mask: no merge
        assert_eq!(spans, vec![(0, 8, 1.0), (12, 10, 0.5), (22, 4, 1.0)]);
        assert_eq!(d.runs[1].values, params26()[12..22]);
        assert_eq!(d.masked_elements(), 22);
        assert!(d.dense_view().is_none());
    }

    #[test]
    fn adjacent_equal_mask_tensors_merge() {
        let m = toy_manifest();
        let d = SparseDelta::from_mask_spec(
            &m,
            &MaskSpec::Tensor(vec![1.0, 1.0, 0.0, 0.0]),
            params26(),
        );
        let spans: Vec<(usize, usize, f32)> =
            d.runs.iter().map(|r| (r.offset, r.values.len(), r.mask)).collect();
        assert_eq!(spans, vec![(0, 12, 1.0)]);
    }

    #[test]
    fn full_coverage_is_the_dense_fallback() {
        let m = toy_manifest();
        let p = params26();
        let d = SparseDelta::from_mask_spec(&m, &MaskSpec::Tensor(vec![1.0; 4]), p.clone());
        assert_eq!(d.runs.len(), 1);
        assert_eq!(d.dense_view(), Some(&p[..]));
        // and overlaying it on anything returns the values themselves
        assert_eq!(d.to_dense(&vec![9.0; 26]).unwrap(), p);
    }

    #[test]
    fn prefix_mask_covers_leading_fractions() {
        let m = toy_manifest();
        let d = SparseDelta::from_mask_spec(
            &m,
            &MaskSpec::Prefix(vec![0.5, 0.0, 1.0, 0.0]),
            params26(),
        );
        let spans: Vec<(usize, usize, f32)> =
            d.runs.iter().map(|r| (r.offset, r.values.len(), r.mask)).collect();
        // half of tensor 0 (8 -> 4 elements), all of tensor 2
        assert_eq!(spans, vec![(0, 4, 1.0), (12, 10, 1.0)]);
        // matches the element-level expansion exactly
        let elem = m.expand_prefix_mask(&[0.5, 0.0, 1.0, 0.0]);
        let p = params26();
        assert_eq!(d, SparseDelta::from_dense_mask(&elem, &p));
    }

    #[test]
    fn spec_and_dense_mask_constructors_agree() {
        let m = toy_manifest();
        let p = params26();
        for mask in [
            MaskSpec::Tensor(vec![1.0, 0.0, 0.5, 1.0]),
            MaskSpec::Tensor(vec![0.0; 4]),
            MaskSpec::Tensor(vec![1.0; 4]),
            MaskSpec::Prefix(vec![0.3, 1.0, 0.0, 1.0]),
        ] {
            let from_spec = SparseDelta::from_mask_spec(&m, &mask, p.clone());
            let from_elem = SparseDelta::from_dense_mask(&mask.expand(&m), &p);
            assert_eq!(from_spec, from_elem, "{mask:?}");
        }
    }

    #[test]
    fn encode_decode_round_trips_and_sizes_exactly() {
        let m = toy_manifest();
        for mask in [
            MaskSpec::Tensor(vec![1.0, 0.0, 0.5, 1.0]),
            MaskSpec::Tensor(vec![0.0; 4]),
            MaskSpec::Tensor(vec![1.0; 4]),
        ] {
            let d = SparseDelta::from_mask_spec(&m, &mask, params26());
            let bytes = d.encode();
            assert_eq!(bytes.len(), d.encoded_bytes(), "{mask:?}");
            assert_eq!(SparseDelta::decode(&bytes).unwrap(), d, "{mask:?}");
        }
        // empty vector, empty delta
        let empty = SparseDelta::dense(Vec::new());
        assert_eq!(empty.dense_view(), Some(&[][..]));
        assert_eq!(SparseDelta::decode(&empty.encode()).unwrap(), empty);
    }

    #[test]
    fn decode_rejects_malformed_run_tables() {
        let d = SparseDelta::from_mask_spec(
            &toy_manifest(),
            &MaskSpec::Tensor(vec![1.0, 0.0, 0.5, 1.0]),
            params26(),
        );
        let good = d.encode();
        assert!(SparseDelta::decode(&good[..good.len() - 1]).is_err(), "truncated values");
        assert!(SparseDelta::decode(&good[..10]).is_err(), "truncated header");
        // out-of-bounds run: bump the first run's offset past param_count
        let mut bad = good.clone();
        bad[16..24].copy_from_slice(&100u64.to_le_bytes());
        assert!(SparseDelta::decode(&bad).is_err(), "out of bounds");
        // overlap: move the second run back onto the first
        let mut bad = good.clone();
        bad[36..44].copy_from_slice(&2u64.to_le_bytes());
        assert!(SparseDelta::decode(&bad).is_err(), "overlapping runs");
    }

    #[test]
    fn diff_then_overlay_reconstructs_bitwise() {
        let base: Vec<f32> = (0..40).map(|i| (i as f32).sin()).collect();
        let mut next = base.clone();
        next[3] = -0.0; // sin(3) != -0.0; a signed-zero value must survive
        next[10] = f32::NAN;
        for k in 20..25 {
            next[k] += 1.0;
        }
        let d = SparseDelta::diff(&base, &next);
        assert_eq!(d.runs.len(), 3);
        let back = d.to_dense(&base).unwrap();
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&back), bits(&next));
        // identical vectors diff to nothing
        assert_eq!(SparseDelta::diff(&base, &base).runs.len(), 0);
        // and a sparse diff encodes far smaller than the dense vector
        assert!(d.encoded_bytes() < 4 * base.len());
    }

    #[test]
    fn to_dense_validates_base_length() {
        let d = SparseDelta::dense(vec![1.0, 2.0]);
        assert!(d.to_dense(&[0.0; 3]).is_err());
        assert_eq!(d.to_dense(&[0.0, 0.0]).unwrap(), vec![1.0, 2.0]);
    }
}
