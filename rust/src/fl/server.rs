//! The FL server round loop: plan → local train → aggregate → observe.
//!
//! Compute is *real* (engine executes the AOT artifacts); wall-clock is
//! *simulated* from the timing model, exactly like the paper's 100-client
//! evaluation (DESIGN.md §4). One round:
//!
//! 1. the strategy plans per-client work (exit, mask, steps, sim cost),
//! 2. each planned client trains locally from the current global model
//!    (FedProx's proximal correction applied between steps when enabled),
//! 3. the server aggregates with the strategy's rule (Eq. 4 masked /
//!    FedAvg / FedNova) and advances the simulated clock by the slowest
//!    participant plus a communication constant,
//! 4. the strategy observes losses + importance signals; the server
//!    computes FedEL's global tensor importance from the aggregated model
//!    delta and the O₁ bias diagnostic from the round's masks.

use crate::data::FedDataset;
use crate::elastic::importance::global_importance;
use crate::fl::aggregate::MaskedAggregator;
use crate::fl::bias::o1_bias;
use crate::runtime::Engine;
use crate::strategies::{ClientPlan, FleetCtx, RoundFeedback, Strategy};

/// Server-side experiment configuration.
#[derive(Clone, Debug)]
pub struct ServerCfg {
    pub rounds: usize,
    pub eval_every: usize,
    /// Per-round communication/aggregation overhead (simulated seconds).
    pub comm_secs: f64,
    /// Record per-round tensor selections (Fig 10/14/18-20 traces).
    pub record_selections: bool,
    pub verbose: bool,
}

impl Default for ServerCfg {
    fn default() -> Self {
        ServerCfg {
            rounds: 50,
            eval_every: 5,
            comm_secs: 30.0,
            record_selections: false,
            verbose: false,
        }
    }
}

/// Everything measured in one round.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub round: usize,
    /// Simulated seconds this round took (slowest participant + comm).
    pub round_secs: f64,
    /// Simulated seconds since experiment start, inclusive.
    pub sim_time: f64,
    pub mean_train_loss: f64,
    pub participants: usize,
    /// Mean fraction of tensors trained across participants.
    pub mean_coverage: f64,
    /// O₁ bias diagnostic (Table 4).
    pub o1: f64,
    /// Eval (global test set) if this was an eval round.
    pub eval_acc: Option<f64>,
    pub eval_loss: Option<f64>,
    /// Per-client simulated seconds (fig 2 / energy model).
    pub client_secs: Vec<(usize, f64)>,
}

#[derive(Clone, Debug)]
pub struct ExperimentResult {
    pub strategy: String,
    pub records: Vec<RoundRecord>,
    pub sim_total_secs: f64,
    pub final_acc: f64,
    pub final_loss: f64,
    /// (round, client, selected tensor ids) when record_selections.
    pub selections: Vec<(usize, usize, Vec<usize>)>,
}

impl ExperimentResult {
    /// Simulated seconds to first reach `target` accuracy (time-to-accuracy).
    pub fn time_to_accuracy(&self, target: f64) -> Option<f64> {
        self.records
            .iter()
            .find(|r| r.eval_acc.map(|a| a >= target).unwrap_or(false))
            .map(|r| r.sim_time)
    }

    /// Simulated seconds to first reach `target` perplexity (LM; lower=better).
    pub fn time_to_perplexity(&self, target: f64) -> Option<f64> {
        self.records
            .iter()
            .find(|r| r.eval_loss.map(|l| l.exp() <= target).unwrap_or(false))
            .map(|r| r.sim_time)
    }

    pub fn final_perplexity(&self) -> f64 {
        self.final_loss.exp()
    }

    /// (sim_time, accuracy) series for time-to-accuracy plots.
    pub fn acc_curve(&self) -> Vec<(f64, f64)> {
        self.records
            .iter()
            .filter_map(|r| r.eval_acc.map(|a| (r.sim_time, a)))
            .collect()
    }

    pub fn mean_o1(&self) -> f64 {
        crate::util::stats::mean(&self.records.iter().map(|r| r.o1).collect::<Vec<_>>())
    }

    pub fn std_o1(&self) -> f64 {
        crate::util::stats::std_dev(&self.records.iter().map(|r| r.o1).collect::<Vec<_>>())
    }
}

fn evaluate(engine: &mut dyn Engine, ds: &FedDataset, params: &[f32]) -> (f64, f64) {
    let mut acc = crate::runtime::EvalOut::default();
    for (x, y) in &ds.test_batches {
        match engine.eval_step(params, x, y) {
            Ok(e) => acc.merge(&e),
            Err(err) => panic!("eval failed: {err}"),
        }
    }
    (acc.accuracy(), acc.mean_loss())
}

/// Run one experiment to completion.
pub fn run_experiment(
    engine: &mut dyn Engine,
    ds: &FedDataset,
    strategy: &mut dyn Strategy,
    ctx: &FleetCtx,
    cfg: &ServerCfg,
) -> anyhow::Result<ExperimentResult> {
    let m = engine.manifest().clone();
    anyhow::ensure!(m.param_count == ctx.manifest.param_count, "engine/ctx manifest mismatch");
    let mut global = m.load_init().unwrap_or_else(|_| vec![0.0; m.param_count]);
    let mut records = Vec::with_capacity(cfg.rounds);
    let mut selections = Vec::new();
    let mut sim_time = 0.0f64;
    let prox_mu = strategy.prox_mu();

    for round in 0..cfg.rounds {
        let plans: Vec<ClientPlan> = strategy.plan_round(round, ctx, &global);
        anyhow::ensure!(!plans.is_empty(), "strategy planned an empty round");

        let mut agg = MaskedAggregator::new(m.param_count, strategy.aggregate_rule());
        let mut fb = RoundFeedback::default();
        let mut tensor_masks: Vec<Vec<f32>> = Vec::with_capacity(plans.len());
        let mut losses = Vec::with_capacity(plans.len());
        let mut coverage = Vec::with_capacity(plans.len());
        let mut round_secs = 0.0f64;
        let mut client_secs = Vec::with_capacity(plans.len());

        for plan in &plans {
            let client = &ds.clients[plan.client];
            let elem_mask = plan.mask.expand(&m);
            let mut p = global.clone();
            let mut sq: Vec<f64> = Vec::new();
            let mut loss_acc = 0.0f64;
            for step in 0..plan.local_steps {
                let step_tag = (round * ctx.local_steps + step) as u64;
                let (x, y) = client.sample_batch(&ds.spec, &m, step_tag);
                let out = engine.train_step(plan.exit, &p, &x, &y, &elem_mask, ctx.lr as f32)?;
                p = out.new_params;
                loss_acc += out.loss as f64;
                if step == 0 {
                    sq = out.sq_grads;
                }
                if prox_mu > 0.0 {
                    // FedProx: w <- w - lr*mu*(w - w_global) on trained elems.
                    let f = (ctx.lr * prox_mu) as f32;
                    for k in 0..p.len() {
                        if elem_mask[k] != 0.0 {
                            p[k] -= f * (p[k] - global[k]);
                        }
                    }
                }
            }
            let mean_loss = loss_acc / plan.local_steps.max(1) as f64;
            agg.add(&p, &elem_mask, client.num_samples as f64, plan.local_steps, &global);
            fb.per_client.push((plan.client, sq, mean_loss));
            let cov = plan.mask.tensor_coverage();
            coverage.push(
                cov.iter().map(|&c| c as f64).sum::<f64>() / cov.len().max(1) as f64,
            );
            tensor_masks.push(cov);
            losses.push(mean_loss);
            round_secs = round_secs.max(plan.est_time);
            client_secs.push((plan.client, plan.est_time));
            if cfg.record_selections {
                let sel: Vec<usize> = plan
                    .mask
                    .tensor_coverage()
                    .iter()
                    .enumerate()
                    .filter(|(_, &c)| c > 0.0)
                    .map(|(i, _)| i)
                    .collect();
                selections.push((round, plan.client, sel));
            }
        }

        let new_global = agg.finish(&global);
        fb.global_importance = global_importance(&m, &new_global, &global, ctx.lr);
        let o1 = o1_bias(&tensor_masks);
        strategy.observe(&fb, ctx);

        round_secs += cfg.comm_secs;
        sim_time += round_secs;
        global = new_global;

        let do_eval = round % cfg.eval_every == cfg.eval_every - 1 || round + 1 == cfg.rounds;
        let (eval_acc, eval_loss) = if do_eval {
            let (a, l) = evaluate(engine, ds, &global);
            (Some(a), Some(l))
        } else {
            (None, None)
        };
        if cfg.verbose {
            if let Some(a) = eval_acc {
                eprintln!(
                    "[{}] round {round:4} t={:8.0}s loss={:.4} acc={:.4}",
                    strategy.name(),
                    sim_time,
                    crate::util::stats::mean(&losses),
                    a
                );
            }
        }
        records.push(RoundRecord {
            round,
            round_secs,
            sim_time,
            mean_train_loss: crate::util::stats::mean(&losses),
            participants: plans.len(),
            mean_coverage: crate::util::stats::mean(&coverage),
            o1,
            eval_acc,
            eval_loss,
            client_secs,
        });
    }

    let (final_acc, final_loss) = evaluate(engine, ds, &global);
    Ok(ExperimentResult {
        strategy: strategy.name().to_string(),
        records,
        sim_total_secs: sim_time,
        final_acc,
        final_loss,
        selections,
    })
}
