//! The FL server's shared vocabulary and its **execute** stage.
//!
//! The round loops themselves live in the staged execution core
//! ([`crate::fl::exec`]): [`run_experiment_from`] routes strategies with
//! an [`crate::strategies::AsyncSpec`] to the event-driven asynchronous
//! schedule ([`crate::fl::exec::event`]) and everything else to the
//! synchronous "barrier every commit" schedule
//! ([`crate::fl::exec::sync`]). This module keeps what both schedules —
//! and every external caller — share:
//!
//! * the configuration and result types ([`ServerCfg`], [`RoundRecord`],
//!   [`ClientOutcome`], [`ExperimentResult`], [`ResumeState`]);
//! * the execute stage: [`execute_plan`] runs one client's local SGD
//!   through a [`TrainSession`] (compute is *real* — sessions execute the
//!   AOT artifacts; wall-clock is *simulated* from the timing model,
//!   exactly like the paper's 100-client evaluation, DESIGN.md §4;
//!   FedProx's proximal correction is applied client-side between steps
//!   when enabled), and [`execute_plans_streaming`] fans plans out across
//!   a rayon pool, handing outcomes back in *plan order* through an order
//!   buffer so the join barrier holds only the out-of-order backlog;
//! * [`evaluate`] and [`plan_payload_bytes`], the eval fan-out and the
//!   communication-payload pricing both schedules charge.
//!
//! Determinism invariant: because a session's output is a pure function
//! of its inputs and aggregation folds in event order on the coordinator
//! thread, an experiment produces bitwise-identical [`ExperimentResult`]s
//! at any `exec_threads` (and `speculate_depth`-backend) setting (proved
//! by `tests/determinism.rs`) — and a run resumed from a [`ResumeState`]
//! checkpoint is bitwise-identical to one that was never interrupted
//! (proved by `tests/resume.rs`).

use rayon::prelude::*;

use crate::data::FedDataset;
use crate::fl::observer::RoundObserver;
use crate::fl::sparse::{mask_runs, SparseDelta};
use crate::manifest::Manifest;
use crate::runtime::{Engine, TrainSession};
use crate::strategies::{ClientPlan, FleetCtx, Strategy};
use crate::timing::CommModel;
use crate::util::json::Json;

/// Server-side experiment configuration.
#[derive(Clone, Debug)]
pub struct ServerCfg {
    pub rounds: usize,
    pub eval_every: usize,
    /// How client communication is priced ([`CommModel`]): a flat
    /// per-round constant (legacy `time.comm_secs`) or per-client
    /// payload/bandwidth times, under which partial-training strategies
    /// bank their masked-upload savings in time-to-accuracy.
    pub comm: CommModel,
    /// Host threads for the client fan-out: 0 = one per core (rayon
    /// default pool), 1 = fully sequential, n = a dedicated n-thread pool.
    /// Results are identical at any setting.
    pub exec_threads: usize,
    /// Abort (with an error) after this many completed rounds — simulates
    /// a mid-flight kill for the fault-tolerance tests and demos: whatever
    /// a [`crate::store::checkpoint::CheckpointObserver`] persisted up to
    /// that point is exactly what a crashed process would have left on
    /// disk. `None` = run to completion.
    pub halt_after: Option<usize>,
    /// Asynchronous modes only: cap on concurrently in-flight clients
    /// (`fleet.sample`). 0 = legacy full fan-out (every client always in
    /// flight). Lazy fleets require a cap — it bounds materialized client
    /// state to O(sample) instead of O(n).
    pub sample: usize,
    /// Experiment seed — all churn/sampling draws are pure functions of
    /// (seed, client, round/time), so there is no RNG state to checkpoint.
    pub seed: u64,
    /// Availability churn ([`crate::fleet::ChurnCfg`]); `None` = every
    /// client always reachable (legacy behavior, bitwise unchanged).
    pub churn: Option<crate::fleet::ChurnCfg>,
    /// Asynchronous modes only (`exec.speculate.depth`): how many future
    /// dispatch arrivals the runner simulates ahead and pre-executes
    /// against *predicted* global versions while earlier uploads are
    /// still in flight ([`crate::fl::exec::speculate`]). Every
    /// speculation is validated on arrival against the version the
    /// client actually received — commit on hit, re-execute on miss — so
    /// results are bitwise-identical at any depth; only wall-clock (and
    /// the record's hit/miss counters) change. 0 = off (serial
    /// reference).
    pub speculate_depth: usize,
}

impl Default for ServerCfg {
    fn default() -> Self {
        ServerCfg {
            rounds: 50,
            eval_every: 5,
            comm: CommModel::default(),
            exec_threads: 0,
            halt_after: None,
            sample: 0,
            seed: 0,
            churn: None,
            speculate_depth: 0,
        }
    }
}

/// Everything measured in one round.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub round: usize,
    /// Simulated seconds this round took (slowest participant + comm).
    pub round_secs: f64,
    /// Simulated seconds since experiment start, inclusive.
    pub sim_time: f64,
    pub mean_train_loss: f64,
    pub participants: usize,
    /// Mean fraction of tensors trained across participants.
    pub mean_coverage: f64,
    /// O₁ bias diagnostic (Table 4).
    pub o1: f64,
    /// Eval (global test set) if this was an eval round.
    pub eval_acc: Option<f64>,
    pub eval_loss: Option<f64>,
    /// Per-client simulated *compute* seconds (fig 2 / energy model);
    /// communication time is not active-power time and stays out.
    pub client_secs: Vec<(usize, f64)>,
    /// Mean server-version lag of the updates aggregated in this record —
    /// asynchronous modes only ([`crate::fl::exec::event`]); `None` for
    /// synchronous rounds, where every update is round-fresh.
    pub mean_staleness: Option<f64>,
    /// Worst staleness among this record's aggregated updates.
    pub max_staleness: Option<f64>,
    /// Clients whose participation was lost to availability churn this
    /// round (offline at round start, mid-round dropout, or departed
    /// before their async upload landed). Empty when churn is off.
    pub dropped: Vec<usize>,
    /// Speculative executions ([`crate::fl::exec::speculate`]) whose
    /// predicted dispatch version matched the version actually received,
    /// among the arrivals validated since the previous commit. Zero
    /// whenever `exec.speculate.depth` is 0 (and always for synchronous
    /// rounds).
    pub spec_hits: usize,
    /// Speculations invalidated at the arrival gate (predicted version
    /// missed) — their work was discarded and the dispatch re-executed
    /// against the true version, preserving bitwise results.
    pub spec_misses: usize,
}

impl RoundRecord {
    /// Flat JSON object (one line of a `.jsonl` experiment log) — the run
    /// store's canonical round schema ([`crate::store::schema`]), so logs
    /// and checkpoints serialize identically.
    pub fn to_json(&self) -> Json {
        crate::store::schema::round_record_to_json(self)
    }
}

/// One client's finished local training, exactly as the execute stage
/// hands it to aggregation and observers.
#[derive(Clone, Debug)]
pub struct ClientOutcome {
    /// Which client trained (always equals the matching plan's `client`;
    /// kept for observer sanity checks). Other plan facts — exit, mask,
    /// est_time — are NOT duplicated here: read them from the plan.
    pub client: usize,
    /// The locally-trained update against the dispatched global, carrying
    /// only the elements the plan's mask covers (run mask values
    /// included, so no separate mask vector rides along). Full-model
    /// plans degenerate to a single dense run with zero copy overhead —
    /// see [`SparseDelta::dense_view`].
    pub delta: SparseDelta,
    /// Per-tensor Σ g² from the first local step (importance signal).
    pub sq_grads: Vec<f64>,
    pub mean_loss: f64,
}

#[derive(Clone, Debug)]
pub struct ExperimentResult {
    pub strategy: String,
    pub records: Vec<RoundRecord>,
    pub sim_total_secs: f64,
    pub final_acc: f64,
    pub final_loss: f64,
    /// Final global model parameters (the determinism tests compare these
    /// bitwise across thread counts).
    pub final_params: Vec<f32>,
    /// (round, client, selected tensor ids). Empty as returned by
    /// [`run_experiment`] (and as seen by `on_experiment_end` observers);
    /// `Experiment::run_observed` merges a
    /// [`crate::fl::observer::SelectionTrace`]'s recordings in afterwards
    /// when `record_selections` is set.
    pub selections: Vec<(usize, usize, Vec<usize>)>,
}

impl ExperimentResult {
    /// Simulated seconds to first reach `target` accuracy (time-to-accuracy).
    pub fn time_to_accuracy(&self, target: f64) -> Option<f64> {
        crate::store::schema::time_to_accuracy(&self.records, target)
    }

    /// Simulated seconds to first reach `target` perplexity (LM; lower=better).
    pub fn time_to_perplexity(&self, target: f64) -> Option<f64> {
        crate::store::schema::time_to_perplexity(&self.records, target)
    }

    /// Full result dump (summary + eval curve + every round record) in the
    /// run store's schema ([`crate::store::schema`]).
    pub fn to_json(&self) -> Json {
        crate::store::schema::result_to_json(self)
    }

    pub fn final_perplexity(&self) -> f64 {
        self.final_loss.exp()
    }

    /// (sim_time, accuracy) series for time-to-accuracy plots.
    pub fn acc_curve(&self) -> Vec<(f64, f64)> {
        self.records
            .iter()
            .filter_map(|r| r.eval_acc.map(|a| (r.sim_time, a)))
            .collect()
    }

    pub fn mean_o1(&self) -> f64 {
        crate::util::stats::mean(&self.records.iter().map(|r| r.o1).collect::<Vec<_>>())
    }

    pub fn std_o1(&self) -> f64 {
        crate::util::stats::std_dev(&self.records.iter().map(|r| r.o1).collect::<Vec<_>>())
    }
}

/// Evaluate the global model over the held-out test set. Eval batches fan
/// out across parallel sessions just like client plans (the coordinator's
/// long-lived session serves the sequential paths); per-batch results
/// merge in *batch order* on the coordinator thread, so the score is
/// thread-count-invariant like everything else in the round loop.
pub(crate) fn evaluate(
    engine: &dyn Engine,
    coordinator: &mut dyn TrainSession,
    pool: ExecPool<'_>,
    ds: &FedDataset,
    params: &[f32],
) -> anyhow::Result<(f64, f64)> {
    let mut acc = crate::runtime::EvalOut::default();
    let parallel = !matches!(pool, ExecPool::Sequential)
        && engine.parallel_sessions()
        && ds.test_batches.len() > 1;
    if parallel {
        let fan_out = || {
            ds.test_batches
                .par_iter()
                .map_init(
                    || engine.session(),
                    |session, (x, y)| session.eval_step(params, x, y),
                )
                .collect::<Vec<_>>()
        };
        let evals = match pool {
            ExecPool::Dedicated(pool) => pool.install(fan_out),
            _ => fan_out(),
        };
        for e in evals {
            acc.merge(&e.map_err(|err| anyhow::anyhow!("eval failed: {err}"))?);
        }
    } else {
        for (x, y) in &ds.test_batches {
            let e = coordinator
                .eval_step(params, x, y)
                .map_err(|err| anyhow::anyhow!("eval failed: {err}"))?;
            acc.merge(&e);
        }
    }
    Ok((acc.accuracy(), acc.mean_loss()))
}

/// Read-only inputs shared by every client of one round's execute stage.
pub struct RoundInputs<'a> {
    pub ds: &'a FedDataset,
    pub ctx: &'a FleetCtx,
    /// Global model at the start of the round.
    pub global: &'a [f32],
    pub round: usize,
    /// FedProx proximal coefficient (0 = off).
    pub prox_mu: f64,
}

/// How the execute stage schedules clients across host threads.
pub enum ExecPool<'p> {
    /// One client at a time on the coordinator thread.
    Sequential,
    /// rayon's global pool (one worker per core).
    Global,
    /// A caller-owned dedicated pool (built once per experiment).
    Dedicated(&'p rayon::ThreadPool),
}

impl ExecPool<'_> {
    /// Build the pool for a `ServerCfg::exec_threads` setting. A dedicated
    /// pool is constructed once here, not per round.
    pub(crate) fn build(threads: usize) -> anyhow::Result<Option<rayon::ThreadPool>> {
        match threads {
            0 | 1 => Ok(None),
            n => rayon::ThreadPoolBuilder::new()
                .num_threads(n)
                .build()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("thread pool ({n} threads): {e}")),
        }
    }

    pub(crate) fn from_cfg(threads: usize, dedicated: Option<&rayon::ThreadPool>) -> ExecPool<'_> {
        match (threads, dedicated) {
            (1, _) => ExecPool::Sequential,
            (_, Some(pool)) => ExecPool::Dedicated(pool),
            _ => ExecPool::Global,
        }
    }
}

/// Execute stage, single client: local SGD from the round's global model
/// through one session. Pure in its inputs — no shared mutable state.
pub(crate) fn execute_plan(
    session: &mut dyn TrainSession,
    inp: &RoundInputs<'_>,
    m: &Manifest,
    plan: &ClientPlan,
) -> anyhow::Result<ClientOutcome> {
    let client = inp.ds.client(plan.client);
    let elem_mask = plan.mask.expand(m);
    let mut p = inp.global.to_vec();
    let mut sq: Vec<f64> = Vec::new();
    let mut loss_acc = 0.0f64;
    for step in 0..plan.local_steps {
        let step_tag = (inp.round * inp.ctx.local_steps + step) as u64;
        let (x, y) = client.sample_batch(&inp.ds.spec, m, step_tag);
        let out = session.train_step(plan.exit, &p, &x, &y, &elem_mask, inp.ctx.lr as f32)?;
        p = out.new_params;
        loss_acc += out.loss as f64;
        if step == 0 {
            sq = out.sq_grads;
        }
        if inp.prox_mu > 0.0 {
            // FedProx: w <- w - lr*mu*(w - w_global) on trained elems.
            let f = (inp.ctx.lr * inp.prox_mu) as f32;
            for k in 0..p.len() {
                if elem_mask[k] != 0.0 {
                    p[k] -= f * (p[k] - inp.global[k]);
                }
            }
        }
    }
    Ok(ClientOutcome {
        client: plan.client,
        delta: SparseDelta::from_mask_spec(m, &plan.mask, p),
        sq_grads: sq,
        mean_loss: loss_acc / plan.local_steps.max(1) as f64,
    })
}

/// Communication payloads of one plan, in bytes: download = the forward
/// sub-model through the plan's exit as raw f32s (at least the trained
/// set, which head-training strategies can exceed), upload = the client's
/// [`SparseDelta`] in its actual encoded form — run table plus values
/// ([`SparseDelta::encoded_bytes`]), so the sparse-index overhead is
/// honestly charged and partial training banks its savings under a
/// bandwidth [`CommModel`].
pub(crate) fn plan_payload_bytes(m: &Manifest, plan: &ClientPlan) -> (f64, f64) {
    let runs = mask_runs(m, &plan.mask);
    let up_elems: usize = runs.iter().map(|&(_, len, _)| len).sum();
    // 16-byte header + 20 bytes per run + 4 bytes per carried element —
    // kept in lockstep with SparseDelta::encoded_bytes.
    let up = (16 + 20 * runs.len() + 4 * up_elems) as f64;
    let down = 4.0 * (m.forward_param_count(plan.exit).max(up_elems) as f64);
    (down, up)
}

/// Execute stage, whole round, streaming: fan the plans out over the pool
/// and hand each outcome to `fold` in *plan order* the moment its turn
/// arrives. Outcomes that finish ahead of their turn wait in an order
/// buffer; folded outcomes are freed immediately, so the join barrier's
/// peak memory is the out-of-order backlog — in practice a few sessions'
/// worth — instead of every participant's full parameter vector. Errors
/// surface in plan order too, not completion order, so even failures are
/// deterministic at any thread count.
pub fn execute_plans_streaming(
    engine: &dyn Engine,
    inp: &RoundInputs<'_>,
    plans: &[ClientPlan],
    pool: ExecPool<'_>,
    mut fold: impl FnMut(usize, ClientOutcome) -> anyhow::Result<()>,
) -> anyhow::Result<()> {
    let m = engine.manifest();
    if matches!(pool, ExecPool::Sequential) || plans.len() <= 1 || !engine.parallel_sessions() {
        let mut session = engine.session();
        for (i, plan) in plans.iter().enumerate() {
            fold(i, execute_plan(session.as_mut(), inp, m, plan)?)?;
        }
        return Ok(());
    }
    let (tx, rx) = std::sync::mpsc::channel::<(usize, anyhow::Result<ClientOutcome>)>();
    std::thread::scope(|scope| {
        scope.spawn(move || {
            let fan_out = || {
                plans.par_iter().enumerate().for_each_init(
                    || (engine.session(), tx.clone()),
                    |(session, tx), (i, plan)| {
                        // A failed send means the coordinator already bailed
                        // on an earlier plan; this outcome is discarded.
                        let _ = tx.send((i, execute_plan(session.as_mut(), inp, m, plan)));
                    },
                );
            };
            match pool {
                ExecPool::Dedicated(pool) => pool.install(fan_out),
                _ => fan_out(),
            }
        });
        let mut backlog: std::collections::BTreeMap<usize, anyhow::Result<ClientOutcome>> =
            std::collections::BTreeMap::new();
        let mut next = 0usize;
        for (i, res) in rx {
            backlog.insert(i, res);
            while let Some(res) = backlog.remove(&next) {
                fold(next, res?)?;
                next += 1;
            }
        }
        anyhow::ensure!(next == plans.len(), "executor lost {} outcomes", plans.len() - next);
        Ok(())
    })
}

/// Execute stage, collected: like [`execute_plans_streaming`] but joining
/// every outcome into a plan-ordered `Vec` (the pre-streaming API, still
/// the right call when the caller genuinely needs the whole round at
/// once). Outcomes are bitwise-independent of the scheduling mode.
pub fn execute_plans(
    engine: &dyn Engine,
    inp: &RoundInputs<'_>,
    plans: &[ClientPlan],
    pool: ExecPool<'_>,
) -> anyhow::Result<Vec<ClientOutcome>> {
    let mut out = Vec::with_capacity(plans.len());
    execute_plans_streaming(engine, inp, plans, pool, |_, o| {
        out.push(o);
        Ok(())
    })?;
    Ok(out)
}

/// Where to pick an experiment up from: everything the round loop needs to
/// continue as if it had never stopped. Built by
/// [`crate::store::checkpoint::resume_state`] from a stored checkpoint, or
/// by [`ResumeState::warm_start`] to seed a fresh run from stored
/// parameters.
pub struct ResumeState {
    /// Rounds already completed; the loop starts at this round index.
    pub completed: usize,
    /// Simulated seconds elapsed over the completed rounds.
    pub sim_time: f64,
    /// Global model after round `completed - 1` (or the warm-start seed).
    pub global: Vec<f32>,
    /// [`Strategy::policy_state`] snapshot taken at the same point
    /// (`Json::Null` = fresh strategy).
    pub policy_state: Json,
    /// Records of the completed rounds, prepended to the result so a
    /// resumed [`ExperimentResult`] is indistinguishable from an
    /// uninterrupted one.
    pub prior_records: Vec<RoundRecord>,
    /// Asynchronous-runner snapshot ([`crate::fl::exec::event`]):
    /// in-flight client clocks, dispatch versions, the staleness buffer,
    /// and any live speculation bindings. `Json::Null` for synchronous
    /// runs and warm starts.
    pub async_state: Json,
}

impl ResumeState {
    /// Warm start: a brand-new experiment (round 0, fresh clock, fresh
    /// strategy) whose global model is seeded from stored parameters
    /// instead of the artifact init.
    pub fn warm_start(global: Vec<f32>) -> ResumeState {
        ResumeState {
            completed: 0,
            sim_time: 0.0,
            global,
            policy_state: Json::Null,
            prior_records: Vec::new(),
            async_state: Json::Null,
        }
    }
}

/// Run one experiment to completion.
pub fn run_experiment(
    engine: &dyn Engine,
    ds: &FedDataset,
    strategy: &mut dyn Strategy,
    ctx: &FleetCtx,
    cfg: &ServerCfg,
    observer: &mut dyn RoundObserver,
) -> anyhow::Result<ExperimentResult> {
    run_experiment_from(engine, ds, strategy, ctx, cfg, observer, None)
}

/// Run one experiment, optionally continuing from a [`ResumeState`].
/// Observers see only the rounds executed by *this* call; the result's
/// record stream covers the whole experiment including prior rounds.
///
/// Strategies that declare an [`crate::strategies::AsyncSpec`] dispatch
/// to the event-driven asynchronous schedule
/// ([`crate::fl::exec::event`]); everything else runs the synchronous
/// "barrier every commit" schedule ([`crate::fl::exec::sync`]) of the
/// same staged execution core.
pub fn run_experiment_from(
    engine: &dyn Engine,
    ds: &FedDataset,
    strategy: &mut dyn Strategy,
    ctx: &FleetCtx,
    cfg: &ServerCfg,
    observer: &mut dyn RoundObserver,
    resume: Option<ResumeState>,
) -> anyhow::Result<ExperimentResult> {
    match strategy.async_spec() {
        Some(spec) => crate::fl::exec::event::run_async(
            engine, ds, strategy, spec, ctx, cfg, observer, resume,
        ),
        None => crate::fl::exec::sync::run_sync(engine, ds, strategy, ctx, cfg, observer, resume),
    }
}
