//! O₁ convergence-bias diagnostic (Theorem D.5, Table 4).
//!
//! Theorem D.5 bounds the gradient norm with a bias term
//!     O₁ = 2Ψ Σ_n ( d_θ γ_n(t) − Σ_k (c_n(t))_k ),
//! where c_n(t) are the per-element aggregation weights of Eq. 4 and
//! γ_n = max_k (c_n)_k. The term vanishes when every client trains
//! everything (c_n ≡ 1/N) and grows when selections are narrow or
//! lopsided — the quantity the rollback ablation (Appendix B.6) compares.
//!
//! Computed at tensor granularity (the granularity at which FedEL's masks
//! are decided): d_θ → K, c_n[k] = m_n[k] / Σ_m m_m[k] over tensors k with
//! any coverage, with Ψ = 1 (the constant is strategy-independent and
//! cancels in the rollback comparison).

/// Per-round O₁ from the fleet's tensor-level masks ([client][tensor]).
pub fn o1_bias(masks: &[Vec<f32>]) -> f64 {
    if masks.is_empty() {
        return 0.0;
    }
    let k = masks[0].len();
    let mut cover = vec![0.0f64; k];
    for m in masks {
        assert_eq!(m.len(), k);
        for (c, &v) in cover.iter_mut().zip(m) {
            *c += v as f64;
        }
    }
    let mut total = 0.0;
    for m in masks {
        let mut gamma: f64 = 0.0;
        let mut sum_c = 0.0;
        for (j, &v) in m.iter().enumerate() {
            if cover[j] > 0.0 {
                let c = v as f64 / cover[j];
                gamma = gamma.max(c);
                sum_c += c;
            }
        }
        total += k as f64 * gamma - sum_c;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_participation_has_zero_bias() {
        let masks = vec![vec![1.0; 6]; 4];
        assert!(o1_bias(&masks).abs() < 1e-12);
    }

    #[test]
    fn narrow_selections_increase_bias() {
        // everyone trains everything vs everyone trains one tensor
        let full = vec![vec![1.0; 6]; 4];
        let narrow: Vec<Vec<f32>> = (0..4)
            .map(|n| {
                let mut m = vec![0.0; 6];
                m[n % 6] = 1.0;
                m
            })
            .collect();
        assert!(o1_bias(&narrow) > o1_bias(&full) + 1.0);
    }

    #[test]
    fn disjoint_single_coverage_gives_max_gamma() {
        // one client covers tensor 0 alone: c = 1 -> gamma = 1
        let masks = vec![vec![1.0, 0.0], vec![0.0, 1.0]];
        // each client: gamma = 1, sum_c = 1, K = 2 -> per-client bias 1
        assert!((o1_bias(&masks) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_input_is_zero() {
        assert_eq!(o1_bias(&[]), 0.0);
    }

    #[test]
    fn balanced_halves_have_less_bias_than_lopsided() {
        let balanced = vec![vec![1.0, 1.0, 0.0, 0.0], vec![0.0, 0.0, 1.0, 1.0]];
        let lopsided = vec![vec![1.0, 0.0, 0.0, 0.0], vec![0.0, 1.0, 1.0, 1.0]];
        assert!(o1_bias(&balanced) <= o1_bias(&lopsided) + 1e-9);
    }
}
