//! The synchronous schedule: the classic FL round loop as the execution
//! core's degenerate "barrier every commit" case.
//!
//! Every event of a round shares one clock tick: **plan** asks the
//! strategy for the whole fleet's work orders, **dispatch** binds them
//! all at the round's start time (availability churn validates
//! participation at the round barrier — there are no mid-flight
//! arrivals to validate individually), **execute** fans them across the
//! rayon pool, outcomes stream back in plan order and fold straight into
//! the aggregation rule, and the round **commits** exactly one
//! aggregation whose wall-clock is the slowest participant plus its
//! transfers — the straggler tax the asynchronous schedule
//! ([`super::event`]) exists to avoid. Speculation never applies here:
//! with a barrier every commit there are no future dispatches to predict
//! (every plan's start version is this round's, known at dispatch), so
//! `exec.speculate.depth` is meaningful only to the async runner and the
//! record's hit/miss counters stay zero.

use crate::data::FedDataset;
use crate::elastic::importance::global_importance;
use crate::fl::aggregate::MaskedAggregator;
use crate::fl::exec::{checkpoint_seam, commit_round, finish_experiment, validate_resume};
use crate::fl::exec::{Evaluator, RoundStats};
use crate::fl::observer::RoundObserver;
use crate::fl::server::{
    execute_plans_streaming, plan_payload_bytes, ExperimentResult, ResumeState, RoundInputs,
    ServerCfg,
};
use crate::runtime::Engine;
use crate::strategies::{ClientPlan, FleetCtx, RoundFeedback, Strategy};
use crate::util::json::Json;

/// Run a synchronous experiment, optionally continuing from a
/// [`ResumeState`]. Called by
/// [`crate::fl::server::run_experiment_from`] for every strategy that
/// does not declare an [`crate::strategies::AsyncSpec`].
pub fn run_sync(
    engine: &dyn Engine,
    ds: &FedDataset,
    strategy: &mut dyn Strategy,
    ctx: &FleetCtx,
    cfg: &ServerCfg,
    observer: &mut dyn RoundObserver,
    resume: Option<ResumeState>,
) -> anyhow::Result<ExperimentResult> {
    if let Some(r) = &resume {
        anyhow::ensure!(
            matches!(r.async_state, Json::Null),
            "checkpoint carries asynchronous runner state but {} runs synchronously",
            strategy.name()
        );
    }
    let m = engine.manifest().clone();
    anyhow::ensure!(m.param_count == ctx.manifest.param_count, "engine/ctx manifest mismatch");
    anyhow::ensure!(cfg.eval_every > 0, "eval_every must be >= 1");
    anyhow::ensure!(
        ctx.fleet.lazy.is_none(),
        "lazy fleets need an asynchronous strategy — {} plans whole synchronous rounds, \
         which would materialize every client",
        strategy.name()
    );
    anyhow::ensure!(
        cfg.sample == 0,
        "fleet.sample caps in-flight clients in asynchronous modes; {} runs synchronously \
         (its strategy already decides per-round participation)",
        strategy.name()
    );
    let (mut global, mut records, mut sim_time, start_round) = match resume {
        Some(r) => {
            validate_resume(&r, m.param_count, cfg.rounds, "round")?;
            // Null = fresh strategy (warm start); only real snapshots are
            // restored.
            if !matches!(r.policy_state, Json::Null) {
                strategy.restore_policy_state(&r.policy_state)?;
            }
            (r.global, r.prior_records, r.sim_time, r.completed)
        }
        None => (
            m.load_init().unwrap_or_else(|_| vec![0.0; m.param_count]),
            Vec::with_capacity(cfg.rounds),
            0.0f64,
            0,
        ),
    };
    let prox_mu = strategy.prox_mu();
    let mut evaluator = Evaluator::new(engine, cfg.exec_threads)?;

    for round in start_round..cfg.rounds {
        // -- plan ---------------------------------------------------------
        let all_plans: Vec<ClientPlan> = strategy.plan_round(round, ctx, &global);
        anyhow::ensure!(!all_plans.is_empty(), "strategy planned an empty round");

        // -- dispatch + validate: the round barrier is the arrival event,
        //    so churn is decided for the whole cohort here. Clients
        //    outside their availability window at round start never
        //    participate (the server's oracle knows up front, so they
        //    cost no wall-clock); a mid-round dropout is only discovered
        //    at the round deadline — the failed client's planned wall
        //    time still gates the round, but its update is lost. Both
        //    decisions are pure functions of (seed, client, round/time).
        let mut dropped: Vec<usize> = Vec::new();
        let mut dropped_secs = 0.0f64;
        let plans: Vec<ClientPlan> = if cfg.churn.is_some() || !ctx.fleet.windows.is_empty() {
            let t0 = sim_time;
            all_plans
                .into_iter()
                .filter(|p| {
                    let away = !ctx.fleet.arrived(p.client, t0)
                        || ctx.fleet.departed(p.client, t0)
                        || cfg.churn.is_some_and(|c| !c.online(cfg.seed, p.client, t0));
                    if away {
                        dropped.push(p.client);
                        return false;
                    }
                    let hit = cfg
                        .churn
                        .is_some_and(|c| c.dropout_hits(cfg.seed, p.client, round as u64));
                    if hit {
                        let (down, up) = plan_payload_bytes(&m, p);
                        dropped_secs =
                            dropped_secs.max(cfg.comm.client_total_secs(p.est_time, down, up));
                        dropped.push(p.client);
                        return false;
                    }
                    true
                })
                .collect()
        } else {
            all_plans
        };
        observer.on_round_start(round, &plans);

        // -- execute + aggregate: outcomes stream back in plan order and
        //    fold straight into the aggregator, so the join barrier never
        //    holds the whole fleet's parameters ---------------------------
        let inputs = RoundInputs { ds, ctx, global: &global, round, prox_mu };
        let mut agg = MaskedAggregator::new(m.param_count, strategy.aggregate_rule());
        let mut fb = RoundFeedback::default();
        let mut stats = RoundStats::default();
        // A dropped client's timeout gates the round exactly like a
        // participant would have (0.0 when churn is off — bitwise no-op).
        let mut round_secs = dropped_secs;
        execute_plans_streaming(engine, &inputs, &plans, evaluator.pool(), |i, out| {
            let plan = &plans[i];
            let weight = ds.clients[plan.client].num_samples as f64;
            // The outcome's delta carries its own run masks, so the
            // aggregator visits only contributed elements — the round's
            // fold costs O(Σ masked sizes), not O(clients × params).
            agg.add_sparse(&out.delta, weight, plan.local_steps, &global)?;
            // The client's wall-clock includes its transfers: download
            // the forward sub-model, upload the encoded sparse delta.
            // Under CommModel::Constant this reduces to the legacy
            // max(est) + comm_secs bitwise (monotone addition).
            let (down_bytes, up_bytes) = plan_payload_bytes(&m, plan);
            round_secs =
                round_secs.max(cfg.comm.client_total_secs(plan.est_time, down_bytes, up_bytes));
            observer.on_client_done(round, plan, &out);
            stats.absorb(plan, &out);
            // Consume the outcome into the strategy feedback (moves
            // sq_grads, no clone) now that the observer released it; the
            // params buffer drops right here.
            fb.per_client.push((plan.client, out.sq_grads, out.mean_loss));
            Ok(())
        })?;
        // A round churn emptied out leaves the global model untouched; the
        // strategy sees no feedback (there is none to see).
        let new_global = if plans.is_empty() { global.clone() } else { agg.finish(&global) };

        // -- observe ------------------------------------------------------
        if !plans.is_empty() {
            fb.global_importance = global_importance(&m, &new_global, &global, ctx.lr);
            strategy.observe(&fb, ctx);
        }

        sim_time += round_secs;
        global = new_global;

        // -- commit -------------------------------------------------------
        let record = commit_round(
            engine,
            ds,
            cfg,
            &mut evaluator,
            observer,
            round,
            round + 1,
            round_secs,
            sim_time,
            &global,
            stats,
            None,
            dropped,
            0,
            0,
        )?;
        records.push(record);
        // Synchronous rounds have no runner state beyond the strategy.
        checkpoint_seam(cfg, observer, round + 1, sim_time, &global, &*strategy, None, "round")?;
    }

    finish_experiment(engine, ds, &mut evaluator, &*strategy, observer, records, sim_time, global)
}
