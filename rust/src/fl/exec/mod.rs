//! The staged, event-driven execution core shared by every run mode:
//!
//! **plan → dispatch → execute → validate → commit**
//!
//! * **plan** — a strategy emits per-client work orders ([`ClientPlan`]s)
//!   from the current global model.
//! * **dispatch** — a plan is bound to a client clock: the synchronous
//!   schedule dispatches a whole round at once, the asynchronous schedule
//!   keeps one dispatch per runner slot with its own simulated finish
//!   time.
//! * **execute** — dispatched plans train through engine sessions.
//!   Training is a pure function of (start params, client, iteration
//!   tag), so *when* and *where* a dispatch executes can never change
//!   *what* it produces — the freedom the speculative backend exploits.
//! * **validate** — the arrival gate: availability churn dooms are
//!   decided here (never at speculation time), staleness is measured
//!   here, and every speculated execution is checked against the global
//!   version the client actually received — commit on hit, re-execute on
//!   miss.
//! * **commit** — exactly one aggregation folds in on the coordinator
//!   thread, the clock advances, and one [`RoundRecord`] flows to the
//!   observers and the checkpoint seam.
//!
//! [`sync`] runs the degenerate "barrier every commit" schedule (the
//! classic FL round loop); [`event`] runs the discrete-event asynchronous
//! schedule behind the `fedasync`/`fedbuff` registry rows; [`speculate`]
//! is the execute stage's speculative backend (`exec.speculate.depth`),
//! which trains *predicted* future dispatches on background workers while
//! earlier uploads are still in flight.
//!
//! The helpers below are the plumbing both schedules share — resume
//! validation, the eval harness, the commit stage (eval cadence + round
//! record + observers), and the checkpoint seam — so record, observer,
//! and checkpoint behavior can never drift between the two loops. Both
//! repo invariants hold on the shared core: bitwise thread-count
//! determinism (`tests/determinism.rs`) and bitwise kill/resume
//! (`tests/resume.rs`).

pub mod event;
pub(crate) mod speculate;
pub mod sync;

use crate::data::FedDataset;
use crate::fl::bias::o1_bias;
use crate::fl::observer::{RoundObserver, ServerState};
use crate::fl::server::{
    evaluate, ClientOutcome, ExecPool, ExperimentResult, ResumeState, RoundRecord, ServerCfg,
};
use crate::runtime::{Engine, TrainSession};
use crate::strategies::{ClientPlan, Strategy};
use crate::util::json::Json;

/// The shared eval harness: one coordinator-side session reused across
/// rounds, plus the experiment's dedicated executor pool (built once, and
/// not at all for engines whose sessions aren't validated for
/// concurrency).
pub(crate) struct Evaluator<'e> {
    session: Box<dyn TrainSession + 'e>,
    pool: Option<rayon::ThreadPool>,
    threads: usize,
}

impl<'e> Evaluator<'e> {
    pub(crate) fn new(engine: &'e dyn Engine, threads: usize) -> anyhow::Result<Evaluator<'e>> {
        let pool = if engine.parallel_sessions() { ExecPool::build(threads)? } else { None };
        Ok(Evaluator { session: engine.session(), pool, threads })
    }

    /// The pool every fan-out of this experiment rides (client plans and
    /// eval batches alike).
    pub(crate) fn pool(&self) -> ExecPool<'_> {
        ExecPool::from_cfg(self.threads, self.pool.as_ref())
    }

    /// Evaluate the global model over the held-out test set.
    pub(crate) fn eval(
        &mut self,
        engine: &dyn Engine,
        ds: &FedDataset,
        params: &[f32],
    ) -> anyhow::Result<(f64, f64)> {
        evaluate(
            engine,
            self.session.as_mut(),
            ExecPool::from_cfg(self.threads, self.pool.as_ref()),
            ds,
            params,
        )
    }
}

/// Per-commit accumulator over the aggregated (plan, outcome) pairs —
/// everything a [`RoundRecord`] needs that isn't clock or counters.
#[derive(Default)]
pub(crate) struct RoundStats {
    pub losses: Vec<f64>,
    pub coverage: Vec<f64>,
    pub tensor_masks: Vec<Vec<f32>>,
    pub client_secs: Vec<(usize, f64)>,
}

impl RoundStats {
    pub(crate) fn absorb(&mut self, plan: &ClientPlan, out: &ClientOutcome) {
        let cov = plan.mask.tensor_coverage();
        self.coverage.push(cov.iter().map(|&c| c as f64).sum::<f64>() / cov.len().max(1) as f64);
        self.tensor_masks.push(cov);
        self.losses.push(out.mean_loss);
        self.client_secs.push((plan.client, plan.est_time));
    }
}

/// Common [`ResumeState`] sanity checks. `noun` is the schedule's unit of
/// progress ("round" / "aggregation"), so error messages keep their
/// historical shapes.
pub(crate) fn validate_resume(
    r: &ResumeState,
    param_count: usize,
    rounds: usize,
    noun: &str,
) -> anyhow::Result<()> {
    anyhow::ensure!(
        r.global.len() == param_count,
        "resume params hold {} elements, manifest wants {}",
        r.global.len(),
        param_count
    );
    anyhow::ensure!(
        r.completed <= rounds,
        "resume point ({noun} {}) is beyond the configured {} rounds",
        r.completed,
        rounds
    );
    anyhow::ensure!(
        r.prior_records.len() == r.completed,
        "resume carries {} records for {} completed {noun}s",
        r.prior_records.len(),
        r.completed
    );
    Ok(())
}

/// The commit stage's tail: run the eval cadence, build the round record,
/// and hand it to the observers. `completed` counts this commit, so the
/// final round always evaluates.
#[allow(clippy::too_many_arguments)]
pub(crate) fn commit_round(
    engine: &dyn Engine,
    ds: &FedDataset,
    cfg: &ServerCfg,
    evaluator: &mut Evaluator<'_>,
    observer: &mut dyn RoundObserver,
    round: usize,
    completed: usize,
    round_secs: f64,
    sim_time: f64,
    global: &[f32],
    stats: RoundStats,
    staleness: Option<&[usize]>,
    dropped: Vec<usize>,
    spec_hits: usize,
    spec_misses: usize,
) -> anyhow::Result<RoundRecord> {
    let do_eval = round % cfg.eval_every == cfg.eval_every - 1 || completed == cfg.rounds;
    let (eval_acc, eval_loss) = if do_eval {
        let (a, l) = evaluator.eval(engine, ds, global)?;
        observer.on_eval(round, a, l);
        (Some(a), Some(l))
    } else {
        (None, None)
    };
    let o1 = if stats.tensor_masks.is_empty() { 0.0 } else { o1_bias(&stats.tensor_masks) };
    let (mean_staleness, max_staleness) = match staleness {
        Some(s) => (
            Some(crate::util::stats::mean(&s.iter().map(|&x| x as f64).collect::<Vec<_>>())),
            Some(s.iter().copied().max().unwrap_or(0) as f64),
        ),
        None => (None, None),
    };
    let record = RoundRecord {
        round,
        round_secs,
        sim_time,
        mean_train_loss: crate::util::stats::mean(&stats.losses),
        participants: stats.losses.len(),
        mean_coverage: crate::util::stats::mean(&stats.coverage),
        o1,
        eval_acc,
        eval_loss,
        client_secs: stats.client_secs,
        mean_staleness,
        max_staleness,
        dropped,
        spec_hits,
        spec_misses,
    };
    observer.on_round_end(&record);
    Ok(record)
}

/// The post-commit checkpoint seam: expose the server state to observers
/// (the checkpointing hook, [`crate::store`]) and honor the simulated
/// kill switch. `noun` keeps the halt message's historical shape
/// ("round" / "aggregation").
#[allow(clippy::too_many_arguments)]
pub(crate) fn checkpoint_seam(
    cfg: &ServerCfg,
    observer: &mut dyn RoundObserver,
    completed: usize,
    sim_time: f64,
    global: &[f32],
    strategy: &dyn Strategy,
    async_state: Option<&dyn Fn() -> Json>,
    noun: &str,
) -> anyhow::Result<()> {
    observer.on_server_state(&ServerState { completed, sim_time, global, strategy, async_state });
    if cfg.halt_after == Some(completed) && completed < cfg.rounds {
        anyhow::bail!(
            "halted after {noun} {completed} (simulated interruption — \
             resume from the run store)"
        );
    }
    Ok(())
}

/// Close out an experiment: the final score reuses the last commit's eval
/// (the cadence forces one) and the fallback only fires for `rounds == 0`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn finish_experiment(
    engine: &dyn Engine,
    ds: &FedDataset,
    evaluator: &mut Evaluator<'_>,
    strategy: &dyn Strategy,
    observer: &mut dyn RoundObserver,
    records: Vec<RoundRecord>,
    sim_time: f64,
    global: Vec<f32>,
) -> anyhow::Result<ExperimentResult> {
    let (final_acc, final_loss) = match records.last().and_then(|r| r.eval_acc.zip(r.eval_loss)) {
        Some((a, l)) => (a, l),
        None => evaluator.eval(engine, ds, &global)?,
    };
    let result = ExperimentResult {
        strategy: strategy.name().to_string(),
        records,
        sim_total_secs: sim_time,
        final_acc,
        final_loss,
        final_params: global,
        selections: Vec::new(),
    };
    observer.on_experiment_end(&result);
    Ok(result)
}
