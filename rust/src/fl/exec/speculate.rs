//! The execute stage's speculative backend (`exec.speculate.depth`).
//!
//! Steady-state asynchronous dispatches are serial by nature — each
//! depends on the latest aggregated global — so without speculation a
//! multi-core host simulates a parallel fleet one upload at a time. This
//! module recovers the parallelism:
//!
//! * **Lookahead** ([`lookahead`]) simulates the next `depth` events on a
//!   clone of the event queue. The simulated (client, iteration, finish)
//!   facts are *exact* — re-dispatch sampling, iteration bookkeeping, and
//!   finish-time arithmetic are pure functions of the runner state — so
//!   the only speculative quantity is the global **version** a future
//!   dispatch will start from, predicted optimistically (every simulated
//!   arrival aggregates; churn dooms are never assumed, because dooming
//!   is the validate stage's decision).
//! * **Binding**: the first prediction for a (client, iteration) is
//!   recorded in [`AsyncState::speculated`] and never rebound. Arrival
//!   validates the binding against the version the client actually
//!   received — equal is a **hit**, anything else a **miss** (doomed
//!   arrivals score their bindings as misses too). Because lookahead is
//!   pure and bindings drain at their arrival events, the counters are a
//!   pure function of (state, depth) — independent of thread count, of
//!   whether the worker pool exists, and of kill/resume.
//! * **Execution**: predicted dispatches whose start version already has
//!   materialized params run on background worker threads (one engine
//!   session each, fed through a shared job channel) while the
//!   coordinator aggregates earlier arrivals. Training is a pure function
//!   of (start params, client, iteration tag), so a speculated outcome is
//!   bitwise-identical to the same dispatch executed inline — a hit
//!   commits the precomputed outcome, a miss re-executes at the actual
//!   version ([`SpecExec::resolve`]).
//!
//! At depth 0 (the default) [`SpecExec::prepare`] degenerates to the
//! eager executor the runner always had: every in-flight dispatch
//! materializes before its event pops, through the parallel pool when
//! the pending set is uniform (the initial fleet-wide fan-out) and the
//! coordinator session otherwise.

use std::collections::{BTreeSet, HashMap, HashSet};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

use crate::data::FedDataset;
use crate::fl::exec::event::{is_doomed, sample_client, AsyncState, EventKey};
use crate::fl::server::{
    execute_plan, execute_plans_streaming, plan_payload_bytes, ClientOutcome, ExecPool,
    RoundInputs, ServerCfg,
};
use crate::manifest::Manifest;
use crate::runtime::{Engine, TrainSession};
use crate::strategies::{full_model_plan, AsyncMode, ClientPlan, FleetCtx};

/// A dispatch's identity for the outcome cache: (client, iteration, start
/// version). The same (client, iteration) speculated at a wrong version
/// and re-executed at the right one are different keys — only the version
/// the client actually received ever aggregates.
type Key = (usize, usize, usize);

/// A unit of background work: train `client` at `iter` from the `start`
/// params (version `version`).
struct Job {
    client: usize,
    iter: usize,
    version: usize,
    start: Vec<f32>,
    plan: ClientPlan,
}

type JobResult = (Key, anyhow::Result<ClientOutcome>);

/// One simulated future dispatch from the lookahead.
struct Pred {
    client: usize,
    iter: usize,
    /// Optimistically predicted start version.
    version: usize,
    /// Exact simulated finish time (used only for the doom filter).
    finish: f64,
    plan: ClientPlan,
}

/// The execute stage's state machine: an outcome cache over dispatch
/// keys, the in-flight background submissions, and the speculation
/// hit/miss counters (drained into each committed record).
pub(crate) struct SpecExec {
    depth: usize,
    /// Ready outcomes by dispatch key.
    cache: HashMap<Key, ClientOutcome>,
    /// Keys submitted to the workers and not yet returned.
    pending: HashSet<Key>,
    /// Background failures held until (unless) their key resolves —
    /// a mispredicted dispatch's error must not sink the run.
    failed: HashMap<Key, anyhow::Error>,
    /// Per-client highest resolved iteration: late background results at
    /// or below it are stale and dropped on arrival.
    consumed: HashMap<usize, usize>,
    jobs: Option<Sender<Job>>,
    results: Option<Receiver<JobResult>>,
    hits: usize,
    misses: usize,
}

impl SpecExec {
    pub(crate) fn new(depth: usize) -> SpecExec {
        SpecExec {
            depth,
            cache: HashMap::new(),
            pending: HashSet::new(),
            failed: HashMap::new(),
            consumed: HashMap::new(),
            jobs: None,
            results: None,
            hits: 0,
            misses: 0,
        }
    }

    /// Spawn the background worker pool into `scope`. Each worker owns
    /// one engine session and pulls jobs from a shared channel; workers
    /// exit when the job sender drops (i.e. when this `SpecExec` does,
    /// at the end of the event loop's scope). Purely an execution
    /// backend: nothing the workers do is ever observable in the
    /// simulation's bookkeeping, only in wall-clock.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn spawn_workers<'scope, 'env>(
        &mut self,
        scope: &'scope std::thread::Scope<'scope, 'env>,
        engine: &'env dyn Engine,
        ds: &'env FedDataset,
        ctx: &'env FleetCtx,
        m: &'env Manifest,
        prox_mu: f64,
        threads: usize,
    ) {
        let workers = match threads {
            0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            n => n,
        };
        let (jtx, jrx) = channel::<Job>();
        let (rtx, rrx) = channel::<JobResult>();
        let jrx = Arc::new(Mutex::new(jrx));
        for _ in 0..workers {
            let jrx = Arc::clone(&jrx);
            let rtx = rtx.clone();
            scope.spawn(move || {
                let mut session = engine.session();
                loop {
                    // Hold the lock only for the blocking recv, never
                    // while training.
                    let job = match jrx.lock() {
                        Ok(rx) => rx.recv(),
                        Err(_) => break,
                    };
                    let Ok(Job { client, iter, version, start, plan }) = job else {
                        break;
                    };
                    let inputs = RoundInputs { ds, ctx, global: &start, round: iter, prox_mu };
                    let out = execute_plan(session.as_mut(), &inputs, m, &plan);
                    if rtx.send(((client, iter, version), out)).is_err() {
                        break;
                    }
                }
            });
        }
        self.jobs = Some(jtx);
        self.results = Some(rrx);
    }

    /// The execute stage, called once before every event pop. Depth 0:
    /// eagerly materialize every in-flight outcome (bitwise and
    /// schedule-identical to the pre-speculation executor). Depth > 0:
    /// submit known in-flight work to the background pool, then run the
    /// lookahead — record version bindings for the next `depth` predicted
    /// dispatches and submit the executable ones. The binding/counter
    /// bookkeeping runs whether or not a worker pool exists, so recorded
    /// results never depend on the execution backend.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn prepare(
        &mut self,
        engine: &dyn Engine,
        ds: &FedDataset,
        ctx: &FleetCtx,
        m: &Manifest,
        prox_mu: f64,
        cfg: &ServerCfg,
        mode: &AsyncMode,
        state: &mut AsyncState,
        completed: usize,
        coordinator: &mut dyn TrainSession,
        pool: ExecPool<'_>,
    ) -> anyhow::Result<()> {
        self.drain_ready();
        if self.jobs.is_some() {
            self.submit_known(ctx, cfg, state);
        } else {
            self.execute_known(engine, ds, ctx, m, prox_mu, cfg, state, coordinator, pool)?;
        }
        if self.depth > 0 {
            self.speculate_ahead(ctx, m, cfg, mode, state, completed);
        }
        Ok(())
    }

    /// Eager executor (no background pool): every not-yet-materialized,
    /// not-doomed in-flight dispatch runs now. When all pending
    /// dispatches share a start version and iteration tag (the initial
    /// fleet-wide fan-out), they fan across the parallel pool; mixed
    /// pending sets (post-resume) run serially through the coordinator
    /// session — outcomes are pure either way, so results never depend
    /// on the path taken.
    #[allow(clippy::too_many_arguments)]
    fn execute_known(
        &mut self,
        engine: &dyn Engine,
        ds: &FedDataset,
        ctx: &FleetCtx,
        m: &Manifest,
        prox_mu: f64,
        cfg: &ServerCfg,
        state: &AsyncState,
        coordinator: &mut dyn TrainSession,
        pool: ExecPool<'_>,
    ) -> anyhow::Result<()> {
        let pending: Vec<usize> = (0..state.inflight.len())
            .filter(|&s| {
                let f = &state.inflight[s];
                !self.cache.contains_key(&(f.client, f.iter, f.version))
                    && !is_doomed(ctx, cfg, f.client, f.iter, f.finish)
            })
            .collect();
        let Some(&first) = pending.first() else {
            return Ok(());
        };
        let uniform = pending.iter().all(|&s| {
            state.inflight[s].version == state.inflight[first].version
                && state.inflight[s].iter == state.inflight[first].iter
        });
        if uniform && pending.len() > 1 {
            let start = state.versions[&state.inflight[first].version].clone();
            let inputs =
                RoundInputs { ds, ctx, global: &start, round: state.inflight[first].iter, prox_mu };
            let plans: Vec<ClientPlan> =
                pending.iter().map(|&s| state.inflight[s].plan.clone()).collect();
            let keys: Vec<Key> = pending
                .iter()
                .map(|&s| {
                    let f = &state.inflight[s];
                    (f.client, f.iter, f.version)
                })
                .collect();
            execute_plans_streaming(engine, &inputs, &plans, pool, |i, out| {
                self.cache.insert(keys[i], out);
                Ok(())
            })?;
        } else {
            for s in pending {
                let f = &state.inflight[s];
                let key = (f.client, f.iter, f.version);
                let plan = f.plan.clone();
                let round = f.iter;
                let start = state.versions[&f.version].clone();
                let inputs = RoundInputs { ds, ctx, global: &start, round, prox_mu };
                let out = execute_plan(coordinator, &inputs, m, &plan)?;
                self.cache.insert(key, out);
            }
        }
        Ok(())
    }

    /// Submit every known in-flight dispatch that isn't already
    /// materialized, submitted, or doomed to the background pool.
    fn submit_known(&mut self, ctx: &FleetCtx, cfg: &ServerCfg, state: &AsyncState) {
        let Some(jobs) = &self.jobs else { return };
        for f in &state.inflight {
            let key = (f.client, f.iter, f.version);
            if self.cache.contains_key(&key)
                || self.pending.contains(&key)
                || is_doomed(ctx, cfg, f.client, f.iter, f.finish)
            {
                continue;
            }
            let job = Job {
                client: f.client,
                iter: f.iter,
                version: f.version,
                start: state.versions[&f.version].clone(),
                plan: f.plan.clone(),
            };
            if jobs.send(job).is_ok() {
                self.pending.insert(key);
            }
        }
    }

    /// Run the lookahead, bind first predictions, and submit the
    /// executable ones (predicted version already materialized, upload
    /// not doomed) to the background pool.
    fn speculate_ahead(
        &mut self,
        ctx: &FleetCtx,
        m: &Manifest,
        cfg: &ServerCfg,
        mode: &AsyncMode,
        state: &mut AsyncState,
        completed: usize,
    ) {
        for p in lookahead(state, ctx, m, cfg, mode, completed, self.depth) {
            // First prediction binds; the arrival event scores it.
            state.speculated.entry((p.client, p.iter)).or_insert(p.version);
            let Some(jobs) = &self.jobs else { continue };
            let key = (p.client, p.iter, p.version);
            if self.cache.contains_key(&key) || self.pending.contains(&key) {
                continue;
            }
            // A predicted version with no materialized params yet (the
            // aggregation producing it hasn't happened) can't train.
            let Some(start) = state.versions.get(&p.version) else { continue };
            if is_doomed(ctx, cfg, p.client, p.iter, p.finish) {
                continue;
            }
            let job = Job {
                client: p.client,
                iter: p.iter,
                version: p.version,
                start: start.clone(),
                plan: p.plan,
            };
            if jobs.send(job).is_ok() {
                self.pending.insert(key);
            }
        }
    }

    /// Move every already-finished background result into the cache
    /// without blocking.
    fn drain_ready(&mut self) {
        let Some(rx) = &self.results else { return };
        while let Ok((key, out)) = rx.try_recv() {
            self.pending.remove(&key);
            if self.consumed.get(&key.0).is_some_and(|&it| key.1 <= it) {
                continue; // stale: that (client, iteration) already resolved
            }
            match out {
                Ok(o) => {
                    self.cache.insert(key, o);
                }
                Err(e) => {
                    self.failed.insert(key, e);
                }
            }
        }
    }

    /// Take `key`'s outcome: from the cache, or by blocking on the
    /// results channel while the key is pending. `None` = never
    /// materialized (caller executes inline).
    fn take(&mut self, key: Key) -> anyhow::Result<Option<ClientOutcome>> {
        loop {
            if let Some(o) = self.cache.remove(&key) {
                return Ok(Some(o));
            }
            if let Some(e) = self.failed.remove(&key) {
                return Err(e);
            }
            if !self.pending.contains(&key) {
                return Ok(None);
            }
            let rx = self.results.as_ref().expect("pending background work without a pool");
            match rx.recv() {
                Ok((k, out)) => {
                    self.pending.remove(&k);
                    match out {
                        Ok(o) => {
                            self.cache.insert(k, o);
                        }
                        Err(e) => {
                            self.failed.insert(k, e);
                        }
                    }
                }
                Err(_) => anyhow::bail!("speculative executor lost its workers"),
            }
        }
    }

    /// The validate stage for one non-doomed arrival: score its
    /// speculation binding (if any), then produce the outcome at the
    /// version the client actually received — the precomputed one on a
    /// hit, a fresh inline execution otherwise. Either way the returned
    /// outcome is the pure function of (actual start params, client,
    /// iteration), so aggregation never sees speculation.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn resolve(
        &mut self,
        ds: &FedDataset,
        ctx: &FleetCtx,
        m: &Manifest,
        prox_mu: f64,
        state: &mut AsyncState,
        client: usize,
        iter: usize,
        version: usize,
        plan: &ClientPlan,
        coordinator: &mut dyn TrainSession,
    ) -> anyhow::Result<ClientOutcome> {
        if let Some(bound) = state.speculated.remove(&(client, iter)) {
            if bound == version {
                self.hits += 1;
            } else {
                self.misses += 1;
            }
        }
        let out = match self.take((client, iter, version))? {
            Some(o) => o,
            None => {
                // Mispredicted version, no background pool, or a
                // post-resume cold cache: re-execute at the actual
                // version. Purity makes this bitwise-identical to having
                // executed it anywhere else.
                let start = state
                    .versions
                    .get(&version)
                    .expect("arrived dispatch references a live version")
                    .clone();
                let inputs = RoundInputs { ds, ctx, global: &start, round: iter, prox_mu };
                execute_plan(coordinator, &inputs, m, plan)?
            }
        };
        self.consume(client, iter);
        Ok(out)
    }

    /// The validate stage for a doomed arrival: the dispatch never
    /// aggregates, so an open binding for it scores a miss and any
    /// precomputed outcome is waste.
    pub(crate) fn discard(&mut self, state: &mut AsyncState, client: usize, iter: usize) {
        if state.speculated.remove(&(client, iter)).is_some() {
            self.misses += 1;
        }
        self.consume(client, iter);
    }

    /// Retire a (client, iteration): purge its cached/failed entries and
    /// remember the watermark so late background results for it are
    /// dropped on arrival.
    fn consume(&mut self, client: usize, iter: usize) {
        let e = self.consumed.entry(client).or_insert(iter);
        if *e < iter {
            *e = iter;
        }
        self.cache.retain(|k, _| !(k.0 == client && k.1 <= iter));
        self.failed.retain(|k, _| !(k.0 == client && k.1 <= iter));
    }

    /// Drain the hit/miss counters accumulated since the last commit.
    pub(crate) fn take_counters(&mut self) -> (usize, usize) {
        (std::mem::take(&mut self.hits), std::mem::take(&mut self.misses))
    }
}

/// Simulate the next `depth` events on a clone of the queue. Re-dispatch
/// facts — which client, at which iteration, finishing when — replicate
/// the real loop's arithmetic exactly (sampling draws, iteration
/// bookkeeping, arrival windows, per-client comm pricing); the predicted
/// start *version* is optimistic, advancing as if every simulated arrival
/// aggregated (per-arrival: +1 each; buffered: +1 per flush of
/// `k.max(1)`). Under churn, doomed arrivals don't actually aggregate, so
/// the real version lags the prediction — those speculations miss and
/// re-execute; churn-free runs predict perfectly.
fn lookahead(
    state: &AsyncState,
    ctx: &FleetCtx,
    m: &Manifest,
    cfg: &ServerCfg,
    mode: &AsyncMode,
    completed: usize,
    depth: usize,
) -> Vec<Pred> {
    let n = ctx.n_clients();
    let sampled = cfg.sample != 0;
    let mut queue = state.queue.clone();
    // Facts of each slot's *simulated* current dispatch, where it has
    // already been re-dispatched in simulation (real facts otherwise).
    let mut overlay: HashMap<usize, (usize, usize, f64)> = HashMap::new();
    let mut slot_client: Vec<usize> = state.inflight.iter().map(|f| f.client).collect();
    let mut sim_seq = state.seq;
    let mut sim_iters = state.iters.clone();
    let mut sim_completed = completed;
    let mut sim_buf = state.buffer.len();
    let mut preds = Vec::with_capacity(depth);
    for _ in 0..depth {
        let Some(std::cmp::Reverse(ev)) = queue.pop() else { break };
        let (client, iter, finish) = overlay.get(&ev.slot).copied().unwrap_or_else(|| {
            let f = &state.inflight[ev.slot];
            (f.client, f.iter, f.finish)
        });
        match mode {
            AsyncMode::PerArrival { .. } => sim_completed += 1,
            AsyncMode::Buffered { k, .. } => {
                sim_buf += 1;
                if sim_buf >= (*k).max(1) {
                    sim_buf = 0;
                    sim_completed += 1;
                }
            }
        }
        let (next_client, next_iter) = if sampled {
            let busy: BTreeSet<usize> = slot_client
                .iter()
                .enumerate()
                .filter(|&(s, _)| s != ev.slot)
                .map(|(_, &c)| c)
                .collect();
            let c = sample_client(cfg.seed, sim_seq, n, &busy);
            sim_seq += 1;
            let it = sim_iters.get(&c).copied().unwrap_or(0);
            sim_iters.insert(c, it + 1);
            (c, it)
        } else {
            (client, iter + 1)
        };
        let plan = full_model_plan(ctx, next_client);
        let (down, up) = plan_payload_bytes(m, &plan);
        let start = ctx.fleet.start_at(next_client, finish);
        let comm = ctx.client_comm(cfg.comm, next_client);
        let next_finish = start + comm.client_total_secs(plan.est_time, down, up);
        queue.push(std::cmp::Reverse(EventKey {
            finish: next_finish,
            client: next_client,
            slot: ev.slot,
        }));
        overlay.insert(ev.slot, (next_client, next_iter, next_finish));
        slot_client[ev.slot] = next_client;
        preds.push(Pred {
            client: next_client,
            iter: next_iter,
            version: sim_completed,
            finish: next_finish,
            plan,
        });
    }
    preds
}
