//! Event-driven asynchronous execution: the runtime behind the
//! `fedasync` / `fedbuff` strategy rows, staged on the execution core
//! ([`crate::fl::exec`]).
//!
//! The synchronous schedule ([`super::sync`]) advances its clock by the
//! slowest participant — the exact straggler tax FedEL attacks.
//! Asynchronous FL sidesteps the barrier instead: every client trains the
//! full model **at its own device pace**, and the server folds updates in
//! as they arrive. This module simulates that with a discrete-event
//! clock:
//!
//! * each runner *slot* always has exactly one dispatch in flight, whose
//!   finish time = dispatch time + download + compute + upload under the
//!   client's [`CommModel`](crate::timing::CommModel) (per-client trace
//!   links override the base model). With `fleet.sample = 0` there is one
//!   slot per client (legacy full fan-out); with `fleet.sample = k` only
//!   k clients are in flight at once and a finished slot re-samples a
//!   fresh client — the O(sampled) regime lazy million-client fleets
//!   require;
//! * events (upload completions) pop from a binary heap in simulated-time
//!   order — O(log n) per event — with ties broken by client id then
//!   slot, so the event sequence is a pure function of the inputs;
//! * availability churn ([`crate::fleet::ChurnCfg`] + trace windows)
//!   dooms an upload **at its arrival event** (the validate stage) — a
//!   pure function of (seed, client, iteration, finish time), never of
//!   when or whether the dispatch was speculatively executed — and a
//!   doomed upload is discarded instead of aggregated, recorded in the
//!   next [`RoundRecord::dropped`](crate::fl::server::RoundRecord);
//! * the server aggregates per the strategy's [`AsyncSpec`]:
//!   [`AsyncMode::PerArrival`] mixes every arrival immediately with a
//!   staleness-decayed weight (FedAsync), [`AsyncMode::Buffered`] flushes
//!   a data-size-weighted delta average every K arrivals (FedBuff). One
//!   aggregation = one record, carrying the folded arrivals' staleness
//!   statistics and the interval's speculation hit/miss counters.
//!
//! With `exec.speculate.depth > 0` the execute stage runs through
//! [`super::speculate`]: an exact event-lookahead predicts the next
//! dispatches, background workers train them against predicted global
//! versions while earlier uploads are still in flight, and each arrival
//! validates its speculation against the version the client actually
//! received — commit on hit, re-execute on miss.
//!
//! Both of the repo's execution invariants carry over:
//!
//! * **Thread-count determinism** — training outcomes are pure functions
//!   of (start params, client, iteration tag); speculation only ever
//!   changes *where* a dispatch executes, never *what* it produces, and
//!   aggregation runs on the coordinator in event order, so results are
//!   bitwise-identical at any `exec_threads` (`tests/determinism.rs`).
//!   The prediction bookkeeping (and therefore every hit/miss counter)
//!   is a pure function of the event sequence and the speculation depth —
//!   it never consults the worker pool.
//! * **Kill/resume identity** — the runner's full execution state
//!   (in-flight client clocks + dispatch versions, the referenced global
//!   versions, the staleness buffer, the open speculation bindings)
//!   snapshots to JSON after every aggregation and rides
//!   `Checkpoint::async_state` ([`crate::store::schema::Checkpoint`]); a
//!   resumed run re-executes in-flight dispatches from their recorded
//!   start versions and continues the event sequence exactly
//!   (`tests/resume.rs`).

use crate::data::FedDataset;
use crate::fl::exec::speculate::SpecExec;
use crate::fl::exec::{checkpoint_seam, commit_round, finish_experiment, validate_resume};
use crate::fl::exec::{Evaluator, RoundStats};
use crate::fl::observer::RoundObserver;
use crate::fl::server::{
    plan_payload_bytes, ClientOutcome, ExperimentResult, ResumeState, ServerCfg,
};
use crate::fl::sparse::SparseDelta;
use crate::manifest::Manifest;
use crate::runtime::Engine;
use crate::strategies::{full_model_plan, AsyncMode, AsyncSpec, ClientPlan, FleetCtx, Strategy};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// One slot's dispatch currently in flight.
pub(crate) struct InFlight {
    /// Which client this dispatch belongs to. Equal to the slot index in
    /// full fan-out mode; an arbitrary sampled client when `fleet.sample`
    /// caps the in-flight set.
    pub(crate) client: usize,
    /// Client-local iteration index — the batch-sampling tag base, so a
    /// client's data stream continues deterministically across dispatches
    /// (and across kill/resume).
    pub(crate) iter: usize,
    /// Server version (aggregation count) whose global the dispatch
    /// started from; staleness at aggregation = current version − this.
    pub(crate) version: usize,
    /// Simulated completion time (download + compute + upload).
    pub(crate) finish: f64,
    pub(crate) plan: ClientPlan,
}

/// Heap key for the event queue: earliest finish first, ties broken by
/// client id (the documented deterministic order) then slot. One live
/// entry per slot at all times — pushed at dispatch, popped at the event —
/// so there is no lazy deletion. `Clone` so the speculation lookahead can
/// simulate forward on a copy of the queue.
#[derive(Clone)]
pub(crate) struct EventKey {
    pub(crate) finish: f64,
    pub(crate) client: usize,
    pub(crate) slot: usize,
}

impl Ord for EventKey {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        self.finish
            .total_cmp(&o.finish)
            .then(self.client.cmp(&o.client))
            .then(self.slot.cmp(&o.slot))
    }
}

impl PartialOrd for EventKey {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}

impl PartialEq for EventKey {
    fn eq(&self, o: &Self) -> bool {
        self.cmp(o) == std::cmp::Ordering::Equal
    }
}

impl Eq for EventKey {}

/// An arrived update waiting in the FedBuff buffer.
pub(crate) struct BufEntry {
    pub(crate) version: usize,
    pub(crate) plan: ClientPlan,
    pub(crate) outcome: ClientOutcome,
}

/// The runner's mutable simulation state — everything a checkpoint must
/// capture beyond the global model and the record stream.
pub(crate) struct AsyncState {
    /// In-flight slots. Full fan-out: one per client, index == client id.
    /// Sampled (`fleet.sample = k`): `min(k, n)` slots over a rotating
    /// client set.
    pub(crate) inflight: Vec<InFlight>,
    /// The event queue: min-heap over (finish, client, slot). NOT
    /// serialized — rebuilt from `inflight` on resume.
    pub(crate) queue: std::collections::BinaryHeap<std::cmp::Reverse<EventKey>>,
    /// Global params by version, for every version still referenced by an
    /// in-flight dispatch or a buffered update (GC'd as references drop).
    pub(crate) versions: std::collections::BTreeMap<usize, Vec<f32>>,
    /// FedBuff's pending arrivals (always empty for FedAsync).
    pub(crate) buffer: Vec<BufEntry>,
    /// Sampled mode only: how many sampling draws have been made — the
    /// pure-hash tag of the next draw, so sampling needs no RNG state.
    pub(crate) seq: u64,
    /// Sampled mode only: each previously-sampled client's next iteration
    /// index (absent = 0), so a re-sampled client's data stream continues
    /// where it left off.
    pub(crate) iters: std::collections::BTreeMap<usize, usize>,
    /// Clients whose uploads churn discarded since the last aggregation;
    /// drained into the record's `dropped` (and therefore always empty at
    /// the post-aggregation checkpoint seam).
    pub(crate) dropped: Vec<usize>,
    /// Open speculation bindings: (client, iter) → the global version the
    /// lookahead predicted when it first speculated that dispatch. The
    /// first prediction binds (later lookaheads never rebind), arrival
    /// validates — bound == actual is a hit, anything else a miss. Part
    /// of the checkpoint snapshot so a resumed run scores the same
    /// already-made predictions an uninterrupted run would. Always empty
    /// at depth 0.
    pub(crate) speculated: std::collections::BTreeMap<(usize, usize), usize>,
}

impl AsyncState {
    /// Drop version params nothing references anymore.
    pub(crate) fn gc_versions(&mut self) {
        let live: std::collections::BTreeSet<usize> = self
            .inflight
            .iter()
            .map(|f| f.version)
            .chain(self.buffer.iter().map(|b| b.version))
            .collect();
        self.versions.retain(|v, _| live.contains(v));
    }

    /// Enqueue slot `slot`'s current dispatch.
    pub(crate) fn push_event(&mut self, slot: usize) {
        let f = &self.inflight[slot];
        self.queue.push(std::cmp::Reverse(EventKey {
            finish: f.finish,
            client: f.client,
            slot,
        }));
    }

    /// The earliest-finishing in-flight slot — O(log n), ties break by
    /// client id, the deterministic event order the module doc promises.
    /// The popped slot MUST be re-dispatched (re-pushed) before the next
    /// pop to keep the one-entry-per-slot invariant.
    pub(crate) fn pop_event(&mut self) -> usize {
        self.queue.pop().expect("async runner with an empty fleet").0.slot
    }

    /// Rebuild the queue from scratch (after construction or resume).
    pub(crate) fn rebuild_queue(&mut self) {
        self.queue.clear();
        for slot in 0..self.inflight.len() {
            self.push_event(slot);
        }
    }

    /// Serialize for `Checkpoint::async_state`. f32 params ride JSON f64
    /// numbers (exact: f32→f64 is lossless and the writer's shortest
    /// round-trip Display preserves every f64), so resumed state is
    /// bit-identical.
    pub(crate) fn to_json(&self, mode: &AsyncMode) -> Json {
        let mut fields = vec![
            ("mode", Json::Str(mode_tag(mode).to_string())),
            (
                "inflight",
                Json::Arr(
                    self.inflight
                        .iter()
                        .map(|f| {
                            Json::obj(vec![
                                ("client", Json::Num(f.client as f64)),
                                ("iter", Json::Num(f.iter as f64)),
                                ("version", Json::Num(f.version as f64)),
                                ("finish", Json::Num(f.finish)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "versions",
                Json::Arr(
                    self.versions
                        .iter()
                        .map(|(v, params)| {
                            Json::obj(vec![
                                ("version", Json::Num(*v as f64)),
                                ("params", f32s_to_json(params)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "buffer",
                Json::Arr(
                    self.buffer
                        .iter()
                        .map(|b| {
                            Json::obj(vec![
                                ("client", Json::Num(b.outcome.client as f64)),
                                ("version", Json::Num(b.version as f64)),
                                ("mean_loss", Json::Num(b.outcome.mean_loss)),
                                ("sq_grads", Json::from_f64s(&b.outcome.sq_grads)),
                                // Async dispatches always train the full
                                // model, so the delta is dense — keep the
                                // legacy "params" key (and the blob
                                // externalization that walks it) intact.
                                ("params", f32s_to_json(dense(&b.outcome))),
                            ])
                        })
                        .collect(),
                ),
            ),
        ];
        // Omit-at-default: depth-0 runs (and full fan-out snapshots) stay
        // bitwise-identical to the pre-speculation schema.
        if self.seq > 0 {
            fields.push(("seq", Json::Num(self.seq as f64)));
        }
        if !self.iters.is_empty() {
            fields.push((
                "iters",
                Json::Arr(
                    self.iters
                        .iter()
                        .map(|(&c, &i)| {
                            Json::obj(vec![
                                ("client", Json::Num(c as f64)),
                                ("iter", Json::Num(i as f64)),
                            ])
                        })
                        .collect(),
                ),
            ));
        }
        if !self.dropped.is_empty() {
            fields.push((
                "dropped",
                Json::Arr(self.dropped.iter().map(|&c| Json::Num(c as f64)).collect()),
            ));
        }
        if !self.speculated.is_empty() {
            fields.push((
                "speculated",
                Json::Arr(
                    self.speculated
                        .iter()
                        .map(|(&(c, i), &v)| {
                            Json::obj(vec![
                                ("client", Json::Num(c as f64)),
                                ("iter", Json::Num(i as f64)),
                                ("version", Json::Num(v as f64)),
                            ])
                        })
                        .collect(),
                ),
            ));
        }
        Json::obj(fields)
    }

    /// Rebuild from a checkpoint snapshot. In-flight *outcomes* are not
    /// stored — they re-execute deterministically from the recorded start
    /// version and iteration tag; churn verdicts are likewise recomputed
    /// at validate time (pure functions of the stored dispatch facts).
    pub(crate) fn from_json(
        j: &Json,
        ctx: &FleetCtx,
        cfg: &ServerCfg,
        mode: &AsyncMode,
    ) -> anyhow::Result<AsyncState> {
        let got = j.s("mode")?;
        anyhow::ensure!(
            got == mode_tag(mode),
            "checkpoint was taken in async mode {got:?} but the strategy runs {:?}",
            mode_tag(mode)
        );
        let n = ctx.n_clients();
        let slots = if cfg.sample == 0 { n } else { cfg.sample.min(n) };
        let mut inflight: Vec<InFlight> = Vec::with_capacity(slots);
        let mut seen = std::collections::BTreeSet::new();
        for f in j.arr("inflight")? {
            let client = f.u("client")?;
            anyhow::ensure!(client < n, "async state: in-flight client {client} out of range");
            anyhow::ensure!(seen.insert(client), "async state: client {client} in flight twice");
            inflight.push(InFlight {
                client,
                iter: f.u("iter")?,
                version: f.u("version")?,
                finish: f.f("finish")?,
                plan: full_model_plan(ctx, client),
            });
        }
        anyhow::ensure!(
            inflight.len() == slots,
            "async state: {} in-flight slots, the runner wants {slots}",
            inflight.len()
        );
        if cfg.sample == 0 {
            // Full fan-out: slot s holds client s (the legacy layout —
            // and what to_json always wrote).
            for (s, f) in inflight.iter().enumerate() {
                anyhow::ensure!(
                    f.client == s,
                    "async state: full fan-out slot {s} holds client {}",
                    f.client
                );
            }
        }
        let mut versions = std::collections::BTreeMap::new();
        for v in j.arr("versions")? {
            let params = json_to_f32s(v.req("params")?, "version params")?;
            anyhow::ensure!(
                params.len() == ctx.manifest.param_count,
                "async state: version params hold {} elements, manifest wants {}",
                params.len(),
                ctx.manifest.param_count
            );
            versions.insert(v.u("version")?, params);
        }
        let mut buffer = Vec::new();
        for b in j.arr("buffer")? {
            let client = b.u("client")?;
            anyhow::ensure!(client < n, "async state: buffered client {client} out of range");
            buffer.push(BufEntry {
                version: b.u("version")?,
                plan: full_model_plan(ctx, client),
                outcome: ClientOutcome {
                    client,
                    delta: SparseDelta::dense(json_to_f32s(b.req("params")?, "buffered params")?),
                    sq_grads: b.req("sq_grads")?.to_f64_vec()?,
                    mean_loss: b.f("mean_loss")?,
                },
            });
        }
        let seq = j.get("seq").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
        let mut iters = std::collections::BTreeMap::new();
        if let Some(arr) = j.get("iters").and_then(|v| v.as_arr()) {
            for e in arr {
                iters.insert(e.u("client")?, e.u("iter")?);
            }
        }
        let mut dropped = Vec::new();
        if let Some(arr) = j.get("dropped").and_then(|v| v.as_arr()) {
            for e in arr {
                dropped.push(
                    e.as_f64()
                        .ok_or_else(|| anyhow::anyhow!("async state: dropped entry not a number"))?
                        as usize,
                );
            }
        }
        let mut speculated = std::collections::BTreeMap::new();
        if let Some(arr) = j.get("speculated").and_then(|v| v.as_arr()) {
            for e in arr {
                let client = e.u("client")?;
                anyhow::ensure!(
                    client < n,
                    "async state: speculated client {client} out of range"
                );
                speculated.insert((client, e.u("iter")?), e.u("version")?);
            }
        }
        let mut state = AsyncState {
            inflight,
            queue: std::collections::BinaryHeap::new(),
            versions,
            buffer,
            seq,
            iters,
            dropped,
            speculated,
        };
        for f in &state.inflight {
            anyhow::ensure!(
                state.versions.contains_key(&f.version),
                "async state: in-flight version {} has no stored params",
                f.version
            );
        }
        for b in &state.buffer {
            anyhow::ensure!(
                b.outcome.delta.param_count == ctx.manifest.param_count,
                "async state: buffered params hold {} elements, manifest wants {}",
                b.outcome.delta.param_count,
                ctx.manifest.param_count
            );
        }
        state.rebuild_queue();
        Ok(state)
    }
}

pub(crate) fn mode_tag(mode: &AsyncMode) -> &'static str {
    match mode {
        AsyncMode::PerArrival { .. } => "per_arrival",
        AsyncMode::Buffered { .. } => "buffered",
    }
}

/// An async outcome's full parameter vector. Every async dispatch is a
/// full-model plan, so the outcome's delta is always dense.
fn dense(out: &ClientOutcome) -> &[f32] {
    out.delta.dense_view().expect("async dispatches train the full model")
}

fn f32s_to_json(v: &[f32]) -> Json {
    Json::Arr(v.iter().map(|&p| Json::Num(p as f64)).collect())
}

fn json_to_f32s(j: &Json, what: &str) -> anyhow::Result<Vec<f32>> {
    j.as_arr()
        .ok_or_else(|| anyhow::anyhow!("async state: {what} not an array"))?
        .iter()
        .map(|x| {
            x.as_f64()
                .map(|x| x as f32)
                .ok_or_else(|| anyhow::anyhow!("async state: {what} entry not a number"))
        })
        .collect()
}

/// Will this dispatch's upload be discarded? Pure in (config, client,
/// iter, finish): the client departs or churns offline before its upload
/// lands, or the per-iteration dropout draw hits. Called at the *validate*
/// stage (the arrival event) — and, purely as a compute-saving filter,
/// before executing or speculating a dispatch whose upload is already
/// known to be discarded. Because the verdict is a pure function of the
/// dispatch facts, the filter can never disagree with the validate-time
/// decision.
pub(crate) fn is_doomed(
    ctx: &FleetCtx,
    cfg: &ServerCfg,
    client: usize,
    iter: usize,
    finish: f64,
) -> bool {
    ctx.fleet.departed(client, finish)
        || cfg.churn.is_some_and(|c| {
            !c.online(cfg.seed, client, finish) || c.dropout_hits(cfg.seed, client, iter as u64)
        })
}

/// Draw the next sampled client: a pure function of (seed, seq) rejecting
/// clients currently in flight. `busy.len() < n` always holds (there are
/// at most `min(sample, n) - 1` other slots).
pub(crate) fn sample_client(
    seed: u64,
    seq: u64,
    n: usize,
    busy: &std::collections::BTreeSet<usize>,
) -> usize {
    let mut s = seed ^ 0x5A3F1E ^ seq.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let mut rng = Rng::new(crate::util::rng::splitmix64(&mut s));
    loop {
        let c = rng.below(n);
        if !busy.contains(&c) {
            return c;
        }
    }
}

/// Dispatch a fresh full-model work order for `client` at simulated time
/// `now`, starting from the current global (`version`). The dispatch
/// starts no earlier than the client's trace arrival window, and its
/// transfers are priced by the client's own links when the trace
/// provides them.
pub(crate) fn dispatch(
    ctx: &FleetCtx,
    m: &Manifest,
    cfg: &ServerCfg,
    client: usize,
    iter: usize,
    version: usize,
    now: f64,
) -> InFlight {
    let plan = full_model_plan(ctx, client);
    let (down, up) = plan_payload_bytes(m, &plan);
    let start = ctx.fleet.start_at(client, now);
    let comm = ctx.client_comm(cfg.comm, client);
    let finish = start + comm.client_total_secs(plan.est_time, down, up);
    InFlight { client, iter, version, finish, plan }
}

/// Run an asynchronous experiment to `cfg.rounds` aggregations (the async
/// analogue of rounds), optionally continuing from a [`ResumeState`]
/// whose checkpoint carried the runner snapshot. Called by
/// [`crate::fl::server::run_experiment_from`] whenever the strategy
/// declares an [`AsyncSpec`] — the sync entry points, the run store, and
/// the campaign runner all route here transparently.
#[allow(clippy::too_many_arguments)]
pub fn run_async(
    engine: &dyn Engine,
    ds: &FedDataset,
    strategy: &mut dyn Strategy,
    spec: AsyncSpec,
    ctx: &FleetCtx,
    cfg: &ServerCfg,
    observer: &mut dyn RoundObserver,
    resume: Option<ResumeState>,
) -> anyhow::Result<ExperimentResult> {
    let m: Manifest = engine.manifest().clone();
    anyhow::ensure!(m.param_count == ctx.manifest.param_count, "engine/ctx manifest mismatch");
    anyhow::ensure!(cfg.eval_every > 0, "eval_every must be >= 1");
    anyhow::ensure!(ctx.n_clients() > 0, "async runner needs at least one client");
    anyhow::ensure!(
        ds.n_clients() == ctx.n_clients(),
        "dataset holds {} clients, fleet has {}",
        ds.n_clients(),
        ctx.n_clients()
    );
    let n = ctx.n_clients();
    let sampled = cfg.sample != 0;
    let slots = if sampled { cfg.sample.min(n) } else { n };
    anyhow::ensure!(
        ctx.fleet.lazy.is_none() || sampled,
        "a lazy fleet needs fleet.sample > 0 — a full fan-out would materialize \
         all {n} clients' state"
    );
    let prox_mu = strategy.prox_mu();

    // -- restore or initialize ------------------------------------------------
    let (mut global, mut records, mut sim_time, mut completed, restored) = match resume {
        Some(r) => {
            validate_resume(&r, m.param_count, cfg.rounds, "aggregation")?;
            if !matches!(r.policy_state, Json::Null) {
                strategy.restore_policy_state(&r.policy_state)?;
            }
            let restored = match &r.async_state {
                Json::Null => {
                    // A warm start (aggregation 0, fresh clocks) is fine;
                    // a real mid-flight checkpoint without runner state
                    // is not reconstructible.
                    anyhow::ensure!(
                        r.completed == 0,
                        "checkpoint at aggregation {} has no async runner state — \
                         it was taken by a synchronous run",
                        r.completed
                    );
                    None
                }
                j => Some(AsyncState::from_json(j, ctx, cfg, &spec.mode)?),
            };
            (r.global, r.prior_records, r.sim_time, r.completed, restored)
        }
        None => (
            m.load_init().unwrap_or_else(|_| vec![0.0; m.param_count]),
            Vec::with_capacity(cfg.rounds),
            0.0f64,
            0,
            None,
        ),
    };

    // Fresh start: fill every slot at t = 0 from version 0 — the whole
    // fleet in full fan-out mode, `slots` distinct sampled clients when
    // `fleet.sample` caps the in-flight set.
    let mut state = match restored {
        Some(s) => s,
        None => {
            let mut versions = std::collections::BTreeMap::new();
            versions.insert(completed, global.clone());
            let mut st = AsyncState {
                inflight: Vec::with_capacity(slots),
                queue: std::collections::BinaryHeap::new(),
                versions,
                buffer: Vec::new(),
                seq: 0,
                iters: std::collections::BTreeMap::new(),
                dropped: Vec::new(),
                speculated: std::collections::BTreeMap::new(),
            };
            if sampled {
                let mut busy = std::collections::BTreeSet::new();
                for _ in 0..slots {
                    let client = sample_client(cfg.seed, st.seq, n, &busy);
                    st.seq += 1;
                    busy.insert(client);
                    st.iters.insert(client, 1);
                    st.inflight.push(dispatch(ctx, &m, cfg, client, 0, completed, sim_time));
                }
            } else {
                for client in 0..n {
                    st.inflight.push(dispatch(ctx, &m, cfg, client, 0, completed, sim_time));
                }
            }
            st.rebuild_queue();
            st
        }
    };

    let mut evaluator = Evaluator::new(engine, cfg.exec_threads)?;
    let mut coordinator = engine.session();

    // -- the event loop -------------------------------------------------------
    // The whole loop runs inside one thread scope so the speculative
    // backend can borrow the engine/dataset for its worker threads; the
    // workers shut down when `exec` drops at the end of the closure.
    std::thread::scope(|scope| -> anyhow::Result<()> {
        let mut exec = SpecExec::new(cfg.speculate_depth);
        if cfg.speculate_depth > 0 && cfg.exec_threads != 1 && engine.parallel_sessions() {
            exec.spawn_workers(scope, engine, ds, ctx, &m, prox_mu, cfg.exec_threads);
        }
        // Churn-starvation guard: a fleet whose every upload is being
        // discarded (all clients departed, dropout ~ 1) would loop forever
        // — bail after enough consecutive drops to cycle the in-flight
        // set several times over.
        let mut starved = 0usize;
        while completed < cfg.rounds {
            // -- execute: materialize in-flight outcomes (eagerly at depth
            //    0, via the background workers + lookahead speculation at
            //    depth > 0) ---------------------------------------------------
            exec.prepare(
                engine,
                ds,
                ctx,
                &m,
                prox_mu,
                cfg,
                &spec.mode,
                &mut state,
                completed,
                coordinator.as_mut(),
                evaluator.pool(),
            )?;

            // -- validate: pop the earliest upload and decide its fate at
            //    arrival time -----------------------------------------------
            let slot = state.pop_event();
            let client = state.inflight[slot].client;
            let iter = state.inflight[slot].iter;
            let now = state.inflight[slot].finish;
            let arrived_version = state.inflight[slot].version;
            let next_iter = iter + 1;
            let doomed = is_doomed(ctx, cfg, client, iter, now);

            // What (if anything) this arrival aggregates: the folded
            // updates' (plans, outcomes, staleness). A doomed arrival
            // aggregates nothing — its upload is discarded
            // deterministically, and any speculation bound to it scores a
            // miss.
            let aggregated = if doomed {
                state.dropped.push(client);
                exec.discard(&mut state, client, iter);
                starved += 1;
                anyhow::ensure!(
                    starved <= 4 * state.inflight.len() + 16,
                    "churn starved the runner: {starved} consecutive uploads discarded \
                     (every in-flight client departed or offline) — loosen fleet.churn.* \
                     or the trace's availability windows"
                );
                None
            } else {
                starved = 0;
                let arrived_plan = state.inflight[slot].plan.clone();
                let outcome = exec.resolve(
                    ds,
                    ctx,
                    &m,
                    prox_mu,
                    &mut state,
                    client,
                    iter,
                    arrived_version,
                    &arrived_plan,
                    coordinator.as_mut(),
                )?;
                match spec.mode {
                    AsyncMode::PerArrival { alpha, staleness_exp } => {
                        let staleness = completed - arrived_version;
                        let w = alpha / (1.0 + staleness as f64).powf(staleness_exp);
                        let arrived = dense(&outcome);
                        for k in 0..global.len() {
                            global[k] =
                                ((1.0 - w) * global[k] as f64 + w * arrived[k] as f64) as f32;
                        }
                        Some((vec![arrived_plan], vec![outcome], vec![staleness]))
                    }
                    AsyncMode::Buffered { k, staleness_exp } => {
                        state.buffer.push(BufEntry {
                            version: arrived_version,
                            plan: arrived_plan,
                            outcome,
                        });
                        if state.buffer.len() >= k.max(1) {
                            // Data-size-weighted average of the buffered
                            // deltas (update − its dispatch-version
                            // global), folded in arrival order. A nonzero
                            // `staleness_exp` further decays each delta's
                            // weight by `1/(1+s)^exp`; the guard keeps
                            // exp=0 bitwise-identical to the plain average
                            // (no spurious `powf` in the weights).
                            let mut acc = vec![0.0f64; global.len()];
                            let mut wsum = 0.0f64;
                            let mut plans = Vec::with_capacity(state.buffer.len());
                            let mut outs = Vec::with_capacity(state.buffer.len());
                            let mut stale = Vec::with_capacity(state.buffer.len());
                            for b in state.buffer.drain(..) {
                                let staleness = completed - b.version;
                                let mut weight = ds.client(b.outcome.client).num_samples as f64;
                                if staleness_exp != 0.0 {
                                    weight /= (1.0 + staleness as f64).powf(staleness_exp);
                                }
                                let start = &state.versions[&b.version];
                                let arrived = dense(&b.outcome);
                                for i in 0..acc.len() {
                                    acc[i] += weight * (arrived[i] as f64 - start[i] as f64);
                                }
                                wsum += weight;
                                stale.push(staleness);
                                plans.push(b.plan);
                                outs.push(b.outcome);
                            }
                            for i in 0..global.len() {
                                global[i] = (global[i] as f64 + acc[i] / wsum) as f32;
                            }
                            Some((plans, outs, stale))
                        } else {
                            None
                        }
                    }
                }
            };

            // -- commit: one aggregation = one record -----------------------
            let did_aggregate = aggregated.is_some();
            if let Some((plans, outs, stale)) = aggregated {
                let round = completed;
                observer.on_round_start(round, &plans);
                let mut stats = RoundStats::default();
                for (plan, out) in plans.iter().zip(&outs) {
                    observer.on_client_done(round, plan, out);
                    stats.absorb(plan, out);
                }
                completed += 1;
                let round_secs = now - sim_time;
                sim_time = now;
                // Speculation counters accumulated since the last commit
                // drain into this record — so they are always zero at the
                // checkpoint seam and never need serializing.
                let (spec_hits, spec_misses) = exec.take_counters();
                let record = commit_round(
                    engine,
                    ds,
                    cfg,
                    &mut evaluator,
                    observer,
                    round,
                    completed,
                    round_secs,
                    sim_time,
                    &global,
                    stats,
                    Some(&stale),
                    std::mem::take(&mut state.dropped),
                    spec_hits,
                    spec_misses,
                )?;
                records.push(record);
            }

            // -- dispatch: re-fill the slot from the (possibly just
            //    updated) global — FedAsync hands back the freshly mixed
            //    model, FedBuff the current (post-flush, if this arrival
            //    flushed) one. Full fan-out re-dispatches the same client;
            //    sampled mode draws a fresh one (the finished client
            //    rejoins the eligible pool). -------------------------------
            state.versions.entry(completed).or_insert_with(|| global.clone());
            let (next_client, it) = if sampled {
                let busy: std::collections::BTreeSet<usize> = state
                    .inflight
                    .iter()
                    .enumerate()
                    .filter(|&(s, _)| s != slot)
                    .map(|(_, f)| f.client)
                    .collect();
                let c = sample_client(cfg.seed, state.seq, n, &busy);
                state.seq += 1;
                let it = state.iters.get(&c).copied().unwrap_or(0);
                state.iters.insert(c, it + 1);
                (c, it)
            } else {
                (client, next_iter)
            };
            state.inflight[slot] = dispatch(ctx, &m, cfg, next_client, it, completed, now);
            state.push_event(slot);
            state.gc_versions();

            // An aggregation closed this event: expose the checkpoint
            // seam. The snapshot closure captures the state AFTER the
            // re-dispatch, so a resumed run re-enters the event loop
            // exactly here — and it only serializes if an observer
            // (checkpoint cadence) asks.
            if did_aggregate {
                let snapshot = || state.to_json(&spec.mode);
                checkpoint_seam(
                    cfg,
                    observer,
                    completed,
                    sim_time,
                    &global,
                    &*strategy,
                    Some(&snapshot),
                    "aggregation",
                )?;
            }
        }
        Ok(())
    })?;

    finish_experiment(engine, ds, &mut evaluator, &*strategy, observer, records, sim_time, global)
}
