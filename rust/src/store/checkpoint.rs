//! CheckpointObserver: the bridge between the server's observer seam and
//! the run store. It accumulates round records as they close and, every k
//! rounds, persists a [`Checkpoint`] — global parameters as a
//! content-addressed blob plus the strategy's policy snapshot — with an
//! atomic manifest rewrite. A killed process therefore leaves exactly its
//! last checkpoint on disk, and [`resume_state`] turns that back into the
//! [`ResumeState`] the round loop continues from.
//!
//! Parameter blobs delta-encode (schema v4): once a full snapshot exists,
//! subsequent checkpoints store only the elements whose f32 bits changed
//! since the previous checkpoint, as a [`SparseDelta`] blob chained
//! against that base ([`Checkpoint::params_chain`]). Chains are capped at
//! [`MAX_DELTA_CHAIN`] links — and a delta that would not beat the dense
//! encoding rebases immediately — so resume cost stays bounded and
//! `runs gc` retires old bases once no chain references them. The diff is
//! bitwise (changed bits copied, never re-derived), so a resumed run is
//! still bit-identical to an uninterrupted one.
//!
//! Persistence failures follow the [`crate::fl::observer::JsonlObserver`]
//! idiom: best-effort during the run (a full disk never aborts training),
//! with the first error retained for callers that need the checkpoints to
//! have landed ([`CheckpointObserver::take_error`]).

use crate::config::ExperimentCfg;
use crate::fl::observer::{RoundObserver, ServerState};
use crate::fl::server::{ExperimentResult, ResumeState, RoundRecord};
use crate::fl::sparse::SparseDelta;
use crate::store::schema::{BlobRef, Checkpoint, FinalState, RunManifest, RunStatus, SCHEMA_VERSION};
use crate::store::RunStore;
use crate::util::json::Json;
use crate::util::unix_now;

/// Longest delta chain a checkpoint may ride before the next checkpoint
/// stores a full vector again (chain = 1 full base + up to 7 deltas).
/// Bounds both resume cost (one blob fetch per link) and how long a
/// superseded base must stay alive for gc.
pub const MAX_DELTA_CHAIN: usize = 8;

pub struct CheckpointObserver<'s> {
    store: &'s RunStore,
    manifest: RunManifest,
    every: usize,
    /// Optional wall-clock cadence (`--checkpoint-secs`): also persist
    /// whenever this much real time has passed since the last persisted
    /// checkpoint. The round cadence still applies; whichever trips first
    /// wins. Wall-clock checkpoints never affect results — they only
    /// bound how much recomputation a kill can cost, which matters for
    /// PJRT workloads whose round cost varies.
    secs: Option<f64>,
    last_persist: std::time::Instant,
    /// Delta-encoding state: the previous persisted checkpoint's blob
    /// chain (its `params_chain` plus its own blob) and the exact global
    /// vector it encodes — the diff base for the next checkpoint. `None`
    /// until the first checkpoint lands (or after a persistence error), so
    /// the next one stores a full vector; resumed observers also start
    /// `None` rather than re-fetch the old chain, which merely costs one
    /// full snapshot after each resume.
    last: Option<(Vec<BlobRef>, Vec<f32>)>,
    error: Option<anyhow::Error>,
}

impl<'s> CheckpointObserver<'s> {
    /// Register a brand-new run (fresh id from strategy + seed, allocated
    /// under the store lock) and persist its initial, empty manifest so
    /// the run is visible in `runs list` from round 0.
    pub fn create(
        store: &'s RunStore,
        cfg: &ExperimentCfg,
        strategy: &str,
        every: usize,
    ) -> anyhow::Result<Self> {
        let id = store.fresh_run_id(strategy, cfg.seed)?;
        CheckpointObserver::create_as(store, cfg, strategy, every, id)
    }

    /// Like [`CheckpointObserver::create`] but with a caller-supplied run
    /// id — the campaign runner allocates ids up front so the cell→run
    /// assignment is recorded before the first round executes.
    pub fn create_as(
        store: &'s RunStore,
        cfg: &ExperimentCfg,
        strategy: &str,
        every: usize,
        id: String,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(every >= 1, "checkpoint interval must be >= 1");
        let mut config = cfg.clone();
        config.strategy = strategy.to_string();
        let now = unix_now();
        let manifest = RunManifest {
            schema_version: SCHEMA_VERSION,
            id,
            created_unix: now,
            updated_unix: now,
            status: RunStatus::Running,
            strategy: strategy.to_string(),
            config,
            records: Vec::new(),
            checkpoint: None,
            final_state: None,
        };
        store.save_manifest(&manifest)?;
        Ok(CheckpointObserver {
            store,
            manifest,
            every,
            secs: None,
            last_persist: std::time::Instant::now(),
            last: None,
            error: None,
        })
    }

    /// Continue checkpointing an existing run (the resume path); the
    /// manifest should already be truncated to its checkpoint.
    pub fn resume(store: &'s RunStore, manifest: RunManifest, every: usize) -> Self {
        CheckpointObserver {
            store,
            manifest,
            every: every.max(1),
            secs: None,
            last_persist: std::time::Instant::now(),
            last: None,
            error: None,
        }
    }

    /// Add a wall-clock cadence on top of the round cadence
    /// (`--checkpoint-secs`): checkpoint after any round when `secs` of
    /// real time have elapsed since the last persisted checkpoint. Useful
    /// when round cost varies (real PJRT workloads) and a pure round
    /// count would leave long uncovered stretches.
    pub fn every_secs(mut self, secs: Option<f64>) -> Self {
        self.secs = secs;
        self
    }

    pub fn run_id(&self) -> &str {
        &self.manifest.id
    }

    /// The first persistence error, if any. Callers that rely on the
    /// checkpoints (tests, `runs resume`) must check this after the run.
    pub fn take_error(&mut self) -> Option<anyhow::Error> {
        self.error.take()
    }

    fn record(&mut self, r: anyhow::Result<()>) {
        if let Err(e) = r {
            self.error.get_or_insert(e);
        }
    }
}

impl RoundObserver for CheckpointObserver<'_> {
    fn on_round_end(&mut self, record: &RoundRecord) {
        self.manifest.records.push(record.clone());
    }

    fn on_server_state(&mut self, st: &ServerState<'_>) {
        let round_due = st.completed % self.every == 0;
        let clock_due = self
            .secs
            .map(|s| self.last_persist.elapsed().as_secs_f64() >= s)
            .unwrap_or(false);
        if !round_due && !clock_due {
            return;
        }
        self.last_persist = std::time::Instant::now();
        let r = (|| {
            // Delta-encode against the previous checkpoint while the chain
            // is short and a delta actually beats a dense blob (few rounds
            // between checkpoints touch few elements; a full-coverage
            // round changes everything and rebases). `take()` means a
            // failure below falls back to a full snapshot next time.
            let (params, chain) = match self.last.take() {
                Some((prev_chain, prev_params))
                    if prev_chain.len() < MAX_DELTA_CHAIN
                        && prev_params.len() == st.global.len() =>
                {
                    let delta = SparseDelta::diff(&prev_params, st.global);
                    if delta.encoded_bytes() < 4 * st.global.len() {
                        (self.store.put_params_delta(&delta)?, prev_chain)
                    } else {
                        (self.store.put_params(st.global)?, Vec::new())
                    }
                }
                _ => (self.store.put_params(st.global)?, Vec::new()),
            };
            let mut next_chain = chain.clone();
            next_chain.push(params.clone());
            self.last = Some((next_chain, st.global.to_vec()));
            // Async snapshots carry whole parameter vectors (referenced
            // global versions, buffered updates); externalizing them into
            // content-addressed blobs keeps the manifest small and dedups
            // identical versions across checkpoints.
            let async_state = match st.async_state {
                Some(snapshot) => externalize_async_state(self.store, snapshot())?,
                None => Json::Null,
            };
            self.manifest.checkpoint = Some(Checkpoint {
                completed: st.completed,
                sim_time: st.sim_time,
                params,
                params_chain: chain,
                policy_state: st.strategy.policy_state(),
                async_state,
            });
            self.manifest.updated_unix = unix_now();
            self.store.save_manifest(&self.manifest)
        })();
        self.record(r);
    }

    fn on_experiment_end(&mut self, res: &ExperimentResult) {
        let r = self.store.put_params(&res.final_params).and_then(|params| {
            self.manifest.status = RunStatus::Complete;
            self.manifest.final_state = Some(FinalState {
                final_acc: res.final_acc,
                final_loss: res.final_loss,
                sim_total_secs: res.sim_total_secs,
                params,
            });
            self.manifest.updated_unix = unix_now();
            self.store.save_manifest(&self.manifest)
        });
        self.record(r);
    }
}

/// Rebuild the [`ResumeState`] of a stored run from its latest checkpoint:
/// global parameters from the blob store, policy (+ RNG) state from the
/// snapshot, and the completed rounds' records.
pub fn resume_state(store: &RunStore, manifest: &RunManifest) -> anyhow::Result<ResumeState> {
    anyhow::ensure!(
        manifest.status == RunStatus::Running,
        "run {} already completed — warm-start a new run from it instead",
        manifest.id
    );
    let ck = manifest
        .checkpoint
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("run {} has no checkpoint to resume from", manifest.id))?;
    anyhow::ensure!(
        manifest.records.len() >= ck.completed,
        "run {}: manifest holds {} records but its checkpoint is at round {}",
        manifest.id,
        manifest.records.len(),
        ck.completed
    );
    Ok(ResumeState {
        completed: ck.completed,
        sim_time: ck.sim_time,
        global: store.resolve_params(&ck.params, &ck.params_chain)?,
        policy_state: ck.policy_state.clone(),
        prior_records: manifest.records[..ck.completed].to_vec(),
        async_state: inline_async_state(store, &ck.async_state)?,
    })
}

/// Replace the parameter arrays inside an async-runner snapshot — the
/// `params` of every `versions`/`buffer` entry — with content-addressed
/// [`BlobRef`]s (schema v3). The vectors dominate the snapshot's size and
/// identical versions recur across checkpoints, so externalizing them
/// shrinks async manifests by an order of magnitude and dedups for free.
/// Non-parameter payloads (`sq_grads`, client clocks) stay inline.
///
/// Bitwise exactness: the inline form is `Num(p as f64)` per element and
/// the runner reads it back `as f32` — exact both ways — while blobs store
/// the f32 bits directly, so externalize → [`inline_async_state`] is an
/// identity on the snapshot.
pub fn externalize_async_state(store: &RunStore, state: Json) -> anyhow::Result<Json> {
    let mut entries = match state {
        Json::Obj(entries) => entries,
        other => return Ok(other),
    };
    for (key, value) in entries.iter_mut() {
        if key != "versions" && key != "buffer" {
            continue;
        }
        let Json::Arr(items) = value else { continue };
        for item in items {
            let Json::Obj(fields) = item else { continue };
            for (fk, fv) in fields.iter_mut() {
                if fk != "params" {
                    continue;
                }
                let Json::Arr(nums) = &*fv else { continue };
                let mut params = Vec::with_capacity(nums.len());
                for n in nums {
                    let x = n.as_f64().ok_or_else(|| {
                        anyhow::anyhow!("async snapshot params entry not a number")
                    })?;
                    params.push(x as f32);
                }
                *fv = store.put_params(&params)?.to_json();
            }
        }
    }
    Ok(Json::Obj(entries))
}

/// The inverse of [`externalize_async_state`]: fetch every externalized
/// `params` [`BlobRef`] back into the inline `Num` array the async runner
/// deserializes. Snapshots from v2-era manifests (params already inline)
/// pass through unchanged, which is the whole v2-compatibility story.
pub fn inline_async_state(store: &RunStore, state: &Json) -> anyhow::Result<Json> {
    let mut state = state.clone();
    if let Json::Obj(entries) = &mut state {
        for (key, value) in entries.iter_mut() {
            if key != "versions" && key != "buffer" {
                continue;
            }
            let Json::Arr(items) = value else { continue };
            for item in items {
                let Json::Obj(fields) = item else { continue };
                for (fk, fv) in fields.iter_mut() {
                    if fk != "params" || !matches!(fv, Json::Obj(_)) {
                        continue;
                    }
                    let blob = BlobRef::from_json(fv)?;
                    let params = store.get_params(&blob)?;
                    *fv = Json::Arr(params.iter().map(|&p| Json::Num(p as f64)).collect());
                }
            }
        }
    }
    Ok(state)
}
