//! Persistent run store: checkpointed, resumable, comparable experiments.
//!
//! FedEL's headline metric is time-to-accuracy over long multi-round
//! campaigns; real deployments treat interruption as the norm. This
//! subsystem makes run state durable and first-class:
//!
//! ```text
//! <root>/
//!   runs/<id>/manifest.json   versioned RunManifest (schema.rs): config
//!                             snapshot, round records, latest checkpoint,
//!                             final summary
//!   blobs/<sha256-hex>        content-addressed blobs: global parameter
//!                             vectors (f32 little-endian) and sparse
//!                             checkpoint deltas against the previous
//!                             round's base — identical snapshots dedup
//!                             across rounds and runs
//! ```
//!
//! * [`checkpoint::CheckpointObserver`] hangs off the server's observer
//!   seam and persists every k rounds (atomically: tmp + rename).
//! * [`checkpoint::resume_state`] turns a stored checkpoint back into a
//!   [`crate::fl::server::ResumeState`]; resumed runs are
//!   bitwise-identical to uninterrupted ones (`tests/resume.rs`).
//! * [`RunStore::latest_params`] is the warm-start seam: any stored run
//!   can seed a new experiment's global model.
//!
//! Where the bytes live is a [`backend::StoreBackend`] concern:
//! [`RunStore::open`] takes either a directory path (the default
//! [`backend::LocalBackend`]) or an `http://host:port` URL (a
//! [`backend::remote::RemoteBackend`] talking to `fedel runs serve`), so
//! campaign workers on several machines can share one store. This module
//! owns everything backend-agnostic: schema parsing, digest bookkeeping,
//! and the campaign claim protocol.
//!
//! Concurrency: one store may be written by several threads *and*
//! processes at once (the campaign runner, parallel sweeps, a human
//! running `fedel train` against the same `--store`). Run-id allocation
//! serializes through the local backend's advisory lockfile (on the
//! serving host, for remote writers); manifests and blobs are written to
//! uniquely-named temporaries and renamed into place, blobs are immutable
//! once published, and campaign-manifest mutations ride an optimistic
//! compare-and-swap over the manifest's content digest
//! ([`backend::CasExpect`]) — first writer wins, losers reload and retry.
//!
//! CLI: `fedel runs list | show <id> | resume <id> | compare <a> ... | gc
//! | serve`.

pub mod backend;
pub mod checkpoint;
pub mod schema;

use std::path::PathBuf;
use std::time::Duration;

use crate::util::json::Json;
use crate::util::sha256;
use self::backend::{CasExpect, CasOutcome, LocalBackend, StoreBackend};
use self::schema::{BlobRef, CampaignManifest, RunManifest};

pub use self::backend::StoreLock;

/// Media type of a little-endian f32 parameter-vector blob (the same
/// encoding as the artifacts' `init.bin`).
pub const MEDIA_PARAMS_F32LE: &str = "application/x-fedel-params.f32le";

/// Media type of a sparse parameter *delta* blob
/// ([`crate::fl::sparse::SparseDelta::encode`]): run-encoded changed
/// elements against some base vector. Checkpoints chain these against the
/// previous checkpoint's params ([`schema::Checkpoint::params_chain`]);
/// the media type keeps a delta from ever being decoded as a raw f32
/// vector.
pub const MEDIA_PARAMS_DELTA: &str = "application/x-fedel-params.delta";

/// How many times an optimistic campaign CAS loop reloads before giving
/// up. Claims conflict only while several workers race the same manifest;
/// each retry re-reads the authoritative state, so the loop settles in a
/// couple of iterations under any realistic contention.
const CAS_RETRIES: usize = 64;

/// What [`RunStore::lease_campaign_cell`] found when it tried to take a
/// cell's worker lease.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum LeaseOutcome {
    /// The lease is ours — a fresh claim, a heartbeat renewal, or an
    /// expired-lease reclaim (`reclaimed_from` names the dead holder).
    Acquired {
        cell: schema::CellState,
        reclaimed_from: Option<String>,
    },
    /// Another worker's lease is still live; `age_secs` since its last
    /// heartbeat.
    Held { worker: String, age_secs: u64 },
    /// The halving policy retired this cell; it can never be leased.
    Pruned,
}

/// What `RunStore::gc_blobs` did (or would do, under `dry_run`).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct GcReport {
    /// Blobs still referenced by at least one manifest.
    pub live: usize,
    /// Orphaned blobs swept (or that would be, under `dry_run`).
    pub swept: usize,
    /// Bytes those orphans occupy.
    pub swept_bytes: u64,
}

/// A store over one backend; see the module docs for the object model.
pub struct RunStore {
    backend: Box<dyn StoreBackend>,
}

impl RunStore {
    /// Open a store. A plain path opens (and creates, if absent) the
    /// directory layout; an `http://host:port` value opens a remote
    /// client against a `fedel runs serve` instance — every `--store`
    /// argument accepts either form.
    pub fn open(location: impl Into<PathBuf>) -> anyhow::Result<RunStore> {
        let location = location.into();
        let text = location.to_string_lossy();
        if text.starts_with("http://") {
            let remote = backend::remote::RemoteBackend::new(&text)?;
            return Ok(RunStore { backend: Box::new(remote) });
        }
        anyhow::ensure!(
            !text.starts_with("https://"),
            "https:// stores are not supported (the hand-rolled client speaks plain http)"
        );
        Ok(RunStore { backend: Box::new(LocalBackend::open(location)?) })
    }

    /// Human-readable location for messages: the root directory of a
    /// local store, the base URL of a remote one.
    pub fn location(&self) -> String {
        self.backend.location()
    }

    /// The local directory backend, for operations that only make sense
    /// on the storing host (gc). Errors with `what` for remote stores.
    fn local(&self, what: &str) -> anyhow::Result<&LocalBackend> {
        self.backend.as_local().ok_or_else(|| {
            anyhow::anyhow!(
                "{what} must run on the host serving {} (against its local directory)",
                self.location()
            )
        })
    }

    // -- runs ---------------------------------------------------------------

    /// Allocate a fresh, human-readable run id: `<strategy>-s<seed>`,
    /// suffixed `-2`, `-3`, ... when taken. Allocation *reserves* the id
    /// under the (serving host's) store lock, so concurrent writers —
    /// threads, processes, or machines — can never both observe the same
    /// id free and clobber each other's run directory.
    pub fn fresh_run_id(&self, strategy: &str, seed: u64) -> anyhow::Result<String> {
        self.backend.fresh_run_id(strategy, seed)
    }

    /// Persist a manifest atomically: a crash mid-write leaves the
    /// previous manifest intact, never a torn one.
    pub fn save_manifest(&self, m: &RunManifest) -> anyhow::Result<()> {
        self.backend
            .save_manifest(&m.id, m.to_json().to_string_pretty().as_bytes())
    }

    pub fn load_manifest(&self, id: &str) -> anyhow::Result<RunManifest> {
        let bytes = self.backend.load_manifest(id)?;
        let text = String::from_utf8_lossy(&bytes);
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("run {id:?}: {e}"))?;
        RunManifest::from_json(&j).map_err(|e| anyhow::anyhow!("run {id:?}: {e}"))
    }

    /// All stored runs, oldest first (creation time, then id). Unreadable
    /// manifests (torn external copies, future schema versions) are
    /// skipped with a warning — one bad entry must not take the whole
    /// store's listing down.
    pub fn list(&self) -> anyhow::Result<Vec<RunManifest>> {
        let mut out = Vec::new();
        for id in self.backend.list_runs()? {
            match self.load_manifest(&id) {
                Ok(m) => out.push(m),
                Err(e) => eprintln!("warning: skipping unreadable run: {e}"),
            }
        }
        out.sort_by(|a, b| {
            a.created_unix.cmp(&b.created_unix).then_with(|| a.id.cmp(&b.id))
        });
        Ok(out)
    }

    // -- blobs --------------------------------------------------------------

    /// Store bytes under their content address; already-present digests
    /// are not rewritten, so identical snapshots dedup for free.
    pub fn put_blob(&self, bytes: &[u8], media_type: &str) -> anyhow::Result<BlobRef> {
        let hex = sha256::hex(bytes);
        self.backend.put_blob(&hex, bytes)?;
        Ok(BlobRef {
            digest: format!("sha256:{hex}"),
            size: bytes.len() as u64,
            media_type: media_type.to_string(),
        })
    }

    /// Fetch a blob, verifying size and digest (a store is only useful if
    /// corruption is loud). The remote backend additionally verifies on
    /// the wire before anything enters its cache.
    pub fn get_blob(&self, r: &BlobRef) -> anyhow::Result<Vec<u8>> {
        let hex = r
            .digest
            .strip_prefix("sha256:")
            .ok_or_else(|| anyhow::anyhow!("unsupported digest {:?}", r.digest))?;
        let bytes = self.backend.get_blob(hex)?;
        anyhow::ensure!(
            bytes.len() as u64 == r.size,
            "blob {hex}: {} bytes stored, descriptor says {}",
            bytes.len(),
            r.size
        );
        anyhow::ensure!(sha256::hex(&bytes) == hex, "blob {hex}: content digest mismatch");
        Ok(bytes)
    }

    /// Store a global parameter vector (little-endian f32 — bitwise exact).
    pub fn put_params(&self, params: &[f32]) -> anyhow::Result<BlobRef> {
        let mut bytes = Vec::with_capacity(params.len() * 4);
        for x in params {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        self.put_blob(&bytes, MEDIA_PARAMS_F32LE)
    }

    pub fn get_params(&self, r: &BlobRef) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(
            r.media_type == MEDIA_PARAMS_F32LE,
            "blob {} is {:?}, not a parameter vector",
            r.digest,
            r.media_type
        );
        let bytes = self.get_blob(r)?;
        anyhow::ensure!(bytes.len() % 4 == 0, "params blob not a multiple of 4 bytes");
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Store a sparse parameter delta ([`crate::fl::sparse::SparseDelta`])
    /// under its content address.
    pub fn put_params_delta(
        &self,
        delta: &crate::fl::sparse::SparseDelta,
    ) -> anyhow::Result<BlobRef> {
        self.put_blob(&delta.encode(), MEDIA_PARAMS_DELTA)
    }

    pub fn get_params_delta(
        &self,
        r: &BlobRef,
    ) -> anyhow::Result<crate::fl::sparse::SparseDelta> {
        anyhow::ensure!(
            r.media_type == MEDIA_PARAMS_DELTA,
            "blob {} is {:?}, not a parameter delta",
            r.digest,
            r.media_type
        );
        let bytes = self.get_blob(r)?;
        crate::fl::sparse::SparseDelta::decode(&bytes)
            .map_err(|e| anyhow::anyhow!("delta blob {}: {e}", r.digest))
    }

    /// Reconstruct a checkpoint's full parameter vector from its blob plus
    /// its delta chain ([`schema::Checkpoint::params_chain`]). An empty
    /// chain means `params` is already a full vector. Otherwise the chain's
    /// first entry is the full base and every later entry a delta against
    /// its predecessor, oldest first; `params` itself (the newest delta) is
    /// overlaid last. Reconstruction is bitwise: deltas copy the exact f32
    /// bits that were diffed out, never re-derived arithmetic.
    pub fn resolve_params(
        &self,
        params: &BlobRef,
        chain: &[BlobRef],
    ) -> anyhow::Result<Vec<f32>> {
        let Some((base, deltas)) = chain.split_first() else {
            return self.get_params(params);
        };
        let mut current = self.get_params(base)?;
        for r in deltas.iter().chain(std::iter::once(params)) {
            current = self.get_params_delta(r)?.to_dense(&current)?;
        }
        Ok(current)
    }

    /// Warm-start source: a stored run's newest global parameters — the
    /// final model if complete, else the latest checkpoint (resolved
    /// through its delta chain, if any).
    pub fn latest_params(&self, id: &str) -> anyhow::Result<Vec<f32>> {
        let m = self.load_manifest(id)?;
        if let Some(f) = m.final_state.as_ref() {
            return self.get_params(&f.params);
        }
        let ck = m
            .checkpoint
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("run {id} has no stored parameters yet"))?;
        self.resolve_params(&ck.params, &ck.params_chain)
    }

    // -- gc -----------------------------------------------------------------

    /// Mark-and-sweep orphaned blobs: hand-deleting `runs/<id>/` leaves
    /// its content-addressed parameter snapshots stranded under `blobs/`
    /// forever; this walks every *readable* manifest, marks the digests
    /// they reference (checkpoint and final params, every base/delta blob
    /// in a checkpoint's delta chain, plus any blob refs inside async
    /// checkpoint state), and sweeps the rest.
    ///
    /// Local-backend only: gc must see every blob and hold the store
    /// lock, so it runs on the serving host against the directory itself.
    ///
    /// Safety properties:
    /// * Runs with an unreadable manifest abort the sweep — a torn or
    ///   future-schema manifest might reference any blob, so deleting
    ///   around it would be guessing.
    /// * Blobs (and abandoned `.tmp-` scratch files) younger than
    ///   `min_age` are spared: a concurrent writer publishes the blob
    ///   *before* the manifest that references it, so a grace window keeps
    ///   the sweep from racing in between.
    /// * The store lock is held throughout, serializing gc against id
    ///   allocation and other sweeps.
    pub fn gc_blobs(&self, min_age: Duration, dry_run: bool) -> anyhow::Result<GcReport> {
        let local = self.local("gc")?;
        let lock = local.lock()?;
        // gc over a huge store can legitimately outlive the lock's stale
        // window; heartbeat the lockfile so contenders don't reclaim it
        // mid-sweep.
        let mut heartbeat = 0usize;
        let mut live: std::collections::BTreeSet<String> = Default::default();
        let runs_dir = local.root().join("runs");
        for entry in std::fs::read_dir(&runs_dir)
            .map_err(|e| anyhow::anyhow!("read {runs_dir:?}: {e}"))?
        {
            heartbeat += 1;
            if heartbeat % 64 == 0 {
                lock.refresh();
            }
            let entry = entry?;
            if !entry.path().join("manifest.json").exists() {
                continue;
            }
            let id = entry.file_name().to_string_lossy().to_string();
            let m = self
                .load_manifest(&id)
                .map_err(|e| anyhow::anyhow!("gc aborted, run {id:?} unreadable: {e}"))?;
            for blob in m
                .checkpoint
                .iter()
                .flat_map(|c| std::iter::once(&c.params).chain(c.params_chain.iter()))
                .chain(m.final_state.iter().map(|f| &f.params))
            {
                if let Some(hex) = blob.digest.strip_prefix("sha256:") {
                    live.insert(hex.to_string());
                }
            }
            // Async checkpoints carry further content-addressed refs
            // (in-flight version params, buffered updates): mark anything
            // shaped like a digest reference inside the runner snapshot.
            if let Some(ck) = &m.checkpoint {
                mark_json_digests(&ck.async_state, &mut live);
            }
        }
        let mut report = GcReport::default();
        let blobs_dir = local.root().join("blobs");
        for entry in std::fs::read_dir(&blobs_dir)
            .map_err(|e| anyhow::anyhow!("read {blobs_dir:?}: {e}"))?
        {
            heartbeat += 1;
            if heartbeat % 64 == 0 {
                lock.refresh();
            }
            let entry = entry?;
            let name = entry.file_name().to_string_lossy().to_string();
            if live.contains(&name) {
                report.live += 1;
                continue;
            }
            let meta = entry.metadata()?;
            // Zero grace means sweep unconditionally; otherwise an
            // unreadable or future mtime counts as young (skip — never
            // guess toward deletion).
            let young = !min_age.is_zero()
                && meta
                    .modified()
                    .ok()
                    .and_then(|t| t.elapsed().ok())
                    .map(|age| age < min_age)
                    .unwrap_or(true);
            if young {
                // Could be a blob a concurrent writer just published (or
                // is about to reference); count neither way, sweep later.
                continue;
            }
            report.swept += 1;
            report.swept_bytes += meta.len();
            if !dry_run {
                let path = entry.path();
                std::fs::remove_file(&path)
                    .map_err(|e| anyhow::anyhow!("sweep {path:?}: {e}"))?;
            }
        }
        Ok(report)
    }

    // -- campaigns ----------------------------------------------------------

    /// Persist a campaign manifest unconditionally (creation and full
    /// rewrites; racing writers go through [`RunStore::update_campaign`]
    /// or [`RunStore::claim_campaign_cell`] instead).
    pub fn save_campaign(&self, m: &CampaignManifest) -> anyhow::Result<()> {
        anyhow::ensure!(
            !m.name.is_empty()
                && m.name
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.')),
            "campaign name {:?} must be [A-Za-z0-9._-]+",
            m.name
        );
        self.backend.save_campaign(
            &m.name,
            m.to_json().to_string_pretty().as_bytes(),
            CasExpect::Any,
        )?;
        Ok(())
    }

    /// Load-transform-store a campaign manifest as one atomic update: the
    /// authoritative manifest is re-read, transformed by `f`, and written
    /// back under a compare-and-swap on the loaded digest — when another
    /// writer lands in between, the update reloads and `f` runs again on
    /// the fresh state (which is why `f` is `FnMut`). The update can
    /// therefore never erase a concurrent writer's changes (the
    /// schema-migration path uses this; a plain load → mutate →
    /// [`RunStore::save_campaign`] would race [`RunStore::claim_campaign_cell`]
    /// and lose cell claims).
    pub fn update_campaign<F>(&self, name: &str, mut f: F) -> anyhow::Result<CampaignManifest>
    where
        F: FnMut(CampaignManifest) -> anyhow::Result<CampaignManifest>,
    {
        for _ in 0..CAS_RETRIES {
            let (current, digest) = self.load_campaign_versioned(name)?;
            let m = f(current)?;
            anyhow::ensure!(
                m.name == name,
                "update_campaign must not rename {name:?} to {:?}",
                m.name
            );
            match self.backend.save_campaign(
                name,
                m.to_json().to_string_pretty().as_bytes(),
                CasExpect::Digest(&digest),
            )? {
                CasOutcome::Committed(_) => return Ok(m),
                CasOutcome::Conflict => continue,
            }
        }
        anyhow::bail!("campaign {name:?} update lost {CAS_RETRIES} straight CAS races")
    }

    /// Atomically claim a campaign cell for `run_id` — a compare-and-swap
    /// over the manifest digest, so concurrent campaign workers (threads,
    /// processes, or machines behind a remote store) can never overwrite
    /// each other's cell→run assignments. The manifest is re-read here
    /// (not trusted from the caller's memory); the claim lands only if the
    /// cell's stored assignment equals `expect` (or is unassigned).
    /// Returns the cell's authoritative assignment after the call —
    /// `run_id` if the claim won, the standing winner if not.
    ///
    /// Cells are addressed by `label`, not index: live edits
    /// (`campaign edit --sweep key=+v`) re-expand the grid and reorder
    /// indices under concurrent workers, but labels are stable. The
    /// index is resolved inside each CAS pass, against the manifest the
    /// swap is conditioned on.
    pub fn claim_campaign_cell(
        &self,
        name: &str,
        label: &str,
        expect: Option<&str>,
        run_id: &str,
    ) -> anyhow::Result<String> {
        for _ in 0..CAS_RETRIES {
            let (mut m, digest) = self.load_campaign_versioned(name)?;
            let index = Self::cell_index(&m, name, label)?;
            match &m.cells[index].run_id {
                Some(current) if Some(current.as_str()) != expect => {
                    return Ok(current.clone())
                }
                _ => {}
            }
            m.cells[index].run_id = Some(run_id.to_string());
            m.updated_unix = crate::util::unix_now();
            match self.backend.save_campaign(
                name,
                m.to_json().to_string_pretty().as_bytes(),
                CasExpect::Digest(&digest),
            )? {
                CasOutcome::Committed(_) => return Ok(run_id.to_string()),
                // Another writer landed first — reload; if it claimed
                // this very cell, the next pass returns its id.
                CasOutcome::Conflict => continue,
            }
        }
        anyhow::bail!("cell {label:?} of campaign {name:?} lost {CAS_RETRIES} straight CAS races")
    }

    /// Acquire, renew, or reclaim the worker lease on one campaign cell —
    /// the same manifest-digest compare-and-swap as
    /// [`RunStore::claim_campaign_cell`], so workers on other threads,
    /// processes, or machines can never hold the same cell at once. The
    /// lease lands when the cell is unleased, already held by `worker`
    /// (heartbeat renewal), or held by a holder whose last heartbeat is
    /// older than `lease_secs` (crash reclaim). Pruned cells are never
    /// leased.
    pub fn lease_campaign_cell(
        &self,
        name: &str,
        label: &str,
        worker: &str,
        lease_secs: u64,
    ) -> anyhow::Result<LeaseOutcome> {
        for _ in 0..CAS_RETRIES {
            let (mut m, digest) = self.load_campaign_versioned(name)?;
            let index = Self::cell_index(&m, name, label)?;
            let now = crate::util::unix_now();
            let cell = &m.cells[index];
            if cell.pruned {
                return Ok(LeaseOutcome::Pruned);
            }
            let reclaimed_from = match &cell.worker {
                Some(holder) if holder != worker => {
                    let age = now.saturating_sub(cell.lease_unix);
                    if age < lease_secs {
                        return Ok(LeaseOutcome::Held { worker: holder.clone(), age_secs: age });
                    }
                    Some(holder.clone())
                }
                _ => None,
            };
            m.cells[index].worker = Some(worker.to_string());
            m.cells[index].lease_unix = now;
            m.updated_unix = now;
            match self.backend.save_campaign(
                name,
                m.to_json().to_string_pretty().as_bytes(),
                CasExpect::Digest(&digest),
            )? {
                CasOutcome::Committed(_) => {
                    return Ok(LeaseOutcome::Acquired {
                        cell: m.cells[index].clone(),
                        reclaimed_from,
                    })
                }
                CasOutcome::Conflict => continue,
            }
        }
        anyhow::bail!("cell {label:?} of campaign {name:?} lost {CAS_RETRIES} straight CAS races")
    }

    /// Drop `worker`'s lease on a cell (a no-op when the lease has already
    /// moved on — e.g. it expired and was reclaimed while we were
    /// finishing, in which case the reclaimer's lease must stand).
    pub fn release_campaign_lease(
        &self,
        name: &str,
        label: &str,
        worker: &str,
    ) -> anyhow::Result<()> {
        self.update_campaign(name, |mut m| {
            let index = Self::cell_index(&m, name, label)?;
            if m.cells[index].worker.as_deref() == Some(worker) {
                m.cells[index].worker = None;
                m.cells[index].lease_unix = 0;
            }
            Ok(m)
        })?;
        Ok(())
    }

    /// Resolve a cell label against a freshly loaded manifest. Labels are
    /// the stable cell address (indices shift under live grid edits).
    fn cell_index(m: &CampaignManifest, name: &str, label: &str) -> anyhow::Result<usize> {
        m.cells
            .iter()
            .position(|c| c.label == label)
            .ok_or_else(|| anyhow::anyhow!("campaign {name:?} has no cell {label:?}"))
    }

    /// The parsed manifest plus its content digest (the CAS token).
    fn load_campaign_versioned(
        &self,
        name: &str,
    ) -> anyhow::Result<(CampaignManifest, String)> {
        let (bytes, digest) = self
            .backend
            .load_campaign(name)?
            .ok_or_else(|| anyhow::anyhow!("no stored campaign {name:?} under {}", self.location()))?;
        let text = String::from_utf8_lossy(&bytes);
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("campaign {name:?}: {e}"))?;
        let m = CampaignManifest::from_json(&j)
            .map_err(|e| anyhow::anyhow!("campaign {name:?}: {e}"))?;
        Ok((m, digest))
    }

    pub fn load_campaign(&self, name: &str) -> anyhow::Result<CampaignManifest> {
        Ok(self.load_campaign_versioned(name)?.0)
    }

    pub fn campaign_exists(&self, name: &str) -> bool {
        self.backend.load_campaign(name).map(|c| c.is_some()).unwrap_or(false)
    }

    /// Names of all stored campaigns, sorted.
    pub fn list_campaigns(&self) -> anyhow::Result<Vec<String>> {
        let mut out = self.backend.list_campaigns()?;
        out.sort();
        Ok(out)
    }
}

/// Collect every `sha256:` digest referenced by [`BlobRef`]-shaped objects
/// (`{"digest": "sha256:...", ...}`) anywhere in a JSON tree — the gc mark
/// phase for checkpoint extensions that externalize payloads, like the
/// async runner's version/buffer params.
fn mark_json_digests(j: &Json, live: &mut std::collections::BTreeSet<String>) {
    match j {
        Json::Obj(entries) => {
            for (k, v) in entries {
                if k == "digest" {
                    if let Json::Str(s) = v {
                        if let Some(hex) = s.strip_prefix("sha256:") {
                            live.insert(hex.to_string());
                        }
                    }
                }
                mark_json_digests(v, live);
            }
        }
        Json::Arr(items) => {
            for item in items {
                mark_json_digests(item, live);
            }
        }
        _ => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch(tag: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("fedel-store-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn blob_round_trip_and_dedup() {
        let dir = scratch("blob");
        let store = RunStore::open(&dir).unwrap();
        let a = store.put_blob(b"hello", "text/plain").unwrap();
        let b = store.put_blob(b"hello", "text/plain").unwrap();
        assert_eq!(a, b, "identical content must share one address");
        assert_eq!(store.get_blob(&a).unwrap(), b"hello");
        let blobs: Vec<_> = std::fs::read_dir(dir.join("blobs")).unwrap().collect();
        assert_eq!(blobs.len(), 1, "dedup must not write twice");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn params_round_trip_bitwise() {
        let dir = scratch("params");
        let store = RunStore::open(&dir).unwrap();
        let params = vec![0.1f32, -0.0, f32::MIN_POSITIVE, 1.0e30, -7.25];
        let r = store.put_params(&params).unwrap();
        let back = store.get_params(&r).unwrap();
        assert_eq!(params.len(), back.len());
        for (a, b) in params.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corruption_is_detected() {
        let dir = scratch("corrupt");
        let store = RunStore::open(&dir).unwrap();
        let r = store.put_blob(b"precious", "text/plain").unwrap();
        let hex = r.digest.strip_prefix("sha256:").unwrap();
        std::fs::write(dir.join("blobs").join(hex), b"precioms").unwrap();
        let err = store.get_blob(&r).unwrap_err();
        assert!(err.to_string().contains("digest mismatch"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fresh_run_ids_never_collide() {
        let dir = scratch("ids");
        let store = RunStore::open(&dir).unwrap();
        let a = store.fresh_run_id("fedel", 42).unwrap();
        assert_eq!(a, "fedel-s42");
        // allocation reserves the directory itself — no create needed
        assert!(dir.join("runs").join(&a).exists(), "allocation must reserve the id");
        let b = store.fresh_run_id("fedel", 42).unwrap();
        assert_eq!(b, "fedel-s42-2");
        assert_eq!(store.fresh_run_id("fedel", 42).unwrap(), "fedel-s42-3");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn https_and_pathful_urls_are_rejected() {
        assert!(RunStore::open("https://127.0.0.1:1").is_err());
        assert!(RunStore::open("http://127.0.0.1:1/sub").is_err());
    }

    fn manifest_with_params(
        store: &RunStore,
        id: &str,
        ck: Option<&[f32]>,
        fin: Option<&[f32]>,
    ) -> RunManifest {
        use crate::store::schema::{Checkpoint, FinalState, RunStatus, SCHEMA_VERSION};
        RunManifest {
            schema_version: SCHEMA_VERSION,
            id: id.to_string(),
            created_unix: 0,
            updated_unix: 0,
            status: if fin.is_some() { RunStatus::Complete } else { RunStatus::Running },
            strategy: "fedavg".into(),
            config: Default::default(),
            records: Vec::new(),
            checkpoint: ck.map(|p| Checkpoint {
                completed: 1,
                sim_time: 1.0,
                params: store.put_params(p).unwrap(),
                params_chain: Vec::new(),
                policy_state: crate::util::json::Json::Null,
                async_state: crate::util::json::Json::Null,
            }),
            final_state: fin.map(|p| FinalState {
                final_acc: 0.5,
                final_loss: 0.5,
                sim_total_secs: 2.0,
                params: store.put_params(p).unwrap(),
            }),
        }
    }

    #[test]
    fn gc_sweeps_orphans_and_keeps_referenced() {
        let dir = scratch("gc");
        let store = RunStore::open(&dir).unwrap();
        let keep = manifest_with_params(&store, "keep-s1", Some(&[1.0, 2.0]), Some(&[3.0, 4.0]));
        store.save_manifest(&keep).unwrap();
        let doomed =
            manifest_with_params(&store, "doomed-s1", Some(&[5.0, 6.0]), Some(&[7.0, 8.0]));
        store.save_manifest(&doomed).unwrap();
        // hand-delete the second run: its two blobs are now orphans
        std::fs::remove_dir_all(dir.join("runs").join("doomed-s1")).unwrap();

        // dry run reports but deletes nothing
        let dry = store.gc_blobs(Duration::ZERO, true).unwrap();
        assert_eq!((dry.live, dry.swept), (2, 2), "{dry:?}");
        assert!(dry.swept_bytes > 0);
        assert_eq!(std::fs::read_dir(dir.join("blobs")).unwrap().count(), 4);

        let report = store.gc_blobs(Duration::ZERO, false).unwrap();
        assert_eq!((report.live, report.swept), (2, 2), "{report:?}");
        assert_eq!(std::fs::read_dir(dir.join("blobs")).unwrap().count(), 2);
        // referenced blobs still fetch + verify
        assert_eq!(
            store.get_params(&keep.final_state.as_ref().unwrap().params).unwrap(),
            vec![3.0, 4.0]
        );
        // idempotent
        let again = store.gc_blobs(Duration::ZERO, false).unwrap();
        assert_eq!((again.live, again.swept), (2, 0), "{again:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_marks_blob_refs_inside_async_state() {
        let dir = scratch("gc-async");
        let store = RunStore::open(&dir).unwrap();
        let mut m = manifest_with_params(&store, "buf-s1", Some(&[1.0, 2.0]), None);
        // An async checkpoint referencing an externalized params blob.
        let version_params = store.put_params(&[9.0, 10.0, 11.0]).unwrap();
        m.checkpoint.as_mut().unwrap().async_state = Json::obj(vec![
            ("mode", Json::Str("buffered".into())),
            (
                "versions",
                Json::Arr(vec![Json::obj(vec![
                    ("version", Json::Num(3.0)),
                    ("params", version_params.to_json()),
                ])]),
            ),
        ]);
        store.save_manifest(&m).unwrap();
        let report = store.gc_blobs(Duration::ZERO, false).unwrap();
        assert_eq!(report.swept, 0, "{report:?}");
        assert_eq!(report.live, 2, "checkpoint params + async version params");
        assert_eq!(store.get_params(&version_params).unwrap(), vec![9.0, 10.0, 11.0]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn delta_chain_resolves_bitwise_and_gc_keeps_it_alive() {
        use crate::fl::sparse::SparseDelta;
        let dir = scratch("delta-chain");
        let store = RunStore::open(&dir).unwrap();
        let g0 = vec![1.0f32, -0.0, 3.0, 4.0, 5.0, 6.0];
        let mut g1 = g0.clone();
        g1[1] = 0.0; // -0.0 -> +0.0 is a bitwise change a delta must carry
        g1[4] = 5.5;
        let mut g2 = g1.clone();
        g2[0] = f32::MIN_POSITIVE;

        let base = store.put_params(&g0).unwrap();
        let d1 = store.put_params_delta(&SparseDelta::diff(&g0, &g1)).unwrap();
        let d2 = store.put_params_delta(&SparseDelta::diff(&g1, &g2)).unwrap();
        assert_eq!(d2.media_type, MEDIA_PARAMS_DELTA);
        // a delta blob must never decode as a raw vector, or vice versa
        assert!(store.get_params(&d2).is_err());
        assert!(store.get_params_delta(&base).is_err());

        // empty chain: params is already full
        let full = store.resolve_params(&base, &[]).unwrap();
        assert_eq!(full.len(), g0.len());
        // chained: base, then d1, then the checkpoint's own blob d2
        let back = store.resolve_params(&d2, &[base.clone(), d1.clone()]).unwrap();
        assert_eq!(back.len(), g2.len());
        for (a, b) in g2.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }

        // a manifest whose checkpoint rides that chain keeps every link
        // alive through gc, and latest_params resolves through it
        let mut m = manifest_with_params(&store, "chained-s1", Some(&g0), None);
        let ck = m.checkpoint.as_mut().unwrap();
        ck.params = d2.clone();
        ck.params_chain = vec![base, d1];
        store.save_manifest(&m).unwrap();
        let report = store.gc_blobs(Duration::ZERO, false).unwrap();
        assert_eq!(report.swept, 0, "{report:?}");
        assert_eq!(report.live, 3, "base + 2 deltas (g0 blob is the chain base)");
        let latest = store.latest_params("chained-s1").unwrap();
        for (a, b) in g2.iter().zip(&latest) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_grace_window_spares_young_orphans() {
        let dir = scratch("gc-young");
        let store = RunStore::open(&dir).unwrap();
        store.put_blob(b"unreferenced-but-fresh", "text/plain").unwrap();
        let report = store.gc_blobs(Duration::from_secs(3600), false).unwrap();
        assert_eq!(report.swept, 0, "young orphans must survive the grace window");
        assert_eq!(std::fs::read_dir(dir.join("blobs")).unwrap().count(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_aborts_on_unreadable_manifest() {
        let dir = scratch("gc-unreadable");
        let store = RunStore::open(&dir).unwrap();
        store.put_blob(b"maybe-referenced", "text/plain").unwrap();
        let bad = dir.join("runs").join("torn-s1");
        std::fs::create_dir_all(&bad).unwrap();
        std::fs::write(bad.join("manifest.json"), b"{ torn").unwrap();
        let err = store.gc_blobs(Duration::ZERO, false).unwrap_err();
        assert!(err.to_string().contains("unreadable"), "{err}");
        assert_eq!(
            std::fs::read_dir(dir.join("blobs")).unwrap().count(),
            1,
            "gc must not sweep past an unreadable manifest"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn campaign_cell_claims_are_first_writer_wins() {
        use crate::store::schema::{CampaignManifest, CellState, CAMPAIGN_SCHEMA_VERSION};
        let dir = scratch("claim");
        let store = RunStore::open(&dir).unwrap();
        let m = CampaignManifest {
            schema_version: CAMPAIGN_SCHEMA_VERSION,
            name: "sweep".into(),
            created_unix: 0,
            updated_unix: 0,
            spec: crate::util::json::Json::Null,
            cells: vec![
                CellState::unassigned("a".into()),
                CellState::unassigned("b".into()),
            ],
        };
        store.save_campaign(&m).unwrap();
        // first claim lands and persists
        let won = store.claim_campaign_cell("sweep", "a", None, "fedavg-s1").unwrap();
        assert_eq!(won, "fedavg-s1");
        assert_eq!(
            store.load_campaign("sweep").unwrap().cells[0].run_id.as_deref(),
            Some("fedavg-s1")
        );
        // a competing claim (e.g. from a second campaign process) is told
        // who won instead of overwriting
        assert_eq!(
            store.claim_campaign_cell("sweep", "a", None, "fedavg-s1-2").unwrap(),
            "fedavg-s1"
        );
        // other cells are untouched and claimable
        assert_eq!(store.claim_campaign_cell("sweep", "b", None, "fedel-s1").unwrap(), "fedel-s1");
        // CAS on the old id reassigns (the hand-deleted-run path)...
        assert_eq!(
            store.claim_campaign_cell("sweep", "a", Some("fedavg-s1"), "fedavg-s1-9").unwrap(),
            "fedavg-s1-9"
        );
        // ...but a stale expectation loses to the standing winner
        assert_eq!(
            store.claim_campaign_cell("sweep", "a", Some("fedavg-s1"), "fedavg-s1-7").unwrap(),
            "fedavg-s1-9"
        );
        let back = store.load_campaign("sweep").unwrap();
        assert_eq!(back.cells[0].run_id.as_deref(), Some("fedavg-s1-9"));
        assert_eq!(back.cells[1].run_id.as_deref(), Some("fedel-s1"));
        assert!(
            store.claim_campaign_cell("sweep", "zz", None, "x").is_err(),
            "unknown label must error"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn update_campaign_transforms_the_authoritative_stored_state() {
        use crate::store::schema::{CampaignManifest, CellState, CAMPAIGN_SCHEMA_VERSION};
        let dir = scratch("update-campaign");
        let store = RunStore::open(&dir).unwrap();
        let stale = CampaignManifest {
            schema_version: CAMPAIGN_SCHEMA_VERSION,
            name: "sweep".into(),
            created_unix: 0,
            updated_unix: 0,
            spec: crate::util::json::Json::Null,
            cells: vec![CellState::unassigned("a".into())],
        };
        store.save_campaign(&stale).unwrap();
        // a claim lands after our (stale) load above...
        store.claim_campaign_cell("sweep", "a", None, "fedavg-s1").unwrap();
        // ...and an update must see it: the closure gets the stored
        // manifest, not whatever the caller last loaded, so transforming
        // labels/spec can never erase the concurrent claim.
        let updated = store
            .update_campaign("sweep", |mut m| {
                assert_eq!(m.cells[0].run_id.as_deref(), Some("fedavg-s1"));
                m.cells[0].label = "relabeled".into();
                Ok(m)
            })
            .unwrap();
        assert_eq!(updated.cells[0].run_id.as_deref(), Some("fedavg-s1"));
        let back = store.load_campaign("sweep").unwrap();
        assert_eq!(back.cells[0].label, "relabeled");
        assert_eq!(back.cells[0].run_id.as_deref(), Some("fedavg-s1"));
        // a renaming closure is rejected before anything is written
        assert!(store
            .update_campaign("sweep", |mut m| {
                m.name = "other".into();
                Ok(m)
            })
            .is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cell_leases_acquire_renew_reclaim_and_release() {
        use crate::store::schema::{CampaignManifest, CellState, CAMPAIGN_SCHEMA_VERSION};
        let dir = scratch("lease");
        let store = RunStore::open(&dir).unwrap();
        let m = CampaignManifest {
            schema_version: CAMPAIGN_SCHEMA_VERSION,
            name: "sweep".into(),
            created_unix: 0,
            updated_unix: 0,
            spec: crate::util::json::Json::Null,
            cells: vec![CellState::unassigned("a".into()), CellState::unassigned("b".into())],
        };
        store.save_campaign(&m).unwrap();
        // fresh acquisition
        match store.lease_campaign_cell("sweep", "a", "w1", 3600).unwrap() {
            LeaseOutcome::Acquired { cell, reclaimed_from } => {
                assert_eq!(cell.worker.as_deref(), Some("w1"));
                assert!(cell.lease_unix > 0);
                assert_eq!(reclaimed_from, None);
            }
            other => panic!("expected acquisition, got {other:?}"),
        }
        // a live lease holds off other workers...
        match store.lease_campaign_cell("sweep", "a", "w2", 3600).unwrap() {
            LeaseOutcome::Held { worker, .. } => assert_eq!(worker, "w1"),
            other => panic!("expected held, got {other:?}"),
        }
        // ...but the holder heartbeats freely
        assert!(matches!(
            store.lease_campaign_cell("sweep", "a", "w1", 3600).unwrap(),
            LeaseOutcome::Acquired { reclaimed_from: None, .. }
        ));
        // lease_secs = 0 makes any heartbeat stale: reclaim names the
        // dead holder
        match store.lease_campaign_cell("sweep", "a", "w2", 0).unwrap() {
            LeaseOutcome::Acquired { reclaimed_from, .. } => {
                assert_eq!(reclaimed_from.as_deref(), Some("w1"))
            }
            other => panic!("expected reclaim, got {other:?}"),
        }
        // a stale holder's release is a no-op — the reclaimer keeps it
        store.release_campaign_lease("sweep", "a", "w1").unwrap();
        assert_eq!(
            store.load_campaign("sweep").unwrap().cells[0].worker.as_deref(),
            Some("w2")
        );
        // the live holder's release clears the lease
        store.release_campaign_lease("sweep", "a", "w2").unwrap();
        let back = store.load_campaign("sweep").unwrap();
        assert_eq!(back.cells[0].worker, None);
        assert_eq!(back.cells[0].lease_unix, 0);
        // pruned cells are never leased
        store
            .update_campaign("sweep", |mut m| {
                m.cells[1].pruned = true;
                Ok(m)
            })
            .unwrap();
        assert_eq!(
            store.lease_campaign_cell("sweep", "b", "w1", 3600).unwrap(),
            LeaseOutcome::Pruned
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn wrong_media_type_rejected_for_params() {
        let dir = scratch("media");
        let store = RunStore::open(&dir).unwrap();
        let r = store.put_blob(&[0u8; 8], "text/plain").unwrap();
        assert!(store.get_params(&r).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
