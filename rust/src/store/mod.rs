//! Persistent run store: checkpointed, resumable, comparable experiments.
//!
//! FedEL's headline metric is time-to-accuracy over long multi-round
//! campaigns; real deployments treat interruption as the norm. This
//! subsystem makes run state durable and first-class:
//!
//! ```text
//! <root>/
//!   runs/<id>/manifest.json   versioned RunManifest (schema.rs): config
//!                             snapshot, round records, latest checkpoint,
//!                             final summary
//!   blobs/<sha256-hex>        content-addressed blobs (global parameter
//!                             vectors, f32 little-endian) — identical
//!                             snapshots dedup across rounds and runs
//! ```
//!
//! * [`checkpoint::CheckpointObserver`] hangs off the server's observer
//!   seam and persists every k rounds (atomically: tmp + rename).
//! * [`checkpoint::resume_state`] turns a stored checkpoint back into a
//!   [`crate::fl::server::ResumeState`]; resumed runs are
//!   bitwise-identical to uninterrupted ones (`tests/resume.rs`).
//! * [`RunStore::latest_params`] is the warm-start seam: any stored run
//!   can seed a new experiment's global model.
//!
//! Concurrency: one store may be written by several threads *and*
//! processes at once (the campaign runner, parallel sweeps, a human
//! running `fedel train` against the same `--store`). Mutations that
//! race — run-id allocation, campaign-manifest rewrites, blob GC — are
//! serialized through an advisory lockfile (`<root>/.lock`, created with
//! `O_EXCL`, removed on drop, reclaimed when stale); everything else is
//! made safe by construction: manifests and blobs are written to
//! uniquely-named temporaries and renamed into place, and blobs are
//! immutable once published.
//!
//! CLI: `fedel runs list | show <id> | resume <id> | compare <a> ... | gc`.

pub mod checkpoint;
pub mod schema;

use std::io::Write;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::util::sha256;
use self::schema::{BlobRef, CampaignManifest, RunManifest};

/// Media type of a little-endian f32 parameter-vector blob (the same
/// encoding as the artifacts' `init.bin`).
pub const MEDIA_PARAMS_F32LE: &str = "application/x-fedel-params.f32le";

/// A crashed process can strand `.lock`; holders keep it for microseconds
/// (id allocation, one small file rename) — long operations like gc
/// heartbeat via [`StoreLock::refresh`] — so a lockfile this old is
/// abandoned and gets reclaimed.
const LOCK_STALE: Duration = Duration::from_secs(30);

/// How long a contender waits for the lock before giving up loudly.
const LOCK_WAIT: Duration = Duration::from_secs(20);

/// Held advisory store lock; released (unlinked) on drop. The file holds
/// a per-acquisition token, and release/reclaim are token-checked /
/// rename-based, so a contender can never unlink a lock another holder
/// legitimately owns.
pub struct StoreLock {
    path: PathBuf,
    token: String,
}

impl StoreLock {
    /// Re-stamp the lockfile's mtime. Holders that legitimately exceed
    /// [`LOCK_STALE`] (gc over a huge store) must call this periodically
    /// or a contender will reclaim the lock out from under them.
    pub fn refresh(&self) {
        let _ = std::fs::write(&self.path, &self.token);
    }
}

impl Drop for StoreLock {
    fn drop(&mut self) {
        // Only unlink a lock that is still ours: if a contender reclaimed
        // it as stale and re-acquired, the file now holds their token and
        // removing it would admit a third holder.
        if std::fs::read_to_string(&self.path).map(|t| t == self.token).unwrap_or(false) {
            let _ = std::fs::remove_file(&self.path);
        }
    }
}

/// A unique temporary file name: scratch writes from concurrent
/// threads/processes must never interleave on one path, or a rename could
/// publish a torn file.
fn tmp_name(stem: &str) -> String {
    use std::sync::atomic::{AtomicU64, Ordering};
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    format!(
        "{stem}.tmp-{}-{}",
        std::process::id(),
        COUNTER.fetch_add(1, Ordering::Relaxed)
    )
}

/// Write `bytes` to `path` atomically via a uniquely-named sibling tmp.
fn write_atomic(path: &Path, bytes: &[u8]) -> anyhow::Result<()> {
    let file_name = path
        .file_name()
        .ok_or_else(|| anyhow::anyhow!("no file name in {path:?}"))?
        .to_string_lossy()
        .to_string();
    let tmp = path.with_file_name(tmp_name(&file_name));
    std::fs::write(&tmp, bytes).map_err(|e| anyhow::anyhow!("write {tmp:?}: {e}"))?;
    std::fs::rename(&tmp, path).map_err(|e| {
        let _ = std::fs::remove_file(&tmp);
        anyhow::anyhow!("rename to {path:?}: {e}")
    })?;
    Ok(())
}

/// What `RunStore::gc_blobs` did (or would do, under `dry_run`).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct GcReport {
    /// Blobs still referenced by at least one manifest.
    pub live: usize,
    /// Orphaned blobs swept (or that would be, under `dry_run`).
    pub swept: usize,
    /// Bytes those orphans occupy.
    pub swept_bytes: u64,
}

/// A store rooted at one directory; see the module docs for the layout.
pub struct RunStore {
    root: PathBuf,
}

impl RunStore {
    /// Open a store, creating the directory skeleton if absent.
    pub fn open(root: impl Into<PathBuf>) -> anyhow::Result<RunStore> {
        let root = root.into();
        for sub in ["runs", "blobs", "campaigns"] {
            let dir = root.join(sub);
            std::fs::create_dir_all(&dir)
                .map_err(|e| anyhow::anyhow!("create {dir:?}: {e}"))?;
        }
        Ok(RunStore { root })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn run_dir(&self, id: &str) -> PathBuf {
        self.root.join("runs").join(id)
    }

    fn blob_path(&self, hex: &str) -> PathBuf {
        self.root.join("blobs").join(hex)
    }

    fn campaign_path(&self, name: &str) -> PathBuf {
        self.root.join("campaigns").join(format!("{name}.json"))
    }

    // -- locking ------------------------------------------------------------

    /// Take the store-wide advisory lock. `O_EXCL` creation is atomic on
    /// every platform we care about, across threads and processes alike;
    /// contenders spin with a short sleep, reclaim abandoned locks older
    /// than [`LOCK_STALE`], and give up after [`LOCK_WAIT`].
    ///
    /// Stale reclaim is rename-based: `rename` succeeds for exactly one
    /// contender (the others see the file gone), so several contenders
    /// observing the same abandoned lock can never all "remove and
    /// re-create" their way into concurrent ownership.
    pub fn lock(&self) -> anyhow::Result<StoreLock> {
        let path = self.root.join(".lock");
        // pid + counter, for humans debugging a stuck store and for the
        // token-checked release.
        let token = tmp_name("holder");
        let deadline = Instant::now() + LOCK_WAIT;
        loop {
            match std::fs::OpenOptions::new().write(true).create_new(true).open(&path) {
                Ok(mut f) => {
                    let _ = write!(f, "{token}");
                    return Ok(StoreLock { path, token });
                }
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    let stale = std::fs::metadata(&path)
                        .and_then(|m| m.modified())
                        .ok()
                        .and_then(|t| t.elapsed().ok())
                        .map(|age| age >= LOCK_STALE)
                        .unwrap_or(false);
                    if stale {
                        // Claim the corpse by renaming it to a unique
                        // graveyard name; exactly one contender wins.
                        let grave = path.with_file_name(tmp_name(".lock.stale"));
                        if std::fs::rename(&path, &grave).is_ok() {
                            let _ = std::fs::remove_file(&grave);
                        }
                        continue;
                    }
                    anyhow::ensure!(
                        Instant::now() < deadline,
                        "store lock {path:?} held for over {LOCK_WAIT:?} — \
                         remove it by hand if its owner is gone"
                    );
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(e) => return Err(anyhow::anyhow!("create lock {path:?}: {e}")),
            }
        }
    }

    // -- runs ---------------------------------------------------------------

    /// Allocate a fresh, human-readable run id: `<strategy>-s<seed>`,
    /// suffixed `-2`, `-3`, ... when taken. Allocation *reserves* the id
    /// by creating `runs/<id>/` while holding the store lock, so
    /// concurrent writers — threads or whole processes — can never both
    /// observe the same id free and clobber each other's run directory.
    pub fn fresh_run_id(&self, strategy: &str, seed: u64) -> anyhow::Result<String> {
        let _lock = self.lock()?;
        let base = format!("{strategy}-s{seed}");
        let mut id = base.clone();
        let mut n = 2usize;
        loop {
            let dir = self.run_dir(&id);
            if !dir.exists() {
                std::fs::create_dir_all(&dir)
                    .map_err(|e| anyhow::anyhow!("reserve {dir:?}: {e}"))?;
                return Ok(id);
            }
            id = format!("{base}-{n}");
            n += 1;
        }
    }

    /// Persist a manifest atomically (uniquely-named tmp + rename): a
    /// crash mid-write leaves the previous manifest intact, never a torn
    /// one, and concurrent writers never share a scratch path.
    pub fn save_manifest(&self, m: &RunManifest) -> anyhow::Result<()> {
        let dir = self.run_dir(&m.id);
        std::fs::create_dir_all(&dir).map_err(|e| anyhow::anyhow!("create {dir:?}: {e}"))?;
        write_atomic(&dir.join("manifest.json"), m.to_json().to_string_pretty().as_bytes())
    }

    pub fn load_manifest(&self, id: &str) -> anyhow::Result<RunManifest> {
        let path = self.run_dir(id).join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("no stored run {id:?} ({path:?}: {e})"))?;
        let j = crate::util::json::Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;
        RunManifest::from_json(&j).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))
    }

    /// All stored runs, oldest first (creation time, then id). Unreadable
    /// manifests (torn external copies, future schema versions) are
    /// skipped with a warning — one bad directory must not take the whole
    /// store's listing down.
    pub fn list(&self) -> anyhow::Result<Vec<RunManifest>> {
        let dir = self.root.join("runs");
        let mut out = Vec::new();
        for entry in
            std::fs::read_dir(&dir).map_err(|e| anyhow::anyhow!("read {dir:?}: {e}"))?
        {
            let entry = entry?;
            if !entry.path().join("manifest.json").exists() {
                continue;
            }
            match self.load_manifest(&entry.file_name().to_string_lossy()) {
                Ok(m) => out.push(m),
                Err(e) => eprintln!("warning: skipping unreadable run: {e}"),
            }
        }
        out.sort_by(|a, b| {
            a.created_unix.cmp(&b.created_unix).then_with(|| a.id.cmp(&b.id))
        });
        Ok(out)
    }

    // -- blobs --------------------------------------------------------------

    /// Store bytes under their content address; already-present digests
    /// are not rewritten, so identical snapshots dedup for free.
    /// Concurrent writers of the same content are harmless: each writes
    /// its own uniquely-named tmp, and whichever rename lands last
    /// replaces identical bytes with identical bytes.
    pub fn put_blob(&self, bytes: &[u8], media_type: &str) -> anyhow::Result<BlobRef> {
        let hex = sha256::hex(bytes);
        let path = self.blob_path(&hex);
        if !path.exists() {
            write_atomic(&path, bytes)?;
        }
        Ok(BlobRef {
            digest: format!("sha256:{hex}"),
            size: bytes.len() as u64,
            media_type: media_type.to_string(),
        })
    }

    /// Fetch a blob, verifying size and digest (a store is only useful if
    /// corruption is loud).
    pub fn get_blob(&self, r: &BlobRef) -> anyhow::Result<Vec<u8>> {
        let hex = r
            .digest
            .strip_prefix("sha256:")
            .ok_or_else(|| anyhow::anyhow!("unsupported digest {:?}", r.digest))?;
        let path = self.blob_path(hex);
        let bytes =
            std::fs::read(&path).map_err(|e| anyhow::anyhow!("read blob {path:?}: {e}"))?;
        anyhow::ensure!(
            bytes.len() as u64 == r.size,
            "blob {hex}: {} bytes on disk, descriptor says {}",
            bytes.len(),
            r.size
        );
        anyhow::ensure!(sha256::hex(&bytes) == hex, "blob {hex}: content digest mismatch");
        Ok(bytes)
    }

    /// Store a global parameter vector (little-endian f32 — bitwise exact).
    pub fn put_params(&self, params: &[f32]) -> anyhow::Result<BlobRef> {
        let mut bytes = Vec::with_capacity(params.len() * 4);
        for x in params {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        self.put_blob(&bytes, MEDIA_PARAMS_F32LE)
    }

    pub fn get_params(&self, r: &BlobRef) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(
            r.media_type == MEDIA_PARAMS_F32LE,
            "blob {} is {:?}, not a parameter vector",
            r.digest,
            r.media_type
        );
        let bytes = self.get_blob(r)?;
        anyhow::ensure!(bytes.len() % 4 == 0, "params blob not a multiple of 4 bytes");
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Warm-start source: a stored run's newest global parameters — the
    /// final model if complete, else the latest checkpoint.
    pub fn latest_params(&self, id: &str) -> anyhow::Result<Vec<f32>> {
        let m = self.load_manifest(id)?;
        let blob = m
            .final_state
            .as_ref()
            .map(|f| &f.params)
            .or_else(|| m.checkpoint.as_ref().map(|c| &c.params))
            .ok_or_else(|| anyhow::anyhow!("run {id} has no stored parameters yet"))?;
        self.get_params(blob)
    }

    // -- gc -----------------------------------------------------------------

    /// Mark-and-sweep orphaned blobs: hand-deleting `runs/<id>/` leaves
    /// its content-addressed parameter snapshots stranded under `blobs/`
    /// forever; this walks every *readable* manifest, marks the digests
    /// they reference (checkpoints and final states), and sweeps the rest.
    ///
    /// Safety properties:
    /// * Runs with an unreadable manifest abort the sweep — a torn or
    ///   future-schema manifest might reference any blob, so deleting
    ///   around it would be guessing.
    /// * Blobs (and abandoned `.tmp-` scratch files) younger than
    ///   `min_age` are spared: a concurrent writer publishes the blob
    ///   *before* the manifest that references it, so a grace window keeps
    ///   the sweep from racing in between.
    /// * The store lock is held throughout, serializing gc against id
    ///   allocation and other sweeps.
    pub fn gc_blobs(&self, min_age: Duration, dry_run: bool) -> anyhow::Result<GcReport> {
        let lock = self.lock()?;
        // gc over a huge store can legitimately outlive LOCK_STALE;
        // heartbeat the lockfile so contenders don't reclaim it mid-sweep.
        let mut heartbeat = 0usize;
        let mut live: std::collections::BTreeSet<String> = Default::default();
        let runs_dir = self.root.join("runs");
        for entry in std::fs::read_dir(&runs_dir)
            .map_err(|e| anyhow::anyhow!("read {runs_dir:?}: {e}"))?
        {
            heartbeat += 1;
            if heartbeat % 64 == 0 {
                lock.refresh();
            }
            let entry = entry?;
            if !entry.path().join("manifest.json").exists() {
                continue;
            }
            let id = entry.file_name().to_string_lossy().to_string();
            let m = self
                .load_manifest(&id)
                .map_err(|e| anyhow::anyhow!("gc aborted, run {id:?} unreadable: {e}"))?;
            for blob in m
                .checkpoint
                .iter()
                .map(|c| &c.params)
                .chain(m.final_state.iter().map(|f| &f.params))
            {
                if let Some(hex) = blob.digest.strip_prefix("sha256:") {
                    live.insert(hex.to_string());
                }
            }
        }
        let mut report = GcReport::default();
        let blobs_dir = self.root.join("blobs");
        for entry in std::fs::read_dir(&blobs_dir)
            .map_err(|e| anyhow::anyhow!("read {blobs_dir:?}: {e}"))?
        {
            heartbeat += 1;
            if heartbeat % 64 == 0 {
                lock.refresh();
            }
            let entry = entry?;
            let name = entry.file_name().to_string_lossy().to_string();
            if live.contains(&name) {
                report.live += 1;
                continue;
            }
            let meta = entry.metadata()?;
            // Zero grace means sweep unconditionally; otherwise an
            // unreadable or future mtime counts as young (skip — never
            // guess toward deletion).
            let young = !min_age.is_zero()
                && meta
                    .modified()
                    .ok()
                    .and_then(|t| t.elapsed().ok())
                    .map(|age| age < min_age)
                    .unwrap_or(true);
            if young {
                // Could be a blob a concurrent writer just published (or
                // is about to reference); count neither way, sweep later.
                continue;
            }
            report.swept += 1;
            report.swept_bytes += meta.len();
            if !dry_run {
                let path = entry.path();
                std::fs::remove_file(&path)
                    .map_err(|e| anyhow::anyhow!("sweep {path:?}: {e}"))?;
            }
        }
        Ok(report)
    }

    // -- campaigns ----------------------------------------------------------

    /// Persist a campaign manifest atomically, serialized through the
    /// store lock (several campaign workers record cell→run assignments
    /// into one file).
    pub fn save_campaign(&self, m: &CampaignManifest) -> anyhow::Result<()> {
        anyhow::ensure!(
            !m.name.is_empty()
                && m.name
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.')),
            "campaign name {:?} must be [A-Za-z0-9._-]+",
            m.name
        );
        let _lock = self.lock()?;
        write_atomic(&self.campaign_path(&m.name), m.to_json().to_string_pretty().as_bytes())
    }

    /// Load-mutate-store a campaign manifest as **one locked
    /// transaction**: the manifest is re-read from disk under the store
    /// lock, transformed, and written back before the lock releases — so
    /// the update can never erase a concurrent writer's changes (the
    /// schema-migration path uses this; a plain load → mutate →
    /// [`RunStore::save_campaign`] would race `claim_campaign_cell` and
    /// lose cell claims). `f` sees the authoritative manifest; returning
    /// it unchanged is a no-op rewrite.
    pub fn update_campaign<F>(&self, name: &str, f: F) -> anyhow::Result<CampaignManifest>
    where
        F: FnOnce(CampaignManifest) -> anyhow::Result<CampaignManifest>,
    {
        let _lock = self.lock()?;
        let m = f(self.load_campaign(name)?)?;
        anyhow::ensure!(
            m.name == name,
            "update_campaign must not rename {name:?} to {:?}",
            m.name
        );
        write_atomic(&self.campaign_path(name), m.to_json().to_string_pretty().as_bytes())?;
        Ok(m)
    }

    /// Atomically claim a campaign cell for `run_id` — a compare-and-swap
    /// through the store lock, so concurrent campaign *processes* can
    /// never overwrite each other's cell→run assignments. The manifest is
    /// re-read from disk here (not trusted from the caller's memory); the
    /// claim lands only if the cell's stored assignment equals `expect`
    /// (or is unassigned). Returns the cell's authoritative assignment
    /// after the call — `run_id` if the claim won, the standing winner if
    /// not.
    pub fn claim_campaign_cell(
        &self,
        name: &str,
        index: usize,
        expect: Option<&str>,
        run_id: &str,
    ) -> anyhow::Result<String> {
        let _lock = self.lock()?;
        let mut m = self.load_campaign(name)?;
        anyhow::ensure!(
            index < m.cells.len(),
            "campaign {name:?} has {} cells, no index {index}",
            m.cells.len()
        );
        match &m.cells[index].run_id {
            Some(current) if Some(current.as_str()) != expect => return Ok(current.clone()),
            _ => {}
        }
        m.cells[index].run_id = Some(run_id.to_string());
        m.updated_unix = crate::util::unix_now();
        write_atomic(&self.campaign_path(name), m.to_json().to_string_pretty().as_bytes())?;
        Ok(run_id.to_string())
    }

    pub fn load_campaign(&self, name: &str) -> anyhow::Result<CampaignManifest> {
        let path = self.campaign_path(name);
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("no stored campaign {name:?} ({path:?}: {e})"))?;
        let j = crate::util::json::Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;
        CampaignManifest::from_json(&j).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))
    }

    pub fn campaign_exists(&self, name: &str) -> bool {
        self.campaign_path(name).exists()
    }

    /// Names of all stored campaigns, sorted.
    pub fn list_campaigns(&self) -> anyhow::Result<Vec<String>> {
        let dir = self.root.join("campaigns");
        let mut out = Vec::new();
        for entry in
            std::fs::read_dir(&dir).map_err(|e| anyhow::anyhow!("read {dir:?}: {e}"))?
        {
            let name = entry?.file_name().to_string_lossy().to_string();
            if let Some(stem) = name.strip_suffix(".json") {
                out.push(stem.to_string());
            }
        }
        out.sort();
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch(tag: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("fedel-store-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn blob_round_trip_and_dedup() {
        let dir = scratch("blob");
        let store = RunStore::open(&dir).unwrap();
        let a = store.put_blob(b"hello", "text/plain").unwrap();
        let b = store.put_blob(b"hello", "text/plain").unwrap();
        assert_eq!(a, b, "identical content must share one address");
        assert_eq!(store.get_blob(&a).unwrap(), b"hello");
        let blobs: Vec<_> = std::fs::read_dir(dir.join("blobs")).unwrap().collect();
        assert_eq!(blobs.len(), 1, "dedup must not write twice");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn params_round_trip_bitwise() {
        let dir = scratch("params");
        let store = RunStore::open(&dir).unwrap();
        let params = vec![0.1f32, -0.0, f32::MIN_POSITIVE, 1.0e30, -7.25];
        let r = store.put_params(&params).unwrap();
        let back = store.get_params(&r).unwrap();
        assert_eq!(params.len(), back.len());
        for (a, b) in params.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corruption_is_detected() {
        let dir = scratch("corrupt");
        let store = RunStore::open(&dir).unwrap();
        let r = store.put_blob(b"precious", "text/plain").unwrap();
        let hex = r.digest.strip_prefix("sha256:").unwrap();
        std::fs::write(store.blob_path(hex), b"precioms").unwrap();
        let err = store.get_blob(&r).unwrap_err();
        assert!(err.to_string().contains("digest mismatch"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fresh_run_ids_never_collide() {
        let dir = scratch("ids");
        let store = RunStore::open(&dir).unwrap();
        let a = store.fresh_run_id("fedel", 42).unwrap();
        assert_eq!(a, "fedel-s42");
        // allocation reserves the directory itself — no create needed
        assert!(store.run_dir(&a).exists(), "allocation must reserve the id");
        let b = store.fresh_run_id("fedel", 42).unwrap();
        assert_eq!(b, "fedel-s42-2");
        assert_eq!(store.fresh_run_id("fedel", 42).unwrap(), "fedel-s42-3");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn lock_excludes_and_releases() {
        let dir = scratch("lock");
        let store = RunStore::open(&dir).unwrap();
        let held = store.lock().unwrap();
        assert!(dir.join(".lock").exists());
        drop(held);
        assert!(!dir.join(".lock").exists(), "lock must release on drop");
        // reacquirable after release
        drop(store.lock().unwrap());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stale_lock_is_reclaimed() {
        let dir = scratch("stale");
        let store = RunStore::open(&dir).unwrap();
        // Simulate a crashed holder: a lockfile whose mtime is ancient.
        let path = dir.join(".lock");
        std::fs::write(&path, b"dead").unwrap();
        let old = std::time::SystemTime::now() - (LOCK_STALE + Duration::from_secs(5));
        let f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
        f.set_modified(old).unwrap();
        drop(f);
        let _held = store.lock().expect("stale lock must be reclaimed");
        let _ = std::fs::remove_dir_all(&dir);
    }

    fn manifest_with_params(
        store: &RunStore,
        id: &str,
        ck: Option<&[f32]>,
        fin: Option<&[f32]>,
    ) -> RunManifest {
        use crate::store::schema::{Checkpoint, FinalState, RunStatus, SCHEMA_VERSION};
        RunManifest {
            schema_version: SCHEMA_VERSION,
            id: id.to_string(),
            created_unix: 0,
            updated_unix: 0,
            status: if fin.is_some() { RunStatus::Complete } else { RunStatus::Running },
            strategy: "fedavg".into(),
            config: Default::default(),
            records: Vec::new(),
            checkpoint: ck.map(|p| Checkpoint {
                completed: 1,
                sim_time: 1.0,
                params: store.put_params(p).unwrap(),
                policy_state: crate::util::json::Json::Null,
                async_state: crate::util::json::Json::Null,
            }),
            final_state: fin.map(|p| FinalState {
                final_acc: 0.5,
                final_loss: 0.5,
                sim_total_secs: 2.0,
                params: store.put_params(p).unwrap(),
            }),
        }
    }

    #[test]
    fn gc_sweeps_orphans_and_keeps_referenced() {
        let dir = scratch("gc");
        let store = RunStore::open(&dir).unwrap();
        let keep = manifest_with_params(&store, "keep-s1", Some(&[1.0, 2.0]), Some(&[3.0, 4.0]));
        store.save_manifest(&keep).unwrap();
        let doomed =
            manifest_with_params(&store, "doomed-s1", Some(&[5.0, 6.0]), Some(&[7.0, 8.0]));
        store.save_manifest(&doomed).unwrap();
        // hand-delete the second run: its two blobs are now orphans
        std::fs::remove_dir_all(store.run_dir("doomed-s1")).unwrap();

        // dry run reports but deletes nothing
        let dry = store.gc_blobs(Duration::ZERO, true).unwrap();
        assert_eq!((dry.live, dry.swept), (2, 2), "{dry:?}");
        assert!(dry.swept_bytes > 0);
        assert_eq!(std::fs::read_dir(dir.join("blobs")).unwrap().count(), 4);

        let report = store.gc_blobs(Duration::ZERO, false).unwrap();
        assert_eq!((report.live, report.swept), (2, 2), "{report:?}");
        assert_eq!(std::fs::read_dir(dir.join("blobs")).unwrap().count(), 2);
        // referenced blobs still fetch + verify
        assert_eq!(
            store.get_params(&keep.final_state.as_ref().unwrap().params).unwrap(),
            vec![3.0, 4.0]
        );
        // idempotent
        let again = store.gc_blobs(Duration::ZERO, false).unwrap();
        assert_eq!((again.live, again.swept), (2, 0), "{again:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_grace_window_spares_young_orphans() {
        let dir = scratch("gc-young");
        let store = RunStore::open(&dir).unwrap();
        store.put_blob(b"unreferenced-but-fresh", "text/plain").unwrap();
        let report = store.gc_blobs(Duration::from_secs(3600), false).unwrap();
        assert_eq!(report.swept, 0, "young orphans must survive the grace window");
        assert_eq!(std::fs::read_dir(dir.join("blobs")).unwrap().count(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_aborts_on_unreadable_manifest() {
        let dir = scratch("gc-unreadable");
        let store = RunStore::open(&dir).unwrap();
        store.put_blob(b"maybe-referenced", "text/plain").unwrap();
        let bad = store.run_dir("torn-s1");
        std::fs::create_dir_all(&bad).unwrap();
        std::fs::write(bad.join("manifest.json"), b"{ torn").unwrap();
        let err = store.gc_blobs(Duration::ZERO, false).unwrap_err();
        assert!(err.to_string().contains("unreadable"), "{err}");
        assert_eq!(
            std::fs::read_dir(dir.join("blobs")).unwrap().count(),
            1,
            "gc must not sweep past an unreadable manifest"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn campaign_cell_claims_are_first_writer_wins() {
        use crate::store::schema::{CampaignManifest, CellState, CAMPAIGN_SCHEMA_VERSION};
        let dir = scratch("claim");
        let store = RunStore::open(&dir).unwrap();
        let m = CampaignManifest {
            schema_version: CAMPAIGN_SCHEMA_VERSION,
            name: "sweep".into(),
            created_unix: 0,
            updated_unix: 0,
            spec: crate::util::json::Json::Null,
            cells: vec![
                CellState { label: "a".into(), run_id: None },
                CellState { label: "b".into(), run_id: None },
            ],
        };
        store.save_campaign(&m).unwrap();
        // first claim lands and persists
        assert_eq!(store.claim_campaign_cell("sweep", 0, None, "fedavg-s1").unwrap(), "fedavg-s1");
        assert_eq!(
            store.load_campaign("sweep").unwrap().cells[0].run_id.as_deref(),
            Some("fedavg-s1")
        );
        // a competing claim (e.g. from a second campaign process) is told
        // who won instead of overwriting
        assert_eq!(
            store.claim_campaign_cell("sweep", 0, None, "fedavg-s1-2").unwrap(),
            "fedavg-s1"
        );
        // other cells are untouched and claimable
        assert_eq!(store.claim_campaign_cell("sweep", 1, None, "fedel-s1").unwrap(), "fedel-s1");
        // CAS on the old id reassigns (the hand-deleted-run path)...
        assert_eq!(
            store.claim_campaign_cell("sweep", 0, Some("fedavg-s1"), "fedavg-s1-9").unwrap(),
            "fedavg-s1-9"
        );
        // ...but a stale expectation loses to the standing winner
        assert_eq!(
            store.claim_campaign_cell("sweep", 0, Some("fedavg-s1"), "fedavg-s1-7").unwrap(),
            "fedavg-s1-9"
        );
        let back = store.load_campaign("sweep").unwrap();
        assert_eq!(back.cells[0].run_id.as_deref(), Some("fedavg-s1-9"));
        assert_eq!(back.cells[1].run_id.as_deref(), Some("fedel-s1"));
        assert!(
            store.claim_campaign_cell("sweep", 2, None, "x").is_err(),
            "bad index must error"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn update_campaign_transforms_the_authoritative_on_disk_state() {
        use crate::store::schema::{CampaignManifest, CellState, CAMPAIGN_SCHEMA_VERSION};
        let dir = scratch("update-campaign");
        let store = RunStore::open(&dir).unwrap();
        let stale = CampaignManifest {
            schema_version: CAMPAIGN_SCHEMA_VERSION,
            name: "sweep".into(),
            created_unix: 0,
            updated_unix: 0,
            spec: crate::util::json::Json::Null,
            cells: vec![CellState { label: "a".into(), run_id: None }],
        };
        store.save_campaign(&stale).unwrap();
        // a claim lands after our (stale) load above...
        store.claim_campaign_cell("sweep", 0, None, "fedavg-s1").unwrap();
        // ...and an update must see it: the closure gets the on-disk
        // manifest, not whatever the caller last loaded, so transforming
        // labels/spec can never erase the concurrent claim.
        let updated = store
            .update_campaign("sweep", |mut m| {
                assert_eq!(m.cells[0].run_id.as_deref(), Some("fedavg-s1"));
                m.cells[0].label = "relabeled".into();
                Ok(m)
            })
            .unwrap();
        assert_eq!(updated.cells[0].run_id.as_deref(), Some("fedavg-s1"));
        let back = store.load_campaign("sweep").unwrap();
        assert_eq!(back.cells[0].label, "relabeled");
        assert_eq!(back.cells[0].run_id.as_deref(), Some("fedavg-s1"));
        // a renaming closure is rejected before anything is written
        assert!(store
            .update_campaign("sweep", |mut m| {
                m.name = "other".into();
                Ok(m)
            })
            .is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn wrong_media_type_rejected_for_params() {
        let dir = scratch("media");
        let store = RunStore::open(&dir).unwrap();
        let r = store.put_blob(&[0u8; 8], "text/plain").unwrap();
        assert!(store.get_params(&r).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
