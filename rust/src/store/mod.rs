//! Persistent run store: checkpointed, resumable, comparable experiments.
//!
//! FedEL's headline metric is time-to-accuracy over long multi-round
//! campaigns; real deployments treat interruption as the norm. This
//! subsystem makes run state durable and first-class:
//!
//! ```text
//! <root>/
//!   runs/<id>/manifest.json   versioned RunManifest (schema.rs): config
//!                             snapshot, round records, latest checkpoint,
//!                             final summary
//!   blobs/<sha256-hex>        content-addressed blobs (global parameter
//!                             vectors, f32 little-endian) — identical
//!                             snapshots dedup across rounds and runs
//! ```
//!
//! * [`checkpoint::CheckpointObserver`] hangs off the server's observer
//!   seam and persists every k rounds (atomically: tmp + rename).
//! * [`checkpoint::resume_state`] turns a stored checkpoint back into a
//!   [`crate::fl::server::ResumeState`]; resumed runs are
//!   bitwise-identical to uninterrupted ones (`tests/resume.rs`).
//! * [`RunStore::latest_params`] is the warm-start seam: any stored run
//!   can seed a new experiment's global model.
//!
//! CLI: `fedel runs list | show <id> | resume <id> | compare <a> <b>`.

pub mod checkpoint;
pub mod schema;

use std::path::{Path, PathBuf};

use crate::util::sha256;
use self::schema::{BlobRef, RunManifest};

/// Media type of a little-endian f32 parameter-vector blob (the same
/// encoding as the artifacts' `init.bin`).
pub const MEDIA_PARAMS_F32LE: &str = "application/x-fedel-params.f32le";

/// A store rooted at one directory; see the module docs for the layout.
pub struct RunStore {
    root: PathBuf,
}

impl RunStore {
    /// Open a store, creating the directory skeleton if absent.
    pub fn open(root: impl Into<PathBuf>) -> anyhow::Result<RunStore> {
        let root = root.into();
        for sub in ["runs", "blobs"] {
            let dir = root.join(sub);
            std::fs::create_dir_all(&dir)
                .map_err(|e| anyhow::anyhow!("create {dir:?}: {e}"))?;
        }
        Ok(RunStore { root })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn run_dir(&self, id: &str) -> PathBuf {
        self.root.join("runs").join(id)
    }

    fn blob_path(&self, hex: &str) -> PathBuf {
        self.root.join("blobs").join(hex)
    }

    // -- runs ---------------------------------------------------------------

    /// Allocate a fresh, human-readable run id: `<strategy>-s<seed>`,
    /// suffixed `-2`, `-3`, ... when taken.
    pub fn fresh_run_id(&self, strategy: &str, seed: u64) -> String {
        let base = format!("{strategy}-s{seed}");
        if !self.run_dir(&base).exists() {
            return base;
        }
        let mut n = 2usize;
        loop {
            let id = format!("{base}-{n}");
            if !self.run_dir(&id).exists() {
                return id;
            }
            n += 1;
        }
    }

    /// Persist a manifest atomically (tmp + rename): a crash mid-write
    /// leaves the previous manifest intact, never a torn one.
    pub fn save_manifest(&self, m: &RunManifest) -> anyhow::Result<()> {
        let dir = self.run_dir(&m.id);
        std::fs::create_dir_all(&dir).map_err(|e| anyhow::anyhow!("create {dir:?}: {e}"))?;
        let tmp = dir.join("manifest.json.tmp");
        std::fs::write(&tmp, m.to_json().to_string_pretty())
            .map_err(|e| anyhow::anyhow!("write {tmp:?}: {e}"))?;
        let path = dir.join("manifest.json");
        std::fs::rename(&tmp, &path).map_err(|e| anyhow::anyhow!("rename to {path:?}: {e}"))?;
        Ok(())
    }

    pub fn load_manifest(&self, id: &str) -> anyhow::Result<RunManifest> {
        let path = self.run_dir(id).join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("no stored run {id:?} ({path:?}: {e})"))?;
        let j = crate::util::json::Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;
        RunManifest::from_json(&j).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))
    }

    /// All stored runs, oldest first (creation time, then id). Unreadable
    /// manifests (torn external copies, future schema versions) are
    /// skipped with a warning — one bad directory must not take the whole
    /// store's listing down.
    pub fn list(&self) -> anyhow::Result<Vec<RunManifest>> {
        let dir = self.root.join("runs");
        let mut out = Vec::new();
        for entry in
            std::fs::read_dir(&dir).map_err(|e| anyhow::anyhow!("read {dir:?}: {e}"))?
        {
            let entry = entry?;
            if !entry.path().join("manifest.json").exists() {
                continue;
            }
            match self.load_manifest(&entry.file_name().to_string_lossy()) {
                Ok(m) => out.push(m),
                Err(e) => eprintln!("warning: skipping unreadable run: {e}"),
            }
        }
        out.sort_by(|a, b| {
            a.created_unix.cmp(&b.created_unix).then_with(|| a.id.cmp(&b.id))
        });
        Ok(out)
    }

    // -- blobs --------------------------------------------------------------

    /// Store bytes under their content address; already-present digests
    /// are not rewritten, so identical snapshots dedup for free.
    pub fn put_blob(&self, bytes: &[u8], media_type: &str) -> anyhow::Result<BlobRef> {
        let hex = sha256::hex(bytes);
        let path = self.blob_path(&hex);
        if !path.exists() {
            let tmp = self.blob_path(&format!("{hex}.tmp"));
            std::fs::write(&tmp, bytes).map_err(|e| anyhow::anyhow!("write {tmp:?}: {e}"))?;
            std::fs::rename(&tmp, &path)
                .map_err(|e| anyhow::anyhow!("rename to {path:?}: {e}"))?;
        }
        Ok(BlobRef {
            digest: format!("sha256:{hex}"),
            size: bytes.len() as u64,
            media_type: media_type.to_string(),
        })
    }

    /// Fetch a blob, verifying size and digest (a store is only useful if
    /// corruption is loud).
    pub fn get_blob(&self, r: &BlobRef) -> anyhow::Result<Vec<u8>> {
        let hex = r
            .digest
            .strip_prefix("sha256:")
            .ok_or_else(|| anyhow::anyhow!("unsupported digest {:?}", r.digest))?;
        let path = self.blob_path(hex);
        let bytes =
            std::fs::read(&path).map_err(|e| anyhow::anyhow!("read blob {path:?}: {e}"))?;
        anyhow::ensure!(
            bytes.len() as u64 == r.size,
            "blob {hex}: {} bytes on disk, descriptor says {}",
            bytes.len(),
            r.size
        );
        anyhow::ensure!(sha256::hex(&bytes) == hex, "blob {hex}: content digest mismatch");
        Ok(bytes)
    }

    /// Store a global parameter vector (little-endian f32 — bitwise exact).
    pub fn put_params(&self, params: &[f32]) -> anyhow::Result<BlobRef> {
        let mut bytes = Vec::with_capacity(params.len() * 4);
        for x in params {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        self.put_blob(&bytes, MEDIA_PARAMS_F32LE)
    }

    pub fn get_params(&self, r: &BlobRef) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(
            r.media_type == MEDIA_PARAMS_F32LE,
            "blob {} is {:?}, not a parameter vector",
            r.digest,
            r.media_type
        );
        let bytes = self.get_blob(r)?;
        anyhow::ensure!(bytes.len() % 4 == 0, "params blob not a multiple of 4 bytes");
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Warm-start source: a stored run's newest global parameters — the
    /// final model if complete, else the latest checkpoint.
    pub fn latest_params(&self, id: &str) -> anyhow::Result<Vec<f32>> {
        let m = self.load_manifest(id)?;
        let blob = m
            .final_state
            .as_ref()
            .map(|f| &f.params)
            .or_else(|| m.checkpoint.as_ref().map(|c| &c.params))
            .ok_or_else(|| anyhow::anyhow!("run {id} has no stored parameters yet"))?;
        self.get_params(blob)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch(tag: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("fedel-store-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn blob_round_trip_and_dedup() {
        let dir = scratch("blob");
        let store = RunStore::open(&dir).unwrap();
        let a = store.put_blob(b"hello", "text/plain").unwrap();
        let b = store.put_blob(b"hello", "text/plain").unwrap();
        assert_eq!(a, b, "identical content must share one address");
        assert_eq!(store.get_blob(&a).unwrap(), b"hello");
        let blobs: Vec<_> = std::fs::read_dir(dir.join("blobs")).unwrap().collect();
        assert_eq!(blobs.len(), 1, "dedup must not write twice");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn params_round_trip_bitwise() {
        let dir = scratch("params");
        let store = RunStore::open(&dir).unwrap();
        let params = vec![0.1f32, -0.0, f32::MIN_POSITIVE, 1.0e30, -7.25];
        let r = store.put_params(&params).unwrap();
        let back = store.get_params(&r).unwrap();
        assert_eq!(params.len(), back.len());
        for (a, b) in params.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corruption_is_detected() {
        let dir = scratch("corrupt");
        let store = RunStore::open(&dir).unwrap();
        let r = store.put_blob(b"precious", "text/plain").unwrap();
        let hex = r.digest.strip_prefix("sha256:").unwrap();
        std::fs::write(store.blob_path(hex), b"precioms").unwrap();
        let err = store.get_blob(&r).unwrap_err();
        assert!(err.to_string().contains("digest mismatch"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fresh_run_ids_never_collide() {
        let dir = scratch("ids");
        let store = RunStore::open(&dir).unwrap();
        let a = store.fresh_run_id("fedel", 42);
        assert_eq!(a, "fedel-s42");
        std::fs::create_dir_all(store.run_dir(&a)).unwrap();
        let b = store.fresh_run_id("fedel", 42);
        assert_eq!(b, "fedel-s42-2");
        std::fs::create_dir_all(store.run_dir(&b)).unwrap();
        assert_eq!(store.fresh_run_id("fedel", 42), "fedel-s42-3");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn wrong_media_type_rejected_for_params() {
        let dir = scratch("media");
        let store = RunStore::open(&dir).unwrap();
        let r = store.put_blob(&[0u8; 8], "text/plain").unwrap();
        assert!(store.get_params(&r).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
