//! The run store's versioned, serde-style schema (the offline registry has
//! no serde, so the types (de)serialize explicitly over [`crate::util::json`]).
//!
//! One [`RunManifest`] per stored run (`runs/<id>/manifest.json`) carries
//! the config snapshot, the full round-record stream, the latest
//! [`Checkpoint`] (resume point), and the [`FinalState`] once complete.
//! Bulk data — global parameter vectors — never lives in the manifest:
//! it is content-addressed into `blobs/<sha256>` and referenced by
//! [`BlobRef`] (the OCI descriptor idiom: digest + size + media type), so
//! identical snapshots dedup across rounds and runs.
//!
//! Round-trip exactness is a design requirement, not a nicety: resumed
//! runs must be bitwise-identical to uninterrupted ones, so every f64
//! rides the JSON writer's shortest round-trip Display, f32 parameters
//! ride little-endian blobs, and u64 RNG words ride strings. These same
//! functions back `RoundRecord::to_json` / `ExperimentResult::to_json`
//! and the JSONL observer, so logs, result dumps, and checkpoints share
//! one serialization path.

use crate::config::ExperimentCfg;
use crate::fl::server::{ExperimentResult, RoundRecord};
use crate::util::json::Json;

/// Bump on any incompatible manifest change; `RunManifest::from_json`
/// rejects versions it does not understand.
///
/// v1 -> v2: checkpoints may carry `async_state` (the asynchronous
/// runner's in-flight client clocks + staleness buffer) and round records
/// may carry staleness statistics. v1 manifests load unchanged (those
/// keys simply read as absent); v2 is a distinct version because a
/// v1-era binary resuming an async checkpoint would silently drop the
/// runner state and diverge.
///
/// v2 -> v3: the parameter vectors inside `async_state` (`versions` /
/// `buffer` entries' `params`) are externalized into content-addressed
/// [`BlobRef`]s instead of inline number arrays
/// ([`crate::store::checkpoint::externalize_async_state`]), shrinking
/// async manifests by an order of magnitude. v2 manifests load and
/// resume unchanged (inline arrays pass through); v3 is a distinct
/// version because a v2-era binary would feed the BlobRef object to the
/// async runner's array decoder and fail.
///
/// v3 -> v4: checkpoints may delta-encode their parameter blob against
/// the previous checkpoint's ([`Checkpoint::params_chain`] names the
/// base-to-delta blob chain; empty = `params` is a full vector, which is
/// exactly what every v≤3 manifest reads as). v4 is a distinct version
/// because a v3-era binary would decode a delta blob as a raw f32 vector
/// and resume from garbage.
pub const SCHEMA_VERSION: usize = 4;

/// Oldest run-manifest schema `RunManifest::from_json` still accepts.
pub const SCHEMA_MIN: usize = 1;

/// Content-addressed reference to a blob in the store.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BlobRef {
    /// `sha256:<lowercase hex>` of the blob's bytes.
    pub digest: String,
    /// Byte length (integrity-checked on read).
    pub size: u64,
    /// What the bytes are (e.g. [`crate::store::MEDIA_PARAMS_F32LE`]).
    pub media_type: String,
}

impl BlobRef {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("digest", Json::Str(self.digest.clone())),
            ("size", Json::Num(self.size as f64)),
            ("mediaType", Json::Str(self.media_type.clone())),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<BlobRef> {
        Ok(BlobRef {
            digest: j.s("digest")?.to_string(),
            size: j.f("size")? as u64,
            media_type: j.s("mediaType")?.to_string(),
        })
    }
}

/// Lifecycle of a stored run. A crashed process leaves `Running` behind —
/// that plus a checkpoint is exactly what "resumable" means.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RunStatus {
    Running,
    Complete,
}

impl RunStatus {
    pub fn as_str(&self) -> &'static str {
        match self {
            RunStatus::Running => "running",
            RunStatus::Complete => "complete",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<RunStatus> {
        match s {
            "running" => Ok(RunStatus::Running),
            "complete" => Ok(RunStatus::Complete),
            other => anyhow::bail!("unknown run status {other:?}"),
        }
    }
}

/// A resume point: everything [`crate::fl::server::run_experiment_from`]
/// needs beyond the config snapshot and round records.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    /// Rounds completed when the checkpoint was taken.
    pub completed: usize,
    /// Simulated clock at that point.
    pub sim_time: f64,
    /// Global parameters after round `completed - 1`. A full f32 vector
    /// blob when `params_chain` is empty; otherwise a sparse-delta blob
    /// ([`crate::store::MEDIA_PARAMS_DELTA`]) to overlay on the resolved
    /// chain.
    pub params: BlobRef,
    /// Delta-encoding ancestry of `params`: a full-vector base blob
    /// followed by the intermediate delta blobs, oldest first. Empty =
    /// `params` is itself a full vector (the only shape v≤3 writers
    /// produced, so old manifests load unchanged). Resolution:
    /// `chain[0]` decoded dense, each later entry overlaid in order,
    /// then `params` overlaid last
    /// ([`crate::store::RunStore::resolve_params`]).
    pub params_chain: Vec<BlobRef>,
    /// [`crate::strategies::Strategy::policy_state`] snapshot (includes
    /// any strategy RNG state; `Null` for stateless strategies).
    pub policy_state: Json,
    /// Asynchronous-runner snapshot ([`crate::fl::exec::event`]): in-flight
    /// client clocks + dispatch versions, the referenced global versions,
    /// and the staleness buffer. `Null` for synchronous runs.
    pub async_state: Json,
}

impl Checkpoint {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("completed", Json::Num(self.completed as f64)),
            ("sim_time", Json::Num(self.sim_time)),
            ("params", self.params.to_json()),
        ];
        // Omit-at-default: full-vector checkpoints keep the v≤3 shape.
        if !self.params_chain.is_empty() {
            fields.push((
                "params_chain",
                Json::Arr(self.params_chain.iter().map(BlobRef::to_json).collect()),
            ));
        }
        fields.push(("policy_state", self.policy_state.clone()));
        fields.push(("async_state", self.async_state.clone()));
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Checkpoint> {
        let params_chain = match j.get("params_chain") {
            None | Some(Json::Null) => Vec::new(),
            Some(v) => v
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("checkpoint params_chain not an array"))?
                .iter()
                .map(BlobRef::from_json)
                .collect::<anyhow::Result<_>>()?,
        };
        Ok(Checkpoint {
            completed: j.u("completed")?,
            sim_time: j.f("sim_time")?,
            params: BlobRef::from_json(j.req("params")?)?,
            params_chain,
            policy_state: j.get("policy_state").cloned().unwrap_or(Json::Null),
            async_state: j.get("async_state").cloned().unwrap_or(Json::Null),
        })
    }
}

/// Terminal summary of a completed run; `params` is the final global model
/// (the warm-start seed of choice).
#[derive(Clone, Debug)]
pub struct FinalState {
    pub final_acc: f64,
    pub final_loss: f64,
    pub sim_total_secs: f64,
    pub params: BlobRef,
}

impl FinalState {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("final_acc", Json::Num(self.final_acc)),
            ("final_loss", Json::Num(self.final_loss)),
            ("sim_total_secs", Json::Num(self.sim_total_secs)),
            ("params", self.params.to_json()),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<FinalState> {
        Ok(FinalState {
            final_acc: j.f("final_acc")?,
            final_loss: j.f("final_loss")?,
            sim_total_secs: j.f("sim_total_secs")?,
            params: BlobRef::from_json(j.req("params")?)?,
        })
    }
}

/// Everything the store knows about one run: `runs/<id>/manifest.json`.
#[derive(Clone, Debug)]
pub struct RunManifest {
    pub schema_version: usize,
    pub id: String,
    pub created_unix: u64,
    pub updated_unix: u64,
    pub status: RunStatus,
    /// Resolved strategy (the config's unless overridden at launch).
    pub strategy: String,
    /// Config snapshot — enough to rebuild the engine, fleet, dataset, and
    /// strategy deterministically ([`ExperimentCfg::from_json`]).
    pub config: ExperimentCfg,
    /// Round records up to the latest persisted point.
    pub records: Vec<RoundRecord>,
    pub checkpoint: Option<Checkpoint>,
    pub final_state: Option<FinalState>,
}

impl RunManifest {
    /// Final accuracy: the terminal summary if complete, else the newest
    /// eval on record.
    pub fn final_acc(&self) -> Option<f64> {
        self.final_state
            .as_ref()
            .map(|f| f.final_acc)
            .or_else(|| self.records.iter().rev().find_map(|r| r.eval_acc))
    }

    /// Simulated seconds covered by the persisted records.
    pub fn sim_time(&self) -> f64 {
        self.records.last().map(|r| r.sim_time).unwrap_or(0.0)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema_version", Json::Num(self.schema_version as f64)),
            ("id", Json::Str(self.id.clone())),
            ("created_unix", Json::Num(self.created_unix as f64)),
            ("updated_unix", Json::Num(self.updated_unix as f64)),
            ("status", Json::Str(self.status.as_str().to_string())),
            ("strategy", Json::Str(self.strategy.clone())),
            ("config", self.config.to_json()),
            (
                "records",
                Json::Arr(self.records.iter().map(round_record_to_json).collect()),
            ),
            (
                "checkpoint",
                self.checkpoint.as_ref().map(Checkpoint::to_json).unwrap_or(Json::Null),
            ),
            (
                "final_state",
                self.final_state.as_ref().map(FinalState::to_json).unwrap_or(Json::Null),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<RunManifest> {
        let version = j.u("schema_version")?;
        anyhow::ensure!(
            (SCHEMA_MIN..=SCHEMA_VERSION).contains(&version),
            "run manifest schema v{version} unsupported \
             (this build reads v{SCHEMA_MIN}..v{SCHEMA_VERSION})"
        );
        let opt = |key: &str| match j.get(key) {
            None | Some(Json::Null) => None,
            Some(v) => Some(v),
        };
        Ok(RunManifest {
            schema_version: version,
            id: j.s("id")?.to_string(),
            created_unix: j.f("created_unix")? as u64,
            updated_unix: j.f("updated_unix")? as u64,
            status: RunStatus::parse(j.s("status")?)?,
            strategy: j.s("strategy")?.to_string(),
            config: ExperimentCfg::from_json(j.req("config")?)?,
            records: j
                .arr("records")?
                .iter()
                .map(round_record_from_json)
                .collect::<anyhow::Result<_>>()?,
            checkpoint: opt("checkpoint").map(Checkpoint::from_json).transpose()?,
            final_state: opt("final_state").map(FinalState::from_json).transpose()?,
        })
    }
}

// -- campaigns ---------------------------------------------------------------

/// Bump on any incompatible campaign-manifest change (independent of the
/// run-manifest version: the two files evolve separately).
///
/// v1 -> v2: the spec's four fixed grid axes
/// (`strategies`/`seeds`/`fleets`/`t_th_factors`) became generic
/// `axes: [{key, values}]` over the typed parameter space, and cell
/// labels derive from the resolved overlay (`strategy=fedavg,seed=1`)
/// instead of the `fedavg-s1-...` format. v1 manifests still load;
/// [`crate::sim::campaign`] migrates them in place on the next run so
/// existing campaigns stay resumable.
///
/// v2 -> v3: cells grew operator state — a worker lease (`worker` id +
/// `lease_unix` heartbeat, written only while held) and a `pruned` flag
/// set when a successive-halving policy retires the cell. All three
/// serialize omit-at-default, so a v3 manifest with no leases and no
/// pruned cells is byte-identical to its v2 form and v2 manifests load
/// unchanged; [`crate::sim::campaign`] stamps the version forward on
/// the next run.
pub const CAMPAIGN_SCHEMA_VERSION: usize = 3;

/// Oldest campaign schema [`CampaignManifest::from_json`] still accepts
/// (the campaign runner upgrades anything older than current on load).
pub const CAMPAIGN_SCHEMA_MIN: usize = 1;

/// One grid cell's persisted assignment: the deterministic label plus the
/// run id it was allocated (None until a worker first touches the cell),
/// plus operator state — the worker lease (holder id + last heartbeat)
/// and the halving policy's pruned flag. Lease fields and `pruned`
/// serialize omit-at-default so lease-free manifests keep their v2 bytes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CellState {
    pub label: String,
    pub run_id: Option<String>,
    /// Worker id currently holding this cell's lease (None = unleased).
    pub worker: Option<String>,
    /// Unix time of the lease holder's last heartbeat (0 = unleased).
    pub lease_unix: u64,
    /// Retired by a successive-halving rung; never scheduled again.
    pub pruned: bool,
}

impl CellState {
    pub fn unassigned(label: String) -> CellState {
        CellState { label, run_id: None, worker: None, lease_unix: 0, pruned: false }
    }

    /// Seconds since the holder's last heartbeat (None when unleased).
    pub fn lease_age_secs(&self, now: u64) -> Option<u64> {
        self.worker.as_ref().map(|_| now.saturating_sub(self.lease_unix))
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("label", Json::Str(self.label.clone())),
            (
                "run_id",
                self.run_id.as_ref().map(|s| Json::Str(s.clone())).unwrap_or(Json::Null),
            ),
        ];
        if let Some(w) = &self.worker {
            fields.push(("worker", Json::Str(w.clone())));
        }
        if self.lease_unix != 0 {
            fields.push(("lease_unix", Json::Num(self.lease_unix as f64)));
        }
        if self.pruned {
            fields.push(("pruned", Json::Bool(true)));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> anyhow::Result<CellState> {
        Ok(CellState {
            label: j.s("label")?.to_string(),
            run_id: match j.get("run_id") {
                None | Some(Json::Null) => None,
                Some(v) => Some(
                    v.as_str()
                        .ok_or_else(|| anyhow::anyhow!("cell run_id not a string"))?
                        .to_string(),
                ),
            },
            worker: match j.get("worker") {
                None | Some(Json::Null) => None,
                Some(v) => Some(
                    v.as_str()
                        .ok_or_else(|| anyhow::anyhow!("cell worker not a string"))?
                        .to_string(),
                ),
            },
            lease_unix: match j.get("lease_unix") {
                Some(Json::Num(n)) => *n as u64,
                _ => 0,
            },
            pruned: matches!(j.get("pruned"), Some(Json::Bool(true))),
        })
    }
}

/// Everything the store knows about one campaign:
/// `campaigns/<name>.json`. The `spec` snapshot is the grid definition
/// ([`crate::sim::campaign::CampaignCfg`] serialization) so a bare
/// `campaign run --name <x>` can resume without respecifying the grid;
/// `cells` is the persisted cell→run assignment that makes resumption
/// find each cell's runs again.
#[derive(Clone, Debug)]
pub struct CampaignManifest {
    pub schema_version: usize,
    pub name: String,
    pub created_unix: u64,
    pub updated_unix: u64,
    /// Grid spec snapshot (opaque to the store, owned by sim::campaign).
    pub spec: Json,
    pub cells: Vec<CellState>,
}

impl CampaignManifest {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema_version", Json::Num(self.schema_version as f64)),
            ("name", Json::Str(self.name.clone())),
            ("created_unix", Json::Num(self.created_unix as f64)),
            ("updated_unix", Json::Num(self.updated_unix as f64)),
            ("spec", self.spec.clone()),
            ("cells", Json::Arr(self.cells.iter().map(CellState::to_json).collect())),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<CampaignManifest> {
        let version = j.u("schema_version")?;
        anyhow::ensure!(
            (CAMPAIGN_SCHEMA_MIN..=CAMPAIGN_SCHEMA_VERSION).contains(&version),
            "campaign manifest schema v{version} unsupported \
             (this build reads v{CAMPAIGN_SCHEMA_MIN}..v{CAMPAIGN_SCHEMA_VERSION})"
        );
        Ok(CampaignManifest {
            schema_version: version,
            name: j.s("name")?.to_string(),
            created_unix: j.f("created_unix")? as u64,
            updated_unix: j.f("updated_unix")? as u64,
            spec: j.req("spec")?.clone(),
            cells: j
                .arr("cells")?
                .iter()
                .map(CellState::from_json)
                .collect::<anyhow::Result<_>>()?,
        })
    }
}

// -- round records ----------------------------------------------------------

/// Canonical [`RoundRecord`] serialization (manifests, JSONL logs, result
/// dumps all use this one function).
pub fn round_record_to_json(r: &RoundRecord) -> Json {
    let mut fields = vec![
        ("round", Json::Num(r.round as f64)),
        ("round_secs", Json::Num(r.round_secs)),
        ("sim_time", Json::Num(r.sim_time)),
        ("mean_train_loss", Json::Num(r.mean_train_loss)),
        ("participants", Json::Num(r.participants as f64)),
        ("mean_coverage", Json::Num(r.mean_coverage)),
        ("o1", Json::Num(r.o1)),
        ("eval_acc", r.eval_acc.map(Json::Num).unwrap_or(Json::Null)),
        ("eval_loss", r.eval_loss.map(Json::Num).unwrap_or(Json::Null)),
        ("mean_staleness", r.mean_staleness.map(Json::Num).unwrap_or(Json::Null)),
        ("max_staleness", r.max_staleness.map(Json::Num).unwrap_or(Json::Null)),
        (
            "client_secs",
            Json::Arr(
                r.client_secs
                    .iter()
                    .map(|&(c, t)| Json::Arr(vec![Json::Num(c as f64), Json::Num(t)]))
                    .collect(),
            ),
        ),
    ];
    // Omit-at-default: churn-free records keep the pre-churn schema
    // byte-for-byte (and old records read back as "no drops").
    if !r.dropped.is_empty() {
        fields.push((
            "dropped",
            Json::Arr(r.dropped.iter().map(|&c| Json::Num(c as f64)).collect()),
        ));
    }
    // Speculation counters likewise omit at zero: depth-0 (and
    // synchronous) records keep the pre-speculation schema byte-for-byte.
    if r.spec_hits != 0 {
        fields.push(("spec_hits", Json::Num(r.spec_hits as f64)));
    }
    if r.spec_misses != 0 {
        fields.push(("spec_misses", Json::Num(r.spec_misses as f64)));
    }
    Json::obj(fields)
}

pub fn round_record_from_json(j: &Json) -> anyhow::Result<RoundRecord> {
    let eval = |key: &str| match j.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => v
            .as_f64()
            .map(Some)
            .ok_or_else(|| anyhow::anyhow!("round record {key} not a number")),
    };
    let client_secs = j
        .arr("client_secs")?
        .iter()
        .map(|pair| {
            let pair = pair.as_arr().filter(|p| p.len() == 2);
            let pair = pair.ok_or_else(|| anyhow::anyhow!("client_secs entry not a pair"))?;
            let c = pair[0]
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("client_secs client not a number"))?;
            let t = pair[1]
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("client_secs seconds not a number"))?;
            Ok((c, t))
        })
        .collect::<anyhow::Result<_>>()?;
    Ok(RoundRecord {
        round: j.u("round")?,
        round_secs: j.f("round_secs")?,
        sim_time: j.f("sim_time")?,
        mean_train_loss: j.f("mean_train_loss")?,
        participants: j.u("participants")?,
        mean_coverage: j.f("mean_coverage")?,
        o1: j.f("o1")?,
        eval_acc: eval("eval_acc")?,
        eval_loss: eval("eval_loss")?,
        client_secs,
        mean_staleness: eval("mean_staleness")?,
        max_staleness: eval("max_staleness")?,
        dropped: match j.get("dropped") {
            None | Some(Json::Null) => Vec::new(),
            Some(v) => v
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("round record dropped not an array"))?
                .iter()
                .map(|c| {
                    c.as_usize()
                        .ok_or_else(|| anyhow::anyhow!("dropped client not a number"))
                })
                .collect::<anyhow::Result<_>>()?,
        },
        spec_hits: j.get("spec_hits").and_then(Json::as_usize).unwrap_or(0),
        spec_misses: j.get("spec_misses").and_then(Json::as_usize).unwrap_or(0),
    })
}

// -- results ----------------------------------------------------------------

/// Terminal result summary (the JSONL observer's closing line and the
/// head of [`result_to_json`]).
pub fn result_summary_to_json(res: &ExperimentResult) -> Json {
    Json::obj(vec![
        ("strategy", Json::Str(res.strategy.clone())),
        ("rounds", Json::Num(res.records.len() as f64)),
        ("sim_total_secs", Json::Num(res.sim_total_secs)),
        ("final_acc", Json::Num(res.final_acc)),
        ("final_loss", Json::Num(res.final_loss)),
    ])
}

/// Full result dump: summary, eval curve, and every round record.
pub fn result_to_json(res: &ExperimentResult) -> Json {
    let mut kv = match result_summary_to_json(res) {
        Json::Obj(kv) => kv,
        _ => unreachable!("summary is an object"),
    };
    kv.push((
        "acc_curve".to_string(),
        Json::Arr(res.acc_curve().iter().map(|&(t, a)| Json::from_f64s(&[t, a])).collect()),
    ));
    kv.push((
        "records".to_string(),
        Json::Arr(res.records.iter().map(round_record_to_json).collect()),
    ));
    Json::Obj(kv)
}

// -- curve queries ----------------------------------------------------------

/// Simulated seconds until the eval curve first reaches `target` accuracy
/// (the paper's time-to-accuracy; works on stored records and live results
/// alike).
pub fn time_to_accuracy(records: &[RoundRecord], target: f64) -> Option<f64> {
    records
        .iter()
        .find(|r| r.eval_acc.map(|a| a >= target).unwrap_or(false))
        .map(|r| r.sim_time)
}

/// Simulated seconds until the eval curve first reaches `target`
/// perplexity (LM tasks; lower is better).
pub fn time_to_perplexity(records: &[RoundRecord], target: f64) -> Option<f64> {
    records
        .iter()
        .find(|r| r.eval_loss.map(|l| l.exp() <= target).unwrap_or(false))
        .map(|r| r.sim_time)
}

/// Simulated seconds until the eval curve first reaches `target` loss
/// (lower is better; perplexity targets are `target.ln()` here).
pub fn time_to_loss(records: &[RoundRecord], target: f64) -> Option<f64> {
    records
        .iter()
        .find(|r| r.eval_loss.map(|l| l <= target).unwrap_or(false))
        .map(|r| r.sim_time)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(round: usize, eval: Option<f64>) -> RoundRecord {
        RoundRecord {
            round,
            round_secs: 100.25 + round as f64,
            sim_time: 130.5 * (round + 1) as f64 + 0.1,
            mean_train_loss: 1.0 / (round + 1) as f64,
            participants: 3,
            mean_coverage: 0.625,
            o1: 0.037,
            eval_acc: eval,
            eval_loss: eval.map(|a| 1.0 - a),
            client_secs: vec![(0, 10.125), (2, 100.25 + round as f64)],
            mean_staleness: eval.map(|_| 1.0 / 3.0),
            max_staleness: eval.map(|_| 2.0),
            dropped: if round % 2 == 1 { vec![1, 4] } else { Vec::new() },
            spec_hits: if round % 3 == 2 { 5 } else { 0 },
            spec_misses: if round % 3 == 2 { 2 } else { 0 },
        }
    }

    fn assert_records_bitwise_eq(a: &RoundRecord, b: &RoundRecord) {
        assert_eq!(a.round, b.round);
        assert_eq!(a.round_secs.to_bits(), b.round_secs.to_bits());
        assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits());
        assert_eq!(a.mean_train_loss.to_bits(), b.mean_train_loss.to_bits());
        assert_eq!(a.participants, b.participants);
        assert_eq!(a.mean_coverage.to_bits(), b.mean_coverage.to_bits());
        assert_eq!(a.o1.to_bits(), b.o1.to_bits());
        assert_eq!(a.eval_acc.map(f64::to_bits), b.eval_acc.map(f64::to_bits));
        assert_eq!(a.eval_loss.map(f64::to_bits), b.eval_loss.map(f64::to_bits));
        assert_eq!(a.client_secs.len(), b.client_secs.len());
        for ((ca, ta), (cb, tb)) in a.client_secs.iter().zip(&b.client_secs) {
            assert_eq!(ca, cb);
            assert_eq!(ta.to_bits(), tb.to_bits());
        }
        assert_eq!(a.mean_staleness.map(f64::to_bits), b.mean_staleness.map(f64::to_bits));
        assert_eq!(a.max_staleness.map(f64::to_bits), b.max_staleness.map(f64::to_bits));
        assert_eq!(a.dropped, b.dropped);
        assert_eq!(a.spec_hits, b.spec_hits);
        assert_eq!(a.spec_misses, b.spec_misses);
    }

    #[test]
    fn dropped_clients_stay_out_of_churn_free_records() {
        let clean = round_record_to_json(&record(0, None));
        assert!(clean.get("dropped").is_none());
        let churned = round_record_to_json(&record(1, None));
        assert_eq!(churned.req("dropped").unwrap().to_f64_vec().unwrap(), vec![1.0, 4.0]);
    }

    #[test]
    fn speculation_counters_stay_out_of_serial_records() {
        let serial = round_record_to_json(&record(0, None));
        assert!(serial.get("spec_hits").is_none());
        assert!(serial.get("spec_misses").is_none());
        let speculative = round_record_to_json(&record(2, None));
        assert_eq!(speculative.u("spec_hits").unwrap(), 5);
        assert_eq!(speculative.u("spec_misses").unwrap(), 2);
        let back = round_record_from_json(&speculative).unwrap();
        assert_eq!((back.spec_hits, back.spec_misses), (5, 2));
    }

    #[test]
    fn round_record_round_trips_bitwise_through_text() {
        // Awkward f64s on purpose: round-trip exactness is what resume
        // determinism stands on.
        for r in [
            record(0, None),
            record(7, Some(0.1 + 0.2)),
            record(3, Some(1.0 / 3.0)),
        ] {
            let text = round_record_to_json(&r).to_string_pretty();
            let back = round_record_from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_records_bitwise_eq(&r, &back);
        }
    }

    fn manifest() -> RunManifest {
        RunManifest {
            schema_version: SCHEMA_VERSION,
            id: "fedel-s42".into(),
            created_unix: 1_700_000_000,
            updated_unix: 1_700_000_123,
            status: RunStatus::Running,
            strategy: "fedel".into(),
            config: ExperimentCfg { model: "mock:6x50".into(), ..Default::default() },
            records: vec![record(0, None), record(1, Some(0.5))],
            checkpoint: Some(Checkpoint {
                completed: 2,
                sim_time: 261.1,
                params: BlobRef {
                    digest: "sha256:00ff".into(),
                    size: 16,
                    media_type: crate::store::MEDIA_PARAMS_F32LE.into(),
                },
                params_chain: Vec::new(),
                policy_state: Json::obj(vec![("x", Json::from_f64s(&[1.5, -2.25]))]),
                async_state: Json::obj(vec![("mode", Json::Str("buffered".into()))]),
            }),
            final_state: None,
        }
    }

    #[test]
    fn manifest_round_trips() {
        let m = manifest();
        let text = m.to_json().to_string_pretty();
        let back = RunManifest::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.id, m.id);
        assert_eq!(back.status, RunStatus::Running);
        assert_eq!(back.strategy, "fedel");
        assert_eq!(back.config.model, "mock:6x50");
        assert_eq!(back.records.len(), 2);
        assert_records_bitwise_eq(&back.records[1], &m.records[1]);
        let ck = back.checkpoint.unwrap();
        assert_eq!(ck.completed, 2);
        assert_eq!(ck.params, m.checkpoint.as_ref().unwrap().params);
        assert_eq!(ck.policy_state, m.checkpoint.as_ref().unwrap().policy_state);
        assert_eq!(ck.async_state, m.checkpoint.as_ref().unwrap().async_state);
        assert!(back.final_state.is_none());
    }

    #[test]
    fn delta_checkpoint_chain_round_trips_and_defaults_empty() {
        let mut m = manifest();
        // full-vector checkpoints must not write the key at all (v≤3 shape)
        let j = m.to_json();
        let ck_json = j.req("checkpoint").unwrap();
        assert!(ck_json.get("params_chain").is_none());

        let base = BlobRef {
            digest: "sha256:aa".into(),
            size: 64,
            media_type: crate::store::MEDIA_PARAMS_F32LE.into(),
        };
        let mid = BlobRef {
            digest: "sha256:bb".into(),
            size: 40,
            media_type: crate::store::MEDIA_PARAMS_DELTA.into(),
        };
        m.checkpoint.as_mut().unwrap().params_chain = vec![base.clone(), mid.clone()];
        let text = m.to_json().to_string_pretty();
        let back = RunManifest::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.checkpoint.unwrap().params_chain, vec![base, mid]);
    }

    #[test]
    fn v1_manifests_without_async_keys_still_load() {
        let mut m = manifest();
        m.schema_version = 1;
        let mut j = m.to_json();
        // strip the v2-era keys the way a v1 writer would have
        if let Json::Obj(kv) = &mut j {
            for (key, val) in kv.iter_mut() {
                if key == "checkpoint" {
                    if let Json::Obj(ck) = val {
                        ck.retain(|(k, _)| k != "async_state");
                    }
                }
                if key == "records" {
                    if let Json::Arr(records) = val {
                        for r in records {
                            if let Json::Obj(fields) = r {
                                fields.retain(|(k, _)| {
                                    k != "mean_staleness" && k != "max_staleness"
                                });
                            }
                        }
                    }
                }
            }
        }
        let back = RunManifest::from_json(&Json::parse(&j.to_string_pretty()).unwrap()).unwrap();
        assert_eq!(back.schema_version, 1);
        assert_eq!(back.checkpoint.unwrap().async_state, Json::Null);
        assert!(back.records.iter().all(|r| r.mean_staleness.is_none()));
    }

    #[test]
    fn unknown_schema_version_rejected() {
        let mut m = manifest();
        m.schema_version = SCHEMA_VERSION + 1;
        let text = m.to_json().to_string_pretty();
        let err = RunManifest::from_json(&Json::parse(&text).unwrap()).unwrap_err();
        assert!(err.to_string().contains("schema"), "{err}");
    }

    #[test]
    fn final_acc_prefers_final_state_then_latest_eval() {
        let mut m = manifest();
        assert_eq!(m.final_acc(), Some(0.5));
        m.final_state = Some(FinalState {
            final_acc: 0.9,
            final_loss: 0.1,
            sim_total_secs: 1e4,
            params: m.checkpoint.as_ref().unwrap().params.clone(),
        });
        assert_eq!(m.final_acc(), Some(0.9));
        m.final_state = None;
        m.records.clear();
        assert_eq!(m.final_acc(), None);
    }

    #[test]
    fn campaign_manifest_round_trips() {
        let m = CampaignManifest {
            schema_version: CAMPAIGN_SCHEMA_VERSION,
            name: "sweep1".into(),
            created_unix: 1_700_000_000,
            updated_unix: 1_700_000_001,
            spec: Json::obj(vec![("strategies", Json::from_strs(&["fedavg", "fedel"]))]),
            cells: vec![
                CellState {
                    run_id: Some("fedavg-s1".into()),
                    ..CellState::unassigned("fedavg-s1".into())
                },
                CellState::unassigned("fedel-s1".into()),
            ],
        };
        let text = m.to_json().to_string_pretty();
        let back = CampaignManifest::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.name, "sweep1");
        assert_eq!(back.cells, m.cells);
        assert_eq!(back.spec, m.spec);

        let mut future = m.clone();
        future.schema_version = CAMPAIGN_SCHEMA_VERSION + 1;
        let err =
            CampaignManifest::from_json(&Json::parse(&future.to_json().to_string_pretty()).unwrap())
                .unwrap_err();
        assert!(err.to_string().contains("schema"), "{err}");
    }

    #[test]
    fn campaign_manifest_accepts_v1_rejects_future() {
        let m = CampaignManifest {
            schema_version: 1,
            name: "old".into(),
            created_unix: 0,
            updated_unix: 0,
            spec: Json::obj(vec![("strategies", Json::from_strs(&["fedavg"]))]),
            cells: vec![CellState::unassigned("fedavg-s1-fsmall10-t1".into())],
        };
        let back = CampaignManifest::from_json(&Json::parse(&m.to_json().to_string_pretty()).unwrap())
            .unwrap();
        assert_eq!(back.schema_version, 1, "v1 loads unmodified; migration is the runner's job");
    }

    #[test]
    fn cell_lease_fields_round_trip_and_stay_out_of_unleased_cells() {
        // Unleased, unpruned cells must keep their pre-v3 serialization
        // byte for byte (worker/lease_unix/pruned omit-at-default).
        let plain = CellState::unassigned("strategy=fedavg,seed=1".into());
        let text = plain.to_json().to_string_pretty();
        assert!(!text.contains("worker"), "unleased cell leaks lease key: {text}");
        assert!(!text.contains("lease_unix"), "unleased cell leaks lease key: {text}");
        assert!(!text.contains("pruned"), "unpruned cell leaks pruned key: {text}");
        assert_eq!(CellState::from_json(&Json::parse(&text).unwrap()).unwrap(), plain);

        let leased = CellState {
            run_id: Some("fedavg-s1".into()),
            worker: Some("host1:1234".into()),
            lease_unix: 1_700_000_000,
            pruned: true,
            ..CellState::unassigned("strategy=fedavg,seed=1".into())
        };
        let back =
            CellState::from_json(&Json::parse(&leased.to_json().to_string_pretty()).unwrap())
                .unwrap();
        assert_eq!(back, leased);
        assert_eq!(back.lease_age_secs(1_700_000_030), Some(30));
        assert_eq!(plain.lease_age_secs(1_700_000_030), None, "unleased cells have no lease age");
    }

    #[test]
    fn time_to_loss_walks_the_loss_curve() {
        // record() sets eval_loss = 1.0 - eval_acc
        let records =
            vec![record(0, None), record(1, Some(0.4)), record(2, Some(0.6)), record(3, Some(0.7))];
        assert_eq!(time_to_loss(&records, 0.45), Some(records[2].sim_time));
        assert_eq!(time_to_loss(&records, 0.05), None);
        assert_eq!(time_to_loss(&records, 0.6), Some(records[1].sim_time));
    }

    #[test]
    fn time_to_accuracy_walks_the_curve() {
        let records =
            vec![record(0, None), record(1, Some(0.4)), record(2, Some(0.6)), record(3, Some(0.7))];
        assert_eq!(time_to_accuracy(&records, 0.5), Some(records[2].sim_time));
        assert_eq!(time_to_accuracy(&records, 0.9), None);
        assert_eq!(time_to_accuracy(&records, 0.0), Some(records[1].sim_time));
    }
}
