//! Minimal HTTP/1.1 over `std::net` — just enough protocol for the store
//! server ([`super::serve`]) and client ([`super::remote`]) to speak the
//! OCI-registry-style routes, in the spirit of the repo's hand-rolled
//! `util/sha256.rs` (the offline registry has no hyper/reqwest).
//!
//! Deliberate simplifications, safe because we own both ends:
//! * `Content-Length` framing only — no chunked transfer encoding;
//! * one request per connection (`Connection: close` always);
//! * headers are ASCII, matched case-insensitively, size-capped.

use std::io::{BufRead, Read, Write};

/// Largest accepted header section; a line beyond this is a protocol error,
/// not a buffer to grow.
const MAX_HEADER_BYTES: usize = 64 * 1024;

/// Largest accepted body (1 GiB): parameter blobs for the models this repo
/// simulates are far below this, and a cap turns a corrupt length into a
/// loud error instead of an allocation bomb.
const MAX_BODY_BYTES: u64 = 1 << 30;

/// A parsed request (server side) — method, origin-form target, headers,
/// and a fully-read body.
pub struct Request {
    pub method: String,
    pub target: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// The target's path component (before `?`).
    pub fn path(&self) -> &str {
        self.target.split('?').next().unwrap_or("")
    }

    /// The raw query string, if any.
    pub fn query(&self) -> Option<&str> {
        self.target.split_once('?').map(|(_, q)| q)
    }

    /// First value of a `key=value` query parameter, percent-decoded.
    pub fn query_param(&self, key: &str) -> Option<String> {
        self.query()?
            .split('&')
            .filter_map(|kv| kv.split_once('='))
            .find(|(k, _)| *k == key)
            .map(|(_, v)| percent_decode(v))
    }

    pub fn header(&self, name: &str) -> Option<&str> {
        header_get(&self.headers, name)
    }
}

/// A response, built server-side or parsed client-side.
pub struct Response {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Response {
    pub fn new(status: u16) -> Response {
        Response { status, headers: Vec::new(), body: Vec::new() }
    }

    pub fn with_header(mut self, name: &str, value: &str) -> Response {
        self.headers.push((name.to_string(), value.to_string()));
        self
    }

    pub fn with_body(mut self, body: Vec<u8>, content_type: &str) -> Response {
        self.headers.push(("Content-Type".to_string(), content_type.to_string()));
        self.body = body;
        self
    }

    pub fn json(status: u16, j: &crate::util::json::Json) -> Response {
        Response::new(status).with_body(j.to_string().into_bytes(), "application/json")
    }

    pub fn header(&self, name: &str) -> Option<&str> {
        header_get(&self.headers, name)
    }

    pub fn ok(&self) -> bool {
        (200..300).contains(&self.status)
    }
}

fn header_get<'a>(headers: &'a [(String, String)], name: &str) -> Option<&'a str> {
    headers
        .iter()
        .find(|(k, _)| k.eq_ignore_ascii_case(name))
        .map(|(_, v)| v.as_str())
}

/// Decode `%XX` escapes (and `+` as space) — run ids and strategy names
/// are plain tokens, but the client encodes defensively.
pub fn percent_decode(s: &str) -> String {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'%' => {
                let hex = bytes.get(i + 1..i + 3).and_then(|h| {
                    u8::from_str_radix(std::str::from_utf8(h).ok()?, 16).ok()
                });
                match hex {
                    Some(b) => {
                        out.push(b);
                        i += 3;
                    }
                    None => {
                        out.push(b'%');
                        i += 1;
                    }
                }
            }
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            b => {
                out.push(b);
                i += 1;
            }
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

/// Encode a path/query segment conservatively: everything outside the
/// unreserved set is `%XX`-escaped.
pub fn percent_encode(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for b in s.bytes() {
        match b {
            b'A'..=b'Z' | b'a'..=b'z' | b'0'..=b'9' | b'-' | b'_' | b'.' | b'~' | b':' => {
                out.push(b as char)
            }
            _ => out.push_str(&format!("%{b:02X}")),
        }
    }
    out
}

fn read_line_capped(r: &mut impl BufRead, budget: &mut usize) -> std::io::Result<String> {
    let mut line = Vec::new();
    loop {
        let mut byte = [0u8; 1];
        r.read_exact(&mut byte)?;
        if byte[0] == b'\n' {
            break;
        }
        line.push(byte[0]);
        *budget = budget.checked_sub(1).ok_or_else(|| {
            std::io::Error::new(std::io::ErrorKind::InvalidData, "header section too large")
        })?;
    }
    if line.last() == Some(&b'\r') {
        line.pop();
    }
    String::from_utf8(line)
        .map_err(|_| std::io::Error::new(std::io::ErrorKind::InvalidData, "non-UTF-8 header"))
}

fn read_headers(
    r: &mut impl BufRead,
    budget: &mut usize,
) -> std::io::Result<Vec<(String, String)>> {
    let mut headers = Vec::new();
    loop {
        let line = read_line_capped(r, budget)?;
        if line.is_empty() {
            return Ok(headers);
        }
        if let Some((k, v)) = line.split_once(':') {
            headers.push((k.trim().to_string(), v.trim().to_string()));
        }
    }
}

fn read_body(
    r: &mut impl BufRead,
    headers: &[(String, String)],
) -> std::io::Result<Vec<u8>> {
    let len: u64 = match header_get(headers, "Content-Length") {
        Some(v) => v.parse().map_err(|_| {
            std::io::Error::new(std::io::ErrorKind::InvalidData, "bad Content-Length")
        })?,
        None => 0,
    };
    if len > MAX_BODY_BYTES {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("body of {len} bytes exceeds the {MAX_BODY_BYTES}-byte cap"),
        ));
    }
    let mut body = vec![0u8; len as usize];
    r.read_exact(&mut body)?;
    Ok(body)
}

/// Read one request. `Ok(None)` means the peer closed cleanly before
/// sending anything (a health probe, the shutdown self-connect).
pub fn read_request(r: &mut impl BufRead) -> std::io::Result<Option<Request>> {
    let mut budget = MAX_HEADER_BYTES;
    let start = match read_line_capped(r, &mut budget) {
        Ok(line) => line,
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e),
    };
    let mut parts = start.split_whitespace();
    let (method, target) = match (parts.next(), parts.next()) {
        (Some(m), Some(t)) => (m.to_string(), t.to_string()),
        _ => {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("malformed request line {start:?}"),
            ))
        }
    };
    let headers = read_headers(r, &mut budget)?;
    let body = read_body(r, &headers)?;
    Ok(Some(Request { method, target, headers, body }))
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        201 => "Created",
        202 => "Accepted",
        204 => "No Content",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        412 => "Precondition Failed",
        416 => "Range Not Satisfiable",
        500 => "Internal Server Error",
        _ => "",
    }
}

/// Serialize a response; always closes the framing with `Connection: close`
/// and an explicit `Content-Length`.
pub fn write_response(w: &mut impl Write, resp: &Response) -> std::io::Result<()> {
    write!(w, "HTTP/1.1 {} {}\r\n", resp.status, reason(resp.status))?;
    for (k, v) in &resp.headers {
        write!(w, "{k}: {v}\r\n")?;
    }
    write!(w, "Content-Length: {}\r\nConnection: close\r\n\r\n", resp.body.len())?;
    w.write_all(&resp.body)?;
    w.flush()
}

/// Serialize a request (client side).
pub fn write_request(
    w: &mut impl Write,
    method: &str,
    target: &str,
    host: &str,
    headers: &[(String, String)],
    body: &[u8],
) -> std::io::Result<()> {
    write!(w, "{method} {target} HTTP/1.1\r\nHost: {host}\r\n")?;
    for (k, v) in headers {
        write!(w, "{k}: {v}\r\n")?;
    }
    write!(w, "Content-Length: {}\r\nConnection: close\r\n\r\n", body.len())?;
    w.write_all(body)?;
    w.flush()
}

/// Read a response (client side). `head_only` skips the body read for
/// HEAD requests, whose `Content-Length` describes the entity, not the
/// (empty) wire body.
pub fn read_response(r: &mut impl BufRead, head_only: bool) -> std::io::Result<Response> {
    let mut budget = MAX_HEADER_BYTES;
    let start = read_line_capped(r, &mut budget)?;
    let status: u16 = start
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| {
            std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("malformed status line {start:?}"),
            )
        })?;
    let headers = read_headers(r, &mut budget)?;
    let body = if head_only || status == 204 { Vec::new() } else { read_body(r, &headers)? };
    Ok(Response { status, headers, body })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_round_trips_through_the_wire_format() {
        let mut wire = Vec::new();
        write_request(
            &mut wire,
            "PUT",
            "/v2/runs/manifests/a-s1?x=1",
            "localhost",
            &[("If-Match".into(), "\"sha256:ab\"".into())],
            b"{\"k\":1}",
        )
        .unwrap();
        let req = read_request(&mut std::io::BufReader::new(&wire[..])).unwrap().unwrap();
        assert_eq!(req.method, "PUT");
        assert_eq!(req.path(), "/v2/runs/manifests/a-s1");
        assert_eq!(req.query_param("x").as_deref(), Some("1"));
        assert_eq!(req.header("if-match"), Some("\"sha256:ab\""));
        assert_eq!(req.body, b"{\"k\":1}");
    }

    #[test]
    fn response_round_trips_and_eof_is_clean_none() {
        let mut wire = Vec::new();
        write_response(
            &mut wire,
            &Response::new(201).with_header("ETag", "\"sha256:cd\""),
        )
        .unwrap();
        let resp = read_response(&mut std::io::BufReader::new(&wire[..]), false).unwrap();
        assert_eq!(resp.status, 201);
        assert_eq!(resp.header("etag"), Some("\"sha256:cd\""));
        assert!(resp.ok());
        // a silent close before any bytes is not an error
        let none = read_request(&mut std::io::BufReader::new(&b""[..])).unwrap();
        assert!(none.is_none());
    }

    #[test]
    fn percent_coding_round_trips() {
        for s in ["plain", "with space", "a/b?c=d", "sha256:abc", "100%"] {
            assert_eq!(percent_decode(&percent_encode(s)), s, "{s:?}");
        }
    }
}
