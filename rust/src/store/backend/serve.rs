//! `fedel runs serve` — the store as an OCI-registry-style HTTP service.
//!
//! A [`StoreServer`] wraps a [`LocalBackend`] directory and exposes it
//! over hand-rolled HTTP/1.1 ([`super::http`]) so campaign workers on
//! other machines can read and write it through
//! [`super::remote::RemoteBackend`]. The route shapes follow the OCI
//! distribution spec (the store's blob/manifest model already matches its
//! descriptor idiom):
//!
//! ```text
//! GET  /v2/                                      liveness ping
//! GET|HEAD /v2/runs/blobs/sha256:<hex>           content-addressed blob
//! POST /v2/runs/blobs/uploads/                   open a resumable upload
//! GET  /v2/runs/blobs/uploads/<sid>              upload offset (resume)
//! PATCH /v2/runs/blobs/uploads/<sid>             append a chunk
//! PUT  /v2/runs/blobs/uploads/<sid>?digest=...   verify + publish
//! GET|HEAD|PUT /v2/runs/manifests/<id>           run manifest bytes
//! GET  /v2/runs/tags/list                        run ids
//! POST /v2/runs/ids?strategy=<s>&seed=<n>        allocate a fresh run id
//! GET|HEAD|PUT /v2/campaigns/manifests/<name>    campaign manifest; GET
//!                                                carries an ETag, PUT
//!                                                honors If-Match /
//!                                                If-None-Match (CAS)
//! GET  /v2/campaigns/tags/list                   campaign names
//! ```
//!
//! Concurrency: requests are served by a small thread pool, and every
//! mutation goes through the same [`LocalBackend`] primitives local
//! writers use — the lockfile and the atomic tmp+rename publishes
//! serialize remote and local writers identically, so a served store can
//! simultaneously be used as a plain `--store <dir>` on its host.
//!
//! Upload sessions live under `<root>/.uploads/<sid>` and are appended by
//! `PATCH` with strictly sequential `Content-Range`s; a commit (`PUT`)
//! verifies the digest server-side before publishing, so a torn or
//! corrupted upload can never become a blob. Sessions abandoned before
//! commit (a crashed worker mid-upload) are garbage-collected lazily:
//! opening a new session sweeps any session file untouched for longer
//! than the server's upload max-age ([`DEFAULT_UPLOAD_MAX_AGE`], or
//! `--upload-gc-secs` on the CLI), so orphans can never accumulate
//! unboundedly while live uploads — which append continuously — survive.

use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use crate::util::json::Json;
use crate::util::sha256;

use super::http::{read_request, write_response, Request, Response};
use super::{LocalBackend, StoreBackend};

/// Per-connection socket timeout: a wedged peer must not pin a worker.
const IO_TIMEOUT: Duration = Duration::from_secs(30);

/// Default age after which an uncommitted upload session counts as
/// abandoned and is swept ([`StoreServer::start_with_upload_gc`] to
/// override). Generous next to the 30s socket timeout: a client retrying
/// a resumable upload across several dropped connections keeps its
/// session as long as any chunk lands within the window.
pub const DEFAULT_UPLOAD_MAX_AGE: Duration = Duration::from_secs(15 * 60);

/// A running store server; shut down (and joined) via
/// [`StoreServer::shutdown`], or detached for the lifetime of the process
/// with [`StoreServer::serve_forever`].
pub struct StoreServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl StoreServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) and serve
    /// the store rooted at `root` on `threads` worker threads, sweeping
    /// abandoned upload sessions after [`DEFAULT_UPLOAD_MAX_AGE`].
    pub fn start(
        root: impl Into<PathBuf>,
        addr: &str,
        threads: usize,
    ) -> anyhow::Result<StoreServer> {
        StoreServer::start_with_upload_gc(root, addr, threads, DEFAULT_UPLOAD_MAX_AGE)
    }

    /// [`StoreServer::start`] with an explicit upload-session max-age:
    /// sessions whose file hasn't been touched for `upload_max_age` are
    /// swept the next time any upload opens (`--upload-gc-secs`).
    pub fn start_with_upload_gc(
        root: impl Into<PathBuf>,
        addr: &str,
        threads: usize,
        upload_max_age: Duration,
    ) -> anyhow::Result<StoreServer> {
        let backend = Arc::new(LocalBackend::open(root)?);
        let listener =
            TcpListener::bind(addr).map_err(|e| anyhow::anyhow!("bind {addr}: {e}"))?;
        let bound = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let (tx, rx) = mpsc::channel::<TcpStream>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads.max(1))
            .map(|_| {
                let rx = Arc::clone(&rx);
                let backend = Arc::clone(&backend);
                std::thread::spawn(move || loop {
                    let stream = match rx.lock().expect("server queue poisoned").recv() {
                        Ok(s) => s,
                        Err(_) => return, // channel closed: shutdown
                    };
                    serve_connection(stream, &backend, upload_max_age);
                })
            })
            .collect();
        let accept_stop = Arc::clone(&stop);
        let accept_thread = std::thread::spawn(move || {
            for stream in listener.incoming() {
                if accept_stop.load(Ordering::SeqCst) {
                    return; // drops tx: workers drain and exit
                }
                if let Ok(s) = stream {
                    let _ = tx.send(s);
                }
            }
        });
        Ok(StoreServer { addr: bound, stop, accept_thread: Some(accept_thread), workers })
    }

    /// The bound address (resolves `:0` to the actual ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, drain in-flight requests, join every thread.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Unblock the accept loop with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }

    /// Block the calling thread for the server's lifetime (the CLI path).
    /// Only returns if the accept loop dies, which is fatal.
    pub fn serve_forever(mut self) -> anyhow::Result<()> {
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        anyhow::bail!("store server accept loop exited unexpectedly")
    }
}

fn serve_connection(stream: TcpStream, backend: &LocalBackend, upload_max_age: Duration) {
    let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let req = match read_request(&mut reader) {
        Ok(Some(req)) => req,
        Ok(None) | Err(_) => return, // probe/shutdown connect or torn request
    };
    let resp = handle(&req, backend, upload_max_age)
        .unwrap_or_else(|e| error_response(500, &format!("internal error: {e:#}")));
    let mut w = stream;
    let _ = write_response(&mut w, &resp);
    let _ = w.flush();
}

fn error_response(status: u16, msg: &str) -> Response {
    Response::json(status, &Json::obj(vec![("error", Json::Str(msg.to_string()))]))
}

/// A path segment a client may name: run ids, campaign names, session ids.
/// The charset matches the store's campaign-name rule and forbids
/// traversal by construction.
fn valid_segment(s: &str) -> bool {
    !s.is_empty()
        && s != "."
        && s != ".."
        && s.chars().all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.'))
}

/// `sha256:<64 lowercase hex>` → the hex part.
fn parse_digest(s: &str) -> Option<&str> {
    let hex = s.strip_prefix("sha256:")?;
    (hex.len() == 64 && hex.bytes().all(|b| b.is_ascii_hexdigit() && !b.is_ascii_uppercase()))
        .then_some(hex)
}

fn handle(
    req: &Request,
    backend: &LocalBackend,
    upload_max_age: Duration,
) -> anyhow::Result<Response> {
    let segments: Vec<&str> =
        req.path().split('/').filter(|s| !s.is_empty()).collect();
    match segments.as_slice() {
        ["v2"] => Ok(Response::json(200, &Json::obj(vec![]))),
        ["v2", "runs", "blobs", "uploads"] => {
            handle_upload_open(req, backend, upload_max_age)
        }
        ["v2", "runs", "blobs", "uploads", sid] => handle_upload_session(req, backend, sid),
        ["v2", repo @ ("runs" | "campaigns"), "blobs", digest] => {
            handle_blob(req, backend, repo, digest)
        }
        ["v2", "runs", "manifests", id] => handle_run_manifest(req, backend, id),
        ["v2", "campaigns", "manifests", name] => handle_campaign_manifest(req, backend, name),
        ["v2", repo @ ("runs" | "campaigns"), "tags", "list"] => {
            handle_tags(req, backend, repo)
        }
        ["v2", "runs", "ids"] => handle_fresh_id(req, backend),
        _ => Ok(error_response(404, &format!("no route for {}", req.path()))),
    }
}

// -- blobs -------------------------------------------------------------------

fn handle_blob(
    req: &Request,
    backend: &LocalBackend,
    _repo: &str,
    digest: &str,
) -> anyhow::Result<Response> {
    let Some(hex) = parse_digest(digest) else {
        return Ok(error_response(400, &format!("malformed digest {digest:?}")));
    };
    let Some(size) = backend.head_blob(hex)? else {
        return Ok(error_response(404, &format!("blob {digest} not found")));
    };
    match req.method.as_str() {
        "HEAD" => Ok(Response::new(200)
            .with_header("Docker-Content-Digest", digest)
            .with_header("Content-Length", &size.to_string())),
        "GET" => Ok(Response::new(200)
            .with_header("Docker-Content-Digest", digest)
            .with_body(backend.get_blob(hex)?, "application/octet-stream")),
        m => Ok(error_response(405, &format!("{m} not allowed on blobs"))),
    }
}

// -- resumable uploads -------------------------------------------------------

fn uploads_dir(backend: &LocalBackend) -> PathBuf {
    backend.root().join(".uploads")
}

fn session_path(backend: &LocalBackend, sid: &str) -> PathBuf {
    uploads_dir(backend).join(sid)
}

/// Sweep upload sessions untouched for `max_age` — abandoned by crashed
/// or wandered-off clients. Runs under the open path (the only place new
/// session files appear), so a server with no upload traffic pays
/// nothing. Best-effort on purpose: an unreadable mtime or a future
/// timestamp (clock skew) counts as young — never guess toward deletion —
/// and a racing `remove_file` failure is ignored (the next open retries).
fn sweep_stale_uploads(dir: &std::path::Path, max_age: Duration) {
    let Ok(entries) = std::fs::read_dir(dir) else {
        return; // no uploads dir yet: nothing to sweep
    };
    for entry in entries.flatten() {
        let stale = entry
            .metadata()
            .ok()
            .and_then(|m| m.modified().ok())
            .and_then(|t| t.elapsed().ok())
            .map(|age| age >= max_age)
            .unwrap_or(false);
        if stale {
            let _ = std::fs::remove_file(entry.path());
        }
    }
}

fn handle_upload_open(
    req: &Request,
    backend: &LocalBackend,
    upload_max_age: Duration,
) -> anyhow::Result<Response> {
    if req.method != "POST" {
        return Ok(error_response(405, "uploads open with POST"));
    }
    static SESSION: AtomicU64 = AtomicU64::new(0);
    let sid = format!(
        "u{}-{}",
        std::process::id(),
        SESSION.fetch_add(1, Ordering::Relaxed)
    );
    let dir = uploads_dir(backend);
    sweep_stale_uploads(&dir, upload_max_age);
    std::fs::create_dir_all(&dir).map_err(|e| anyhow::anyhow!("create {dir:?}: {e}"))?;
    std::fs::write(session_path(backend, &sid), b"")?;
    Ok(Response::new(202)
        .with_header("Location", &format!("/v2/runs/blobs/uploads/{sid}"))
        .with_header("Range", "0-0"))
}

/// `Range: 0-<end>` / `Content-Range: <start>-<end>` use inclusive byte
/// indexes; a session holding N bytes reports end = N-1 (no Range header
/// at all when empty, which clients read as offset 0).
fn range_header(resp: Response, size: u64) -> Response {
    if size == 0 {
        resp
    } else {
        resp.with_header("Range", &format!("0-{}", size - 1))
    }
}

fn handle_upload_session(
    req: &Request,
    backend: &LocalBackend,
    sid: &str,
) -> anyhow::Result<Response> {
    if !valid_segment(sid) {
        return Ok(error_response(400, &format!("malformed upload session {sid:?}")));
    }
    let path = session_path(backend, sid);
    let size = match std::fs::metadata(&path) {
        Ok(m) => m.len(),
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok(error_response(404, &format!("no upload session {sid:?}")))
        }
        Err(e) => return Err(anyhow::anyhow!("stat {path:?}: {e}")),
    };
    match req.method.as_str() {
        // Offset query — the client's resume point after a dropped chunk.
        "GET" => Ok(range_header(Response::new(204), size)),
        "PATCH" => {
            // Strictly sequential appends: the declared start must equal
            // the bytes already landed, or the client is told the real
            // offset (416 + Range) and resumes from there.
            let declared = req
                .header("Content-Range")
                .and_then(|r| r.split('-').next())
                .and_then(|s| s.trim().parse::<u64>().ok());
            match declared {
                Some(start) if start == size => {}
                _ => return Ok(range_header(Response::new(416), size)),
            }
            let mut f = std::fs::OpenOptions::new().append(true).open(&path)?;
            f.write_all(&req.body)?;
            f.flush()?;
            Ok(range_header(Response::new(202), size + req.body.len() as u64))
        }
        "PUT" => {
            // Commit: optional final body chunk, then digest-verify the
            // whole session before publishing. A mismatch discards the
            // session — the server never stores unverified bytes.
            let Some(digest) = req.query_param("digest") else {
                return Ok(error_response(400, "commit needs ?digest=sha256:<hex>"));
            };
            let Some(hex) = parse_digest(&digest) else {
                return Ok(error_response(400, &format!("malformed digest {digest:?}")));
            };
            if !req.body.is_empty() {
                let mut f = std::fs::OpenOptions::new().append(true).open(&path)?;
                f.write_all(&req.body)?;
                f.flush()?;
            }
            let bytes = std::fs::read(&path)?;
            if sha256::hex(&bytes) != hex {
                let _ = std::fs::remove_file(&path);
                return Ok(error_response(
                    400,
                    &format!("upload does not match digest {digest} ({} bytes)", bytes.len()),
                ));
            }
            backend.put_blob(hex, &bytes)?;
            let _ = std::fs::remove_file(&path);
            Ok(Response::new(201)
                .with_header("Docker-Content-Digest", &digest)
                .with_header("Location", &format!("/v2/runs/blobs/{digest}")))
        }
        m => Ok(error_response(405, &format!("{m} not allowed on upload sessions"))),
    }
}

// -- manifests ---------------------------------------------------------------

fn handle_run_manifest(
    req: &Request,
    backend: &LocalBackend,
    id: &str,
) -> anyhow::Result<Response> {
    if !valid_segment(id) {
        return Ok(error_response(400, &format!("malformed run id {id:?}")));
    }
    match req.method.as_str() {
        "GET" | "HEAD" => match backend.load_manifest(id) {
            Ok(bytes) => {
                let mut resp = Response::new(200)
                    .with_header("Docker-Content-Digest", &super::content_digest(&bytes));
                if req.method == "GET" {
                    resp = resp.with_body(bytes, "application/json");
                }
                Ok(resp)
            }
            Err(_) => Ok(error_response(404, &format!("no stored run {id:?}"))),
        },
        "PUT" => {
            backend.save_manifest(id, &req.body)?;
            Ok(Response::new(201))
        }
        m => Ok(error_response(405, &format!("{m} not allowed on run manifests"))),
    }
}

fn etag(digest: &str) -> String {
    format!("\"{digest}\"")
}

fn handle_campaign_manifest(
    req: &Request,
    backend: &LocalBackend,
    name: &str,
) -> anyhow::Result<Response> {
    if !valid_segment(name) {
        return Ok(error_response(400, &format!("malformed campaign name {name:?}")));
    }
    match req.method.as_str() {
        "GET" | "HEAD" => match backend.load_campaign(name)? {
            Some((bytes, digest)) => {
                let mut resp = Response::new(200).with_header("ETag", &etag(&digest));
                if req.method == "GET" {
                    resp = resp.with_body(bytes, "application/json");
                }
                Ok(resp)
            }
            None => Ok(error_response(404, &format!("no stored campaign {name:?}"))),
        },
        "PUT" => {
            // Conditional PUT is the wire form of the CAS primitive:
            // If-Match pins the stored digest, If-None-Match: * requires
            // absence, neither means unconditional.
            let if_match = req
                .header("If-Match")
                .map(|t| t.trim().trim_start_matches("W/").trim_matches('"'));
            let if_none = req.header("If-None-Match").map(str::trim);
            let expect = match (if_match, if_none) {
                (Some(_), Some(_)) => {
                    return Ok(error_response(400, "If-Match and If-None-Match conflict"))
                }
                (Some(d), None) => super::CasExpect::Digest(d),
                (None, Some("*")) => super::CasExpect::Absent,
                (None, Some(other)) => {
                    return Ok(error_response(
                        400,
                        &format!("If-None-Match only supports *, got {other:?}"),
                    ))
                }
                (None, None) => super::CasExpect::Any,
            };
            match backend.save_campaign(name, &req.body, expect)? {
                super::CasOutcome::Committed(digest) => {
                    Ok(Response::new(201).with_header("ETag", &etag(&digest)))
                }
                super::CasOutcome::Conflict => {
                    let current = backend
                        .load_campaign(name)?
                        .map(|(_, d)| d)
                        .unwrap_or_else(|| "absent".to_string());
                    Ok(error_response(412, &format!("precondition failed; stored: {current}")))
                }
            }
        }
        m => Ok(error_response(405, &format!("{m} not allowed on campaign manifests"))),
    }
}

fn handle_tags(req: &Request, backend: &LocalBackend, repo: &str) -> anyhow::Result<Response> {
    if req.method != "GET" {
        return Ok(error_response(405, "tags list with GET"));
    }
    let mut tags =
        if repo == "runs" { backend.list_runs()? } else { backend.list_campaigns()? };
    tags.sort();
    Ok(Response::json(
        200,
        &Json::obj(vec![
            ("name", Json::Str(repo.to_string())),
            ("tags", Json::Arr(tags.into_iter().map(Json::Str).collect())),
        ]),
    ))
}

fn handle_fresh_id(req: &Request, backend: &LocalBackend) -> anyhow::Result<Response> {
    if req.method != "POST" {
        return Ok(error_response(405, "id allocation with POST"));
    }
    let Some(strategy) = req.query_param("strategy").filter(|s| valid_segment(s)) else {
        return Ok(error_response(400, "id allocation needs ?strategy=<name>&seed=<n>"));
    };
    let Some(seed) = req.query_param("seed").and_then(|s| s.parse::<u64>().ok()) else {
        return Ok(error_response(400, "id allocation needs a numeric ?seed="));
    };
    let id = backend.fresh_run_id(&strategy, seed)?;
    Ok(Response::json(201, &Json::obj(vec![("id", Json::Str(id))])))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn segments_and_digests_are_validated() {
        assert!(valid_segment("fedavg-s1.2"));
        assert!(!valid_segment(".."));
        assert!(!valid_segment("a/b"));
        assert!(!valid_segment(""));
        let hex = sha256::hex(b"x");
        assert_eq!(parse_digest(&format!("sha256:{hex}")), Some(hex.as_str()));
        assert_eq!(parse_digest("sha256:short"), None);
        assert_eq!(parse_digest("md5:abcd"), None);
        assert_eq!(parse_digest(&format!("sha256:{}", hex.to_uppercase())), None);
    }
}
