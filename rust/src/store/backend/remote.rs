//! [`RemoteBackend`]: the HTTP client side of the distributed store.
//!
//! Opens whenever a `--store` value starts with `http://` — every CLI
//! path that accepts a store directory transparently works against a
//! `fedel runs serve` instance instead. Design points:
//!
//! * **One connection per request** (`Connection: close`): dead simple,
//!   and the request volume (a manifest every few rounds, a params blob
//!   per checkpoint) is nowhere near where keep-alive matters.
//! * **Bounded retry with exponential backoff** on transient failures
//!   (connect/IO errors, 5xx): campaigns survive a briefly unreachable
//!   server. Only idempotent requests are blindly retried; chunk uploads
//!   resume instead (below).
//! * **Digest verification on every pull.** A blob is only accepted — and
//!   only enters the local cache — after its sha256 matches the address
//!   it was requested under. A corrupted wire byte reads as a transient
//!   error and retries.
//! * **Resumable uploads.** Blobs push through OCI-style upload sessions
//!   (`POST` open, `PATCH` chunks, `PUT` digest-verified commit); after a
//!   dropped connection the client asks the session for its landed offset
//!   and continues from there, re-opening the session only if it is gone.
//! * **Read-through blob cache.** Blobs are immutable by digest, so a
//!   verified pull is cached on local disk forever and never invalidated;
//!   repeated resumes of a remote campaign pull each params blob once.

use std::io::BufReader;
use std::net::{TcpStream, ToSocketAddrs};
use std::path::PathBuf;
use std::time::Duration;

use crate::util::sha256;

use super::http::{percent_encode, read_response, write_request, Response};
use super::{content_digest, write_atomic, CasExpect, CasOutcome, LocalBackend, StoreBackend};

const CONNECT_TIMEOUT: Duration = Duration::from_secs(3);
const IO_TIMEOUT: Duration = Duration::from_secs(30);
/// Transient-failure retry budget (per logical operation).
const RETRIES: usize = 4;
/// First backoff step; doubles per attempt (50, 100, 200, 400 ms).
const BACKOFF: Duration = Duration::from_millis(50);
/// Upload chunk size: small enough that a dropped connection loses little
/// progress, large enough that per-chunk overhead is noise.
const CHUNK: usize = 256 * 1024;

/// Where pulled blobs are cached (content-addressed, shared by every
/// remote store this machine talks to — digests can't collide across
/// servers). Overridable via `FEDEL_CACHE_DIR`.
pub fn default_cache_dir() -> PathBuf {
    match std::env::var_os("FEDEL_CACHE_DIR") {
        Some(dir) => PathBuf::from(dir),
        None => std::env::temp_dir().join("fedel-blob-cache"),
    }
}

/// An error classified for the retry loop: transient failures (connect
/// refused, torn connection, 5xx, digest mismatch on a pull) retry with
/// backoff; permanent ones (404, 4xx) surface immediately.
enum RemoteError {
    Transient(anyhow::Error),
    Permanent(anyhow::Error),
}

fn transient(e: impl Into<anyhow::Error>) -> RemoteError {
    RemoteError::Transient(e.into())
}

fn status_error(what: &str, resp: &Response) -> RemoteError {
    let detail = String::from_utf8_lossy(&resp.body).into_owned();
    let e = anyhow::anyhow!("{what}: HTTP {} {detail}", resp.status);
    if resp.status >= 500 {
        RemoteError::Transient(e)
    } else {
        RemoteError::Permanent(e)
    }
}

pub struct RemoteBackend {
    /// `host:port` — the connect target and `Host` header.
    host: String,
    cache: PathBuf,
}

impl RemoteBackend {
    /// `url` is `http://host:port` (no path; TLS is out of scope for a
    /// lab-network store). The connection is lazy — constructing a backend
    /// never touches the network.
    pub fn new(url: &str) -> anyhow::Result<RemoteBackend> {
        let rest = url
            .strip_prefix("http://")
            .ok_or_else(|| anyhow::anyhow!("remote store url must start with http://, got {url:?}"))?;
        let host = rest.trim_end_matches('/');
        anyhow::ensure!(
            !host.is_empty() && !host.contains('/'),
            "remote store url must be http://host:port with no path, got {url:?}"
        );
        Ok(RemoteBackend { host: host.to_string(), cache: default_cache_dir() })
    }

    fn cache_path(&self, hex: &str) -> PathBuf {
        self.cache.join(hex)
    }

    /// One request over a fresh connection. IO failure anywhere —
    /// connect, send, or a torn response — is transient.
    fn request(
        &self,
        method: &str,
        target: &str,
        headers: &[(String, String)],
        body: &[u8],
    ) -> Result<Response, RemoteError> {
        let addr = self
            .host
            .to_socket_addrs()
            .map_err(transient)?
            .next()
            .ok_or_else(|| RemoteError::Permanent(anyhow::anyhow!("{} resolves to nothing", self.host)))?;
        let stream = TcpStream::connect_timeout(&addr, CONNECT_TIMEOUT).map_err(transient)?;
        stream.set_read_timeout(Some(IO_TIMEOUT)).map_err(transient)?;
        stream.set_write_timeout(Some(IO_TIMEOUT)).map_err(transient)?;
        let mut w = stream.try_clone().map_err(transient)?;
        write_request(&mut w, method, target, &self.host, headers, body).map_err(transient)?;
        let mut r = BufReader::new(stream);
        read_response(&mut r, method == "HEAD").map_err(transient)
    }

    /// Run `op` with the transient-retry policy. `op` must be safe to
    /// repeat (idempotent, or harmless when duplicated).
    fn with_retry<T>(
        &self,
        what: &str,
        mut op: impl FnMut() -> Result<T, RemoteError>,
    ) -> anyhow::Result<T> {
        let mut delay = BACKOFF;
        let mut last: Option<anyhow::Error> = None;
        for attempt in 0..=RETRIES {
            match op() {
                Ok(v) => return Ok(v),
                Err(RemoteError::Permanent(e)) => {
                    return Err(e.context(format!("{what} (http://{})", self.host)))
                }
                Err(RemoteError::Transient(e)) => {
                    last = Some(e);
                    if attempt < RETRIES {
                        std::thread::sleep(delay);
                        delay *= 2;
                    }
                }
            }
        }
        Err(last
            .unwrap_or_else(|| anyhow::anyhow!("unreachable: no attempt ran"))
            .context(format!("{what} failed after {} attempts (http://{})", RETRIES + 1, self.host)))
    }

    /// Push `bytes` through one upload-session attempt, resuming at
    /// `PATCH` granularity: on a dropped chunk the session's landed offset
    /// is re-queried and the transfer continues from there.
    fn upload_once(&self, hex: &str, bytes: &[u8]) -> Result<(), RemoteError> {
        let open = self.request("POST", "/v2/runs/blobs/uploads/", &[], &[])?;
        if open.status != 202 {
            return Err(status_error("open upload session", &open));
        }
        let session = open
            .header("Location")
            .ok_or_else(|| RemoteError::Permanent(anyhow::anyhow!("upload session without Location")))?
            .to_string();
        let mut offset = 0usize;
        while offset < bytes.len() {
            let end = (offset + CHUNK).min(bytes.len());
            let headers =
                vec![("Content-Range".to_string(), format!("{}-{}", offset, end - 1))];
            match self.request("PATCH", &session, &headers, &bytes[offset..end]) {
                Ok(resp) if resp.status == 202 => offset = end,
                Ok(resp) if resp.status == 416 => {
                    // Offset disagreement (e.g. a chunk landed but its
                    // response was lost): trust the server's Range.
                    offset = range_end(&resp).map(|e| e + 1).unwrap_or(0) as usize;
                }
                Ok(resp) if resp.status == 404 => {
                    // Session expired server-side: restart from scratch.
                    return Err(status_error("upload chunk", &resp));
                }
                Ok(resp) => return Err(status_error("upload chunk", &resp)),
                Err(RemoteError::Transient(_)) => {
                    // The connection dropped mid-chunk — ask the session
                    // how much actually landed and resume there.
                    let status = self.request("GET", &session, &[], &[])?;
                    if status.status != 204 {
                        return Err(status_error("query upload offset", &status));
                    }
                    offset = range_end(&status).map(|e| e + 1).unwrap_or(0) as usize;
                }
                Err(e) => return Err(e),
            }
        }
        let commit =
            self.request("PUT", &format!("{session}?digest=sha256:{hex}"), &[], &[])?;
        if commit.status != 201 {
            return Err(status_error("commit upload", &commit));
        }
        Ok(())
    }

    /// Fetch + verify one blob from the wire (no cache involvement).
    fn fetch_verified(&self, hex: &str) -> Result<Vec<u8>, RemoteError> {
        let resp = self.request("GET", &format!("/v2/runs/blobs/sha256:{hex}"), &[], &[])?;
        if !resp.ok() {
            return Err(status_error("pull blob", &resp));
        }
        if sha256::hex(&resp.body) != hex {
            // Corruption on the wire (or a byzantine server): loud, and
            // retryable — the next attempt may traverse a clean path.
            return Err(RemoteError::Transient(anyhow::anyhow!(
                "blob sha256:{hex}: pulled bytes do not match their digest"
            )));
        }
        Ok(resp.body)
    }

    fn campaign_target(name: &str) -> String {
        format!("/v2/campaigns/manifests/{}", percent_encode(name))
    }
}

/// The inclusive end index from a `Range: 0-<end>` header, if present.
fn range_end(resp: &Response) -> Option<u64> {
    resp.header("Range")?.split('-').nth(1)?.trim().parse().ok()
}

impl StoreBackend for RemoteBackend {
    fn location(&self) -> String {
        format!("http://{}", self.host)
    }

    /// Allocation happens on the serving host, under its store lock — the
    /// id namespace is race-free across every client machine. A retried
    /// POST whose first response was lost may allocate (and strand) an
    /// extra empty id, which is harmless: ids are cheap, and `runs list`
    /// skips directories without a manifest.
    fn fresh_run_id(&self, strategy: &str, seed: u64) -> anyhow::Result<String> {
        self.with_retry("allocate run id", || {
            let resp = self.request(
                "POST",
                &format!("/v2/runs/ids?strategy={}&seed={seed}", percent_encode(strategy)),
                &[],
                &[],
            )?;
            if resp.status != 201 {
                return Err(status_error("allocate run id", &resp));
            }
            let j = crate::util::json::Json::parse(&String::from_utf8_lossy(&resp.body))
                .map_err(|e| RemoteError::Permanent(anyhow::anyhow!("id response: {e}")))?;
            j.s("id")
                .map(|s| s.to_string())
                .map_err(RemoteError::Permanent)
        })
    }

    fn save_manifest(&self, id: &str, bytes: &[u8]) -> anyhow::Result<()> {
        self.with_retry(&format!("save manifest {id:?}"), || {
            let resp = self.request(
                "PUT",
                &format!("/v2/runs/manifests/{}", percent_encode(id)),
                &[],
                bytes,
            )?;
            if resp.status != 201 {
                return Err(status_error("save manifest", &resp));
            }
            Ok(())
        })
    }

    fn load_manifest(&self, id: &str) -> anyhow::Result<Vec<u8>> {
        self.with_retry(&format!("load manifest {id:?}"), || {
            let resp = self.request(
                "GET",
                &format!("/v2/runs/manifests/{}", percent_encode(id)),
                &[],
                &[],
            )?;
            if resp.status == 404 {
                return Err(RemoteError::Permanent(anyhow::anyhow!(
                    "no stored run {id:?} on http://{}",
                    self.host
                )));
            }
            if !resp.ok() {
                return Err(status_error("load manifest", &resp));
            }
            Ok(resp.body)
        })
    }

    fn list_runs(&self) -> anyhow::Result<Vec<String>> {
        self.with_retry("list runs", || {
            let resp = self.request("GET", "/v2/runs/tags/list", &[], &[])?;
            if !resp.ok() {
                return Err(status_error("list runs", &resp));
            }
            parse_tags(&resp.body).map_err(RemoteError::Permanent)
        })
    }

    fn put_blob(&self, hex: &str, bytes: &[u8]) -> anyhow::Result<()> {
        // Already on the server? One cheap HEAD skips the upload — the
        // common case for checkpoint params that didn't change.
        if let Ok(Some(_)) = self.head_blob(hex) {
            return Ok(());
        }
        self.with_retry(&format!("upload blob sha256:{hex}"), || {
            self.upload_once(hex, bytes)
        })?;
        // A blob we hold the bytes of is cache-worthy without a pull.
        let _ = cache_write(&self.cache_path(hex), bytes, &self.cache);
        Ok(())
    }

    fn get_blob(&self, hex: &str) -> anyhow::Result<Vec<u8>> {
        // Read-through cache: verify even cache hits (a corrupted cache
        // file must repair itself, not poison every future read).
        let cached = self.cache_path(hex);
        if let Ok(bytes) = std::fs::read(&cached) {
            if sha256::hex(&bytes) == hex {
                return Ok(bytes);
            }
            let _ = std::fs::remove_file(&cached);
        }
        let bytes = self.with_retry(&format!("pull blob sha256:{hex}"), || {
            self.fetch_verified(hex)
        })?;
        // Only verified bytes ever enter the cache.
        let _ = cache_write(&cached, &bytes, &self.cache);
        Ok(bytes)
    }

    fn head_blob(&self, hex: &str) -> anyhow::Result<Option<u64>> {
        self.with_retry(&format!("head blob sha256:{hex}"), || {
            let resp =
                self.request("HEAD", &format!("/v2/runs/blobs/sha256:{hex}"), &[], &[])?;
            match resp.status {
                200 => Ok(resp
                    .header("Content-Length")
                    .and_then(|v| v.parse().ok())
                    .or(Some(0))),
                404 => Ok(None),
                _ => Err(status_error("head blob", &resp)),
            }
        })
    }

    fn load_campaign(&self, name: &str) -> anyhow::Result<Option<(Vec<u8>, String)>> {
        self.with_retry(&format!("load campaign {name:?}"), || {
            let resp = self.request("GET", &Self::campaign_target(name), &[], &[])?;
            match resp.status {
                404 => Ok(None),
                200 => {
                    // The ETag is advisory; the bytes are authoritative.
                    // Recomputing locally keeps the CAS token consistent
                    // even against a server that normalizes storage.
                    let digest = content_digest(&resp.body);
                    Ok(Some((resp.body, digest)))
                }
                _ => Err(status_error("load campaign", &resp)),
            }
        })
    }

    /// Conditional PUT. Safe to blind-retry: if a first attempt landed but
    /// its response was lost, the retry's `If-Match` token is now stale and
    /// reads back as `Conflict` — callers' CAS loops re-load and see the
    /// committed state (their own write) as the standing value.
    fn save_campaign(
        &self,
        name: &str,
        bytes: &[u8],
        expect: CasExpect<'_>,
    ) -> anyhow::Result<CasOutcome> {
        let headers = match expect {
            CasExpect::Any => Vec::new(),
            CasExpect::Absent => vec![("If-None-Match".to_string(), "*".to_string())],
            CasExpect::Digest(d) => vec![("If-Match".to_string(), format!("\"{d}\""))],
        };
        self.with_retry(&format!("save campaign {name:?}"), || {
            let resp = self.request("PUT", &Self::campaign_target(name), &headers, bytes)?;
            match resp.status {
                201 => {
                    let digest = resp
                        .header("ETag")
                        .map(|t| t.trim_matches('"').to_string())
                        .unwrap_or_else(|| content_digest(bytes));
                    Ok(CasOutcome::Committed(digest))
                }
                412 => Ok(CasOutcome::Conflict),
                _ => Err(status_error("save campaign", &resp)),
            }
        })
    }

    fn list_campaigns(&self) -> anyhow::Result<Vec<String>> {
        self.with_retry("list campaigns", || {
            let resp = self.request("GET", "/v2/campaigns/tags/list", &[], &[])?;
            if !resp.ok() {
                return Err(status_error("list campaigns", &resp));
            }
            parse_tags(&resp.body).map_err(RemoteError::Permanent)
        })
    }

    fn as_local(&self) -> Option<&LocalBackend> {
        None
    }
}

fn parse_tags(body: &[u8]) -> anyhow::Result<Vec<String>> {
    let j = crate::util::json::Json::parse(&String::from_utf8_lossy(body))?;
    j.arr("tags")?
        .iter()
        .map(|t| {
            t.as_str()
                .map(|s| s.to_string())
                .ok_or_else(|| anyhow::anyhow!("tags entry not a string"))
        })
        .collect()
}

/// Best-effort cache insert (atomic; a failed cache write never fails the
/// operation that produced the bytes).
fn cache_write(path: &std::path::Path, bytes: &[u8], dir: &std::path::Path) -> anyhow::Result<()> {
    std::fs::create_dir_all(dir)?;
    write_atomic(path, bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn url_parsing_accepts_host_port_only() {
        assert!(RemoteBackend::new("http://127.0.0.1:7878").is_ok());
        assert!(RemoteBackend::new("http://store.lab:7878/").is_ok());
        assert!(RemoteBackend::new("https://127.0.0.1:7878").is_err());
        assert!(RemoteBackend::new("http://host:1/path").is_err());
        assert!(RemoteBackend::new("http://").is_err());
        assert_eq!(
            RemoteBackend::new("http://h:1").unwrap().location(),
            "http://h:1"
        );
    }

    #[test]
    fn connection_failures_are_bounded_not_hangs() {
        // Nothing listens on this port (bind-then-drop reserves it as
        // closed); every op must fail after the retry budget, not wedge.
        let port = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().port()
        };
        let b = RemoteBackend::new(&format!("http://127.0.0.1:{port}")).unwrap();
        let err = b.list_runs().unwrap_err();
        assert!(err.to_string().contains("attempts"), "{err}");
    }
}
