//! Storage backends: where a [`crate::store::RunStore`] keeps its bytes.
//!
//! The store's object model (versioned run/campaign manifests +
//! content-addressed blobs, `schema.rs`) is backend-agnostic; this module
//! defines the primitive surface a backend must provide and the two
//! implementations:
//!
//! * [`LocalBackend`] — the original directory layout (`runs/`, `blobs/`,
//!   `campaigns/`), with the advisory lockfile serializing the mutations
//!   that race (run-id allocation, campaign compare-and-swap, gc).
//! * [`remote::RemoteBackend`] — an HTTP client speaking OCI-registry-style
//!   routes against `fedel runs serve` ([`serve::StoreServer`]), so
//!   campaign workers on different machines can share one store.
//!
//! The split of concerns: backends move *bytes* (and provide one atomic
//! compare-and-swap primitive for campaign manifests); parsing, schema
//! validation, digest bookkeeping, and the campaign claim protocol live in
//! `RunStore` on top. `fresh_run_id` allocation, blob GC, and the lockfile
//! are local-backend concerns — the remote backend delegates allocation to
//! the serving host (whose local backend holds the lock) and refuses gc.

pub mod http;
pub mod remote;
pub mod serve;

use std::io::Write;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::util::sha256;

/// A crashed process can strand `.lock`; holders keep it for microseconds
/// (id allocation, one small file rename) — long operations like gc
/// heartbeat via [`StoreLock::refresh`] — so a lockfile this old is
/// abandoned and gets reclaimed.
const LOCK_STALE: Duration = Duration::from_secs(30);

/// How long a contender waits for the lock before giving up loudly.
const LOCK_WAIT: Duration = Duration::from_secs(20);

/// Held advisory store lock; released (unlinked) on drop. The file holds
/// a per-acquisition token, and release/reclaim are token-checked /
/// rename-based, so a contender can never unlink a lock another holder
/// legitimately owns.
pub struct StoreLock {
    path: PathBuf,
    token: String,
}

impl StoreLock {
    /// Re-stamp the lockfile's mtime. Holders that legitimately exceed
    /// [`LOCK_STALE`] (gc over a huge store) must call this periodically
    /// or a contender will reclaim the lock out from under them.
    pub fn refresh(&self) {
        let _ = std::fs::write(&self.path, &self.token);
    }
}

impl Drop for StoreLock {
    fn drop(&mut self) {
        // Only unlink a lock that is still ours: if a contender reclaimed
        // it as stale and re-acquired, the file now holds their token and
        // removing it would admit a third holder.
        if std::fs::read_to_string(&self.path).map(|t| t == self.token).unwrap_or(false) {
            let _ = std::fs::remove_file(&self.path);
        }
    }
}

/// A unique temporary file name: scratch writes from concurrent
/// threads/processes must never interleave on one path, or a rename could
/// publish a torn file.
pub(crate) fn tmp_name(stem: &str) -> String {
    use std::sync::atomic::{AtomicU64, Ordering};
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    format!(
        "{stem}.tmp-{}-{}",
        std::process::id(),
        COUNTER.fetch_add(1, Ordering::Relaxed)
    )
}

/// Write `bytes` to `path` atomically via a uniquely-named sibling tmp.
pub(crate) fn write_atomic(path: &Path, bytes: &[u8]) -> anyhow::Result<()> {
    let file_name = path
        .file_name()
        .ok_or_else(|| anyhow::anyhow!("no file name in {path:?}"))?
        .to_string_lossy()
        .to_string();
    let tmp = path.with_file_name(tmp_name(&file_name));
    std::fs::write(&tmp, bytes).map_err(|e| anyhow::anyhow!("write {tmp:?}: {e}"))?;
    std::fs::rename(&tmp, path).map_err(|e| {
        let _ = std::fs::remove_file(&tmp);
        anyhow::anyhow!("rename to {path:?}: {e}")
    })?;
    Ok(())
}

/// The digest string (`sha256:<hex>`) that addresses `bytes` — blobs are
/// stored under it, and campaign manifests use it as their CAS token
/// (served over HTTP as the `ETag`).
pub fn content_digest(bytes: &[u8]) -> String {
    format!("sha256:{}", sha256::hex(bytes))
}

/// What a [`StoreBackend::save_campaign`] caller expects the stored
/// campaign manifest to look like for its write to land — the store's one
/// compare-and-swap primitive, and the invariant that keeps concurrent
/// cell claims from clobbering each other.
#[derive(Clone, Copy, Debug)]
pub enum CasExpect<'a> {
    /// Unconditional write (last writer wins) — creation and full rewrites.
    Any,
    /// The manifest must not exist yet (HTTP `If-None-Match: *`).
    Absent,
    /// The stored manifest's content digest must equal this
    /// (`sha256:<hex>`; HTTP `If-Match`).
    Digest(&'a str),
}

/// Outcome of a [`StoreBackend::save_campaign`] compare-and-swap.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CasOutcome {
    /// The write landed; carries the new content digest.
    Committed(String),
    /// The expectation failed — someone else wrote first. Reload and retry.
    Conflict,
}

/// The primitive surface a store backend provides. Everything takes and
/// returns raw bytes; `RunStore` layers parsing, digest verification, and
/// the claim protocol on top. Implementations must be safe to share across
/// threads (the campaign runner hits one backend from its worker pool).
pub trait StoreBackend: Send + Sync {
    /// Human-readable location for messages (`runs`, `http://host:port`).
    fn location(&self) -> String;

    /// Allocate (and reserve) a fresh run id; see
    /// [`LocalBackend::fresh_run_id`] for the id scheme. Remote backends
    /// delegate to the serving host so the allocation lock stays local.
    fn fresh_run_id(&self, strategy: &str, seed: u64) -> anyhow::Result<String>;

    fn save_manifest(&self, id: &str, bytes: &[u8]) -> anyhow::Result<()>;
    fn load_manifest(&self, id: &str) -> anyhow::Result<Vec<u8>>;
    /// Ids of all stored runs (unordered; callers sort after parsing).
    fn list_runs(&self) -> anyhow::Result<Vec<String>>;

    /// Store `bytes` under content address `hex` (already computed by the
    /// caller); already-present digests need not be rewritten.
    fn put_blob(&self, hex: &str, bytes: &[u8]) -> anyhow::Result<()>;
    fn get_blob(&self, hex: &str) -> anyhow::Result<Vec<u8>>;
    /// Size of the stored blob, or `None` if absent.
    fn head_blob(&self, hex: &str) -> anyhow::Result<Option<u64>>;

    /// The stored campaign manifest and its content digest, or `None` if
    /// no campaign of that name exists.
    fn load_campaign(&self, name: &str) -> anyhow::Result<Option<(Vec<u8>, String)>>;
    /// Compare-and-swap write of a campaign manifest (see [`CasExpect`]).
    /// The comparison and the write are atomic with respect to every other
    /// writer of the same store, across threads, processes, and hosts.
    fn save_campaign(
        &self,
        name: &str,
        bytes: &[u8],
        expect: CasExpect<'_>,
    ) -> anyhow::Result<CasOutcome>;
    /// Names of all stored campaigns (unordered).
    fn list_campaigns(&self) -> anyhow::Result<Vec<String>>;

    /// Downcast seam for operations that only make sense against a local
    /// directory (gc, the CLI server's root, lock-holding maintenance).
    fn as_local(&self) -> Option<&LocalBackend>;
}

/// The original directory-backed store (see [`crate::store`] module docs
/// for the layout): everything under one root, mutations that race
/// serialized through the `.lock` advisory lockfile.
pub struct LocalBackend {
    root: PathBuf,
}

impl LocalBackend {
    /// Open a directory store, creating the skeleton if absent.
    pub fn open(root: impl Into<PathBuf>) -> anyhow::Result<LocalBackend> {
        let root = root.into();
        for sub in ["runs", "blobs", "campaigns"] {
            let dir = root.join(sub);
            std::fs::create_dir_all(&dir)
                .map_err(|e| anyhow::anyhow!("create {dir:?}: {e}"))?;
        }
        Ok(LocalBackend { root })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn run_dir(&self, id: &str) -> PathBuf {
        self.root.join("runs").join(id)
    }

    fn blob_path(&self, hex: &str) -> PathBuf {
        self.root.join("blobs").join(hex)
    }

    fn campaign_path(&self, name: &str) -> PathBuf {
        self.root.join("campaigns").join(format!("{name}.json"))
    }

    /// Take the store-wide advisory lock. `O_EXCL` creation is atomic on
    /// every platform we care about, across threads and processes alike;
    /// contenders spin with a short sleep, reclaim abandoned locks older
    /// than [`LOCK_STALE`], and give up after [`LOCK_WAIT`].
    ///
    /// Stale reclaim is rename-based: `rename` succeeds for exactly one
    /// contender (the others see the file gone), so several contenders
    /// observing the same abandoned lock can never all "remove and
    /// re-create" their way into concurrent ownership.
    pub fn lock(&self) -> anyhow::Result<StoreLock> {
        let path = self.root.join(".lock");
        // pid + counter, for humans debugging a stuck store and for the
        // token-checked release.
        let token = tmp_name("holder");
        let deadline = Instant::now() + LOCK_WAIT;
        loop {
            match std::fs::OpenOptions::new().write(true).create_new(true).open(&path) {
                Ok(mut f) => {
                    let _ = write!(f, "{token}");
                    return Ok(StoreLock { path, token });
                }
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    let stale = std::fs::metadata(&path)
                        .and_then(|m| m.modified())
                        .ok()
                        .and_then(|t| t.elapsed().ok())
                        .map(|age| age >= LOCK_STALE)
                        .unwrap_or(false);
                    if stale {
                        // Claim the corpse by renaming it to a unique
                        // graveyard name; exactly one contender wins.
                        let grave = path.with_file_name(tmp_name(".lock.stale"));
                        if std::fs::rename(&path, &grave).is_ok() {
                            let _ = std::fs::remove_file(&grave);
                        }
                        continue;
                    }
                    anyhow::ensure!(
                        Instant::now() < deadline,
                        "store lock {path:?} held for over {LOCK_WAIT:?} — \
                         remove it by hand if its owner is gone"
                    );
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(e) => return Err(anyhow::anyhow!("create lock {path:?}: {e}")),
            }
        }
    }
}

impl StoreBackend for LocalBackend {
    fn location(&self) -> String {
        self.root.display().to_string()
    }

    /// Allocate a fresh, human-readable run id: `<strategy>-s<seed>`,
    /// suffixed `-2`, `-3`, ... when taken. Allocation *reserves* the id
    /// by creating `runs/<id>/` while holding the store lock, so
    /// concurrent writers — threads or whole processes — can never both
    /// observe the same id free and clobber each other's run directory.
    fn fresh_run_id(&self, strategy: &str, seed: u64) -> anyhow::Result<String> {
        let _lock = self.lock()?;
        let base = format!("{strategy}-s{seed}");
        let mut id = base.clone();
        let mut n = 2usize;
        loop {
            let dir = self.run_dir(&id);
            if !dir.exists() {
                std::fs::create_dir_all(&dir)
                    .map_err(|e| anyhow::anyhow!("reserve {dir:?}: {e}"))?;
                return Ok(id);
            }
            id = format!("{base}-{n}");
            n += 1;
        }
    }

    /// Persist a manifest atomically (uniquely-named tmp + rename): a
    /// crash mid-write leaves the previous manifest intact, never a torn
    /// one, and concurrent writers never share a scratch path.
    fn save_manifest(&self, id: &str, bytes: &[u8]) -> anyhow::Result<()> {
        let dir = self.run_dir(id);
        std::fs::create_dir_all(&dir).map_err(|e| anyhow::anyhow!("create {dir:?}: {e}"))?;
        write_atomic(&dir.join("manifest.json"), bytes)
    }

    fn load_manifest(&self, id: &str) -> anyhow::Result<Vec<u8>> {
        let path = self.run_dir(id).join("manifest.json");
        std::fs::read(&path).map_err(|e| anyhow::anyhow!("no stored run {id:?} ({path:?}: {e})"))
    }

    fn list_runs(&self) -> anyhow::Result<Vec<String>> {
        let dir = self.root.join("runs");
        let mut out = Vec::new();
        for entry in
            std::fs::read_dir(&dir).map_err(|e| anyhow::anyhow!("read {dir:?}: {e}"))?
        {
            let entry = entry?;
            if entry.path().join("manifest.json").exists() {
                out.push(entry.file_name().to_string_lossy().to_string());
            }
        }
        Ok(out)
    }

    /// Concurrent writers of the same content are harmless: each writes
    /// its own uniquely-named tmp, and whichever rename lands last
    /// replaces identical bytes with identical bytes.
    fn put_blob(&self, hex: &str, bytes: &[u8]) -> anyhow::Result<()> {
        let path = self.blob_path(hex);
        if !path.exists() {
            write_atomic(&path, bytes)?;
        }
        Ok(())
    }

    fn get_blob(&self, hex: &str) -> anyhow::Result<Vec<u8>> {
        let path = self.blob_path(hex);
        std::fs::read(&path).map_err(|e| anyhow::anyhow!("read blob {path:?}: {e}"))
    }

    fn head_blob(&self, hex: &str) -> anyhow::Result<Option<u64>> {
        match std::fs::metadata(self.blob_path(hex)) {
            Ok(m) => Ok(Some(m.len())),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
            Err(e) => Err(anyhow::anyhow!("stat blob {hex}: {e}")),
        }
    }

    fn load_campaign(&self, name: &str) -> anyhow::Result<Option<(Vec<u8>, String)>> {
        let path = self.campaign_path(name);
        match std::fs::read(&path) {
            Ok(bytes) => {
                let digest = content_digest(&bytes);
                Ok(Some((bytes, digest)))
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
            Err(e) => Err(anyhow::anyhow!("read campaign {path:?}: {e}")),
        }
    }

    /// The compare and the write happen under the store lock, making the
    /// pair atomic against every other writer of this directory — the
    /// same guarantee the HTTP server gives remote writers by computing
    /// it inside its own local backend.
    fn save_campaign(
        &self,
        name: &str,
        bytes: &[u8],
        expect: CasExpect<'_>,
    ) -> anyhow::Result<CasOutcome> {
        let _lock = self.lock()?;
        let current = self.load_campaign(name)?;
        let ok = match (&expect, &current) {
            (CasExpect::Any, _) => true,
            (CasExpect::Absent, None) => true,
            (CasExpect::Absent, Some(_)) => false,
            (CasExpect::Digest(d), Some((_, cur))) => *d == cur.as_str(),
            (CasExpect::Digest(_), None) => false,
        };
        if !ok {
            return Ok(CasOutcome::Conflict);
        }
        write_atomic(&self.campaign_path(name), bytes)?;
        Ok(CasOutcome::Committed(content_digest(bytes)))
    }

    fn list_campaigns(&self) -> anyhow::Result<Vec<String>> {
        let dir = self.root.join("campaigns");
        let mut out = Vec::new();
        for entry in
            std::fs::read_dir(&dir).map_err(|e| anyhow::anyhow!("read {dir:?}: {e}"))?
        {
            let name = entry?.file_name().to_string_lossy().to_string();
            if let Some(stem) = name.strip_suffix(".json") {
                out.push(stem.to_string());
            }
        }
        Ok(out)
    }

    fn as_local(&self) -> Option<&LocalBackend> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch(tag: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("fedel-backend-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn lock_excludes_and_releases() {
        let dir = scratch("lock");
        let b = LocalBackend::open(&dir).unwrap();
        let held = b.lock().unwrap();
        assert!(dir.join(".lock").exists());
        drop(held);
        assert!(!dir.join(".lock").exists(), "lock must release on drop");
        // reacquirable after release
        drop(b.lock().unwrap());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stale_lock_is_reclaimed() {
        let dir = scratch("stale");
        let b = LocalBackend::open(&dir).unwrap();
        // Simulate a crashed holder: a lockfile whose mtime is ancient.
        let path = dir.join(".lock");
        std::fs::write(&path, b"dead").unwrap();
        let old = std::time::SystemTime::now() - (LOCK_STALE + Duration::from_secs(5));
        let f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
        f.set_modified(old).unwrap();
        drop(f);
        let _held = b.lock().expect("stale lock must be reclaimed");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn campaign_cas_honors_expectations() {
        let dir = scratch("cas");
        let b = LocalBackend::open(&dir).unwrap();
        // Absent: only the first creator wins.
        let first = b.save_campaign("c", b"v1", CasExpect::Absent).unwrap();
        let CasOutcome::Committed(d1) = first else { panic!("create must land") };
        assert_eq!(d1, content_digest(b"v1"));
        assert_eq!(
            b.save_campaign("c", b"v1b", CasExpect::Absent).unwrap(),
            CasOutcome::Conflict,
            "second creator must lose"
        );
        // Digest: stale tokens lose, current ones win.
        assert_eq!(
            b.save_campaign("c", b"v2", CasExpect::Digest(&content_digest(b"other"))).unwrap(),
            CasOutcome::Conflict
        );
        let CasOutcome::Committed(d2) =
            b.save_campaign("c", b"v2", CasExpect::Digest(&d1)).unwrap()
        else {
            panic!("matching digest must land")
        };
        let (bytes, digest) = b.load_campaign("c").unwrap().unwrap();
        assert_eq!(bytes, b"v2");
        assert_eq!(digest, d2);
        // Any: unconditional.
        assert!(matches!(
            b.save_campaign("c", b"v3", CasExpect::Any).unwrap(),
            CasOutcome::Committed(_)
        ));
        // Digest against a missing manifest is a conflict, not an error.
        assert_eq!(
            b.save_campaign("nope", b"x", CasExpect::Digest(&d2)).unwrap(),
            CasOutcome::Conflict
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn blob_and_manifest_primitives_round_trip() {
        let dir = scratch("prims");
        let b = LocalBackend::open(&dir).unwrap();
        let hex = crate::util::sha256::hex(b"payload");
        assert_eq!(b.head_blob(&hex).unwrap(), None);
        b.put_blob(&hex, b"payload").unwrap();
        assert_eq!(b.head_blob(&hex).unwrap(), Some(7));
        assert_eq!(b.get_blob(&hex).unwrap(), b"payload");
        b.save_manifest("run-s1", b"{}").unwrap();
        assert_eq!(b.load_manifest("run-s1").unwrap(), b"{}");
        assert_eq!(b.list_runs().unwrap(), vec!["run-s1".to_string()]);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
