//! # FedEL — Federated Elastic Learning for Heterogeneous Devices
//!
//! A production-grade reproduction of the FedEL paper as a three-layer
//! rust + JAX + Pallas stack. This crate is the L3 coordinator: it loads
//! AOT-compiled HLO artifacts (built once by `make artifacts`; python is
//! never on the training path) through the PJRT CPU client, simulates a
//! heterogeneous device fleet with a calibrated timing model, and
//! implements the paper's contribution — sliding-window training with
//! window-bounded ElasticTrainer tensor selection and local/global tensor
//! importance adjustment — plus every baseline from the evaluation.
//!
//! Layering (see DESIGN.md):
//! * [`manifest`] — the L2→L3 contract (flat layouts, blocks, FLOPs).
//! * [`runtime`] — PJRT/mock engines executing the artifacts.
//! * [`timing`] — device profiles + per-tensor `t_g`/`t_w` timing model.
//! * [`elastic`] — ElasticTrainer importance + DP tensor selection.
//! * [`window`] — FedEL's sliding window state machine.
//! * [`data`] — synthetic non-iid datasets (Dirichlet partitioning).
//! * [`fl`] — server loop, masked aggregation, bias diagnostics.
//! * [`fleet`] — client profiles, trace/generator fleets, availability churn.
//! * [`strategies`] — FedEL + the seven baselines.
//! * [`metrics`] — time-to-accuracy, memory & energy models.
//! * [`sim`] — fleet construction and end-to-end experiment runner.
//! * [`store`] — persistent run store: checkpoints, resume, warm start.
//! * [`operator`] — campaign control plane: reconcile-loop workers with
//!   leases, live grid edits, successive-halving sweep pruning.
//! * [`report`] — paper-style table/figure emission.

pub mod config;
pub mod data;
pub mod elastic;
pub mod fl;
pub mod fleet;
pub mod manifest;
pub mod metrics;
pub mod operator;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod store;
pub mod strategies;
pub mod timing;
pub mod util;
pub mod window;
