//! FedEL's sliding window (Sec. 4.1): the state machine that decides which
//! contiguous run of blocks a client trains each round.
//!
//! The window `[end, front)` holds the trainable blocks; the early-exit
//! head of block `front-1` is the round's output layer. Per round:
//!
//! * **End-edge movement** (Fig 7c): blocks at the shallow edge whose
//!   tensors went unselected last round are culled (frozen), shrinking the
//!   window — either the window was too large for the budget, or
//!   ElasticTrainer found nothing important there.
//! * **Front-edge movement** (Fig 7a): the front advances to include the
//!   next run of blocks whose cumulative training time `Σ T^b` just
//!   exceeds `T_th`; reaching the model's end with budget left over still
//!   counts as a movement.
//! * **Reset / rollback** (Fig 7b): when the front edge is already at the
//!   model's end, the window rolls back to the initial window so earlier
//!   layers get revisited (Appendix B.6 shows this lowers the O₁ bias
//!   term). `WindowPolicy::NoRollback` disables this for the Table 4
//!   ablation; `WindowPolicy::Collapsed` is FedEL-C (end edge jumps to the
//!   old front every round, Fig 13/14).

/// Variant knobs for ablations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WindowPolicy {
    /// Full FedEL: end-edge culling + reset when the front reaches the end.
    FedEl,
    /// FedEL-C (Fig 13): the end edge collapses to the previous front, so
    /// consecutive windows are disjoint.
    Collapsed,
    /// Table 4 "Not Rollback": the front never resets; once it reaches the
    /// model end the window pins to the final run of blocks.
    NoRollback,
}

/// The window over blocks `[end, front)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Window {
    pub end: usize,
    pub front: usize,
}

impl Window {
    pub fn blocks(&self) -> std::ops::Range<usize> {
        self.end..self.front
    }

    pub fn len(&self) -> usize {
        self.front - self.end
    }

    pub fn is_empty(&self) -> bool {
        self.front == self.end
    }

    pub fn contains(&self, b: usize) -> bool {
        (self.end..self.front).contains(&b)
    }
}

/// Per-round block costs on ONE device: `train[b]` is the paper's
/// `T^b = Σ_k (t_g^k + t_w^k)` and `fwd[b]` the forward time of block `b`
/// (both already multiplied by the local step count).
///
/// The forward vector is a deliberate refinement of the paper's
/// block-time rule: Eq. 1's budget constraint is `T_fw + T_bw(A) ≤ T_th`,
/// and a window with exit at block `front-1` pays forward time for EVERY
/// block below the front (including frozen ones below the end edge). If
/// window sizing ignores that term — summing only `Σ T^b` as Sec. 4.1
/// literally states — a straggler's initial window is so deep that the DP
/// can never afford the gradient chain back to the window's shallow end,
/// and front blocks starve. Counting `fwd` makes every window's full
/// training cost land just above `T_th`, which is what the rule is for.
#[derive(Clone, Debug)]
pub struct BlockCosts {
    train: Vec<f64>,
    /// `fwd_pre[k]` = forward time through all blocks `< k` (len nb + 1).
    /// Precomputed once at construction: the window walkers query a
    /// forward prefix for every candidate front, and recomputing it by
    /// summation made `initial_window`/`front_advance` O(nb²) per client
    /// per round (`perf_hotpaths` benches the difference).
    fwd_pre: Vec<f64>,
}

impl BlockCosts {
    /// `train[b]` and `fwd[b]` per block; the forward prefix sums are
    /// accumulated here, left to right, exactly as the old per-query
    /// summation did — so window decisions are bitwise-unchanged.
    pub fn new(train: Vec<f64>, fwd: Vec<f64>) -> BlockCosts {
        assert_eq!(train.len(), fwd.len(), "train/fwd cost length mismatch");
        let mut fwd_pre = Vec::with_capacity(fwd.len() + 1);
        let mut acc = 0.0f64;
        fwd_pre.push(0.0);
        for x in fwd {
            acc += x;
            fwd_pre.push(acc);
        }
        BlockCosts { train, fwd_pre }
    }

    pub fn uniform(nb: usize) -> BlockCosts {
        BlockCosts::new(vec![1.0; nb], vec![0.0; nb])
    }

    pub fn len(&self) -> usize {
        self.train.len()
    }

    pub fn is_empty(&self) -> bool {
        self.train.is_empty()
    }

    pub fn train(&self) -> &[f64] {
        &self.train
    }

    /// Forward time through all blocks `< front` — O(1) table lookup.
    #[inline]
    fn fwd_prefix(&self, front: usize) -> f64 {
        self.fwd_pre[front]
    }
}

/// Per-client sliding-window state.
#[derive(Clone, Debug)]
pub struct WindowState {
    pub win: Window,
    pub policy: WindowPolicy,
    /// Rounds since the state was created (for diagnostics/traces).
    pub rounds: usize,
    /// How many times the window rolled back to the initial window.
    pub resets: usize,
}

/// The initial window: blocks from 0 until the cumulative cost (block
/// training time + the window's forward prefix) first reaches `t_th`
/// (Sec. 4.1 with the T_fw refinement documented on [`BlockCosts`]).
pub fn initial_window(costs: &BlockCosts, t_th: f64) -> Window {
    let nb = costs.len();
    let mut acc_train = 0.0;
    for b in 0..nb {
        acc_train += costs.train[b];
        if acc_train + costs.fwd_prefix(b + 1) >= t_th {
            return Window { end: 0, front: b + 1 };
        }
    }
    Window { end: 0, front: nb }
}

impl WindowState {
    pub fn new(costs: &BlockCosts, t_th: f64, policy: WindowPolicy) -> Self {
        WindowState { win: initial_window(costs, t_th), policy, rounds: 0, resets: 0 }
    }

    /// Advance the window for the next round.
    ///
    /// `block_selected[b]` — whether any tensor of block `b` was selected
    /// by ElasticTrainer in the round just finished (drives the end edge).
    pub fn advance(&mut self, costs: &BlockCosts, t_th: f64, block_selected: &[bool]) {
        let nb = costs.len();
        debug_assert_eq!(block_selected.len(), nb);
        self.rounds += 1;

        match self.policy {
            WindowPolicy::Collapsed => {
                // FedEL-C: next window starts exactly at the old front.
                if self.win.front >= nb {
                    self.win = initial_window(costs, t_th);
                    self.resets += 1;
                    return;
                }
                let end = self.win.front;
                let front = front_advance(costs, end, t_th);
                self.win = Window { end, front };
            }
            WindowPolicy::FedEl | WindowPolicy::NoRollback => {
                // End edge: cull unselected blocks from the shallow side
                // (keep at least one block in the window).
                let mut end = self.win.end;
                while end + 1 < self.win.front && !block_selected[end] {
                    end += 1;
                }
                // Front edge.
                if self.win.front >= nb {
                    match self.policy {
                        WindowPolicy::FedEl => {
                            self.win = initial_window(costs, t_th);
                            self.resets += 1;
                        }
                        _ => {
                            // NoRollback: pin to the final run of blocks
                            // worth ~T_th ending at the model end.
                            let end = rear_window_start(costs, t_th);
                            self.win = Window { end, front: nb };
                        }
                    }
                    return;
                }
                let front = front_advance(costs, self.win.front, t_th);
                self.win = Window { end: end.min(front - 1), front };
            }
        }
    }
}

/// Front-edge movement: from `from`, include blocks until the added
/// training time plus the new window's forward prefix reaches `t_th`
/// (always at least one block; stops at the model end).
fn front_advance(costs: &BlockCosts, from: usize, t_th: f64) -> usize {
    let nb = costs.len();
    let mut acc = 0.0;
    let mut front = from;
    while front < nb {
        acc += costs.train[front];
        front += 1;
        if acc + costs.fwd_prefix(front) >= t_th {
            break;
        }
    }
    front.max(from + 1).min(nb)
}

/// Start of a rear window of ~`t_th` cumulative cost ending at the model
/// end (NoRollback terminal state).
fn rear_window_start(costs: &BlockCosts, t_th: f64) -> usize {
    let nb = costs.len();
    let mut acc = costs.fwd_prefix(nb);
    for b in (0..nb).rev() {
        acc += costs.train[b];
        if acc >= t_th {
            return b;
        }
    }
    0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uniform(nb: usize) -> BlockCosts {
        BlockCosts::uniform(nb)
    }

    #[test]
    fn initial_window_covers_budget() {
        let bt = uniform(8);
        let w = initial_window(&bt, 3.0);
        assert_eq!(w, Window { end: 0, front: 3 });
        // threshold smaller than one block -> single block
        assert_eq!(initial_window(&bt, 0.5).front, 1);
        // threshold bigger than the whole model -> all blocks
        assert_eq!(initial_window(&bt, 100.0).front, 8);
    }

    #[test]
    fn front_advances_by_budget_worth_of_blocks() {
        let bt = uniform(8);
        let mut st = WindowState::new(&bt, 3.0, WindowPolicy::FedEl);
        assert_eq!(st.win, Window { end: 0, front: 3 });
        st.advance(&bt, 3.0, &[true; 8]);
        assert_eq!(st.win.front, 6);
        // all blocks selected -> end edge unchanged
        assert_eq!(st.win.end, 0);
    }

    #[test]
    fn end_edge_culls_unselected_blocks() {
        let bt = uniform(8);
        let mut st = WindowState::new(&bt, 3.0, WindowPolicy::FedEl);
        let mut sel = vec![true; 8];
        sel[0] = false;
        sel[1] = false;
        st.advance(&bt, 3.0, &sel);
        assert_eq!(st.win.end, 2, "unselected shallow blocks culled");
        assert_eq!(st.win.front, 6);
    }

    #[test]
    fn reset_when_front_reaches_end() {
        let bt = uniform(6);
        let mut st = WindowState::new(&bt, 2.0, WindowPolicy::FedEl);
        // round 1: front 2 -> 4; round 2: front 4 -> 6; round 3: reset
        st.advance(&bt, 2.0, &[true; 6]);
        st.advance(&bt, 2.0, &[true; 6]);
        assert_eq!(st.win.front, 6);
        st.advance(&bt, 2.0, &[true; 6]);
        assert_eq!(st.win, Window { end: 0, front: 2 });
        assert_eq!(st.resets, 1);
    }

    #[test]
    fn no_rollback_pins_to_rear_window() {
        let bt = uniform(6);
        let mut st = WindowState::new(&bt, 2.0, WindowPolicy::NoRollback);
        for _ in 0..3 {
            st.advance(&bt, 2.0, &[true; 6]);
        }
        assert_eq!(st.win, Window { end: 4, front: 6 });
        assert_eq!(st.resets, 0);
        // stays pinned
        st.advance(&bt, 2.0, &[true; 6]);
        assert_eq!(st.win, Window { end: 4, front: 6 });
    }

    #[test]
    fn collapsed_windows_are_disjoint() {
        let bt = uniform(8);
        let mut st = WindowState::new(&bt, 3.0, WindowPolicy::Collapsed);
        let w0 = st.win;
        st.advance(&bt, 3.0, &[true; 8]);
        let w1 = st.win;
        assert_eq!(w1.end, w0.front);
        assert!(w1.front > w1.end);
    }

    #[test]
    fn window_always_nonempty() {
        let bt = uniform(5);
        let mut st = WindowState::new(&bt, 1.0, WindowPolicy::FedEl);
        // nothing ever selected: end edge must not cross the front.
        for _ in 0..20 {
            st.advance(&bt, 1.0, &[false; 5]);
            assert!(st.win.front > st.win.end, "{:?}", st.win);
            assert!(st.win.front <= 5);
        }
    }

    #[test]
    fn fast_device_big_threshold_covers_model_every_round() {
        let bt = uniform(4);
        let mut st = WindowState::new(&bt, 10.0, WindowPolicy::FedEl);
        assert_eq!(st.win, Window { end: 0, front: 4 });
        st.advance(&bt, 10.0, &[true; 4]);
        // front was at end -> reset to initial == full model again
        assert_eq!(st.win, Window { end: 0, front: 4 });
    }

    #[test]
    fn heterogeneous_block_times_respected() {
        let bt = BlockCosts::new(vec![0.5, 0.5, 4.0, 1.0, 1.0], vec![0.0; 5]);
        let w = initial_window(&bt, 2.0);
        assert_eq!(w.front, 3); // 0.5+0.5 < 2.0 <= 0.5+0.5+4.0
        let mut st = WindowState::new(&bt, 2.0, WindowPolicy::FedEl);
        st.advance(&bt, 2.0, &[true; 5]);
        // from block 3: 1.0 + 1.0 == 2.0 -> front = 5
        assert_eq!(st.win.front, 5);
    }
}
