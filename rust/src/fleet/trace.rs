//! JSONL fleet traces: one [`ClientProfile`] object per line.
//!
//! Schema (per line; omitted keys take their documented defaults):
//!
//! ```json
//! {"name": "pixel6", "scale": 0.5, "power_watts": 4.0,
//!  "up_mbps": 10, "down_mbps": 40, "energy": "battery",
//!  "arrive": 0, "depart": 86400}
//! ```
//!
//! Traces are external inputs, so loading is strict: unknown keys,
//! non-finite numbers, inverted windows, and empty files are all errors
//! with line numbers. The parsed profiles are inlined into the run
//! manifest at build time ([`crate::sim::Experiment::build`]), so resuming
//! a trace-driven run never re-reads — or even requires — the file.

use super::ClientProfile;
use crate::util::json::Json;
use std::path::Path;

/// Load and validate a JSONL trace. Blank lines and `#` comment lines are
/// skipped.
pub fn load_trace(path: &Path) -> anyhow::Result<Vec<ClientProfile>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading fleet trace {}: {e}", path.display()))?;
    parse_trace(&text).map_err(|e| anyhow::anyhow!("fleet trace {}: {e}", path.display()))
}

/// Parse trace text (separated from I/O for tests and future remote
/// sources).
pub fn parse_trace(text: &str) -> anyhow::Result<Vec<ClientProfile>> {
    let mut profiles = Vec::new();
    for (idx, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let j = Json::parse(line).map_err(|e| anyhow::anyhow!("line {}: {e}", idx + 1))?;
        let p = ClientProfile::from_json(&j).map_err(|e| anyhow::anyhow!("line {}: {e}", idx + 1))?;
        profiles.push(p);
    }
    anyhow::ensure!(!profiles.is_empty(), "trace contains no client profiles");
    Ok(profiles)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::{EnergyClass, DEFAULT_POWER_WATTS};

    #[test]
    fn parses_a_trace_with_comments_and_defaults() {
        let text = "# two-device fleet\n\
                    {\"name\":\"edge\",\"scale\":2.0,\"power_watts\":8.5}\n\
                    \n\
                    {\"name\":\"phone\",\"scale\":0.5,\"up_mbps\":5,\"energy\":\"battery\",\"depart\":3600}\n";
        let ps = parse_trace(text).unwrap();
        assert_eq!(ps.len(), 2);
        assert_eq!(ps[0].device.name, "edge");
        assert_eq!(ps[0].device.power_watts, 8.5);
        assert_eq!(ps[0].up_mbps, 0.0);
        assert!(ps[0].depart_secs.is_infinite());
        assert_eq!(ps[1].energy, EnergyClass::Battery);
        assert_eq!(ps[1].device.power_watts, DEFAULT_POWER_WATTS);
        assert_eq!(ps[1].depart_secs, 3600.0);
    }

    #[test]
    fn rejects_bad_lines_with_line_numbers() {
        let err = parse_trace("{\"name\":\"a\",\"scale\":1}\nnot json\n").unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
        let err = parse_trace("{\"name\":\"a\",\"scale\":0}\n").unwrap_err().to_string();
        assert!(err.contains("line 1"), "{err}");
        assert!(parse_trace("\n# only comments\n").is_err());
    }

    #[test]
    fn load_trace_reads_a_file() {
        let dir = std::env::temp_dir().join(format!("fleet_trace_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("fleet.jsonl");
        std::fs::write(&path, "{\"name\":\"a\",\"scale\":1.5}\n").unwrap();
        let ps = load_trace(&path).unwrap();
        assert_eq!(ps.len(), 1);
        assert_eq!(ps[0].device.scale, 1.5);
        assert!(load_trace(&dir.join("missing.jsonl")).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
