//! Registered fleet generators: distributions over device types that a
//! [`super::LazyFleet`] samples from by pure per-client hashing.
//!
//! Three families are registered (the `fleet=lazyN[:gen]` spec):
//!
//! - `uniform` (the default) — equal weight over the registered sim device
//!   types ([`DeviceProfile::sim_types`]).
//! - `cat:w1,w2,...` — categorical over those same types, one weight per
//!   type in registry order.
//! - `lognormal:mu:sigma` — a lognormal compute-scale spectrum, quantized
//!   into [`LOGNORMAL_BUCKETS`] equiprobable device types at the quantile
//!   midpoints `exp(mu + sigma * Phi^-1((i + 0.5) / B))`. Quantization
//!   keeps the type set finite (one timing model per type backs a lazy
//!   fleet) while preserving the distribution's shape and tails.

use crate::timing::DeviceProfile;

/// Bucket count for the quantized lognormal scale spectrum.
pub const LOGNORMAL_BUCKETS: usize = 32;

#[derive(Clone, Debug, PartialEq)]
pub enum GeneratorSpec {
    /// Uniform over [`DeviceProfile::sim_types`].
    Uniform,
    /// Categorical over [`DeviceProfile::sim_types`], one weight per type.
    Categorical(Vec<f64>),
    /// Lognormal compute scale: `ln(scale) ~ Normal(mu, sigma)`.
    LogNormal { mu: f64, sigma: f64 },
}

impl GeneratorSpec {
    /// Parse the generator suffix of a `lazyN:<gen>` fleet spec.
    pub fn parse(s: &str) -> anyhow::Result<GeneratorSpec> {
        if s == "uniform" {
            return Ok(GeneratorSpec::Uniform);
        }
        if let Some(rest) = s.strip_prefix("cat:") {
            let weights: Vec<f64> = rest
                .split(',')
                .map(|w| {
                    w.parse::<f64>()
                        .map_err(|_| anyhow::anyhow!("bad categorical weight {w:?} in {s:?}"))
                })
                .collect::<anyhow::Result<_>>()?;
            let spec = GeneratorSpec::Categorical(weights);
            spec.weights()?;
            return Ok(spec);
        }
        if let Some(rest) = s.strip_prefix("lognormal:") {
            let (mu, sigma) = rest
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("lognormal generator needs mu:sigma, got {s:?}"))?;
            let mu: f64 = mu.parse().map_err(|_| anyhow::anyhow!("bad lognormal mu in {s:?}"))?;
            let sigma: f64 =
                sigma.parse().map_err(|_| anyhow::anyhow!("bad lognormal sigma in {s:?}"))?;
            anyhow::ensure!(
                mu.is_finite() && sigma.is_finite() && sigma > 0.0,
                "lognormal generator needs finite mu and sigma > 0, got {s:?}"
            );
            return Ok(GeneratorSpec::LogNormal { mu, sigma });
        }
        anyhow::bail!("unknown fleet generator {s:?} (uniform | cat:w1,w2,... | lognormal:mu:sigma)")
    }

    /// Exact inverse of [`GeneratorSpec::parse`] (specs round-trip through
    /// config snapshots as labels).
    pub fn label(&self) -> String {
        match self {
            GeneratorSpec::Uniform => "uniform".to_string(),
            GeneratorSpec::Categorical(w) => {
                let ws: Vec<String> = w.iter().map(|x| x.to_string()).collect();
                format!("cat:{}", ws.join(","))
            }
            GeneratorSpec::LogNormal { mu, sigma } => format!("lognormal:{mu}:{sigma}"),
        }
    }

    /// The finite device-type set this generator draws from.
    pub fn device_types(&self) -> Vec<DeviceProfile> {
        match self {
            GeneratorSpec::Uniform | GeneratorSpec::Categorical(_) => DeviceProfile::sim_types(),
            GeneratorSpec::LogNormal { mu, sigma } => (0..LOGNORMAL_BUCKETS)
                .map(|i| {
                    let p = (i as f64 + 0.5) / LOGNORMAL_BUCKETS as f64;
                    let scale = (mu + sigma * norm_quantile(p)).exp();
                    DeviceProfile::new(&format!("lognorm{i:02}"), scale, super::DEFAULT_POWER_WATTS)
                })
                .collect(),
        }
    }

    /// Per-type sampling weights, aligned with [`GeneratorSpec::device_types`].
    pub fn weights(&self) -> anyhow::Result<Vec<f64>> {
        match self {
            GeneratorSpec::Uniform => Ok(vec![1.0; DeviceProfile::sim_types().len()]),
            GeneratorSpec::LogNormal { .. } => Ok(vec![1.0; LOGNORMAL_BUCKETS]),
            GeneratorSpec::Categorical(w) => {
                let n_types = DeviceProfile::sim_types().len();
                anyhow::ensure!(
                    w.len() == n_types,
                    "categorical generator needs {n_types} weights (one per registered device type), got {}",
                    w.len()
                );
                anyhow::ensure!(
                    w.iter().all(|x| x.is_finite() && *x >= 0.0),
                    "categorical weights must be finite and >= 0"
                );
                anyhow::ensure!(w.iter().sum::<f64>() > 0.0, "categorical weights sum to zero");
                Ok(w.clone())
            }
        }
    }
}

/// erf via the Abramowitz–Stegun 7.1.26 rational approximation
/// (|error| < 1.5e-7) — deterministic and dependency-free.
fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let poly = t
        * (0.254829592
            + t * (-0.284496736 + t * (1.421413741 + t * (-1.453152027 + t * 1.061405429))));
    sign * (1.0 - poly * (-x * x).exp())
}

fn norm_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// Inverse standard-normal CDF by bisection of [`norm_cdf`]: ~60
/// halvings of [-8, 8] pin x to ~1e-16, and monotonicity of the bracket
/// is exact regardless of the erf approximation's absolute error.
fn norm_quantile(p: f64) -> f64 {
    debug_assert!(p > 0.0 && p < 1.0);
    let (mut lo, mut hi) = (-8.0f64, 8.0f64);
    for _ in 0..60 {
        let mid = 0.5 * (lo + hi);
        if norm_cdf(mid) < p {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_label_round_trip() {
        for s in ["uniform", "cat:1,2,3,4", "lognormal:0:0.5", "lognormal:-0.25:1"] {
            let g = GeneratorSpec::parse(s).unwrap();
            assert_eq!(g.label(), s);
            assert_eq!(GeneratorSpec::parse(&g.label()).unwrap(), g);
        }
        assert!(GeneratorSpec::parse("zipf:2").is_err());
        assert!(GeneratorSpec::parse("cat:1,2").is_err(), "wrong weight count");
        assert!(GeneratorSpec::parse("cat:1,-2,3,4").is_err(), "negative weight");
        assert!(GeneratorSpec::parse("lognormal:0:-1").is_err(), "sigma <= 0");
    }

    #[test]
    fn norm_quantile_is_monotone_and_symmetric() {
        let mut last = f64::NEG_INFINITY;
        for i in 1..100 {
            let q = norm_quantile(i as f64 / 100.0);
            assert!(q > last, "quantile not monotone at {i}");
            last = q;
        }
        assert!(norm_quantile(0.5).abs() < 1e-6);
        assert!((norm_quantile(0.975) - 1.96).abs() < 1e-2);
        assert!((norm_quantile(0.025) + norm_quantile(0.975)).abs() < 1e-6);
    }

    #[test]
    fn lognormal_types_follow_the_distribution() {
        let g = GeneratorSpec::LogNormal { mu: 0.0, sigma: 0.5 };
        let types = g.device_types();
        assert_eq!(types.len(), LOGNORMAL_BUCKETS);
        // Scales are positive, increasing, and median-centered at e^mu = 1.
        let mut last = 0.0;
        for t in &types {
            assert!(t.scale > last);
            last = t.scale;
        }
        let mid = 0.5 * (types[15].scale + types[16].scale);
        assert!((mid.ln()).abs() < 0.05, "median scale {mid}");
    }
}
