//! First-class fleet modeling: who the clients are, how they are wired,
//! and when they are reachable.
//!
//! A fleet used to be a small eagerly-allocated `Vec<DeviceProfile>` with
//! three hardcoded shapes. This module promotes it to a subsystem:
//!
//! - [`ClientProfile`] enriches a compute-scale [`DeviceProfile`] with
//!   per-client up/down link rates, an [`EnergyClass`], and a one-shot
//!   availability window.
//! - [`GeneratorSpec`] (see [`generator`]) draws profiles from registered
//!   distributions — uniform / categorical over the registered device
//!   types, or a lognormal compute-scale spectrum.
//! - [`trace`] loads schema-validated JSONL traces; parsed profiles are
//!   inlined into the run manifest so resume never re-reads the file.
//! - [`LazyFleet`] + [`FleetView`] yield profiles by client id as a pure
//!   function of (seed, generator spec): a million-client fleet allocates
//!   O(device types), not O(n).
//! - [`ChurnCfg`] models availability churn (periodic on/off windows and
//!   mid-round dropout) as pure draws over (seed, client, iteration) —
//!   deterministic across thread counts and kill/resume by construction.
//!
//! Layering: `fleet` depends only on [`crate::timing`] and [`crate::util`].

pub mod generator;
pub mod trace;

pub use generator::GeneratorSpec;

use crate::timing::DeviceProfile;
use crate::util::json::Json;
use crate::util::rng::splitmix64;

/// Power draw assumed for devices that do not declare one — the
/// [`crate::config::FleetSpec::Scales`] shorthand and trace lines without a
/// `power_watts` key. Custom powers come from a generator's device types or
/// a JSONL trace; [`crate::metrics::energy`] reports reflect whichever was
/// used.
pub const DEFAULT_POWER_WATTS: f64 = 12.0;

/// How a device is powered — trace metadata surfaced to energy reporting
/// and (eventually) availability policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EnergyClass {
    Mains,
    Battery,
}

impl EnergyClass {
    pub fn as_str(&self) -> &'static str {
        match self {
            EnergyClass::Mains => "mains",
            EnergyClass::Battery => "battery",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<EnergyClass> {
        match s {
            "mains" => Ok(EnergyClass::Mains),
            "battery" => Ok(EnergyClass::Battery),
            other => anyhow::bail!("unknown energy class {other:?} (mains | battery)"),
        }
    }
}

/// One client of a fleet: compute profile plus the per-client link and
/// availability attributes a bare [`DeviceProfile`] cannot carry.
#[derive(Clone, Debug, PartialEq)]
pub struct ClientProfile {
    pub device: DeviceProfile,
    /// Uplink rate in Mbit/s; 0 = inherit the experiment-wide comm model.
    pub up_mbps: f64,
    /// Downlink rate in Mbit/s; 0 = inherit the experiment-wide comm model.
    pub down_mbps: f64,
    pub energy: EnergyClass,
    /// Sim time at which the client first comes online.
    pub arrive_secs: f64,
    /// Sim time at which the client permanently departs; uploads arriving
    /// at or after this instant are discarded. `f64::INFINITY` = never.
    pub depart_secs: f64,
}

impl ClientProfile {
    /// A plain always-available, fleet-wide-comm client around `device`.
    pub fn plain(device: DeviceProfile) -> ClientProfile {
        ClientProfile {
            device,
            up_mbps: 0.0,
            down_mbps: 0.0,
            energy: EnergyClass::Mains,
            arrive_secs: 0.0,
            depart_secs: f64::INFINITY,
        }
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.device.name.is_empty(), "client profile with an empty device name");
        anyhow::ensure!(
            self.device.scale.is_finite() && self.device.scale > 0.0,
            "device {:?}: scale must be finite and > 0 (got {})",
            self.device.name,
            self.device.scale
        );
        anyhow::ensure!(
            self.device.power_watts.is_finite() && self.device.power_watts >= 0.0,
            "device {:?}: power_watts must be finite and >= 0",
            self.device.name
        );
        for (key, v) in [("up_mbps", self.up_mbps), ("down_mbps", self.down_mbps)] {
            anyhow::ensure!(
                v.is_finite() && v >= 0.0,
                "device {:?}: {key} must be finite and >= 0",
                self.device.name
            );
        }
        anyhow::ensure!(
            self.arrive_secs.is_finite() && self.arrive_secs >= 0.0,
            "device {:?}: arrive must be finite and >= 0",
            self.device.name
        );
        anyhow::ensure!(
            self.depart_secs > self.arrive_secs,
            "device {:?}: depart ({}) must be > arrive ({})",
            self.device.name,
            self.depart_secs,
            self.arrive_secs
        );
        Ok(())
    }

    /// Serialize; attributes at their defaults are omitted so plain
    /// profiles stay one short line (and `depart: inf` never needs to be
    /// spelled in JSON, which has no infinity literal).
    pub fn to_json(&self) -> Json {
        let mut kv: Vec<(String, Json)> = vec![
            ("name".into(), Json::Str(self.device.name.clone())),
            ("scale".into(), Json::Num(self.device.scale)),
        ];
        if self.device.power_watts != DEFAULT_POWER_WATTS {
            kv.push(("power_watts".into(), Json::Num(self.device.power_watts)));
        }
        if self.up_mbps != 0.0 {
            kv.push(("up_mbps".into(), Json::Num(self.up_mbps)));
        }
        if self.down_mbps != 0.0 {
            kv.push(("down_mbps".into(), Json::Num(self.down_mbps)));
        }
        if self.energy != EnergyClass::Mains {
            kv.push(("energy".into(), Json::Str(self.energy.as_str().into())));
        }
        if self.arrive_secs != 0.0 {
            kv.push(("arrive".into(), Json::Num(self.arrive_secs)));
        }
        if self.depart_secs.is_finite() {
            kv.push(("depart".into(), Json::Num(self.depart_secs)));
        }
        Json::Obj(kv)
    }

    /// Parse one profile object (a trace line or a manifest snapshot
    /// entry). Unknown keys are rejected — traces are hand-written, and a
    /// typo'd `dpart` silently meaning "never departs" is the failure mode
    /// schemas exist to prevent.
    pub fn from_json(j: &Json) -> anyhow::Result<ClientProfile> {
        let obj = match j {
            Json::Obj(kv) => kv,
            _ => anyhow::bail!("client profile must be a JSON object"),
        };
        for (k, _) in obj {
            anyhow::ensure!(
                matches!(
                    k.as_str(),
                    "name" | "scale" | "power_watts" | "up_mbps" | "down_mbps" | "energy"
                        | "arrive" | "depart"
                ),
                "client profile: unknown key {k:?} (name scale power_watts up_mbps down_mbps energy arrive depart)"
            );
        }
        let f = |k: &str, d: f64| j.get(k).and_then(Json::as_f64).unwrap_or(d);
        let p = ClientProfile {
            device: DeviceProfile::new(
                j.get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow::anyhow!("client profile: missing \"name\""))?,
                j.get("scale")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| anyhow::anyhow!("client profile: missing numeric \"scale\""))?,
                f("power_watts", DEFAULT_POWER_WATTS),
            ),
            up_mbps: f("up_mbps", 0.0),
            down_mbps: f("down_mbps", 0.0),
            energy: match j.get("energy").and_then(Json::as_str) {
                Some(s) => EnergyClass::parse(s)?,
                None => EnergyClass::Mains,
            },
            arrive_secs: f("arrive", 0.0),
            depart_secs: f("depart", f64::INFINITY),
        };
        p.validate()?;
        Ok(p)
    }
}

/// Yields client profiles by id on demand. Eager fleets are backed by a
/// `Vec`; [`LazyFleet`] derives each profile as a pure function of
/// (seed, generator spec, client id), so holding a view of a 1M-client
/// fleet costs O(device types) memory.
pub trait FleetView {
    fn len(&self) -> usize;
    fn profile(&self, client: usize) -> ClientProfile;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl FleetView for Vec<ClientProfile> {
    fn len(&self) -> usize {
        self.as_slice().len()
    }
    fn profile(&self, client: usize) -> ClientProfile {
        self[client].clone()
    }
}

/// A generated fleet that never materializes: client `c`'s device type is
/// a pure hash of `(seed, c)` bucketed by the generator's type weights.
#[derive(Clone, Debug)]
pub struct LazyFleet {
    pub n: usize,
    pub seed: u64,
    pub spec: GeneratorSpec,
    /// The generator's device types (small: O(types)).
    types: Vec<DeviceProfile>,
    /// Cumulative normalized type weights, same length as `types`.
    cum: Vec<f64>,
}

impl LazyFleet {
    pub fn new(n: usize, spec: GeneratorSpec, seed: u64) -> anyhow::Result<LazyFleet> {
        anyhow::ensure!(n > 0, "lazy fleet must have at least one client");
        let types = spec.device_types();
        let weights = spec.weights()?;
        debug_assert_eq!(types.len(), weights.len());
        let total: f64 = weights.iter().sum();
        let mut acc = 0.0;
        let cum = weights
            .iter()
            .map(|w| {
                acc += w / total;
                acc
            })
            .collect();
        Ok(LazyFleet { n, seed, spec, types, cum })
    }

    pub fn device_types(&self) -> &[DeviceProfile] {
        &self.types
    }

    /// Index into [`LazyFleet::device_types`] for one client — pure in
    /// (seed, client), so any subset of the fleet can be inspected in any
    /// order with identical results.
    pub fn type_of(&self, client: usize) -> usize {
        let u = unit_draw(self.seed ^ 0xF1EE7_1A2, client as u64, 0);
        self.cum.partition_point(|&c| c <= u).min(self.types.len() - 1)
    }
}

impl FleetView for LazyFleet {
    fn len(&self) -> usize {
        self.n
    }
    fn profile(&self, client: usize) -> ClientProfile {
        ClientProfile::plain(self.types[self.type_of(client)].clone())
    }
}

/// Availability churn, swept through `fleet.churn.*` keys. All decisions
/// are pure hashes of (experiment seed, client, iteration/time): no RNG
/// state to checkpoint, so bitwise kill/resume and thread-count
/// determinism hold by construction.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChurnCfg {
    /// Probability that a finished update is discarded — the client died
    /// mid-round after training started. In `[0, 1)`.
    pub dropout: f64,
    /// Availability cycle length in sim seconds; 0 = always online.
    pub period_secs: f64,
    /// Fraction of each cycle a client spends online, `(0, 1]`.
    pub avail_frac: f64,
}

impl ChurnCfg {
    pub fn active(&self) -> bool {
        self.dropout > 0.0 || (self.period_secs > 0.0 && self.avail_frac < 1.0)
    }

    /// Does churn discard this client's `iter`-th update on arrival?
    pub fn dropout_hits(&self, seed: u64, client: usize, iter: u64) -> bool {
        self.dropout > 0.0 && unit_draw(seed ^ 0xD0D0_0001, client as u64, iter) < self.dropout
    }

    /// Is the client inside its availability window at sim time `t`? Each
    /// client's cycle gets a deterministic phase offset so the fleet's
    /// availability is staggered rather than synchronized.
    pub fn online(&self, seed: u64, client: usize, t: f64) -> bool {
        if self.period_secs <= 0.0 || self.avail_frac >= 1.0 {
            return true;
        }
        let phase = unit_draw(seed ^ 0xD0D0_0002, client as u64, 0) * self.period_secs;
        let pos = (t + phase) % self.period_secs;
        pos < self.avail_frac * self.period_secs
    }
}

/// Per-client fleet attributes the round loops consume, alongside the
/// timing models. `Default` is the classic eager fleet: no lazy view, no
/// per-client links or windows.
#[derive(Clone, Debug, Default)]
pub struct FleetInfo {
    /// `Some` = generated lazy fleet; timing models are per device *type*
    /// and clients map onto them via [`LazyFleet::type_of`]. `None` =
    /// eager fleet with one timing model per client.
    pub lazy: Option<LazyFleet>,
    /// Per-client `(up_mbps, down_mbps)` link overrides from a trace;
    /// empty = every client uses the experiment-wide comm model.
    pub links: Vec<(f64, f64)>,
    /// Per-client one-shot `(arrive_secs, depart_secs)` windows from a
    /// trace; empty = every client is present for the whole run.
    pub windows: Vec<(f64, f64)>,
}

impl FleetInfo {
    /// Earliest time `client` can start a dispatch at or after `now`.
    pub fn start_at(&self, client: usize, now: f64) -> f64 {
        match self.windows.get(client) {
            Some(&(arrive, _)) => now.max(arrive),
            None => now,
        }
    }

    /// Has `client` permanently departed by sim time `t`?
    pub fn departed(&self, client: usize, t: f64) -> bool {
        matches!(self.windows.get(client), Some(&(_, depart)) if t >= depart)
    }

    /// Had `client` arrived by sim time `t`?
    pub fn arrived(&self, client: usize, t: f64) -> bool {
        match self.windows.get(client) {
            Some(&(arrive, _)) => t >= arrive,
            None => true,
        }
    }
}

/// A uniform draw in `[0, 1)` as a pure function of `(seed, a, b)` — the
/// substrate for every churn/sampling decision. Two rounds of the
/// splitmix64 finalizer give full avalanche over the xor-folded words.
pub fn unit_draw(seed: u64, a: u64, b: u64) -> f64 {
    let mut s = seed
        ^ a.wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ b.wrapping_mul(0xD1B5_4A32_D192_ED03);
    let _ = splitmix64(&mut s);
    let z = splitmix64(&mut s);
    (z >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_draw_is_pure_and_uniform_ish() {
        assert_eq!(unit_draw(7, 3, 9), unit_draw(7, 3, 9));
        let n: u64 = 10_000;
        let mean = (0..n).map(|i| unit_draw(42, i, 0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
        for i in 0..n {
            let u = unit_draw(42, i, 0);
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn profile_json_round_trips() {
        let mut p = ClientProfile::plain(DeviceProfile::new("phone", 0.5, 3.0));
        p.up_mbps = 2.0;
        p.energy = EnergyClass::Battery;
        p.arrive_secs = 100.0;
        p.depart_secs = 5000.0;
        let back = ClientProfile::from_json(&p.to_json()).unwrap();
        assert_eq!(p, back);
        // Defaults are omitted and restored.
        let plain = ClientProfile::plain(DeviceProfile::new("d", 1.0, DEFAULT_POWER_WATTS));
        let j = plain.to_json();
        for omitted in ["power_watts", "up_mbps", "down_mbps", "energy", "arrive", "depart"] {
            assert!(j.get(omitted).is_none(), "{omitted} should be omitted at default");
        }
        assert_eq!(ClientProfile::from_json(&j).unwrap(), plain);
    }

    #[test]
    fn profile_json_rejects_garbage() {
        let bad = Json::parse("{\"name\":\"d\",\"scale\":1,\"dpart\":5}").unwrap();
        assert!(ClientProfile::from_json(&bad).unwrap_err().to_string().contains("unknown key"));
        let nan_scale = Json::parse("{\"name\":\"d\",\"scale\":-1}").unwrap();
        assert!(ClientProfile::from_json(&nan_scale).is_err());
        let inverted = Json::parse("{\"name\":\"d\",\"scale\":1,\"arrive\":10,\"depart\":5}").unwrap();
        assert!(ClientProfile::from_json(&inverted).is_err());
    }

    #[test]
    fn lazy_fleet_is_pure_and_small() {
        let lf = LazyFleet::new(1_000_000, GeneratorSpec::Uniform, 9).unwrap();
        assert_eq!(lf.len(), 1_000_000);
        assert!(lf.device_types().len() <= 8);
        // Pure per-id: re-querying and cross-instance agreement.
        let lf2 = LazyFleet::new(1_000_000, GeneratorSpec::Uniform, 9).unwrap();
        for c in [0usize, 1, 17, 999_999] {
            assert_eq!(lf.type_of(c), lf2.type_of(c));
            assert_eq!(lf.profile(c), lf.profile(c));
        }
        // All types are reachable.
        let mut seen = vec![0usize; lf.device_types().len()];
        for c in 0..4096 {
            seen[lf.type_of(c)] += 1;
        }
        assert!(seen.iter().all(|&s| s > 0), "type histogram {seen:?}");
    }

    #[test]
    fn churn_draws_are_deterministic() {
        let ch = ChurnCfg { dropout: 0.3, period_secs: 1000.0, avail_frac: 0.6 };
        assert!(ch.active());
        let hits: Vec<bool> = (0..64).map(|i| ch.dropout_hits(5, 3, i)).collect();
        assert_eq!(hits, (0..64).map(|i| ch.dropout_hits(5, 3, i)).collect::<Vec<_>>());
        let frac = (0..1000).filter(|&i| ch.dropout_hits(5, i as usize, 0)).count();
        assert!((200..400).contains(&frac), "dropout rate {frac}/1000");
        // Availability covers roughly avail_frac of each client's timeline.
        let online = (0..1000).filter(|&k| ch.online(5, 7, k as f64)).count();
        assert!((500..700).contains(&online), "online {online}/1000");
        // Inactive config is always online and never drops.
        let off = ChurnCfg { dropout: 0.0, period_secs: 0.0, avail_frac: 1.0 };
        assert!(!off.active());
        assert!(off.online(5, 7, 123.0));
        assert!(!off.dropout_hits(5, 7, 1));
    }
}
