//! Deterministic successive halving over campaign cells.
//!
//! Related systems (TimelyFL's deadline-bounded rounds, adaptive-dropout
//! FL) make the case for reallocating budget away from low-value work;
//! at the sweep layer that means killing hopeless cells early. This
//! module decides *which* cells: at each rung — a shared round boundary
//! aligned to the checkpoint cadence — live cells are ranked by their
//! eval metric and only the top `keep_frac` survive. The knobs ride the
//! registered parameter space (`--set operator.halving.rungs=2`,
//! `operator.halving.keep_frac`, `operator.halving.metric`), so they
//! persist in the campaign spec like any other knob.
//!
//! [`plan_prunes`] is a **pure function of (spec, observed status)** and
//! recomputes every rung from scratch on every call, ignoring persisted
//! prune flags. That makes it idempotent and crash-safe by construction:
//! however many operators run it, however often, at whatever point they
//! died last time, the decisions come out identical — a rung's ranking
//! depends only on eval records at or before its boundary, which never
//! change once written. Callers apply decisions as a union (never
//! un-prune), so a raced double-application is harmless.

use crate::config::params::ParamSpace;
use crate::operator::status::{CampaignStatus, CellStatusRow};
use crate::sim::campaign::CampaignCfg;

/// One cell the policy wants retired.
#[derive(Clone, Debug, PartialEq)]
pub struct PruneDecision {
    pub label: String,
    /// The rung boundary (absolute round) the cell lost at.
    pub rung_round: usize,
    /// The metric value it was ranked by (`None` = no eval recorded by
    /// the boundary, which ranks worst).
    pub metric: Option<f64>,
}

/// The rung boundaries for a `rounds`-round campaign: `rungs` cuts at
/// even fractions of the budget, each aligned UP to the checkpoint
/// cadence (so every cell pausing there has a durable checkpoint),
/// deduplicated, and dropped when they'd land at or past the final
/// round (nothing left to save by then).
pub fn rung_rounds(rounds: usize, checkpoint_every: usize, rungs: usize) -> Vec<usize> {
    let every = checkpoint_every.max(1);
    let mut out = Vec::new();
    for i in 1..=rungs {
        let raw = rounds * i / (rungs + 1);
        let aligned = raw.div_ceil(every) * every;
        if aligned == 0 || aligned >= rounds {
            continue;
        }
        if out.last() != Some(&aligned) {
            out.push(aligned);
        }
    }
    out
}

/// The campaign's effective halving knobs: base config plus the `--set`
/// overlay (the same precedence every cell resolves with — axes don't
/// carry operator keys, so base+set is the whole story).
fn effective(cfg: &CampaignCfg) -> anyhow::Result<crate::config::ExperimentCfg> {
    let mut eff = cfg.base.clone();
    cfg.set.apply(ParamSpace::shared(), &mut eff)?;
    Ok(eff)
}

/// The rung boundaries the campaign's effective config implies. The
/// worker uses them as segment halt targets, so every cell pauses at
/// each rung with a durable checkpoint instead of racing past it.
pub fn cfg_rungs(cfg: &CampaignCfg) -> anyhow::Result<Vec<usize>> {
    let eff = effective(cfg)?;
    Ok(rung_rounds(eff.rounds, cfg.checkpoint_every, eff.halving_rungs))
}

/// The cell's ranking metric at a rung boundary: the last eval at or
/// before round `boundary`. Records past the boundary are ignored so a
/// cell that raced ahead is judged at the same round as everyone else.
fn metric_at(row: &CellStatusRow, boundary: usize, metric: &str) -> Option<f64> {
    let run = row.run.as_ref()?;
    let upto = &run.records[..boundary.min(run.records.len())];
    match metric {
        "loss" => upto.iter().rev().find_map(|r| r.eval_loss),
        _ => upto.iter().rev().find_map(|r| r.eval_acc),
    }
}

/// Every cell the policy wants pruned, given what the store shows now.
/// Recomputed from scratch (see module docs); the result is the union of
/// all rungs that have *fired* — a rung fires once every cell still live
/// at it has progressed to its boundary. Ranking: higher accuracy (or
/// lower loss) survives; a missing metric ranks worst; ties break toward
/// the lower cell index. `ceil(keep_frac × live)` cells (at least one)
/// survive each rung.
pub fn plan_prunes(
    cfg: &CampaignCfg,
    status: &CampaignStatus,
) -> anyhow::Result<Vec<PruneDecision>> {
    let eff = effective(cfg)?;
    if eff.halving_rungs == 0 || status.cells.len() < 2 {
        return Ok(Vec::new());
    }
    let higher_better = eff.halving_metric != "loss";
    let boundaries = rung_rounds(eff.rounds, cfg.checkpoint_every, eff.halving_rungs);
    let mut live: Vec<usize> = (0..status.cells.len()).collect();
    let mut decisions = Vec::new();
    for &b in &boundaries {
        // The rung fires only when every live cell reached the boundary
        // (a complete run trivially has). Until then — and this includes
        // "a worker is still grinding the laggard" — no decision.
        if live.iter().any(|&i| status.cells[i].rounds_done < b) {
            break;
        }
        let keep = ((eff.halving_keep_frac * live.len() as f64).ceil() as usize).max(1);
        if keep >= live.len() {
            continue;
        }
        let mut ranked: Vec<(usize, Option<f64>)> = live
            .iter()
            .map(|&i| (i, metric_at(&status.cells[i], b, &eff.halving_metric)))
            .collect();
        ranked.sort_by(|(ia, ma), (ib, mb)| {
            let ord = match (ma, mb) {
                (Some(x), Some(y)) => {
                    if higher_better {
                        y.total_cmp(x)
                    } else {
                        x.total_cmp(y)
                    }
                }
                (Some(_), None) => std::cmp::Ordering::Less,
                (None, Some(_)) => std::cmp::Ordering::Greater,
                (None, None) => std::cmp::Ordering::Equal,
            };
            ord.then(ia.cmp(ib))
        });
        let losers = ranked.split_off(keep);
        for (i, metric) in losers {
            decisions.push(PruneDecision {
                label: status.cells[i].label.clone(),
                rung_round: b,
                metric,
            });
        }
        live = ranked.into_iter().map(|(i, _)| i).collect();
    }
    Ok(decisions)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentCfg;
    use crate::fl::server::RoundRecord;
    use crate::operator::status::CampaignStatus;
    use crate::store::schema::{RunManifest, RunStatus};

    #[test]
    fn rung_boundaries_align_up_to_checkpoints_and_stay_inside_the_run() {
        assert_eq!(rung_rounds(20, 5, 1), vec![10]);
        assert_eq!(rung_rounds(20, 5, 3), vec![5, 10, 15]);
        // 12 rounds, cadence 5, 2 rungs: raw cuts 4, 8 -> aligned 5, 10
        assert_eq!(rung_rounds(12, 5, 2), vec![5, 10]);
        // boundaries at/past the final round are dropped, duplicates fold
        assert_eq!(rung_rounds(6, 5, 3), vec![5]);
        assert_eq!(rung_rounds(4, 5, 2), Vec::<usize>::new());
        assert_eq!(rung_rounds(20, 5, 0), Vec::<usize>::new());
    }

    fn record(round: usize, acc: Option<f64>) -> RoundRecord {
        RoundRecord {
            round,
            round_secs: 1.0,
            sim_time: round as f64,
            mean_train_loss: 1.0,
            participants: 1,
            mean_coverage: 1.0,
            o1: 0.0,
            eval_acc: acc,
            eval_loss: acc.map(|a| 1.0 - a),
            client_secs: vec![],
            mean_staleness: None,
            max_staleness: None,
            dropped: vec![],
            spec_hits: 0,
            spec_misses: 0,
        }
    }

    fn row_with_run(
        index: usize,
        label: &str,
        rounds_done: usize,
        accs: &[Option<f64>],
    ) -> CellStatusRow {
        let cfg = ExperimentCfg { rounds: 8, ..Default::default() };
        let records: Vec<RoundRecord> =
            (0..rounds_done).map(|r| record(r, accs.get(r).copied().flatten())).collect();
        let run = RunManifest {
            schema_version: crate::store::schema::SCHEMA_VERSION,
            id: format!("run-{label}"),
            created_unix: 0,
            updated_unix: 0,
            status: RunStatus::Running,
            strategy: "fedavg".into(),
            config: cfg,
            records,
            checkpoint: None,
            final_state: None,
        };
        CellStatusRow {
            index,
            label: label.into(),
            run_id: Some(run.id.clone()),
            worker: None,
            lease_age_secs: None,
            pruned: false,
            state: "resumable",
            rounds_done,
            rounds_total: Some(8),
            final_acc: None,
            run: Some(run),
        }
    }

    fn halving_cfg() -> CampaignCfg {
        let base = ExperimentCfg {
            rounds: 8,
            halving_rungs: 1,
            halving_keep_frac: 0.5,
            ..Default::default()
        };
        let mut cfg = CampaignCfg::new("halve", base);
        cfg.checkpoint_every = 2;
        cfg
    }

    fn status_of(cells: Vec<CellStatusRow>) -> CampaignStatus {
        CampaignStatus { name: "halve".into(), observed_unix: 0, cells }
    }

    #[test]
    fn rung_waits_for_laggards_then_prunes_the_bottom_half_deterministically() {
        let cfg = halving_cfg();
        // rounds=8, cadence 2, 1 rung -> boundary at round 4
        assert_eq!(rung_rounds(8, 2, 1), vec![4]);
        let acc = |xs: &[f64]| xs.iter().map(|&a| Some(a)).collect::<Vec<_>>();
        // a laggard below the boundary holds the rung
        let held = status_of(vec![
            row_with_run(0, "a", 4, &acc(&[0.1, 0.2, 0.3, 0.4])),
            row_with_run(1, "b", 3, &acc(&[0.1, 0.1, 0.1])),
        ]);
        assert!(plan_prunes(&cfg, &held).unwrap().is_empty());
        // all cells at/past the boundary: bottom half pruned, ranked by
        // the last eval at or before round 4 (extra progress ignored)
        let fired = status_of(vec![
            row_with_run(0, "a", 4, &acc(&[0.1, 0.2, 0.3, 0.4])),
            row_with_run(1, "b", 6, &acc(&[0.1, 0.1, 0.1, 0.1, 0.9, 0.9])),
            row_with_run(2, "c", 4, &acc(&[0.1, 0.2, 0.3, 0.35])),
            row_with_run(3, "d", 4, &[None, None, None, None]),
        ]);
        let decisions = plan_prunes(&cfg, &fired).unwrap();
        let labels: Vec<&str> = decisions.iter().map(|d| d.label.as_str()).collect();
        // keep = ceil(0.5 * 4) = 2 -> "a" (0.4) and "c" (0.35) survive;
        // "b"'s late 0.9 is past the boundary and doesn't count (0.1 at
        // rung), "d" never evaluated and ranks worst
        assert_eq!(labels, vec!["b", "d"]);
        assert_eq!(decisions[0].rung_round, 4);
        assert_eq!(decisions[0].metric, Some(0.1));
        assert_eq!(decisions[1].metric, None);
        // pure function: same observed state, same answer
        assert_eq!(plan_prunes(&cfg, &fired).unwrap(), decisions);
    }

    #[test]
    fn later_rungs_ignore_earlier_losers_stalled_progress() {
        let mut cfg = halving_cfg();
        cfg.base.halving_rungs = 2;
        // rounds=8, cadence 2, 2 rungs -> raw cuts 2, 5 -> boundaries 2, 6
        assert_eq!(rung_rounds(8, 2, 2), vec![2, 6]);
        let acc = |xs: &[f64]| xs.iter().map(|&a| Some(a)).collect::<Vec<_>>();
        // rung 1 (round 2) prunes the two weakest of four; their frozen
        // progress (2 rounds) must not block rung 2 for the survivors
        let status = status_of(vec![
            row_with_run(0, "a", 6, &acc(&[0.1, 0.40, 0.5, 0.5, 0.5, 0.60])),
            row_with_run(1, "b", 6, &acc(&[0.1, 0.35, 0.5, 0.5, 0.5, 0.70])),
            row_with_run(2, "c", 2, &acc(&[0.1, 0.20])),
            row_with_run(3, "d", 2, &acc(&[0.1, 0.10])),
        ]);
        let decisions = plan_prunes(&cfg, &status).unwrap();
        let got: Vec<(&str, usize)> =
            decisions.iter().map(|d| (d.label.as_str(), d.rung_round)).collect();
        // rung 2 keeps ceil(0.5 * 2) = 1 of the two survivors: "a" loses
        assert_eq!(got, vec![("c", 2), ("d", 2), ("a", 6)]);
    }

    #[test]
    fn halving_off_or_degenerate_grids_prune_nothing() {
        let mut cfg = halving_cfg();
        cfg.base.halving_rungs = 0;
        let acc = |xs: &[f64]| xs.iter().map(|&a| Some(a)).collect::<Vec<_>>();
        let status = status_of(vec![
            row_with_run(0, "a", 8, &acc(&[0.1; 8])),
            row_with_run(1, "b", 8, &acc(&[0.2; 8])),
        ]);
        assert!(plan_prunes(&cfg, &status).unwrap().is_empty());
        // one-cell campaigns never prune (keep >= 1 always)
        let cfg = halving_cfg();
        let solo = status_of(vec![row_with_run(0, "a", 8, &acc(&[0.1; 8]))]);
        assert!(plan_prunes(&cfg, &solo).unwrap().is_empty());
    }
}
