//! The reconciler: a worker loop converging observed state to desired.
//!
//! [`operate`] is what a `campaign operate` process runs. Each pass it
//! re-reads the stored spec (desired state — live edits land between
//! passes), snapshots the store ([`crate::operator::status::observe`],
//! observed state), and takes exactly one convergence step:
//!
//! 1. **Apply policy.** If [`crate::operator::policy::plan_prunes`]
//!    wants cells retired that aren't yet marked, mark them (one CAS
//!    transaction, a union — never un-prune) and go around again.
//! 2. **Done?** Every cell complete-or-pruned → return, converged.
//! 3. **Lease a cell.** Candidates are unfinished, unpruned cells whose
//!    lease is free, ours, or expired — laggards first (lowest rounds
//!    done), so shared rung boundaries unblock as early as possible.
//!    Nothing leasable → sleep one poll interval and go around.
//! 4. **Advance one segment.** Run the cell to its next rung boundary
//!    (or completion when none remain) via the ordinary campaign cell
//!    executor, with a heartbeat observer renewing the lease every few
//!    rounds. Release the lease; go around.
//!
//! Crash recovery needs no extra machinery: a worker that dies mid-cell
//! stops heartbeating, its lease goes stale, and step 3 in any surviving
//! worker reclaims the cell — the run resumes from its checkpoint
//! bitwise-identically (`tests/campaign.rs`). If a presumed-dead worker
//! is actually alive (a stalled VM resuming), both briefly run the same
//! cell; every write is a deterministic function of the run's config and
//! round index, so the double execution is wasted work, not corruption.
//!
//! Any number of operate processes — across hosts, against one served
//! store — cooperate through the same three store primitives (claim,
//! lease, conditional-PUT campaign swap) with no coordinator process.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use crate::fl::observer::RoundObserver;
use crate::fl::server::RoundRecord;
use crate::operator::{policy, status};
use crate::sim::campaign::{self, CampaignCfg, CellRun};
use crate::store::{LeaseOutcome, RunStore};
use crate::util::unix_now;

/// Give up on a cell after this many consecutive failed segments — a
/// deterministic config error (bad model name, unloadable data) fails
/// identically every retry, and retrying it forever would wedge the
/// whole fleet on one cell.
const MAX_CELL_FAILURES: usize = 3;

/// One operate process's knobs (process identity and cadences; the
/// sweep itself lives in the stored campaign spec).
#[derive(Clone, Debug)]
pub struct OperateCfg {
    pub name: String,
    /// Worker identity recorded in leases. Must be unique per process —
    /// the default encodes the pid, which is enough on one host; fleet
    /// deployments should pass `host:pid`.
    pub worker: String,
    /// A lease not heartbeat-renewed for this long is reclaimable.
    pub lease_secs: u64,
    /// Sleep between reconcile passes when nothing is actionable.
    pub poll_secs: u64,
    /// Stop after this many segments (drills/tests; `None` = run to
    /// convergence).
    pub max_segments: Option<usize>,
    /// Per-decision progress lines on stderr.
    pub verbose: bool,
}

impl OperateCfg {
    pub fn new(name: impl Into<String>) -> OperateCfg {
        OperateCfg {
            name: name.into(),
            worker: format!("w{}", std::process::id()),
            lease_secs: 30,
            poll_secs: 2,
            max_segments: None,
            verbose: false,
        }
    }
}

/// What one [`operate`] invocation did.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct OperateOutcome {
    /// Cells this worker drove to completion (final segment ours).
    pub completed: usize,
    /// Checkpoint-aligned segments executed (including final ones).
    pub segments: usize,
    /// Expired leases taken over from dead workers.
    pub reclaimed: usize,
    /// Prune decisions this worker applied to the manifest.
    pub pruned: usize,
    /// Every cell ended complete or pruned (false only when
    /// `max_segments` stopped the loop early).
    pub converged: bool,
}

/// Renews the worker's lease from inside the round loop, so a cell
/// whose segment outlives `lease_secs` isn't "reclaimed" out from under
/// a perfectly live worker. Renewal is best-effort: a store hiccup (or
/// an actual steal, surfacing as [`LeaseOutcome::Held`]) must not abort
/// training — the worst case is a double execution, which determinism
/// makes benign (module docs).
struct LeaseHeartbeat<'a> {
    store: &'a RunStore,
    name: &'a str,
    label: &'a str,
    worker: &'a str,
    lease_secs: u64,
    last: Instant,
}

impl RoundObserver for LeaseHeartbeat<'_> {
    fn on_round_end(&mut self, _record: &RoundRecord) {
        let cadence = (self.lease_secs / 3).max(1);
        if self.last.elapsed().as_secs() < cadence {
            return;
        }
        let _ = self
            .store
            .lease_campaign_cell(self.name, self.label, self.worker, self.lease_secs);
        self.last = Instant::now();
    }
}

/// The round count the store currently shows for a cell's run (`None`
/// when the cell or its run can't be read) — how the reconciler tells a
/// planned segment halt (progress reached the boundary) from a real
/// failure after `run_cell` returns an error for either.
fn stored_progress(store: &RunStore, name: &str, label: &str) -> Option<usize> {
    let m = store.load_campaign(name).ok()?;
    let id = m.cells.iter().find(|c| c.label == label)?.run_id.clone()?;
    store.load_manifest(&id).ok().map(|r| r.records.len())
}

/// Run the reconcile loop until the campaign converges (every cell
/// complete or pruned), a cell fails [`MAX_CELL_FAILURES`] times in a
/// row, or `max_segments` trips. `seed` registers the campaign when it
/// doesn't exist yet (its grid must agree if it does — same rule as
/// `campaign run`); pass `None` to require an existing campaign.
pub fn operate(
    store: &RunStore,
    ocfg: &OperateCfg,
    seed: Option<&CampaignCfg>,
) -> anyhow::Result<OperateOutcome> {
    anyhow::ensure!(!ocfg.worker.is_empty(), "operate worker id must be non-empty");
    anyhow::ensure!(ocfg.lease_secs >= 1, "operate lease must be at least 1s");
    if let Some(cfg) = seed {
        anyhow::ensure!(
            cfg.name == ocfg.name,
            "operate name {:?} does not match seed campaign {:?}",
            ocfg.name,
            cfg.name
        );
        campaign::load_or_create_manifest(store, cfg, &cfg.cells()?)?;
    } else {
        anyhow::ensure!(
            store.campaign_exists(&ocfg.name),
            "campaign {:?} does not exist under {} — seed it with grid args \
             (`campaign operate --sweep ...`) or `campaign run` first",
            ocfg.name,
            store.location()
        );
    }
    let mut out = OperateOutcome::default();
    // label -> (consecutive failures, last error)
    let mut failures: HashMap<String, (usize, String)> = HashMap::new();
    loop {
        if out.segments >= ocfg.max_segments.unwrap_or(usize::MAX) {
            return Ok(out);
        }
        // Desired state: the stored spec, re-read every pass so live
        // `campaign edit`s take effect at the next convergence step.
        let stored = store.load_campaign(&ocfg.name)?;
        let mut cfg = CampaignCfg::from_spec_json(&stored.name, &stored.spec)?;
        cfg.verbose = false;
        let cells = cfg.cells()?;
        // Validates label agreement and migrates pre-v2 manifests.
        let manifest = campaign::load_or_create_manifest(store, &cfg, &cells)?;

        // Observed state, then policy: persist any prune decisions not
        // yet marked, and re-observe before doing anything else.
        let observed = status::observe(store, &manifest);
        let decisions = policy::plan_prunes(&cfg, &observed)?;
        let fresh: Vec<&policy::PruneDecision> = decisions
            .iter()
            .filter(|d| observed.cells.iter().any(|c| c.label == d.label && !c.pruned))
            .collect();
        if !fresh.is_empty() {
            store.update_campaign(&ocfg.name, |mut m| {
                for d in &decisions {
                    if let Some(c) = m.cells.iter_mut().find(|c| c.label == d.label) {
                        c.pruned = true;
                        c.worker = None;
                        c.lease_unix = 0;
                    }
                }
                m.updated_unix = unix_now();
                Ok(m)
            })?;
            out.pruned += fresh.len();
            if ocfg.verbose {
                for d in &fresh {
                    eprintln!(
                        "[operate {}] {}: pruned at rung {} (metric {:?})",
                        ocfg.name, d.label, d.rung_round, d.metric
                    );
                }
            }
            continue;
        }
        if observed.converged() {
            out.converged = true;
            return Ok(out);
        }

        // Lease a runnable cell: unfinished, unpruned, free / ours /
        // expired — and not parked at an unfired rung. A cell that
        // reached a boundary some unpruned cell hasn't must wait there:
        // running it further would waste compute it may lose at the rung,
        // and would make a pruned cell's stored progress depend on worker
        // interleaving instead of being exactly its losing rung. The most
        // lagging unpruned incomplete cell is never gated (every boundary
        // it reached, the whole grid has), so the campaign always has a
        // runnable cell and can't deadlock on this rule. Laggards first,
        // so rung boundaries unblock earliest.
        let boundaries = policy::cfg_rungs(&cfg)?;
        let frontier = observed
            .cells
            .iter()
            .filter(|c| !c.pruned)
            .map(|c| c.rounds_done)
            .min()
            .unwrap_or(0);
        let mut candidates: Vec<&status::CellStatusRow> = observed
            .cells
            .iter()
            .filter(|r| !r.pruned && r.state != "complete")
            .filter(|r| !boundaries.iter().any(|&b| r.rounds_done >= b && frontier < b))
            .filter(|r| match (r.worker.as_deref(), r.lease_age_secs) {
                (None, _) => true,
                (Some(w), _) if w == ocfg.worker => true,
                (Some(_), Some(age)) => age >= ocfg.lease_secs,
                (Some(_), None) => true,
            })
            .collect();
        candidates.sort_by_key(|r| (r.rounds_done, r.index));
        let Some(target) = candidates.first().copied() else {
            // Everything runnable is held by a live worker; wait for
            // their progress (or their lease to expire).
            std::thread::sleep(Duration::from_secs(ocfg.poll_secs.max(1)));
            continue;
        };
        let label = target.label.clone();
        match store.lease_campaign_cell(&ocfg.name, &label, &ocfg.worker, ocfg.lease_secs)? {
            LeaseOutcome::Pruned => continue,
            LeaseOutcome::Held { .. } => {
                // Lost the race for this cell; another pass will find
                // the next candidate.
                std::thread::sleep(Duration::from_secs(ocfg.poll_secs.max(1)));
                continue;
            }
            LeaseOutcome::Acquired { reclaimed_from, .. } => {
                if let Some(prev) = reclaimed_from {
                    out.reclaimed += 1;
                    if ocfg.verbose {
                        eprintln!(
                            "[operate {}] {label}: reclaimed expired lease from {prev}",
                            ocfg.name
                        );
                    }
                }
            }
        }

        // One segment: to the next rung boundary ahead of the cell, or
        // completion when none remain. Boundaries align to the
        // checkpoint cadence, so a halted segment always leaves a
        // durable checkpoint exactly at the rung.
        let halt = boundaries.iter().copied().find(|&b| b > target.rounds_done);
        let cell = cells
            .iter()
            .find(|c| c.label() == label)
            .ok_or_else(|| anyhow::anyhow!("campaign {:?} grid lost cell {label:?}", ocfg.name))?;
        let mut seg = cfg.clone();
        seg.halt_after = halt;
        if ocfg.verbose {
            let until = halt.map(|h| format!("round {h}")).unwrap_or_else(|| "completion".into());
            eprintln!("[operate {}] {label}: advancing to {until}", ocfg.name);
        }
        let mut heartbeat = LeaseHeartbeat {
            store,
            name: &ocfg.name,
            label: &label,
            worker: &ocfg.worker,
            lease_secs: ocfg.lease_secs,
            last: Instant::now(),
        };
        let ran = campaign::run_cell(store, &seg, cell, &mut heartbeat);
        out.segments += 1;
        let mut failed: Option<String> = None;
        match ran {
            Ok((_, CellRun::Completed)) => {
                out.completed += 1;
                failures.remove(&label);
            }
            Ok(_) => {
                // Skipped / Pruned / Pending: the store changed under us
                // (another worker finished it, the policy retired it);
                // nothing to do, the next pass sees the new state.
                failures.remove(&label);
            }
            Err(e) => {
                // A segment halt surfaces as an error from the server's
                // kill switch; tell it apart from a real failure by what
                // the store shows — a halted segment checkpointed at or
                // past its boundary, a failed one didn't.
                match (halt, stored_progress(store, &ocfg.name, &label)) {
                    (Some(h), Some(done)) if done >= h => {
                        failures.remove(&label);
                    }
                    _ => failed = Some(format!("{e:#}")),
                }
            }
        }
        store.release_campaign_lease(&ocfg.name, &label, &ocfg.worker)?;
        if let Some(msg) = failed {
            if ocfg.verbose {
                eprintln!("[operate {}] {label}: segment FAILED: {msg}", ocfg.name);
            }
            let entry = failures.entry(label.clone()).or_insert((0, String::new()));
            entry.0 += 1;
            entry.1 = msg;
            if entry.0 >= MAX_CELL_FAILURES {
                anyhow::bail!(
                    "campaign {:?}: cell {label:?} failed {MAX_CELL_FAILURES} segments \
                     in a row; last error: {}",
                    ocfg.name,
                    entry.1
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::{ParamSpace, SpecOverlay};
    use crate::config::ExperimentCfg;

    fn scratch(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "fedel-operator-worker-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn sweep(name: &str, rungs: usize) -> CampaignCfg {
        let base = ExperimentCfg { model: "mock:4x20".into(), rounds: 4, ..Default::default() };
        let mut cfg = CampaignCfg::new(name, base);
        cfg.checkpoint_every = 2;
        cfg.axis("seed=1,2,3").unwrap();
        if rungs > 0 {
            cfg.set = SpecOverlay::parse(
                ParamSpace::shared(),
                &[&format!("operator.halving.rungs={rungs}")],
            )
            .unwrap();
        }
        cfg
    }

    fn fast(name: &str) -> OperateCfg {
        let mut ocfg = OperateCfg::new(name);
        ocfg.worker = "w-test".into();
        ocfg.lease_secs = 3600;
        ocfg.poll_secs = 1;
        ocfg
    }

    #[test]
    fn operate_requires_an_existing_campaign_or_a_seed() {
        let dir = scratch("seedless");
        let store = RunStore::open(&dir).unwrap();
        let err = operate(&store, &fast("ghost"), None).unwrap_err().to_string();
        assert!(err.contains("does not exist"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn operate_converges_a_plain_sweep_in_segments() {
        let dir = scratch("converge");
        let store = RunStore::open(&dir).unwrap();
        let cfg = sweep("plain", 0);
        let out = operate(&store, &fast("plain"), Some(&cfg)).unwrap();
        assert!(out.converged);
        assert_eq!(out.completed, 3);
        assert_eq!(out.reclaimed, 0);
        assert_eq!(out.pruned, 0);
        // no rungs -> each cell is one completion segment
        assert_eq!(out.segments, 3);
        let m = store.load_campaign("plain").unwrap();
        for c in &m.cells {
            assert!(c.worker.is_none(), "leases released: {c:?}");
            let run = store.load_manifest(c.run_id.as_ref().unwrap()).unwrap();
            assert_eq!(run.records.len(), 4);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn operate_halves_at_the_rung_and_skips_pruned_cells() {
        let dir = scratch("halving");
        let store = RunStore::open(&dir).unwrap();
        let cfg = sweep("halve", 1); // rounds=4, cadence 2, rung at round 2
        let out = operate(&store, &fast("halve"), Some(&cfg)).unwrap();
        assert!(out.converged);
        // keep = ceil(0.5 * 3) = 2 -> exactly one cell pruned at round 2
        assert_eq!(out.pruned, 1);
        assert_eq!(out.completed, 2);
        let m = store.load_campaign("halve").unwrap();
        let pruned: Vec<&str> =
            m.cells.iter().filter(|c| c.pruned).map(|c| c.label.as_str()).collect();
        assert_eq!(pruned.len(), 1);
        // the loser stopped at the rung boundary; survivors finished
        for c in &m.cells {
            let run = store.load_manifest(c.run_id.as_ref().unwrap()).unwrap();
            assert_eq!(run.records.len(), if c.pruned { 2 } else { 4 }, "{}", c.label);
        }
        // a second operate pass over the converged campaign is a no-op
        // (prunes recompute identically, nothing re-runs)
        let again = operate(&store, &fast("halve"), Some(&cfg)).unwrap();
        assert!(again.converged);
        assert_eq!(again.segments, 0);
        assert_eq!(again.pruned, 0);
        let m2 = store.load_campaign("halve").unwrap();
        let pruned2: Vec<&str> =
            m2.cells.iter().filter(|c| c.pruned).map(|c| c.label.as_str()).collect();
        assert_eq!(pruned, pruned2, "prune decisions are stable across operators");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn max_segments_stops_early_and_a_later_operate_finishes() {
        let dir = scratch("resume");
        let store = RunStore::open(&dir).unwrap();
        let cfg = sweep("staged", 1);
        let mut first = fast("staged");
        first.max_segments = Some(2);
        let out = operate(&store, &first, Some(&cfg)).unwrap();
        assert!(!out.converged);
        assert_eq!(out.segments, 2);
        let rest = operate(&store, &fast("staged"), None).unwrap();
        assert!(rest.converged);
        assert_eq!(out.completed + rest.completed, 2);
        assert_eq!(out.pruned + rest.pruned, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
