//! Live edits to a running campaign's desired-state spec.
//!
//! A campaign's grid used to be frozen at launch: adding a seed or a new
//! strategy value meant a new campaign name and re-running everything.
//! [`edit_campaign`] appends values to an existing sweep axis *while
//! workers are running* — the spec rewrite, grid re-expansion, and cell
//! re-keying happen inside one [`crate::store::RunStore::update_campaign`]
//! compare-and-swap transaction, so concurrent claims, leases, and prune
//! flags are never lost. Existing cells keep their state (matched by
//! label — which is why every cell-addressing store operation is
//! label-keyed, not index-keyed: appending a value to an outer axis
//! renumbers the expansion); new combinations appear as unassigned cells
//! that any reconciling worker picks up on its next pass.
//!
//! Grammar: `key=+v1[,+v2...]` — the same value syntax as `--sweep`,
//! each appended value prefixed with `+`. The `+` is load-bearing: it
//! makes "append" explicit, so an edit can never be mistaken for (or
//! typo'd into) a grid *replacement*, which is unsupported — removing or
//! reordering values would orphan cells that already ran.

use crate::config::params::{ParamSpace, SweepAxis};
use crate::sim::campaign::{CampaignCell, CampaignCfg};
use crate::store::schema::{CampaignManifest, CellState, CAMPAIGN_SCHEMA_VERSION};
use crate::store::RunStore;
use crate::util::unix_now;

/// Strip the `+` append markers from an edit spec's value list: required
/// on the first value, accepted after every `,`/`;` separator (`;`
/// separates fleet values, `,` everything else — inside a fleet value,
/// `,` separates scales and carries no marker).
fn strip_plus(key: &str, rest: &str) -> anyhow::Result<String> {
    anyhow::ensure!(
        rest.starts_with('+'),
        "campaign edit appends values: write --sweep {key}=+{rest} \
         (the + marks each appended value; replacing a grid is unsupported)"
    );
    let mut out = String::with_capacity(rest.len());
    let mut after_sep = true;
    for c in rest.chars() {
        if after_sep && c == '+' {
            after_sep = false;
            continue;
        }
        after_sep = matches!(c, ',' | ';');
        out.push(c);
    }
    Ok(out)
}

/// Append values to one or more sweep axes of a stored campaign, as one
/// atomic spec+cells rewrite. Every `spec` is `key=+v[,+v...]`; the key
/// must name an existing `--sweep` axis (zip axes advance in lockstep —
/// appending to one would desynchronize the group, so they are
/// rejected). Returns the updated manifest.
pub fn edit_campaign(
    store: &RunStore,
    name: &str,
    sweeps: &[String],
) -> anyhow::Result<CampaignManifest> {
    anyhow::ensure!(
        !sweeps.is_empty(),
        "campaign edit needs at least one --sweep key=+value"
    );
    // Pre-v2 manifests carry v1 labels; upgrade first so re-keying by
    // label matches (idempotent, CAS-transactional).
    if store.load_campaign(name)?.schema_version < CAMPAIGN_SCHEMA_VERSION {
        crate::sim::campaign::migrate_campaign(store, name)?;
    }
    store.update_campaign(name, |mut m| {
        let mut cfg = CampaignCfg::from_spec_json(&m.name, &m.spec)?;
        for spec in sweeps {
            let (key, rest) = spec.split_once('=').ok_or_else(|| {
                anyhow::anyhow!("edit spec {spec:?} is not key=+value[,+value...]")
            })?;
            let stripped = strip_plus(key, rest)?;
            let parsed = SweepAxis::parse(ParamSpace::shared(), &format!("{key}={stripped}"))?;
            anyhow::ensure!(
                !cfg.zip.iter().any(|a| a.key == parsed.key),
                "campaign {name:?}: {key:?} is a zip axis — zipped groups advance \
                 in lockstep and can't be appended to one at a time"
            );
            let axis = cfg
                .axes
                .iter_mut()
                .find(|a| a.key == parsed.key)
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "campaign {name:?} has no {key:?} sweep axis — only existing \
                         axes can be appended to (axes: {})",
                        cfg.axes.iter().map(|a| a.key.as_str()).collect::<Vec<_>>().join(", ")
                    )
                })?;
            for v in parsed.values {
                anyhow::ensure!(
                    !axis.values.contains(&v),
                    "campaign {name:?}: axis {key:?} already has value {}",
                    v.render()
                );
                axis.values.push(v);
            }
        }
        // Re-expand and re-key: appended values only grow the grid, so
        // every existing label reappears and keeps its full CellState
        // (assignment, lease, pruned flag).
        let cells = cfg.cells()?;
        let mut old: std::collections::HashMap<String, CellState> =
            m.cells.drain(..).map(|c| (c.label.clone(), c)).collect();
        m.cells = cells
            .iter()
            .map(CampaignCell::label)
            .map(|label| old.remove(&label).unwrap_or_else(|| CellState::unassigned(label)))
            .collect();
        anyhow::ensure!(
            old.is_empty(),
            "campaign {name:?}: edit would orphan cell(s) [{}] — this is a bug, \
             appends can only grow the grid",
            old.keys().cloned().collect::<Vec<_>>().join(", ")
        );
        m.spec = cfg.spec_to_json();
        m.schema_version = CAMPAIGN_SCHEMA_VERSION;
        m.updated_unix = unix_now();
        Ok(m)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentCfg;

    fn scratch(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "fedel-operator-spec-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn seeded(store: &RunStore) -> CampaignCfg {
        let base = ExperimentCfg { model: "mock:4x20".into(), rounds: 4, ..Default::default() };
        let mut cfg = CampaignCfg::new("edit", base);
        cfg.axis("strategy=fedavg,fedel").unwrap();
        cfg.axis("seed=1,2").unwrap();
        let cells = cfg.cells().unwrap();
        crate::sim::campaign::load_or_create_manifest(store, &cfg, &cells).unwrap();
        cfg
    }

    #[test]
    fn edit_appends_axis_values_and_preserves_cell_state_by_label() {
        let dir = scratch("append");
        let store = RunStore::open(&dir).unwrap();
        seeded(&store);
        // give one cell visible state so the rekeying has to carry it
        store
            .claim_campaign_cell("edit", "strategy=fedel,seed=2", None, "fedel-s2-run")
            .unwrap();
        store
            .update_campaign("edit", |mut m| {
                let c = m.cells.iter_mut().find(|c| c.label == "strategy=fedavg,seed=1").unwrap();
                c.pruned = true;
                Ok(m)
            })
            .unwrap();

        let m = edit_campaign(&store, "edit", &["seed=+3".to_string()]).unwrap();
        let labels: Vec<&str> = m.cells.iter().map(|c| c.label.as_str()).collect();
        // seed is the INNER axis: appending renumbers fedel cells — the
        // exact reordering hazard label-keying exists for
        assert_eq!(
            labels,
            vec![
                "strategy=fedavg,seed=1",
                "strategy=fedavg,seed=2",
                "strategy=fedavg,seed=3",
                "strategy=fedel,seed=1",
                "strategy=fedel,seed=2",
                "strategy=fedel,seed=3",
            ]
        );
        let cell = |label: &str| m.cells.iter().find(|c| c.label == label).unwrap();
        assert_eq!(cell("strategy=fedel,seed=2").run_id.as_deref(), Some("fedel-s2-run"));
        assert!(cell("strategy=fedavg,seed=1").pruned);
        assert_eq!(cell("strategy=fedavg,seed=3").run_id, None);
        // the spec snapshot re-expands to the same grid (bare resume works)
        let back = CampaignCfg::from_spec_json("edit", &m.spec).unwrap();
        assert_eq!(
            back.cells().unwrap().iter().map(CampaignCell::label).collect::<Vec<_>>(),
            labels
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn edit_rejects_unmarked_duplicate_unknown_and_zip_targets() {
        let dir = scratch("reject");
        let store = RunStore::open(&dir).unwrap();
        seeded(&store);
        let edit = |specs: &[&str]| {
            edit_campaign(&store, "edit", &specs.iter().map(|s| s.to_string()).collect::<Vec<_>>())
        };
        // missing '+' marker
        let err = edit(&["seed=3"]).unwrap_err().to_string();
        assert!(err.contains("seed=+3"), "{err}");
        // duplicate value
        let err = edit(&["seed=+2"]).unwrap_err().to_string();
        assert!(err.contains("already has value 2"), "{err}");
        // unknown axis
        let err = edit(&["data.alpha=+0.3"]).unwrap_err().to_string();
        assert!(err.contains("no \"data.alpha\" sweep axis"), "{err}");
        // zip axes can't be edited
        let base = ExperimentCfg { model: "mock:4x20".into(), rounds: 4, ..Default::default() };
        let mut zcfg = CampaignCfg::new("zipped", base);
        zcfg.axis("seed=1,2").unwrap();
        zcfg.zip_axis("strategy=fedavg,fedel").unwrap();
        zcfg.zip_axis("time.t_th_factor=1.0,0.8").unwrap();
        let cells = zcfg.cells().unwrap();
        crate::sim::campaign::load_or_create_manifest(&store, &zcfg, &cells).unwrap();
        let err = edit_campaign(&store, "zipped", &["strategy=+fedprox".to_string()])
            .unwrap_err()
            .to_string();
        assert!(err.contains("zip axis"), "{err}");
        // a failed edit leaves the stored grid untouched
        assert_eq!(store.load_campaign("edit").unwrap().cells.len(), 4);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn multi_value_and_multi_axis_edits_apply_atomically() {
        let dir = scratch("multi");
        let store = RunStore::open(&dir).unwrap();
        seeded(&store);
        let m = edit_campaign(
            &store,
            "edit",
            &["seed=+3,+4".to_string(), "strategy=+fedprox".to_string()],
        )
        .unwrap();
        assert_eq!(m.cells.len(), 3 * 4);
        assert!(m.cells.iter().any(|c| c.label == "strategy=fedprox,seed=4"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
