//! Observed campaign state: what the store actually holds, per cell.
//!
//! One [`observe`] call is the operator's entire view of the world — the
//! reconcile loop, the halving policy, `campaign status` (table and
//! `--json`), and CI assertions all read the same snapshot, so they can
//! never disagree about what a cell is doing. Run manifests load across
//! a thread pool: against an HTTP store the old serial loop cost
//! O(cells × RTT) per status call, which is exactly the path the
//! operator polls.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::store::schema::{CampaignManifest, CellState, RunManifest, RunStatus};
use crate::store::RunStore;
use crate::util::json::Json;
use crate::util::unix_now;

/// One cell's observed state, joined from the campaign manifest (the
/// assignment + lease) and its run manifest (the progress).
#[derive(Clone, Debug)]
pub struct CellStatusRow {
    /// Position in the current grid expansion (shifts under live edits;
    /// `label` is the stable identity).
    pub index: usize,
    pub label: String,
    pub run_id: Option<String>,
    /// Lease holder, when some worker currently holds the cell.
    pub worker: Option<String>,
    /// Seconds since the holder's last heartbeat (`None` when unleased).
    pub lease_age_secs: Option<u64>,
    /// Retired by the halving policy; never advanced again.
    pub pruned: bool,
    /// Store view: "pending" (no run), "missing" (assigned run
    /// unreadable), "incomplete" (running, no checkpoint), "resumable"
    /// (running with a checkpoint), or "complete". Pruning is orthogonal
    /// — a pruned cell keeps the state its partial run last had.
    pub state: &'static str,
    /// Rounds recorded so far (0 without a readable run).
    pub rounds_done: usize,
    /// The run's configured round budget, when a run exists.
    pub rounds_total: Option<usize>,
    pub final_acc: Option<f64>,
    /// The loaded run manifest, so downstream consumers (the halving
    /// policy ranking eval records) never re-fetch it.
    pub run: Option<RunManifest>,
}

/// A point-in-time snapshot of a whole campaign.
#[derive(Clone, Debug)]
pub struct CampaignStatus {
    pub name: String,
    /// Wall-clock second the snapshot was taken (lease ages are relative
    /// to this instant).
    pub observed_unix: u64,
    pub cells: Vec<CellStatusRow>,
}

impl CampaignStatus {
    /// Every cell is finished: complete in the store or pruned.
    pub fn converged(&self) -> bool {
        self.cells.iter().all(|c| c.pruned || c.state == "complete")
    }
}

fn row(store: &RunStore, now: u64, index: usize, cell: &CellState) -> CellStatusRow {
    let loaded = cell.run_id.as_ref().map(|id| store.load_manifest(id));
    let (state, run): (&'static str, Option<RunManifest>) = match loaded {
        None => ("pending", None),
        Some(Err(_)) => ("missing", None),
        Some(Ok(r)) => (
            match (r.status, &r.checkpoint) {
                (RunStatus::Complete, _) => "complete",
                (RunStatus::Running, Some(_)) => "resumable",
                (RunStatus::Running, None) => "incomplete",
            },
            Some(r),
        ),
    };
    CellStatusRow {
        index,
        label: cell.label.clone(),
        run_id: cell.run_id.clone(),
        worker: cell.worker.clone(),
        lease_age_secs: cell.lease_age_secs(now),
        pruned: cell.pruned,
        state,
        rounds_done: run.as_ref().map(|r| r.records.len()).unwrap_or(0),
        rounds_total: run.as_ref().map(|r| r.config.rounds),
        final_acc: run.as_ref().and_then(|r| r.final_acc()),
        run,
    }
}

/// Snapshot every cell of `m`, loading run manifests across a bounded
/// thread pool (cells are independent, so rows land in manifest order
/// regardless of which worker fetched them).
pub fn observe(store: &RunStore, m: &CampaignManifest) -> CampaignStatus {
    let now = unix_now();
    let slots: Vec<Mutex<Option<CellStatusRow>>> =
        m.cells.iter().map(|_| Mutex::new(None)).collect();
    let cursor = AtomicUsize::new(0);
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .clamp(1, m.cells.len().max(1));
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= m.cells.len() {
                    break;
                }
                let r = row(store, now, i, &m.cells[i]);
                *slots[i].lock().expect("status slot lock poisoned") = Some(r);
            });
        }
    });
    CampaignStatus {
        name: m.name.clone(),
        observed_unix: now,
        cells: slots
            .into_iter()
            .map(|s| {
                s.into_inner()
                    .expect("status slot lock poisoned")
                    .expect("status worker skipped a cell")
            })
            .collect(),
    }
}

/// The status snapshot as structured JSON (`campaign status --json`):
/// everything the table shows plus lease ages and prune flags, so the
/// operator loop and CI assert progress without scraping text.
pub fn status_json(status: &CampaignStatus) -> Json {
    let cells: Vec<Json> = status
        .cells
        .iter()
        .map(|c| {
            let opt_str = |v: &Option<String>| {
                v.as_ref().map(|s| Json::Str(s.clone())).unwrap_or(Json::Null)
            };
            Json::obj(vec![
                ("cell", Json::Str(c.label.clone())),
                ("run", opt_str(&c.run_id)),
                ("state", Json::Str(c.state.to_string())),
                ("pruned", Json::Bool(c.pruned)),
                ("worker", opt_str(&c.worker)),
                (
                    "lease_age_secs",
                    c.lease_age_secs.map(|a| Json::Num(a as f64)).unwrap_or(Json::Null),
                ),
                ("rounds", Json::Num(c.rounds_done as f64)),
                (
                    "rounds_total",
                    c.rounds_total.map(|r| Json::Num(r as f64)).unwrap_or(Json::Null),
                ),
                (
                    "final_acc",
                    c.final_acc.map(Json::Num).unwrap_or(Json::Null),
                ),
            ])
        })
        .collect();
    Json::obj(vec![
        ("campaign", Json::Str(status.name.clone())),
        ("observed_unix", Json::Num(status.observed_unix as f64)),
        ("cells", Json::Arr(cells)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::schema::{CampaignManifest, CellState, CAMPAIGN_SCHEMA_VERSION};

    fn scratch(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "fedel-operator-status-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn observe_joins_cells_with_their_runs_and_renders_json() {
        let dir = scratch("observe");
        let store = RunStore::open(&dir).unwrap();
        // one real stored run for cell "a"
        let cfg = crate::config::ExperimentCfg {
            model: "mock:4x20".into(),
            rounds: 2,
            ..Default::default()
        };
        let mut exp = crate::sim::experiment::Experiment::build(cfg).unwrap();
        let mut ckpt =
            crate::store::checkpoint::CheckpointObserver::create(&store, &exp.cfg, "fedavg", 1)
                .unwrap();
        let id = ckpt.run_id().to_string();
        exp.run_from(Some("fedavg"), &mut ckpt, None).unwrap();
        assert!(ckpt.take_error().is_none());

        let m = CampaignManifest {
            schema_version: CAMPAIGN_SCHEMA_VERSION,
            name: "obs".into(),
            created_unix: 0,
            updated_unix: 0,
            spec: Json::Null,
            cells: vec![
                CellState { run_id: Some(id.clone()), ..CellState::unassigned("a".into()) },
                CellState {
                    worker: Some("w9".into()),
                    lease_unix: unix_now().saturating_sub(12),
                    ..CellState::unassigned("b".into())
                },
                CellState { pruned: true, ..CellState::unassigned("c".into()) },
                CellState {
                    run_id: Some("vanished-run".into()),
                    ..CellState::unassigned("d".into())
                },
            ],
        };
        store.save_campaign(&m).unwrap();
        let status = observe(&store, &m);
        assert_eq!(status.cells.len(), 4);
        let a = &status.cells[0];
        assert_eq!(a.state, "complete");
        assert_eq!(a.rounds_done, 2);
        assert_eq!(a.rounds_total, Some(2));
        assert!(a.final_acc.is_some());
        assert!(a.run.is_some());
        let b = &status.cells[1];
        assert_eq!(b.state, "pending");
        assert_eq!(b.worker.as_deref(), Some("w9"));
        assert!(b.lease_age_secs.unwrap_or(0) >= 12);
        assert!(status.cells[2].pruned);
        assert_eq!(status.cells[3].state, "missing");
        assert!(!status.converged(), "b and d are unfinished");

        // the JSON view round-trips through the parser and keeps the
        // fields CI greps for
        let j = Json::parse(&status_json(&status).to_string_pretty()).unwrap();
        assert_eq!(j.s("campaign").unwrap(), "obs");
        let cells = j.arr("cells").unwrap();
        assert_eq!(cells.len(), 4);
        assert_eq!(cells[0].s("state").unwrap(), "complete");
        assert_eq!(cells[0].s("run").unwrap(), id);
        assert_eq!(cells[0].f("rounds").unwrap(), 2.0);
        assert!(matches!(cells[2].get("pruned"), Some(Json::Bool(true))));
        assert!(matches!(cells[0].get("worker"), Some(Json::Null)));
        assert_eq!(cells[1].s("worker").unwrap(), "w9");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
