//! Campaign operator: reconcile-loop orchestration for sweep grids.
//!
//! `campaign run` drives a grid with a bounded thread pool inside ONE
//! process — a crash strands its claimed cells and the grid is frozen at
//! launch. This module restructures campaign execution the way a
//! Kubernetes controller runs pods, as three cleanly separated pieces
//! over the existing [`crate::store`] substrate:
//!
//! * **Desired state** — the sweep spec persisted in the campaign
//!   manifest, now live-editable: [`spec::edit_campaign`] appends values
//!   to a sweep axis (`campaign edit --sweep key=+v`) under the store's
//!   compare-and-swap, re-expanding the grid while preserving every
//!   existing cell's assignment by label.
//! * **Observed state** — [`status::observe`] snapshots what the store
//!   actually holds: per-cell run progress, checkpoint state, worker
//!   leases and their heartbeat age (run manifests are fanned across a
//!   thread pool, so an HTTP-backed status is one round-trip deep, not
//!   O(cells × RTT)).
//! * **Reconciler** — [`worker::operate`] repeatedly diffs the two and
//!   converges them: lease a runnable cell ([`crate::store::RunStore::
//!   lease_campaign_cell`], a CAS claim carrying worker id + heartbeat),
//!   advance it one checkpoint-aligned segment, release, repeat. Crash
//!   recovery falls out of the lease: a worker that dies mid-cell stops
//!   heartbeating, its lease expires, and any surviving worker reclaims
//!   the cell and resumes it from its checkpoint bitwise-identically.
//!   Priority falls out of candidate order (laggards first, so shared
//!   rung boundaries unblock as early as possible).
//!
//! On top rides the **adaptive sweep policy** ([`policy`]): deterministic
//! successive halving configured through registered parameter keys
//! (`operator.halving.rungs|keep_frac|metric`). At each rung boundary —
//! aligned to the checkpoint cadence so every cell has a durable
//! checkpoint there — live cells are ranked by their eval metric and the
//! bottom `1 - keep_frac` are marked pruned in the campaign manifest,
//! freeing their workers for surviving cells. Every decision is a pure
//! function of (spec, observed status): operators can be killed and
//! restarted anywhere, in any number, and the set of pruned cells and
//! the bytes of every completed run come out identical.

pub mod policy;
pub mod spec;
pub mod status;
pub mod worker;

pub use policy::{cfg_rungs, plan_prunes, rung_rounds, PruneDecision};
pub use spec::edit_campaign;
pub use status::{observe, status_json, CampaignStatus, CellStatusRow};
pub use worker::{operate, OperateCfg, OperateOutcome};
