//! Tensor timing model: the rust half of ElasticTrainer's offline profiler.
//!
//! The paper profiles per-tensor backward times (`t_g` gradient-compute,
//! `t_w` weight-update) on real Jetson hardware, then — for its own
//! 100-client evaluation — *simulates* heterogeneous devices by scaling one
//! measured profile by {1, 1/2, 1/3, 1/4}. We reproduce exactly that
//! mechanism, deriving the base profile from the manifest's per-tensor
//! forward FLOPs instead of a hardware trace (DESIGN.md §4): backward
//! gradient-compute costs ≈ the forward FLOPs of the op, weight-update
//! costs ≈ the dL/dW FLOPs plus a per-element update term. A calibration
//! helper pins the slowest device's full-model round to the paper's
//! measured wall-clock (71.8 min for CIFAR10/VGG) so reproduced tables
//! land in the paper's units.

use crate::manifest::Manifest;

/// A heterogeneous device in the fleet.
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceProfile {
    pub name: String,
    /// Time multiplier relative to the base profile (bigger == slower).
    pub scale: f64,
    /// Active power draw in watts (energy model, Fig 9).
    pub power_watts: f64,
}

impl DeviceProfile {
    pub fn new(name: &str, scale: f64, power_watts: f64) -> Self {
        DeviceProfile { name: name.to_string(), scale, power_watts }
    }

    /// The paper's small-scale testbed devices.
    pub fn orin() -> Self {
        DeviceProfile::new("orin", 1.0, 15.0)
    }

    pub fn xavier() -> Self {
        // Fig 2a: Xavier's full-model round is ~2x Orin's.
        DeviceProfile::new("xavier", 2.0, 10.0)
    }

    /// The paper's large-scale simulated types: baseline profiling time
    /// and devices at 1/2, 1/3, 1/4 of it.
    pub fn sim_types() -> Vec<DeviceProfile> {
        vec![
            DeviceProfile::new("type1.0", 1.0, 15.0),
            DeviceProfile::new("type0.5", 0.5, 15.0),
            DeviceProfile::new("type0.33", 1.0 / 3.0, 15.0),
            DeviceProfile::new("type0.25", 0.25, 15.0),
        ]
    }
}

/// Calibration constants mapping manifest FLOPs -> seconds on the *base*
/// (scale 1.0) device.
#[derive(Clone, Copy, Debug)]
pub struct TimingCfg {
    /// Sustained FLOP/s of the base device for this workload.
    pub flops_per_sec: f64,
    /// Fixed per-tensor kernel-launch/bookkeeping overhead (seconds).
    pub per_tensor_overhead: f64,
    /// Seconds per parameter element for the optimizer update.
    pub secs_per_update_elem: f64,
}

impl Default for TimingCfg {
    fn default() -> Self {
        TimingCfg {
            flops_per_sec: 5.0e9,
            per_tensor_overhead: 2.0e-4,
            secs_per_update_elem: 2.0e-9,
        }
    }
}

impl TimingCfg {
    /// Calibrate the timing constants so one full-model round (local_steps
    /// SGD steps, all tensors trained) on a `scale`-x device takes
    /// `target_secs`. ALL THREE constants scale by the same ratio —
    /// `flops_per_sec`, `per_tensor_overhead`, and `secs_per_update_elem`
    /// stretch together — so the flop-term : overhead proportion of every
    /// tensor's time is preserved exactly (pinned by
    /// `calibration_preserves_flop_overhead_proportion`); only the units
    /// change, never the shape of the cost model.
    pub fn calibrated(
        m: &Manifest,
        local_steps: usize,
        scale: f64,
        target_secs: f64,
    ) -> TimingCfg {
        let mut cfg = TimingCfg::default();
        let base = TimingModel::profile(m, &DeviceProfile::new("cal", scale, 0.0), &cfg);
        let t = base.full_round_time(m, local_steps);
        // Scale every constant by the same ratio so ALL times (flop terms
        // and overheads alike) stretch linearly onto the target.
        let ratio = target_secs / t;
        cfg.flops_per_sec /= ratio;
        cfg.per_tensor_overhead *= ratio;
        cfg.secs_per_update_elem *= ratio;
        cfg
    }
}

/// Forward cost per FLOP relative to backward's gradient-compute pass
/// (see the comment in [`TimingModel::profile`]).
pub const FWD_COST_FRAC: f64 = 0.6;

/// Per-client communication model: how long a client spends moving
/// parameters each round (or each asynchronous dispatch).
///
/// The legacy behavior is [`CommModel::Constant`] — a flat per-round
/// charge (`time.comm_secs`), identical for every client and every
/// payload, which made the communication savings of partial training
/// invisible. [`CommModel::Bandwidth`] prices each transfer from its
/// actual payload: `latency + payload_bytes * 8 / (mbps * 1e6)` per
/// direction. Upload bytes are the *encoded sparse payload* — run headers
/// plus the masked elements' f32s, exactly what
/// [`crate::fl::sparse::SparseDelta::encoded_bytes`] reports for the
/// plan's mask — so a FedEL client uploading a masked sub-model banks real
/// time-to-accuracy savings over a full-model FedAvg upload
/// (`comm.up_mbps` / `comm.down_mbps` / `comm.latency_secs` in the
/// parameter space). A rate of 0 makes that direction free apart from
/// latency (useful to model upload-constrained edge links).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CommModel {
    /// Flat per-round seconds, payload-independent (the degenerate model;
    /// `time.comm_secs` survives here).
    Constant(f64),
    /// Payload-priced transfers, per client and per direction.
    Bandwidth { up_mbps: f64, down_mbps: f64, latency_secs: f64 },
}

impl Default for CommModel {
    fn default() -> Self {
        CommModel::Constant(30.0)
    }
}

impl CommModel {
    /// Seconds to download `bytes` to a client (0 under `Constant`, whose
    /// flat charge is applied once in [`CommModel::client_total_secs`]).
    pub fn down_secs(&self, bytes: f64) -> f64 {
        match self {
            CommModel::Constant(_) => 0.0,
            CommModel::Bandwidth { down_mbps, latency_secs, .. } => {
                latency_secs + transfer_secs(bytes, *down_mbps)
            }
        }
    }

    /// Seconds to upload `bytes` from a client.
    pub fn up_secs(&self, bytes: f64) -> f64 {
        match self {
            CommModel::Constant(_) => 0.0,
            CommModel::Bandwidth { up_mbps, latency_secs, .. } => {
                latency_secs + transfer_secs(bytes, *up_mbps)
            }
        }
    }

    /// One client's simulated wall-clock for a dispatch: download the
    /// payload, compute for `train_secs`, upload the update. Under
    /// `Constant` this is `train_secs + c` — the legacy round shape —
    /// which keeps pre-CommModel results bitwise intact (f64 addition is
    /// monotone, so `max_i(t_i) + c == max_i(t_i + c)` exactly).
    pub fn client_total_secs(&self, train_secs: f64, down_bytes: f64, up_bytes: f64) -> f64 {
        match self {
            CommModel::Constant(c) => train_secs + c,
            CommModel::Bandwidth { .. } => {
                self.down_secs(down_bytes) + train_secs + self.up_secs(up_bytes)
            }
        }
    }
}

/// Wire seconds for `bytes` at `mbps` megabits/second (0 = free link).
fn transfer_secs(bytes: f64, mbps: f64) -> f64 {
    if mbps > 0.0 {
        bytes * 8.0 / (mbps * 1e6)
    } else {
        0.0
    }
}

/// Backward timing of one tensor (paper Fig 3).
#[derive(Clone, Copy, Debug, Default)]
pub struct TensorTiming {
    /// Gradient-computation time: dL/dx of the op, propagated upstream.
    pub t_g: f64,
    /// Weight-update time: dL/dW plus the optimizer update.
    pub t_w: f64,
    /// Forward time of the op this tensor parameterizes.
    pub t_f: f64,
}

/// Per-tensor timing for one (model, device) pair.
#[derive(Clone, Debug)]
pub struct TimingModel {
    pub device: DeviceProfile,
    pub tensors: Vec<TensorTiming>,
    /// Per-block body forward time (heads excluded), seconds per step.
    pub block_fwd: Vec<f64>,
    /// Per-block T^b = sum of body (t_g + t_w) — the window unit cost.
    pub block_train: Vec<f64>,
}

impl TimingModel {
    pub fn profile(m: &Manifest, device: &DeviceProfile, cfg: &TimingCfg) -> TimingModel {
        let spf = device.scale / cfg.flops_per_sec;
        let tensors: Vec<TensorTiming> = m
            .tensors
            .iter()
            .map(|t| {
                let batch_flops = t.flops_fwd * m.batch as f64;
                // Forward is cheaper per FLOP than backward on-device:
                // backward runs two contractions (dL/dx, dL/dW) plus the
                // optimizer update and gradient materialization, giving the
                // fwd:bwd ≈ 1:3 ratio measured for edge training (the
                // ElasticTrainer profiles show 2-4x). This ratio also makes
                // the paper's window geometry feasible: with bwd <= 2x fwd
                // the initial window's shallow tensors would sit exactly at
                // the budget boundary (DESIGN.md §Perf has the derivation).
                let t_f = FWD_COST_FRAC * batch_flops * spf
                    + cfg.per_tensor_overhead * device.scale;
                let t_g = batch_flops * spf + cfg.per_tensor_overhead * device.scale;
                let t_w = batch_flops * spf
                    + t.size as f64 * cfg.secs_per_update_elem * device.scale
                    + cfg.per_tensor_overhead * device.scale;
                TensorTiming { t_g, t_w, t_f }
            })
            .collect();
        let mut block_fwd = vec![0.0; m.num_blocks];
        let mut block_train = vec![0.0; m.num_blocks];
        for (i, t) in m.tensors.iter().enumerate() {
            if t.is_head {
                continue;
            }
            block_fwd[t.block] += tensors[i].t_f;
            block_train[t.block] += tensors[i].t_g + tensors[i].t_w;
        }
        TimingModel { device: device.clone(), tensors, block_fwd, block_train }
    }

    /// Forward time per step for blocks `< exit` plus its head.
    pub fn forward_time(&self, m: &Manifest, exit: usize) -> f64 {
        let mut t: f64 = self.block_fwd[..exit].iter().sum();
        for i in m.head_tensors_of_block(exit - 1) {
            t += self.tensors[i].t_f;
        }
        t
    }

    /// Full-model backward time per step: every tensor pays t_g + t_w.
    pub fn full_backward_time(&self) -> f64 {
        self.tensors.iter().map(|t| t.t_g + t.t_w).sum()
    }

    /// One full-model SGD step (fwd through everything + full backward).
    pub fn full_step_time(&self, m: &Manifest) -> f64 {
        self.forward_time(m, m.num_blocks) + self.full_backward_time()
    }

    /// The paper's per-round full-model training time.
    pub fn full_round_time(&self, m: &Manifest, local_steps: usize) -> f64 {
        self.full_step_time(m) * local_steps as f64
    }

    /// Backward time per step for an explicit tensor selection inside a
    /// window whose exit head is `exit` (paper Fig 3 semantics):
    /// t_g for every window tensor deeper than the shallowest selected,
    /// t_w for selected only. `order` must list candidate tensor ids from
    /// DEEPEST to SHALLOWEST; `selected[i]` flags order[i].
    pub fn backward_time_for(&self, order: &[usize], selected: &[bool]) -> f64 {
        debug_assert_eq!(order.len(), selected.len());
        let deepest_needed = match selected.iter().rposition(|&s| s) {
            None => return 0.0,
            Some(p) => p,
        };
        let mut t = 0.0;
        for i in 0..=deepest_needed {
            if i < deepest_needed {
                t += self.tensors[order[i]].t_g;
            }
            if selected[i] {
                t += self.tensors[order[i]].t_w;
            }
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::tests_support::chain_manifest;

    fn model() -> Manifest {
        chain_manifest(6, 100)
    }

    #[test]
    fn scale_multiplies_times() {
        let m = model();
        let cfg = TimingCfg::default();
        let fast = TimingModel::profile(&m, &DeviceProfile::new("f", 1.0, 0.0), &cfg);
        let slow = TimingModel::profile(&m, &DeviceProfile::new("s", 2.0, 0.0), &cfg);
        let (tf, ts) = (fast.full_step_time(&m), slow.full_step_time(&m));
        assert!((ts / tf - 2.0).abs() < 1e-9, "{ts} vs {tf}");
    }

    #[test]
    fn block_times_are_positive_and_monotone_with_flops() {
        let m = model();
        let tm = TimingModel::profile(&m, &DeviceProfile::orin(), &TimingCfg::default());
        assert!(tm.block_train.iter().all(|&t| t > 0.0));
        // chain_manifest FLOPs grow with depth
        for w in tm.block_train.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn forward_time_monotone_in_exit() {
        let m = model();
        let tm = TimingModel::profile(&m, &DeviceProfile::orin(), &TimingCfg::default());
        let mut last = 0.0;
        for e in 1..=m.num_blocks {
            let t = tm.forward_time(&m, e);
            assert!(t > last);
            last = t;
        }
    }

    #[test]
    fn calibration_hits_target() {
        let m = model();
        let cfg = TimingCfg::calibrated(&m, 50, 2.0, 3600.0);
        let tm = TimingModel::profile(&m, &DeviceProfile::new("slow", 2.0, 0.0), &cfg);
        let t = tm.full_round_time(&m, 50);
        assert!((t - 3600.0).abs() / 3600.0 < 0.01, "{t}");
    }

    #[test]
    fn backward_time_matches_paper_fig3_example() {
        // 5 tensors, select {2, 4} (1-indexed from input): expected
        // t_g5 + t_w4 + t_g4 + t_g3 + t_w2.
        let m = chain_manifest(5, 10);
        let tm = TimingModel::profile(&m, &DeviceProfile::orin(), &TimingCfg::default());
        // body tensor ids: 0,2,4,6,8 (input->output); deepest-first order:
        let order = vec![8usize, 6, 4, 2, 0];
        let selected = vec![false, true, false, true, false]; // tensors 4 & 2
        let got = tm.backward_time_for(&order, &selected);
        let want = tm.tensors[8].t_g
            + tm.tensors[6].t_w
            + tm.tensors[6].t_g
            + tm.tensors[4].t_g
            + tm.tensors[2].t_w;
        assert!((got - want).abs() < 1e-12);
    }

    #[test]
    fn backward_time_empty_selection_is_zero() {
        let m = model();
        let tm = TimingModel::profile(&m, &DeviceProfile::orin(), &TimingCfg::default());
        assert_eq!(tm.backward_time_for(&[0, 2, 4], &[false, false, false]), 0.0);
    }

    #[test]
    fn calibration_preserves_flop_overhead_proportion() {
        // Every constant scales by the same ratio, so the proportion of a
        // tensor's time spent in the flop term vs the overhead terms must
        // survive calibration exactly — the doc used to claim overheads
        // stayed at defaults, which was wrong in the opposite direction.
        let m = model();
        let d = TimingCfg::default();
        for target in [600.0, 3600.0, 86_400.0] {
            let c = TimingCfg::calibrated(&m, 50, 2.0, target);
            // overhead-seconds per flop-second = overhead * flops_per_sec
            let over = |cfg: &TimingCfg| cfg.per_tensor_overhead * cfg.flops_per_sec;
            let upd = |cfg: &TimingCfg| cfg.secs_per_update_elem * cfg.flops_per_sec;
            assert!((over(&c) / over(&d) - 1.0).abs() < 1e-9, "target {target}");
            assert!((upd(&c) / upd(&d) - 1.0).abs() < 1e-9, "target {target}");
        }
    }

    #[test]
    fn comm_model_prices_payloads_and_keeps_constant_shape() {
        let c = CommModel::Constant(30.0);
        assert_eq!(c.client_total_secs(100.0, 1e9, 1e9), 130.0);
        assert_eq!(c.down_secs(1e9), 0.0);

        let b = CommModel::Bandwidth { up_mbps: 10.0, down_mbps: 100.0, latency_secs: 0.05 };
        // 1 MB at 10 Mbps = 0.8 s + latency; at 100 Mbps = 0.08 s + latency
        assert!((b.up_secs(1e6) - 0.85).abs() < 1e-12);
        assert!((b.down_secs(1e6) - 0.13).abs() < 1e-12);
        let total = b.client_total_secs(100.0, 1e6, 1e6);
        assert!((total - (0.13 + 100.0 + 0.85)).abs() < 1e-12);
        // a masked (smaller) upload is strictly cheaper — the whole point
        assert!(b.client_total_secs(100.0, 1e6, 0.25e6) < total);
        // rate 0 = free link apart from latency
        let free = CommModel::Bandwidth { up_mbps: 0.0, down_mbps: 0.0, latency_secs: 0.1 };
        assert_eq!(free.up_secs(1e12), 0.1);
    }

    #[test]
    fn sim_types_match_paper_fractions() {
        let types = DeviceProfile::sim_types();
        let scales: Vec<f64> = types.iter().map(|d| d.scale).collect();
        assert_eq!(scales[0], 1.0);
        assert_eq!(scales[1], 0.5);
        assert!((scales[2] - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(scales[3], 0.25);
    }
}
