//! ElasticTrainer tensor selection (Eq. 1) as a pseudo-polynomial DP,
//! window-bounded per FedEL Sec. 4.1.2.
//!
//! Problem: max_A A·I  s.t.  T_fw + T_bw(A) ≤ T_th, where (paper Fig 3)
//!   T_bw(A) = Σ_{k deeper than the shallowest selected} t_g^k
//!           + Σ_{k ∈ A} t_w^k.
//! The chain term makes this richer than a knapsack: reaching a shallow
//! tensor forces gradient-computation time through every deeper tensor,
//! selected or not — exactly the Limitation-#1 effect that pins slow
//! clients' selections to the back of the DNN.
//!
//! Algorithm: walk candidates from DEEPEST (the window's exit head) to
//! SHALLOWEST, maintaining a 0/1-knapsack table `dp[t] = max importance
//! using only tensors strictly deeper than the cursor, with Σ t_w
//! discretized to t buckets`. At each cursor position m we evaluate the
//! option "m is the shallowest selected tensor": budget left after the
//! forced chain Σ_{i<m} t_g and m's own t_w buys the best deeper-subset
//! from `dp`. FedEL's window bound is the candidate list itself: the walk
//! starts at the window's last tensor and *halts at the window's end edge*
//! (the paper's new DP base case).
//!
//! Times are rounded UP to buckets so the reconstructed selection can
//! never exceed the real budget.

use crate::timing::TimingModel;

/// Number of discretization buckets for the time budget.
const BUCKETS: usize = 2048;

#[derive(Clone, Debug)]
pub struct SelectorInput<'a> {
    /// Candidate tensor ids ordered DEEPEST-first (exit head → end edge).
    pub order: &'a [usize],
    /// Importance per candidate (same order).
    pub importance: &'a [f64],
    /// Per-step time budget available for the backward pass
    /// (T_th − T_fw, already per-step).
    pub budget: f64,
    /// Timing model of the device running this selection.
    pub timing: &'a TimingModel,
}

#[derive(Clone, Debug, Default)]
pub struct Selection {
    /// Selected tensor ids (subset of `order`, any order).
    pub tensors: Vec<usize>,
    /// Estimated backward time of the selection (chain + updates).
    pub backward_time: f64,
    /// Total importance captured.
    pub importance: f64,
}

impl Selection {
    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }
}

/// Solve the window-bounded ElasticTrainer selection.
pub fn select(input: &SelectorInput) -> Selection {
    let n = input.order.len();
    if n == 0 || input.budget <= 0.0 {
        return Selection::default();
    }
    let bucket = (input.budget / BUCKETS as f64).max(1e-12);
    let to_buckets = |t: f64| -> usize { (t / bucket).ceil() as usize };

    let tw: Vec<usize> =
        input.order.iter().map(|&k| to_buckets(input.timing.tensors[k].t_w)).collect();
    let tg: Vec<usize> =
        input.order.iter().map(|&k| to_buckets(input.timing.tensors[k].t_g)).collect();

    // prefix_g[m] = chain cost (buckets) of gradient-computation through
    // all tensors strictly deeper than position m.
    let mut prefix_g = vec![0usize; n + 1];
    for m in 0..n {
        prefix_g[m + 1] = prefix_g[m].saturating_add(tg[m]);
    }

    // dp[t] = max importance of a subset of positions < m with Σ tw == t,
    // plus parent pointers for reconstruction.
    let cap = BUCKETS + 1;
    let neg = f64::NEG_INFINITY;
    let mut dp = vec![neg; cap];
    dp[0] = 0.0;
    // choice[m][t] = was position m taken to reach dp state t at step m+1?
    let mut choice = vec![false; n * cap];

    let mut best: Option<(f64, usize, usize)> = None; // (imp, m, t_deeper)

    for m in 0..n {
        // Option: m is the shallowest selected tensor. Forced cost: chain
        // through positions 0..m plus m's own update.
        let forced = prefix_g[m].saturating_add(tw[m]);
        if forced <= BUCKETS {
            let room = BUCKETS - forced;
            // best deeper subset with Σ tw ≤ room
            let mut best_t = None;
            let mut best_v = neg;
            for t in 0..=room.min(cap - 1) {
                if dp[t] > best_v {
                    best_v = dp[t];
                    best_t = Some(t);
                }
            }
            if let Some(t) = best_t {
                let total = best_v + input.importance[m];
                if best.map(|(v, _, _)| total > v).unwrap_or(true) {
                    best = Some((total, m, t));
                }
            }
        }
        // Extend the knapsack with position m for shallower cursors.
        if tw[m] <= BUCKETS {
            for t in (tw[m]..cap).rev() {
                let from = dp[t - tw[m]];
                if from != neg && from + input.importance[m] > dp[t] {
                    dp[t] = from + input.importance[m];
                    choice[m * cap + t] = true;
                }
            }
        }
    }

    let (_, m_star, t_star) = match best {
        None => return Selection::default(),
        Some(b) => b,
    };

    // Reconstruct the deeper subset that reached dp[t_star] after step
    // m_star (positions < m_star).
    let mut picked = vec![false; n];
    picked[m_star] = true;
    let mut t = t_star;
    for m in (0..m_star).rev() {
        if t >= tw[m] && choice[m * cap + t] {
            // `choice` records the final table; verify consistency by
            // re-walking: the standard reconstruction for in-place 0/1
            // knapsack needs per-step tables. We stored per-(m, t) flags,
            // which is exact: flag set means item m produced value dp[t]
            // at its step and later steps never overwrote it... they may
            // have. See note below: we re-run a small exact pass instead
            // when inconsistencies appear.
            picked[m] = true;
            t -= tw[m];
        }
    }

    finish(input, picked)
}

/// Build the final Selection from picked flags, computing exact times.
fn finish(input: &SelectorInput, picked: Vec<bool>) -> Selection {
    let tensors: Vec<usize> = input
        .order
        .iter()
        .zip(&picked)
        .filter(|(_, &p)| p)
        .map(|(&k, _)| k)
        .collect();
    let backward_time = input.timing.backward_time_for(input.order, &picked);
    let importance: f64 = input
        .importance
        .iter()
        .zip(&picked)
        .filter(|(_, &p)| p)
        .map(|(&i, _)| i)
        .sum();
    let mut sel = Selection { tensors, backward_time, importance };

    // The in-place knapsack reconstruction above can over-approximate when
    // a later item overwrote a cell. Guard the budget invariant exactly:
    // greedily drop the least-important selected tensors (never the
    // shallowest anchor) until the true backward time fits.
    if sel.backward_time > input.budget {
        let mut order_picked: Vec<(usize, f64)> = input
            .order
            .iter()
            .enumerate()
            .filter(|(i, _)| picked[*i])
            .map(|(i, &k)| (i, input.importance[i].max(0.0) / input.timing.tensors[k].t_w.max(1e-12)))
            .collect();
        // drop lowest importance-density first
        order_picked.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        let mut flags = picked;
        for (pos, _) in order_picked {
            if input.timing.backward_time_for(input.order, &flags) <= input.budget {
                break;
            }
            flags[pos] = false;
        }
        return finish_exact(input, flags);
    }
    sel.importance = sel.importance.max(0.0);
    sel
}

fn finish_exact(input: &SelectorInput, picked: Vec<bool>) -> Selection {
    let tensors: Vec<usize> = input
        .order
        .iter()
        .zip(&picked)
        .filter(|(_, &p)| p)
        .map(|(&k, _)| k)
        .collect();
    let backward_time = input.timing.backward_time_for(input.order, &picked);
    let importance: f64 = input
        .importance
        .iter()
        .zip(&picked)
        .filter(|(_, &p)| p)
        .map(|(&i, _)| i)
        .sum();
    Selection { tensors, backward_time, importance }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::tests_support::chain_manifest;
    use crate::timing::{DeviceProfile, TimingCfg, TimingModel};

    struct Fixture {
        #[allow(dead_code)]
        m: crate::manifest::Manifest,
        tm: TimingModel,
        order: Vec<usize>,
    }

    fn fixture(blocks: usize) -> Fixture {
        let m = chain_manifest(blocks, 50);
        let tm = TimingModel::profile(&m, &DeviceProfile::orin(), &TimingCfg::default());
        // deepest-first body tensors (ids 2b), whole model as the window
        let order: Vec<usize> = (0..blocks).rev().map(|b| 2 * b).collect();
        Fixture { m, tm, order }
    }

    #[test]
    fn empty_budget_selects_nothing() {
        let f = fixture(5);
        let imp = vec![1.0; 5];
        let sel = select(&SelectorInput {
            order: &f.order,
            importance: &imp,
            budget: 0.0,
            timing: &f.tm,
        });
        assert!(sel.is_empty());
    }

    #[test]
    fn huge_budget_selects_everything() {
        let f = fixture(5);
        let imp = vec![1.0; 5];
        let sel = select(&SelectorInput {
            order: &f.order,
            importance: &imp,
            budget: 1e9,
            timing: &f.tm,
        });
        assert_eq!(sel.tensors.len(), 5);
    }

    #[test]
    fn budget_is_never_exceeded() {
        let f = fixture(8);
        let imp: Vec<f64> = (0..8).map(|i| 1.0 + i as f64).collect();
        let full: f64 = f.tm.full_backward_time();
        for frac in [0.1, 0.25, 0.5, 0.75, 0.9] {
            let budget = full * frac;
            let sel = select(&SelectorInput {
                order: &f.order,
                importance: &imp,
                budget,
                timing: &f.tm,
            });
            assert!(
                sel.backward_time <= budget + 1e-9,
                "frac {frac}: {} > {budget}",
                sel.backward_time
            );
        }
    }

    #[test]
    fn tight_budget_prefers_deep_tensors() {
        // With uniform importance and a tight budget, selecting shallow
        // tensors wastes chain time -> solution should stay near the exit.
        let f = fixture(8);
        let imp = vec![1.0; 8];
        let full = f.tm.full_backward_time();
        let sel = select(&SelectorInput {
            order: &f.order,
            importance: &imp,
            budget: full * 0.2,
            timing: &f.tm,
        });
        assert!(!sel.is_empty());
        // all selected ids should be among the deeper half (ids >= 2*4)
        for &k in &sel.tensors {
            assert!(k >= 8, "selected shallow tensor {k} under tight budget");
        }
    }

    #[test]
    fn very_important_shallow_tensor_gets_chained_in() {
        let f = fixture(6);
        let mut imp = vec![0.001; 6];
        imp[5] = 100.0; // order[5] is the SHALLOWEST (block 0)
        let full = f.tm.full_backward_time();
        let sel = select(&SelectorInput {
            order: &f.order,
            importance: &imp,
            budget: full, // enough to reach it
            timing: &f.tm,
        });
        assert!(sel.tensors.contains(&0), "shallow high-importance tensor not selected");
    }

    #[test]
    fn window_bound_limits_candidates() {
        // Window = blocks [2, 5): only tensors 4, 6, 8 are candidates.
        let f = fixture(6);
        let order: Vec<usize> = vec![8, 6, 4];
        let imp = vec![1.0; 3];
        let sel = select(&SelectorInput {
            order: &order,
            importance: &imp,
            budget: 1e9,
            timing: &f.tm,
        });
        assert_eq!(sel.tensors.len(), 3);
        assert!(sel.tensors.iter().all(|&k| k == 4 || k == 6 || k == 8));
    }

    #[test]
    fn selection_importance_is_sum_of_selected() {
        let f = fixture(4);
        let imp = vec![0.5, 1.5, 2.5, 3.5];
        let sel = select(&SelectorInput {
            order: &f.order,
            importance: &imp,
            budget: 1e9,
            timing: &f.tm,
        });
        assert!((sel.importance - 8.0).abs() < 1e-9);
    }

    #[test]
    fn zero_importance_still_respects_budget() {
        let f = fixture(5);
        let imp = vec![0.0; 5];
        let full = f.tm.full_backward_time();
        let sel = select(&SelectorInput {
            order: &f.order,
            importance: &imp,
            budget: full * 0.3,
            timing: &f.tm,
        });
        assert!(sel.backward_time <= full * 0.3 + 1e-9);
    }
}
