//! Tensor importance (ElasticTrainer) and FedEL's adjustment module.
//!
//! ElasticTrainer scores a tensor by how much loss its update would remove:
//! I = dL/dw · Δw; with SGD (Δw = -η g) this is η·Σ g² per tensor, which
//! the train-step artifact already returns as per-tensor Σ g² (the L1
//! masked-SGD kernel's second output).
//!
//! FedEL's adjustment (Sec. 4.2): after aggregation the client estimates
//! the *global* model's tensor importance from two consecutive global
//! models, I^g = (w_{r+1} − w_r)² / η, then blends
//! I ← β·I_local + (1−β)·I^g. Both vectors are normalized to unit sum
//! before blending — they live on different scales (one is built from
//! single-client gradients, the other from an aggregated model delta), and
//! β is only meaningful as a mixing weight over comparable quantities.

use crate::manifest::Manifest;

/// Local ElasticTrainer importance from the artifact's per-tensor Σ g².
pub fn local_importance(sq_grads: &[f64], lr: f64) -> Vec<f64> {
    sq_grads.iter().map(|&s| s * lr).collect()
}

/// FedEL global importance per tensor: Σ over the tensor of (Δw)² / η.
pub fn global_importance(m: &Manifest, w_new: &[f32], w_old: &[f32], lr: f64) -> Vec<f64> {
    assert_eq!(w_new.len(), m.param_count);
    assert_eq!(w_old.len(), m.param_count);
    m.tensors
        .iter()
        .map(|t| {
            let mut s = 0.0f64;
            for j in t.offset..t.offset + t.size {
                let dw = (w_new[j] - w_old[j]) as f64;
                s += dw * dw;
            }
            s / lr
        })
        .collect()
}

fn normalized(v: &[f64]) -> Vec<f64> {
    let s: f64 = v.iter().sum();
    if s <= 0.0 {
        // No signal: uniform.
        return vec![1.0 / v.len().max(1) as f64; v.len()];
    }
    v.iter().map(|&x| x / s).collect()
}

/// FedEL Sec. 4.2: I = β·I_local + (1−β)·I_global (unit-normalized).
pub fn blend_importance(local: &[f64], global: &[f64], beta: f64) -> Vec<f64> {
    assert_eq!(local.len(), global.len());
    let (l, g) = (normalized(local), normalized(global));
    l.iter().zip(&g).map(|(&a, &b)| beta * a + (1.0 - beta) * b).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::tests_support::toy_manifest;

    #[test]
    fn local_importance_scales_with_lr() {
        let sq = vec![1.0, 4.0, 0.0];
        assert_eq!(local_importance(&sq, 0.5), vec![0.5, 2.0, 0.0]);
    }

    #[test]
    fn global_importance_is_squared_delta_over_lr() {
        let m = toy_manifest();
        let w_old = vec![0.0f32; m.param_count];
        let mut w_new = vec![0.0f32; m.param_count];
        // change only tensor 2 (block1/w, offset 12..22) by 0.1 each
        for v in &mut w_new[12..22] {
            *v = 0.1;
        }
        let ig = global_importance(&m, &w_new, &w_old, 0.1);
        assert_eq!(ig.len(), 4);
        assert!(ig[0].abs() < 1e-12 && ig[1].abs() < 1e-12 && ig[3].abs() < 1e-12);
        let want = 10.0 * 0.01f64 / 0.1;
        assert!((ig[2] - want).abs() < 1e-6, "{} vs {want}", ig[2]);
    }

    #[test]
    fn blend_beta_one_is_local_only() {
        let l = vec![3.0, 1.0];
        let g = vec![0.0, 10.0];
        let b = blend_importance(&l, &g, 1.0);
        assert!((b[0] - 0.75).abs() < 1e-12);
        assert!((b[1] - 0.25).abs() < 1e-12);
    }

    #[test]
    fn blend_beta_zero_is_global_only() {
        let l = vec![3.0, 1.0];
        let g = vec![0.0, 10.0];
        let b = blend_importance(&l, &g, 0.0);
        assert_eq!(b, vec![0.0, 1.0]);
    }

    #[test]
    fn blend_is_convex_combination() {
        let l = vec![1.0, 2.0, 3.0];
        let g = vec![3.0, 2.0, 1.0];
        let b = blend_importance(&l, &g, 0.6);
        assert!((b.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        for &x in &b {
            assert!(x >= 0.0);
        }
    }

    #[test]
    fn zero_signal_falls_back_to_uniform() {
        let b = blend_importance(&[0.0, 0.0], &[0.0, 0.0], 0.5);
        assert_eq!(b, vec![0.5, 0.5]);
    }
}
