//! ElasticTrainer core: tensor importance evaluation + DP tensor selection
//! under a runtime budget (Eq. 1), extended with FedEL's window bounds
//! (Sec. 4.1.2) and local/global importance adjustment (Sec. 4.2).

pub mod importance;
pub mod selector;

pub use importance::{blend_importance, global_importance, local_importance};
pub use selector::{select, Selection, SelectorInput};
