//! Deterministic PRNG substrate (the offline registry has no `rand`).
//!
//! xoshiro256** seeded through SplitMix64 — the standard, well-tested
//! construction. Every stochastic component in the coordinator (data
//! synthesis, Dirichlet partitioning, client sampling, property tests)
//! draws from this so experiments are reproducible from a single seed.

/// SplitMix64: seeds xoshiro and doubles as a cheap stateless mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** 1.0 (Blackman & Vigna).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Raw generator state, for checkpointing (u64s don't survive the JSON
    /// number path exactly, so stores serialize these through strings);
    /// restore with [`Rng::from_state`].
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a [`Rng::state`] snapshot — the restored
    /// stream continues bit-for-bit where the snapshot was taken.
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    /// Derive an independent stream (e.g. per client) from this seed space.
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mut sm = self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15);
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang (shape > 0).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // boost: Gamma(a) = Gamma(a+1) * U^{1/a}
            let u = self.f64().max(1e-300);
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64().max(1e-300);
            if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
                return d * v;
            }
        }
    }

    /// Dirichlet(alpha * 1) over `k` categories — the paper's non-iid
    /// partitioner (alpha = 0.1).
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.gamma(alpha).max(1e-30)).collect();
        let s: f64 = g.iter().sum();
        for v in &mut g {
            *v /= s;
        }
        g
    }

    /// Sample an index from an (unnormalized, non-negative) weight vector.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// k distinct indices from [0, n), order randomized.
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k.min(n));
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(4);
        for n in [1usize, 2, 3, 10, 1000] {
            for _ in 0..1000 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::new(6);
        for shape in [0.1f64, 0.5, 1.0, 3.0] {
            let n = 20_000;
            let m = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!((m - shape).abs() < 0.1 * shape.max(0.5), "shape {shape} mean {m}");
        }
    }

    #[test]
    fn dirichlet_sums_to_one_and_is_skewed_for_small_alpha() {
        let mut r = Rng::new(7);
        let p = r.dirichlet(0.1, 10);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        let max = p.iter().cloned().fold(0.0, f64::max);
        assert!(max > 0.3, "alpha=0.1 should concentrate: {p:?}");
        let p2 = r.dirichlet(100.0, 10);
        let max2 = p2.iter().cloned().fold(0.0, f64::max);
        assert!(max2 < 0.2, "alpha=100 should be near-uniform: {p2:?}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Rng::new(9);
        let k = r.choose_k(50, 10);
        assert_eq!(k.len(), 10);
        let mut s = k.clone();
        s.sort();
        s.dedup();
        assert_eq!(s.len(), 10);
    }

    #[test]
    fn categorical_prefers_heavy_weight() {
        let mut r = Rng::new(10);
        let w = [0.01, 0.01, 10.0];
        let hits = (0..1000).filter(|_| r.categorical(&w) == 2).count();
        assert!(hits > 900);
    }

    #[test]
    fn state_round_trip_continues_stream() {
        let mut a = Rng::new(13);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(11);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
