//! Statistics substrate: summary stats, confidence intervals, box-plot
//! five-number summaries (Figure 21), and simple vector helpers used by the
//! report/bench layer.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample standard deviation (0.0 for n < 2).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Population variance.
pub fn var_pop(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Linear-interpolated quantile, q in [0, 1]. NaN entries are ignored;
/// with no finite-orderable data left (empty input or all-NaN) the result
/// is NaN rather than a panic — the report layer reaches this with
/// empty series (runs that never evaluated) and must not crash on them.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    let mut v: Vec<f64> = xs.iter().copied().filter(|x| !x.is_nan()).collect();
    if v.is_empty() {
        return f64::NAN;
    }
    v.sort_by(f64::total_cmp);
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

/// 95% CI half-width using the normal approximation (t-table for small n).
pub fn ci95_half_width(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    // two-sided 97.5% t quantiles for df = 1..=30, then z.
    const T: [f64; 30] = [
        12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228,
        2.201, 2.179, 2.160, 2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086,
        2.080, 2.074, 2.069, 2.064, 2.060, 2.056, 2.052, 2.048, 2.045, 2.042,
    ];
    let df = xs.len() - 1;
    let t = if df <= 30 { T[df - 1] } else { 1.96 };
    t * std_dev(xs) / (xs.len() as f64).sqrt()
}

/// Five-number box-plot summary (Figure 21): min, q1, median, q3, max.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BoxStats {
    pub min: f64,
    pub q1: f64,
    pub median: f64,
    pub q3: f64,
    pub max: f64,
}

pub fn box_stats(xs: &[f64]) -> BoxStats {
    BoxStats {
        min: quantile(xs, 0.0),
        q1: quantile(xs, 0.25),
        median: quantile(xs, 0.5),
        q3: quantile(xs, 0.75),
        max: quantile(xs, 1.0),
    }
}

/// Welch's t statistic for two independent samples (Fig 21 significance).
pub fn welch_t(a: &[f64], b: &[f64]) -> f64 {
    let (ma, mb) = (mean(a), mean(b));
    let (va, vb) = (std_dev(a).powi(2), std_dev(b).powi(2));
    let denom = (va / a.len() as f64 + vb / b.len() as f64).sqrt();
    if denom == 0.0 {
        0.0
    } else {
        (ma - mb) / denom
    }
}

/// Exponential moving average over a series (metric smoothing).
pub fn ema(xs: &[f64], alpha: f64) -> Vec<f64> {
    let mut out = Vec::with_capacity(xs.len());
    let mut acc = None;
    for &x in xs {
        let v = match acc {
            None => x,
            Some(prev) => alpha * x + (1.0 - alpha) * prev,
        };
        out.push(v);
        acc = Some(v);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.13809).abs() < 1e-4);
    }

    #[test]
    fn quantiles_interpolate() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
        assert!((quantile(&xs, 0.25) - 1.75).abs() < 1e-12);
    }

    #[test]
    fn box_stats_ordered() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        let b = box_stats(&xs);
        assert!(b.min <= b.q1 && b.q1 <= b.median);
        assert!(b.median <= b.q3 && b.q3 <= b.max);
        assert_eq!(b.min, 1.0);
        assert_eq!(b.max, 5.0);
        assert_eq!(b.median, 3.0);
    }

    #[test]
    fn ci_shrinks_with_n() {
        let a: Vec<f64> = (0..5).map(|i| i as f64).collect();
        let b: Vec<f64> = (0..50).map(|i| (i % 5) as f64).collect();
        assert!(ci95_half_width(&a) > ci95_half_width(&b));
    }

    #[test]
    fn welch_t_zero_for_identical() {
        let a = [1.0, 2.0, 3.0];
        assert_eq!(welch_t(&a, &a), 0.0);
        let b = [11.0, 12.0, 13.0];
        assert!(welch_t(&b, &a) > 5.0);
    }

    #[test]
    fn ema_first_is_input() {
        let xs = [10.0, 0.0, 0.0];
        let e = ema(&xs, 0.5);
        assert_eq!(e[0], 10.0);
        assert_eq!(e[1], 5.0);
        assert_eq!(e[2], 2.5);
    }

    #[test]
    fn empty_inputs_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(std_dev(&[1.0]), 0.0);
        assert_eq!(ci95_half_width(&[1.0]), 0.0);
    }

    #[test]
    fn quantile_and_box_stats_survive_empty_slices() {
        // Regression: these used to assert/panic; a run with no eval
        // rounds feeds the report layer exactly this.
        assert!(quantile(&[], 0.5).is_nan());
        assert!(median(&[]).is_nan());
        let b = box_stats(&[]);
        assert!(b.min.is_nan() && b.median.is_nan() && b.max.is_nan());
    }

    #[test]
    fn quantile_ignores_nans_instead_of_panicking() {
        // Regression: partial_cmp().unwrap() in the old sort aborted on
        // any NaN in the sample; total_cmp + filtering keeps the finite
        // statistics intact.
        let xs = [f64::NAN, 3.0, 1.0, f64::NAN, 2.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 3.0);
        assert_eq!(median(&xs), 2.0);
        let b = box_stats(&xs);
        assert_eq!((b.min, b.median, b.max), (1.0, 2.0, 3.0));
        assert!(quantile(&[f64::NAN], 0.5).is_nan());
    }
}
