//! Substrate utilities built from scratch for the offline environment:
//! PRNG, JSON, CLI parsing, statistics, property testing, binary I/O.

pub mod cli;
pub mod io;
pub mod json;
pub mod prop;
pub mod rng;
pub mod sha256;
pub mod stats;

/// Seconds since the unix epoch (0 if the clock is before it) — the
/// timestamp every store manifest carries.
pub fn unix_now() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

/// Format a duration in simulated hours the way the paper's tables do.
pub fn fmt_hours(secs: f64) -> String {
    format!("{:.1}h", secs / 3600.0)
}

/// Format a speedup column ("N/A" for the baseline itself).
pub fn fmt_speedup(x: Option<f64>) -> String {
    match x {
        None => "N/A".to_string(),
        Some(v) => format!("{v:.2}x"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hours_formatting() {
        assert_eq!(fmt_hours(3600.0), "1.0h");
        assert_eq!(fmt_hours(119.8 * 3600.0), "119.8h");
    }

    #[test]
    fn speedup_formatting() {
        assert_eq!(fmt_speedup(None), "N/A");
        assert_eq!(fmt_speedup(Some(3.87)), "3.87x");
    }
}
