//! Substrate utilities built from scratch for the offline environment:
//! PRNG, JSON, CLI parsing, statistics, property testing, binary I/O.

pub mod cli;
pub mod io;
pub mod json;
pub mod prop;
pub mod rng;
pub mod sha256;
pub mod stats;

/// Seconds since the unix epoch (0 if the clock is before it) — the
/// timestamp every store manifest carries.
pub fn unix_now() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

/// Format a duration in simulated hours the way the paper's tables do.
pub fn fmt_hours(secs: f64) -> String {
    format!("{:.1}h", secs / 3600.0)
}

/// Format a speedup column ("N/A" for the baseline itself).
pub fn fmt_speedup(x: Option<f64>) -> String {
    match x {
        None => "N/A".to_string(),
        Some(v) => format!("{v:.2}x"),
    }
}

/// The candidate closest to `input` by edit distance, for "did you mean"
/// hints on unknown CLI names/keys. None when nothing is plausibly close
/// (distance > half the input length, minimum 2).
pub fn nearest_match<'a>(input: &str, candidates: &[&'a str]) -> Option<&'a str> {
    let cutoff = (input.len() / 2).max(2);
    candidates
        .iter()
        .map(|c| (edit_distance(input, c), *c))
        .filter(|(d, _)| *d <= cutoff)
        .min_by_key(|(d, _)| *d)
        .map(|(_, c)| c)
}

/// Levenshtein distance (two-row DP; inputs are short CLI tokens).
pub fn edit_distance(a: &str, b: &str) -> usize {
    let (a, b): (Vec<char>, Vec<char>) = (a.chars().collect(), b.chars().collect());
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edit_distance_and_nearest_match() {
        assert_eq!(edit_distance("", "abc"), 3);
        assert_eq!(edit_distance("kitten", "sitting"), 3);
        assert_eq!(edit_distance("alpha", "alpha"), 0);
        assert_eq!(nearest_match("data.alhpa", &["data.alpha", "train.lr"]), Some("data.alpha"));
        assert_eq!(nearest_match("zzzzzzzz", &["data.alpha", "train.lr"]), None);
    }

    #[test]
    fn hours_formatting() {
        assert_eq!(fmt_hours(3600.0), "1.0h");
        assert_eq!(fmt_hours(119.8 * 3600.0), "119.8h");
    }

    #[test]
    fn speedup_formatting() {
        assert_eq!(fmt_speedup(None), "N/A");
        assert_eq!(fmt_speedup(Some(3.87)), "3.87x");
    }
}
