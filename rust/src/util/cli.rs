//! CLI argument parsing substrate (offline registry has no clap).
//!
//! Supports: `prog <subcommand> --flag --key value --key=value positional`.
//! Each binary declares its options by querying an [`Args`] after parsing;
//! unknown keys produce an error listing what was accepted, so typos fail
//! loudly instead of silently running a default experiment.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    kv: BTreeMap<String, String>,
    /// Every `--key value` occurrence in command-line order; repeatable
    /// options (`--set`, `--sweep`) read all of them via [`Args::all`],
    /// while `kv` keeps the last-wins view for single-valued options.
    pairs: Vec<(String, String)>,
    flags: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse process args; `expect_subcommand` treats the first bare word
    /// as a subcommand.
    pub fn parse(raw: impl IntoIterator<Item = String>, expect_subcommand: bool) -> Args {
        let mut a = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    a.kv.insert(k.to_string(), v.to_string());
                    a.pairs.push((k.to_string(), v.to_string()));
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    a.kv.insert(stripped.to_string(), v.clone());
                    a.pairs.push((stripped.to_string(), v));
                } else {
                    a.flags.push(stripped.to_string());
                }
            } else if expect_subcommand && a.subcommand.is_none() && a.positional.is_empty() {
                a.subcommand = Some(tok);
            } else {
                a.positional.push(tok);
            }
        }
        a
    }

    pub fn from_env(expect_subcommand: bool) -> Args {
        Args::parse(std::env::args().skip(1), expect_subcommand)
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().push(key.to_string());
    }

    pub fn flag(&self, key: &str) -> bool {
        self.mark(key);
        self.flags.iter().any(|f| f == key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.mark(key);
        self.kv.get(key).map(|s| s.as_str())
    }

    /// Every value a repeatable `--key value` option was given, in
    /// command-line order (`--set a=1 --set b=2` -> ["a=1", "b=2"]).
    pub fn all(&self, key: &str) -> Vec<&str> {
        self.mark(key);
        self.pairs
            .iter()
            .filter(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
            .collect()
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.parse_or(key, default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.parse_or(key, default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.parse_or(key, default)
    }

    fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        match self.get(key) {
            None => default,
            Some(s) => s.parse().unwrap_or_else(|_| {
                eprintln!("warning: --{key} value {s:?} unparseable; using default");
                default
            }),
        }
    }

    /// Error if the command line carried keys nobody asked about.
    pub fn check_unused(&self) -> anyhow::Result<()> {
        let seen = self.consumed.borrow();
        let unknown: Vec<&String> = self
            .kv
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !seen.contains(k))
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            anyhow::bail!("unknown arguments: {:?} (accepted: {:?})", unknown, *seen)
        }
    }

    /// Comma-separated list value.
    pub fn list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.get(key) {
            Some(s) => s.split(',').filter(|p| !p.is_empty()).map(String::from).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str], sub: bool) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string()), sub)
    }

    #[test]
    fn parses_subcommand_kv_flags_positional() {
        let a = parse(
            &["train", "extra", "--model", "mlp", "--rounds=20", "--verbose"],
            true,
        );
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("model"), Some("mlp"));
        assert_eq!(a.usize_or("rounds", 0), 20);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[], false);
        assert_eq!(a.usize_or("n", 7), 7);
        assert_eq!(a.f64_or("beta", 0.6), 0.6);
        assert_eq!(a.str_or("model", "mlp"), "mlp");
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["--a", "--b", "val"], false);
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("val"));
    }

    #[test]
    fn unused_detection() {
        let a = parse(&["--known", "1", "--typo", "2"], false);
        let _ = a.get("known");
        assert!(a.check_unused().is_err());
        let _ = a.get("typo");
        assert!(a.check_unused().is_ok());
    }

    #[test]
    fn repeated_options_keep_every_value_in_order() {
        let a = parse(&["--set", "a=1", "--other", "x", "--set", "b=2", "--set=c=3"], false);
        assert_eq!(a.all("set"), vec!["a=1", "b=2", "c=3"]);
        assert_eq!(a.get("set"), Some("c=3"), "kv keeps the last-wins view");
        assert!(a.all("missing").is_empty());
    }

    #[test]
    fn list_values() {
        let a = parse(&["--models", "mlp,vgg_cifar"], false);
        assert_eq!(a.list_or("models", &[]), vec!["mlp", "vgg_cifar"]);
        assert_eq!(a.list_or("other", &["x"]), vec!["x"]);
    }
}
