//! Binary/file I/O helpers: f32 little-endian vectors (init.bin, metric
//! dumps) and small CSV emission for figure data series.

use std::io::{Read, Write};
use std::path::Path;

/// Read a little-endian f32 vector (e.g. artifacts/<model>/init.bin).
pub fn read_f32_vec(path: &Path) -> anyhow::Result<Vec<f32>> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)
        .map_err(|e| anyhow::anyhow!("open {path:?}: {e}"))?
        .read_to_end(&mut bytes)?;
    anyhow::ensure!(bytes.len() % 4 == 0, "{path:?} not a multiple of 4 bytes");
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Write a little-endian f32 vector.
pub fn write_f32_vec(path: &Path, data: &[f32]) -> anyhow::Result<()> {
    let mut f = std::fs::File::create(path)?;
    let mut buf = Vec::with_capacity(data.len() * 4);
    for x in data {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    f.write_all(&buf)?;
    Ok(())
}

/// Write a CSV file: header row + numeric rows (figure data series).
pub fn write_csv(path: &Path, header: &[&str], rows: &[Vec<f64>]) -> anyhow::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut out = String::new();
    out.push_str(&header.join(","));
    out.push('\n');
    for row in rows {
        let cells: Vec<String> = row.iter().map(|x| format!("{x}")).collect();
        out.push_str(&cells.join(","));
        out.push('\n');
    }
    std::fs::write(path, out)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_vec_round_trip() {
        let dir = std::env::temp_dir().join("fedel_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("v.bin");
        let data = vec![1.5f32, -2.0, 0.0, f32::MAX];
        write_f32_vec(&p, &data).unwrap();
        assert_eq!(read_f32_vec(&p).unwrap(), data);
    }

    #[test]
    fn csv_emission() {
        let dir = std::env::temp_dir().join("fedel_io_test");
        let p = dir.join("t.csv");
        write_csv(&p, &["a", "b"], &[vec![1.0, 2.0], vec![3.5, 4.0]]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text, "a,b\n1,2\n3.5,4\n");
    }

    #[test]
    fn rejects_ragged_binary() {
        let dir = std::env::temp_dir().join("fedel_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, [1u8, 2, 3]).unwrap();
        assert!(read_f32_vec(&p).is_err());
    }
}
