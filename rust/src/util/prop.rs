//! Property-testing substrate (offline registry has no proptest).
//!
//! A small randomized-testing harness: generate N random cases from a seed,
//! run the property, and on failure greedily shrink the failing input via a
//! user-supplied shrinker before reporting. Deterministic: failures print
//! the case seed so they can be replayed exactly.

use super::rng::Rng;

/// Run `prop` against `cases` random inputs drawn by `gen`.
/// Panics with the minimal (greedily shrunk) counterexample.
pub fn check<T, G, P, S>(name: &str, cases: usize, mut gen: G, mut prop: P, shrink: S)
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
    S: Fn(&T) -> Vec<T>,
{
    let base_seed = 0xFED_E1u64;
    for case in 0..cases {
        let mut rng = Rng::new(base_seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            // Greedy shrink: keep taking the first failing shrink candidate.
            let mut cur = input.clone();
            let mut cur_msg = msg;
            let mut budget = 1000;
            'outer: while budget > 0 {
                for cand in shrink(&cur) {
                    budget -= 1;
                    if let Err(m) = prop(&cand) {
                        cur = cand;
                        cur_msg = m;
                        continue 'outer;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                break;
            }
            let seed = base_seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
            panic!(
                "property {name:?} failed (case {case}, replay seed {seed:#x})\n\
                 shrunk input: {cur:#?}\nreason: {cur_msg}"
            );
        }
    }
}

/// No-op shrinker for types where shrinking isn't worth it.
pub fn no_shrink<T: Clone>(_: &T) -> Vec<T> {
    Vec::new()
}

/// Shrinker for Vec<T>: halves, then single-element removals (capped).
pub fn shrink_vec<T: Clone>(v: &Vec<T>) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    if v.is_empty() {
        return out;
    }
    out.push(v[..v.len() / 2].to_vec());
    out.push(v[v.len() / 2..].to_vec());
    for i in 0..v.len().min(16) {
        let mut c = v.clone();
        c.remove(i);
        out.push(c);
    }
    out
}

/// Shrinker for numeric scalars toward zero.
pub fn shrink_usize(x: &usize) -> Vec<usize> {
    let x = *x;
    let mut out = Vec::new();
    if x > 0 {
        out.push(x / 2);
        out.push(x - 1);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check(
            "sum-commutes",
            50,
            |r| (r.below(100) as i64, r.below(100) as i64),
            |&(a, b)| {
                count += 1;
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("math broke".into())
                }
            },
            no_shrink,
        );
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics() {
        check(
            "always-small",
            100,
            |r| r.below(1000),
            |&x| if x < 5 { Ok(()) } else { Err(format!("{x} too big")) },
            shrink_usize,
        );
    }

    #[test]
    fn shrink_vec_produces_smaller() {
        let v = vec![1, 2, 3, 4];
        for c in shrink_vec(&v) {
            assert!(c.len() < v.len());
        }
    }
}
