//! Minimal JSON substrate (offline registry has no serde/serde_json).
//!
//! Full RFC 8259 parser + writer, enough for the artifact manifests,
//! experiment configs, and metric dumps this framework exchanges. Numbers
//! are held as f64 (the manifests only carry ints/floats well inside f64
//! range); object key order is preserved for stable round-trips.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn u(&self, key: &str) -> anyhow::Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("key {key:?} not a number"))
    }

    pub fn f(&self, key: &str) -> anyhow::Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("key {key:?} not a number"))
    }

    pub fn s(&self, key: &str) -> anyhow::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("key {key:?} not a string"))
    }

    pub fn arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("key {key:?} not an array"))
    }

    // -- builders ----------------------------------------------------------

    pub fn obj(kv: Vec<(&str, Json)>) -> Json {
        Json::Obj(kv.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn from_f64s(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x)).collect())
    }

    pub fn from_strs(v: &[&str]) -> Json {
        Json::Arr(v.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    pub fn from_bools(v: &[bool]) -> Json {
        Json::Arr(v.iter().map(|&b| Json::Bool(b)).collect())
    }

    // -- schema decode helpers ----------------------------------------------

    /// Array of numbers -> Vec<f64> (f64 round-trips the writer bitwise:
    /// Display prints the shortest representation that parses back exact).
    pub fn to_f64_vec(&self) -> anyhow::Result<Vec<f64>> {
        self.as_arr()
            .ok_or_else(|| anyhow::anyhow!("not an array"))?
            .iter()
            .map(|x| x.as_f64().ok_or_else(|| anyhow::anyhow!("array element not a number")))
            .collect()
    }

    /// Array of booleans -> Vec<bool>.
    pub fn to_bool_vec(&self) -> anyhow::Result<Vec<bool>> {
        self.as_arr()
            .ok_or_else(|| anyhow::anyhow!("not an array"))?
            .iter()
            .map(|x| x.as_bool().ok_or_else(|| anyhow::anyhow!("array element not a bool")))
            .collect()
    }

    // -- parse -------------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    // -- write -------------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(0));
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(d) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(d + 1));
                        item.write(out, Some(d + 1));
                    } else {
                        item.write(out, None);
                    }
                }
                if let (Some(d), false) = (indent, v.is_empty()) {
                    out.push('\n');
                    out.push_str(&" ".repeat(d));
                }
                out.push(']');
            }
            Json::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(d) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(d + 1));
                        write_escaped(out, k);
                        out.push_str(": ");
                        v.write(out, Some(d + 1));
                    } else {
                        write_escaped(out, k);
                        out.push(':');
                        v.write(out, None);
                    }
                }
                if let (Some(d), false) = (indent, kv.is_empty()) {
                    out.push('\n');
                    out.push_str(&" ".repeat(d));
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, None);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs: handle the high half.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                self.i += 5;
                                if self.peek() != Some(b'\\') {
                                    return Err(self.err("lone surrogate"));
                                }
                                self.i += 1;
                                if self.peek() != Some(b'u') || self.i + 4 >= self.b.len() {
                                    return Err(self.err("lone surrogate"));
                                }
                                let hex2 =
                                    std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                        .map_err(|_| self.err("bad \\u escape"))?;
                                let lo = u32::from_str_radix(hex2, 16)
                                    .map_err(|_| self.err("bad \\u escape"))?;
                                let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?
                            } else {
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                            };
                            s.push(ch);
                            self.i += 4; // the final +1 happens below
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let start = self.i;
                    let text = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let ch = text.chars().next().unwrap();
                    s.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

/// Convenience: ordered map of top-level numeric results -> Json object.
pub fn num_map(m: &BTreeMap<String, f64>) -> Json {
    Json::Obj(m.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str(), Some("x"));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn escapes_round_trip() {
        let s = Json::Str("a\"b\\c\nd\te\u{1}".into());
        let text = s.to_string();
        assert_eq!(Json::parse(&text).unwrap(), s);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(
            Json::parse(r#""😀""#).unwrap(),
            Json::Str("😀".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn round_trip_pretty() {
        let j = Json::obj(vec![
            ("n", Json::Num(3.0)),
            ("arr", Json::from_f64s(&[1.0, 2.5])),
            ("s", Json::Str("x".into())),
            ("empty", Json::Arr(vec![])),
        ]);
        let text = j.to_string_pretty();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn integers_written_without_fraction() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.25).to_string(), "5.25");
    }

    #[test]
    fn key_order_preserved() {
        let j = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        if let Json::Obj(kv) = &j {
            let keys: Vec<&str> = kv.iter().map(|(k, _)| k.as_str()).collect();
            assert_eq!(keys, vec!["z", "a", "m"]);
        } else {
            panic!();
        }
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{
 "model": "mlp", "param_count": 28860,
 "tensors": [{"name": "block0/dense/w", "shape": [64, 64], "offset": 0,
              "size": 4096, "block": 0, "is_head": false,
              "flops_fwd": 8192.0}]
}"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.u("param_count").unwrap(), 28860);
        let t = &j.arr("tensors").unwrap()[0];
        assert_eq!(t.s("name").unwrap(), "block0/dense/w");
        assert_eq!(t.u("size").unwrap(), 4096);
        assert_eq!(t.get("is_head").unwrap().as_bool(), Some(false));
    }
}
