//! Power / energy model (Fig 9).
//!
//! The paper observes that power draw is roughly method-independent (the
//! GPU runs at full tilt whenever active) while *energy* tracks active
//! time. We model exactly that: a device burns `power_watts` while
//! training and `idle_watts` while waiting for the round to finish, so
//!
//!   E_round(client) = P_active · t_client + P_idle · (t_round − t_client).

use crate::fl::server::ExperimentResult;
use crate::timing::DeviceProfile;

#[derive(Clone, Debug, Default)]
pub struct EnergyReport {
    /// Mean active power across devices and rounds (W).
    pub mean_power_w: f64,
    /// Total fleet energy over the experiment (kJ).
    pub total_kj: f64,
    /// Per-device-name totals (kJ).
    pub per_device: Vec<(String, f64)>,
}

const IDLE_FRACTION: f64 = 0.25; // idle draw relative to active

/// Fleet energy from an experiment's per-round per-client times.
///
/// Every recorded client id must index into `fleet`: a result paired with
/// the wrong fleet is a provenance bug, and silently wrapping the id (the
/// old `fleet[client % fleet.len()]`) attributed one device's energy to
/// another without a trace. Mismatches now error instead.
pub fn energy_report(
    res: &ExperimentResult,
    fleet: &[DeviceProfile],
) -> anyhow::Result<EnergyReport> {
    anyhow::ensure!(!fleet.is_empty(), "energy report over an empty fleet");
    let mut total_j = 0.0;
    let mut per: std::collections::BTreeMap<String, f64> = Default::default();
    let mut power_sum = 0.0;
    let mut power_n = 0usize;
    for rec in &res.records {
        for &(client, secs) in &rec.client_secs {
            anyhow::ensure!(
                client < fleet.len(),
                "round {}: client id {client} out of range for a {}-device fleet — \
                 this result was recorded against a different fleet",
                rec.round,
                fleet.len()
            );
            let dev = &fleet[client];
            let active = dev.power_watts * secs;
            let idle = dev.power_watts * IDLE_FRACTION * (rec.round_secs - secs).max(0.0);
            total_j += active + idle;
            *per.entry(dev.name.clone()).or_insert(0.0) += (active + idle) / 1e3;
            power_sum += dev.power_watts;
            power_n += 1;
        }
    }
    Ok(EnergyReport {
        mean_power_w: if power_n == 0 { 0.0 } else { power_sum / power_n as f64 },
        total_kj: total_j / 1e3,
        per_device: per.into_iter().collect(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::server::{ExperimentResult, RoundRecord};

    fn result_with(times: Vec<(usize, f64)>, round_secs: f64) -> ExperimentResult {
        ExperimentResult {
            strategy: "t".into(),
            records: vec![RoundRecord {
                round: 0,
                round_secs,
                sim_time: round_secs,
                mean_train_loss: 0.0,
                participants: times.len(),
                mean_coverage: 1.0,
                o1: 0.0,
                eval_acc: None,
                eval_loss: None,
                client_secs: times,
                mean_staleness: None,
                max_staleness: None,
                dropped: vec![],
                spec_hits: 0,
                spec_misses: 0,
            }],
            sim_total_secs: round_secs,
            final_acc: 0.0,
            final_loss: 0.0,
            final_params: vec![],
            selections: vec![],
        }
    }

    #[test]
    fn energy_tracks_active_time() {
        let fleet = vec![DeviceProfile::new("d", 1.0, 10.0)];
        let short = energy_report(&result_with(vec![(0, 100.0)], 100.0), &fleet).unwrap();
        let long = energy_report(&result_with(vec![(0, 200.0)], 200.0), &fleet).unwrap();
        assert!(long.total_kj > short.total_kj * 1.9);
    }

    #[test]
    fn idle_waiting_costs_less_than_training() {
        let fleet = vec![DeviceProfile::new("fast", 1.0, 10.0), DeviceProfile::new("slow", 2.0, 10.0)];
        // fast client finishes at 100s, waits 100s for the slow one
        let rep = energy_report(&result_with(vec![(0, 100.0), (1, 200.0)], 200.0), &fleet).unwrap();
        // fast: 10*100 + 2.5*100 = 1250 J; slow: 10*200 = 2000 J
        assert!((rep.total_kj - 3.25).abs() < 1e-9, "{}", rep.total_kj);
    }

    #[test]
    fn mean_power_is_profile_power() {
        let fleet = vec![DeviceProfile::new("d", 1.0, 15.0)];
        let rep = energy_report(&result_with(vec![(0, 50.0)], 50.0), &fleet).unwrap();
        assert_eq!(rep.mean_power_w, 15.0);
    }

    #[test]
    fn out_of_range_client_ids_error_instead_of_wrapping() {
        // Regression: `fleet[client % fleet.len()]` silently charged
        // client 2's energy to device 0 of a 2-device fleet.
        let fleet =
            vec![DeviceProfile::new("a", 1.0, 10.0), DeviceProfile::new("b", 2.0, 10.0)];
        let err = energy_report(&result_with(vec![(2, 50.0)], 50.0), &fleet).unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
        assert!(err.to_string().contains("different fleet"), "{err}");
        assert!(energy_report(&result_with(vec![(0, 1.0)], 1.0), &[]).is_err());
    }
}
