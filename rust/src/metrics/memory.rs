//! Training-memory model (Fig 8).
//!
//! The paper measures device memory with the Jetson Power GUI; we model
//! the same quantity analytically (DESIGN.md §4):
//!
//!   memory = parameters                       (always resident)
//!          + gradient buffers                 (backward-reachable tensors)
//!          + activations of forward blocks    (saved for backward)
//!
//! The backward-reachable set under a mask is the chain from the exit head
//! down to the *shallowest selected* tensor (unselected tensors in between
//! still materialize gradients — Limitation #1); blocks past the exit are
//! never forwarded, which is where FedEL's window saves activation memory.
//!
//! Activation elements per tensor are derived from the manifest:
//! out_elems ≈ flops_fwd / (2 · fan_in), exact for dense and conv ops.

use crate::manifest::Manifest;

#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MemoryBreakdown {
    pub params_bytes: f64,
    pub grad_bytes: f64,
    pub act_bytes: f64,
}

impl MemoryBreakdown {
    pub fn total(&self) -> f64 {
        self.params_bytes + self.grad_bytes + self.act_bytes
    }

    pub fn total_mb(&self) -> f64 {
        self.total() / (1024.0 * 1024.0)
    }
}

/// Activation elements produced by the op of tensor `i` (per example).
pub fn act_elems(m: &Manifest, i: usize) -> f64 {
    let t = &m.tensors[i];
    let fan_in: f64 = if t.shape.len() >= 2 {
        t.shape[..t.shape.len() - 1].iter().product::<usize>() as f64
    } else {
        1.0
    };
    (t.flops_fwd / (2.0 * fan_in.max(1.0))).max(t.shape.last().copied().unwrap_or(1) as f64)
}

/// Memory for one client plan: exit + per-tensor coverage mask [K].
pub fn memory_bytes(m: &Manifest, exit: usize, tensor_mask: &[f32]) -> MemoryBreakdown {
    assert_eq!(tensor_mask.len(), m.tensors.len());
    let f32b = 4.0;
    let params_bytes = m.param_count as f64 * f32b;

    // Backward-reachable set: find the shallowest selected tensor among
    // forward-participating tensors (blocks < exit and the exit head);
    // everything from it to the exit head holds a gradient buffer.
    let in_forward = |i: usize| -> bool {
        let t = &m.tensors[i];
        if t.is_head {
            t.block == exit - 1
        } else {
            t.block < exit
        }
    };
    let selected_offsets: Vec<usize> = (0..m.tensors.len())
        .filter(|&i| in_forward(i) && tensor_mask[i] > 0.0)
        .map(|i| m.tensors[i].offset)
        .collect();
    let grad_bytes = match selected_offsets.iter().min() {
        None => 0.0,
        Some(&min_off) => (0..m.tensors.len())
            .filter(|&i| in_forward(i) && m.tensors[i].offset >= min_off)
            .map(|i| m.tensors[i].size as f64 * f32b)
            .sum(),
    };

    // Activations: every forward-visited op saves its output.
    let act_bytes: f64 = (0..m.tensors.len())
        .filter(|&i| in_forward(i))
        .map(|i| act_elems(m, i) * m.batch as f64 * f32b)
        .sum();

    MemoryBreakdown { params_bytes, grad_bytes, act_bytes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::tests_support::chain_manifest;

    #[test]
    fn full_training_uses_most_memory() {
        let m = chain_manifest(6, 100);
        let k = m.tensors.len();
        let full = memory_bytes(&m, 6, &vec![1.0; k]);
        let mut partial_mask = vec![0.0f32; k];
        partial_mask[0] = 1.0; // only block 0 body
        let partial = memory_bytes(&m, 2, &partial_mask);
        assert!(full.total() > partial.total());
        assert!(full.grad_bytes > 0.0 && full.act_bytes > 0.0);
    }

    #[test]
    fn early_exit_cuts_activation_memory() {
        let m = chain_manifest(8, 50);
        let k = m.tensors.len();
        let deep = memory_bytes(&m, 8, &vec![1.0; k]);
        let shallow = memory_bytes(&m, 2, &vec![1.0; k]);
        assert!(shallow.act_bytes < deep.act_bytes * 0.5);
    }

    #[test]
    fn chain_rule_counts_unselected_between() {
        // selecting only a shallow tensor still allocates grads up the chain
        let m = chain_manifest(4, 100);
        let k = m.tensors.len();
        let mut only_shallow = vec![0.0f32; k];
        only_shallow[0] = 1.0; // block0 body
        let a = memory_bytes(&m, 4, &only_shallow);
        let mut only_deep = vec![0.0f32; k];
        only_deep[6] = 1.0; // block3 body
        let b = memory_bytes(&m, 4, &only_deep);
        assert!(a.grad_bytes > b.grad_bytes, "{} vs {}", a.grad_bytes, b.grad_bytes);
    }

    #[test]
    fn empty_selection_no_grad_memory() {
        let m = chain_manifest(4, 10);
        let k = m.tensors.len();
        let br = memory_bytes(&m, 4, &vec![0.0; k]);
        assert_eq!(br.grad_bytes, 0.0);
        assert!(br.params_bytes > 0.0);
    }

    #[test]
    fn params_memory_constant() {
        let m = chain_manifest(5, 20);
        let k = m.tensors.len();
        let a = memory_bytes(&m, 1, &vec![0.0; k]);
        let b = memory_bytes(&m, 5, &vec![1.0; k]);
        assert_eq!(a.params_bytes, b.params_bytes);
    }
}
