//! Measurement models: training memory (Fig 8), power/energy (Fig 9), and
//! time-to-accuracy bookkeeping.

pub mod energy;
pub mod memory;

pub use energy::{energy_report, EnergyReport};
pub use memory::{memory_bytes, MemoryBreakdown};
