//! Model manifest: the L2→L3 contract describing each AOT-compiled model.
//!
//! `artifacts/<model>/manifest.json` (written by `python -m compile.aot`)
//! carries the flat-parameter layout — per-tensor offsets/shapes, block
//! membership, head flags, and forward-FLOP counts — which is everything
//! the coordinator needs to build masks, tensor timings, importance
//! vectors, and aggregation coverage without ever touching python.

use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// One tensor of the flat parameter vector.
#[derive(Clone, Debug)]
pub struct TensorInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
    pub block: usize,
    pub is_head: bool,
    /// Forward FLOPs (per example) of the op this tensor parameterizes —
    /// the raw material for the ElasticTrainer timing model.
    pub flops_fwd: f64,
}

/// One sliding-window block.
#[derive(Clone, Debug)]
pub struct BlockInfo {
    pub id: usize,
    pub tensor_ids: Vec<usize>,
    pub flops_fwd: f64,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub model: String,
    pub dir: PathBuf,
    pub batch: usize,
    pub input_shape: Vec<usize>,
    pub num_classes: usize,
    pub label_len: usize,
    pub task: Task,
    pub param_count: usize,
    pub num_blocks: usize,
    pub tensors: Vec<TensorInfo>,
    pub blocks: Vec<BlockInfo>,
    pub init_sha1: String,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    Classification,
    Lm,
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| anyhow::anyhow!("read {dir:?}/manifest.json: {e}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        Self::from_json(&j, dir)
    }

    pub fn from_json(j: &Json, dir: &Path) -> anyhow::Result<Manifest> {
        let task = match j.s("task")? {
            "classification" => Task::Classification,
            "lm" => Task::Lm,
            other => anyhow::bail!("unknown task {other:?}"),
        };
        let tensors: Vec<TensorInfo> = j
            .arr("tensors")?
            .iter()
            .map(|t| -> anyhow::Result<TensorInfo> {
                Ok(TensorInfo {
                    name: t.s("name")?.to_string(),
                    shape: t.arr("shape")?.iter().filter_map(|x| x.as_usize()).collect(),
                    offset: t.u("offset")?,
                    size: t.u("size")?,
                    block: t.u("block")?,
                    is_head: t.req("is_head")?.as_bool().unwrap_or(false),
                    flops_fwd: t.f("flops_fwd")?,
                })
            })
            .collect::<anyhow::Result<_>>()?;
        let blocks: Vec<BlockInfo> = j
            .arr("blocks")?
            .iter()
            .map(|b| -> anyhow::Result<BlockInfo> {
                Ok(BlockInfo {
                    id: b.u("id")?,
                    tensor_ids: b.arr("tensor_ids")?.iter().filter_map(|x| x.as_usize()).collect(),
                    flops_fwd: b.f("flops_fwd")?,
                })
            })
            .collect::<anyhow::Result<_>>()?;
        let m = Manifest {
            model: j.s("model")?.to_string(),
            dir: dir.to_path_buf(),
            batch: j.u("batch")?,
            input_shape: j.arr("input_shape")?.iter().filter_map(|x| x.as_usize()).collect(),
            num_classes: j.u("num_classes")?,
            label_len: j.u("label_len")?,
            task,
            param_count: j.u("param_count")?,
            num_blocks: j.u("num_blocks")?,
            tensors,
            blocks,
            init_sha1: j.s("init_sha1").unwrap_or("").to_string(),
        };
        m.validate()?;
        Ok(m)
    }

    /// Structural invariants every manifest must satisfy.
    pub fn validate(&self) -> anyhow::Result<()> {
        let mut off = 0usize;
        for t in &self.tensors {
            anyhow::ensure!(t.offset == off, "tensor {} offset gap", t.name);
            anyhow::ensure!(
                t.size == t.shape.iter().product::<usize>(),
                "tensor {} size/shape mismatch",
                t.name
            );
            anyhow::ensure!(t.block < self.num_blocks, "tensor {} bad block", t.name);
            off += t.size;
        }
        anyhow::ensure!(off == self.param_count, "param_count mismatch");
        anyhow::ensure!(self.blocks.len() == self.num_blocks, "blocks len");
        let mut seen = vec![false; self.tensors.len()];
        for b in &self.blocks {
            for &i in &b.tensor_ids {
                anyhow::ensure!(i < self.tensors.len(), "block tensor id oob");
                anyhow::ensure!(!seen[i], "tensor {i} in two blocks");
                seen[i] = true;
                anyhow::ensure!(self.tensors[i].block == b.id, "block membership");
            }
        }
        anyhow::ensure!(seen.iter().all(|&s| s), "tensor not covered by blocks");
        Ok(())
    }

    pub fn train_hlo_path(&self, exit: usize) -> PathBuf {
        self.dir.join(format!("train_exit_{exit}.hlo.txt"))
    }

    pub fn eval_hlo_path(&self) -> PathBuf {
        self.dir.join("eval.hlo.txt")
    }

    pub fn init_path(&self) -> PathBuf {
        self.dir.join("init.bin")
    }

    pub fn load_init(&self) -> anyhow::Result<Vec<f32>> {
        let v = crate::util::io::read_f32_vec(&self.init_path())?;
        anyhow::ensure!(v.len() == self.param_count, "init.bin length mismatch");
        Ok(v)
    }

    /// Tensor ids of block `b`, body (non-head) only.
    pub fn body_tensors_of_block(&self, b: usize) -> Vec<usize> {
        self.blocks[b]
            .tensor_ids
            .iter()
            .copied()
            .filter(|&i| !self.tensors[i].is_head)
            .collect()
    }

    /// Tensor ids of the early-exit head attached to block `b`.
    pub fn head_tensors_of_block(&self, b: usize) -> Vec<usize> {
        self.blocks[b]
            .tensor_ids
            .iter()
            .copied()
            .filter(|&i| self.tensors[i].is_head)
            .collect()
    }

    /// Expand a per-tensor [K] mask into the element-level [P] mask the
    /// train artifact consumes. Fractional values allowed (HeteroFL).
    pub fn expand_mask(&self, tensor_mask: &[f32]) -> Vec<f32> {
        assert_eq!(
            tensor_mask.len(),
            self.tensors.len(),
            "expand_mask: tensor mask holds {} entries, manifest has {} tensors",
            tensor_mask.len(),
            self.tensors.len()
        );
        let mut out = vec![0.0f32; self.param_count];
        for (t, &m) in self.tensors.iter().zip(tensor_mask) {
            if m != 0.0 {
                out[t.offset..t.offset + t.size].fill(m);
            }
        }
        out
    }

    /// Expand a per-tensor *fractional prefix coverage* vector: entry k in
    /// [0,1] marks the leading fraction of tensor k's elements as
    /// trainable (HeteroFL-style width scaling at element granularity).
    pub fn expand_prefix_mask(&self, frac: &[f32]) -> Vec<f32> {
        assert_eq!(
            frac.len(),
            self.tensors.len(),
            "expand_prefix_mask: coverage holds {} entries, manifest has {} tensors",
            frac.len(),
            self.tensors.len()
        );
        let mut out = vec![0.0f32; self.param_count];
        for (t, &f) in self.tensors.iter().zip(frac) {
            let n = ((t.size as f64) * f.clamp(0.0, 1.0) as f64).round() as usize;
            out[t.offset..t.offset + n.min(t.size)].fill(1.0);
        }
        out
    }

    /// Parameter elements a client must download to run forward to `exit`:
    /// bodies of every block `< exit` plus the exit head (the same
    /// sub-model [`TimingModel::forward_time`](crate::timing::TimingModel)
    /// prices). The communication model's download payload.
    pub fn forward_param_count(&self, exit: usize) -> usize {
        let mut n = 0usize;
        for b in 0..exit {
            for &i in &self.blocks[b].tensor_ids {
                if !self.tensors[i].is_head {
                    n += self.tensors[i].size;
                }
            }
        }
        for i in self.head_tensors_of_block(exit - 1) {
            n += self.tensors[i].size;
        }
        n
    }

    /// Fractional trained-element count under a per-tensor coverage vector
    /// (the [`MaskSpec::tensor_coverage`](crate::strategies::MaskSpec)
    /// form): the communication model's upload payload.
    pub fn masked_param_count(&self, coverage: &[f32]) -> f64 {
        assert_eq!(
            coverage.len(),
            self.tensors.len(),
            "masked_param_count: coverage holds {} entries, manifest has {} tensors",
            coverage.len(),
            self.tensors.len()
        );
        self.tensors
            .iter()
            .zip(coverage)
            .map(|(t, &c)| t.size as f64 * c as f64)
            .sum()
    }
}

/// Discover all model manifests under an artifacts root.
pub fn discover(root: &Path) -> anyhow::Result<Vec<Manifest>> {
    let mut out = Vec::new();
    for entry in std::fs::read_dir(root)? {
        let dir = entry?.path();
        if dir.is_dir() && dir.join("manifest.json").exists() {
            out.push(Manifest::load(&dir)?);
        }
    }
    out.sort_by(|a, b| a.model.cmp(&b.model));
    Ok(out)
}

/// Synthetic manifests for tests, benches, and the mock engine — usable
/// from integration tests and examples, hence not #[cfg(test)].
pub mod tests_support {
    use super::*;
    use std::path::Path;

    /// JSON text of a tiny 2-block manifest (2 body tensors + 2 heads,
    /// 26 params) exercised by unit tests.
    pub fn toy_json() -> String {
        r#"{
 "model": "toy", "batch": 4, "input_shape": [8], "num_classes": 3,
 "label_len": 4, "task": "classification", "param_count": 26,
 "num_tensors": 4, "num_blocks": 2,
 "tensors": [
  {"name": "block0/w", "shape": [2, 4], "offset": 0, "size": 8,
   "block": 0, "is_head": false, "flops_fwd": 64.0},
  {"name": "head0/w", "shape": [4], "offset": 8, "size": 4,
   "block": 0, "is_head": true, "flops_fwd": 8.0},
  {"name": "block1/w", "shape": [2, 5], "offset": 12, "size": 10,
   "block": 1, "is_head": false, "flops_fwd": 100.0},
  {"name": "head1/w", "shape": [4], "offset": 22, "size": 4,
   "block": 1, "is_head": true, "flops_fwd": 8.0}
 ],
 "blocks": [
  {"id": 0, "tensor_ids": [0, 1], "flops_fwd": 64.0},
  {"id": 1, "tensor_ids": [2, 3], "flops_fwd": 100.0}
 ],
 "exits": [1, 2]
}"#
        .to_string()
    }

    /// A toy 2-block manifest (2 body tensors + 2 heads, 26 params).
    pub fn toy_manifest() -> Manifest {
        let j = Json::parse(&toy_json()).unwrap();
        Manifest::from_json(&j, Path::new("/tmp/toy")).unwrap()
    }

    /// A synthetic chain model with `blocks` blocks; each block has a body
    /// tensor of `body` params (FLOPs grow with depth: flops_i = base *
    /// (1 + i/2), ~10 MFLOP so the timing model is FLOP-dominated like the
    /// real zoo manifests, with cheap heads) and a small head. Used by
    /// window/DP/strategy tests at realistic scale.
    pub fn chain_manifest(blocks: usize, body: usize) -> Manifest {
        let mut tensors = Vec::new();
        let mut block_list = Vec::new();
        let mut off = 0usize;
        for b in 0..blocks {
            let flops = 1.0e7 * (1.0 + b as f64 / 2.0);
            tensors.push(Json::obj(vec![
                ("name", Json::Str(format!("block{b}/w"))),
                ("shape", Json::from_f64s(&[body as f64])),
                ("offset", Json::Num(off as f64)),
                ("size", Json::Num(body as f64)),
                ("block", Json::Num(b as f64)),
                ("is_head", Json::Bool(false)),
                ("flops_fwd", Json::Num(flops)),
            ]));
            off += body;
            tensors.push(Json::obj(vec![
                ("name", Json::Str(format!("head{b}/w"))),
                ("shape", Json::from_f64s(&[4.0])),
                ("offset", Json::Num(off as f64)),
                ("size", Json::Num(4.0)),
                ("block", Json::Num(b as f64)),
                ("is_head", Json::Bool(true)),
                ("flops_fwd", Json::Num(8.0)),
            ]));
            off += 4;
            block_list.push(Json::obj(vec![
                ("id", Json::Num(b as f64)),
                ("tensor_ids", Json::from_f64s(&[(2 * b) as f64, (2 * b + 1) as f64])),
                ("flops_fwd", Json::Num(flops)),
            ]));
        }
        let j = Json::obj(vec![
            ("model", Json::Str(format!("chain{blocks}"))),
            ("batch", Json::Num(4.0)),
            ("input_shape", Json::from_f64s(&[8.0])),
            ("num_classes", Json::Num(4.0)),
            ("label_len", Json::Num(4.0)),
            ("task", Json::Str("classification".into())),
            ("param_count", Json::Num(off as f64)),
            ("num_tensors", Json::Num(tensors.len() as f64)),
            ("num_blocks", Json::Num(blocks as f64)),
            ("tensors", Json::Arr(tensors)),
            ("blocks", Json::Arr(block_list)),
        ]);
        Manifest::from_json(&j, Path::new("/tmp/chain")).unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::tests_support::{toy_json, toy_manifest as toy};
    use super::*;

    #[test]
    fn parses_toy_manifest() {
        let m = toy();
        assert_eq!(m.model, "toy");
        assert_eq!(m.param_count, 26);
        assert_eq!(m.tensors.len(), 4);
        assert_eq!(m.num_blocks, 2);
        assert_eq!(m.task, Task::Classification);
    }

    #[test]
    fn block_helpers() {
        let m = toy();
        assert_eq!(m.body_tensors_of_block(0), vec![0]);
        assert_eq!(m.head_tensors_of_block(0), vec![1]);
        assert_eq!(m.body_tensors_of_block(1), vec![2]);
    }

    #[test]
    fn expand_mask_covers_selected_tensors() {
        let m = toy();
        let mask = m.expand_mask(&[1.0, 0.0, 0.5, 1.0]);
        assert_eq!(mask.len(), 26);
        assert!(mask[0..8].iter().all(|&x| x == 1.0));
        assert!(mask[8..12].iter().all(|&x| x == 0.0));
        assert!(mask[12..22].iter().all(|&x| x == 0.5));
        assert!(mask[22..26].iter().all(|&x| x == 1.0));
    }

    #[test]
    fn expand_prefix_mask_fractional() {
        let m = toy();
        let mask = m.expand_prefix_mask(&[0.5, 0.0, 1.0, 0.0]);
        assert_eq!(mask[0..4], [1.0, 1.0, 1.0, 1.0]);
        assert_eq!(mask[4..8], [0.0, 0.0, 0.0, 0.0]);
        assert!(mask[12..22].iter().all(|&x| x == 1.0));
    }

    #[test]
    #[should_panic(expected = "expand_mask: tensor mask holds 3 entries")]
    fn expand_mask_rejects_short_mask() {
        toy().expand_mask(&[1.0, 0.0, 1.0]);
    }

    #[test]
    #[should_panic(expected = "expand_prefix_mask: coverage holds 5 entries")]
    fn expand_prefix_mask_rejects_long_mask() {
        toy().expand_prefix_mask(&[1.0; 5]);
    }

    #[test]
    #[should_panic(expected = "masked_param_count: coverage holds 2 entries")]
    fn masked_param_count_rejects_short_coverage() {
        toy().masked_param_count(&[1.0, 0.5]);
    }

    #[test]
    fn validation_rejects_offset_gap() {
        let text = toy_json().replace("\"offset\": 8", "\"offset\": 9");
        let j = Json::parse(&text).unwrap();
        assert!(Manifest::from_json(&j, Path::new("/tmp")).is_err());
    }

    #[test]
    fn validation_rejects_bad_param_count() {
        let text = toy_json().replace("\"param_count\": 26", "\"param_count\": 27");
        let j = Json::parse(&text).unwrap();
        assert!(Manifest::from_json(&j, Path::new("/tmp")).is_err());
    }
}
