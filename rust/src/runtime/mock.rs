//! Mock engine: closed-form compute with the exact `Engine`/`TrainSession`
//! interface.
//!
//! Loss is a masked quadratic pulled toward a data-dependent target:
//!     L(p) = 0.5 / P_e * sum_{k reachable at exit e} (p_k - t_k(x))^2
//! where t(x) = global_target + delta(x) and "reachable at exit e" mirrors
//! the early-exit semantics (blocks >= e contribute no gradient; the head
//! of block e-1 does). Gradients, masked updates, and per-tensor squared
//! gradients are all exact, so every coordinator policy (DP selection,
//! sliding window, importance adjustment, aggregation) can be tested
//! deterministically without PJRT or artifacts.
//!
//! The engine itself is immutable shared state (manifest + global target);
//! each [`MockSession`] owns a per-session scratch buffer for the
//! data-dependent target, so concurrent sessions never contend and a
//! step's output is a pure function of its arguments.

use crate::manifest::Manifest;
use crate::util::rng::Rng;

use super::{check_shapes, Engine, EvalOut, TrainOut, TrainSession};

pub struct MockEngine {
    manifest: Manifest,
    target: Vec<f32>,
    /// Strength of the data-dependent target shift (model drift knob).
    pub data_shift: f32,
}

impl MockEngine {
    pub fn new(manifest: Manifest, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let target: Vec<f32> = (0..manifest.param_count).map(|_| rng.normal_f32()).collect();
        MockEngine { manifest, target, data_shift: 0.25 }
    }

    /// Which tensors receive gradient at a given exit: all body tensors of
    /// blocks < exit, plus the head of block exit-1.
    fn reachable(&self, exit: usize) -> Vec<bool> {
        self.manifest
            .tensors
            .iter()
            .map(|t| {
                if t.is_head {
                    t.block == exit - 1
                } else {
                    t.block < exit
                }
            })
            .collect()
    }

    /// Write the data-dependent target t(x) into `out` (fully overwritten:
    /// session scratch must not leak state between steps).
    fn fill_target_for(&self, x: &[f32], out: &mut Vec<f32>) {
        // Cheap deterministic hash of the batch -> per-tensor shift.
        let mut h = 0u64;
        for &v in x.iter().take(16) {
            h = h.wrapping_mul(0x100000001B3).wrapping_add(v.to_bits() as u64);
        }
        let mut rng = Rng::new(h);
        out.clear();
        out.extend_from_slice(&self.target);
        for ti in &self.manifest.tensors {
            let shift = rng.normal_f32() * self.data_shift;
            for v in &mut out[ti.offset..ti.offset + ti.size] {
                *v += shift;
            }
        }
    }
}

impl Engine for MockEngine {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn session(&self) -> Box<dyn TrainSession + '_> {
        Box::new(MockSession { engine: self, target_scratch: Vec::new() })
    }
}

/// One mock execution stream: borrows the engine's immutable target and
/// keeps a private scratch buffer so parallel sessions never allocate or
/// contend on the hot path.
pub struct MockSession<'a> {
    engine: &'a MockEngine,
    target_scratch: Vec<f32>,
}

impl TrainSession for MockSession<'_> {
    fn train_step(
        &mut self,
        exit: usize,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        mask: &[f32],
        lr: f32,
    ) -> anyhow::Result<TrainOut> {
        let e = self.engine;
        check_shapes(&e.manifest, exit, params, x, y, mask)?;
        let reach = e.reachable(exit);
        e.fill_target_for(x, &mut self.target_scratch);
        let target = &self.target_scratch;
        let k = e.manifest.tensors.len();
        let mut new_params = params.to_vec();
        let mut sq_grads = vec![0.0f64; k];
        let mut loss = 0.0f64;
        let mut n_reach = 0usize;
        for (i, t) in e.manifest.tensors.iter().enumerate() {
            if !reach[i] {
                continue;
            }
            n_reach += t.size;
        }
        let scale = 1.0 / n_reach.max(1) as f32;
        for (i, t) in e.manifest.tensors.iter().enumerate() {
            if !reach[i] {
                continue;
            }
            for j in t.offset..t.offset + t.size {
                let g = (params[j] - target[j]) * scale;
                loss += 0.5 * ((params[j] - target[j]) as f64).powi(2) * scale as f64;
                sq_grads[i] += (g as f64) * (g as f64);
                new_params[j] = params[j] - lr * mask[j] * g;
            }
        }
        let _ = y;
        Ok(TrainOut { new_params, loss: loss as f32, sq_grads })
    }

    fn eval_step(&mut self, params: &[f32], x: &[f32], y: &[i32]) -> anyhow::Result<EvalOut> {
        let _ = (x, y);
        let e = self.engine;
        // Distance of the full parameter vector to the *global* target maps
        // to a pseudo-accuracy in (0, 1]: closer == higher.
        let p = e.manifest.param_count as f64;
        let mse: f64 = params
            .iter()
            .zip(&e.target)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / p;
        let rows = e.manifest.label_len as f64;
        let acc = 1.0 / (1.0 + mse);
        Ok(EvalOut { correct: acc * rows, loss_sum: mse * rows, rows })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::tests_support::toy_manifest;

    fn engine() -> MockEngine {
        MockEngine::new(toy_manifest(), 1)
    }

    fn batch(m: &Manifest) -> (Vec<f32>, Vec<i32>) {
        let x = vec![0.5f32; m.batch * m.input_shape.iter().product::<usize>()];
        let y = vec![0i32; m.label_len];
        (x, y)
    }

    #[test]
    fn full_mask_training_reduces_loss() {
        let e = engine();
        let m = e.manifest().clone();
        let (x, y) = batch(&m);
        let mask = vec![1.0f32; m.param_count];
        let mut p = vec![0.0f32; m.param_count];
        let mut s = e.session();
        let mut last = f32::MAX;
        for _ in 0..50 {
            let out = s.train_step(m.num_blocks, &p, &x, &y, &mask, 0.5).unwrap();
            p = out.new_params;
            assert!(out.loss <= last * 1.0001);
            last = out.loss;
        }
        assert!(last < 0.1, "loss did not converge: {last}");
    }

    #[test]
    fn zero_mask_freezes_params() {
        let e = engine();
        let m = e.manifest().clone();
        let (x, y) = batch(&m);
        let p = vec![0.3f32; m.param_count];
        let mut s = e.session();
        let out = s.train_step(1, &p, &x, &y, &vec![0.0; m.param_count], 0.5).unwrap();
        assert_eq!(out.new_params, p);
        // but gradients (importance) are still reported
        assert!(out.sq_grads.iter().any(|&s| s > 0.0));
    }

    #[test]
    fn exit_limits_gradient_scope() {
        let e = engine();
        let m = e.manifest().clone();
        let (x, y) = batch(&m);
        let p = vec![0.3f32; m.param_count];
        let mut s = e.session();
        let out = s.train_step(1, &p, &x, &y, &vec![1.0; m.param_count], 0.5).unwrap();
        // block 1 body + head1 tensors untouched at exit 1
        for (i, t) in m.tensors.iter().enumerate() {
            let moved = (t.offset..t.offset + t.size).any(|j| out.new_params[j] != p[j]);
            let expect = if t.is_head { t.block == 0 } else { t.block < 1 };
            assert_eq!(moved, expect, "tensor {i} ({})", t.name);
        }
    }

    #[test]
    fn eval_accuracy_improves_with_training() {
        let e = engine();
        let m = e.manifest().clone();
        let (x, y) = batch(&m);
        let mask = vec![1.0f32; m.param_count];
        let mut p = vec![0.0f32; m.param_count];
        let mut s = e.session();
        let before = s.eval_step(&p, &x, &y).unwrap().accuracy();
        for _ in 0..60 {
            p = s.train_step(m.num_blocks, &p, &x, &y, &mask, 0.5).unwrap().new_params;
        }
        let after = s.eval_step(&p, &x, &y).unwrap().accuracy();
        assert!(after > before, "{before} -> {after}");
    }

    #[test]
    fn shape_validation_errors() {
        let e = engine();
        let m = e.manifest().clone();
        let (x, y) = batch(&m);
        let p = vec![0.0f32; m.param_count];
        let mask = vec![1.0f32; m.param_count];
        let mut s = e.session();
        assert!(s.train_step(0, &p, &x, &y, &mask, 0.1).is_err());
        assert!(s.train_step(9, &p, &x, &y, &mask, 0.1).is_err());
        assert!(s.train_step(1, &p[1..], &x, &y, &mask, 0.1).is_err());
    }

    #[test]
    fn scratch_reuse_does_not_change_results() {
        // A reused session (sequential path) and a fresh session (parallel
        // path) must produce identical outputs for the same call.
        let e = engine();
        let m = e.manifest().clone();
        let (x, y) = batch(&m);
        let (x2, y2) = {
            let mut x2 = x.clone();
            x2[0] = -1.5;
            (x2, y.clone())
        };
        let p = vec![0.2f32; m.param_count];
        let mask = vec![1.0f32; m.param_count];
        let mut reused = e.session();
        reused.train_step(m.num_blocks, &p, &x2, &y2, &mask, 0.3).unwrap();
        let a = reused.train_step(m.num_blocks, &p, &x, &y, &mask, 0.3).unwrap();
        let b = e.session().train_step(m.num_blocks, &p, &x, &y, &mask, 0.3).unwrap();
        assert_eq!(a.new_params, b.new_params);
        assert_eq!(a.sq_grads, b.sq_grads);
    }
}
