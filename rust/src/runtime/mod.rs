//! Runtime: load + execute the AOT artifacts from the L3 hot path.
//!
//! The compute interface is split in two:
//!
//! * [`Engine`] is an immutable, `Send + Sync` *factory*: it owns the
//!   expensive shared substrate (manifest, compiled-executable cache,
//!   PJRT client / mock targets) and hands out sessions. One engine is
//!   built per experiment and shared by reference across worker threads.
//! * [`TrainSession`] owns all mutable per-client execution state
//!   (per-session executable handles on PJRT, scratch buffers on the
//!   mock engine) and exposes the actual `train_step`/`eval_step` calls.
//!   Sessions are `Send` but not shared: each worker in the server's
//!   parallel fan-out spawns its own via [`Engine::session`].
//!
//! The *schedule* (which exit, which mask, how many steps) is entirely
//! the coordinator's business — exactly the paper's split between system
//! policy (L3) and compute (L1/L2). The design invariant on top of the
//! split: a session's outputs depend only on the call arguments, never on
//! which session or thread runs them, so the server can fan a round out
//! over N threads and still aggregate bitwise-identical results in plan
//! order (see `fl::server` and `tests/determinism.rs`).
//!
//! `PjrtEngine` (pjrt.rs, behind the `pjrt` cargo feature) is the
//! production engine: it loads HLO text through the `xla` crate, compiles
//! one executable per early-exit lazily on the PJRT CPU client, and keeps
//! them cached behind a mutex; sessions clone cheap `Arc` handles so the
//! lock is never held during execution. `MockEngine` (mock.rs) is a
//! closed-form pure-rust engine with the same interface, backing the
//! engine-independent unit/property tests.

pub mod mock;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use mock::MockEngine;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtEngine;

use crate::manifest::Manifest;

/// Output of one local SGD step through a train_exit_<e> artifact.
#[derive(Clone, Debug)]
pub struct TrainOut {
    pub new_params: Vec<f32>,
    pub loss: f32,
    /// Per-tensor sum of squared gradients [K] — the raw material for
    /// ElasticTrainer tensor importance (importance = lr * sq_grads).
    pub sq_grads: Vec<f64>,
}

/// Output of the eval artifact over one batch.
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalOut {
    /// Correct predictions (classification) / correct next tokens (LM).
    pub correct: f64,
    /// Summed cross-entropy over rows.
    pub loss_sum: f64,
    /// Rows evaluated.
    pub rows: f64,
}

impl EvalOut {
    pub fn accuracy(&self) -> f64 {
        if self.rows == 0.0 {
            0.0
        } else {
            self.correct / self.rows
        }
    }

    pub fn mean_loss(&self) -> f64 {
        if self.rows == 0.0 {
            0.0
        } else {
            self.loss_sum / self.rows
        }
    }

    pub fn perplexity(&self) -> f64 {
        self.mean_loss().exp()
    }

    pub fn merge(&mut self, other: &EvalOut) {
        self.correct += other.correct;
        self.loss_sum += other.loss_sum;
        self.rows += other.rows;
    }
}

/// Shared, thread-safe compute substrate. The server holds one engine per
/// experiment and spawns one [`TrainSession`] per worker when executing a
/// round in parallel.
pub trait Engine: Send + Sync {
    fn manifest(&self) -> &Manifest;

    /// Spawn an independent execution session borrowing this engine's
    /// shared state. Cheap: sessions lazily acquire executable handles /
    /// scratch buffers on first use.
    fn session(&self) -> Box<dyn TrainSession + '_>;

    /// Whether concurrent sessions are validated for this engine. The
    /// server's executor falls back to sequential when false, regardless
    /// of its thread setting — correctness beats wall-clock.
    fn parallel_sessions(&self) -> bool {
        true
    }
}

/// One client-execution stream: owns every piece of mutable compute state
/// so concurrent sessions never contend. Outputs must be a pure function
/// of the arguments (the parallel-determinism invariant).
pub trait TrainSession: Send {
    /// One masked SGD step through the early-exit-`exit` artifact
    /// (`exit` in 1..=num_blocks).
    fn train_step(
        &mut self,
        exit: usize,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        mask: &[f32],
        lr: f32,
    ) -> anyhow::Result<TrainOut>;

    /// Full-model eval over one batch.
    fn eval_step(&mut self, params: &[f32], x: &[f32], y: &[i32]) -> anyhow::Result<EvalOut>;
}

/// Validate raw buffer lengths against the manifest (shared by engines).
pub(crate) fn check_shapes(
    m: &Manifest,
    exit: usize,
    params: &[f32],
    x: &[f32],
    y: &[i32],
    mask: &[f32],
) -> anyhow::Result<()> {
    anyhow::ensure!(
        (1..=m.num_blocks).contains(&exit),
        "exit {exit} out of range 1..={}",
        m.num_blocks
    );
    anyhow::ensure!(params.len() == m.param_count, "params len");
    anyhow::ensure!(mask.len() == m.param_count, "mask len");
    let x_len: usize = m.batch * m.input_shape.iter().product::<usize>();
    anyhow::ensure!(x.len() == x_len, "x len {} != {}", x.len(), x_len);
    anyhow::ensure!(y.len() == m.label_len, "y len");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::tests_support::toy_manifest;

    #[test]
    fn eval_out_accumulates() {
        let mut a = EvalOut { correct: 3.0, loss_sum: 10.0, rows: 10.0 };
        a.merge(&EvalOut { correct: 2.0, loss_sum: 5.0, rows: 10.0 });
        assert_eq!(a.accuracy(), 0.25);
        assert_eq!(a.mean_loss(), 0.75);
    }

    #[test]
    fn perplexity_is_exp_mean_loss() {
        let e = EvalOut { correct: 0.0, loss_sum: 20.0, rows: 10.0 };
        assert!((e.perplexity() - (2.0f64).exp()).abs() < 1e-12);
    }

    #[test]
    fn empty_eval_is_zero() {
        let e = EvalOut::default();
        assert_eq!(e.accuracy(), 0.0);
        assert_eq!(e.mean_loss(), 0.0);
    }

    #[test]
    fn concurrent_sessions_agree_bitwise() {
        // Two sessions spawned from one shared engine reference must give
        // identical outputs for identical inputs — the invariant the
        // parallel round executor is built on.
        let e = MockEngine::new(toy_manifest(), 1);
        let engine: &dyn Engine = &e;
        let m = engine.manifest().clone();
        let x = vec![0.5f32; m.batch * m.input_shape.iter().product::<usize>()];
        let y = vec![0i32; m.label_len];
        let p = vec![0.1f32; m.param_count];
        let mask = vec![1.0f32; m.param_count];
        let mut s1 = engine.session();
        let mut s2 = engine.session();
        let a = s1.train_step(m.num_blocks, &p, &x, &y, &mask, 0.2).unwrap();
        // s2 first runs an unrelated step: session history must not leak.
        s2.train_step(1, &p, &x, &y, &mask, 0.9).unwrap();
        let b = s2.train_step(m.num_blocks, &p, &x, &y, &mask, 0.2).unwrap();
        assert_eq!(a.new_params, b.new_params);
        assert_eq!(a.loss, b.loss);
        assert_eq!(a.sq_grads, b.sq_grads);
    }

    #[test]
    fn sessions_are_send() {
        fn assert_send<T: Send>(_: T) {}
        let e = MockEngine::new(toy_manifest(), 1);
        assert_send(e.session());
    }
}
