//! Runtime: load + execute the AOT artifacts from the L3 hot path.
//!
//! `Engine` is the narrow waist between the FL coordinator and the
//! compute substrate. `PjrtEngine` (pjrt.rs) is the production engine:
//! it loads HLO text through the `xla` crate, compiles one executable per
//! early-exit lazily on the PJRT CPU client, and keeps them cached.
//! `MockEngine` (mock.rs) is a closed-form pure-rust engine with the same
//! interface, backing the engine-independent unit/property tests.

pub mod mock;
pub mod pjrt;

pub use mock::MockEngine;
pub use pjrt::PjrtEngine;

use crate::manifest::Manifest;

/// Output of one local SGD step through a train_exit_<e> artifact.
#[derive(Clone, Debug)]
pub struct TrainOut {
    pub new_params: Vec<f32>,
    pub loss: f32,
    /// Per-tensor sum of squared gradients [K] — the raw material for
    /// ElasticTrainer tensor importance (importance = lr * sq_grads).
    pub sq_grads: Vec<f64>,
}

/// Output of the eval artifact over one batch.
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalOut {
    /// Correct predictions (classification) / correct next tokens (LM).
    pub correct: f64,
    /// Summed cross-entropy over rows.
    pub loss_sum: f64,
    /// Rows evaluated.
    pub rows: f64,
}

impl EvalOut {
    pub fn accuracy(&self) -> f64 {
        if self.rows == 0.0 {
            0.0
        } else {
            self.correct / self.rows
        }
    }

    pub fn mean_loss(&self) -> f64 {
        if self.rows == 0.0 {
            0.0
        } else {
            self.loss_sum / self.rows
        }
    }

    pub fn perplexity(&self) -> f64 {
        self.mean_loss().exp()
    }

    pub fn merge(&mut self, other: &EvalOut) {
        self.correct += other.correct;
        self.loss_sum += other.loss_sum;
        self.rows += other.rows;
    }
}

/// The compute interface the coordinator drives. One SGD step at a time:
/// the *schedule* (which exit, which mask, how many steps) is entirely the
/// coordinator's business — exactly the paper's split between system
/// policy (L3) and compute (L1/L2).
pub trait Engine {
    fn manifest(&self) -> &Manifest;

    /// One masked SGD step through the early-exit-`exit` artifact
    /// (`exit` in 1..=num_blocks).
    fn train_step(
        &mut self,
        exit: usize,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        mask: &[f32],
        lr: f32,
    ) -> anyhow::Result<TrainOut>;

    /// Full-model eval over one batch.
    fn eval_step(&mut self, params: &[f32], x: &[f32], y: &[i32]) -> anyhow::Result<EvalOut>;
}

/// Validate raw buffer lengths against the manifest (shared by engines).
pub(crate) fn check_shapes(
    m: &Manifest,
    exit: usize,
    params: &[f32],
    x: &[f32],
    y: &[i32],
    mask: &[f32],
) -> anyhow::Result<()> {
    anyhow::ensure!(
        (1..=m.num_blocks).contains(&exit),
        "exit {exit} out of range 1..={}",
        m.num_blocks
    );
    anyhow::ensure!(params.len() == m.param_count, "params len");
    anyhow::ensure!(mask.len() == m.param_count, "mask len");
    let x_len: usize = m.batch * m.input_shape.iter().product::<usize>();
    anyhow::ensure!(x.len() == x_len, "x len {} != {}", x.len(), x_len);
    anyhow::ensure!(y.len() == m.label_len, "y len");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_out_accumulates() {
        let mut a = EvalOut { correct: 3.0, loss_sum: 10.0, rows: 10.0 };
        a.merge(&EvalOut { correct: 2.0, loss_sum: 5.0, rows: 10.0 });
        assert_eq!(a.accuracy(), 0.25);
        assert_eq!(a.mean_loss(), 0.75);
    }

    #[test]
    fn perplexity_is_exp_mean_loss() {
        let e = EvalOut { correct: 0.0, loss_sum: 20.0, rows: 10.0 };
        assert!((e.perplexity() - (2.0f64).exp()).abs() < 1e-12);
    }

    #[test]
    fn empty_eval_is_zero() {
        let e = EvalOut::default();
        assert_eq!(e.accuracy(), 0.0);
        assert_eq!(e.mean_loss(), 0.0);
    }
}
