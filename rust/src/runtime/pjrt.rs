//! PJRT engine: the production compute path.
//!
//! Loads `artifacts/<model>/train_exit_<e>.hlo.txt` (HLO *text* — the only
//! interchange format xla_extension 0.5.1 accepts from jax >= 0.5, see
//! DESIGN.md §2) and compiles on the PJRT CPU client. Executables are
//! compiled lazily per exit and cached for the lifetime of the engine, so
//! a fleet that never uses exit 7 never pays its compile time.

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use crate::manifest::Manifest;

use super::{check_shapes, Engine, EvalOut, TrainOut};

pub struct PjrtEngine {
    client: xla::PjRtClient,
    manifest: Manifest,
    train_exes: HashMap<usize, xla::PjRtLoadedExecutable>,
    eval_exe: Option<xla::PjRtLoadedExecutable>,
    /// (exit -> cumulative executions), for the perf report.
    pub exec_counts: HashMap<usize, u64>,
    pub compile_secs: f64,
}

impl PjrtEngine {
    /// Open the artifacts directory of one model, e.g.
    /// `artifacts/vgg_cifar`.
    pub fn open(model_dir: &Path) -> anyhow::Result<Self> {
        let manifest = Manifest::load(model_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu: {e:?}"))?;
        Ok(PjrtEngine {
            client,
            manifest,
            train_exes: HashMap::new(),
            eval_exe: None,
            exec_counts: HashMap::new(),
            compile_secs: 0.0,
        })
    }

    fn compile(&mut self, path: &Path) -> anyhow::Result<xla::PjRtLoadedExecutable> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow::anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {path:?}: {e:?}"))?;
        self.compile_secs += t0.elapsed().as_secs_f64();
        Ok(exe)
    }

    fn ensure_train(&mut self, exit: usize) -> anyhow::Result<()> {
        if !self.train_exes.contains_key(&exit) {
            let path = self.manifest.train_hlo_path(exit);
            let exe = self.compile(&path)?;
            self.train_exes.insert(exit, exe);
        }
        Ok(())
    }

    fn ensure_eval(&mut self) -> anyhow::Result<()> {
        if self.eval_exe.is_none() {
            let path = self.manifest.eval_hlo_path();
            self.eval_exe = Some(self.compile(&path)?);
        }
        Ok(())
    }

    /// Pre-compile a set of exits (and eval) up front, e.g. before timing.
    pub fn warm(&mut self, exits: &[usize]) -> anyhow::Result<()> {
        for &e in exits {
            self.ensure_train(e)?;
        }
        self.ensure_eval()
    }

    fn lit_f32(data: &[f32], dims: &[i64]) -> anyhow::Result<xla::Literal> {
        let v = xla::Literal::vec1(data);
        if dims.len() == 1 {
            return Ok(v);
        }
        v.reshape(dims).map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
    }
}

impl Engine for PjrtEngine {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn train_step(
        &mut self,
        exit: usize,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        mask: &[f32],
        lr: f32,
    ) -> anyhow::Result<TrainOut> {
        check_shapes(&self.manifest, exit, params, x, y, mask)?;
        self.ensure_train(exit)?;
        *self.exec_counts.entry(exit).or_insert(0) += 1;

        let mut x_dims: Vec<i64> = vec![self.manifest.batch as i64];
        x_dims.extend(self.manifest.input_shape.iter().map(|&d| d as i64));

        let p_lit = Self::lit_f32(params, &[params.len() as i64])?;
        let x_lit = Self::lit_f32(x, &x_dims)?;
        let y_lit = xla::Literal::vec1(y);
        let m_lit = Self::lit_f32(mask, &[mask.len() as i64])?;
        let lr_lit = xla::Literal::scalar(lr);

        let exe = self.train_exes.get(&exit).unwrap();
        let bufs = exe
            .execute::<xla::Literal>(&[p_lit, x_lit, y_lit, m_lit, lr_lit])
            .map_err(|e| anyhow::anyhow!("execute train_exit_{exit}: {e:?}"))?;
        let result = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
        let (p_out, loss_out, sq_out) = result
            .to_tuple3()
            .map_err(|e| anyhow::anyhow!("tuple3: {e:?}"))?;
        let new_params: Vec<f32> =
            p_out.to_vec().map_err(|e| anyhow::anyhow!("params out: {e:?}"))?;
        let loss: f32 = loss_out
            .get_first_element()
            .map_err(|e| anyhow::anyhow!("loss out: {e:?}"))?;
        let sq: Vec<f32> = sq_out.to_vec().map_err(|e| anyhow::anyhow!("sq out: {e:?}"))?;
        Ok(TrainOut {
            new_params,
            loss,
            sq_grads: sq.iter().map(|&v| v as f64).collect(),
        })
    }

    fn eval_step(&mut self, params: &[f32], x: &[f32], y: &[i32]) -> anyhow::Result<EvalOut> {
        let m = &self.manifest;
        anyhow::ensure!(params.len() == m.param_count, "params len");
        anyhow::ensure!(y.len() == m.label_len, "y len");
        self.ensure_eval()?;

        let mut x_dims: Vec<i64> = vec![self.manifest.batch as i64];
        x_dims.extend(self.manifest.input_shape.iter().map(|&d| d as i64));
        let p_lit = Self::lit_f32(params, &[params.len() as i64])?;
        let x_lit = Self::lit_f32(x, &x_dims)?;
        let y_lit = xla::Literal::vec1(y);

        let exe = self.eval_exe.as_ref().unwrap();
        let bufs = exe
            .execute::<xla::Literal>(&[p_lit, x_lit, y_lit])
            .map_err(|e| anyhow::anyhow!("execute eval: {e:?}"))?;
        let result = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
        let (c_out, l_out) = result
            .to_tuple2()
            .map_err(|e| anyhow::anyhow!("tuple2: {e:?}"))?;
        let correct: f32 = c_out.get_first_element().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let loss_sum: f32 = l_out.get_first_element().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        Ok(EvalOut {
            correct: correct as f64,
            loss_sum: loss_sum as f64,
            rows: self.manifest.label_len as f64,
        })
    }
}
