//! PJRT engine: the production compute path (behind the `pjrt` feature).
//!
//! Loads `artifacts/<model>/train_exit_<e>.hlo.txt` (HLO *text* — the only
//! interchange format xla_extension 0.5.1 accepts from jax >= 0.5, see
//! DESIGN.md §2) and compiles on the PJRT CPU client. Executables are
//! compiled lazily per exit, cached for the lifetime of the engine behind
//! a mutex, and handed to sessions as `Arc` handles: a session holds its
//! own handle map, and the engine lock is never held across an execution
//! *or a compile* (double-checked locking), so a cache miss on one exit
//! never stalls sessions running other exits. A fleet
//! that never uses exit 7 never pays its compile time; N parallel
//! sessions executing the same exit share one compiled artifact.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::manifest::Manifest;

use super::{check_shapes, Engine, EvalOut, TrainOut, TrainSession};

/// Lazily-built shared state: the compile cache plus perf counters.
struct PjrtShared {
    train_exes: HashMap<usize, Arc<xla::PjRtLoadedExecutable>>,
    eval_exe: Option<Arc<xla::PjRtLoadedExecutable>>,
    /// (exit -> cumulative executions), for the perf report.
    exec_counts: HashMap<usize, u64>,
    compile_secs: f64,
}

pub struct PjrtEngine {
    client: xla::PjRtClient,
    manifest: Manifest,
    shared: Mutex<PjrtShared>,
}

// SAFETY: the PJRT C API requires clients and loaded executables to be
// thread-safe (concurrent Execute calls on one executable are the norm on
// the CPU plugin); the `xla` crate simply never declares it. All
// lazily-mutated rust-side state lives behind `shared`'s Mutex.
//
// RESIDUAL RISK: the xla crate's own wrapper internals have not been
// validated for concurrent use against a real xla_extension build, which
// is why `parallel_sessions()` below keeps the server's fan-out
// sequential. These impls still hand out Send sessions (the TrainSession
// contract requires it), so code driving sessions concurrently outside
// the server executor runs ahead of that validation — see the ROADMAP
// follow-up before flipping the gate or doing so.
unsafe impl Send for PjrtEngine {}
unsafe impl Sync for PjrtEngine {}

impl PjrtEngine {
    /// Open the artifacts directory of one model, e.g.
    /// `artifacts/vgg_cifar`.
    pub fn open(model_dir: &Path) -> anyhow::Result<Self> {
        let manifest = Manifest::load(model_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu: {e:?}"))?;
        Ok(PjrtEngine {
            client,
            manifest,
            shared: Mutex::new(PjrtShared {
                train_exes: HashMap::new(),
                eval_exe: None,
                exec_counts: HashMap::new(),
                compile_secs: 0.0,
            }),
        })
    }

    fn compile(&self, path: &Path) -> anyhow::Result<(Arc<xla::PjRtLoadedExecutable>, f64)> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow::anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {path:?}: {e:?}"))?;
        Ok((Arc::new(exe), t0.elapsed().as_secs_f64()))
    }

    /// Get-or-compile the train executable for `exit` (no exec counting —
    /// shared by `warm` and the counting fetch path). Compilation happens
    /// OUTSIDE the lock so concurrent sessions executing cached exits (or
    /// compiling other exits) never stall behind a multi-second compile;
    /// two sessions racing on the same uncached exit may both compile, but
    /// only the first insert wins and all sessions share that artifact.
    fn ensure_train(&self, exit: usize) -> anyhow::Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.shared.lock().unwrap().train_exes.get(&exit) {
            return Ok(exe.clone());
        }
        let (exe, secs) = self.compile(&self.manifest.train_hlo_path(exit))?;
        let mut sh = self.shared.lock().unwrap();
        sh.compile_secs += secs;
        Ok(sh.train_exes.entry(exit).or_insert(exe).clone())
    }

    fn eval_exe(&self) -> anyhow::Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = &self.shared.lock().unwrap().eval_exe {
            return Ok(exe.clone());
        }
        // Same double-checked pattern as ensure_train: compile unlocked.
        let (exe, secs) = self.compile(&self.manifest.eval_hlo_path())?;
        let mut sh = self.shared.lock().unwrap();
        sh.compile_secs += secs;
        Ok(sh.eval_exe.get_or_insert(exe).clone())
    }

    /// Pre-compile a set of exits (and eval) up front, e.g. before timing.
    pub fn warm(&self, exits: &[usize]) -> anyhow::Result<()> {
        for &e in exits {
            self.ensure_train(e)?;
        }
        self.eval_exe().map(|_| ())
    }

    /// Snapshot of (exit -> cumulative executions), for the perf report.
    /// Sessions count locally and merge on drop (the hot path never locks
    /// for counting), so live sessions' steps appear only once dropped.
    pub fn exec_counts(&self) -> HashMap<usize, u64> {
        self.shared.lock().unwrap().exec_counts.clone()
    }

    /// Cumulative lazy-compilation wall time in seconds.
    pub fn compile_secs(&self) -> f64 {
        self.shared.lock().unwrap().compile_secs
    }

    fn lit_f32(data: &[f32], dims: &[i64]) -> anyhow::Result<xla::Literal> {
        let v = xla::Literal::vec1(data);
        if dims.len() == 1 {
            return Ok(v);
        }
        v.reshape(dims).map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
    }
}

impl Engine for PjrtEngine {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn session(&self) -> Box<dyn TrainSession + '_> {
        Box::new(PjrtSession {
            engine: self,
            train_exes: HashMap::new(),
            eval_exe: None,
            local_counts: HashMap::new(),
        })
    }

    /// Concurrent execution rests on the PJRT plugin contract, but the
    /// `xla` crate's own wrapper state has not been validated against a
    /// real xla_extension build (ROADMAP follow-up) — keep PJRT rounds
    /// sequential until it has.
    fn parallel_sessions(&self) -> bool {
        false
    }
}

/// One PJRT execution stream: owns per-session executable handles and a
/// local execution counter, so the engine's cache lock is only taken on
/// the first use of each exit (and once more when the session drops, to
/// merge its counts).
pub struct PjrtSession<'a> {
    engine: &'a PjrtEngine,
    train_exes: HashMap<usize, Arc<xla::PjRtLoadedExecutable>>,
    eval_exe: Option<Arc<xla::PjRtLoadedExecutable>>,
    /// (exit -> executions by this session), merged into the engine on drop.
    local_counts: HashMap<usize, u64>,
}

// SAFETY: see `PjrtEngine` — loaded executables are thread-safe by PJRT
// contract; the session merely moves `Arc` handles between threads.
unsafe impl Send for PjrtSession<'_> {}

impl PjrtSession<'_> {
    fn train_handle(&mut self, exit: usize) -> anyhow::Result<Arc<xla::PjRtLoadedExecutable>> {
        let exe = match self.train_exes.get(&exit) {
            Some(exe) => exe.clone(),
            None => {
                let exe = self.engine.ensure_train(exit)?;
                self.train_exes.insert(exit, exe.clone());
                exe
            }
        };
        *self.local_counts.entry(exit).or_insert(0) += 1;
        Ok(exe)
    }
}

impl Drop for PjrtSession<'_> {
    fn drop(&mut self) {
        if self.local_counts.is_empty() {
            return;
        }
        let mut sh = self.engine.shared.lock().unwrap();
        for (exit, n) in self.local_counts.drain() {
            *sh.exec_counts.entry(exit).or_insert(0) += n;
        }
    }
}

impl TrainSession for PjrtSession<'_> {
    fn train_step(
        &mut self,
        exit: usize,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        mask: &[f32],
        lr: f32,
    ) -> anyhow::Result<TrainOut> {
        let m = &self.engine.manifest;
        check_shapes(m, exit, params, x, y, mask)?;
        let exe = self.train_handle(exit)?;

        let mut x_dims: Vec<i64> = vec![m.batch as i64];
        x_dims.extend(m.input_shape.iter().map(|&d| d as i64));

        let p_lit = PjrtEngine::lit_f32(params, &[params.len() as i64])?;
        let x_lit = PjrtEngine::lit_f32(x, &x_dims)?;
        let y_lit = xla::Literal::vec1(y);
        let m_lit = PjrtEngine::lit_f32(mask, &[mask.len() as i64])?;
        let lr_lit = xla::Literal::scalar(lr);

        let bufs = exe
            .execute::<xla::Literal>(&[p_lit, x_lit, y_lit, m_lit, lr_lit])
            .map_err(|e| anyhow::anyhow!("execute train_exit_{exit}: {e:?}"))?;
        let result = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
        let (p_out, loss_out, sq_out) = result
            .to_tuple3()
            .map_err(|e| anyhow::anyhow!("tuple3: {e:?}"))?;
        let new_params: Vec<f32> =
            p_out.to_vec().map_err(|e| anyhow::anyhow!("params out: {e:?}"))?;
        let loss: f32 = loss_out
            .get_first_element()
            .map_err(|e| anyhow::anyhow!("loss out: {e:?}"))?;
        let sq: Vec<f32> = sq_out.to_vec().map_err(|e| anyhow::anyhow!("sq out: {e:?}"))?;
        Ok(TrainOut {
            new_params,
            loss,
            sq_grads: sq.iter().map(|&v| v as f64).collect(),
        })
    }

    fn eval_step(&mut self, params: &[f32], x: &[f32], y: &[i32]) -> anyhow::Result<EvalOut> {
        let m = &self.engine.manifest;
        anyhow::ensure!(params.len() == m.param_count, "params len");
        anyhow::ensure!(y.len() == m.label_len, "y len");
        let exe = match &self.eval_exe {
            Some(exe) => exe.clone(),
            None => {
                let exe = self.engine.eval_exe()?;
                self.eval_exe = Some(exe.clone());
                exe
            }
        };

        let mut x_dims: Vec<i64> = vec![m.batch as i64];
        x_dims.extend(m.input_shape.iter().map(|&d| d as i64));
        let p_lit = PjrtEngine::lit_f32(params, &[params.len() as i64])?;
        let x_lit = PjrtEngine::lit_f32(x, &x_dims)?;
        let y_lit = xla::Literal::vec1(y);

        let bufs = exe
            .execute::<xla::Literal>(&[p_lit, x_lit, y_lit])
            .map_err(|e| anyhow::anyhow!("execute eval: {e:?}"))?;
        let result = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
        let (c_out, l_out) = result
            .to_tuple2()
            .map_err(|e| anyhow::anyhow!("tuple2: {e:?}"))?;
        let correct: f32 = c_out.get_first_element().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let loss_sum: f32 = l_out.get_first_element().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        Ok(EvalOut {
            correct: correct as f64,
            loss_sum: loss_sum as f64,
            rows: m.label_len as f64,
        })
    }
}
