//! Experiment configuration: one struct drives every table, figure,
//! example, and the CLI. JSON round-trips for provenance (every result
//! dump embeds the config that produced it).
//!
//! The [`params`] submodule is the typed key registry over this struct
//! (`train.lr`, `data.alpha`, `strategy.fedel.harmonize_weight`, ...):
//! anything registered there is settable via `--set key=value` and
//! sweepable via `campaign run --sweep key=v1,v2`.

pub mod params;

use std::path::PathBuf;

use crate::util::cli::Args;
use crate::util::json::Json;

/// Which fleet to simulate.
#[derive(Clone, Debug, PartialEq)]
pub enum FleetSpec {
    /// The paper's small-scale testbed: 5 Jetson Xavier + 5 Jetson Orin.
    Small10,
    /// The paper's large-scale simulation: n clients over device types
    /// {1, 1/2, 1/3, 1/4}x the base profile.
    Large(usize),
    /// Explicit per-client scales.
    Scales(Vec<f64>),
    /// A lazily-materialized generated fleet: `lazyN[:generator]` where
    /// the generator is `uniform` (default), `cat:w1,w2,...`, or
    /// `lognormal:mu:sigma` (see [`crate::fleet::GeneratorSpec`]). Client
    /// profiles are derived on demand from (seed, generator), so the
    /// fleet never allocates O(n) state.
    Lazy { n: usize, generator: crate::fleet::GeneratorSpec },
}

impl FleetSpec {
    pub fn parse(s: &str) -> anyhow::Result<FleetSpec> {
        match s {
            "small10" => Ok(FleetSpec::Small10),
            _ if s.starts_with("lazy") => {
                let rest = &s["lazy".len()..];
                let (n_str, gen_str) = match rest.split_once(':') {
                    Some((n, g)) => (n, Some(g)),
                    None => (rest, None),
                };
                let n: usize = n_str
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad lazy fleet size in {s:?} (lazyN[:generator])"))?;
                anyhow::ensure!(n > 0, "lazy fleet must have at least one client: {s:?}");
                let generator = match gen_str {
                    Some(g) => crate::fleet::GeneratorSpec::parse(g)?,
                    None => crate::fleet::GeneratorSpec::Uniform,
                };
                Ok(FleetSpec::Lazy { n, generator })
            }
            _ if s.starts_with("large") => {
                let n: usize = s["large".len()..].parse().unwrap_or(100);
                Ok(FleetSpec::Large(n))
            }
            _ if s.contains(',') || s.parse::<f64>().is_ok() => {
                let scales: Vec<f64> = s
                    .split(',')
                    .filter(|p| !p.is_empty())
                    .map(|p| p.parse().map_err(|e| anyhow::anyhow!("bad scale {p:?}: {e}")))
                    .collect::<anyhow::Result<_>>()?;
                Ok(FleetSpec::Scales(scales))
            }
            other => anyhow::bail!(
                "unknown fleet {other:?} (small10 | largeN | s1,s2,... | lazyN[:generator])"
            ),
        }
    }

    pub fn label(&self) -> String {
        match self {
            FleetSpec::Small10 => "small10".into(),
            FleetSpec::Large(n) => format!("large{n}"),
            FleetSpec::Scales(v) => v
                .iter()
                .map(|s| format!("{s}"))
                .collect::<Vec<_>>()
                .join(","),
            FleetSpec::Lazy { n, generator } => match generator {
                crate::fleet::GeneratorSpec::Uniform => format!("lazy{n}"),
                g => format!("lazy{n}:{}", g.label()),
            },
        }
    }
}

#[derive(Clone, Debug)]
pub struct ExperimentCfg {
    /// Zoo model name, or "mock:<blocks>x<body>" for the pure-rust engine.
    pub model: String,
    pub artifacts_dir: PathBuf,
    pub strategy: String,
    pub fleet: FleetSpec,
    pub rounds: usize,
    pub local_steps: usize,
    pub lr: f64,
    /// Dirichlet non-iid concentration (paper: 0.1).
    pub alpha: f64,
    /// T_th = t_th_factor x (fastest device's full-model round time).
    pub t_th_factor: f64,
    /// Calibrate the SLOWEST device's full round to this many simulated
    /// seconds (paper Table 2: 71.8 min for CIFAR10). 0 = no calibration.
    pub slowest_round_secs: f64,
    pub seed: u64,
    pub eval_every: usize,
    pub eval_batches: usize,
    /// Flat per-round communication seconds — the degenerate
    /// [`CommModel`](crate::timing::CommModel), in effect whenever no
    /// bandwidth key below is set.
    pub comm_secs: f64,
    /// Client upload bandwidth (Mbit/s). Setting any of the three
    /// bandwidth keys switches to the payload-priced CommModel, where
    /// per-client transfer time = masked-payload bytes / bandwidth +
    /// latency. 0 = unset.
    pub comm_up_mbps: f64,
    /// Client download bandwidth (Mbit/s); 0 = unset.
    pub comm_down_mbps: f64,
    /// Per-transfer link latency (seconds); 0 = unset.
    pub comm_latency_secs: f64,
    /// Host threads for the per-round client fan-out: 0 = one per core,
    /// 1 = sequential, n = dedicated n-thread pool. Purely a wall-clock
    /// knob — results are bitwise-identical at any setting.
    pub exec_threads: usize,
    /// Async speculation lookahead (`exec.speculate.depth`): how many
    /// future dispatches the event runner pre-executes against predicted
    /// global versions while earlier uploads are in flight. Like
    /// `exec_threads`, purely a wall-clock knob — every speculation is
    /// validated at arrival, so results are bitwise-identical at any
    /// depth. 0 = off.
    pub exec_speculate_depth: usize,
    /// Strategy-declared tunables, keyed by their full registry key
    /// (`strategy.<strategy>.<param>` -> value), kept sorted for stable
    /// serialization. Populated via `--set`/`--sweep`; anything unset
    /// falls back to the declaration's default
    /// ([`crate::strategies::registry`]).
    pub strategy_params: Vec<(String, f64)>,
    /// JSONL fleet trace path (`fleet.trace`); when set it overrides
    /// `fleet`. Empty = unset.
    pub fleet_trace: String,
    /// Parsed trace profiles, inlined into the config snapshot the first
    /// time the experiment is built — resume and campaign replays never
    /// re-read (or require) the trace file.
    pub fleet_profiles: Vec<crate::fleet::ClientProfile>,
    /// Async in-flight cap (`fleet.sample`): at most this many clients
    /// hold dispatches (and parameter state) at once; fresh clients are
    /// drawn deterministically as uploads land. 0 = every client in
    /// flight (the legacy full fan-out). Required for lazy fleets.
    pub fleet_sample: usize,
    /// Mid-round dropout probability (`fleet.churn.dropout`), [0, 1):
    /// each finished update is discarded with this probability.
    pub churn_dropout: f64,
    /// Availability cycle length in sim seconds (`fleet.churn.period_secs`);
    /// 0 = clients are always online.
    pub churn_period_secs: f64,
    /// Fraction of each availability cycle a client is online
    /// (`fleet.churn.avail_frac`), (0, 1].
    pub churn_avail_frac: f64,
    /// Successive-halving rung count (`operator.halving.rungs`): the
    /// campaign operator ranks cells at this many evenly-spaced
    /// checkpoint-aligned round boundaries and prunes the losers.
    /// 0 = halving off (every cell runs to completion).
    pub halving_rungs: usize,
    /// Fraction of live cells each rung keeps
    /// (`operator.halving.keep_frac`), (0, 1].
    pub halving_keep_frac: f64,
    /// Metric rungs rank by (`operator.halving.metric`): "acc" (higher
    /// wins) or "loss" (lower wins).
    pub halving_metric: String,
    pub record_selections: bool,
    pub verbose: bool,
    /// Abort after this many rounds (simulated kill, for fault-tolerance
    /// demos/tests — see `ServerCfg::halt_after`). Not part of the stored
    /// config snapshot: a resumed run always runs to completion.
    pub halt_after: Option<usize>,
}

impl Default for ExperimentCfg {
    fn default() -> Self {
        ExperimentCfg {
            model: "mlp".into(),
            artifacts_dir: PathBuf::from("artifacts"),
            strategy: "fedel".into(),
            fleet: FleetSpec::Small10,
            rounds: 60,
            local_steps: 8,
            lr: 0.05,
            alpha: 0.1,
            t_th_factor: 1.0,
            slowest_round_secs: 71.8 * 60.0,
            seed: 42,
            eval_every: 5,
            eval_batches: 16,
            comm_secs: 30.0,
            comm_up_mbps: 0.0,
            comm_down_mbps: 0.0,
            comm_latency_secs: 0.0,
            exec_threads: 0,
            exec_speculate_depth: 0,
            strategy_params: Vec::new(),
            fleet_trace: String::new(),
            fleet_profiles: Vec::new(),
            fleet_sample: 0,
            churn_dropout: 0.0,
            churn_period_secs: 0.0,
            churn_avail_frac: 1.0,
            halving_rungs: 0,
            halving_keep_frac: 0.5,
            halving_metric: "acc".into(),
            record_selections: false,
            verbose: false,
            halt_after: None,
        }
    }
}

impl ExperimentCfg {
    /// Merge CLI args over defaults. Repeated `--set key=value` bindings
    /// apply last (the CLI layer of the overlay precedence base < axis <
    /// `--set`), so they win over the per-field flags.
    pub fn from_args(args: &Args) -> anyhow::Result<ExperimentCfg> {
        let d = ExperimentCfg::default();
        let mut cfg = ExperimentCfg {
            model: args.str_or("model", &d.model),
            artifacts_dir: PathBuf::from(args.str_or("artifacts", "artifacts")),
            strategy: args.str_or("strategy", &d.strategy),
            fleet: FleetSpec::parse(&args.str_or("fleet", "small10"))?,
            rounds: args.usize_or("rounds", d.rounds),
            local_steps: args.usize_or("local-steps", d.local_steps),
            lr: args.f64_or("lr", d.lr),
            alpha: args.f64_or("alpha", d.alpha),
            t_th_factor: args.f64_or("t-th-factor", d.t_th_factor),
            slowest_round_secs: args.f64_or("slowest-round-secs", d.slowest_round_secs),
            seed: args.u64_or("seed", d.seed),
            eval_every: args.usize_or("eval-every", d.eval_every),
            eval_batches: args.usize_or("eval-batches", d.eval_batches),
            comm_secs: args.f64_or("comm-secs", d.comm_secs),
            comm_up_mbps: args.f64_or("comm-up-mbps", d.comm_up_mbps),
            comm_down_mbps: args.f64_or("comm-down-mbps", d.comm_down_mbps),
            comm_latency_secs: args.f64_or("comm-latency-secs", d.comm_latency_secs),
            exec_threads: args.usize_or("threads", d.exec_threads),
            exec_speculate_depth: args.usize_or("speculate-depth", d.exec_speculate_depth),
            strategy_params: Vec::new(),
            fleet_trace: args.str_or("fleet-trace", &d.fleet_trace),
            fleet_profiles: Vec::new(),
            fleet_sample: args.usize_or("fleet-sample", d.fleet_sample),
            churn_dropout: d.churn_dropout,
            churn_period_secs: d.churn_period_secs,
            churn_avail_frac: d.churn_avail_frac,
            halving_rungs: d.halving_rungs,
            halving_keep_frac: d.halving_keep_frac,
            halving_metric: d.halving_metric.clone(),
            record_selections: args.flag("record-selections"),
            verbose: args.flag("verbose"),
            halt_after: args.get("halt-after").and_then(|s| s.parse().ok()),
        };
        // `--beta` is a deprecated alias for the FedEL family's
        // harmonize_weight tunables: fold it into the parameter bag (the
        // one path strategy tunables flow through since the legacy field
        // was removed). Applied before --set so explicit bindings win.
        if let Some(raw) = args.get("beta") {
            let beta: f64 = raw
                .parse()
                .map_err(|e| anyhow::anyhow!("--beta value {raw:?}: {e}"))?;
            eprintln!(
                "note: --beta is deprecated — use --set strategy.<s>.harmonize_weight={beta}"
            );
            fold_beta_into_bag(&mut cfg.strategy_params, beta);
        }
        let sets = args.all("set");
        if !sets.is_empty() {
            let space = params::ParamSpace::shared();
            params::SpecOverlay::parse(space, &sets)?.apply(space, &mut cfg)?;
        }
        Ok(cfg)
    }

    /// The communication model this config asks for: payload-priced
    /// bandwidth when any `comm.*_mbps` / `comm.latency_secs` key is set,
    /// else the flat `time.comm_secs` constant.
    pub fn comm_model(&self) -> crate::timing::CommModel {
        if self.comm_up_mbps > 0.0 || self.comm_down_mbps > 0.0 || self.comm_latency_secs > 0.0 {
            crate::timing::CommModel::Bandwidth {
                up_mbps: self.comm_up_mbps,
                down_mbps: self.comm_down_mbps,
                latency_secs: self.comm_latency_secs,
            }
        } else {
            crate::timing::CommModel::Constant(self.comm_secs)
        }
    }

    /// Config snapshot: every field an experiment rebuild needs
    /// (`from_json` inverts it). Presentation flags (verbose,
    /// record_selections) and the halt_after kill-switch stay out — they
    /// describe a process invocation, not the experiment.
    pub fn to_json(&self) -> Json {
        let mut kv = vec![
            ("model", Json::Str(self.model.clone())),
            ("artifacts_dir", Json::Str(self.artifacts_dir.display().to_string())),
            ("strategy", Json::Str(self.strategy.clone())),
            ("fleet", Json::Str(self.fleet.label())),
            ("rounds", Json::Num(self.rounds as f64)),
            ("local_steps", Json::Num(self.local_steps as f64)),
            ("lr", Json::Num(self.lr)),
            ("alpha", Json::Num(self.alpha)),
            ("t_th_factor", Json::Num(self.t_th_factor)),
            ("slowest_round_secs", Json::Num(self.slowest_round_secs)),
            // u64 seeds don't survive the f64 JSON number path above 2^53;
            // like the store's RNG words, they ride a string.
            ("seed", Json::Str(format!("{}", self.seed))),
            ("eval_every", Json::Num(self.eval_every as f64)),
            ("eval_batches", Json::Num(self.eval_batches as f64)),
            ("comm_secs", Json::Num(self.comm_secs)),
            ("threads", Json::Num(self.exec_threads as f64)),
        ];
        // Bandwidth keys are omitted at their 0 ("unset") defaults so
        // pre-CommModel snapshots — and campaign specs built from them —
        // compare and round-trip unchanged.
        for (key, v) in [
            ("comm_up_mbps", self.comm_up_mbps),
            ("comm_down_mbps", self.comm_down_mbps),
            ("comm_latency_secs", self.comm_latency_secs),
        ] {
            if v != 0.0 {
                kv.push((key, Json::Num(v)));
            }
        }
        // Speculation off (the default) stays out of the snapshot, so
        // depth-0 manifests are byte-identical to pre-speculation ones.
        if self.exec_speculate_depth != 0 {
            kv.push(("exec_speculate_depth", Json::Num(self.exec_speculate_depth as f64)));
        }
        // Fleet-scale keys are likewise omitted at their "unset" defaults.
        if !self.fleet_trace.is_empty() {
            kv.push(("fleet_trace", Json::Str(self.fleet_trace.clone())));
        }
        if !self.fleet_profiles.is_empty() {
            kv.push((
                "fleet_profiles",
                Json::Arr(self.fleet_profiles.iter().map(|p| p.to_json()).collect()),
            ));
        }
        if self.fleet_sample != 0 {
            kv.push(("fleet_sample", Json::Num(self.fleet_sample as f64)));
        }
        if self.churn_dropout != 0.0 {
            kv.push(("churn_dropout", Json::Num(self.churn_dropout)));
        }
        if self.churn_period_secs != 0.0 {
            kv.push(("churn_period_secs", Json::Num(self.churn_period_secs)));
        }
        if self.churn_avail_frac != 1.0 {
            kv.push(("churn_avail_frac", Json::Num(self.churn_avail_frac)));
        }
        // Halving keys are omitted at their "off" defaults so pre-operator
        // snapshots — and campaign specs built from them — compare and
        // round-trip unchanged.
        if self.halving_rungs != 0 {
            kv.push(("halving_rungs", Json::Num(self.halving_rungs as f64)));
        }
        if self.halving_keep_frac != 0.5 {
            kv.push(("halving_keep_frac", Json::Num(self.halving_keep_frac)));
        }
        if self.halving_metric != "acc" {
            kv.push(("halving_metric", Json::Str(self.halving_metric.clone())));
        }
        // Omitted when empty so pre-registry snapshots compare and
        // round-trip unchanged.
        if !self.strategy_params.is_empty() {
            kv.push((
                "strategy_params",
                Json::Obj(
                    self.strategy_params
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::Num(*v)))
                        .collect(),
                ),
            ));
        }
        Json::obj(kv)
    }

    /// Rebuild a config from a [`ExperimentCfg::to_json`] snapshot.
    /// Missing keys fall back to defaults (older snapshots keep loading as
    /// the schema grows); a malformed fleet label is the one hard error.
    pub fn from_json(j: &Json) -> anyhow::Result<ExperimentCfg> {
        let d = ExperimentCfg::default();
        let s = |key: &str, dv: &str| {
            j.get(key).and_then(Json::as_str).unwrap_or(dv).to_string()
        };
        let f = |key: &str, dv: f64| j.get(key).and_then(Json::as_f64).unwrap_or(dv);
        let u = |key: &str, dv: usize| j.get(key).and_then(Json::as_usize).unwrap_or(dv);
        let mut cfg = ExperimentCfg {
            model: s("model", &d.model),
            artifacts_dir: PathBuf::from(s("artifacts_dir", "artifacts")),
            strategy: s("strategy", &d.strategy),
            fleet: FleetSpec::parse(&s("fleet", &d.fleet.label()))?,
            rounds: u("rounds", d.rounds),
            local_steps: u("local_steps", d.local_steps),
            lr: f("lr", d.lr),
            alpha: f("alpha", d.alpha),
            t_th_factor: f("t_th_factor", d.t_th_factor),
            slowest_round_secs: f("slowest_round_secs", d.slowest_round_secs),
            seed: match j.get("seed") {
                Some(Json::Str(s)) => s
                    .parse()
                    .map_err(|e| anyhow::anyhow!("config snapshot: bad seed {s:?}: {e}"))?,
                Some(Json::Num(x)) => *x as u64, // pre-string snapshots
                _ => d.seed,
            },
            eval_every: u("eval_every", d.eval_every),
            eval_batches: u("eval_batches", d.eval_batches),
            comm_secs: f("comm_secs", d.comm_secs),
            comm_up_mbps: f("comm_up_mbps", 0.0),
            comm_down_mbps: f("comm_down_mbps", 0.0),
            comm_latency_secs: f("comm_latency_secs", 0.0),
            exec_threads: u("threads", d.exec_threads),
            exec_speculate_depth: u("exec_speculate_depth", d.exec_speculate_depth),
            strategy_params: match j.get("strategy_params") {
                Some(Json::Obj(kv)) => {
                    let mut bag = kv
                        .iter()
                        .map(|(k, v)| {
                            v.as_f64().map(|x| (k.clone(), x)).ok_or_else(|| {
                                anyhow::anyhow!("config snapshot: strategy param {k:?} not a number")
                            })
                        })
                        .collect::<anyhow::Result<Vec<_>>>()?;
                    bag.sort_by(|a, b| a.0.cmp(&b.0));
                    bag
                }
                _ => Vec::new(),
            },
            fleet_trace: s("fleet_trace", &d.fleet_trace),
            fleet_profiles: match j.get("fleet_profiles") {
                Some(Json::Arr(items)) => items
                    .iter()
                    .map(crate::fleet::ClientProfile::from_json)
                    .collect::<anyhow::Result<Vec<_>>>()?,
                _ => Vec::new(),
            },
            fleet_sample: u("fleet_sample", d.fleet_sample),
            churn_dropout: f("churn_dropout", d.churn_dropout),
            churn_period_secs: f("churn_period_secs", d.churn_period_secs),
            churn_avail_frac: f("churn_avail_frac", d.churn_avail_frac),
            halving_rungs: u("halving_rungs", d.halving_rungs),
            halving_keep_frac: f("halving_keep_frac", d.halving_keep_frac),
            halving_metric: s("halving_metric", &d.halving_metric),
            record_selections: false,
            verbose: false,
            halt_after: None,
        };
        // Legacy snapshots carried a top-level `beta` that seeded the
        // FedEL family's harmonize_weight; fold it into the bag so runs
        // stored before the field's removal rebuild (and resume)
        // identically. Explicit bag bindings win, as they did then.
        if let Some(beta) = j.get("beta").and_then(Json::as_f64) {
            fold_beta_into_bag(&mut cfg.strategy_params, beta);
        }
        Ok(cfg)
    }
}

/// Bind every registered `harmonize_weight` tunable (the FedEL family) to
/// `beta`, leaving already-present bindings untouched — the deprecated
/// `--beta` alias and the legacy config-snapshot field both land here.
fn fold_beta_into_bag(bag: &mut Vec<(String, f64)>, beta: f64) {
    use crate::strategies::registry;
    for def in registry::builtin().defs() {
        for p in def.params.iter().filter(|p| p.name == "harmonize_weight") {
            let key = registry::StrategyRegistry::param_key(def.name, p.name);
            if !bag.iter().any(|(k, _)| *k == key) {
                bag.push((key, beta));
            }
        }
    }
    bag.sort_by(|a, b| a.0.cmp(&b.0));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_spec_parsing() {
        assert_eq!(FleetSpec::parse("small10").unwrap(), FleetSpec::Small10);
        assert_eq!(FleetSpec::parse("large100").unwrap(), FleetSpec::Large(100));
        assert_eq!(
            FleetSpec::parse("1.0,2.0").unwrap(),
            FleetSpec::Scales(vec![1.0, 2.0])
        );
        assert!(FleetSpec::parse("bogus").is_err());
    }

    #[test]
    fn lazy_fleet_spec_parses_and_labels_round_trip() {
        use crate::fleet::GeneratorSpec;
        let cases = [
            ("lazy1000000", GeneratorSpec::Uniform, 1_000_000),
            ("lazy100:cat:1,2,3,4", GeneratorSpec::Categorical(vec![1.0, 2.0, 3.0, 4.0]), 100),
            ("lazy50:lognormal:0:0.5", GeneratorSpec::LogNormal { mu: 0.0, sigma: 0.5 }, 50),
        ];
        for (label, generator, n) in cases {
            let spec = FleetSpec::parse(label).unwrap();
            assert_eq!(spec, FleetSpec::Lazy { n, generator: generator.clone() });
            assert_eq!(spec.label(), label, "label must invert parse");
        }
        assert!(FleetSpec::parse("lazy").is_err());
        assert!(FleetSpec::parse("lazy0").is_err());
        assert!(FleetSpec::parse("lazy10:zipf:2").is_err());
    }

    #[test]
    fn fleet_scale_keys_round_trip_and_stay_out_of_plain_snapshots() {
        use crate::fleet::{ClientProfile, EnergyClass};
        use crate::timing::DeviceProfile;
        // Plain configs never mention the new keys (old snapshots compare
        // and round-trip unchanged).
        let plain = ExperimentCfg::default().to_json();
        for key in [
            "fleet_trace",
            "fleet_profiles",
            "fleet_sample",
            "churn_dropout",
            "churn_period_secs",
            "churn_avail_frac",
        ] {
            assert!(plain.get(key).is_none(), "{key} leaked into a default snapshot");
        }
        let mut profile = ClientProfile::plain(DeviceProfile::new("edge", 2.0, 7.5));
        profile.up_mbps = 5.0;
        profile.energy = EnergyClass::Battery;
        let cfg = ExperimentCfg {
            fleet: FleetSpec::parse("lazy1000:lognormal:0:0.5").unwrap(),
            fleet_trace: "fleet.jsonl".into(),
            fleet_profiles: vec![profile],
            fleet_sample: 64,
            churn_dropout: 0.1,
            churn_period_secs: 3600.0,
            churn_avail_frac: 0.75,
            ..Default::default()
        };
        let text = cfg.to_json().to_string_pretty();
        let back = ExperimentCfg::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.fleet, cfg.fleet);
        assert_eq!(back.fleet_trace, cfg.fleet_trace);
        assert_eq!(back.fleet_profiles, cfg.fleet_profiles);
        assert_eq!(back.fleet_sample, 64);
        assert_eq!(back.churn_dropout.to_bits(), cfg.churn_dropout.to_bits());
        assert_eq!(back.churn_period_secs.to_bits(), cfg.churn_period_secs.to_bits());
        assert_eq!(back.churn_avail_frac.to_bits(), cfg.churn_avail_frac.to_bits());
    }

    #[test]
    fn speculate_depth_round_trips_and_stays_out_of_plain_snapshots() {
        let plain = ExperimentCfg::default().to_json();
        assert!(
            plain.get("exec_speculate_depth").is_none(),
            "exec_speculate_depth leaked into a default snapshot"
        );
        let cfg = ExperimentCfg { exec_speculate_depth: 4, ..Default::default() };
        let text = cfg.to_json().to_string_pretty();
        let back = ExperimentCfg::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.exec_speculate_depth, 4);
    }

    #[test]
    fn halving_keys_round_trip_and_stay_out_of_plain_snapshots() {
        let plain = ExperimentCfg::default().to_json();
        for key in ["halving_rungs", "halving_keep_frac", "halving_metric"] {
            assert!(plain.get(key).is_none(), "{key} leaked into a default snapshot");
        }
        let cfg = ExperimentCfg {
            halving_rungs: 3,
            halving_keep_frac: 0.25,
            halving_metric: "loss".into(),
            ..Default::default()
        };
        let text = cfg.to_json().to_string_pretty();
        let back = ExperimentCfg::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.halving_rungs, 3);
        assert_eq!(back.halving_keep_frac.to_bits(), cfg.halving_keep_frac.to_bits());
        assert_eq!(back.halving_metric, "loss");
    }

    #[test]
    fn args_override_defaults() {
        let args = Args::parse(
            ["--model", "vgg_cifar", "--rounds", "7"].iter().map(|s| s.to_string()),
            false,
        );
        let cfg = ExperimentCfg::from_args(&args).unwrap();
        assert_eq!(cfg.model, "vgg_cifar");
        assert_eq!(cfg.rounds, 7);
        assert_eq!(cfg.alpha, 0.1); // default preserved
    }

    #[test]
    fn deprecated_beta_flag_folds_into_the_bag() {
        let args = Args::parse(["--beta", "0.4"].iter().map(|s| s.to_string()), false);
        let cfg = ExperimentCfg::from_args(&args).unwrap();
        let get = |k: &str| cfg.strategy_params.iter().find(|(key, _)| key == k).map(|(_, v)| *v);
        assert_eq!(get("strategy.fedel.harmonize_weight"), Some(0.4));
        assert_eq!(get("strategy.fedel-c.harmonize_weight"), Some(0.4));
        // an explicit --set wins over the alias
        let args = Args::parse(
            ["--beta", "0.4", "--set", "strategy.fedel.harmonize_weight=0.9"]
                .iter()
                .map(|s| s.to_string()),
            false,
        );
        let cfg = ExperimentCfg::from_args(&args).unwrap();
        let get = |k: &str| cfg.strategy_params.iter().find(|(key, _)| key == k).map(|(_, v)| *v);
        assert_eq!(get("strategy.fedel.harmonize_weight"), Some(0.9));
        assert_eq!(get("strategy.fedel-norollback.harmonize_weight"), Some(0.4));
    }

    #[test]
    fn legacy_beta_snapshot_key_folds_on_load() {
        // A pre-removal snapshot: top-level beta, no strategy_params.
        let j = Json::parse(r#"{"model": "mock:4x10", "beta": 0.45}"#).unwrap();
        let cfg = ExperimentCfg::from_json(&j).unwrap();
        assert!(cfg
            .strategy_params
            .iter()
            .any(|(k, v)| k == "strategy.fedel.harmonize_weight" && *v == 0.45));
        // an explicit bag binding beats the legacy field, like it always did
        let j = Json::parse(
            r#"{"beta": 0.45,
                "strategy_params": {"strategy.fedel.harmonize_weight": 0.2}}"#,
        )
        .unwrap();
        let cfg = ExperimentCfg::from_json(&j).unwrap();
        assert!(cfg
            .strategy_params
            .iter()
            .any(|(k, v)| k == "strategy.fedel.harmonize_weight" && *v == 0.2));
    }

    #[test]
    fn json_dump_contains_provenance() {
        let cfg = ExperimentCfg::default();
        let j = cfg.to_json();
        assert_eq!(j.s("strategy").unwrap(), "fedel");
        assert!(j.get("beta").is_none(), "legacy field must stay out of new snapshots");
    }

    #[test]
    fn comm_model_resolution_and_snapshot_stability() {
        use crate::timing::CommModel;
        let cfg = ExperimentCfg::default();
        assert_eq!(cfg.comm_model(), CommModel::Constant(30.0));
        // unset bandwidth keys stay out of the snapshot (old specs compare equal)
        assert!(cfg.to_json().get("comm_up_mbps").is_none());
        let cfg = ExperimentCfg { comm_up_mbps: 20.0, comm_latency_secs: 0.05, ..Default::default() };
        match cfg.comm_model() {
            CommModel::Bandwidth { up_mbps, down_mbps, latency_secs } => {
                assert_eq!((up_mbps, down_mbps, latency_secs), (20.0, 0.0, 0.05));
            }
            other => panic!("{other:?}"),
        }
        let back = ExperimentCfg::from_json(&Json::parse(&cfg.to_json().to_string_pretty()).unwrap())
            .unwrap();
        assert_eq!(back.comm_up_mbps, 20.0);
        assert_eq!(back.comm_latency_secs, 0.05);
        assert_eq!(back.comm_down_mbps, 0.0);
    }

    #[test]
    fn snapshot_round_trips_through_json_text() {
        let cfg = ExperimentCfg {
            model: "mock:8x100".into(),
            strategy: "pyramidfl".into(),
            fleet: FleetSpec::Scales(vec![1.0, 2.5, 4.0]),
            rounds: 17,
            local_steps: 3,
            lr: 0.0125,
            alpha: 0.3,
            t_th_factor: 1.5,
            slowest_round_secs: 1234.5,
            seed: 77,
            eval_every: 3,
            eval_batches: 5,
            comm_secs: 12.25,
            exec_threads: 2,
            ..Default::default()
        };
        let text = cfg.to_json().to_string_pretty();
        let back = ExperimentCfg::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.model, cfg.model);
        assert_eq!(back.strategy, cfg.strategy);
        assert_eq!(back.fleet, cfg.fleet);
        assert_eq!(back.rounds, cfg.rounds);
        assert_eq!(back.local_steps, cfg.local_steps);
        assert_eq!(back.lr.to_bits(), cfg.lr.to_bits());
        assert_eq!(back.alpha.to_bits(), cfg.alpha.to_bits());
        assert_eq!(back.seed, cfg.seed);
        assert_eq!(back.eval_every, cfg.eval_every);
        assert_eq!(back.eval_batches, cfg.eval_batches);
        assert_eq!(back.comm_secs.to_bits(), cfg.comm_secs.to_bits());
        assert_eq!(back.exec_threads, cfg.exec_threads);
    }

    #[test]
    fn seed_survives_beyond_f64_integer_range() {
        // 2^53 + 1 is unrepresentable as f64 — the string path must keep
        // it exact, or resumed runs would rebuild a different fleet.
        let cfg = ExperimentCfg { seed: (1u64 << 53) + 1, ..Default::default() };
        let text = cfg.to_json().to_string_pretty();
        let back = ExperimentCfg::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.seed, (1u64 << 53) + 1);
    }

    #[test]
    fn strategy_params_round_trip_and_set_overrides_flags() {
        let cfg = ExperimentCfg {
            strategy_params: vec![
                ("strategy.fedel.harmonize_weight".to_string(), 0.25),
                ("strategy.pyramidfl.frac".to_string(), 0.8),
            ],
            ..Default::default()
        };
        let back =
            ExperimentCfg::from_json(&Json::parse(&cfg.to_json().to_string_pretty()).unwrap())
                .unwrap();
        assert_eq!(back.strategy_params, cfg.strategy_params);
        // empty bag stays out of the snapshot entirely
        let j = ExperimentCfg::default().to_json();
        assert!(j.get("strategy_params").is_none());

        // --set is the last layer: it wins over the per-field flag
        let args = Args::parse(
            ["--lr", "0.5", "--set", "train.lr=0.125", "--set", "data.alpha=0.3"]
                .iter()
                .map(|s| s.to_string()),
            false,
        );
        let cfg = ExperimentCfg::from_args(&args).unwrap();
        assert_eq!(cfg.lr, 0.125);
        assert_eq!(cfg.alpha, 0.3);
        // unknown --set keys error with a suggestion instead of a bare bail
        let args = Args::parse(
            ["--set", "data.alhpa=0.3"].iter().map(|s| s.to_string()),
            false,
        );
        let err = ExperimentCfg::from_args(&args).unwrap_err().to_string();
        assert!(err.contains("did you mean"), "{err}");
    }

    #[test]
    fn from_json_defaults_missing_keys() {
        let j = Json::parse(r#"{"model": "mock:4x10", "fleet": "large20"}"#).unwrap();
        let cfg = ExperimentCfg::from_json(&j).unwrap();
        assert_eq!(cfg.model, "mock:4x10");
        assert_eq!(cfg.fleet, FleetSpec::Large(20));
        assert_eq!(cfg.rounds, ExperimentCfg::default().rounds);
        assert!(ExperimentCfg::from_json(&Json::parse(r#"{"fleet": "bogus"}"#).unwrap()).is_err());
    }
}
