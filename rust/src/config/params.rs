//! The typed parameter space over [`ExperimentCfg`]: every sweepable /
//! settable knob is a registered key with a type, bounds, and help text.
//!
//! * [`ParamSpace`] is the key registry: fixed keys for the config's own
//!   fields (`train.lr`, `data.alpha`, `seed`, `fleet`, ...) plus one
//!   dynamic key per tunable each strategy declares in
//!   [`crate::strategies::registry`] (`strategy.fedel.harmonize_weight`).
//!   Unknown keys fail with the full roster and a nearest-match hint.
//! * [`ParamValue`] is a parsed, typed value with a **canonical string
//!   rendering** — f64 renders via the shortest-round-trip `Display`, so
//!   `render -> parse` is exact and cell labels / manifests built from
//!   rendered values are stable identities.
//! * [`SpecOverlay`] is an ordered list of `key=value` bindings. Overlays
//!   layer with defined precedence — base config < campaign axis < CLI
//!   `--set` — by applying later layers after earlier ones; *within* one
//!   layer a key may be bound at most once, which is what makes layer
//!   application order-independent (`tests/params.rs` proves both).
//! * [`SweepAxis`] is one campaign grid dimension: a key plus the list of
//!   values to sweep (`--sweep data.alpha=0.1,0.5`).

use std::fmt;

use crate::config::{ExperimentCfg, FleetSpec};
use crate::strategies::registry::{self, StrategyRegistry};
use crate::util::json::Json;

/// The type a registered key parses to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParamType {
    Str,
    F64,
    U64,
    Usize,
    Fleet,
}

impl ParamType {
    pub fn as_str(&self) -> &'static str {
        match self {
            ParamType::Str => "str",
            ParamType::F64 => "f64",
            ParamType::U64 => "u64",
            ParamType::Usize => "usize",
            ParamType::Fleet => "fleet",
        }
    }
}

/// A parsed, typed value. `render()` is canonical: rendering and
/// re-parsing under the same key yields an identical value (f64 rides the
/// shortest round-trip `Display`, u64 stays decimal, fleets use their
/// label form).
#[derive(Clone, Debug, PartialEq)]
pub enum ParamValue {
    Str(String),
    F64(f64),
    U64(u64),
    Usize(usize),
    Fleet(FleetSpec),
}

impl ParamValue {
    pub fn render(&self) -> String {
        match self {
            ParamValue::Str(s) => s.clone(),
            ParamValue::F64(x) => format!("{x}"),
            ParamValue::U64(x) => format!("{x}"),
            ParamValue::Usize(x) => format!("{x}"),
            ParamValue::Fleet(f) => f.label(),
        }
    }
}

impl fmt::Display for ParamValue {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.render())
    }
}

/// Which piece of state a key reads/writes.
#[derive(Clone, Debug)]
enum Slot {
    Model,
    Fleet,
    Seed,
    Strategy,
    Rounds,
    LocalSteps,
    Lr,
    Alpha,
    EvalEvery,
    EvalBatches,
    TThFactor,
    CommSecs,
    CommUpMbps,
    CommDownMbps,
    CommLatencySecs,
    SlowestRoundSecs,
    FleetTrace,
    FleetSample,
    ChurnDropout,
    ChurnPeriodSecs,
    ChurnAvailFrac,
    SpeculateDepth,
    HalvingRungs,
    HalvingKeepFrac,
    HalvingMetric,
    /// A strategy-declared tunable living in the config's parameter bag
    /// under its full key.
    StrategyParam { default: f64, min: f64, max: f64 },
}

/// One registered key.
#[derive(Clone, Debug)]
pub struct KeyDef {
    pub key: String,
    pub ty: ParamType,
    pub help: String,
    slot: Slot,
}

impl KeyDef {
    fn fixed(key: &str, ty: ParamType, help: &str, slot: Slot) -> KeyDef {
        KeyDef { key: key.to_string(), ty, help: help.to_string(), slot }
    }

    /// Parse + validate a raw string for this key.
    pub fn parse(&self, raw: &str) -> anyhow::Result<ParamValue> {
        let bad = |what: &str| anyhow::anyhow!("{}: {what} (got {raw:?})", self.key);
        let v = match self.ty {
            ParamType::Str => ParamValue::Str(raw.to_string()),
            ParamType::Fleet => ParamValue::Fleet(FleetSpec::parse(raw)?),
            ParamType::F64 => {
                ParamValue::F64(raw.parse().map_err(|_| bad("expected a number"))?)
            }
            ParamType::U64 => {
                ParamValue::U64(raw.parse().map_err(|_| bad("expected an unsigned integer"))?)
            }
            ParamType::Usize => {
                ParamValue::Usize(raw.parse().map_err(|_| bad("expected an unsigned integer"))?)
            }
        };
        self.validate(&v)?;
        Ok(v)
    }

    /// Range/semantic validation (also applied when values arrive already
    /// typed, e.g. from spec JSON).
    pub fn validate(&self, v: &ParamValue) -> anyhow::Result<()> {
        let err = |what: String| Err(anyhow::anyhow!("{}: {what}", self.key));
        match (&self.slot, v) {
            (Slot::Strategy, ParamValue::Str(s)) => {
                registry::builtin().require(s)?;
            }
            (Slot::Rounds, ParamValue::Usize(n))
            | (Slot::LocalSteps, ParamValue::Usize(n))
            | (Slot::EvalEvery, ParamValue::Usize(n))
            | (Slot::EvalBatches, ParamValue::Usize(n)) => {
                if *n == 0 {
                    return err("must be >= 1".into());
                }
            }
            (Slot::Lr, ParamValue::F64(x))
            | (Slot::Alpha, ParamValue::F64(x))
            | (Slot::TThFactor, ParamValue::F64(x)) => {
                if !x.is_finite() || *x <= 0.0 {
                    return err(format!("must be > 0 (got {x})"));
                }
            }
            (Slot::CommSecs, ParamValue::F64(x))
            | (Slot::CommUpMbps, ParamValue::F64(x))
            | (Slot::CommDownMbps, ParamValue::F64(x))
            | (Slot::CommLatencySecs, ParamValue::F64(x))
            | (Slot::SlowestRoundSecs, ParamValue::F64(x))
            | (Slot::ChurnPeriodSecs, ParamValue::F64(x)) => {
                if !x.is_finite() || *x < 0.0 {
                    return err(format!("must be >= 0 (got {x})"));
                }
            }
            (Slot::ChurnDropout, ParamValue::F64(x)) => {
                if !x.is_finite() || *x < 0.0 || *x >= 1.0 {
                    return err(format!("must be in [0, 1) (got {x})"));
                }
            }
            (Slot::ChurnAvailFrac, ParamValue::F64(x))
            | (Slot::HalvingKeepFrac, ParamValue::F64(x)) => {
                if !x.is_finite() || *x <= 0.0 || *x > 1.0 {
                    return err(format!("must be in (0, 1] (got {x})"));
                }
            }
            (Slot::HalvingMetric, ParamValue::Str(s)) => {
                if s != "acc" && s != "loss" {
                    return err(format!("must be \"acc\" or \"loss\" (got {s:?})"));
                }
            }
            (Slot::StrategyParam { min, max, .. }, ParamValue::F64(x)) => {
                if x.is_nan() || *x < *min || *x > *max {
                    return err(format!("{x} out of bounds [{min}, {max}]"));
                }
            }
            _ => {}
        }
        Ok(())
    }

    /// Read this key's current value off a config.
    pub fn get(&self, cfg: &ExperimentCfg) -> ParamValue {
        match &self.slot {
            Slot::Model => ParamValue::Str(cfg.model.clone()),
            Slot::Fleet => ParamValue::Fleet(cfg.fleet.clone()),
            Slot::Seed => ParamValue::U64(cfg.seed),
            Slot::Strategy => ParamValue::Str(cfg.strategy.clone()),
            Slot::Rounds => ParamValue::Usize(cfg.rounds),
            Slot::LocalSteps => ParamValue::Usize(cfg.local_steps),
            Slot::Lr => ParamValue::F64(cfg.lr),
            Slot::Alpha => ParamValue::F64(cfg.alpha),
            Slot::EvalEvery => ParamValue::Usize(cfg.eval_every),
            Slot::EvalBatches => ParamValue::Usize(cfg.eval_batches),
            Slot::TThFactor => ParamValue::F64(cfg.t_th_factor),
            Slot::CommSecs => ParamValue::F64(cfg.comm_secs),
            Slot::CommUpMbps => ParamValue::F64(cfg.comm_up_mbps),
            Slot::CommDownMbps => ParamValue::F64(cfg.comm_down_mbps),
            Slot::CommLatencySecs => ParamValue::F64(cfg.comm_latency_secs),
            Slot::SlowestRoundSecs => ParamValue::F64(cfg.slowest_round_secs),
            Slot::FleetTrace => ParamValue::Str(cfg.fleet_trace.clone()),
            Slot::FleetSample => ParamValue::Usize(cfg.fleet_sample),
            Slot::ChurnDropout => ParamValue::F64(cfg.churn_dropout),
            Slot::ChurnPeriodSecs => ParamValue::F64(cfg.churn_period_secs),
            Slot::ChurnAvailFrac => ParamValue::F64(cfg.churn_avail_frac),
            Slot::SpeculateDepth => ParamValue::Usize(cfg.exec_speculate_depth),
            Slot::HalvingRungs => ParamValue::Usize(cfg.halving_rungs),
            Slot::HalvingKeepFrac => ParamValue::F64(cfg.halving_keep_frac),
            Slot::HalvingMetric => ParamValue::Str(cfg.halving_metric.clone()),
            Slot::StrategyParam { default, .. } => ParamValue::F64(
                cfg.strategy_params
                    .iter()
                    .find(|(k, _)| *k == self.key)
                    .map(|(_, v)| *v)
                    .unwrap_or(*default),
            ),
        }
    }

    /// Write a (validated) value onto a config. The value must carry this
    /// key's type — overlays built through [`KeyDef::parse`] always do.
    pub fn apply(&self, cfg: &mut ExperimentCfg, v: &ParamValue) -> anyhow::Result<()> {
        self.validate(v)?;
        let type_err = || {
            anyhow::anyhow!(
                "{}: expected a {} value, got {v:?}",
                self.key,
                self.ty.as_str()
            )
        };
        match (&self.slot, v) {
            (Slot::Model, ParamValue::Str(s)) => cfg.model = s.clone(),
            (Slot::Fleet, ParamValue::Fleet(f)) => cfg.fleet = f.clone(),
            (Slot::Seed, ParamValue::U64(x)) => cfg.seed = *x,
            (Slot::Strategy, ParamValue::Str(s)) => cfg.strategy = s.clone(),
            (Slot::Rounds, ParamValue::Usize(n)) => cfg.rounds = *n,
            (Slot::LocalSteps, ParamValue::Usize(n)) => cfg.local_steps = *n,
            (Slot::Lr, ParamValue::F64(x)) => cfg.lr = *x,
            (Slot::Alpha, ParamValue::F64(x)) => cfg.alpha = *x,
            (Slot::EvalEvery, ParamValue::Usize(n)) => cfg.eval_every = *n,
            (Slot::EvalBatches, ParamValue::Usize(n)) => cfg.eval_batches = *n,
            (Slot::TThFactor, ParamValue::F64(x)) => cfg.t_th_factor = *x,
            (Slot::CommSecs, ParamValue::F64(x)) => cfg.comm_secs = *x,
            (Slot::CommUpMbps, ParamValue::F64(x)) => cfg.comm_up_mbps = *x,
            (Slot::CommDownMbps, ParamValue::F64(x)) => cfg.comm_down_mbps = *x,
            (Slot::CommLatencySecs, ParamValue::F64(x)) => cfg.comm_latency_secs = *x,
            (Slot::SlowestRoundSecs, ParamValue::F64(x)) => cfg.slowest_round_secs = *x,
            (Slot::FleetTrace, ParamValue::Str(s)) => cfg.fleet_trace = s.clone(),
            (Slot::FleetSample, ParamValue::Usize(n)) => cfg.fleet_sample = *n,
            (Slot::ChurnDropout, ParamValue::F64(x)) => cfg.churn_dropout = *x,
            (Slot::ChurnPeriodSecs, ParamValue::F64(x)) => cfg.churn_period_secs = *x,
            (Slot::ChurnAvailFrac, ParamValue::F64(x)) => cfg.churn_avail_frac = *x,
            (Slot::SpeculateDepth, ParamValue::Usize(n)) => cfg.exec_speculate_depth = *n,
            (Slot::HalvingRungs, ParamValue::Usize(n)) => cfg.halving_rungs = *n,
            (Slot::HalvingKeepFrac, ParamValue::F64(x)) => cfg.halving_keep_frac = *x,
            (Slot::HalvingMetric, ParamValue::Str(s)) => cfg.halving_metric = s.clone(),
            (Slot::StrategyParam { .. }, ParamValue::F64(x)) => {
                match cfg.strategy_params.iter_mut().find(|(k, _)| *k == self.key) {
                    Some(entry) => entry.1 = *x,
                    None => {
                        cfg.strategy_params.push((self.key.clone(), *x));
                        cfg.strategy_params.sort_by(|a, b| a.0.cmp(&b.0));
                    }
                }
            }
            _ => return Err(type_err()),
        }
        Ok(())
    }
}

/// The key registry: fixed config fields + every strategy-declared
/// tunable. Cheap to build; [`ParamSpace::shared`] caches one.
pub struct ParamSpace {
    keys: Vec<KeyDef>,
}

impl ParamSpace {
    pub fn new() -> ParamSpace {
        use ParamType::*;
        let mut keys = vec![
            KeyDef::fixed("model", Str, "zoo model name, or mock:<blocks>x<body>", Slot::Model),
            KeyDef::fixed("fleet", Fleet, "small10 | largeN | s1,s2,...", Slot::Fleet),
            KeyDef::fixed("seed", U64, "experiment seed (fleet, data split, init)", Slot::Seed),
            KeyDef::fixed("strategy", Str, "registered strategy name", Slot::Strategy),
            KeyDef::fixed("train.rounds", Usize, "federated rounds", Slot::Rounds),
            KeyDef::fixed("train.local_steps", Usize, "local steps per round", Slot::LocalSteps),
            KeyDef::fixed("train.lr", F64, "client learning rate", Slot::Lr),
            KeyDef::fixed(
                "data.alpha",
                F64,
                "Dirichlet non-iid concentration (paper: 0.1)",
                Slot::Alpha,
            ),
            KeyDef::fixed("eval.every", Usize, "evaluate every k rounds", Slot::EvalEvery),
            KeyDef::fixed("eval.batches", Usize, "eval batches per evaluation", Slot::EvalBatches),
            KeyDef::fixed(
                "time.t_th_factor",
                F64,
                "T_th as a factor of the fastest device's full round",
                Slot::TThFactor,
            ),
            KeyDef::fixed(
                "time.comm_secs",
                F64,
                "flat per-round communication cost (the degenerate CommModel)",
                Slot::CommSecs,
            ),
            KeyDef::fixed(
                "comm.up_mbps",
                F64,
                "client upload bandwidth, Mbit/s (any comm.* key > 0 switches to \
                 the payload-priced CommModel; 0 = that direction free)",
                Slot::CommUpMbps,
            ),
            KeyDef::fixed(
                "comm.down_mbps",
                F64,
                "client download bandwidth, Mbit/s",
                Slot::CommDownMbps,
            ),
            KeyDef::fixed(
                "comm.latency_secs",
                F64,
                "per-transfer link latency, seconds",
                Slot::CommLatencySecs,
            ),
            KeyDef::fixed(
                "time.slowest_round_secs",
                F64,
                "calibrate the slowest device's full round to this (0 = off)",
                Slot::SlowestRoundSecs,
            ),
            KeyDef::fixed(
                "fleet.trace",
                Str,
                "JSONL fleet trace path (one client profile per line); overrides `fleet`",
                Slot::FleetTrace,
            ),
            KeyDef::fixed(
                "fleet.sample",
                Usize,
                "async in-flight client cap (0 = all clients in flight); required for lazy fleets",
                Slot::FleetSample,
            ),
            KeyDef::fixed(
                "fleet.churn.dropout",
                F64,
                "probability a finished update is discarded mid-round, [0, 1)",
                Slot::ChurnDropout,
            ),
            KeyDef::fixed(
                "fleet.churn.period_secs",
                F64,
                "availability cycle length in sim seconds (0 = always online)",
                Slot::ChurnPeriodSecs,
            ),
            KeyDef::fixed(
                "fleet.churn.avail_frac",
                F64,
                "fraction of each availability cycle a client is online, (0, 1]",
                Slot::ChurnAvailFrac,
            ),
            KeyDef::fixed(
                "exec.speculate.depth",
                Usize,
                "async speculation lookahead: dispatches pre-executed against predicted \
                 globals while earlier uploads are in flight (0 = off; results are \
                 bitwise-identical at any depth)",
                Slot::SpeculateDepth,
            ),
            KeyDef::fixed(
                "operator.halving.rungs",
                Usize,
                "successive-halving rung count over the round budget (0 = halving off)",
                Slot::HalvingRungs,
            ),
            KeyDef::fixed(
                "operator.halving.keep_frac",
                F64,
                "fraction of live cells each rung keeps, (0, 1]",
                Slot::HalvingKeepFrac,
            ),
            KeyDef::fixed(
                "operator.halving.metric",
                Str,
                "rung ranking metric: acc (higher wins) or loss (lower wins)",
                Slot::HalvingMetric,
            ),
        ];
        for def in registry::builtin().defs() {
            for p in &def.params {
                keys.push(KeyDef {
                    key: StrategyRegistry::param_key(def.name, p.name),
                    ty: ParamType::F64,
                    help: p.help.to_string(),
                    slot: Slot::StrategyParam { default: p.default, min: p.min, max: p.max },
                });
            }
        }
        ParamSpace { keys }
    }

    /// The process-wide space (the registry it derives from is static).
    pub fn shared() -> &'static ParamSpace {
        static SPACE: std::sync::OnceLock<ParamSpace> = std::sync::OnceLock::new();
        SPACE.get_or_init(ParamSpace::new)
    }

    pub fn keys(&self) -> &[KeyDef] {
        &self.keys
    }

    /// Look a key up, or fail with the full roster and a nearest-match
    /// suggestion — a typo should never read as "feature missing".
    pub fn resolve(&self, key: &str) -> anyhow::Result<&KeyDef> {
        if let Some(def) = self.keys.iter().find(|d| d.key == key) {
            return Ok(def);
        }
        let names: Vec<&str> = self.keys.iter().map(|d| d.key.as_str()).collect();
        let hint = crate::util::nearest_match(key, &names)
            .map(|n| format!(" — did you mean {n:?}?"))
            .unwrap_or_default();
        anyhow::bail!(
            "unknown parameter key {key:?}{hint}\nregistered keys:\n  {}",
            names.join("\n  ")
        )
    }
}

impl Default for ParamSpace {
    fn default() -> Self {
        ParamSpace::new()
    }
}

/// One `key=value` binding.
#[derive(Clone, Debug, PartialEq)]
pub struct Binding {
    pub key: String,
    pub value: ParamValue,
}

impl Binding {
    /// Parse `key=value` against the space.
    pub fn parse(space: &ParamSpace, spec: &str) -> anyhow::Result<Binding> {
        let (key, raw) = spec
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("binding {spec:?} is not key=value"))?;
        let def = space.resolve(key)?;
        Ok(Binding { key: def.key.clone(), value: def.parse(raw)? })
    }

    /// Canonical `key=value` rendering (inverse of [`Binding::parse`]).
    pub fn render(&self) -> String {
        format!("{}={}", self.key, self.value.render())
    }
}

/// Deterministic label for a list of bindings — the campaign's cell
/// identity ("base" for an empty list).
pub fn bindings_label(bindings: &[Binding]) -> String {
    if bindings.is_empty() {
        return "base".to_string();
    }
    bindings.iter().map(Binding::render).collect::<Vec<_>>().join(",")
}

/// An ordered list of bindings forming one precedence layer. A key may be
/// bound at most once per overlay, so applying an overlay is
/// order-independent; layers stack by applying one overlay after another
/// (base config < campaign axis < CLI `--set`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SpecOverlay {
    pub bindings: Vec<Binding>,
}

impl SpecOverlay {
    pub fn new() -> SpecOverlay {
        SpecOverlay { bindings: Vec::new() }
    }

    pub fn is_empty(&self) -> bool {
        self.bindings.is_empty()
    }

    /// Parse `key=value` specs (e.g. repeated `--set` values) into one
    /// layer, rejecting duplicate keys.
    pub fn parse(space: &ParamSpace, specs: &[&str]) -> anyhow::Result<SpecOverlay> {
        let mut overlay = SpecOverlay::new();
        for spec in specs {
            overlay.push(Binding::parse(space, spec)?)?;
        }
        Ok(overlay)
    }

    /// Add a binding; a key already bound in this layer is an error (two
    /// values for one key in one layer has no defined winner).
    pub fn push(&mut self, b: Binding) -> anyhow::Result<()> {
        anyhow::ensure!(
            !self.bindings.iter().any(|x| x.key == b.key),
            "key {:?} bound twice in one layer",
            b.key
        );
        self.bindings.push(b);
        Ok(())
    }

    /// Apply every binding onto a config.
    pub fn apply(&self, space: &ParamSpace, cfg: &mut ExperimentCfg) -> anyhow::Result<()> {
        for b in &self.bindings {
            space.resolve(&b.key)?.apply(cfg, &b.value)?;
        }
        Ok(())
    }

    /// Manifest form: an array of canonical `key=value` strings.
    pub fn to_json(&self) -> Json {
        Json::Arr(self.bindings.iter().map(|b| Json::Str(b.render())).collect())
    }

    pub fn from_json(space: &ParamSpace, j: &Json) -> anyhow::Result<SpecOverlay> {
        let arr = j
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("overlay is not an array of key=value strings"))?;
        let mut overlay = SpecOverlay::new();
        for v in arr {
            let s = v
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("overlay entry {v:?} is not a string"))?;
            overlay.push(Binding::parse(space, s)?)?;
        }
        Ok(overlay)
    }
}

/// One campaign grid dimension: a registered key and the values it sweeps.
#[derive(Clone, Debug, PartialEq)]
pub struct SweepAxis {
    pub key: String,
    pub values: Vec<ParamValue>,
}

impl SweepAxis {
    /// Parse `key=v1,v2,...`. Fleet-typed keys split on ';' instead
    /// (fleet specs like `1,2.5,4` use commas internally):
    /// `--sweep "fleet=small10;large20"`. Any other key also accepts ';'
    /// when the value list uses it exclusively — `fleet.churn.dropout=
    /// 0;0.1;0.3` and `0,0.1,0.3` are the same axis.
    pub fn parse(space: &ParamSpace, spec: &str) -> anyhow::Result<SweepAxis> {
        let (key, raw) = spec
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("sweep axis {spec:?} is not key=v1,v2,..."))?;
        let def = space.resolve(key)?;
        let sep = if def.ty == ParamType::Fleet || (raw.contains(';') && !raw.contains(',')) {
            ';'
        } else {
            ','
        };
        let mut values = Vec::new();
        for part in raw.split(sep).filter(|p| !p.is_empty()) {
            let v = def.parse(part)?;
            anyhow::ensure!(
                !values.contains(&v),
                "sweep axis {key}: value {part:?} listed twice",
            );
            values.push(v);
        }
        anyhow::ensure!(!values.is_empty(), "sweep axis {key} has no values");
        Ok(SweepAxis { key: def.key.clone(), values })
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("key", Json::Str(self.key.clone())),
            (
                "values",
                Json::Arr(self.values.iter().map(|v| Json::Str(v.render())).collect()),
            ),
        ])
    }

    pub fn from_json(space: &ParamSpace, j: &Json) -> anyhow::Result<SweepAxis> {
        let key = j.s("key")?;
        let def = space.resolve(key)?;
        let mut values = Vec::new();
        for v in j.arr("values")? {
            let s = v
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("axis {key}: value {v:?} not a string"))?;
            values.push(def.parse(s)?);
        }
        anyhow::ensure!(!values.is_empty(), "sweep axis {key} has no values");
        Ok(SweepAxis { key: def.key.clone(), values })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_and_strategy_keys_resolve() {
        let space = ParamSpace::shared();
        for key in ["train.lr", "data.alpha", "seed", "fleet", "strategy"] {
            space.resolve(key).unwrap();
        }
        let def = space.resolve("strategy.fedel.harmonize_weight").unwrap();
        assert_eq!(def.ty, ParamType::F64);
        assert!(space.resolve("strategy.pyramidfl.frac").is_ok());
    }

    #[test]
    fn unknown_key_lists_roster_and_suggests() {
        let err = ParamSpace::shared().resolve("data.alhpa").unwrap_err().to_string();
        assert!(err.contains("did you mean \"data.alpha\""), "{err}");
        assert!(err.contains("train.lr"), "roster missing from {err}");
    }

    #[test]
    fn bindings_parse_apply_and_render_canonically() {
        let space = ParamSpace::shared();
        let mut cfg = ExperimentCfg::default();
        for spec in [
            "train.lr=0.125",
            "data.alpha=0.5",
            "seed=18014398509481985", // 2^54 + 1: u64 path, not f64
            "fleet=1,2.5,4",
            "strategy.fedel.harmonize_weight=0.4",
        ] {
            let b = Binding::parse(space, spec).unwrap();
            assert_eq!(b.render(), *spec, "canonical rendering");
            space.resolve(&b.key).unwrap().apply(&mut cfg, &b.value).unwrap();
        }
        assert_eq!(cfg.lr, 0.125);
        assert_eq!(cfg.alpha, 0.5);
        assert_eq!(cfg.seed, (1u64 << 54) + 1);
        assert_eq!(cfg.fleet, FleetSpec::Scales(vec![1.0, 2.5, 4.0]));
        assert_eq!(
            cfg.strategy_params,
            vec![("strategy.fedel.harmonize_weight".to_string(), 0.4)]
        );
    }

    #[test]
    fn validation_rejects_bad_values() {
        let space = ParamSpace::shared();
        assert!(Binding::parse(space, "train.rounds=0").is_err());
        assert!(Binding::parse(space, "train.lr=-1").is_err());
        assert!(Binding::parse(space, "train.lr=abc").is_err());
        assert!(Binding::parse(space, "strategy=bogus").is_err());
        assert!(Binding::parse(space, "strategy.fedel.harmonize_weight=2").is_err());
        assert!(Binding::parse(space, "no-equals").is_err());
    }

    #[test]
    fn overlay_rejects_duplicate_keys_within_a_layer() {
        let space = ParamSpace::shared();
        let err = SpecOverlay::parse(space, &["train.lr=0.1", "train.lr=0.2"]).unwrap_err();
        assert!(err.to_string().contains("bound twice"), "{err}");
    }

    #[test]
    fn sweep_axis_parses_commas_and_fleet_semicolons() {
        let space = ParamSpace::shared();
        let a = SweepAxis::parse(space, "data.alpha=0.1,0.5").unwrap();
        assert_eq!(a.values, vec![ParamValue::F64(0.1), ParamValue::F64(0.5)]);
        let f = SweepAxis::parse(space, "fleet=small10;1,2.5").unwrap();
        assert_eq!(
            f.values,
            vec![
                ParamValue::Fleet(FleetSpec::Small10),
                ParamValue::Fleet(FleetSpec::Scales(vec![1.0, 2.5]))
            ]
        );
        assert!(SweepAxis::parse(space, "data.alpha=").is_err());
        assert!(SweepAxis::parse(space, "data.alpha=0.1,0.1").is_err());
        let axis_json = a.to_json();
        assert_eq!(SweepAxis::from_json(space, &axis_json).unwrap(), a);
    }

    #[test]
    fn comm_keys_resolve_and_apply() {
        let space = ParamSpace::shared();
        let mut cfg = ExperimentCfg::default();
        for spec in ["comm.up_mbps=20", "comm.down_mbps=100", "comm.latency_secs=0.05"] {
            let b = Binding::parse(space, spec).unwrap();
            space.resolve(&b.key).unwrap().apply(&mut cfg, &b.value).unwrap();
        }
        assert_eq!(cfg.comm_up_mbps, 20.0);
        assert_eq!(cfg.comm_down_mbps, 100.0);
        assert_eq!(cfg.comm_latency_secs, 0.05);
        assert!(Binding::parse(space, "comm.up_mbps=-1").is_err());
        // sweepable like any other key
        let axis = SweepAxis::parse(space, "comm.up_mbps=5,50").unwrap();
        assert_eq!(axis.values.len(), 2);
    }

    #[test]
    fn async_strategy_tunables_are_registered_keys() {
        let space = ParamSpace::shared();
        assert!(space.resolve("strategy.fedasync.alpha").is_ok());
        assert!(space.resolve("strategy.fedasync.staleness_exp").is_ok());
        assert!(space.resolve("strategy.fedbuff.buffer_k").is_ok());
        assert!(Binding::parse(space, "strategy.fedbuff.buffer_k=0.5").is_err());
    }

    #[test]
    fn fleet_keys_resolve_apply_and_validate() {
        let space = ParamSpace::shared();
        let mut cfg = ExperimentCfg::default();
        for spec in [
            "fleet.trace=devices.jsonl",
            "fleet.sample=128",
            "fleet.churn.dropout=0.25",
            "fleet.churn.period_secs=3600",
            "fleet.churn.avail_frac=0.8",
        ] {
            let b = Binding::parse(space, spec).unwrap();
            assert_eq!(b.render(), *spec, "canonical rendering");
            space.resolve(&b.key).unwrap().apply(&mut cfg, &b.value).unwrap();
        }
        assert_eq!(cfg.fleet_trace, "devices.jsonl");
        assert_eq!(cfg.fleet_sample, 128);
        assert_eq!(cfg.churn_dropout, 0.25);
        assert_eq!(cfg.churn_period_secs, 3600.0);
        assert_eq!(cfg.churn_avail_frac, 0.8);
        // bounds: dropout in [0,1), avail_frac in (0,1]
        assert!(Binding::parse(space, "fleet.churn.dropout=1").is_err());
        assert!(Binding::parse(space, "fleet.churn.dropout=-0.1").is_err());
        assert!(Binding::parse(space, "fleet.churn.avail_frac=0").is_err());
        assert!(Binding::parse(space, "fleet.churn.avail_frac=1.5").is_err());
        assert!(Binding::parse(space, "fleet.churn.period_secs=-1").is_err());
        // fleet.sample=0 is legal: the legacy full fan-out
        assert!(Binding::parse(space, "fleet.sample=0").is_ok());
        // the lazy fleet spec flows through the existing `fleet` key
        let b = Binding::parse(space, "fleet=lazy100000:lognormal:0:0.5").unwrap();
        space.resolve(&b.key).unwrap().apply(&mut cfg, &b.value).unwrap();
        assert!(matches!(cfg.fleet, FleetSpec::Lazy { n: 100_000, .. }));
        // churn keys sweep like any F64 key; ';' and ',' both separate
        let axis = SweepAxis::parse(space, "fleet.churn.dropout=0,0.1,0.3").unwrap();
        assert_eq!(axis.values.len(), 3);
        let semi = SweepAxis::parse(space, "fleet.churn.dropout=0;0.1;0.3").unwrap();
        assert_eq!(semi, axis);
    }

    #[test]
    fn speculate_depth_key_resolves_and_applies() {
        let space = ParamSpace::shared();
        let mut cfg = ExperimentCfg::default();
        let b = Binding::parse(space, "exec.speculate.depth=4").unwrap();
        assert_eq!(b.render(), "exec.speculate.depth=4", "canonical rendering");
        space.resolve(&b.key).unwrap().apply(&mut cfg, &b.value).unwrap();
        assert_eq!(cfg.exec_speculate_depth, 4);
        // 0 is legal: speculation off (the serial reference)
        assert!(Binding::parse(space, "exec.speculate.depth=0").is_ok());
        assert!(Binding::parse(space, "exec.speculate.depth=-1").is_err());
        assert!(Binding::parse(space, "exec.speculate.depth=2.5").is_err());
        // sweepable like any other key
        let axis = SweepAxis::parse(space, "exec.speculate.depth=0,4,16").unwrap();
        assert_eq!(axis.values.len(), 3);
    }

    #[test]
    fn halving_keys_resolve_apply_and_validate() {
        let space = ParamSpace::shared();
        let mut cfg = ExperimentCfg::default();
        for spec in [
            "operator.halving.rungs=3",
            "operator.halving.keep_frac=0.25",
            "operator.halving.metric=loss",
        ] {
            let b = Binding::parse(space, spec).unwrap();
            assert_eq!(b.render(), *spec, "canonical rendering");
            space.resolve(&b.key).unwrap().apply(&mut cfg, &b.value).unwrap();
        }
        assert_eq!(cfg.halving_rungs, 3);
        assert_eq!(cfg.halving_keep_frac, 0.25);
        assert_eq!(cfg.halving_metric, "loss");
        // rungs=0 is legal: halving off
        assert!(Binding::parse(space, "operator.halving.rungs=0").is_ok());
        assert!(Binding::parse(space, "operator.halving.keep_frac=0").is_err());
        assert!(Binding::parse(space, "operator.halving.keep_frac=1.5").is_err());
        assert!(Binding::parse(space, "operator.halving.metric=bogus").is_err());
    }

    #[test]
    fn strategy_param_get_reads_bag_or_default() {
        let space = ParamSpace::shared();
        let def = space.resolve("strategy.pyramidfl.frac").unwrap();
        let mut cfg = ExperimentCfg::default();
        assert_eq!(def.get(&cfg), ParamValue::F64(0.6));
        def.apply(&mut cfg, &ParamValue::F64(0.8)).unwrap();
        assert_eq!(def.get(&cfg), ParamValue::F64(0.8));
    }
}
