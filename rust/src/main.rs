//! fedel — the FedEL coordinator CLI.
//!
//! Subcommands:
//!   train    — run one FL experiment and print the round log + summary
//!   compare  — run several strategies on one workload, print a table
//!   runs     — the persistent run store: list / show / resume / compare
//!   inspect  — dump a model manifest summary
//!   list     — list AOT-compiled models under artifacts/
//!
//! Examples:
//!   fedel train --model mlp --strategy fedel --fleet small10 --rounds 40
//!   fedel train --model mock:8x100 --threads 1 --jsonl rounds.jsonl
//!   fedel train --model mock:8x100 --store runs --checkpoint-every 5
//!   fedel train --model mock:8x100 --store runs --warm-start fedel-s42
//!   fedel runs list --store runs
//!   fedel runs resume fedel-s42 --store runs
//!   fedel runs compare fedel-s42 fedavg-s42 --store runs
//!   fedel compare --model mock:8x100 --strategies fedavg,fedel --rounds 20
//!   fedel inspect --model vgg_cifar

use std::path::Path;

use fedel::config::ExperimentCfg;
use fedel::fl::observer::{ConsoleObserver, JsonlObserver, ObserverSet};
use fedel::fl::server::ResumeState;
use fedel::manifest;
use fedel::report::{render_table1, runs_compare, table1_rows, Table};
use fedel::sim::experiment::{resume_run, Experiment};
use fedel::store::checkpoint::CheckpointObserver;
use fedel::store::schema::RunStatus;
use fedel::store::RunStore;
use fedel::util::cli::Args;

fn main() {
    let args = Args::from_env(true);
    let code = match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("compare") => cmd_compare(&args),
        Some("runs") => cmd_runs(&args),
        Some("inspect") => cmd_inspect(&args),
        Some("list") => cmd_list(&args),
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand {o:?}");
            }
            eprintln!("usage: fedel <train|compare|runs|inspect|list> [--key value ...]");
            Err(anyhow::anyhow!("bad usage"))
        }
    }
    .map(|_| 0)
    .unwrap_or_else(|e| {
        eprintln!("error: {e:#}");
        1
    });
    std::process::exit(code);
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let mut cfg = ExperimentCfg::from_args(args)?;
    cfg.verbose = true;
    let out_json = args.get("out").map(|s| s.to_string());
    let out_jsonl = args.get("jsonl").map(|s| s.to_string());
    let store_dir = args.get("store").map(|s| s.to_string());
    let every = args.usize_or("checkpoint-every", 5);
    let warm = args.get("warm-start").map(|s| s.to_string());
    args.check_unused()?;
    println!("config: {}", cfg.to_json());
    let t0 = std::time::Instant::now();
    let mut exp = Experiment::build(cfg)?;

    // Optional persistence: a run store makes the experiment durable
    // (checkpointed every k rounds, resumable via `runs resume`) and lets
    // --warm-start seed the global model from any stored run.
    let store = store_dir.map(RunStore::open).transpose()?;
    let strategy_name = exp.cfg.strategy.clone();
    let mut ckpt = match &store {
        Some(s) => {
            let c = CheckpointObserver::create(s, &exp.cfg, &strategy_name, every)?;
            println!("run id: {} (store {})", c.run_id(), s.root().display());
            Some(c)
        }
        None => None,
    };
    let resume = match &warm {
        Some(src) => {
            let s = store
                .as_ref()
                .ok_or_else(|| anyhow::anyhow!("--warm-start needs --store"))?;
            println!("warm start: seeding global model from run {src}");
            Some(ResumeState::warm_start(s.latest_params(src)?))
        }
        None => None,
    };

    // A failed round log must not discard a completed run: remember the
    // error, print the results regardless, and fail the exit code at the
    // end.
    let mut log_err: Option<String> = None;
    let mut jsonl = match &out_jsonl {
        Some(path) => Some(JsonlObserver::create(Path::new(path))?),
        None => None,
    };
    let res = {
        let mut observers = ObserverSet::new();
        if let Some(j) = jsonl.as_mut() {
            observers.push(j);
        }
        if let Some(c) = ckpt.as_mut() {
            observers.push(c);
        }
        exp.run_from(None, &mut observers, resume)?
    };
    if let (Some(j), Some(path)) = (jsonl.as_mut(), &out_jsonl) {
        match j.take_error() {
            Some(e) => log_err = Some(format!("writing {path}: {e}")),
            None => println!("round log streamed to {path}"),
        }
    }
    if let Some(c) = ckpt.as_mut() {
        if let Some(e) = c.take_error() {
            log_err.get_or_insert(format!("checkpointing run {}: {e}", c.run_id()));
        }
    }
    println!(
        "\n{}: {} rounds, simulated {}, final acc {:.2}% (ppl {:.2}), wall {:.1}s",
        res.strategy,
        res.records.len(),
        fedel::util::fmt_hours(res.sim_total_secs),
        100.0 * res.final_acc,
        res.final_perplexity(),
        t0.elapsed().as_secs_f64()
    );
    if let Some(path) = out_json {
        // The store's result schema, with the config snapshot spliced in
        // for provenance.
        let mut j = res.to_json();
        if let fedel::util::json::Json::Obj(kv) = &mut j {
            kv.insert(0, ("config".to_string(), exp.cfg.to_json()));
        }
        std::fs::write(&path, j.to_string_pretty())?;
        println!("wrote {path}");
    }
    if let Some(e) = log_err {
        anyhow::bail!("run output lost: {e}");
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> anyhow::Result<()> {
    let cfg = ExperimentCfg::from_args(args)?;
    let strategies = args.list_or("strategies", &["fedavg", "fedel"]);
    args.check_unused()?;
    let mut exp = Experiment::build(cfg)?;
    let mut results = Vec::new();
    for s in &strategies {
        eprintln!("running {s}...");
        results.push(exp.run(Some(s))?);
    }
    let lm = exp.ctx.manifest.task == manifest::Task::Lm;
    let rows = table1_rows(&results, 0.95, lm);
    render_table1(
        &format!("compare: {} on {}", strategies.join(","), exp.cfg.model),
        &rows,
        lm,
    )
    .print();
    Ok(())
}

/// The run-store subcommand family: `runs <list|show|resume|compare> ...`.
fn cmd_runs(args: &Args) -> anyhow::Result<()> {
    let store = RunStore::open(args.str_or("store", "runs"))?;
    let action = args.positional.first().map(|s| s.as_str()).unwrap_or("list");
    match action {
        "list" => {
            args.check_unused()?;
            let runs = store.list()?;
            if runs.is_empty() {
                println!("no stored runs under {}", store.root().display());
                return Ok(());
            }
            let mut t = Table::new(
                &format!("runs ({})", store.root().display()),
                &["id", "strategy", "model", "status", "rounds", "final acc", "sim total"],
            );
            for m in &runs {
                let status = match (m.status, &m.checkpoint) {
                    (RunStatus::Running, Some(_)) => "resumable".to_string(),
                    (s, _) => s.as_str().to_string(),
                };
                t.row(vec![
                    m.id.clone(),
                    m.strategy.clone(),
                    m.config.model.clone(),
                    status,
                    format!("{}/{}", m.records.len(), m.config.rounds),
                    m.final_acc()
                        .map(|a| format!("{:.2}%", 100.0 * a))
                        .unwrap_or_else(|| "n/a".into()),
                    fedel::util::fmt_hours(m.sim_time()),
                ]);
            }
            t.print();
        }
        "show" => {
            let id = run_id_arg(args, "show")?;
            args.check_unused()?;
            let m = store.load_manifest(&id)?;
            println!("run {} [{}]", m.id, m.status.as_str());
            println!("config: {}", m.config.to_json());
            if let Some(ck) = &m.checkpoint {
                println!(
                    "checkpoint: round {} @ {} ({})",
                    ck.completed,
                    fedel::util::fmt_hours(ck.sim_time),
                    ck.params.digest
                );
            }
            if let Some(f) = &m.final_state {
                println!(
                    "final: acc {:.2}%, loss {:.4}, simulated {} ({})",
                    100.0 * f.final_acc,
                    f.final_loss,
                    fedel::util::fmt_hours(f.sim_total_secs),
                    f.params.digest
                );
            }
            let mut t = Table::new("eval curve", &["round", "sim time", "acc", "loss"]);
            for r in m.records.iter().filter(|r| r.eval_acc.is_some()) {
                t.row(vec![
                    format!("{}", r.round),
                    fedel::util::fmt_hours(r.sim_time),
                    format!("{:.4}", r.eval_acc.unwrap_or(0.0)),
                    format!("{:.4}", r.eval_loss.unwrap_or(0.0)),
                ]);
            }
            t.print();
        }
        "resume" => {
            let id = run_id_arg(args, "resume")?;
            let every = args.usize_or("checkpoint-every", 5);
            args.check_unused()?;
            let mut console = ConsoleObserver::new(&format!("resume:{id}"));
            let res = resume_run(&store, &id, every, &mut console)?;
            println!(
                "run {id} resumed to completion: {} rounds, simulated {}, final acc {:.2}%",
                res.records.len(),
                fedel::util::fmt_hours(res.sim_total_secs),
                100.0 * res.final_acc
            );
        }
        "compare" => {
            let (a, b) = match &args.positional[..] {
                [_, a, b] => (a.clone(), b.clone()),
                _ => anyhow::bail!("usage: fedel runs compare <run-a> <run-b> [--target acc]"),
            };
            let target = args.get("target").and_then(|s| s.parse().ok());
            args.check_unused()?;
            let ma = store.load_manifest(&a)?;
            let mb = store.load_manifest(&b)?;
            let (table, speedup) = runs_compare(&ma, &mb, target);
            table.print();
            match speedup {
                Some(s) => println!("time-to-accuracy: {a} is {s:.2}x vs {b}"),
                None => println!("time-to-accuracy: at least one run never reaches the target"),
            }
        }
        other => anyhow::bail!("unknown runs action {other:?} (list | show | resume | compare)"),
    }
    Ok(())
}

fn run_id_arg(args: &Args, action: &str) -> anyhow::Result<String> {
    args.positional
        .get(1)
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("usage: fedel runs {action} <run-id> [--store dir]"))
}

fn cmd_inspect(args: &Args) -> anyhow::Result<()> {
    let model = args.str_or("model", "mlp");
    let dir = args.str_or("artifacts", "artifacts");
    args.check_unused()?;
    let m = manifest::Manifest::load(Path::new(&dir).join(&model).as_path())?;
    println!(
        "model {} — task {:?}, {} params, {} tensors, {} blocks, batch {}",
        m.model, m.task, m.param_count, m.tensors.len(), m.num_blocks, m.batch
    );
    let mut t = Table::new("blocks", &["block", "tensors", "params", "MFLOPs(fwd/ex)"]);
    for b in &m.blocks {
        let params: usize = b.tensor_ids.iter().map(|&i| m.tensors[i].size).sum();
        t.row(vec![
            format!("{}", b.id),
            format!("{}", b.tensor_ids.len()),
            format!("{}", params),
            format!("{:.2}", b.flops_fwd / 1e6),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_list(args: &Args) -> anyhow::Result<()> {
    let dir = args.str_or("artifacts", "artifacts");
    args.check_unused()?;
    let models = manifest::discover(Path::new(&dir))?;
    if models.is_empty() {
        println!("no models under {dir}/ — run `make artifacts`");
        return Ok(());
    }
    let mut t = Table::new("models", &["name", "task", "params", "blocks", "batch"]);
    for m in &models {
        t.row(vec![
            m.model.clone(),
            format!("{:?}", m.task),
            format!("{}", m.param_count),
            format!("{}", m.num_blocks),
            format!("{}", m.batch),
        ]);
    }
    t.print();
    Ok(())
}
