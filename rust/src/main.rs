//! fedel — the FedEL coordinator CLI.
//!
//! Subcommands:
//!   train    — run one FL experiment and print the round log + summary
//!   compare  — run several strategies on one workload, print a table
//!   inspect  — dump a model manifest summary
//!   list     — list AOT-compiled models under artifacts/
//!
//! Examples:
//!   fedel train --model mlp --strategy fedel --fleet small10 --rounds 40
//!   fedel train --model mock:8x100 --threads 1 --jsonl rounds.jsonl
//!   fedel compare --model mock:8x100 --strategies fedavg,fedel --rounds 20
//!   fedel inspect --model vgg_cifar

use std::path::Path;

use fedel::config::ExperimentCfg;
use fedel::fl::observer::JsonlObserver;
use fedel::manifest;
use fedel::report::{render_table1, table1_rows, Table};
use fedel::sim::experiment::Experiment;
use fedel::util::cli::Args;

fn main() {
    let args = Args::from_env(true);
    let code = match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("compare") => cmd_compare(&args),
        Some("inspect") => cmd_inspect(&args),
        Some("list") => cmd_list(&args),
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand {o:?}");
            }
            eprintln!("usage: fedel <train|compare|inspect|list> [--key value ...]");
            Err(anyhow::anyhow!("bad usage"))
        }
    }
    .map(|_| 0)
    .unwrap_or_else(|e| {
        eprintln!("error: {e:#}");
        1
    });
    std::process::exit(code);
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let mut cfg = ExperimentCfg::from_args(args)?;
    cfg.verbose = true;
    let out_json = args.get("out").map(|s| s.to_string());
    let out_jsonl = args.get("jsonl").map(|s| s.to_string());
    args.check_unused()?;
    println!("config: {}", cfg.to_json());
    let t0 = std::time::Instant::now();
    let mut exp = Experiment::build(cfg)?;
    // A failed round log must not discard a completed run: remember the
    // error, print the results regardless, and fail the exit code at the
    // end.
    let mut log_err: Option<String> = None;
    let res = if let Some(path) = &out_jsonl {
        let mut jsonl = JsonlObserver::create(Path::new(path))?;
        let res = exp.run_observed(None, &mut jsonl)?;
        match jsonl.take_error() {
            Some(e) => log_err = Some(format!("writing {path}: {e}")),
            None => println!("round log streamed to {path}"),
        }
        res
    } else {
        exp.run(None)?
    };
    println!(
        "\n{}: {} rounds, simulated {}, final acc {:.2}% (ppl {:.2}), wall {:.1}s",
        res.strategy,
        res.records.len(),
        fedel::util::fmt_hours(res.sim_total_secs),
        100.0 * res.final_acc,
        res.final_perplexity(),
        t0.elapsed().as_secs_f64()
    );
    if let Some(path) = out_json {
        let curve: Vec<_> = res
            .acc_curve()
            .iter()
            .map(|&(t, a)| fedel::util::json::Json::from_f64s(&[t, a]))
            .collect();
        let j = fedel::util::json::Json::obj(vec![
            ("strategy", fedel::util::json::Json::Str(res.strategy.clone())),
            ("config", exp.cfg.to_json()),
            ("final_acc", fedel::util::json::Json::Num(res.final_acc)),
            ("sim_total_secs", fedel::util::json::Json::Num(res.sim_total_secs)),
            ("acc_curve", fedel::util::json::Json::Arr(curve)),
        ]);
        std::fs::write(&path, j.to_string_pretty())?;
        println!("wrote {path}");
    }
    if let Some(e) = log_err {
        anyhow::bail!("round log lost: {e}");
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> anyhow::Result<()> {
    let cfg = ExperimentCfg::from_args(args)?;
    let strategies = args.list_or("strategies", &["fedavg", "fedel"]);
    args.check_unused()?;
    let mut exp = Experiment::build(cfg)?;
    let mut results = Vec::new();
    for s in &strategies {
        eprintln!("running {s}...");
        results.push(exp.run(Some(s))?);
    }
    let lm = exp.ctx.manifest.task == manifest::Task::Lm;
    let rows = table1_rows(&results, 0.95, lm);
    render_table1(
        &format!("compare: {} on {}", strategies.join(","), exp.cfg.model),
        &rows,
        lm,
    )
    .print();
    Ok(())
}

fn cmd_inspect(args: &Args) -> anyhow::Result<()> {
    let model = args.str_or("model", "mlp");
    let dir = args.str_or("artifacts", "artifacts");
    args.check_unused()?;
    let m = manifest::Manifest::load(Path::new(&dir).join(&model).as_path())?;
    println!(
        "model {} — task {:?}, {} params, {} tensors, {} blocks, batch {}",
        m.model, m.task, m.param_count, m.tensors.len(), m.num_blocks, m.batch
    );
    let mut t = Table::new("blocks", &["block", "tensors", "params", "MFLOPs(fwd/ex)"]);
    for b in &m.blocks {
        let params: usize = b.tensor_ids.iter().map(|&i| m.tensors[i].size).sum();
        t.row(vec![
            format!("{}", b.id),
            format!("{}", b.tensor_ids.len()),
            format!("{}", params),
            format!("{:.2}", b.flops_fwd / 1e6),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_list(args: &Args) -> anyhow::Result<()> {
    let dir = args.str_or("artifacts", "artifacts");
    args.check_unused()?;
    let models = manifest::discover(Path::new(&dir))?;
    if models.is_empty() {
        println!("no models under {dir}/ — run `make artifacts`");
        return Ok(());
    }
    let mut t = Table::new("models", &["name", "task", "params", "blocks", "batch"]);
    for m in &models {
        t.row(vec![
            m.model.clone(),
            format!("{:?}", m.task),
            format!("{}", m.param_count),
            format!("{}", m.num_blocks),
            format!("{}", m.batch),
        ]);
    }
    t.print();
    Ok(())
}
