//! fedel — the FedEL coordinator CLI.
//!
//! Subcommands:
//!   train    — run one FL experiment and print the round log + summary
//!   compare  — run several strategies on one workload, print a table
//!   runs     — the persistent run store: list / show / resume / compare / gc
//!   campaign — grids of stored runs: run / operate / edit / status / report
//!   inspect  — dump a model manifest summary
//!   fleet    — summarize the device fleet a config would run with
//!   list     — list AOT-compiled models under artifacts/
//!
//! Examples:
//!   fedel train --model mlp --strategy fedel --fleet small10 --rounds 40
//!   fedel train --model mock:8x100 --set strategy.fedel.harmonize_weight=0.4
//!   fedel train --list-strategies
//!   fedel train --model mock:8x100 --threads 1 --jsonl rounds.jsonl
//!   fedel train --model mock:8x100 --store runs --checkpoint-every 5 --checkpoint-secs 300
//!   fedel train --model mock:8x100 --store runs --warm-start fedel-s42
//!   fedel runs list --store runs
//!   fedel runs resume fedel-s42 --store runs
//!   fedel runs compare fedel-s42 timelyfl-s42 fedavg-s42 --store runs --json -
//!   fedel runs gc --store runs
//!   fedel campaign run --name sweep --store runs --model mock:8x100 \
//!       --sweep strategy=fedavg,fedel --sweep seed=1,2,3 \
//!       --sweep data.alpha=0.1,0.5 --rounds 20
//!   fedel campaign run --name async --store runs --model mock:8x100 \
//!       --sweep strategy=fedavg,fedel,fedbuff --rounds 20 \
//!       --set comm.up_mbps=20 --set comm.down_mbps=100
//!   fedel campaign run --name sweep --store runs        # resume after a kill
//!   fedel campaign run --name paired --store runs --model mock:8x100 \
//!       --zip strategy=fedavg,fedel --zip time.t_th_factor=1.0,0.8 --rounds 20
//!   fedel campaign report --name sweep --store runs --over seed --json report.json
//!   fedel campaign report --name sweep --store runs --over seed,fleet
//!   fedel runs serve --root runs --addr 0.0.0.0:7878 --upload-gc-secs 900
//!   fedel campaign run --name sweep --store http://hub:7878   # remote worker
//!   fedel campaign operate --name sweep --store http://hub:7878 \
//!       --worker host1:1 --lease-secs 30        # reconcile-loop worker
//!   fedel campaign operate --name halve --store runs --model mock:8x100 \
//!       --sweep strategy=fedavg,fedel --sweep seed=1,2,3 --rounds 20 \
//!       --set operator.halving.rungs=2           # adaptive halving sweep
//!   fedel campaign edit --name sweep --store runs --sweep seed=+4,+5
//!   fedel campaign status --name sweep --store runs --json
//!   fedel compare --model mock:8x100 --strategies fedavg,fedel --rounds 20
//!   fedel inspect --model vgg_cifar

use std::path::Path;
use std::time::Duration;

use fedel::config::params::ParamSpace;
use fedel::config::ExperimentCfg;
use fedel::fl::observer::{ConsoleObserver, JsonlObserver, ObserverSet};
use fedel::fl::server::ResumeState;
use fedel::manifest;
use fedel::report::{
    compare_runs, render_table1, table1_rows, CompareReport, GroupedReport, Table, Target,
};
use fedel::sim::campaign::{self, CampaignCfg};
use fedel::sim::experiment::{resume_run, Experiment};
use fedel::store::checkpoint::CheckpointObserver;
use fedel::store::schema::RunStatus;
use fedel::store::RunStore;
use fedel::strategies::registry;
use fedel::util::cli::Args;

fn main() {
    let args = Args::from_env(true);
    let code = match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("compare") => cmd_compare(&args),
        Some("runs") => cmd_runs(&args),
        Some("campaign") => cmd_campaign(&args),
        Some("inspect") => cmd_inspect(&args),
        Some("fleet") => cmd_fleet(&args),
        Some("list") => cmd_list(&args),
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand {o:?}");
            }
            eprintln!(
                "usage: fedel <train|compare|runs|campaign|inspect|fleet|list> [--key value ...]"
            );
            Err(anyhow::anyhow!("bad usage"))
        }
    }
    .map(|_| 0)
    .unwrap_or_else(|e| {
        eprintln!("error: {e:#}");
        1
    });
    std::process::exit(code);
}

/// Print the strategy registry (names, declared tunables, summaries) and
/// every sweepable parameter key.
fn list_strategies() {
    let mut t = Table::new("registered strategies", &["name", "tunables", "summary"]);
    for def in registry::builtin().defs() {
        let params = if def.params.is_empty() {
            "-".to_string()
        } else {
            def.params
                .iter()
                .map(|p| format!("{}={} [{}..{}]", p.name, p.default, p.min, p.max))
                .collect::<Vec<_>>()
                .join(", ")
        };
        t.row(vec![def.name.to_string(), params, def.summary.to_string()]);
    }
    t.print();
    let mut k = Table::new(
        "parameter keys (--set key=value; campaign run --sweep key=v1,v2)",
        &["key", "type", "help"],
    );
    for def in ParamSpace::shared().keys() {
        k.row(vec![def.key.clone(), def.ty.as_str().to_string(), def.help.clone()]);
    }
    k.print();
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    if args.flag("list-strategies") {
        args.check_unused()?;
        list_strategies();
        return Ok(());
    }
    let mut cfg = ExperimentCfg::from_args(args)?;
    cfg.verbose = true;
    let out_json = args.get("out").map(|s| s.to_string());
    let out_jsonl = args.get("jsonl").map(|s| s.to_string());
    let store_dir = args.get("store").map(|s| s.to_string());
    let every = args.usize_or("checkpoint-every", 5);
    let ckpt_secs = parse_opt_f64(args, "checkpoint-secs")?;
    let warm = args.get("warm-start").map(|s| s.to_string());
    args.check_unused()?;
    println!("config: {}", cfg.to_json());
    let t0 = std::time::Instant::now();
    let mut exp = Experiment::build(cfg)?;

    // Optional persistence: a run store makes the experiment durable
    // (checkpointed every k rounds, resumable via `runs resume`) and lets
    // --warm-start seed the global model from any stored run.
    let store = store_dir.map(RunStore::open).transpose()?;
    let strategy_name = exp.cfg.strategy.clone();
    let mut ckpt = match &store {
        Some(s) => {
            let c = CheckpointObserver::create(s, &exp.cfg, &strategy_name, every)?
                .every_secs(ckpt_secs);
            println!("run id: {} (store {})", c.run_id(), s.location());
            Some(c)
        }
        None => None,
    };
    let resume = match &warm {
        Some(src) => {
            let s = store
                .as_ref()
                .ok_or_else(|| anyhow::anyhow!("--warm-start needs --store"))?;
            println!("warm start: seeding global model from run {src}");
            Some(ResumeState::warm_start(s.latest_params(src)?))
        }
        None => None,
    };

    // A failed round log must not discard a completed run: remember the
    // error, print the results regardless, and fail the exit code at the
    // end.
    let mut log_err: Option<String> = None;
    let mut jsonl = match &out_jsonl {
        Some(path) => Some(JsonlObserver::create(Path::new(path))?),
        None => None,
    };
    let res = {
        let mut observers = ObserverSet::new();
        if let Some(j) = jsonl.as_mut() {
            observers.push(j);
        }
        if let Some(c) = ckpt.as_mut() {
            observers.push(c);
        }
        exp.run_from(None, &mut observers, resume)?
    };
    if let (Some(j), Some(path)) = (jsonl.as_mut(), &out_jsonl) {
        match j.take_error() {
            Some(e) => log_err = Some(format!("writing {path}: {e}")),
            None => println!("round log streamed to {path}"),
        }
    }
    if let Some(c) = ckpt.as_mut() {
        if let Some(e) = c.take_error() {
            log_err.get_or_insert(format!("checkpointing run {}: {e}", c.run_id()));
        }
    }
    println!(
        "\n{}: {} rounds, simulated {}, final acc {:.2}% (ppl {:.2}), wall {:.1}s",
        res.strategy,
        res.records.len(),
        fedel::util::fmt_hours(res.sim_total_secs),
        100.0 * res.final_acc,
        res.final_perplexity(),
        t0.elapsed().as_secs_f64()
    );
    if let Some(path) = out_json {
        // The store's result schema, with the config snapshot spliced in
        // for provenance.
        let mut j = res.to_json();
        if let fedel::util::json::Json::Obj(kv) = &mut j {
            kv.insert(0, ("config".to_string(), exp.cfg.to_json()));
        }
        std::fs::write(&path, j.to_string_pretty())?;
        println!("wrote {path}");
    }
    if let Some(e) = log_err {
        anyhow::bail!("run output lost: {e}");
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> anyhow::Result<()> {
    let cfg = ExperimentCfg::from_args(args)?;
    let strategies = args.list_or("strategies", &["fedavg", "fedel"]);
    args.check_unused()?;
    let mut exp = Experiment::build(cfg)?;
    let mut results = Vec::new();
    for s in &strategies {
        eprintln!("running {s}...");
        results.push(exp.run(Some(s))?);
    }
    let lm = exp.ctx.manifest.task == manifest::Task::Lm;
    let rows = table1_rows(&results, 0.95, lm);
    render_table1(
        &format!("compare: {} on {}", strategies.join(","), exp.cfg.model),
        &rows,
        lm,
    )
    .print();
    Ok(())
}

/// The run-store subcommand family:
/// `runs <list|show|resume|compare|gc|serve> ...`.
fn cmd_runs(args: &Args) -> anyhow::Result<()> {
    let action = args.positional.first().map(|s| s.as_str()).unwrap_or("list");
    if action == "serve" {
        // Serve a *local* store directory over http for remote workers;
        // --root falls back to --store so either spelling works.
        let root = args
            .get("root")
            .map(|s| s.to_string())
            .unwrap_or_else(|| args.str_or("store", "runs"));
        anyhow::ensure!(
            !root.starts_with("http://") && !root.starts_with("https://"),
            "runs serve exposes a local directory — --root {root:?} is already a URL"
        );
        let addr = args.str_or("addr", "127.0.0.1:7878");
        let threads = args.usize_or("threads", 4);
        // Abandoned upload sessions are swept once untouched this long.
        let gc_secs = args.u64_or("upload-gc-secs", 900);
        args.check_unused()?;
        let server = fedel::store::backend::serve::StoreServer::start_with_upload_gc(
            &root,
            &addr,
            threads,
            Duration::from_secs(gc_secs),
        )?;
        println!(
            "serving store {root} on http://{} — point workers at --store http://{}",
            server.addr(),
            server.addr()
        );
        return server.serve_forever();
    }
    let store = RunStore::open(args.str_or("store", "runs"))?;
    match action {
        "list" => {
            args.check_unused()?;
            let runs = store.list()?;
            if runs.is_empty() {
                println!("no stored runs under {}", store.location());
                return Ok(());
            }
            let mut t = Table::new(
                &format!("runs ({})", store.location()),
                &["id", "strategy", "model", "status", "rounds", "final acc", "sim total"],
            );
            for m in &runs {
                let status = match (m.status, &m.checkpoint) {
                    (RunStatus::Running, Some(_)) => "resumable".to_string(),
                    (s, _) => s.as_str().to_string(),
                };
                t.row(vec![
                    m.id.clone(),
                    m.strategy.clone(),
                    m.config.model.clone(),
                    status,
                    format!("{}/{}", m.records.len(), m.config.rounds),
                    m.final_acc()
                        .map(|a| format!("{:.2}%", 100.0 * a))
                        .unwrap_or_else(|| "n/a".into()),
                    fedel::util::fmt_hours(m.sim_time()),
                ]);
            }
            t.print();
        }
        "show" => {
            let id = run_id_arg(args, "show")?;
            args.check_unused()?;
            let m = store.load_manifest(&id)?;
            println!("run {} [{}]", m.id, m.status.as_str());
            println!("config: {}", m.config.to_json());
            if let Some(ck) = &m.checkpoint {
                println!(
                    "checkpoint: round {} @ {} ({})",
                    ck.completed,
                    fedel::util::fmt_hours(ck.sim_time),
                    ck.params.digest
                );
            }
            if let Some(f) = &m.final_state {
                println!(
                    "final: acc {:.2}%, loss {:.4}, simulated {} ({})",
                    100.0 * f.final_acc,
                    f.final_loss,
                    fedel::util::fmt_hours(f.sim_total_secs),
                    f.params.digest
                );
            }
            // Availability churn leaves its mark on the records; surface
            // the total so an unexpectedly quiet run is visible at a glance.
            let dropped: usize = m.records.iter().map(|r| r.dropped.len()).sum();
            if dropped > 0 {
                println!(
                    "churn: {dropped} dropped client uploads across {} rounds",
                    m.records.len()
                );
            }
            // Speculative dispatch accounting: hits rode the prediction,
            // misses re-executed at the true version.
            let hits: usize = m.records.iter().map(|r| r.spec_hits).sum();
            let misses: usize = m.records.iter().map(|r| r.spec_misses).sum();
            if hits + misses > 0 {
                println!("speculation: {hits} hits, {misses} misses (re-executed)");
            }
            // Async runs (fedasync/fedbuff) record per-aggregation
            // staleness; show the column only when it exists.
            let has_staleness = m.records.iter().any(|r| r.mean_staleness.is_some());
            let mut headers = vec!["round", "sim time", "acc", "loss"];
            if has_staleness {
                headers.push("staleness (mean/max)");
            }
            let mut t = Table::new("eval curve", &headers);
            for r in m.records.iter().filter(|r| r.eval_acc.is_some()) {
                let mut row = vec![
                    format!("{}", r.round),
                    fedel::util::fmt_hours(r.sim_time),
                    format!("{:.4}", r.eval_acc.unwrap_or(0.0)),
                    format!("{:.4}", r.eval_loss.unwrap_or(0.0)),
                ];
                if has_staleness {
                    row.push(match (r.mean_staleness, r.max_staleness) {
                        (Some(mean), Some(max)) => format!("{mean:.2}/{max:.0}"),
                        _ => "-".to_string(),
                    });
                }
                t.row(row);
            }
            t.print();
        }
        "resume" => {
            let id = run_id_arg(args, "resume")?;
            let every = args.usize_or("checkpoint-every", 5);
            args.check_unused()?;
            let mut console = ConsoleObserver::new(&format!("resume:{id}"));
            let res = resume_run(&store, &id, every, &mut console)?;
            println!(
                "run {id} resumed to completion: {} rounds, simulated {}, final acc {:.2}%",
                res.records.len(),
                fedel::util::fmt_hours(res.sim_total_secs),
                100.0 * res.final_acc
            );
        }
        "compare" => {
            let ids = &args.positional[1..];
            let target = target_from_args(args)?;
            let json_out = args.get("json").map(|s| s.to_string());
            args.check_unused()?;
            anyhow::ensure!(
                ids.len() >= 2,
                "usage: fedel runs compare <run-a> <run-b> [<run-c> ...] \
                 [--target acc | --target-loss loss] [--json path|-]\n\
                 (speedups are reported vs the LAST run listed)"
            );
            let mut manifests = Vec::with_capacity(ids.len());
            for id in ids {
                manifests.push(store.load_manifest(id).map_err(|_| {
                    anyhow::anyhow!(
                        "unknown run id {id:?} under {} — `fedel runs list` shows what's stored",
                        store.location()
                    )
                })?);
            }
            let refs: Vec<&fedel::store::schema::RunManifest> = manifests.iter().collect();
            let report = compare_runs(&refs, target, refs.len() - 1);
            emit_compare_report(&report, json_out.as_deref())?;
        }
        "gc" => {
            let dry = args.flag("dry-run");
            let min_age = args.u64_or("min-age-secs", 60);
            args.check_unused()?;
            let r = store.gc_blobs(Duration::from_secs(min_age), dry)?;
            println!(
                "gc {}: {} live blob(s) kept, {} orphan(s){} ({} bytes)",
                store.location(),
                r.live,
                r.swept,
                if dry { " would be swept (--dry-run)" } else { " swept" },
                r.swept_bytes
            );
        }
        other => {
            anyhow::bail!(
                "unknown runs action {other:?} (list | show | resume | compare | gc | serve)"
            )
        }
    }
    Ok(())
}

/// Parse an optional f64 option loudly (a typo'd value must not silently
/// fall back to a default).
fn parse_opt_f64(args: &Args, key: &str) -> anyhow::Result<Option<f64>> {
    args.get(key)
        .map(|s| {
            s.parse()
                .map_err(|e| anyhow::anyhow!("--{key} value {s:?}: {e}"))
        })
        .transpose()
}

/// Resolve `--target` (accuracy) / `--target-loss` into a [`Target`].
fn target_from_args(args: &Args) -> anyhow::Result<Target> {
    let acc = parse_opt_f64(args, "target")?;
    let loss = parse_opt_f64(args, "target-loss")?;
    match (acc, loss) {
        (Some(_), Some(_)) => anyhow::bail!("--target and --target-loss are mutually exclusive"),
        (Some(a), None) => Ok(Target::Acc(a)),
        (None, Some(l)) => Ok(Target::Loss(l)),
        (None, None) => Ok(Target::Default),
    }
}

/// Print an N-way comparison, optionally also as JSON (`-` = stdout).
fn emit_compare_report(report: &CompareReport, json_out: Option<&str>) -> anyhow::Result<()> {
    match json_out {
        Some("-") => println!("{}", report.to_json().to_string_pretty()),
        Some(path) => {
            std::fs::write(path, report.to_json().to_string_pretty())?;
            report.table().print();
            println!("wrote {path}");
        }
        None => {
            report.table().print();
            for r in &report.rows {
                if r.id == report.baseline {
                    continue;
                }
                match r.speedup_vs_baseline {
                    Some(s) => println!(
                        "time-to-accuracy: {} is {s:.2}x vs {}",
                        r.id, report.baseline
                    ),
                    None => println!(
                        "time-to-accuracy: {} or {} never reaches the target",
                        r.id, report.baseline
                    ),
                }
            }
        }
    }
    Ok(())
}

/// The campaign subcommand family:
/// `campaign <run|operate|edit|status|report> ...`.
fn cmd_campaign(args: &Args) -> anyhow::Result<()> {
    let store = RunStore::open(args.str_or("store", "runs"))?;
    let action = args.positional.first().map(|s| s.as_str()).unwrap_or("run");
    match action {
        "run" => {
            let name = args.str_or("name", "campaign");
            let mut cfg = campaign_cfg_from_args(&store, &name, args)?;
            cfg.workers = args.usize_or("workers", 0);
            cfg.halt_after = args.get("halt-after").and_then(|s| s.parse().ok());
            cfg.halt_after_cells = args.get("halt-after-cells").and_then(|s| s.parse().ok());
            cfg.verbose = true;
            args.check_unused()?;
            let n_cells = cfg.cells()?.len();
            let grid = if cfg.axes.is_empty() && cfg.zip.is_empty() {
                "base config only".to_string()
            } else {
                let mut parts: Vec<String> = cfg
                    .axes
                    .iter()
                    .map(|a| format!("{}[{}]", a.key, a.values.len()))
                    .collect();
                if !cfg.zip.is_empty() {
                    parts.push(format!(
                        "zip({})[{}]",
                        cfg.zip.iter().map(|a| a.key.as_str()).collect::<Vec<_>>().join(","),
                        cfg.zip[0].values.len()
                    ));
                }
                parts.join(" x ")
            };
            println!(
                "campaign {name}: {n_cells} cell(s) = {grid} (store {})",
                store.location()
            );
            warn_crossed_strategy_axes(&cfg);
            let outcome = campaign::run_campaign(&store, &cfg)?;
            campaign::status_table(&store, &store.load_campaign(&name)?).print();
            let (skipped, completed, failed, pending, pruned) = outcome.counts();
            println!(
                "campaign {name}: {completed} executed, {skipped} already complete, \
                 {failed} failed, {pending} pending, {pruned} pruned"
            );
            for f in outcome.failures() {
                if let fedel::sim::campaign::CellRun::Failed(msg) = &f.status {
                    eprintln!("  cell {} failed: {msg}", f.label);
                }
            }
            anyhow::ensure!(
                outcome.complete(),
                "campaign {name} incomplete — rerun `fedel campaign run --name {name} --store {}` to resume",
                store.location()
            );
            Ok(())
        }
        "operate" => {
            // A reconcile-loop worker (fedel::operator): leases cells,
            // advances them one rung-aligned segment at a time, applies
            // halving prunes, and reclaims dead workers' leases. Grid
            // args seed the campaign when it doesn't exist yet, exactly
            // like `campaign run`.
            let name = args.str_or("name", "campaign");
            let cfg = campaign_cfg_from_args(&store, &name, args)?;
            let mut ocfg = fedel::operator::OperateCfg::new(&name);
            ocfg.worker = args.str_or("worker", &ocfg.worker);
            ocfg.lease_secs = args.u64_or("lease-secs", ocfg.lease_secs);
            ocfg.poll_secs = args.u64_or("poll-secs", ocfg.poll_secs);
            ocfg.max_segments = args.get("max-segments").and_then(|s| s.parse().ok());
            ocfg.verbose = true;
            args.check_unused()?;
            println!(
                "operator {} on campaign {name} (store {}, lease {}s)",
                ocfg.worker,
                store.location(),
                ocfg.lease_secs
            );
            let out = fedel::operator::operate(&store, &ocfg, Some(&cfg))?;
            campaign::status_table(&store, &store.load_campaign(&name)?).print();
            println!(
                "operator {}: {} segment(s), {} cell(s) completed, {} lease(s) reclaimed, \
                 {} cell(s) pruned — campaign {}",
                ocfg.worker,
                out.segments,
                out.completed,
                out.reclaimed,
                out.pruned,
                if out.converged { "converged" } else { "not converged" }
            );
            Ok(())
        }
        "edit" => {
            // Live-edit the desired state: append values to existing
            // sweep axes while workers run. New cells appear unassigned;
            // running workers pick them up on their next pass.
            let name = args.str_or("name", "campaign");
            let sweeps: Vec<String> = args.all("sweep").into_iter().map(String::from).collect();
            args.check_unused()?;
            let m = fedel::operator::edit_campaign(&store, &name, &sweeps)?;
            println!("campaign {name}: grid now {} cell(s)", m.cells.len());
            campaign::status_table(&store, &m).print();
            Ok(())
        }
        "status" => {
            let name = args.str_or("name", "campaign");
            let json = args.flag("json");
            args.check_unused()?;
            let m = store.load_campaign(&name)?;
            if json {
                let status = fedel::operator::observe(&store, &m);
                println!("{}", fedel::operator::status_json(&status).to_string_pretty());
            } else {
                campaign::status_table(&store, &m).print();
            }
            Ok(())
        }
        "report" => {
            let name = args.str_or("name", "campaign");
            let target = target_from_args(args)?;
            let baseline = args.get("baseline").map(|s| s.to_string());
            let over = args.get("over").map(|s| s.to_string());
            let json_out = args.get("json").map(|s| s.to_string());
            args.check_unused()?;
            let m = store.load_campaign(&name)?;
            match over {
                // Table-3 shape: collapse one axis into mean ± std.
                Some(over) => {
                    let rep =
                        campaign::grouped_report(&store, &m, &over, target, baseline.as_deref())?;
                    emit_grouped_report(&rep, json_out.as_deref())
                }
                None => {
                    let report = campaign::report(&store, &m, target, baseline.as_deref())?;
                    emit_compare_report(&report, json_out.as_deref())
                }
            }
        }
        other => anyhow::bail!(
            "unknown campaign action {other:?} (run | operate | edit | status | report)"
        ),
    }
}

/// A strategy-scoped axis (`strategy.<s>.<p>`) crossed with strategies
/// that don't own the key expands cells that ignore it — bitwise
/// duplicates of each other, silently multiplying baseline compute. The
/// cross product is still what was asked for (and keeps labels uniform),
/// but say so once up front.
fn warn_crossed_strategy_axes(cfg: &CampaignCfg) {
    let swept: Vec<String> = cfg
        .axes
        .iter()
        .find(|a| a.key == "strategy")
        .map(|a| a.values.iter().map(|v| v.render()).collect())
        .unwrap_or_else(|| vec![cfg.base.strategy.clone()]);
    for axis in &cfg.axes {
        let Some(owner) = axis
            .key
            .strip_prefix("strategy.")
            .and_then(|rest| rest.split_once('.'))
            .map(|(owner, _)| owner)
        else {
            continue;
        };
        let ignoring: Vec<&str> = swept
            .iter()
            .map(String::as_str)
            .filter(|s| *s != owner)
            .collect();
        if !ignoring.is_empty() {
            eprintln!(
                "note: axis {} only affects {owner:?} cells — [{}] cells ignore it and \
                 run identical duplicates across its {} value(s)",
                axis.key,
                ignoring.join(", "),
                axis.values.len()
            );
        }
    }
}

/// Print a grouped (mean ± std) report, optionally as JSON (`-` = stdout).
fn emit_grouped_report(report: &GroupedReport, json_out: Option<&str>) -> anyhow::Result<()> {
    match json_out {
        Some("-") => println!("{}", report.to_json().to_string_pretty()),
        Some(path) => {
            std::fs::write(path, report.to_json().to_string_pretty())?;
            report.table().print();
            println!("wrote {path}");
        }
        None => report.table().print(),
    }
    Ok(())
}

/// Resolve the grid: a stored campaign resumes from its spec snapshot
/// when no grid args are given; otherwise the args rebuild the spec,
/// which must match the stored one exactly (same name = same grid).
///
/// `--sweep key=v1,v2` (repeatable) is the generic axis syntax — any
/// registered parameter key, including strategy tunables. The PR-3-era
/// flags (`--strategies`, `--seeds`, `--fleets`, `--t-th`) remain as
/// sugar for the equivalent axes, appended in their original nesting
/// order ahead of any `--sweep` axes.
fn campaign_cfg_from_args(
    store: &RunStore,
    name: &str,
    args: &Args,
) -> anyhow::Result<CampaignCfg> {
    let grid_keys = ["model", "strategies", "seeds", "fleets", "t-th", "rounds", "set"];
    let respecified = grid_keys.iter().any(|k| args.get(k).is_some())
        || !args.all("sweep").is_empty()
        || !args.all("zip").is_empty();
    if store.campaign_exists(name) && !respecified {
        let m = store.load_campaign(name)?;
        let mut cfg = CampaignCfg::from_spec_json(name, &m.spec)?;
        cfg.checkpoint_every = args.usize_or("checkpoint-every", cfg.checkpoint_every);
        return Ok(cfg);
    }
    let base = ExperimentCfg::from_args(args)?;
    let mut cfg = CampaignCfg::new(name.to_string(), base);
    // Consumed here, before the spec comparison below: rerunning the
    // exact creation command (same --checkpoint-every) must compare equal.
    cfg.checkpoint_every = args.usize_or("checkpoint-every", cfg.checkpoint_every);
    // The --set layer: already applied onto `base` by from_args, and
    // recorded in the spec so it reapplies after each cell's axis
    // bindings (precedence base < axis < set) and survives bare resumes.
    let sets = args.all("set");
    if !sets.is_empty() {
        cfg.set = fedel::config::params::SpecOverlay::parse(ParamSpace::shared(), &sets)?;
    }
    // Legacy four-axis sugar, in the original nesting order.
    if let Some(s) = args.get("strategies") {
        cfg.axis(&format!("strategy={s}"))?;
    }
    if let Some(s) = args.get("seeds") {
        cfg.axis(&format!("seed={s}"))?;
    }
    if let Some(s) = args.get("fleets") {
        // ';'-separated, same as the fleet sweep syntax
        cfg.axis(&format!("fleet={s}"))?;
    }
    if let Some(s) = args.get("t-th") {
        cfg.axis(&format!("time.t_th_factor={s}"))?;
    }
    for spec in args.all("sweep") {
        cfg.axis(spec)?;
    }
    // Correlated axes: every --zip key advances in lockstep (one zipped
    // dimension), instead of crossing — `--zip a=1,2 --zip b=x,y` yields
    // (1,x) and (2,y), never (1,y).
    for spec in args.all("zip") {
        cfg.zip_axis(spec)?;
    }
    if store.campaign_exists(name) {
        let m = store.load_campaign(name)?;
        // A v1 manifest can never textually match a v2 spec; compare via
        // the expanded grid instead (run_campaign migrates + re-checks).
        let equivalent = cfg.spec_to_json() == m.spec
            || CampaignCfg::from_spec_json(name, &m.spec).is_ok_and(|stored| {
                stored.spec_to_json() == cfg.spec_to_json()
            });
        anyhow::ensure!(
            equivalent,
            "campaign {name:?} already exists with a different spec — resume it \
             without grid args (`fedel campaign run --name {name}`) or pick a new name"
        );
    }
    Ok(cfg)
}

fn run_id_arg(args: &Args, action: &str) -> anyhow::Result<String> {
    args.positional
        .get(1)
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("usage: fedel runs {action} <run-id> [--store dir]"))
}

fn cmd_inspect(args: &Args) -> anyhow::Result<()> {
    let model = args.str_or("model", "mlp");
    let dir = args.str_or("artifacts", "artifacts");
    args.check_unused()?;
    let m = manifest::Manifest::load(Path::new(&dir).join(&model).as_path())?;
    println!(
        "model {} — task {:?}, {} params, {} tensors, {} blocks, batch {}",
        m.model, m.task, m.param_count, m.tensors.len(), m.num_blocks, m.batch
    );
    let mut t = Table::new("blocks", &["block", "tensors", "params", "MFLOPs(fwd/ex)"]);
    for b in &m.blocks {
        let params: usize = b.tensor_ids.iter().map(|&i| m.tensors[i].size).sum();
        t.row(vec![
            format!("{}", b.id),
            format!("{}", b.tensor_ids.len()),
            format!("{}", params),
            format!("{:.2}", b.flops_fwd / 1e6),
        ]);
    }
    t.print();
    Ok(())
}

/// Summarize the device fleet a config would run with — device-type
/// histogram (sampled for lazy fleets), trace links/windows, and churn —
/// without building an engine or a dataset.
fn cmd_fleet(args: &Args) -> anyhow::Result<()> {
    let cfg = ExperimentCfg::from_args(args)?;
    args.check_unused()?;
    let mut hist: std::collections::BTreeMap<String, usize> = std::collections::BTreeMap::new();
    if !cfg.fleet_trace.is_empty() || !cfg.fleet_profiles.is_empty() {
        let profiles = if cfg.fleet_profiles.is_empty() {
            fedel::fleet::trace::load_trace(Path::new(&cfg.fleet_trace))?
        } else {
            cfg.fleet_profiles.clone()
        };
        let linked = profiles.iter().filter(|p| p.up_mbps > 0.0 || p.down_mbps > 0.0).count();
        let windowed = profiles
            .iter()
            .filter(|p| p.arrive_secs > 0.0 || p.depart_secs.is_finite())
            .count();
        println!(
            "trace fleet: {} clients ({linked} with own links, {windowed} with availability windows)",
            profiles.len()
        );
        for p in &profiles {
            *hist.entry(p.device.name.clone()).or_default() += 1;
        }
    } else if let fedel::config::FleetSpec::Lazy { n, generator } = &cfg.fleet {
        use fedel::fleet::FleetView;
        let lf = fedel::fleet::LazyFleet::new(*n, generator.clone(), cfg.seed)?;
        let sample = (*n).min(4096);
        println!(
            "lazy fleet: {n} clients over {} device types (histogram from the first {sample})",
            lf.device_types().len()
        );
        for c in 0..sample {
            *hist.entry(lf.profile(c).device.name).or_default() += 1;
        }
    } else {
        let fleet = fedel::sim::fleet::build_fleet(&cfg.fleet, cfg.seed)?;
        println!("fleet: {} clients", fleet.len());
        for d in &fleet {
            *hist.entry(d.name.clone()).or_default() += 1;
        }
    }
    let mut t = Table::new("device types", &["device", "clients"]);
    for (name, count) in &hist {
        t.row(vec![name.clone(), format!("{count}")]);
    }
    t.print();
    let churn = fedel::fleet::ChurnCfg {
        dropout: cfg.churn_dropout,
        period_secs: cfg.churn_period_secs,
        avail_frac: cfg.churn_avail_frac,
    };
    if churn.active() {
        println!(
            "churn: dropout {} / period {}s / availability {}",
            churn.dropout, churn.period_secs, churn.avail_frac
        );
    }
    if cfg.fleet_sample > 0 {
        println!("async in-flight cap (fleet.sample): {}", cfg.fleet_sample);
    }
    Ok(())
}

fn cmd_list(args: &Args) -> anyhow::Result<()> {
    let dir = args.str_or("artifacts", "artifacts");
    args.check_unused()?;
    let models = manifest::discover(Path::new(&dir))?;
    if models.is_empty() {
        println!("no models under {dir}/ — run `make artifacts`");
        return Ok(());
    }
    let mut t = Table::new("models", &["name", "task", "params", "blocks", "batch"]);
    for m in &models {
        t.row(vec![
            m.model.clone(),
            format!("{:?}", m.task),
            format!("{}", m.param_count),
            format!("{}", m.num_blocks),
            format!("{}", m.batch),
        ]);
    }
    t.print();
    Ok(())
}
