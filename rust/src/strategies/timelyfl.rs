//! TimelyFL (Zhang et al.): heterogeneity-aware asynchronous FL with
//! adaptive partial training. Every client gets the same wall-clock
//! deadline (T_th); each round it trains the deepest prefix sub-model that
//! fits the deadline — recomputed every round, so workloads adapt — and
//! the server aggregates whatever arrived by the deadline. The round
//! always costs exactly the deadline.

use super::depthfl::{prefix_mask, prefix_round_time};
use super::{ClientPlan, FleetCtx, MaskSpec, Strategy};

pub struct TimelyFl {
    nb: usize,
}

impl TimelyFl {
    pub fn new(ctx: &FleetCtx) -> Self {
        TimelyFl { nb: ctx.manifest.num_blocks }
    }
}

impl Strategy for TimelyFl {
    fn name(&self) -> &'static str {
        "timelyfl"
    }

    fn plan_round(&mut self, _round: usize, ctx: &FleetCtx, _global: &[f32]) -> Vec<ClientPlan> {
        (0..ctx.n_clients())
            .map(|client| {
                // deepest prefix that fits the deadline; if even exit 1 is
                // too slow, shed local steps instead (partial epoch).
                let e = (1..=self.nb)
                    .rev()
                    .find(|&e| prefix_round_time(ctx, client, e) <= ctx.t_th)
                    .unwrap_or(1);
                let full = prefix_round_time(ctx, client, e);
                let steps = if full <= ctx.t_th {
                    ctx.local_steps
                } else {
                    ((ctx.local_steps as f64 * ctx.t_th / full).floor() as usize).max(1)
                };
                ClientPlan {
                    client,
                    exit: e,
                    mask: MaskSpec::Tensor(prefix_mask(ctx, e)),
                    local_steps: steps,
                    // async deadline: the round costs T_th regardless.
                    est_time: ctx.t_th,
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::ctx;
    use super::*;

    #[test]
    fn every_round_costs_the_deadline() {
        let c = ctx(8, &[1.0, 2.0, 4.0]);
        let mut s = TimelyFl::new(&c);
        for p in s.plan_round(0, &c, &[]) {
            assert_eq!(p.est_time, c.t_th);
        }
    }

    #[test]
    fn slow_clients_get_shallower_prefixes() {
        let c = ctx(8, &[1.0, 4.0]);
        let mut s = TimelyFl::new(&c);
        let plans = s.plan_round(0, &c, &[]);
        assert!(plans[1].exit < plans[0].exit);
        assert_eq!(plans[0].exit, 8);
    }

    #[test]
    fn extreme_straggler_sheds_steps_not_participation() {
        let c = ctx(8, &[40.0]);
        let mut s = TimelyFl::new(&c);
        let plans = s.plan_round(0, &c, &[]);
        assert_eq!(plans.len(), 1, "TimelyFL keeps everyone participating");
        assert!(plans[0].local_steps < c.local_steps);
        assert!(plans[0].local_steps >= 1);
    }
}
