//! TimelyFL (Zhang et al.): heterogeneity-aware asynchronous FL with
//! adaptive partial training. Every client gets the same wall-clock
//! deadline (T_th); each round it trains the deepest prefix sub-model that
//! fits the deadline — recomputed every round, so workloads adapt — and
//! the server aggregates whatever arrived by the deadline. The round
//! always costs exactly the deadline.

use super::depthfl::{prefix_mask, prefix_round_time};
use super::{ClientPlan, FleetCtx, MaskSpec, Strategy};

pub struct TimelyFl {
    nb: usize,
    /// Per-round deadline as a fraction of T_th (registry param
    /// `strategy.timelyfl.deadline_frac`; 1.0 = the shared threshold).
    deadline_frac: f64,
}

impl TimelyFl {
    pub fn new(ctx: &FleetCtx, deadline_frac: f64) -> Self {
        TimelyFl { nb: ctx.manifest.num_blocks, deadline_frac }
    }

    fn deadline(&self, ctx: &FleetCtx) -> f64 {
        self.deadline_frac * ctx.t_th
    }
}

impl Strategy for TimelyFl {
    fn name(&self) -> &'static str {
        "timelyfl"
    }

    fn plan_round(&mut self, _round: usize, ctx: &FleetCtx, _global: &[f32]) -> Vec<ClientPlan> {
        let deadline = self.deadline(ctx);
        (0..ctx.n_clients())
            .map(|client| {
                // deepest prefix that fits the deadline; if even exit 1 is
                // too slow, shed local steps instead (partial epoch).
                let e = (1..=self.nb)
                    .rev()
                    .find(|&e| prefix_round_time(ctx, client, e) <= deadline)
                    .unwrap_or(1);
                let full = prefix_round_time(ctx, client, e);
                let steps = if full <= deadline {
                    ctx.local_steps
                } else {
                    ((ctx.local_steps as f64 * deadline / full).floor() as usize).max(1)
                };
                ClientPlan {
                    client,
                    exit: e,
                    mask: MaskSpec::Tensor(prefix_mask(ctx, e)),
                    local_steps: steps,
                    // async deadline: the round costs the deadline regardless.
                    est_time: deadline,
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::ctx;
    use super::*;

    #[test]
    fn every_round_costs_the_deadline() {
        let c = ctx(8, &[1.0, 2.0, 4.0]);
        let mut s = TimelyFl::new(&c, 1.0);
        for p in s.plan_round(0, &c, &[]) {
            assert_eq!(p.est_time, c.t_th);
        }
    }

    #[test]
    fn deadline_frac_tightens_the_deadline() {
        let c = ctx(8, &[1.0, 2.0, 4.0]);
        let mut full = TimelyFl::new(&c, 1.0);
        let mut tight = TimelyFl::new(&c, 0.5);
        let plans_full = full.plan_round(0, &c, &[]);
        let plans_tight = tight.plan_round(0, &c, &[]);
        for (f, t) in plans_full.iter().zip(&plans_tight) {
            assert_eq!(t.est_time, 0.5 * c.t_th);
            assert!(t.exit <= f.exit, "tighter deadline must not deepen exits");
        }
    }

    #[test]
    fn slow_clients_get_shallower_prefixes() {
        let c = ctx(8, &[1.0, 4.0]);
        let mut s = TimelyFl::new(&c, 1.0);
        let plans = s.plan_round(0, &c, &[]);
        assert!(plans[1].exit < plans[0].exit);
        assert_eq!(plans[0].exit, 8);
    }

    #[test]
    fn extreme_straggler_sheds_steps_not_participation() {
        let c = ctx(8, &[40.0]);
        let mut s = TimelyFl::new(&c, 1.0);
        let plans = s.plan_round(0, &c, &[]);
        assert_eq!(plans.len(), 1, "TimelyFL keeps everyone participating");
        assert!(plans[0].local_steps < c.local_steps);
        assert!(plans[0].local_steps >= 1);
    }
}
