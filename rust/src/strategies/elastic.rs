//! ElasticTrainer-in-FL (the paper's Section 3 straw man): every client
//! runs the original ElasticTrainer with a uniform T_th — DP tensor
//! selection over the WHOLE model, output layer fixed at the end — and
//! FedAvg-style rounds otherwise. Reproduces Limitation #1: slow clients'
//! selections crowd to the back of the DNN (Fig 4), and Limitation #2:
//! purely local importance amplifies drift.

use crate::elastic::{importance::local_importance, select, SelectorInput};
use crate::util::json::Json;

use super::{ClientPlan, FleetCtx, MaskSpec, RoundFeedback, Strategy};

pub struct ElasticFl {
    /// Last observed per-client local importance [n_clients][K].
    imp: Vec<Vec<f64>>,
}

impl ElasticFl {
    pub fn new(ctx: &FleetCtx) -> Self {
        let k = ctx.manifest.tensors.len();
        ElasticFl { imp: vec![vec![1.0; k]; ctx.n_clients()] }
    }
}

impl Strategy for ElasticFl {
    fn name(&self) -> &'static str {
        "elastictrainer"
    }

    fn plan_round(&mut self, _round: usize, ctx: &FleetCtx, _global: &[f32]) -> Vec<ClientPlan> {
        let m = &ctx.manifest;
        let k = m.tensors.len();
        let nb = m.num_blocks;
        let order = ctx.window_order(0, nb);
        (0..ctx.n_clients())
            .map(|client| {
                let imp: Vec<f64> = order.iter().map(|&t| self.imp[client][t]).collect();
                let budget = ctx.step_backward_budget(client, nb);
                let sel = select(&SelectorInput {
                    order: &order,
                    importance: &imp,
                    budget,
                    timing: ctx.timing(client),
                });
                let mut mask = vec![0.0f32; k];
                for &t in &sel.tensors {
                    mask[t] = 1.0;
                }
                let est_time = ctx.round_time(client, nb, sel.backward_time);
                ClientPlan {
                    client,
                    exit: nb,
                    mask: MaskSpec::Tensor(mask),
                    local_steps: ctx.local_steps,
                    est_time,
                }
            })
            .collect()
    }

    fn observe(&mut self, fb: &RoundFeedback, ctx: &FleetCtx) {
        for (client, sq, _) in &fb.per_client {
            self.imp[*client] = local_importance(sq, ctx.lr);
        }
    }

    fn policy_state(&self) -> Json {
        Json::obj(vec![(
            "imp",
            Json::Arr(self.imp.iter().map(|v| Json::from_f64s(v)).collect()),
        )])
    }

    fn restore_policy_state(&mut self, state: &Json) -> anyhow::Result<()> {
        if matches!(state, Json::Null) {
            return Ok(()); // fresh strategy (warm start)
        }
        let imp: Vec<Vec<f64>> = state
            .arr("imp")?
            .iter()
            .map(Json::to_f64_vec)
            .collect::<anyhow::Result<_>>()?;
        anyhow::ensure!(
            imp.len() == self.imp.len()
                && imp.iter().zip(&self.imp).all(|(a, b)| a.len() == b.len()),
            "elastictrainer snapshot: importance shape mismatch"
        );
        self.imp = imp;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::ctx;
    use super::*;

    #[test]
    fn respects_budget_on_slow_devices() {
        // est_time <= max(T_th, unavoidable fwd cost) + floor slack; the
        // full-model fwd of a 4x straggler alone exceeds T_th (the paper's
        // Appendix B.3 soft-overshoot regime).
        let c = ctx(8, &[1.0, 4.0]);
        let mut s = ElasticFl::new(&c);
        let plans = s.plan_round(0, &c, &[]);
        for p in &plans {
            let fwd = c.timings[p.client].forward_time(&c.manifest, p.exit)
                * c.local_steps as f64;
            let cap = c.t_th.max(fwd) + crate::strategies::MIN_BUDGET_FRAC * c.t_th;
            assert!(
                p.est_time <= cap * 1.05,
                "client {} time {} > cap {cap} (T_th {})",
                p.client,
                p.est_time,
                c.t_th
            );
        }
    }

    #[test]
    fn slow_clients_select_fewer_tensors() {
        let c = ctx(8, &[1.0, 4.0]);
        let mut s = ElasticFl::new(&c);
        let plans = s.plan_round(0, &c, &[]);
        let count = |p: &ClientPlan| match &p.mask {
            MaskSpec::Tensor(t) => t.iter().filter(|&&x| x > 0.0).count(),
            _ => 0,
        };
        assert!(count(&plans[1]) < count(&plans[0]));
    }

    #[test]
    fn slow_client_selection_crowds_to_back_blocks() {
        // Limitation #1: the slow client's selected tensors sit in deep blocks.
        let c = ctx(8, &[1.0, 4.0]);
        let mut s = ElasticFl::new(&c);
        let plans = s.plan_round(0, &c, &[]);
        if let MaskSpec::Tensor(t) = &plans[1].mask {
            let selected_blocks: Vec<usize> = t
                .iter()
                .enumerate()
                .filter(|(_, &x)| x > 0.0)
                .map(|(i, _)| c.manifest.tensors[i].block)
                .collect();
            assert!(!selected_blocks.is_empty());
            assert!(
                selected_blocks.iter().all(|&b| b >= 4),
                "slow client trained shallow blocks: {selected_blocks:?}"
            );
        } else {
            panic!()
        }
    }

    #[test]
    fn importance_updates_steer_selection() {
        let c = ctx(6, &[1.0]);
        let mut s = ElasticFl::new(&c);
        let k = c.manifest.tensors.len();
        // claim only tensor of block 5 (deep, cheap to chain) matters
        let mut sq = vec![0.0; k];
        sq[10] = 100.0;
        s.observe(
            &RoundFeedback { per_client: vec![(0, sq, 1.0)], global_importance: vec![0.0; k] },
            &c,
        );
        let plans = s.plan_round(1, &c, &[]);
        if let MaskSpec::Tensor(t) = &plans[0].mask {
            assert!(t[10] > 0.0, "high-importance tensor not selected");
        } else {
            panic!()
        }
    }
}
