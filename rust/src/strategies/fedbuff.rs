//! FedBuff (Nguyen et al., *Federated Learning with Buffered Asynchronous
//! Aggregation*): the buffered asynchronous baseline.
//!
//! Clients train full models at their own pace like FedAsync, but the
//! server holds arriving updates in a buffer and only folds them into the
//! global model once `buffer_k` have accumulated — each flush averages
//! the buffered deltas (data-size weighted), which trades a little
//! freshness for far lower aggregation noise than per-arrival mixing.
//! A client whose update is buffered is re-dispatched immediately, so one
//! client can hold several slots of a large buffer on a small fleet.
//!
//! Execution-side state (client clocks, the buffer itself) lives in the
//! event-driven runner ([`crate::fl::exec::event`]) and checkpoints through
//! its runner-state extension; `policy_state` stays `Null`.

use crate::fl::AggregateRule;

use super::{full_model_plan, AsyncMode, AsyncSpec, ClientPlan, FleetCtx, Strategy};

pub struct FedBuff {
    k: usize,
    staleness_exp: f64,
}

impl FedBuff {
    pub fn new(k: usize) -> Self {
        FedBuff { k: k.max(1), staleness_exp: 0.0 }
    }

    /// Decay each buffered delta's weight by `1 / (1 + s)^exp` inside the
    /// flush average, where `s` is the update's staleness in aggregation
    /// rounds. 0 (the default) reproduces the paper's plain data-size
    /// weighting bitwise.
    pub fn with_staleness_exp(mut self, exp: f64) -> Self {
        self.staleness_exp = exp.max(0.0);
        self
    }
}

impl Strategy for FedBuff {
    fn name(&self) -> &'static str {
        "fedbuff"
    }

    /// Full-model work for every client (see [`super::fedasync`]).
    fn plan_round(&mut self, _round: usize, ctx: &FleetCtx, _global: &[f32]) -> Vec<ClientPlan> {
        (0..ctx.n_clients()).map(|client| full_model_plan(ctx, client)).collect()
    }

    fn aggregate_rule(&self) -> AggregateRule {
        AggregateRule::FedAvg
    }

    fn async_spec(&self) -> Option<AsyncSpec> {
        Some(AsyncSpec {
            mode: AsyncMode::Buffered { k: self.k, staleness_exp: self.staleness_exp },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::ctx;
    use super::*;

    #[test]
    fn declares_buffered_async_spec_with_floor() {
        match FedBuff::new(4).async_spec().unwrap().mode {
            AsyncMode::Buffered { k, staleness_exp } => {
                assert_eq!(k, 4);
                assert_eq!(staleness_exp, 0.0, "staleness weighting off by default");
            }
            other => panic!("wrong mode {other:?}"),
        }
        match FedBuff::new(0).async_spec().unwrap().mode {
            AsyncMode::Buffered { k, .. } => assert_eq!(k, 1, "buffer floor"),
            other => panic!("wrong mode {other:?}"),
        }
    }

    #[test]
    fn staleness_exp_rides_the_async_spec() {
        match FedBuff::new(2).with_staleness_exp(1.5).async_spec().unwrap().mode {
            AsyncMode::Buffered { staleness_exp, .. } => assert_eq!(staleness_exp, 1.5),
            other => panic!("wrong mode {other:?}"),
        }
        match FedBuff::new(2).with_staleness_exp(-3.0).async_spec().unwrap().mode {
            AsyncMode::Buffered { staleness_exp, .. } => {
                assert_eq!(staleness_exp, 0.0, "negative exponents clamp to off")
            }
            other => panic!("wrong mode {other:?}"),
        }
    }

    #[test]
    fn plans_full_model_for_every_client() {
        let c = ctx(4, &[1.0, 2.0]);
        let plans = FedBuff::new(2).plan_round(0, &c, &[]);
        assert_eq!(plans.len(), 2);
        assert!(plans.iter().all(|p| p.exit == 4));
    }
}
