//! The strategy registry: the one place a policy (or ablation) plugs into
//! the framework.
//!
//! Each [`StrategyDef`] names a strategy, documents it, **declares its
//! tunable parameters** (name, default, bounds, help), and provides the
//! builder that turns resolved parameter values into a boxed
//! [`Strategy`]. Everything else derives from the registration:
//!
//! * [`crate::config::params::ParamSpace`] exposes each declared tunable
//!   as a typed key `strategy.<strategy>.<param>`, so it is settable via
//!   `--set` and sweepable via `--sweep` with no further Rust changes,
//! * `train --list-strategies` prints the registry,
//! * unknown strategy names fail with the full list and a nearest-match
//!   suggestion.
//!
//! Parameter values flow in through [`crate::config::ExperimentCfg`]'s
//! `strategy_params` bag (full keys -> f64); anything undeclared there is
//! rejected at parse time by the param space, so builders can trust
//! [`ResolvedParams`] to hold exactly their declared names.

use std::sync::OnceLock;

use super::{fedavg, fedel, FleetCtx, Strategy};
use crate::fl::AggregateRule;
use crate::window::WindowPolicy;

/// One declared tunable of a strategy.
#[derive(Clone, Copy, Debug)]
pub struct ParamSpec {
    /// Short name; the settable key is `strategy.<strategy>.<name>`.
    pub name: &'static str,
    pub default: f64,
    /// Inclusive bounds, validated at parse *and* build time.
    pub min: f64,
    pub max: f64,
    pub help: &'static str,
}

/// Declared tunables resolved against a config's parameter bag: every
/// declared name is present (bag value if bound, else the default).
pub struct ResolvedParams {
    vals: Vec<(&'static str, f64)>,
}

impl ResolvedParams {
    /// Value of a declared parameter. Panics on an undeclared name — that
    /// is a builder bug, not an input error.
    pub fn get(&self, name: &str) -> f64 {
        self.vals
            .iter()
            .find(|(n, _)| *n == name)
            .map(|(_, v)| *v)
            .unwrap_or_else(|| panic!("strategy builder read undeclared param {name:?}"))
    }
}

type BuildFn = fn(&FleetCtx, u64, &ResolvedParams) -> Box<dyn Strategy>;

/// One registered strategy.
pub struct StrategyDef {
    pub name: &'static str,
    pub summary: &'static str,
    pub params: Vec<ParamSpec>,
    build: BuildFn,
}

/// All registered strategies, in Table-1-then-ablations order.
pub struct StrategyRegistry {
    defs: Vec<StrategyDef>,
}

/// FedEL's importance-harmonization weight β (Sec. 4.2): blended
/// importance I = β·I_local + (1−β)·I^g. Declared by every FedEL-family
/// row; bound via `strategy.<s>.harmonize_weight` (the deprecated
/// `--beta` CLI flag is an alias that writes these keys).
const HARMONIZE: ParamSpec = ParamSpec {
    name: "harmonize_weight",
    default: 0.6,
    min: 0.0,
    max: 1.0,
    help: "FedEL importance blend β: I = β·I_local + (1−β)·I_global",
};

const MU: ParamSpec = ParamSpec {
    name: "mu",
    default: 0.01,
    min: 0.0,
    max: 10.0,
    help: "FedProx proximal coefficient μ (client-side pull to the global model)",
};

fn defs() -> Vec<StrategyDef> {
    vec![
        StrategyDef {
            name: "fedavg",
            summary: "full-model synchronous baseline (McMahan et al.)",
            params: vec![],
            build: |_, _, _| Box::new(fedavg::FedAvg::new(AggregateRule::FedAvg, 0.0)),
        },
        StrategyDef {
            name: "elastictrainer",
            summary: "importance-ranked tensor selection under a time budget",
            params: vec![],
            build: |ctx, _, _| Box::new(super::elastic::ElasticFl::new(ctx)),
        },
        StrategyDef {
            name: "heterofl",
            summary: "width-scaled sub-networks matched to device budgets (Diao et al.)",
            params: vec![ParamSpec {
                name: "min_width",
                default: 0.125,
                min: 0.01,
                max: 1.0,
                help: "narrowest width level a straggler may fall back to",
            }],
            build: |ctx, _, p| Box::new(super::heterofl::HeteroFl::new(ctx, p.get("min_width"))),
        },
        StrategyDef {
            name: "depthfl",
            summary: "depth-scaled sub-models via early exits (Kim et al.)",
            params: vec![],
            build: |ctx, _, _| Box::new(super::depthfl::DepthFl::new(ctx)),
        },
        StrategyDef {
            name: "pyramidfl",
            summary: "utility-ranked client selection, full-model training (Li et al.)",
            params: vec![
                ParamSpec {
                    name: "frac",
                    default: 0.6,
                    min: 0.01,
                    max: 1.0,
                    help: "fraction of clients admitted per round",
                },
                ParamSpec {
                    name: "explore",
                    default: 0.1,
                    min: 0.0,
                    max: 0.99,
                    help: "fraction of the admission budget spent on random exploration",
                },
            ],
            build: |ctx, seed, p| {
                Box::new(super::pyramidfl::PyramidFl::new(
                    ctx,
                    seed,
                    p.get("frac"),
                    p.get("explore"),
                ))
            },
        },
        StrategyDef {
            name: "timelyfl",
            summary: "deadline-driven adaptive partial training (Zhang et al.)",
            params: vec![ParamSpec {
                name: "deadline_frac",
                default: 1.0,
                min: 0.05,
                max: 4.0,
                help: "per-round deadline as a fraction of T_th (soft-training ratio)",
            }],
            build: |ctx, _, p| {
                Box::new(super::timelyfl::TimelyFl::new(ctx, p.get("deadline_frac")))
            },
        },
        StrategyDef {
            name: "fiarse",
            summary: "magnitude-thresholded submodel extraction (FIARSE)",
            params: vec![],
            build: |ctx, _, _| Box::new(super::fiarse::Fiarse::new(ctx)),
        },
        StrategyDef {
            name: "feddrop",
            summary: "adaptive per-device federated dropout (device-scaled drop rates)",
            params: vec![
                ParamSpec {
                    name: "rate",
                    default: 0.3,
                    min: 0.0,
                    max: 0.9,
                    help: "base body-tensor drop probability before device scaling",
                },
                ParamSpec {
                    name: "adapt",
                    default: 1.0,
                    min: 0.0,
                    max: 4.0,
                    help: "slowness exponent: rate_c = rate·(t_full/T_th)^adapt (0 = uniform dropout)",
                },
            ],
            build: |_, seed, p| {
                Box::new(super::feddrop::FedDrop::new(p.get("rate"), p.get("adapt"), seed))
            },
        },
        StrategyDef {
            name: "fedasync",
            summary: "per-arrival async aggregation, staleness-decayed mixing (Xie et al.)",
            params: vec![
                ParamSpec {
                    name: "alpha",
                    default: 0.6,
                    min: 0.01,
                    max: 1.0,
                    help: "mixing weight of a fresh arrival: w_g <- (1-s)w_g + s·w_n, s = alpha/(1+staleness)^exp",
                },
                ParamSpec {
                    name: "staleness_exp",
                    default: 0.5,
                    min: 0.0,
                    max: 4.0,
                    help: "staleness-decay exponent (0 = stale updates mix at full alpha)",
                },
            ],
            build: |_, _, p| {
                Box::new(super::fedasync::FedAsync::new(p.get("alpha"), p.get("staleness_exp")))
            },
        },
        StrategyDef {
            name: "fedbuff",
            summary: "buffered async aggregation: flush every K arrivals (Nguyen et al.)",
            params: vec![
                ParamSpec {
                    name: "buffer_k",
                    default: 4.0,
                    min: 1.0,
                    max: 1024.0,
                    help: "arrivals buffered per aggregation (the paper's K)",
                },
                ParamSpec {
                    name: "staleness_exp",
                    default: 0.0,
                    min: 0.0,
                    max: 4.0,
                    help: "decay each buffered delta by 1/(1+staleness)^exp in the flush average (0 = plain data-size weighting)",
                },
            ],
            build: |_, _, p| {
                Box::new(
                    super::fedbuff::FedBuff::new(p.get("buffer_k").round() as usize)
                        .with_staleness_exp(p.get("staleness_exp")),
                )
            },
        },
        StrategyDef {
            name: "fedel",
            summary: "sliding-window elastic training + importance harmonization (the paper)",
            params: vec![HARMONIZE],
            build: |ctx, _, p| {
                Box::new(fedel::FedEl::new(
                    ctx,
                    p.get("harmonize_weight"),
                    WindowPolicy::FedEl,
                    AggregateRule::Masked,
                    0.0,
                ))
            },
        },
        StrategyDef {
            name: "fedel-c",
            summary: "FedEL ablation: collapsed (non-sliding) window",
            params: vec![HARMONIZE],
            build: |ctx, _, p| {
                Box::new(fedel::FedEl::new(
                    ctx,
                    p.get("harmonize_weight"),
                    WindowPolicy::Collapsed,
                    AggregateRule::Masked,
                    0.0,
                ))
            },
        },
        StrategyDef {
            name: "fedel-norollback",
            summary: "FedEL ablation: no end-of-model window rollback",
            params: vec![HARMONIZE],
            build: |ctx, _, p| {
                Box::new(fedel::FedEl::new(
                    ctx,
                    p.get("harmonize_weight"),
                    WindowPolicy::NoRollback,
                    AggregateRule::Masked,
                    0.0,
                ))
            },
        },
        StrategyDef {
            name: "fedprox",
            summary: "FedAvg + proximal regularization (Li et al.)",
            params: vec![MU],
            build: |_, _, p| Box::new(fedavg::FedAvg::new(AggregateRule::FedAvg, p.get("mu"))),
        },
        StrategyDef {
            name: "fednova",
            summary: "FedAvg with normalized averaging (Wang et al.)",
            params: vec![],
            build: |_, _, _| Box::new(fedavg::FedAvg::new(AggregateRule::FedNova, 0.0)),
        },
        StrategyDef {
            name: "fedprox+fedel",
            summary: "FedEL with client-side proximal regularization",
            params: vec![HARMONIZE, MU],
            build: |ctx, _, p| {
                Box::new(fedel::FedEl::new(
                    ctx,
                    p.get("harmonize_weight"),
                    WindowPolicy::FedEl,
                    AggregateRule::Masked,
                    p.get("mu"),
                ))
            },
        },
        StrategyDef {
            name: "fednova+fedel",
            summary: "FedEL under normalized averaging",
            params: vec![HARMONIZE],
            build: |ctx, _, p| {
                Box::new(fedel::FedEl::new(
                    ctx,
                    p.get("harmonize_weight"),
                    WindowPolicy::FedEl,
                    AggregateRule::FedNova,
                    0.0,
                ))
            },
        },
    ]
}

/// The process-wide registry (construction is cheap but allocation-happy;
/// share one).
pub fn builtin() -> &'static StrategyRegistry {
    static REG: OnceLock<StrategyRegistry> = OnceLock::new();
    REG.get_or_init(|| StrategyRegistry { defs: defs() })
}

impl StrategyRegistry {
    pub fn defs(&self) -> &[StrategyDef] {
        &self.defs
    }

    pub fn names(&self) -> Vec<&'static str> {
        self.defs.iter().map(|d| d.name).collect()
    }

    pub fn get(&self, name: &str) -> Option<&StrategyDef> {
        self.defs.iter().find(|d| d.name == name)
    }

    /// Lookup that fails with the full roster and a nearest-match hint.
    pub fn require(&self, name: &str) -> anyhow::Result<&StrategyDef> {
        self.get(name).ok_or_else(|| {
            let names = self.names();
            let hint = crate::util::nearest_match(name, &names)
                .map(|n| format!(" — did you mean {n:?}?"))
                .unwrap_or_default();
            anyhow::anyhow!("unknown strategy {name:?}{hint} (registered: {})", names.join(", "))
        })
    }

    /// The full settable key of a declared parameter.
    pub fn param_key(strategy: &str, param: &str) -> String {
        format!("strategy.{strategy}.{param}")
    }

    /// The [`ParamSpec`] behind `strategy.<strategy>.<param>`, or an error
    /// naming what that strategy actually declares.
    pub fn param_spec(&self, strategy: &str, param: &str) -> anyhow::Result<&ParamSpec> {
        let def = self.require(strategy)?;
        def.params.iter().find(|p| p.name == param).ok_or_else(|| {
            let declared: Vec<&str> = def.params.iter().map(|p| p.name).collect();
            anyhow::anyhow!(
                "strategy {strategy:?} declares no param {param:?} (declared: [{}])",
                declared.join(", ")
            )
        })
    }

    /// Build a strategy with its declared params resolved from a config's
    /// parameter bag (`strategy.<name>.<param>` -> f64); anything unbound
    /// takes its declared default. The legacy `--beta` field is gone:
    /// `harmonize_weight` flows through the bag like every other tunable
    /// (`--beta` on the CLI survives only as a deprecated alias that
    /// writes the bag, see [`crate::config::ExperimentCfg::from_args`]).
    pub fn build(
        &self,
        name: &str,
        ctx: &FleetCtx,
        seed: u64,
        bag: &[(String, f64)],
    ) -> anyhow::Result<Box<dyn Strategy>> {
        let def = self.require(name)?;
        let mut vals = Vec::with_capacity(def.params.len());
        for p in &def.params {
            let key = StrategyRegistry::param_key(name, p.name);
            let v = bag
                .iter()
                .find(|(k, _)| *k == key)
                .map(|(_, v)| *v)
                .unwrap_or(p.default);
            anyhow::ensure!(
                v >= p.min && v <= p.max,
                "{key} = {v} out of bounds [{}, {}]",
                p.min,
                p.max
            );
            vals.push((p.name, v));
        }
        Ok((def.build)(ctx, seed, &ResolvedParams { vals }))
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::ctx;
    use super::*;

    #[test]
    fn registry_covers_every_table1_row_and_ablation() {
        let reg = builtin();
        let c = ctx(4, &[1.0, 2.0]);
        for name in reg.names() {
            reg.build(name, &c, 1, &[]).unwrap_or_else(|e| panic!("{name}: {e}"));
        }
        for name in super::super::table1_names() {
            let s = reg.build(name, &c, 1, &[]).unwrap();
            assert_eq!(s.name(), name);
        }
    }

    #[test]
    fn async_rows_register_and_declare_their_specs() {
        let reg = builtin();
        let c = ctx(4, &[1.0, 2.0]);
        let fa = reg.build("fedasync", &c, 1, &[]).unwrap();
        assert!(fa.async_spec().is_some(), "fedasync must route async");
        let bag = vec![
            ("strategy.fedbuff.buffer_k".to_string(), 2.0),
            ("strategy.fedbuff.staleness_exp".to_string(), 1.0),
        ];
        let fb = reg.build("fedbuff", &c, 1, &bag).unwrap();
        match fb.async_spec().unwrap().mode {
            crate::strategies::AsyncMode::Buffered { k, staleness_exp } => {
                assert_eq!(k, 2);
                assert_eq!(staleness_exp, 1.0);
            }
            other => panic!("{other:?}"),
        }
        // the declared tunables are sweepable keys
        assert_eq!(reg.param_spec("fedasync", "alpha").unwrap().default, 0.6);
        assert_eq!(reg.param_spec("fedbuff", "buffer_k").unwrap().default, 4.0);
        assert_eq!(reg.param_spec("fedbuff", "staleness_exp").unwrap().default, 0.0);
    }

    #[test]
    fn feddrop_declares_adaptive_dropout_tunables() {
        let reg = builtin();
        assert_eq!(reg.param_spec("feddrop", "rate").unwrap().default, 0.3);
        assert_eq!(reg.param_spec("feddrop", "adapt").unwrap().default, 1.0);
        let c = ctx(4, &[1.0, 2.0]);
        let bag = vec![
            ("strategy.feddrop.rate".to_string(), 0.6),
            ("strategy.feddrop.adapt".to_string(), 2.0),
        ];
        let s = reg.build("feddrop", &c, 1, &bag).unwrap();
        assert_eq!(s.name(), "feddrop");
        assert!(s.async_spec().is_none(), "feddrop runs synchronously");
    }

    #[test]
    fn unknown_strategy_suggests_nearest() {
        let err = builtin().require("fedell").unwrap_err().to_string();
        assert!(err.contains("did you mean \"fedel\""), "{err}");
        assert!(err.contains("fedavg"), "roster missing: {err}");
    }

    #[test]
    fn out_of_bounds_bag_value_rejected_at_build() {
        let c = ctx(4, &[1.0, 2.0]);
        let err = builtin()
            .build("fedel", &c, 1, &[("strategy.fedel.harmonize_weight".to_string(), 1.5)])
            .unwrap_err()
            .to_string();
        assert!(err.contains("out of bounds"), "{err}");
        let bag = vec![("strategy.fedel.harmonize_weight".to_string(), 0.25)];
        builtin().build("fedel", &c, 1, &bag).unwrap();
    }

    #[test]
    fn param_spec_lookup_validates_both_levels() {
        let reg = builtin();
        assert_eq!(reg.param_spec("fedel", "harmonize_weight").unwrap().default, 0.6);
        let err = reg.param_spec("fedel", "mu").unwrap_err().to_string();
        assert!(err.contains("declares no param"), "{err}");
        assert!(reg.param_spec("nope", "x").is_err());
    }
}
