//! FedAsync (Xie et al., *Asynchronous Federated Optimization*): the
//! per-arrival asynchronous baseline the paper positions FedEL against.
//!
//! Every client trains the full model at its own device pace; the server
//! mixes each arriving update into the global model immediately,
//! down-weighted by how stale it is:
//!
//!     w_g <- (1 - s(t)) * w_g + s(t) * w_client,
//!     s(t) = alpha / (1 + staleness)^staleness_exp
//!
//! where staleness counts how many server versions elapsed since the
//! client's dispatch. All execution-side state (client clocks, versions)
//! lives in the event-driven runner ([`crate::fl::exec::event`]); this
//! type only declares the policy, so `policy_state` stays `Null` and
//! kill/resume rides the runner's checkpoint extension instead.

use crate::fl::AggregateRule;

use super::{full_model_plan, AsyncMode, AsyncSpec, ClientPlan, FleetCtx, Strategy};

pub struct FedAsync {
    alpha: f64,
    staleness_exp: f64,
}

impl FedAsync {
    pub fn new(alpha: f64, staleness_exp: f64) -> Self {
        FedAsync { alpha, staleness_exp }
    }
}

impl Strategy for FedAsync {
    fn name(&self) -> &'static str {
        "fedasync"
    }

    /// Full-model work for every client — the async runner dispatches one
    /// of these per client at its own pace; a synchronous caller asking
    /// for a round gets the same shape FedAvg would plan.
    fn plan_round(&mut self, _round: usize, ctx: &FleetCtx, _global: &[f32]) -> Vec<ClientPlan> {
        (0..ctx.n_clients()).map(|client| full_model_plan(ctx, client)).collect()
    }

    fn aggregate_rule(&self) -> AggregateRule {
        AggregateRule::FedAvg
    }

    fn async_spec(&self) -> Option<AsyncSpec> {
        Some(AsyncSpec {
            mode: AsyncMode::PerArrival {
                alpha: self.alpha,
                staleness_exp: self.staleness_exp,
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::ctx;
    use super::*;
    use crate::strategies::MaskSpec;

    #[test]
    fn declares_per_arrival_async_spec() {
        let s = FedAsync::new(0.6, 0.5);
        match s.async_spec().unwrap().mode {
            AsyncMode::PerArrival { alpha, staleness_exp } => {
                assert_eq!(alpha, 0.6);
                assert_eq!(staleness_exp, 0.5);
            }
            other => panic!("wrong mode {other:?}"),
        }
    }

    #[test]
    fn plans_full_model_for_every_client() {
        let c = ctx(4, &[1.0, 2.0, 3.0]);
        let plans = FedAsync::new(0.6, 0.5).plan_round(0, &c, &[]);
        assert_eq!(plans.len(), 3);
        for p in &plans {
            assert_eq!(p.exit, 4);
            match &p.mask {
                MaskSpec::Tensor(t) => assert!(t.iter().all(|&x| x == 1.0)),
                _ => panic!(),
            }
        }
        // device pace shows up in the per-dispatch cost
        assert!(plans[2].est_time > plans[0].est_time * 2.9);
    }
}
