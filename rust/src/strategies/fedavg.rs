//! FedAvg (McMahan et al.) — the classic baseline: every client trains the
//! full model every round; the server waits for the slowest device.
//! Doubles as FedProx (prox_mu > 0, same schedule, proximal local steps)
//! and FedNova (normalized aggregation) for Table 3.

use crate::fl::AggregateRule;

use super::{ClientPlan, FleetCtx, MaskSpec, Strategy};

pub struct FedAvg {
    rule: AggregateRule,
    mu: f64,
}

impl FedAvg {
    pub fn new(rule: AggregateRule, mu: f64) -> Self {
        FedAvg { rule, mu }
    }
}

impl Strategy for FedAvg {
    fn name(&self) -> &'static str {
        match (self.rule, self.mu > 0.0) {
            (AggregateRule::FedNova, _) => "fednova",
            (_, true) => "fedprox",
            _ => "fedavg",
        }
    }

    fn plan_round(&mut self, _round: usize, ctx: &FleetCtx, _global: &[f32]) -> Vec<ClientPlan> {
        let k = ctx.manifest.tensors.len();
        (0..ctx.n_clients())
            .map(|client| ClientPlan {
                client,
                exit: ctx.manifest.num_blocks,
                mask: MaskSpec::Tensor(vec![1.0; k]),
                local_steps: ctx.local_steps,
                est_time: ctx.full_round_time(client),
            })
            .collect()
    }

    fn aggregate_rule(&self) -> AggregateRule {
        self.rule
    }

    fn prox_mu(&self) -> f64 {
        self.mu
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::ctx;
    use super::*;

    #[test]
    fn everyone_trains_everything() {
        let c = ctx(4, &[1.0, 2.0, 3.0]);
        let mut s = FedAvg::new(AggregateRule::FedAvg, 0.0);
        let plans = s.plan_round(0, &c, &[]);
        assert_eq!(plans.len(), 3);
        for p in &plans {
            assert_eq!(p.exit, 4);
            match &p.mask {
                MaskSpec::Tensor(t) => assert!(t.iter().all(|&x| x == 1.0)),
                _ => panic!(),
            }
        }
    }

    #[test]
    fn round_time_dominated_by_slowest() {
        let c = ctx(4, &[1.0, 3.0]);
        let mut s = FedAvg::new(AggregateRule::FedAvg, 0.0);
        let plans = s.plan_round(0, &c, &[]);
        assert!(plans[1].est_time > plans[0].est_time * 2.9);
    }

    #[test]
    fn names_reflect_variants() {
        assert_eq!(FedAvg::new(AggregateRule::FedAvg, 0.0).name(), "fedavg");
        assert_eq!(FedAvg::new(AggregateRule::FedAvg, 0.01).name(), "fedprox");
        assert_eq!(FedAvg::new(AggregateRule::FedNova, 0.0).name(), "fednova");
    }
}
