//! DepthFL (Kim et al.): depth scaling — each client permanently trains a
//! prefix sub-model (blocks 0..d with an early-exit classifier) sized to
//! its compute budget. The early-exit artifacts are exactly DepthFL's
//! sub-models. Paper Table 1 analysis: slow clients only ever train front
//! layers, so the deep layers never see their data.

use super::{ClientPlan, FleetCtx, MaskSpec, Strategy};

pub struct DepthFl {
    /// Assigned exit per client (1..=num_blocks).
    pub depths: Vec<usize>,
}

/// Per-round cost of training the full prefix sub-model with exit `e`.
pub(crate) fn prefix_round_time(ctx: &FleetCtx, client: usize, e: usize) -> f64 {
    let m = &ctx.manifest;
    let tm = ctx.timing(client);
    let mut bwd = 0.0;
    for b in 0..e {
        for t in m.body_tensors_of_block(b) {
            bwd += tm.tensors[t].t_g + tm.tensors[t].t_w;
        }
    }
    for t in m.head_tensors_of_block(e - 1) {
        bwd += tm.tensors[t].t_g + tm.tensors[t].t_w;
    }
    ctx.round_time(client, e, bwd)
}

/// Mask covering blocks 0..e plus the exit head.
pub(crate) fn prefix_mask(ctx: &FleetCtx, e: usize) -> Vec<f32> {
    let m = &ctx.manifest;
    let mut mask = vec![0.0f32; m.tensors.len()];
    for (i, t) in m.tensors.iter().enumerate() {
        if !t.is_head && t.block < e {
            mask[i] = 1.0;
        }
    }
    for t in m.head_tensors_of_block(e - 1) {
        mask[t] = 1.0;
    }
    mask
}

impl DepthFl {
    pub fn new(ctx: &FleetCtx) -> Self {
        let nb = ctx.manifest.num_blocks;
        let depths = (0..ctx.n_clients())
            .map(|c| {
                (1..=nb)
                    .rev()
                    .find(|&e| prefix_round_time(ctx, c, e) <= ctx.t_th)
                    .unwrap_or(1)
            })
            .collect();
        DepthFl { depths }
    }
}

impl Strategy for DepthFl {
    fn name(&self) -> &'static str {
        "depthfl"
    }

    fn plan_round(&mut self, _round: usize, ctx: &FleetCtx, _global: &[f32]) -> Vec<ClientPlan> {
        (0..ctx.n_clients())
            .map(|client| {
                let e = self.depths[client];
                ClientPlan {
                    client,
                    exit: e,
                    mask: MaskSpec::Tensor(prefix_mask(ctx, e)),
                    local_steps: ctx.local_steps,
                    est_time: prefix_round_time(ctx, client, e),
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::ctx;
    use super::*;

    #[test]
    fn depth_matches_device_speed() {
        let c = ctx(8, &[1.0, 4.0]);
        let s = DepthFl::new(&c);
        assert_eq!(s.depths[0], 8, "fast client trains everything");
        assert!(s.depths[1] < 8, "slow client gets a shallow sub-model");
        assert!(s.depths[1] >= 1);
    }

    #[test]
    fn cost_fits_threshold() {
        let c = ctx(8, &[1.0, 2.0, 4.0]);
        let mut s = DepthFl::new(&c);
        for p in s.plan_round(0, &c, &[]) {
            if p.exit > 1 {
                assert!(p.est_time <= c.t_th + 1e-9, "client {}", p.client);
            }
        }
    }

    #[test]
    fn mask_is_prefix_plus_head() {
        let c = ctx(6, &[1.0]);
        let mask = prefix_mask(&c, 3);
        for (i, t) in c.manifest.tensors.iter().enumerate() {
            let expect = if t.is_head { t.block == 2 } else { t.block < 3 };
            assert_eq!(mask[i] > 0.0, expect, "{}", t.name);
        }
    }

    #[test]
    fn slow_clients_only_train_front_layers() {
        // the inverse of ElasticTrainer's limitation — DepthFL never
        // trains the BACK of the model on slow clients.
        let c = ctx(8, &[4.0]);
        let mut s = DepthFl::new(&c);
        let plans = s.plan_round(0, &c, &[]);
        if let MaskSpec::Tensor(t) = &plans[0].mask {
            let deepest = t
                .iter()
                .enumerate()
                .filter(|(_, &x)| x > 0.0)
                .map(|(i, _)| c.manifest.tensors[i].block)
                .max()
                .unwrap();
            assert!(deepest < 7);
        }
    }
}
