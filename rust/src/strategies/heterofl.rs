//! HeteroFL (Diao et al.): width scaling — each client trains a
//! channel-scaled sub-network matched to its compute budget.
//!
//! Width levels follow the original (p ∈ {1, 1/2, 1/4, 1/8}); a client
//! takes the widest level whose scaled cost fits T_th. Cost model: conv /
//! dense FLOPs scale ~p² (both fan-in and fan-out shrink), bias/1-D ops
//! scale ~p. At our element-granularity masking a width-p sub-network is a
//! *prefix* mask: the leading p² fraction of each weight tensor, the
//! leading p fraction of each 1-D tensor, with output heads keeping full
//! fan-out (fraction p, input-scaled only) — the paper's "uneven scaling"
//! that disturbs aggregation (Table 1 analysis) appears exactly here.

use super::{ClientPlan, FleetCtx, MaskSpec, Strategy};

const LEVELS: [f64; 4] = [1.0, 0.5, 0.25, 0.125];

pub struct HeteroFl {
    /// Chosen width level per client.
    pub widths: Vec<f64>,
}

impl HeteroFl {
    /// `min_width` (registry param `strategy.heterofl.min_width`) floors
    /// the fallback for stragglers that fit no standard level — the
    /// original's 1/8 by default.
    pub fn new(ctx: &FleetCtx, min_width: f64) -> Self {
        let widths = (0..ctx.n_clients())
            .map(|c| {
                let full = ctx.full_round_time(c);
                LEVELS
                    .iter()
                    .copied()
                    .filter(|&p| p >= min_width)
                    .find(|p| full * p * p <= ctx.t_th)
                    .unwrap_or(min_width)
            })
            .collect();
        HeteroFl { widths }
    }

    fn prefix_fractions(ctx: &FleetCtx, p: f64) -> Vec<f32> {
        ctx.manifest
            .tensors
            .iter()
            .map(|t| {
                if t.is_head || t.shape.len() < 2 {
                    p as f32
                } else {
                    (p * p) as f32
                }
            })
            .collect()
    }
}

impl Strategy for HeteroFl {
    fn name(&self) -> &'static str {
        "heterofl"
    }

    fn plan_round(&mut self, _round: usize, ctx: &FleetCtx, _global: &[f32]) -> Vec<ClientPlan> {
        (0..ctx.n_clients())
            .map(|client| {
                let p = self.widths[client];
                ClientPlan {
                    client,
                    exit: ctx.manifest.num_blocks,
                    mask: MaskSpec::Prefix(Self::prefix_fractions(ctx, p)),
                    local_steps: ctx.local_steps,
                    est_time: ctx.full_round_time(client) * p * p,
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::ctx;
    use super::*;

    #[test]
    fn fast_client_full_width_slow_client_narrow() {
        let c = ctx(6, &[1.0, 4.0]);
        let s = HeteroFl::new(&c, 0.125);
        assert_eq!(s.widths[0], 1.0);
        assert!(s.widths[1] <= 0.5, "slow client width {}", s.widths[1]);
    }

    #[test]
    fn scaled_cost_fits_threshold() {
        let c = ctx(6, &[1.0, 2.0, 3.0, 4.0]);
        let mut s = HeteroFl::new(&c, 0.125);
        for p in s.plan_round(0, &c, &[]) {
            assert!(p.est_time <= c.t_th + 1e-9);
        }
    }

    #[test]
    fn weight_tensors_masked_quadratically() {
        let c = ctx(4, &[2.0]);
        let mut s = HeteroFl::new(&c, 0.125);
        let p = s.widths[0];
        let plans = s.plan_round(0, &c, &[]);
        if let MaskSpec::Prefix(f) = &plans[0].mask {
            for (t, &frac) in c.manifest.tensors.iter().zip(f) {
                if t.is_head || t.shape.len() < 2 {
                    assert!((frac as f64 - p).abs() < 1e-6);
                } else {
                    assert!((frac as f64 - p * p).abs() < 1e-6);
                }
            }
        } else {
            panic!()
        }
    }
}
