//! FedDrop: adaptive per-device federated dropout.
//!
//! Federated-dropout baselines (Caldas et al. and successors) shrink each
//! client's update by randomly dropping a fraction of the model's tensors
//! per round. This variant makes the rate *device-adaptive*: a client's
//! drop probability scales with how far its full-model round time
//! overshoots the fleet threshold T_th, so stragglers shed proportionally
//! more work while fast devices train nearly everything. Heads are never
//! dropped (the model must stay trainable end-to-end); only body tensors
//! enter the lottery.
//!
//! Per client each round:
//!
//!   slowness = full_round_time(c) / T_th
//!   rate_c   = clamp(rate · slowness^adapt, 0, 0.9)
//!
//! and each body tensor is dropped independently with probability
//! `rate_c` via the pure hash [`crate::fleet::unit_draw`] — so plans are
//! a deterministic function of (seed, round, client, tensor), which keeps
//! the server's bitwise determinism and kill/resume invariants without
//! any policy state (the strategy is stateless; `policy_state` stays
//! `Null`).
//!
//! The simulated round cost scales with the *kept element fraction*: a
//! client that drops 40% of its body parameters spends roughly 60% of a
//! full round, mirroring how dropout saves backward work in practice.

use super::{ClientPlan, FleetCtx, MaskSpec, Strategy};
use crate::fleet::unit_draw;

pub struct FedDrop {
    /// Base drop rate (registry param `strategy.feddrop.rate`).
    rate: f64,
    /// Slowness exponent (registry param `strategy.feddrop.adapt`):
    /// 0 = uniform dropout, higher = stragglers drop ever more.
    adapt: f64,
    seed: u64,
}

impl FedDrop {
    pub fn new(rate: f64, adapt: f64, seed: u64) -> Self {
        FedDrop { rate, adapt, seed }
    }

    /// The device-adaptive drop probability for one client.
    fn client_rate(&self, ctx: &FleetCtx, client: usize) -> f64 {
        let slowness = ctx.full_round_time(client) / ctx.t_th;
        (self.rate * slowness.powf(self.adapt)).clamp(0.0, 0.9)
    }
}

impl Strategy for FedDrop {
    fn name(&self) -> &'static str {
        "feddrop"
    }

    fn plan_round(&mut self, round: usize, ctx: &FleetCtx, _global: &[f32]) -> Vec<ClientPlan> {
        let m = &ctx.manifest;
        let total: usize = m.tensors.iter().map(|t| t.size).sum();
        (0..ctx.n_clients())
            .map(|client| {
                let rate_c = self.client_rate(ctx, client);
                let mut mask = vec![1.0f32; m.tensors.len()];
                let mut kept = total;
                for (i, t) in m.tensors.iter().enumerate() {
                    if t.is_head {
                        continue; // heads always train: keep the model end-to-end
                    }
                    let u = unit_draw(
                        self.seed ^ 0xFEDD_0001,
                        ((round as u64) << 32) | client as u64,
                        i as u64,
                    );
                    if u < rate_c {
                        mask[i] = 0.0;
                        kept -= t.size;
                    }
                }
                let kept_frac = kept as f64 / total as f64;
                ClientPlan {
                    client,
                    exit: m.num_blocks,
                    mask: MaskSpec::Tensor(mask),
                    local_steps: ctx.local_steps,
                    est_time: ctx.full_round_time(client) * kept_frac,
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::ctx;
    use super::*;

    fn kept(p: &ClientPlan) -> usize {
        p.mask.tensor_coverage().iter().filter(|&&c| c > 0.0).count()
    }

    #[test]
    fn plans_are_deterministic_in_seed_and_round() {
        let c = ctx(8, &[1.0, 2.0, 4.0]);
        let mut a = FedDrop::new(0.3, 1.0, 7);
        let mut b = FedDrop::new(0.3, 1.0, 7);
        let pa = a.plan_round(3, &c, &[]);
        let pb = b.plan_round(3, &c, &[]);
        for (x, y) in pa.iter().zip(&pb) {
            assert_eq!(x.mask.tensor_coverage(), y.mask.tensor_coverage());
            assert_eq!(x.est_time.to_bits(), y.est_time.to_bits());
        }
        let pc = a.plan_round(4, &c, &[]);
        assert!(
            pa.iter().zip(&pc).any(|(x, y)| x.mask.tensor_coverage() != y.mask.tensor_coverage()),
            "different rounds must redraw the dropout lottery"
        );
    }

    #[test]
    fn heads_survive_even_at_max_rate() {
        let c = ctx(6, &[8.0]);
        let mut s = FedDrop::new(0.9, 4.0, 1);
        for p in s.plan_round(0, &c, &[]) {
            let cov = p.mask.tensor_coverage();
            for (i, t) in c.manifest.tensors.iter().enumerate() {
                if t.is_head {
                    assert_eq!(cov[i], 1.0, "head tensor {i} was dropped");
                }
            }
        }
    }

    #[test]
    fn stragglers_drop_more_than_fast_devices() {
        let c = ctx(8, &[1.0, 8.0]);
        let mut s = FedDrop::new(0.4, 1.0, 1);
        // average over rounds: a single draw is too noisy to order reliably
        let (mut fast, mut slow) = (0usize, 0usize);
        for round in 0..20 {
            let plans = s.plan_round(round, &c, &[]);
            fast += kept(&plans[0]);
            slow += kept(&plans[1]);
        }
        assert!(slow < fast, "slow device kept {slow} vs fast {fast}");
    }

    #[test]
    fn est_time_scales_with_kept_fraction() {
        let c = ctx(8, &[4.0]);
        let mut s = FedDrop::new(0.5, 1.0, 3);
        let plans = s.plan_round(0, &c, &[]);
        let p = &plans[0];
        let full = c.full_round_time(0);
        assert!(p.est_time <= full, "dropout must not cost more than full training");
        if kept(p) < c.manifest.tensors.len() {
            assert!(p.est_time < full);
        }
    }

    #[test]
    fn zero_rate_trains_everything() {
        let c = ctx(4, &[1.0, 2.0]);
        let mut s = FedDrop::new(0.0, 1.0, 9);
        for p in s.plan_round(0, &c, &[]) {
            assert!(p.mask.tensor_coverage().iter().all(|&c| c == 1.0));
            assert_eq!(p.est_time, c.full_round_time(p.client));
        }
    }
}
