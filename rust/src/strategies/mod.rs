//! Training strategies: FedEL plus every baseline in the paper's Table 1.
//!
//! A strategy owns all *policy* state (per-client windows, importance
//! histories, utility scores) and, each round, emits one [`ClientPlan`]
//! per participating client: which early exit to use, which tensors to
//! train, how many local steps, and the simulated wall-clock cost on that
//! client's device. The server (fl::server) executes plans through the
//! engine and feeds observations back.

pub mod depthfl;
pub mod elastic;
pub mod fedasync;
pub mod fedavg;
pub mod fedbuff;
pub mod feddrop;
pub mod fedel;
pub mod fiarse;
pub mod heterofl;
pub mod pyramidfl;
pub mod registry;
pub mod timelyfl;

use crate::manifest::Manifest;
use crate::timing::TimingModel;
use crate::util::json::Json;

/// How a plan's tensor mask is expressed.
#[derive(Clone, Debug)]
pub enum MaskSpec {
    /// Per-tensor 0/1 (or fractional) mask of length K.
    Tensor(Vec<f32>),
    /// Per-tensor fractional *prefix* coverage (HeteroFL width scaling).
    Prefix(Vec<f32>),
}

impl MaskSpec {
    /// Element-level [P] mask for the train artifact.
    pub fn expand(&self, m: &Manifest) -> Vec<f32> {
        match self {
            MaskSpec::Tensor(t) => m.expand_mask(t),
            MaskSpec::Prefix(f) => m.expand_prefix_mask(f),
        }
    }

    /// Tensor-level coverage (for aggregation bias / diagnostics):
    /// fraction of each tensor's elements trained.
    pub fn tensor_coverage(&self) -> Vec<f32> {
        match self {
            MaskSpec::Tensor(t) => t.clone(),
            MaskSpec::Prefix(f) => f.clone(),
        }
    }
}

/// One client's marching orders for a round.
#[derive(Clone, Debug)]
pub struct ClientPlan {
    pub client: usize,
    /// Early exit in 1..=num_blocks (head of block exit-1 is the output).
    pub exit: usize,
    pub mask: MaskSpec,
    pub local_steps: usize,
    /// Simulated wall-clock seconds this round costs on the device.
    pub est_time: f64,
}

/// What the server tells strategies after executing a round.
#[derive(Clone, Debug, Default)]
pub struct RoundFeedback {
    /// (client, per-tensor Σ g² from its first local step, mean loss).
    pub per_client: Vec<(usize, Vec<f64>, f64)>,
    /// Global tensor importance I^g (Sec. 4.2) from the aggregated model.
    pub global_importance: Vec<f64>,
}

/// Backward-budget floor as a fraction of the per-step budget (see
/// [`FleetCtx::step_backward_budget`]).
pub const MIN_BUDGET_FRAC: f64 = 0.15;

/// Immutable per-experiment context handed to strategies at build time.
pub struct FleetCtx {
    pub manifest: Manifest,
    /// Timing models: one per client for eager fleets; one per device
    /// *type* for lazy fleets (`fleet.lazy` maps clients onto them). Use
    /// [`FleetCtx::timing`] rather than indexing directly.
    pub timings: Vec<TimingModel>,
    /// The runtime threshold T_th (seconds per round).
    pub t_th: f64,
    pub local_steps: usize,
    pub lr: f64,
    /// Fleet-scale attributes: lazy view, per-client links, availability
    /// windows. `Default::default()` = classic eager fleet.
    pub fleet: crate::fleet::FleetInfo,
}

impl FleetCtx {
    pub fn n_clients(&self) -> usize {
        match &self.fleet.lazy {
            Some(lf) => lf.n,
            None => self.timings.len(),
        }
    }

    /// The timing model backing one client — per client for eager fleets,
    /// per device type for lazy ones.
    pub fn timing(&self, client: usize) -> &TimingModel {
        match &self.fleet.lazy {
            Some(lf) => &self.timings[lf.type_of(client)],
            None => &self.timings[client],
        }
    }

    /// The communication model one client's transfers are priced with:
    /// the experiment-wide `base`, unless a trace gave this client its own
    /// link rates (then those rates apply, inheriting `base`'s latency
    /// when it has one).
    pub fn client_comm(
        &self,
        base: crate::timing::CommModel,
        client: usize,
    ) -> crate::timing::CommModel {
        match self.fleet.links.get(client) {
            Some(&(up, down)) if up > 0.0 || down > 0.0 => {
                let latency_secs = match base {
                    crate::timing::CommModel::Bandwidth { latency_secs, .. } => latency_secs,
                    crate::timing::CommModel::Constant(_) => 0.0,
                };
                crate::timing::CommModel::Bandwidth { up_mbps: up, down_mbps: down, latency_secs }
            }
            _ => base,
        }
    }

    /// Per-step backward budget for a client: (T_th − T_fw·steps)/steps,
    /// floored at a small fraction of the step budget. The floor matters
    /// on extreme stragglers whose *forward pass alone* exceeds T_th at
    /// deep exits — the paper has the same regime (its slowest simulated
    /// type cannot forward the full model within T_th set by the 4x-faster
    /// type) and reports the resulting soft overshoot in Appendix B.3
    /// Table 2 (3–19% mean deviation from T_th). Without the floor such
    /// clients would select nothing and never train deep blocks.
    pub fn step_backward_budget(&self, client: usize, exit: usize) -> f64 {
        let step_budget = self.t_th / self.local_steps as f64;
        let fwd = self.timing(client).forward_time(&self.manifest, exit);
        (step_budget - fwd).max(MIN_BUDGET_FRAC * step_budget)
    }

    /// Simulated per-round cost of training with `backward_time` per step
    /// at a given exit.
    pub fn round_time(&self, client: usize, exit: usize, backward_time: f64) -> f64 {
        let fwd = self.timing(client).forward_time(&self.manifest, exit);
        (fwd + backward_time) * self.local_steps as f64
    }

    /// Full-model round cost on a client (FedAvg).
    pub fn full_round_time(&self, client: usize) -> f64 {
        let tm = self.timing(client);
        self.round_time(client, self.manifest.num_blocks, tm.full_backward_time())
    }

    /// Candidate tensors of a window, ordered deepest-first: the exit
    /// head, then body tensors of blocks front-1 .. end (reverse layout
    /// order within the window).
    pub fn window_order(&self, end: usize, front: usize) -> Vec<usize> {
        let m = &self.manifest;
        let mut order = m.head_tensors_of_block(front - 1);
        order.reverse();
        for b in (end..front).rev() {
            let mut body = m.body_tensors_of_block(b);
            body.reverse();
            order.extend(body);
        }
        order
    }
}

/// How an asynchronous strategy wants the event-driven runner
/// ([`crate::fl::exec::event`]) to aggregate arrivals.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AsyncMode {
    /// FedAsync (Xie et al.): aggregate every arrival immediately with a
    /// staleness-decayed mixing weight `alpha / (1 + s)^staleness_exp`.
    PerArrival { alpha: f64, staleness_exp: f64 },
    /// FedBuff (Nguyen et al.): buffer arrivals and flush every `k`.
    /// `staleness_exp` optionally down-weights each buffered delta by
    /// `1 / (1 + s)^staleness_exp` inside the flush average (0 = off,
    /// the paper's plain data-size weighting).
    Buffered { k: usize, staleness_exp: f64 },
}

/// Declared by strategies that run under the asynchronous executor
/// instead of the synchronous round loop.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AsyncSpec {
    pub mode: AsyncMode,
}

/// The policy interface.
pub trait Strategy {
    fn name(&self) -> &'static str;

    /// Plan the next round given the current global model parameters
    /// (FIARSE reads magnitudes; most strategies ignore them).
    fn plan_round(&mut self, round: usize, ctx: &FleetCtx, global: &[f32]) -> Vec<ClientPlan>;

    /// Observe the executed round (importance signals, losses).
    fn observe(&mut self, _fb: &RoundFeedback, _ctx: &FleetCtx) {}

    /// Aggregation rule this strategy pairs with.
    fn aggregate_rule(&self) -> crate::fl::AggregateRule {
        crate::fl::AggregateRule::Masked
    }

    /// FedProx proximal coefficient (0 = off); applied client-side.
    fn prox_mu(&self) -> f64 {
        0.0
    }

    /// `Some` routes the experiment through the event-driven asynchronous
    /// executor ([`crate::fl::exec::event`]) — clients train at their own
    /// device pace and the server aggregates per this spec — instead of
    /// the synchronous round loop. Default: synchronous.
    fn async_spec(&self) -> Option<AsyncSpec> {
        None
    }

    /// Snapshot the policy's round-dependent mutable state for
    /// checkpointing ([`crate::store`]). `Json::Null` means "stateless":
    /// strategies whose plans are a pure function of construction inputs
    /// (ctx, seed) keep the default. Stateful strategies must round-trip
    /// every field that influences future plans *bitwise* — f64 survives
    /// the JSON writer exactly (shortest round-trip Display); u64 RNG
    /// words go through strings.
    fn policy_state(&self) -> Json {
        Json::Null
    }

    /// Restore a [`Strategy::policy_state`] snapshot onto an
    /// identically-constructed strategy (same ctx/seed/variant), so a
    /// resumed experiment plans exactly what the uninterrupted one would
    /// have. `Null` restores nothing.
    fn restore_policy_state(&mut self, state: &Json) -> anyhow::Result<()> {
        anyhow::ensure!(
            matches!(state, Json::Null),
            "{} is stateless but got a non-null policy snapshot",
            self.name()
        );
        Ok(())
    }
}

/// Full-model work order for one client — the shape FedAvg-style and
/// asynchronous strategies plan, and the one the async executor
/// ([`crate::fl::exec::event`]) dispatches: train everything, at the
/// device's full-model pace. One definition so the strategies'
/// `plan_round` can never drift from what the runner actually executes.
pub(crate) fn full_model_plan(ctx: &FleetCtx, client: usize) -> ClientPlan {
    ClientPlan {
        client,
        exit: ctx.manifest.num_blocks,
        mask: MaskSpec::Tensor(vec![1.0; ctx.manifest.tensors.len()]),
        local_steps: ctx.local_steps,
        est_time: ctx.full_round_time(client),
    }
}

/// Construct a strategy by table-row name with default tunables — a thin
/// wrapper over [`registry::builtin`] for callers without a full config
/// (benches, quick tests). `beta` binds the FedEL family's
/// `harmonize_weight` through the parameter bag (the legacy `cfg.beta`
/// field is gone — the bag is the one path now); everything else takes
/// its registered default.
pub fn by_name(name: &str, ctx: &FleetCtx, beta: f64, seed: u64) -> anyhow::Result<Box<dyn Strategy>> {
    let reg = registry::builtin();
    let bag: Vec<(String, f64)> = reg
        .get(name)
        .into_iter()
        .flat_map(|def| def.params.iter())
        .filter(|p| p.name == "harmonize_weight")
        .map(|p| (registry::StrategyRegistry::param_key(name, p.name), beta))
        .collect();
    reg.build(name, ctx, seed, &bag)
}

/// All Table-1 row names in paper order.
pub fn table1_names() -> Vec<&'static str> {
    vec![
        "fedavg",
        "elastictrainer",
        "heterofl",
        "depthfl",
        "pyramidfl",
        "timelyfl",
        "fiarse",
        "fedel",
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::tests_support::chain_manifest;
    use crate::timing::{DeviceProfile, TimingCfg, TimingModel};

    pub(crate) fn ctx(blocks: usize, clients: &[f64]) -> FleetCtx {
        let m = chain_manifest(blocks, 40);
        let cfg = TimingCfg::default();
        let timings = clients
            .iter()
            .map(|&s| TimingModel::profile(&m, &DeviceProfile::new("d", s, 10.0), &cfg))
            .collect();
        let t_th = {
            let fast = TimingModel::profile(&m, &DeviceProfile::new("f", 1.0, 10.0), &cfg);
            fast.full_round_time(&m, 4)
        };
        FleetCtx {
            manifest: m,
            timings,
            t_th,
            local_steps: 4,
            lr: 0.05,
            fleet: Default::default(),
        }
    }

    #[test]
    fn window_order_is_deepest_first() {
        let c = ctx(4, &[1.0]);
        let order = c.window_order(1, 3);
        // head of block 2 first, then body of block 2, then block 1
        assert_eq!(order[0], 5); // head2 tensor id = 2*2+1
        assert_eq!(order[1], 4); // body2
        assert_eq!(order[2], 2); // body1
        assert_eq!(order.len(), 3);
    }

    #[test]
    fn step_budget_decreases_with_deeper_exit() {
        let c = ctx(6, &[1.0]);
        let b1 = c.step_backward_budget(0, 1);
        let b6 = c.step_backward_budget(0, 6);
        assert!(b1 > b6);
    }

    #[test]
    fn by_name_covers_table1() {
        let c = ctx(4, &[1.0, 2.0]);
        for n in table1_names() {
            let s = by_name(n, &c, 0.6, 1).unwrap();
            assert_eq!(s.name(), n);
        }
        assert!(by_name("nope", &c, 0.6, 1).is_err());
    }

    #[test]
    fn stateless_strategies_round_trip_null_state() {
        let c = ctx(4, &[1.0, 2.0]);
        for n in ["fedavg", "heterofl", "depthfl", "timelyfl", "fiarse"] {
            let mut s = by_name(n, &c, 0.6, 1).unwrap();
            let st = s.policy_state();
            assert_eq!(st, Json::Null, "{n} should be stateless");
            s.restore_policy_state(&st).unwrap();
            assert!(s.restore_policy_state(&Json::Num(1.0)).is_err(), "{n}");
        }
    }

    #[test]
    fn client_comm_prefers_trace_links() {
        use crate::timing::CommModel;
        let mut c = ctx(4, &[1.0, 2.0]);
        let base = CommModel::Bandwidth { up_mbps: 10.0, down_mbps: 50.0, latency_secs: 0.05 };
        // no links recorded: everyone rides the base model
        assert_eq!(c.client_comm(base, 1), base);
        c.fleet.links = vec![(0.0, 0.0), (2.0, 8.0)];
        assert_eq!(c.client_comm(base, 0), base, "zero links inherit the base");
        assert_eq!(
            c.client_comm(base, 1),
            CommModel::Bandwidth { up_mbps: 2.0, down_mbps: 8.0, latency_secs: 0.05 }
        );
        // under a Constant base, per-client links price payloads latency-free
        assert_eq!(
            c.client_comm(CommModel::Constant(30.0), 1),
            CommModel::Bandwidth { up_mbps: 2.0, down_mbps: 8.0, latency_secs: 0.0 }
        );
    }

    #[test]
    fn lazy_ctx_maps_clients_onto_type_timings() {
        use crate::fleet::{FleetView, GeneratorSpec, LazyFleet};
        let mut c = ctx(4, &[1.0, 0.5, 1.0 / 3.0, 0.25]);
        let lf = LazyFleet::new(1000, GeneratorSpec::Uniform, 3).unwrap();
        assert_eq!(lf.device_types().len(), c.timings.len());
        c.fleet.lazy = Some(lf.clone());
        assert_eq!(c.n_clients(), 1000);
        for client in [0usize, 1, 7, 999] {
            let want = lf.type_of(client);
            assert_eq!(
                c.timing(client).device.scale.to_bits(),
                c.timings[want].device.scale.to_bits()
            );
            assert_eq!(lf.profile(client).device.name, lf.device_types()[want].name);
        }
    }

    #[test]
    fn full_round_time_scales_with_device() {
        let c = ctx(4, &[1.0, 2.0]);
        let t0 = c.full_round_time(0);
        let t1 = c.full_round_time(1);
        assert!((t1 / t0 - 2.0).abs() < 1e-9);
    }
}
