//! FIARSE (Wu et al.): importance-aware submodel extraction. Each client
//! trains the top-magnitude fraction of EVERY tensor (submodels are
//! extracted by parameter-magnitude threshold across the whole model, so
//! coverage spans the full depth — that is why the paper reports FIARSE
//! accuracy on par with FedAvg). Crucially — the paper's Table 1 analysis
//! — FIARSE's output layer is FIXED at the model's end and it has no early
//! exits: the backward chain runs the full depth regardless of the
//! submodel fraction, so a straggler pays Σ t_g over every tensor plus its
//! fraction of Σ t_w, and its round time cannot fall below the full
//! forward+chain cost. That unavoidable floor is what keeps FIARSE slower
//! than FedEL on slow clients.
//!
//! At our element granularity the per-tensor magnitude threshold is
//! approximated by a fractional prefix mask with the same coverage ratio.

use super::{ClientPlan, FleetCtx, MaskSpec, Strategy};

/// Minimum submodel fraction for extreme stragglers.
const MIN_FRAC: f64 = 0.3;

pub struct Fiarse {
    /// Per-client submodel fraction r_n (chosen once from the budget).
    pub fractions: Vec<f64>,
}

impl Fiarse {
    pub fn new(ctx: &FleetCtx) -> Self {
        let m = &ctx.manifest;
        let fractions = (0..ctx.n_clients())
            .map(|c| {
                let tm = ctx.timing(c);
                let step_budget = ctx.t_th / ctx.local_steps as f64;
                let fwd = tm.forward_time(m, m.num_blocks);
                let chain: f64 = tm.tensors.iter().map(|t| t.t_g).sum();
                let tw: f64 = tm.tensors.iter().map(|t| t.t_w).sum();
                (((step_budget - fwd - chain) / tw).clamp(MIN_FRAC, 1.0) * 100.0).round()
                    / 100.0
            })
            .collect();
        Fiarse { fractions }
    }

    fn round_time(ctx: &FleetCtx, client: usize, frac: f64) -> f64 {
        let m = &ctx.manifest;
        let tm = ctx.timing(client);
        let chain: f64 = tm.tensors.iter().map(|t| t.t_g).sum();
        let tw: f64 = tm.tensors.iter().map(|t| t.t_w).sum();
        (tm.forward_time(m, m.num_blocks) + chain + frac * tw) * ctx.local_steps as f64
    }
}

impl Strategy for Fiarse {
    fn name(&self) -> &'static str {
        "fiarse"
    }

    fn plan_round(&mut self, _round: usize, ctx: &FleetCtx, _global: &[f32]) -> Vec<ClientPlan> {
        let m = &ctx.manifest;
        let k = m.tensors.len();
        (0..ctx.n_clients())
            .map(|client| {
                let r = self.fractions[client];
                let mut frac = vec![r as f32; k];
                // the fixed output layer always trains fully
                for t in m.head_tensors_of_block(m.num_blocks - 1) {
                    frac[t] = 1.0;
                }
                ClientPlan {
                    client,
                    exit: m.num_blocks,
                    mask: MaskSpec::Prefix(frac),
                    local_steps: ctx.local_steps,
                    est_time: Self::round_time(ctx, client, r),
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::ctx;
    use super::*;

    #[test]
    fn fractions_scale_with_device_speed() {
        let c = ctx(8, &[1.0, 2.0, 4.0]);
        let s = Fiarse::new(&c);
        assert!(s.fractions[0] >= s.fractions[1]);
        assert!(s.fractions[1] >= s.fractions[2]);
        assert!(s.fractions[2] >= MIN_FRAC);
    }

    #[test]
    fn coverage_spans_full_depth() {
        let c = ctx(8, &[4.0]);
        let mut s = Fiarse::new(&c);
        let plans = s.plan_round(0, &c, &[]);
        if let MaskSpec::Prefix(f) = &plans[0].mask {
            // every tensor gets nonzero coverage — no starved depth range
            assert!(f.iter().all(|&x| x > 0.0));
        } else {
            panic!()
        }
        assert_eq!(plans[0].exit, 8, "no early exits in FIARSE");
    }

    #[test]
    fn straggler_round_time_has_chain_floor() {
        // even at the minimum fraction, the full-depth chain keeps FIARSE
        // rounds above the pure-forward cost — the paper's critique.
        let c = ctx(8, &[4.0]);
        let mut s = Fiarse::new(&c);
        let plans = s.plan_round(0, &c, &[]);
        let tm = &c.timings[0];
        let chain: f64 = tm.tensors.iter().map(|t| t.t_g).sum();
        let floor = (tm.forward_time(&c.manifest, 8) + chain) * c.local_steps as f64;
        assert!(plans[0].est_time >= floor);
    }

    #[test]
    fn output_head_fully_covered() {
        let c = ctx(6, &[2.0]);
        let mut s = Fiarse::new(&c);
        let plans = s.plan_round(0, &c, &[]);
        if let MaskSpec::Prefix(f) = &plans[0].mask {
            for t in c.manifest.head_tensors_of_block(5) {
                assert_eq!(f[t], 1.0);
            }
        }
    }
}
