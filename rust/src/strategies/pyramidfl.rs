//! PyramidFL (Li et al.): fine-grained client *selection* — rank clients
//! by a data+system utility and admit only the top fraction each round
//! (plus an exploration slice so unseen clients get scored). Admitted
//! clients train the full model. The paper's Table 1 finding — accuracy ≈
//! FedAvg, speedup only 1.03–1.3× — comes from selection not shrinking
//! per-client work: a selected straggler still costs its full round time.

use crate::util::json::Json;
use crate::util::rng::Rng;

use super::{ClientPlan, FleetCtx, MaskSpec, RoundFeedback, Strategy};

pub struct PyramidFl {
    /// Participation fraction per round.
    pub frac: f64,
    /// Exploration fraction (random picks).
    pub explore: f64,
    /// Last observed loss per client (statistical utility).
    losses: Vec<f64>,
    seen: Vec<bool>,
    rng: Rng,
}

impl PyramidFl {
    /// `frac` / `explore` are the registry params
    /// `strategy.pyramidfl.{frac,explore}`: the admission fraction and the
    /// random-exploration share of it (paper defaults 0.6 / 0.1).
    pub fn new(ctx: &FleetCtx, seed: u64, frac: f64, explore: f64) -> Self {
        PyramidFl {
            frac,
            explore,
            losses: vec![f64::MAX; ctx.n_clients()],
            seen: vec![false; ctx.n_clients()],
            rng: Rng::new(seed ^ 0x9147),
        }
    }

    /// PyramidFL utility: statistical (loss) x system (speed) terms.
    fn utility(&self, ctx: &FleetCtx, client: usize) -> f64 {
        let stat = if self.seen[client] { self.losses[client] } else { f64::MAX };
        let sys = 1.0 / ctx.full_round_time(client).max(1e-9);
        if stat == f64::MAX {
            f64::MAX // unseen clients float to the top
        } else {
            stat * sys.powf(0.5)
        }
    }
}

impl Strategy for PyramidFl {
    fn name(&self) -> &'static str {
        "pyramidfl"
    }

    fn plan_round(&mut self, _round: usize, ctx: &FleetCtx, _global: &[f32]) -> Vec<ClientPlan> {
        let n = ctx.n_clients();
        let k_total = ((n as f64 * self.frac).ceil() as usize).clamp(1, n);
        let k_explore = ((n as f64 * self.explore).round() as usize).min(k_total - 1);
        let k_top = k_total - k_explore;

        let mut ranked: Vec<usize> = (0..n).collect();
        let utils: Vec<f64> = (0..n).map(|c| self.utility(ctx, c)).collect();
        ranked.sort_by(|&a, &b| utils[b].partial_cmp(&utils[a]).unwrap());
        let mut chosen: Vec<usize> = ranked[..k_top].to_vec();
        let rest: Vec<usize> = ranked[k_top..].to_vec();
        if k_explore > 0 && !rest.is_empty() {
            let picks = self.rng.choose_k(rest.len(), k_explore);
            chosen.extend(picks.into_iter().map(|i| rest[i]));
        }

        let kt = ctx.manifest.tensors.len();
        chosen
            .into_iter()
            .map(|client| ClientPlan {
                client,
                exit: ctx.manifest.num_blocks,
                mask: MaskSpec::Tensor(vec![1.0; kt]),
                local_steps: ctx.local_steps,
                est_time: ctx.full_round_time(client),
            })
            .collect()
    }

    fn observe(&mut self, fb: &RoundFeedback, _ctx: &FleetCtx) {
        for (client, _, loss) in &fb.per_client {
            self.losses[*client] = *loss;
            self.seen[*client] = true;
        }
    }

    fn aggregate_rule(&self) -> crate::fl::AggregateRule {
        crate::fl::AggregateRule::FedAvg
    }

    fn policy_state(&self) -> Json {
        Json::obj(vec![
            ("losses", Json::from_f64s(&self.losses)),
            ("seen", Json::from_bools(&self.seen)),
            // xoshiro words exceed f64's integer range: ship as strings.
            (
                "rng",
                Json::Arr(self.rng.state().iter().map(|w| Json::Str(format!("{w}"))).collect()),
            ),
        ])
    }

    fn restore_policy_state(&mut self, state: &Json) -> anyhow::Result<()> {
        if matches!(state, Json::Null) {
            return Ok(()); // fresh strategy (warm start)
        }
        let losses = state.req("losses")?.to_f64_vec()?;
        anyhow::ensure!(losses.len() == self.losses.len(), "pyramidfl snapshot: fleet size");
        let seen = state.req("seen")?.to_bool_vec()?;
        anyhow::ensure!(seen.len() == self.seen.len(), "pyramidfl snapshot: fleet size");
        let words = state.arr("rng")?;
        anyhow::ensure!(words.len() == 4, "pyramidfl snapshot: rng state must be 4 words");
        let mut s = [0u64; 4];
        for (slot, w) in s.iter_mut().zip(words) {
            *slot = w
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("pyramidfl snapshot: rng word not a string"))?
                .parse::<u64>()
                .map_err(|e| anyhow::anyhow!("pyramidfl snapshot: bad rng word: {e}"))?;
        }
        self.losses = losses;
        self.seen = seen;
        self.rng = Rng::from_state(s);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::ctx;
    use super::*;

    #[test]
    fn selects_a_strict_subset() {
        let c = ctx(4, &[1.0, 1.5, 2.0, 2.5, 3.0, 3.5, 4.0, 1.2, 1.7, 2.2]);
        let mut s = PyramidFl::new(&c, 3, 0.6, 0.1);
        let plans = s.plan_round(0, &c, &[]);
        assert!(plans.len() < 10 && !plans.is_empty());
        let mut ids: Vec<usize> = plans.iter().map(|p| p.client).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), plans.len(), "duplicate client selected");
    }

    #[test]
    fn unseen_clients_get_explored_first() {
        let c = ctx(4, &[1.0, 2.0, 3.0, 4.0]);
        let mut s = PyramidFl::new(&c, 5, 0.6, 0.1);
        let mut participated = vec![false; 4];
        for round in 0..6 {
            let plans = s.plan_round(round, &c, &[]);
            let fb = RoundFeedback {
                per_client: plans.iter().map(|p| (p.client, vec![], 1.0)).collect(),
                global_importance: vec![],
            };
            for p in &plans {
                participated[p.client] = true;
            }
            s.observe(&fb, &c);
        }
        assert!(participated.iter().all(|&p| p), "{participated:?}");
    }

    #[test]
    fn policy_state_restores_rng_stream_exactly() {
        // The exploration RNG must continue bit-for-bit after a restore:
        // run a few rounds, snapshot through JSON text, restore onto a
        // fresh strategy, and check the *random* exploration picks match.
        let c = ctx(4, &[1.0, 1.5, 2.0, 2.5, 3.0, 3.5, 4.0, 1.2, 1.7, 2.2]);
        let mut a = PyramidFl::new(&c, 11, 0.6, 0.1);
        for round in 0..3 {
            let plans = a.plan_round(round, &c, &[]);
            let fb = RoundFeedback {
                per_client: plans.iter().map(|p| (p.client, vec![], 0.4)).collect(),
                global_importance: vec![],
            };
            a.observe(&fb, &c);
        }
        let text = a.policy_state().to_string_pretty();
        let snap = Json::parse(&text).unwrap();
        let mut b = PyramidFl::new(&c, 11, 0.6, 0.1);
        b.restore_policy_state(&snap).unwrap();
        for round in 3..8 {
            let pa: Vec<usize> = a.plan_round(round, &c, &[]).iter().map(|p| p.client).collect();
            let pb: Vec<usize> = b.plan_round(round, &c, &[]).iter().map(|p| p.client).collect();
            assert_eq!(pa, pb, "round {round}: exploration picks diverged");
        }
    }

    #[test]
    fn high_loss_clients_rank_higher() {
        let c = ctx(4, &[1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0]);
        let mut s = PyramidFl::new(&c, 7, 0.6, 0.1);
        s.explore = 0.0;
        s.frac = 0.3;
        // everyone seen; client 9 has the largest loss
        for i in 0..10 {
            s.losses[i] = if i == 9 { 10.0 } else { 0.1 };
            s.seen[i] = true;
        }
        let plans = s.plan_round(1, &c, &[]);
        assert!(plans.iter().any(|p| p.client == 9));
    }
}
