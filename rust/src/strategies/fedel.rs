//! FedEL (the paper's contribution, Sec. 4): sliding-window training +
//! window-bounded ElasticTrainer selection + tensor importance adjustment.
//!
//! Per client per round:
//! 1. advance the sliding window (end-edge culling from last round's
//!    selection, front-edge by block budget, reset/rollback at the end —
//!    policy-dependent for the FedEL-C / NoRollback ablations),
//! 2. blend local importance (last Σg², lr-scaled) with the global
//!    importance the server derived from the aggregated model delta
//!    (I = β·I_local + (1−β)·I^g, Sec. 4.2),
//! 3. run the window-bounded DP selection with the per-step backward
//!    budget T_th/steps − T_fw(front),
//! 4. train through the `front` early exit with the selection mask.

use crate::elastic::{blend_importance, importance::local_importance, select, SelectorInput};
use crate::fl::AggregateRule;
use crate::util::json::Json;
use crate::window::{BlockCosts, Window, WindowPolicy, WindowState};

use super::{ClientPlan, FleetCtx, MaskSpec, RoundFeedback, Strategy};

pub struct FedEl {
    pub beta: f64,
    policy: WindowPolicy,
    rule: AggregateRule,
    mu: f64,
    /// Per-client window state (created on first plan).
    windows: Vec<Option<WindowState>>,
    /// Per-client local importance [K] from the last participation.
    local_imp: Vec<Vec<f64>>,
    /// Global importance from the last aggregation.
    global_imp: Vec<f64>,
    /// Per-client block-selected flags from the last round (end edge).
    last_block_sel: Vec<Vec<bool>>,
    /// Per-client per-round block costs (train + forward).
    block_round: Vec<BlockCosts>,
}

impl FedEl {
    pub fn new(
        ctx: &FleetCtx,
        beta: f64,
        policy: WindowPolicy,
        rule: AggregateRule,
        mu: f64,
    ) -> Self {
        let n = ctx.n_clients();
        let k = ctx.manifest.tensors.len();
        let nb = ctx.manifest.num_blocks;
        let steps = ctx.local_steps as f64;
        let block_round: Vec<BlockCosts> = ctx
            .timings
            .iter()
            .map(|tm| {
                BlockCosts::new(
                    tm.block_train.iter().map(|t| t * steps).collect(),
                    tm.block_fwd.iter().map(|t| t * steps).collect(),
                )
            })
            .collect();
        FedEl {
            beta,
            policy,
            rule,
            mu,
            windows: vec![None; n],
            local_imp: vec![vec![1.0; k]; n],
            global_imp: vec![1.0; k],
            last_block_sel: vec![vec![true; nb]; n],
            block_round,
        }
    }

    /// The current window of a client (for traces/diagnostics).
    pub fn window_of(&self, client: usize) -> Option<crate::window::Window> {
        self.windows[client].as_ref().map(|w| w.win)
    }
}

impl Strategy for FedEl {
    fn name(&self) -> &'static str {
        match (self.policy, self.rule, self.mu > 0.0) {
            (WindowPolicy::Collapsed, _, _) => "fedel-c",
            (WindowPolicy::NoRollback, _, _) => "fedel-norollback",
            (_, AggregateRule::FedNova, _) => "fednova+fedel",
            (_, _, true) => "fedprox+fedel",
            _ => "fedel",
        }
    }

    fn plan_round(&mut self, _round: usize, ctx: &FleetCtx, _global: &[f32]) -> Vec<ClientPlan> {
        let m = &ctx.manifest;
        let k = m.tensors.len();
        (0..ctx.n_clients())
            .map(|client| {
                // 1. window init / advance
                let bt = &self.block_round[client];
                let st = self.windows[client].get_or_insert_with(|| {
                    WindowState::new(bt, ctx.t_th, self.policy)
                });
                let win = st.win;
                let front = win.front;

                // 2. importance adjustment (Sec. 4.2)
                let imp = blend_importance(&self.local_imp[client], &self.global_imp, self.beta);

                // 3. window-bounded selection
                let order = ctx.window_order(win.end, front);
                let imp_order: Vec<f64> = order.iter().map(|&t| imp[t]).collect();
                let budget = ctx.step_backward_budget(client, front);
                let sel = select(&SelectorInput {
                    order: &order,
                    importance: &imp_order,
                    budget,
                    timing: ctx.timing(client),
                });

                // Always train the exit head: without it the window's loss
                // cannot adapt (the DP usually picks it anyway — heads are
                // cheap and high-importance).
                let mut mask = vec![0.0f32; k];
                for &t in &sel.tensors {
                    mask[t] = 1.0;
                }
                for t in m.head_tensors_of_block(front - 1) {
                    mask[t] = 1.0;
                }

                // bookkeeping for the next round's end edge
                let mut block_sel = vec![false; m.num_blocks];
                for &t in &sel.tensors {
                    if !m.tensors[t].is_head {
                        block_sel[m.tensors[t].block] = true;
                    }
                }
                self.last_block_sel[client] = block_sel.clone();
                let st = self.windows[client].as_mut().unwrap();
                st.advance(&self.block_round[client], ctx.t_th, &block_sel);

                let est_time = ctx.round_time(client, front, sel.backward_time);
                ClientPlan {
                    client,
                    exit: front,
                    mask: MaskSpec::Tensor(mask),
                    local_steps: ctx.local_steps,
                    est_time,
                }
            })
            .collect()
    }

    fn observe(&mut self, fb: &RoundFeedback, ctx: &FleetCtx) {
        for (client, sq, _) in &fb.per_client {
            self.local_imp[*client] = local_importance(sq, ctx.lr);
        }
        if !fb.global_importance.is_empty() {
            self.global_imp = fb.global_importance.clone();
        }
    }

    fn aggregate_rule(&self) -> AggregateRule {
        self.rule
    }

    fn prox_mu(&self) -> f64 {
        self.mu
    }

    fn policy_state(&self) -> Json {
        let windows = Json::Arr(
            self.windows
                .iter()
                .map(|w| match w {
                    None => Json::Null,
                    Some(st) => Json::obj(vec![
                        ("end", Json::Num(st.win.end as f64)),
                        ("front", Json::Num(st.win.front as f64)),
                        ("rounds", Json::Num(st.rounds as f64)),
                        ("resets", Json::Num(st.resets as f64)),
                    ]),
                })
                .collect(),
        );
        Json::obj(vec![
            ("windows", windows),
            (
                "local_imp",
                Json::Arr(self.local_imp.iter().map(|v| Json::from_f64s(v)).collect()),
            ),
            ("global_imp", Json::from_f64s(&self.global_imp)),
            (
                "last_block_sel",
                Json::Arr(self.last_block_sel.iter().map(|v| Json::from_bools(v)).collect()),
            ),
        ])
    }

    fn restore_policy_state(&mut self, state: &Json) -> anyhow::Result<()> {
        if matches!(state, Json::Null) {
            return Ok(()); // fresh strategy (warm start)
        }
        let n = self.windows.len();
        let k = self.global_imp.len();
        let nb = self.last_block_sel.first().map(|b| b.len()).unwrap_or(0);
        let windows = state.arr("windows")?;
        anyhow::ensure!(windows.len() == n, "fedel snapshot: fleet size mismatch");
        let windows: Vec<Option<WindowState>> = windows
            .iter()
            .map(|w| match w {
                Json::Null => Ok(None),
                w => Ok(Some(WindowState {
                    win: Window { end: w.u("end")?, front: w.u("front")? },
                    policy: self.policy,
                    rounds: w.u("rounds")?,
                    resets: w.u("resets")?,
                })),
            })
            .collect::<anyhow::Result<_>>()?;
        let local_imp: Vec<Vec<f64>> = state
            .arr("local_imp")?
            .iter()
            .map(Json::to_f64_vec)
            .collect::<anyhow::Result<_>>()?;
        anyhow::ensure!(
            local_imp.len() == n && local_imp.iter().all(|v| v.len() == k),
            "fedel snapshot: importance shape mismatch"
        );
        let global_imp = state.req("global_imp")?.to_f64_vec()?;
        anyhow::ensure!(global_imp.len() == k, "fedel snapshot: global importance len");
        let last_block_sel: Vec<Vec<bool>> = state
            .arr("last_block_sel")?
            .iter()
            .map(Json::to_bool_vec)
            .collect::<anyhow::Result<_>>()?;
        anyhow::ensure!(
            last_block_sel.len() == n && last_block_sel.iter().all(|v| v.len() == nb),
            "fedel snapshot: block selection shape mismatch"
        );
        self.windows = windows;
        self.local_imp = local_imp;
        self.global_imp = global_imp;
        self.last_block_sel = last_block_sel;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::ctx;
    use super::*;

    fn fedel(c: &FleetCtx) -> FedEl {
        FedEl::new(c, 0.6, WindowPolicy::FedEl, AggregateRule::Masked, 0.0)
    }

    #[test]
    fn all_clients_meet_budget() {
        // Budget is met modulo the unavoidable forward cost: est_time must
        // not exceed max(T_th, fwd·steps) + floor slack (Appendix B.3
        // Table 2 reports the same soft overshoot on extreme stragglers).
        let c = ctx(8, &[1.0, 2.0, 4.0]);
        let mut s = fedel(&c);
        for round in 0..6 {
            let plans = s.plan_round(round, &c, &[]);
            for p in &plans {
                let fwd = c.timings[p.client].forward_time(&c.manifest, p.exit)
                    * c.local_steps as f64;
                let cap = c.t_th.max(fwd) + crate::strategies::MIN_BUDGET_FRAC * c.t_th;
                assert!(
                    p.est_time <= cap * 1.05,
                    "round {round} client {} time {} > cap {cap} (T_th {})",
                    p.client,
                    p.est_time,
                    c.t_th
                );
            }
        }
    }

    #[test]
    fn windows_march_and_cover_all_blocks() {
        // A slow client with *adaptive* importance (never-trained tensors
        // keep high gradient mass, as in real training) must eventually
        // select tensors from every block as its window slides and resets.
        let c = ctx(8, &[4.0]);
        let mut s = fedel(&c);
        let k = c.manifest.tensors.len();
        let mut covered = vec![false; 8];
        for round in 0..40 {
            let plans = s.plan_round(round, &c, &[]);
            if let MaskSpec::Tensor(t) = &plans[0].mask {
                for (i, &x) in t.iter().enumerate() {
                    if x > 0.0 {
                        covered[c.manifest.tensors[i].block] = true;
                    }
                }
            }
            // emulate training dynamics: covered blocks' gradients shrink
            let sq: Vec<f64> = (0..k)
                .map(|i| if covered[c.manifest.tensors[i].block] { 0.05 } else { 1.0 })
                .collect();
            s.observe(
                &RoundFeedback {
                    per_client: vec![(0, sq, 1.0)],
                    global_importance: (0..k)
                        .map(|i| if covered[c.manifest.tensors[i].block] { 0.05 } else { 1.0 })
                        .collect(),
                },
                &c,
            );
        }
        // Structural guarantee: the sliding window + reset cycle gives
        // (nearly) every block trained tensors even on a 4x straggler.
        // One block can sit at the chain-cost boundary of its window
        // geometry (the paper's Fig 10 traces show the same sparsity),
        // so require >= nb-1 of nb covered.
        let n_covered = covered.iter().filter(|&&b| b).count();
        assert!(
            n_covered >= 7,
            "sliding windows left blocks untrained: {covered:?}"
        );
    }

    #[test]
    fn fast_client_trains_whole_model() {
        let c = ctx(6, &[1.0]);
        let mut s = fedel(&c);
        let plans = s.plan_round(0, &c, &[]);
        assert_eq!(plans[0].exit, 6, "T_th == its own full time -> full window");
    }

    #[test]
    fn exit_head_always_trained() {
        let c = ctx(8, &[3.0]);
        let mut s = fedel(&c);
        for round in 0..5 {
            let plans = s.plan_round(round, &c, &[]);
            let exit = plans[0].exit;
            if let MaskSpec::Tensor(t) = &plans[0].mask {
                for h in c.manifest.head_tensors_of_block(exit - 1) {
                    assert!(t[h] > 0.0, "round {round}: exit head frozen");
                }
            }
        }
    }

    #[test]
    fn beta_blending_uses_global_importance() {
        let c = ctx(6, &[1.0]);
        let k = c.manifest.tensors.len();
        let mut s = FedEl::new(&c, 0.0, WindowPolicy::FedEl, AggregateRule::Masked, 0.0);
        // fully global focus: a global importance spike on tensor 4 must
        // show in the selection even with zero local signal there.
        let mut gi = vec![0.0; k];
        gi[4] = 10.0;
        s.observe(
            &RoundFeedback { per_client: vec![(0, vec![0.0; k], 1.0)], global_importance: gi },
            &c,
        );
        let plans = s.plan_round(1, &c, &[]);
        if let MaskSpec::Tensor(t) = &plans[0].mask {
            assert!(t[4] > 0.0);
        }
    }

    #[test]
    fn collapsed_policy_produces_disjoint_exits() {
        let c = ctx(8, &[4.0]);
        let mut s = FedEl::new(&c, 0.6, WindowPolicy::Collapsed, AggregateRule::Masked, 0.0);
        let e0 = s.plan_round(0, &c, &[])[0].exit;
        let e1 = s.plan_round(1, &c, &[])[0].exit;
        assert!(e1 > e0, "collapsed window must move strictly forward: {e0} -> {e1}");
    }

    #[test]
    fn policy_state_round_trips_through_json_text() {
        // Warm a strategy through several rounds, snapshot, push the
        // snapshot through the actual JSON writer+parser (what the run
        // store does), restore onto a fresh strategy, and check both plan
        // identically from there on — the resume invariant at policy level.
        let cx = ctx(8, &[1.0, 2.0, 4.0]);
        let k = cx.manifest.tensors.len();
        let mut a = fedel(&cx);
        for round in 0..4 {
            let plans = a.plan_round(round, &cx, &[]);
            let sq: Vec<f64> = (0..k).map(|i| 0.1 + (i % 3) as f64 * 0.7).collect();
            a.observe(
                &RoundFeedback {
                    per_client: plans.iter().map(|p| (p.client, sq.clone(), 1.0)).collect(),
                    global_importance: (0..k).map(|i| 0.5 + (i % 5) as f64 * 0.3).collect(),
                },
                &cx,
            );
        }
        let text = a.policy_state().to_string_pretty();
        let snap = crate::util::json::Json::parse(&text).unwrap();
        let mut b = fedel(&cx);
        b.restore_policy_state(&snap).unwrap();
        for round in 4..7 {
            let pa = a.plan_round(round, &cx, &[]);
            let pb = b.plan_round(round, &cx, &[]);
            assert_eq!(pa.len(), pb.len());
            for (x, y) in pa.iter().zip(&pb) {
                assert_eq!(x.client, y.client);
                assert_eq!(x.exit, y.exit, "round {round}");
                assert_eq!(x.est_time.to_bits(), y.est_time.to_bits(), "round {round}");
                assert_eq!(x.mask.tensor_coverage(), y.mask.tensor_coverage(), "round {round}");
            }
        }
    }

    #[test]
    fn restore_rejects_wrong_fleet_size() {
        let cx = ctx(8, &[1.0, 2.0]);
        let other = ctx(8, &[1.0]);
        let mut a = fedel(&cx);
        a.plan_round(0, &cx, &[]);
        let snap = a.policy_state();
        let mut b = fedel(&other);
        assert!(b.restore_policy_state(&snap).is_err());
    }

    #[test]
    fn names_for_ablations() {
        let c = ctx(4, &[1.0]);
        assert_eq!(fedel(&c).name(), "fedel");
        assert_eq!(
            FedEl::new(&c, 0.6, WindowPolicy::Collapsed, AggregateRule::Masked, 0.0).name(),
            "fedel-c"
        );
        assert_eq!(
            FedEl::new(&c, 0.6, WindowPolicy::NoRollback, AggregateRule::Masked, 0.0).name(),
            "fedel-norollback"
        );
        assert_eq!(
            FedEl::new(&c, 0.6, WindowPolicy::FedEl, AggregateRule::FedNova, 0.0).name(),
            "fednova+fedel"
        );
    }
}
