//! Synthetic federated datasets (DESIGN.md §4 substitutions).
//!
//! Each paper dataset is replaced by a *generative* task spec exercising
//! the same code path, so a 100-client fleet costs no storage: a client
//! holds a class distribution (Dirichlet(α), the paper's partitioner) and
//! samples batches on demand from class-conditional generators.
//!
//! * classification (CIFAR10-like / TinyImageNet-like / Speech-like):
//!   class c ⇒ x = sep·proto_c + ε, prototypes ~ N(0, I) unit-normalized,
//!   ε ~ N(0, σ²). Linearly separable at sep ≫ σ, hard at sep ≪ σ.
//! * lm (Reddit-like): order-1 Markov stream over the vocab with
//!   per-topic affine transition rules; a client's topic mixture is its
//!   Dirichlet draw, so data heterogeneity maps to transition-rule
//!   heterogeneity exactly as label-skew maps to class skew.

use crate::manifest::{Manifest, Task};
use crate::util::rng::Rng;

/// Generative spec of one task (shared across clients).
#[derive(Clone, Debug)]
pub struct TaskSpec {
    pub task: Task,
    pub input_elems: usize,
    pub num_classes: usize,
    /// Class separation (classification) / rule strength (lm).
    pub sep: f32,
    /// Noise std.
    pub noise: f32,
    prototypes: Vec<Vec<f32>>, // classification: one per class
    seq: usize,                // lm: sequence length
}

impl TaskSpec {
    pub fn for_manifest(m: &Manifest, seed: u64) -> TaskSpec {
        let input_elems: usize = m.input_shape.iter().product();
        let mut rng = Rng::new(seed ^ 0xDA7A);
        match m.task {
            Task::Classification => {
                let prototypes = (0..m.num_classes)
                    .map(|_| {
                        let mut v = if m.input_shape.len() == 3 {
                            // Image-like HWC input: a translation-invariant
                            // conv+GAP network provably cannot separate iid
                            // white-noise prototypes, so the class signal
                            // must be LOW-FREQUENCY: draw a coarse 4x4xC
                            // grid and bilinearly upsample it (matches the
                            // python-side learnability study; DESIGN.md §4).
                            smooth_prototype(&m.input_shape, &mut rng)
                        } else {
                            (0..input_elems).map(|_| rng.normal_f32()).collect()
                        };
                        // normalize to per-ELEMENT unit std so `sep` is the
                        // per-pixel signal-to-noise ratio
                        let mean =
                            v.iter().map(|&x| x as f64).sum::<f64>() / v.len() as f64;
                        let std = (v
                            .iter()
                            .map(|&x| (x as f64 - mean).powi(2))
                            .sum::<f64>()
                            / v.len() as f64)
                            .sqrt() as f32;
                        for x in &mut v {
                            *x /= std.max(1e-6);
                        }
                        v
                    })
                    .collect();
                TaskSpec {
                    task: Task::Classification,
                    input_elems,
                    num_classes: m.num_classes,
                    // per-pixel SNR 0.6: hard enough that partial-training
                    // pathologies (Limitations #1/#2) show as accuracy
                    // gaps, easy enough to converge in bench-scale rounds
                    sep: 0.6,
                    noise: 1.0,
                    prototypes,
                    seq: 0,
                }
            }
            Task::Lm => TaskSpec {
                task: Task::Lm,
                input_elems,
                num_classes: m.num_classes,
                sep: 0.9, // P(rule-following transition)
                noise: 0.0,
                prototypes: Vec::new(),
                seq: *m.input_shape.last().unwrap(),
            },
        }
    }

    /// Number of "topics" for lm heterogeneity (affine transition rules).
    pub fn lm_topics(&self) -> usize {
        8
    }

    fn lm_next(&self, topic: usize, tok: usize, rng: &mut Rng) -> usize {
        let v = self.num_classes;
        if rng.f32() < self.sep {
            // topic-specific affine rule: deterministic, learnable
            let a = 2 * topic + 3;
            let b = 17 * (topic + 1);
            (tok * a + b) % v
        } else {
            rng.below(v)
        }
    }
}

/// One client's data distribution.
#[derive(Clone, Debug)]
pub struct ClientData {
    pub id: usize,
    /// Class (or topic) mixture — the Dirichlet draw.
    pub mixture: Vec<f64>,
    /// Nominal local dataset size (drives FedAvg/FedNova weights).
    pub num_samples: usize,
    seed: u64,
}

impl ClientData {
    /// Sample one batch: x flattened [batch * input_elems], y [label_len].
    pub fn sample_batch(
        &self,
        spec: &TaskSpec,
        m: &Manifest,
        step: u64,
    ) -> (Vec<f32>, Vec<i32>) {
        let mut rng = Rng::new(self.seed ^ step.wrapping_mul(0x9E3779B97F4A7C15));
        sample_from_mixture(spec, m, &self.mixture, &mut rng)
    }
}

fn sample_from_mixture(
    spec: &TaskSpec,
    m: &Manifest,
    mixture: &[f64],
    rng: &mut Rng,
) -> (Vec<f32>, Vec<i32>) {
    match spec.task {
        Task::Classification => {
            let mut x = Vec::with_capacity(m.batch * spec.input_elems);
            let mut y = Vec::with_capacity(m.batch);
            for _ in 0..m.batch {
                let c = rng.categorical(mixture);
                y.push(c as i32);
                let proto = &spec.prototypes[c];
                for j in 0..spec.input_elems {
                    x.push(spec.sep * proto[j] + spec.noise * rng.normal_f32());
                }
            }
            (x, y)
        }
        Task::Lm => {
            // x: [batch, seq] token ids as f32; y: next-token per position.
            let mut x = Vec::with_capacity(m.batch * spec.seq);
            let mut y = Vec::with_capacity(m.batch * spec.seq);
            for _ in 0..m.batch {
                let topic = rng.categorical(mixture);
                let mut tok = rng.below(spec.num_classes);
                for _ in 0..spec.seq {
                    x.push(tok as f32);
                    tok = spec.lm_next(topic, tok, rng);
                    y.push(tok as i32);
                }
            }
            (x, y)
        }
    }
}

/// Smooth low-frequency prototype for HWC image inputs: coarse GRID x GRID
/// grid per channel, bilinearly upsampled to the full resolution.
fn smooth_prototype(shape: &[usize], rng: &mut Rng) -> Vec<f32> {
    const GRID: usize = 4;
    let (h, w, c) = (shape[0], shape[1], shape[2]);
    let coarse: Vec<f32> = (0..GRID * GRID * c).map(|_| rng.normal_f32()).collect();
    let sample = |gy: usize, gx: usize, ch: usize| coarse[(gy * GRID + gx) * c + ch];
    let mut out = Vec::with_capacity(h * w * c);
    for i in 0..h {
        let fy = i as f32 / (h - 1).max(1) as f32 * (GRID - 1) as f32;
        let (y0, ty) = (fy.floor() as usize, fy.fract());
        let y1 = (y0 + 1).min(GRID - 1);
        for j in 0..w {
            let fx = j as f32 / (w - 1).max(1) as f32 * (GRID - 1) as f32;
            let (x0, tx) = (fx.floor() as usize, fx.fract());
            let x1 = (x0 + 1).min(GRID - 1);
            for ch in 0..c {
                let top = sample(y0, x0, ch) * (1.0 - tx) + sample(y0, x1, ch) * tx;
                let bot = sample(y1, x0, ch) * (1.0 - tx) + sample(y1, x1, ch) * tx;
                out.push(top * (1.0 - ty) + bot * ty);
            }
        }
    }
    out
}

/// The federated dataset: per-client distributions + a held-out test set.
pub struct FedDataset {
    pub spec: TaskSpec,
    pub clients: Vec<ClientData>,
    /// Pre-generated IID test batches (deterministic eval).
    pub test_batches: Vec<(Vec<f32>, Vec<i32>)>,
    /// Set on lazily-materialized datasets ([`FedDataset::build_lazy`]):
    /// `clients` stays empty and [`FedDataset::client`] derives each
    /// distribution on demand as a pure function of (seed, id).
    lazy: Option<LazyClients>,
}

/// Generator spec for a lazily-materialized client population.
struct LazyClients {
    n: usize,
    alpha: f64,
    cats: usize,
    seed: u64,
}

impl FedDataset {
    /// Dirichlet(alpha) non-iid partition over `n_clients` (paper α=0.1).
    pub fn build(
        m: &Manifest,
        n_clients: usize,
        alpha: f64,
        test_batches: usize,
        seed: u64,
    ) -> FedDataset {
        let spec = TaskSpec::for_manifest(m, seed);
        let mut rng = Rng::new(seed ^ 0xC11E17);
        let cats = match spec.task {
            Task::Classification => spec.num_classes,
            Task::Lm => spec.lm_topics(),
        };
        let clients = (0..n_clients)
            .map(|id| ClientData {
                id,
                mixture: rng.dirichlet(alpha, cats),
                num_samples: 200 + rng.below(300),
                seed: rng.next_u64(),
            })
            .collect();
        let uniform = vec![1.0 / cats as f64; cats];
        let mut test_rng = Rng::new(seed ^ 0x7E57);
        let test_batches = (0..test_batches)
            .map(|_| sample_from_mixture(&spec, m, &uniform, &mut test_rng))
            .collect();
        FedDataset { spec, clients, test_batches, lazy: None }
    }

    /// Like [`FedDataset::build`] but O(1) in `n_clients`: no per-client
    /// state is allocated up front. Each client's Dirichlet mixture is
    /// derived on demand from a per-id RNG instead of the shared sequential
    /// stream, so a lazy dataset is NOT bitwise-identical to an eager one —
    /// lazy fleets are a distinct scenario, not a drop-in memory
    /// optimization of an existing config.
    pub fn build_lazy(
        m: &Manifest,
        n_clients: usize,
        alpha: f64,
        test_batches: usize,
        seed: u64,
    ) -> FedDataset {
        let spec = TaskSpec::for_manifest(m, seed);
        let cats = match spec.task {
            Task::Classification => spec.num_classes,
            Task::Lm => spec.lm_topics(),
        };
        let uniform = vec![1.0 / cats as f64; cats];
        let mut test_rng = Rng::new(seed ^ 0x7E57);
        let test_batches = (0..test_batches)
            .map(|_| sample_from_mixture(&spec, m, &uniform, &mut test_rng))
            .collect();
        FedDataset {
            spec,
            clients: Vec::new(),
            test_batches,
            lazy: Some(LazyClients { n: n_clients, alpha, cats, seed }),
        }
    }

    /// Number of clients, whether materialized or lazy.
    pub fn n_clients(&self) -> usize {
        match &self.lazy {
            Some(l) => l.n,
            None => self.clients.len(),
        }
    }

    /// The distribution of client `id`. On eager datasets this clones the
    /// stored entry; on lazy datasets it derives the entry purely from
    /// (seed, id), so repeated calls are identical and nothing is cached.
    pub fn client(&self, id: usize) -> ClientData {
        match &self.lazy {
            None => self.clients[id].clone(),
            Some(l) => {
                assert!(id < l.n, "client {id} out of range for lazy fleet of {}", l.n);
                let mut s = l.seed
                    ^ 0xC11E17
                    ^ (id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
                let mut rng = Rng::new(crate::util::rng::splitmix64(&mut s));
                ClientData {
                    id,
                    mixture: rng.dirichlet(l.alpha, l.cats),
                    num_samples: 200 + rng.below(300),
                    seed: rng.next_u64(),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::tests_support::{chain_manifest, toy_manifest};

    #[test]
    fn batches_have_right_shapes() {
        let m = toy_manifest();
        let ds = FedDataset::build(&m, 4, 0.1, 2, 1);
        let (x, y) = ds.clients[0].sample_batch(&ds.spec, &m, 0);
        assert_eq!(x.len(), m.batch * m.input_shape.iter().product::<usize>());
        assert_eq!(y.len(), m.label_len);
        for &c in &y {
            assert!((0..m.num_classes as i32).contains(&c));
        }
    }

    #[test]
    fn sampling_is_deterministic_per_step() {
        let m = toy_manifest();
        let ds = FedDataset::build(&m, 2, 0.1, 1, 7);
        let a = ds.clients[0].sample_batch(&ds.spec, &m, 3);
        let b = ds.clients[0].sample_batch(&ds.spec, &m, 3);
        assert_eq!(a, b);
        let c = ds.clients[0].sample_batch(&ds.spec, &m, 4);
        assert_ne!(a, c);
    }

    #[test]
    fn dirichlet_alpha_small_concentrates_labels() {
        let m = chain_manifest(3, 10); // 4 classes
        let ds = FedDataset::build(&m, 20, 0.05, 1, 3);
        // most clients should be dominated by one class
        let dominated = ds
            .clients
            .iter()
            .filter(|c| c.mixture.iter().cloned().fold(0.0, f64::max) > 0.7)
            .count();
        assert!(dominated > 10, "only {dominated}/20 dominated");
    }

    #[test]
    fn clients_have_distinct_distributions() {
        let m = toy_manifest();
        let ds = FedDataset::build(&m, 3, 0.1, 1, 9);
        assert_ne!(ds.clients[0].mixture, ds.clients[1].mixture);
        assert_ne!(ds.clients[0].seed, ds.clients[1].seed);
    }

    #[test]
    fn lazy_dataset_is_pure_and_allocates_no_client_state() {
        let m = toy_manifest();
        let ds = FedDataset::build_lazy(&m, 1_000_000, 0.1, 2, 11);
        assert!(ds.clients.is_empty());
        assert_eq!(ds.n_clients(), 1_000_000);
        let a = ds.client(999_999);
        let b = ds.client(999_999);
        assert_eq!(a.mixture, b.mixture);
        assert_eq!(a.seed, b.seed);
        assert_ne!(ds.client(0).mixture, ds.client(1).mixture);
        // test batches match the eager build (same derivation)
        let eager = FedDataset::build(&m, 2, 0.1, 2, 11);
        assert_eq!(ds.test_batches, eager.test_batches);
    }

    #[test]
    fn test_batches_deterministic_across_builds() {
        let m = toy_manifest();
        let a = FedDataset::build(&m, 2, 0.1, 3, 42);
        let b = FedDataset::build(&m, 2, 0.1, 3, 42);
        assert_eq!(a.test_batches, b.test_batches);
    }

    #[test]
    fn classification_classes_are_separable() {
        // same-class samples must be closer than cross-class on average
        let m = toy_manifest();
        let ds = FedDataset::build(&m, 1, 100.0, 8, 5);
        let spec = &ds.spec;
        let d = spec.input_elems;
        let mut same = Vec::new();
        let mut cross = Vec::new();
        for (x, y) in &ds.test_batches {
            for i in 0..y.len() {
                for j in (i + 1)..y.len() {
                    let dist: f64 = (0..d)
                        .map(|k| (x[i * d + k] - x[j * d + k]) as f64)
                        .map(|v| v * v)
                        .sum();
                    if y[i] == y[j] {
                        same.push(dist);
                    } else {
                        cross.push(dist);
                    }
                }
            }
        }
        let ms = crate::util::stats::mean(&same);
        let mc = crate::util::stats::mean(&cross);
        assert!(mc > ms * 1.5, "same {ms} cross {mc}");
    }
}
