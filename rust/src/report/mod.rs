//! Paper-style reporting: aligned tables with paper-vs-measured rows,
//! figure data series (CSV), and the Table 1 row assembly (accuracy, time,
//! speedup vs FedAvg at matched accuracy).

pub mod bench;

use crate::fl::server::ExperimentResult;

/// A plain-text aligned table.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "ragged table row");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// One assembled Table-1 row.
#[derive(Clone, Debug)]
pub struct Table1Row {
    pub method: String,
    pub final_acc: f64,
    pub final_ppl: f64,
    /// Simulated seconds to the comparison target (or total if never hit).
    pub time_secs: f64,
    pub speedup_vs_fedavg: Option<f64>,
}

/// Assemble Table-1 rows: time is time-to-target-accuracy where target =
/// `target_frac` x the FedAvg final accuracy (the paper compares methods
/// at matched accuracy); methods that never reach the target report their
/// total time. Speedup = FedAvg time / method time.
pub fn table1_rows(
    results: &[ExperimentResult],
    target_frac: f64,
    lm: bool,
) -> Vec<Table1Row> {
    let fedavg = results
        .iter()
        .find(|r| r.strategy == "fedavg")
        .expect("table1 needs a fedavg run");
    let (fedavg_time, target) = if lm {
        let target = fedavg.final_perplexity() / target_frac;
        let t = fedavg
            .time_to_perplexity(target)
            .unwrap_or(fedavg.sim_total_secs);
        (t, target)
    } else {
        let target = fedavg.final_acc * target_frac;
        let t = fedavg.time_to_accuracy(target).unwrap_or(fedavg.sim_total_secs);
        (t, target)
    };
    results
        .iter()
        .map(|r| {
            let time_secs = if lm {
                r.time_to_perplexity(target).unwrap_or(r.sim_total_secs)
            } else {
                r.time_to_accuracy(target).unwrap_or(r.sim_total_secs)
            };
            Table1Row {
                method: r.strategy.clone(),
                final_acc: r.final_acc,
                final_ppl: r.final_perplexity(),
                time_secs,
                speedup_vs_fedavg: if r.strategy == "fedavg" {
                    None
                } else {
                    Some(fedavg_time / time_secs.max(1e-9))
                },
            }
        })
        .collect()
}

/// Render Table-1 rows in the paper's format.
pub fn render_table1(title: &str, rows: &[Table1Row], lm: bool) -> Table {
    let metric = if lm { "Perp.(down)" } else { "Acc.(up)" };
    let mut t = Table::new(title, &["Method", metric, "Time", "Speedup"]);
    for r in rows {
        t.row(vec![
            r.method.clone(),
            if lm {
                format!("{:.2}", r.final_ppl)
            } else {
                format!("{:.2}%", 100.0 * r.final_acc)
            },
            crate::util::fmt_hours(r.time_secs),
            crate::util::fmt_speedup(r.speedup_vs_fedavg),
        ]);
    }
    t
}

/// Compare two *stored* runs at matched accuracy ([`crate::store`]): one
/// row per run with final accuracy, simulated total, and time-to-target,
/// where target = `target` or 95% of the lesser final accuracy. The
/// second return value is the speedup of `a` over `b` at the target
/// (None when either run never reaches it).
pub fn runs_compare(
    a: &crate::store::schema::RunManifest,
    b: &crate::store::schema::RunManifest,
    target: Option<f64>,
) -> (Table, Option<f64>) {
    use crate::store::schema::time_to_accuracy;
    let lesser = a.final_acc().unwrap_or(0.0).min(b.final_acc().unwrap_or(0.0));
    let target = target.unwrap_or(0.95 * lesser);
    let mut t = Table::new(
        &format!("runs compare @ acc {:.3}", target),
        &["run", "strategy", "rounds", "final acc", "sim total", "time-to-target"],
    );
    let times: Vec<Option<f64>> = [a, b]
        .iter()
        .map(|m| {
            let tta = time_to_accuracy(&m.records, target);
            t.row(vec![
                m.id.clone(),
                m.strategy.clone(),
                format!("{}", m.records.len()),
                m.final_acc()
                    .map(|x| format!("{:.2}%", 100.0 * x))
                    .unwrap_or_else(|| "n/a".into()),
                crate::util::fmt_hours(m.sim_time()),
                tta.map(crate::util::fmt_hours).unwrap_or_else(|| "never".into()),
            ]);
            tta
        })
        .collect();
    let speedup = match (times[0], times[1]) {
        (Some(ta), Some(tb)) => Some(tb / ta.max(1e-9)),
        _ => None,
    };
    (t, speedup)
}

/// Print a "paper reports" reference line under a reproduced table.
pub fn paper_note(lines: &[&str]) {
    println!("  paper reference:");
    for l in lines {
        println!("    {l}");
    }
    println!();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::server::RoundRecord;

    fn fake_result(name: &str, times_accs: &[(f64, f64)], final_acc: f64) -> ExperimentResult {
        let records = times_accs
            .iter()
            .enumerate()
            .map(|(i, &(t, a))| RoundRecord {
                round: i,
                round_secs: 0.0,
                sim_time: t,
                mean_train_loss: 0.0,
                participants: 1,
                mean_coverage: 1.0,
                o1: 0.0,
                eval_acc: Some(a),
                eval_loss: Some(1.0),
                client_secs: vec![],
            })
            .collect();
        ExperimentResult {
            strategy: name.into(),
            records,
            sim_total_secs: times_accs.last().map(|&(t, _)| t).unwrap_or(0.0),
            final_acc,
            final_loss: 1.0,
            final_params: vec![],
            selections: vec![],
        }
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["a", "bb"]);
        t.row(vec!["xxx".into(), "y".into()]);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.contains("xxx"));
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_rows_rejected() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn speedup_vs_fedavg_at_matched_accuracy() {
        let fedavg = fake_result("fedavg", &[(100.0, 0.3), (200.0, 0.6)], 0.6);
        let fedel = fake_result("fedel", &[(50.0, 0.4), (100.0, 0.62)], 0.62);
        let rows = table1_rows(&[fedavg, fedel], 0.95, false);
        assert!(rows[0].speedup_vs_fedavg.is_none());
        let s = rows[1].speedup_vs_fedavg.unwrap();
        // fedavg reaches 0.57 at t=200; fedel at t=100 -> 2x
        assert!((s - 2.0).abs() < 1e-9, "{s}");
    }

    #[test]
    fn runs_compare_reports_time_to_accuracy_delta() {
        use crate::store::schema::{RunManifest, RunStatus, SCHEMA_VERSION};
        let man = |id: &str, strategy: &str, curve: &[(f64, f64)], final_acc: f64| RunManifest {
            schema_version: SCHEMA_VERSION,
            id: id.into(),
            created_unix: 0,
            updated_unix: 0,
            status: RunStatus::Running,
            strategy: strategy.into(),
            config: Default::default(),
            records: fake_result(strategy, curve, final_acc).records,
            checkpoint: None,
            final_state: None,
        };
        // both reach 95% of the lesser final acc (0.95*0.6=0.57): fedavg
        // at t=200, fedel at t=100 -> fedel is 2x faster.
        let a = man("fedel-s1", "fedel", &[(50.0, 0.4), (100.0, 0.62)], 0.62);
        let b = man("fedavg-s1", "fedavg", &[(100.0, 0.3), (200.0, 0.6)], 0.6);
        let (t, speedup) = runs_compare(&a, &b, None);
        assert_eq!(t.rows.len(), 2);
        assert!((speedup.unwrap() - 2.0).abs() < 1e-9, "{speedup:?}");
        // a target nobody reaches -> no speedup, "never" rows
        let (t, none) = runs_compare(&a, &b, Some(0.99));
        assert!(none.is_none());
        assert!(t.rows.iter().all(|r| r.last().unwrap() == "never"));
    }

    #[test]
    fn never_reaching_target_uses_total_time() {
        let fedavg = fake_result("fedavg", &[(100.0, 0.5), (200.0, 0.6)], 0.6);
        let bad = fake_result("slowpoke", &[(500.0, 0.1)], 0.1);
        let rows = table1_rows(&[fedavg, bad], 0.95, false);
        assert_eq!(rows[1].time_secs, 500.0);
        assert!(rows[1].speedup_vs_fedavg.unwrap() < 1.0);
    }
}
