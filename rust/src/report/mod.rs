//! Paper-style reporting: aligned tables with paper-vs-measured rows,
//! figure data series (CSV), and the Table 1 row assembly (accuracy, time,
//! speedup vs FedAvg at matched accuracy).

pub mod bench;

use crate::fl::server::ExperimentResult;

/// A plain-text aligned table.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "ragged table row");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// One assembled Table-1 row.
#[derive(Clone, Debug)]
pub struct Table1Row {
    pub method: String,
    pub final_acc: f64,
    pub final_ppl: f64,
    /// Simulated seconds to the comparison target (or total if never hit).
    pub time_secs: f64,
    pub speedup_vs_fedavg: Option<f64>,
}

/// Assemble Table-1 rows: time is time-to-target-accuracy where target =
/// `target_frac` x the FedAvg final accuracy (the paper compares methods
/// at matched accuracy); methods that never reach the target report their
/// total time. Speedup = FedAvg time / method time.
pub fn table1_rows(
    results: &[ExperimentResult],
    target_frac: f64,
    lm: bool,
) -> Vec<Table1Row> {
    let fedavg = results
        .iter()
        .find(|r| r.strategy == "fedavg")
        .expect("table1 needs a fedavg run");
    let (fedavg_time, target) = if lm {
        let target = fedavg.final_perplexity() / target_frac;
        let t = fedavg
            .time_to_perplexity(target)
            .unwrap_or(fedavg.sim_total_secs);
        (t, target)
    } else {
        let target = fedavg.final_acc * target_frac;
        let t = fedavg.time_to_accuracy(target).unwrap_or(fedavg.sim_total_secs);
        (t, target)
    };
    results
        .iter()
        .map(|r| {
            let time_secs = if lm {
                r.time_to_perplexity(target).unwrap_or(r.sim_total_secs)
            } else {
                r.time_to_accuracy(target).unwrap_or(r.sim_total_secs)
            };
            Table1Row {
                method: r.strategy.clone(),
                final_acc: r.final_acc,
                final_ppl: r.final_perplexity(),
                time_secs,
                speedup_vs_fedavg: if r.strategy == "fedavg" {
                    None
                } else {
                    Some(fedavg_time / time_secs.max(1e-9))
                },
            }
        })
        .collect()
}

/// Render Table-1 rows in the paper's format.
pub fn render_table1(title: &str, rows: &[Table1Row], lm: bool) -> Table {
    let metric = if lm { "Perp.(down)" } else { "Acc.(up)" };
    let mut t = Table::new(title, &["Method", metric, "Time", "Speedup"]);
    for r in rows {
        t.row(vec![
            r.method.clone(),
            if lm {
                format!("{:.2}", r.final_ppl)
            } else {
                format!("{:.2}%", 100.0 * r.final_acc)
            },
            crate::util::fmt_hours(r.time_secs),
            crate::util::fmt_speedup(r.speedup_vs_fedavg),
        ]);
    }
    t
}

/// What a comparison times runs to. `Default` resolves to 95% of the
/// least final accuracy across the compared runs (the paper's
/// matched-accuracy methodology); `Loss` supports LM-style workloads
/// where the eval curve is a loss (perplexity = e^loss, so a perplexity
/// target p is `Target::Loss(p.ln())`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Target {
    Acc(f64),
    Loss(f64),
    Default,
}

/// Which metric a resolved target is expressed in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TargetMetric {
    Acc,
    Loss,
}

impl TargetMetric {
    pub fn as_str(&self) -> &'static str {
        match self {
            TargetMetric::Acc => "acc",
            TargetMetric::Loss => "loss",
        }
    }

    /// The JSON key the resolved target value rides under (`target_acc`
    /// is the pre-loss schema key, kept stable for dashboards).
    pub fn json_key(&self) -> &'static str {
        match self {
            TargetMetric::Acc => "target_acc",
            TargetMetric::Loss => "target_loss",
        }
    }
}

/// Time-to-target over a record stream, per metric.
pub fn time_to_target(
    records: &[crate::fl::server::RoundRecord],
    metric: TargetMetric,
    target: f64,
) -> Option<f64> {
    match metric {
        TargetMetric::Acc => crate::store::schema::time_to_accuracy(records, target),
        TargetMetric::Loss => crate::store::schema::time_to_loss(records, target),
    }
}

/// One run's row in an N-way comparison of stored runs.
#[derive(Clone, Debug)]
pub struct CompareRow {
    pub id: String,
    pub strategy: String,
    pub rounds: usize,
    pub final_acc: Option<f64>,
    pub sim_total_secs: f64,
    /// Simulated seconds to the report's target (None = never reached).
    pub time_to_target: Option<f64>,
    /// Baseline's time-to-target / this run's (None when either never
    /// reaches the target; 1.0 for the baseline itself).
    pub speedup_vs_baseline: Option<f64>,
}

/// N-way comparison of stored runs at a matched target — the paper's
/// time-to-accuracy methodology over whole grids. Built by
/// [`compare_runs`]; renders as a table for the terminal or as JSON
/// (`--json`) for dashboards and `campaign report`.
#[derive(Clone, Debug)]
pub struct CompareReport {
    pub metric: TargetMetric,
    /// Resolved target every run is timed to.
    pub target: f64,
    /// Run id of the speedup baseline.
    pub baseline: String,
    pub rows: Vec<CompareRow>,
}

/// Compare N *stored* runs ([`crate::store`]) at a matched target: one
/// row per run with final accuracy, simulated total, time-to-target, and
/// speedup vs `manifests[baseline]`.
pub fn compare_runs(
    manifests: &[&crate::store::schema::RunManifest],
    target: Target,
    baseline: usize,
) -> CompareReport {
    assert!(!manifests.is_empty(), "compare_runs needs at least one run");
    assert!(baseline < manifests.len(), "baseline index out of range");
    let (metric, target) = match target {
        Target::Acc(a) => (TargetMetric::Acc, a),
        Target::Loss(l) => (TargetMetric::Loss, l),
        Target::Default => {
            let least = manifests
                .iter()
                .map(|m| m.final_acc().unwrap_or(0.0))
                .fold(f64::INFINITY, f64::min);
            (TargetMetric::Acc, 0.95 * least)
        }
    };
    let base_time = time_to_target(&manifests[baseline].records, metric, target);
    let rows = manifests
        .iter()
        .map(|m| {
            let tta = time_to_target(&m.records, metric, target);
            CompareRow {
                id: m.id.clone(),
                strategy: m.strategy.clone(),
                rounds: m.records.len(),
                final_acc: m.final_acc(),
                sim_total_secs: m.sim_time(),
                time_to_target: tta,
                speedup_vs_baseline: match (base_time, tta) {
                    (Some(tb), Some(t)) => Some(tb / t.max(1e-9)),
                    _ => None,
                },
            }
        })
        .collect();
    CompareReport { metric, target, baseline: manifests[baseline].id.clone(), rows }
}

impl CompareReport {
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            &format!(
                "runs compare @ {} {:.3} (baseline {})",
                self.metric.as_str(),
                self.target,
                self.baseline
            ),
            &["run", "strategy", "rounds", "final acc", "sim total", "time-to-target", "speedup"],
        );
        for r in &self.rows {
            t.row(vec![
                r.id.clone(),
                r.strategy.clone(),
                format!("{}", r.rounds),
                r.final_acc
                    .map(|x| format!("{:.2}%", 100.0 * x))
                    .unwrap_or_else(|| "n/a".into()),
                crate::util::fmt_hours(r.sim_total_secs),
                r.time_to_target
                    .map(crate::util::fmt_hours)
                    .unwrap_or_else(|| "never".into()),
                crate::util::fmt_speedup(r.speedup_vs_baseline),
            ]);
        }
        t
    }

    /// Machine-readable form (`runs compare --json`, `campaign report
    /// --json`): target, baseline, and one object per run.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let opt = |v: Option<f64>| v.map(Json::Num).unwrap_or(Json::Null);
        Json::obj(vec![
            (self.metric.json_key(), Json::Num(self.target)),
            ("metric", Json::Str(self.metric.as_str().to_string())),
            ("baseline", Json::Str(self.baseline.clone())),
            (
                "runs",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("id", Json::Str(r.id.clone())),
                                ("strategy", Json::Str(r.strategy.clone())),
                                ("rounds", Json::Num(r.rounds as f64)),
                                ("final_acc", opt(r.final_acc)),
                                ("sim_total_secs", Json::Num(r.sim_total_secs)),
                                ("time_to_target_secs", opt(r.time_to_target)),
                                ("speedup_vs_baseline", opt(r.speedup_vs_baseline)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Two-run convenience over [`compare_runs`], preserved for callers that
/// want the original pairwise shape: the returned speedup is of `a` over
/// `b` at the target (None when either run never reaches it).
pub fn runs_compare(
    a: &crate::store::schema::RunManifest,
    b: &crate::store::schema::RunManifest,
    target: Target,
) -> (Table, Option<f64>) {
    let report = compare_runs(&[a, b], target, 1);
    let speedup = report.rows[0].speedup_vs_baseline;
    (report.table(), speedup)
}

// -- grouped (Table-3-shape) reports ----------------------------------------

/// Mean ± sample std over the values that exist (seeds that reached the
/// target, cells that stored a final accuracy, ...).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Agg {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
}

/// Aggregate a sample; None for an empty one.
pub fn aggregate(xs: &[f64]) -> Option<Agg> {
    if xs.is_empty() {
        return None;
    }
    Some(Agg { n: xs.len(), mean: crate::util::stats::mean(xs), std: crate::util::stats::std_dev(xs) })
}

impl Agg {
    fn fmt_with(&self, f: impl Fn(f64) -> String) -> String {
        format!("{} ± {}", f(self.mean), f(self.std))
    }

    fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("mean", Json::Num(self.mean)),
            ("std", Json::Num(self.std)),
            ("n", Json::Num(self.n as f64)),
        ])
    }
}

/// One aggregated row: a grid cell group (every axis binding except the
/// collapsed one) with mean ± std statistics over the collapsed axis.
#[derive(Clone, Debug)]
pub struct GroupRow {
    /// Remaining-axes bindings label (`strategy=fedel,data.alpha=0.1`).
    pub label: String,
    /// Member cells that have a stored run.
    pub cells: usize,
    pub final_acc: Option<Agg>,
    /// Over the members that reach the target (`n` says how many did).
    pub time_to_target: Option<Agg>,
    /// Over members whose *matched* baseline member (same bindings, the
    /// baseline strategy, same collapsed-axis value) also reaches it.
    pub speedup_vs_baseline: Option<Agg>,
}

/// The paper's Table-3 shape: a campaign grid collapsed over one axis
/// (typically `seed`), mean ± std per remaining cell. Built by
/// [`crate::sim::campaign::grouped_report`].
#[derive(Clone, Debug)]
pub struct GroupedReport {
    pub metric: TargetMetric,
    pub target: f64,
    /// The collapsed axis key.
    pub over: String,
    /// Baseline strategy for the speedup columns (None when the grid has
    /// no `strategy` axis to match against).
    pub baseline: Option<String>,
    pub rows: Vec<GroupRow>,
}

impl GroupedReport {
    pub fn table(&self) -> Table {
        let base = self
            .baseline
            .as_deref()
            .map(|b| format!(", speedup vs {b}"))
            .unwrap_or_default();
        let mut t = Table::new(
            &format!(
                "mean ± std over {} @ {} {:.3}{base}",
                self.over,
                self.metric.as_str(),
                self.target
            ),
            &["group", "n", "final acc", "time-to-target", "speedup"],
        );
        for r in &self.rows {
            t.row(vec![
                r.label.clone(),
                format!("{}", r.cells),
                r.final_acc
                    .map(|a| a.fmt_with(|x| format!("{:.2}%", 100.0 * x)))
                    .unwrap_or_else(|| "n/a".into()),
                r.time_to_target
                    .map(|a| format!("{} (n={})", a.fmt_with(crate::util::fmt_hours), a.n))
                    .unwrap_or_else(|| "never".into()),
                r.speedup_vs_baseline
                    .map(|a| a.fmt_with(|x| format!("{x:.2}x")))
                    .unwrap_or_else(|| "N/A".into()),
            ]);
        }
        t
    }

    /// Machine-readable form; extends the [`CompareReport::to_json`]
    /// schema with per-group aggregates.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let opt = |a: &Option<Agg>| a.as_ref().map(Agg::to_json).unwrap_or(Json::Null);
        Json::obj(vec![
            (self.metric.json_key(), Json::Num(self.target)),
            ("metric", Json::Str(self.metric.as_str().to_string())),
            ("aggregated_over", Json::Str(self.over.clone())),
            (
                "baseline_strategy",
                self.baseline.as_ref().map(|b| Json::Str(b.clone())).unwrap_or(Json::Null),
            ),
            (
                "groups",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("label", Json::Str(r.label.clone())),
                                ("n", Json::Num(r.cells as f64)),
                                ("final_acc", opt(&r.final_acc)),
                                ("time_to_target_secs", opt(&r.time_to_target)),
                                ("speedup_vs_baseline", opt(&r.speedup_vs_baseline)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Print a "paper reports" reference line under a reproduced table.
pub fn paper_note(lines: &[&str]) {
    println!("  paper reference:");
    for l in lines {
        println!("    {l}");
    }
    println!();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::server::RoundRecord;

    fn fake_result(name: &str, times_accs: &[(f64, f64)], final_acc: f64) -> ExperimentResult {
        let records = times_accs
            .iter()
            .enumerate()
            .map(|(i, &(t, a))| RoundRecord {
                round: i,
                round_secs: 0.0,
                sim_time: t,
                mean_train_loss: 0.0,
                participants: 1,
                mean_coverage: 1.0,
                o1: 0.0,
                eval_acc: Some(a),
                eval_loss: Some(1.0),
                client_secs: vec![],
                mean_staleness: None,
                max_staleness: None,
                dropped: vec![],
                spec_hits: 0,
                spec_misses: 0,
            })
            .collect();
        ExperimentResult {
            strategy: name.into(),
            records,
            sim_total_secs: times_accs.last().map(|&(t, _)| t).unwrap_or(0.0),
            final_acc,
            final_loss: 1.0,
            final_params: vec![],
            selections: vec![],
        }
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["a", "bb"]);
        t.row(vec!["xxx".into(), "y".into()]);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.contains("xxx"));
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_rows_rejected() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn speedup_vs_fedavg_at_matched_accuracy() {
        let fedavg = fake_result("fedavg", &[(100.0, 0.3), (200.0, 0.6)], 0.6);
        let fedel = fake_result("fedel", &[(50.0, 0.4), (100.0, 0.62)], 0.62);
        let rows = table1_rows(&[fedavg, fedel], 0.95, false);
        assert!(rows[0].speedup_vs_fedavg.is_none());
        let s = rows[1].speedup_vs_fedavg.unwrap();
        // fedavg reaches 0.57 at t=200; fedel at t=100 -> 2x
        assert!((s - 2.0).abs() < 1e-9, "{s}");
    }

    #[test]
    fn runs_compare_reports_time_to_accuracy_delta() {
        use crate::store::schema::{RunManifest, RunStatus, SCHEMA_VERSION};
        let man = |id: &str, strategy: &str, curve: &[(f64, f64)], final_acc: f64| RunManifest {
            schema_version: SCHEMA_VERSION,
            id: id.into(),
            created_unix: 0,
            updated_unix: 0,
            status: RunStatus::Running,
            strategy: strategy.into(),
            config: Default::default(),
            records: fake_result(strategy, curve, final_acc).records,
            checkpoint: None,
            final_state: None,
        };
        // both reach 95% of the lesser final acc (0.95*0.6=0.57): fedavg
        // at t=200, fedel at t=100 -> fedel is 2x faster.
        let a = man("fedel-s1", "fedel", &[(50.0, 0.4), (100.0, 0.62)], 0.62);
        let b = man("fedavg-s1", "fedavg", &[(100.0, 0.3), (200.0, 0.6)], 0.6);
        let (t, speedup) = runs_compare(&a, &b, Target::Default);
        assert_eq!(t.rows.len(), 2);
        assert!((speedup.unwrap() - 2.0).abs() < 1e-9, "{speedup:?}");
        // a target nobody reaches -> no speedup, "never" rows
        let (t, none) = runs_compare(&a, &b, Target::Acc(0.99));
        assert!(none.is_none());
        assert!(t.rows.iter().all(|r| r[5] == "never"));
    }

    #[test]
    fn loss_targets_walk_the_loss_curve() {
        // fake_result sets eval_loss = 1.0 on every eval point, so a loss
        // target of 1.0 is reached at the first eval and 0.5 never.
        let a = stored_manifest("fedel-s1", "fedel", &[(50.0, 0.4), (100.0, 0.62)], 0.62);
        let b = stored_manifest("fedavg-s1", "fedavg", &[(100.0, 0.3), (200.0, 0.6)], 0.6);
        let report = compare_runs(&[&a, &b], Target::Loss(1.0), 1);
        assert_eq!(report.metric, TargetMetric::Loss);
        assert_eq!(report.rows[0].time_to_target, Some(50.0));
        assert_eq!(report.rows[1].time_to_target, Some(100.0));
        assert!((report.rows[0].speedup_vs_baseline.unwrap() - 2.0).abs() < 1e-9);
        let j = crate::util::json::Json::parse(&report.to_json().to_string_pretty()).unwrap();
        assert_eq!(j.f("target_loss").unwrap(), 1.0);
        assert_eq!(j.s("metric").unwrap(), "loss");
        let never = compare_runs(&[&a, &b], Target::Loss(0.5), 1);
        assert!(never.rows.iter().all(|r| r.time_to_target.is_none()));
    }

    #[test]
    fn aggregate_mean_std_over_samples() {
        assert_eq!(aggregate(&[]), None);
        let one = aggregate(&[3.0]).unwrap();
        assert_eq!((one.n, one.mean, one.std), (1, 3.0, 0.0));
        let a = aggregate(&[1.0, 2.0, 3.0]).unwrap();
        assert_eq!(a.n, 3);
        assert!((a.mean - 2.0).abs() < 1e-12);
        assert!((a.std - 1.0).abs() < 1e-12, "{}", a.std);
    }

    #[test]
    fn grouped_report_renders_and_serializes() {
        let rep = GroupedReport {
            metric: TargetMetric::Acc,
            target: 0.57,
            over: "seed".into(),
            baseline: Some("fedavg".into()),
            rows: vec![
                GroupRow {
                    label: "strategy=fedel".into(),
                    cells: 3,
                    final_acc: aggregate(&[0.6, 0.62, 0.61]),
                    time_to_target: aggregate(&[100.0, 110.0]),
                    speedup_vs_baseline: aggregate(&[2.0, 1.8]),
                },
                GroupRow {
                    label: "strategy=slowpoke".into(),
                    cells: 3,
                    final_acc: aggregate(&[0.1, 0.2, 0.15]),
                    time_to_target: None,
                    speedup_vs_baseline: None,
                },
            ],
        };
        let t = rep.table();
        assert_eq!(t.rows.len(), 2);
        assert!(t.rows[0][3].contains("±"), "{}", t.rows[0][3]);
        assert_eq!(t.rows[1][3], "never");
        let j = crate::util::json::Json::parse(&rep.to_json().to_string_pretty()).unwrap();
        assert_eq!(j.s("aggregated_over").unwrap(), "seed");
        let groups = j.arr("groups").unwrap();
        assert_eq!(groups.len(), 2);
        let tta = groups[0].req("time_to_target_secs").unwrap();
        assert_eq!(tta.f("n").unwrap(), 2.0);
        assert!((tta.f("mean").unwrap() - 105.0).abs() < 1e-9);
        assert_eq!(groups[1].get("speedup_vs_baseline"), Some(&crate::util::json::Json::Null));
    }

    fn stored_manifest(
        id: &str,
        strategy: &str,
        curve: &[(f64, f64)],
        final_acc: f64,
    ) -> crate::store::schema::RunManifest {
        use crate::store::schema::{RunManifest, RunStatus, SCHEMA_VERSION};
        RunManifest {
            schema_version: SCHEMA_VERSION,
            id: id.into(),
            created_unix: 0,
            updated_unix: 0,
            status: RunStatus::Running,
            strategy: strategy.into(),
            config: Default::default(),
            records: fake_result(strategy, curve, final_acc).records,
            checkpoint: None,
            final_state: None,
        }
    }

    #[test]
    fn compare_runs_generalizes_to_n_with_baseline() {
        let a = stored_manifest("fedel-s1", "fedel", &[(50.0, 0.4), (100.0, 0.62)], 0.62);
        let b = stored_manifest("timelyfl-s1", "timelyfl", &[(150.0, 0.58)], 0.58);
        let c = stored_manifest("fedavg-s1", "fedavg", &[(100.0, 0.3), (200.0, 0.6)], 0.6);
        // least final acc = 0.58 -> target 0.551; fedel hits at 100,
        // timelyfl at 150, fedavg (baseline) at 200
        let report = compare_runs(&[&a, &b, &c], Target::Default, 2);
        assert_eq!(report.baseline, "fedavg-s1");
        assert_eq!(report.rows.len(), 3);
        assert!((report.rows[0].speedup_vs_baseline.unwrap() - 2.0).abs() < 1e-9);
        assert!((report.rows[1].speedup_vs_baseline.unwrap() - 200.0 / 150.0).abs() < 1e-9);
        assert!((report.rows[2].speedup_vs_baseline.unwrap() - 1.0).abs() < 1e-9);
        assert_eq!(report.table().rows.len(), 3);
    }

    #[test]
    fn compare_report_json_round_trips_through_text() {
        use crate::util::json::Json;
        let a = stored_manifest("fedel-s1", "fedel", &[(50.0, 0.4), (100.0, 0.62)], 0.62);
        let b = stored_manifest("fedavg-s1", "fedavg", &[(100.0, 0.3), (200.0, 0.6)], 0.6);
        let report = compare_runs(&[&a, &b], Target::Acc(0.57), 1);
        let j = Json::parse(&report.to_json().to_string_pretty()).unwrap();
        assert_eq!(j.f("target_acc").unwrap(), 0.57);
        assert_eq!(j.s("baseline").unwrap(), "fedavg-s1");
        let runs = j.arr("runs").unwrap();
        assert_eq!(runs.len(), 2);
        assert_eq!(runs[0].s("strategy").unwrap(), "fedel");
        assert_eq!(runs[0].f("time_to_target_secs").unwrap(), 100.0);
        assert!((runs[0].f("speedup_vs_baseline").unwrap() - 2.0).abs() < 1e-9);
        // a run that never reaches the target serializes nulls, not 0s
        let strict = compare_runs(&[&a, &b], Target::Acc(0.99), 1);
        let j = Json::parse(&strict.to_json().to_string_pretty()).unwrap();
        assert_eq!(j.arr("runs").unwrap()[0].get("time_to_target_secs"), Some(&Json::Null));
    }

    #[test]
    fn never_reaching_target_uses_total_time() {
        let fedavg = fake_result("fedavg", &[(100.0, 0.5), (200.0, 0.6)], 0.6);
        let bad = fake_result("slowpoke", &[(500.0, 0.1)], 0.1);
        let rows = table1_rows(&[fedavg, bad], 0.95, false);
        assert_eq!(rows[1].time_secs, 500.0);
        assert!(rows[1].speedup_vs_fedavg.unwrap() < 1.0);
    }
}
