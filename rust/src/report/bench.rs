//! Shared bench support: workload presets matching the paper's four
//! evaluation columns, paper reference numbers for side-by-side printing,
//! and a scale knob so `cargo bench` finishes in minutes by default while
//! `FEDEL_BENCH_SCALE=full` reproduces closer-to-paper round counts.

use crate::config::{ExperimentCfg, FleetSpec};

/// Bench scale from the environment: "quick" (default) or "full".
pub fn full_scale() -> bool {
    std::env::var("FEDEL_BENCH_SCALE").map(|v| v == "full").unwrap_or(false)
}

/// Scale a round count by the bench scale.
pub fn rounds(quick: usize, full: usize) -> usize {
    if full_scale() {
        full
    } else {
        quick
    }
}

/// The paper's four Table-1 workloads. `slowest_round_secs` pins the
/// simulated clock to Appendix B.3 Table 2's measured FedAvg round times,
/// so reproduced hours are in the paper's units.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Workload {
    /// CIFAR10-like VGG, 10-device testbed.
    Cifar10Dev,
    /// TinyImageNet-like VGG, 100-device simulation.
    TinyIn100Dev,
    /// Google-Speech-like ResNet, 100-device simulation.
    Speech100Dev,
    /// Reddit-like LM, 100-device simulation.
    Reddit100Dev,
}

impl Workload {
    pub fn all() -> [Workload; 4] {
        [
            Workload::Cifar10Dev,
            Workload::TinyIn100Dev,
            Workload::Speech100Dev,
            Workload::Reddit100Dev,
        ]
    }

    pub fn label(&self) -> &'static str {
        match self {
            Workload::Cifar10Dev => "Image Classif. (10 dev, CIFAR10-like)",
            Workload::TinyIn100Dev => "Image Classif. (100 dev, TinyImageNet-like)",
            Workload::Speech100Dev => "Speech Recog. (100 dev)",
            Workload::Reddit100Dev => "NLP next-word (100 dev)",
        }
    }

    pub fn model(&self) -> &'static str {
        match self {
            Workload::Cifar10Dev => "vgg_cifar",
            Workload::TinyIn100Dev => "vgg_tinyin",
            Workload::Speech100Dev => "resnet_speech",
            Workload::Reddit100Dev => "tinylm_reddit",
        }
    }

    pub fn is_lm(&self) -> bool {
        matches!(self, Workload::Reddit100Dev)
    }

    /// Paper Appendix B.3 Table 2 FedAvg per-round minutes (slowest dev).
    pub fn fedavg_round_mins(&self) -> f64 {
        match self {
            Workload::Cifar10Dev => 71.8,
            Workload::TinyIn100Dev => 161.9,
            Workload::Speech100Dev => 212.9,
            Workload::Reddit100Dev => 152.1,
        }
    }

    /// Paper Appendix B.3 Table 2 T_th minutes.
    pub fn t_th_mins(&self) -> f64 {
        match self {
            Workload::Cifar10Dev => 36.0,
            Workload::TinyIn100Dev => 42.2,
            Workload::Speech100Dev => 53.2,
            Workload::Reddit100Dev => 40.9,
        }
    }

    /// Bench-sized experiment config for this workload. `clients_cap`
    /// subsamples the 100-device fleets at quick scale.
    pub fn cfg(&self, seed: u64) -> ExperimentCfg {
        let full = full_scale();
        let (fleet, rounds, steps) = match self {
            Workload::Cifar10Dev => (
                FleetSpec::Small10,
                if full { 150 } else { 40 },
                4,
            ),
            Workload::TinyIn100Dev => (
                FleetSpec::Large(if full { 100 } else { 20 }),
                if full { 120 } else { 16 },
                4,
            ),
            Workload::Speech100Dev => (
                FleetSpec::Large(if full { 100 } else { 12 }),
                if full { 120 } else { 10 },
                4,
            ),
            Workload::Reddit100Dev => (
                FleetSpec::Large(if full { 100 } else { 10 }),
                if full { 80 } else { 10 },
                2,
            ),
        };
        ExperimentCfg {
            model: self.model().into(),
            artifacts_dir: "artifacts".into(),
            strategy: "fedel".into(),
            fleet,
            rounds,
            local_steps: steps,
            lr: if self.is_lm() { 0.1 } else { 0.04 },
            alpha: 0.1,
            t_th_factor: 1.0,
            slowest_round_secs: self.fedavg_round_mins() * 60.0,
            seed,
            eval_every: (rounds / 8).max(2),
            eval_batches: if full { 16 } else { 6 },
            comm_secs: 30.0,
            comm_up_mbps: 0.0,
            comm_down_mbps: 0.0,
            comm_latency_secs: 0.0,
            exec_threads: 0,
            strategy_params: Vec::new(),
            record_selections: false,
            verbose: false,
            halt_after: None,
        }
    }
}

/// Paper Table 1 reference rows: (method, metric, hours, speedup-str).
/// metric is accuracy% except the NLP column (perplexity).
pub fn paper_table1(w: Workload) -> Vec<(&'static str, f64, f64, &'static str)> {
    match w {
        Workload::Cifar10Dev => vec![
            ("fedavg", 56.13, 119.8, "N/A"),
            ("elastictrainer", 40.03, 64.8, "1.84x"),
            ("heterofl", 53.44, 80.1, "1.49x"),
            ("depthfl", 54.89, 77.3, "1.54x"),
            ("pyramidfl", 56.24, 115.7, "1.03x"),
            ("timelyfl", 53.74, 66.3, "1.81x"),
            ("fiarse", 56.48, 71.9, "1.66x"),
            ("fedel", 56.51, 63.8, "1.87x"),
        ],
        Workload::TinyIn100Dev => vec![
            ("fedavg", 33.76, 563.1, "N/A"),
            ("elastictrainer", 27.65, 158.6, "3.55x"),
            ("heterofl", 30.56, 248.2, "2.26x"),
            ("depthfl", 34.14, 198.3, "2.83x"),
            ("pyramidfl", 34.70, 497.4, "1.13x"),
            ("timelyfl", 33.53, 198.1, "2.84x"),
            ("fiarse", 33.98, 191.5, "2.94x"),
            ("fedel", 34.96, 156.8, "3.59x"),
        ],
        Workload::Speech100Dev => vec![
            ("fedavg", 58.04, 709.8, "N/A"),
            ("elastictrainer", 47.96, 184.3, "3.84x"),
            ("heterofl", 51.47, 265.9, "2.66x"),
            ("depthfl", 54.23, 207.4, "3.42x"),
            ("pyramidfl", 58.12, 587.4, "1.21x"),
            ("timelyfl", 56.49, 193.2, "3.67x"),
            ("fiarse", 58.13, 198.2, "3.58x"),
            ("fedel", 58.26, 183.3, "3.87x"),
        ],
        Workload::Reddit100Dev => vec![
            ("fedavg", 77.48, 546.4, "N/A"),
            ("elastictrainer", 81.02, 176.2, "3.10x"),
            ("heterofl", 80.11, 206.1, "2.65x"),
            ("depthfl", 78.08, 212.4, "2.57x"),
            ("pyramidfl", 77.68, 418.2, "1.31x"),
            ("timelyfl", 80.91, 177.6, "3.07x"),
            ("fiarse", 77.31, 191.0, "2.86x"),
            ("fedel", 77.23, 174.5, "3.13x"),
        ],
    }
}

/// Micro-benchmark helper: median wall time of `f` over `iters` runs.
pub fn time_median<F: FnMut()>(iters: usize, mut f: F) -> std::time::Duration {
    let mut samples: Vec<std::time::Duration> = (0..iters)
        .map(|_| {
            let t0 = std::time::Instant::now();
            f();
            t0.elapsed()
        })
        .collect();
    samples.sort();
    samples[samples.len() / 2]
}

/// Standard bench banner.
pub fn banner(id: &str, what: &str) {
    println!("\n######## {id}: {what} ########");
    println!(
        "scale: {} (set FEDEL_BENCH_SCALE=full for paper-scale rounds)\n",
        if full_scale() { "full" } else { "quick" }
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_cfgs_are_wellformed() {
        for w in Workload::all() {
            let cfg = w.cfg(1);
            assert!(cfg.rounds > 0 && cfg.local_steps > 0);
            assert_eq!(cfg.slowest_round_secs, w.fedavg_round_mins() * 60.0);
        }
    }

    #[test]
    fn paper_tables_have_all_methods() {
        for w in Workload::all() {
            let t = paper_table1(w);
            assert_eq!(t.len(), 8);
            assert_eq!(t[0].0, "fedavg");
            assert_eq!(t[7].0, "fedel");
        }
    }

    #[test]
    fn time_median_measures() {
        let d = time_median(5, || std::thread::sleep(std::time::Duration::from_millis(1)));
        assert!(d.as_millis() >= 1);
    }
}
