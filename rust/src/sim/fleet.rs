//! Fleet construction: per-client device profiles.

use crate::config::FleetSpec;
use crate::timing::DeviceProfile;
use crate::util::rng::Rng;

/// Build the per-client device list for a fleet spec.
///
/// * `Small10` — the paper's testbed: clients 0-4 are Jetson Xavier
///   (2x slower), clients 5-9 are Jetson Orin (the base profile).
/// * `Large(n)` — the paper's simulation: each client is a uniformly
///   random draw from the four device types {1, 1/2, 1/3, 1/4}x.
/// * `Scales` — explicit per-client scale factors.
pub fn build_fleet(spec: &FleetSpec, seed: u64) -> Vec<DeviceProfile> {
    match spec {
        FleetSpec::Small10 => {
            let mut v = vec![DeviceProfile::xavier(); 5];
            v.extend(vec![DeviceProfile::orin(); 5]);
            v
        }
        FleetSpec::Large(n) => {
            let types = DeviceProfile::sim_types();
            let mut rng = Rng::new(seed ^ 0xF1EE7);
            (0..*n).map(|_| types[rng.below(types.len())].clone()).collect()
        }
        FleetSpec::Scales(scales) => scales
            .iter()
            .enumerate()
            .map(|(i, &s)| DeviceProfile::new(&format!("dev{i}x{s}"), s, 12.0))
            .collect(),
    }
}

/// The fastest (smallest scale) device in a fleet.
pub fn fastest(fleet: &[DeviceProfile]) -> &DeviceProfile {
    fleet
        .iter()
        .min_by(|a, b| a.scale.partial_cmp(&b.scale).unwrap())
        .expect("empty fleet")
}

/// The slowest (largest scale) device in a fleet.
pub fn slowest(fleet: &[DeviceProfile]) -> &DeviceProfile {
    fleet
        .iter()
        .max_by(|a, b| a.scale.partial_cmp(&b.scale).unwrap())
        .expect("empty fleet")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small10_is_five_xavier_five_orin() {
        let f = build_fleet(&FleetSpec::Small10, 0);
        assert_eq!(f.len(), 10);
        assert_eq!(f.iter().filter(|d| d.name == "xavier").count(), 5);
        assert_eq!(f.iter().filter(|d| d.name == "orin").count(), 5);
        assert_eq!(fastest(&f).name, "orin");
        assert_eq!(slowest(&f).name, "xavier");
    }

    #[test]
    fn large_fleet_uses_all_four_types() {
        let f = build_fleet(&FleetSpec::Large(100), 7);
        assert_eq!(f.len(), 100);
        let mut names: Vec<&str> = f.iter().map(|d| d.name.as_str()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 4, "{names:?}");
    }

    #[test]
    fn large_fleet_deterministic_per_seed() {
        let a = build_fleet(&FleetSpec::Large(20), 3);
        let b = build_fleet(&FleetSpec::Large(20), 3);
        let names = |f: &[DeviceProfile]| f.iter().map(|d| d.name.clone()).collect::<Vec<_>>();
        assert_eq!(names(&a), names(&b));
    }

    #[test]
    fn scales_spec_respected() {
        let f = build_fleet(&FleetSpec::Scales(vec![1.0, 3.5]), 0);
        assert_eq!(f.len(), 2);
        assert_eq!(f[1].scale, 3.5);
    }
}
