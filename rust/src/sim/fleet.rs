//! Fleet construction: per-client device profiles.

use crate::config::FleetSpec;
use crate::fleet::{FleetView, LazyFleet, DEFAULT_POWER_WATTS};
use crate::timing::DeviceProfile;
use crate::util::rng::Rng;

/// Build the per-client device list for a fleet spec.
///
/// * `Small10` — the paper's testbed: clients 0-4 are Jetson Xavier
///   (2x slower), clients 5-9 are Jetson Orin (the base profile).
/// * `Large(n)` — the paper's simulation: each client is a uniformly
///   random draw from the four device types {1, 1/2, 1/3, 1/4}x.
/// * `Scales` — explicit per-client scale factors (power defaults to
///   [`DEFAULT_POWER_WATTS`]; custom powers come from a generator or a
///   `fleet.trace` file).
/// * `Lazy` — materializes the generated fleet eagerly; million-client
///   runs should go through [`crate::fleet::LazyFleet`] instead (the
///   experiment builder does).
pub fn build_fleet(spec: &FleetSpec, seed: u64) -> anyhow::Result<Vec<DeviceProfile>> {
    Ok(match spec {
        FleetSpec::Small10 => {
            let mut v = vec![DeviceProfile::xavier(); 5];
            v.extend(vec![DeviceProfile::orin(); 5]);
            v
        }
        FleetSpec::Large(n) => {
            let types = DeviceProfile::sim_types();
            let mut rng = Rng::new(seed ^ 0xF1EE7);
            (0..*n).map(|_| types[rng.below(types.len())].clone()).collect()
        }
        FleetSpec::Scales(scales) => scales
            .iter()
            .enumerate()
            .map(|(i, &s)| DeviceProfile::new(&format!("dev{i}x{s}"), s, DEFAULT_POWER_WATTS))
            .collect(),
        FleetSpec::Lazy { n, generator } => {
            let lf = LazyFleet::new(*n, generator.clone(), seed)?;
            (0..*n).map(|c| lf.profile(c).device).collect()
        }
    })
}

/// The fastest (smallest scale) device in a fleet. Errors on empty fleets
/// and non-finite scales instead of panicking — fleet contents are user
/// input (trace files, `--fleet` specs).
pub fn fastest(fleet: &[DeviceProfile]) -> anyhow::Result<&DeviceProfile> {
    extremum(fleet, "fastest", false)
}

/// The slowest (largest scale) device in a fleet.
pub fn slowest(fleet: &[DeviceProfile]) -> anyhow::Result<&DeviceProfile> {
    extremum(fleet, "slowest", true)
}

fn extremum<'f>(
    fleet: &'f [DeviceProfile],
    which: &str,
    largest: bool,
) -> anyhow::Result<&'f DeviceProfile> {
    if let Some(bad) = fleet.iter().find(|d| !d.scale.is_finite()) {
        anyhow::bail!("device {:?} has non-finite scale {}", bad.name, bad.scale);
    }
    let pick = if largest {
        fleet.iter().max_by(|a, b| a.scale.total_cmp(&b.scale))
    } else {
        fleet.iter().min_by(|a, b| a.scale.total_cmp(&b.scale))
    };
    pick.ok_or_else(|| anyhow::anyhow!("cannot take the {which} device of an empty fleet"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small10_is_five_xavier_five_orin() {
        let f = build_fleet(&FleetSpec::Small10, 0).unwrap();
        assert_eq!(f.len(), 10);
        assert_eq!(f.iter().filter(|d| d.name == "xavier").count(), 5);
        assert_eq!(f.iter().filter(|d| d.name == "orin").count(), 5);
        assert_eq!(fastest(&f).unwrap().name, "orin");
        assert_eq!(slowest(&f).unwrap().name, "xavier");
    }

    #[test]
    fn large_fleet_uses_all_four_types() {
        let f = build_fleet(&FleetSpec::Large(100), 7).unwrap();
        assert_eq!(f.len(), 100);
        let mut names: Vec<&str> = f.iter().map(|d| d.name.as_str()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 4, "{names:?}");
    }

    #[test]
    fn large_fleet_deterministic_per_seed() {
        let a = build_fleet(&FleetSpec::Large(20), 3).unwrap();
        let b = build_fleet(&FleetSpec::Large(20), 3).unwrap();
        let names = |f: &[DeviceProfile]| f.iter().map(|d| d.name.clone()).collect::<Vec<_>>();
        assert_eq!(names(&a), names(&b));
    }

    #[test]
    fn scales_spec_respected() {
        let f = build_fleet(&FleetSpec::Scales(vec![1.0, 3.5]), 0).unwrap();
        assert_eq!(f.len(), 2);
        assert_eq!(f[1].scale, 3.5);
        assert_eq!(f[0].power_watts, DEFAULT_POWER_WATTS);
    }

    #[test]
    fn lazy_spec_materializes_matching_the_lazy_view() {
        let spec = FleetSpec::parse("lazy64:lognormal:0:0.5").unwrap();
        let f = build_fleet(&spec, 11).unwrap();
        assert_eq!(f.len(), 64);
        let FleetSpec::Lazy { n, generator } = &spec else { unreachable!() };
        let lf = LazyFleet::new(*n, generator.clone(), 11).unwrap();
        for (c, d) in f.iter().enumerate() {
            assert_eq!(d.name, lf.profile(c).device.name);
        }
    }

    // Regression: these used to panic (`expect("empty fleet")` /
    // `partial_cmp().unwrap()` on NaN scales).
    #[test]
    fn empty_fleet_is_an_error_not_a_panic() {
        assert!(fastest(&[]).unwrap_err().to_string().contains("empty fleet"));
        assert!(slowest(&[]).is_err());
    }

    #[test]
    fn nan_scale_is_an_error_not_a_panic() {
        let f = vec![DeviceProfile::orin(), DeviceProfile::new("bad", f64::NAN, 1.0)];
        assert!(fastest(&f).unwrap_err().to_string().contains("non-finite"));
        assert!(slowest(&f).is_err());
    }
}
